//! Multilevel bisection: coarsen → greedy-growing initial split →
//! FM refinement → project back with per-level refinement.

use super::coarsen;
use super::refine;
use crate::graph::csr::CsrGraph;
use crate::util::rng::Rng;

/// Bisect into two roughly equal halves with generous (25%) balance
/// slack — the right mode for `partition_by_max_size`, where only the
/// max-part-size bound matters and forcing exact halves would split
/// natural communities. Returns `side[v]` (false=left).
pub fn bisect(g: &CsrGraph, seed: u64) -> Vec<bool> {
    bisect_slack(g, g.n() / 2, 0.25, seed)
}

/// Bisect with an explicit left-side size target, enforced exactly
/// (within +-1) — the mode `partition_kway` needs for balanced parts.
pub fn bisect_with_target(g: &CsrGraph, target_left: usize, seed: u64) -> Vec<bool> {
    let mut side = bisect_slack(g, target_left, 0.05, seed);
    rebalance(g, &mut side, target_left, 0);
    side
}

/// Multilevel bisection with a balance slack fraction: the final left
/// side lands within `slack_frac * n` of `target_left`, wherever the
/// cut is cheapest.
pub fn bisect_slack(g: &CsrGraph, target_left: usize, slack_frac: f64, seed: u64) -> Vec<bool> {
    let n = g.n();
    if n <= 1 {
        return vec![false; n];
    }
    let target_left = target_left.clamp(1, n - 1);
    let mut rng = Rng::new(seed);

    // ---- coarsen
    let coarse_target = 200.max(n / 64).min(n);
    let levels = coarsen_to(g, coarse_target, &mut rng);

    // ---- initial partition on the coarsest graph (weighted target)
    let (coarsest, vwgt): (&CsrGraph, Vec<u32>) = match levels.last() {
        Some(l) => (&l.graph, l.vwgt.clone()),
        None => (g, vec![1u32; n]),
    };
    let frac = target_left as f64 / n as f64;
    let coarse_total: u64 = vwgt.iter().map(|&w| w as u64).sum();
    let coarse_target_left = ((coarse_total as f64) * frac).round() as u64;
    let mut side = greedy_grow(coarsest, &vwgt, coarse_target_left, &mut rng);
    refine::fm_refine_slack(coarsest, &vwgt, &mut side, coarse_target_left, 8, slack_frac);

    // ---- project back through the levels, refining each time
    for i in (0..levels.len()).rev() {
        let fine_graph: &CsrGraph = if i == 0 { g } else { &levels[i - 1].graph };
        let fine_vwgt: Vec<u32> = if i == 0 {
            vec![1u32; g.n()]
        } else {
            levels[i - 1].vwgt.clone()
        };
        let map = &levels[i].map;
        let mut fine_side = vec![false; fine_graph.n()];
        for v in 0..fine_graph.n() {
            fine_side[v] = side[map[v] as usize];
        }
        let fine_total: u64 = fine_vwgt.iter().map(|&w| w as u64).sum();
        let fine_target_left = ((fine_total as f64) * frac).round() as u64;
        refine::fm_refine_slack(
            fine_graph,
            &fine_vwgt,
            &mut fine_side,
            fine_target_left,
            4,
            slack_frac,
        );
        side = fine_side;
    }
    debug_assert_eq!(side.len(), n);
    let slack = ((n as f64) * slack_frac) as usize;
    rebalance(g, &mut side, target_left, slack);
    side
}

fn coarsen_to(g: &CsrGraph, target: usize, rng: &mut Rng) -> Vec<coarsen::CoarseLevel> {
    coarsen::coarsen_to(g, target, rng)
}

/// Greedy graph growing: BFS from a random seed, absorbing vertices until
/// the left side reaches the weight target. Disconnected leftovers stay
/// right.
fn greedy_grow(g: &CsrGraph, vwgt: &[u32], target_left: u64, rng: &mut Rng) -> Vec<bool> {
    let n = g.n();
    let mut side = vec![true; n]; // true = right
    if n == 0 {
        return side;
    }
    let mut grown: u64 = 0;
    let mut visited = vec![false; n];
    let mut queue = std::collections::VecDeque::new();
    while grown < target_left {
        // (re)seed from an unvisited vertex (handles disconnected graphs)
        if queue.is_empty() {
            let mut start = rng.gen_range(n);
            let mut tries = 0;
            while visited[start] && tries < n {
                start = (start + 1) % n;
                tries += 1;
            }
            if visited[start] {
                break;
            }
            visited[start] = true;
            queue.push_back(start);
        }
        if let Some(v) = queue.pop_front() {
            side[v] = false;
            grown += vwgt[v] as u64;
            for (u, _) in g.neighbors(v) {
                if !visited[u] {
                    visited[u] = true;
                    queue.push_back(u);
                }
            }
        }
    }
    side
}

/// Pull the left side size to within `slack` of `target_left` by moving
/// the cheapest boundary vertices (ensures downstream size invariants;
/// `slack = 0` forces the target exactly).
fn rebalance(g: &CsrGraph, side: &mut [bool], target_left: usize, slack: usize) {
    let n = side.len();
    let count_left = side.iter().filter(|&&s| !s).count();
    let (from_right, deficit) = if count_left + slack < target_left {
        (true, target_left - slack - count_left)
    } else if count_left > target_left + slack {
        (false, count_left - target_left - slack)
    } else {
        return;
    };
    if deficit == 0 {
        return;
    }
    // score candidates by how "attached" they are to the destination side
    let mut cands: Vec<(i64, usize)> = (0..n)
        .filter(|&v| side[v] == from_right)
        .map(|v| {
            let mut gain = 0i64;
            for (u, _) in g.neighbors(v) {
                if side[u] == from_right {
                    gain -= 1;
                } else {
                    gain += 1;
                }
            }
            (-gain, v) // sort ascending => best gain first
        })
        .collect();
    cands.sort_unstable();
    for &(_, v) in cands.iter().take(deficit) {
        side[v] = !side[v];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, Weights};

    #[test]
    fn bisect_halves_within_slack() {
        let g = generators::newman_watts_strogatz(300, 4, 0.05, Weights::Unit, 1);
        let side = bisect(&g, 42);
        let left = side.iter().filter(|&&s| !s).count();
        // 25% slack around n/2: the cut lands where it is cheapest
        assert!((75..=225).contains(&left), "left={left}");
    }

    #[test]
    fn bisect_with_target_exact() {
        let g = generators::random_connected(100, 80, Weights::Unit, 2);
        for target in [10usize, 33, 50, 90] {
            let side = bisect_with_target(&g, target, 7);
            let left = side.iter().filter(|&&s| !s).count();
            assert_eq!(left, target, "target {target}");
        }
    }

    #[test]
    fn cut_quality_on_two_cliques() {
        // two dense cliques joined by one bridge: ideal cut = 1 edge
        let mut edges = Vec::new();
        for u in 0..20u32 {
            for v in (u + 1)..20 {
                edges.push((u, v, 1.0f32));
            }
        }
        for u in 20..40u32 {
            for v in (u + 1)..40 {
                edges.push((u, v, 1.0));
            }
        }
        edges.push((5, 25, 1.0));
        let g = CsrGraph::from_undirected_edges(40, &edges);
        let side = bisect(&g, 3);
        // sides must separate the cliques
        let first_clique_side = side[0];
        assert!(
            (0..20).all(|v| side[v] == first_clique_side),
            "clique A split"
        );
        assert!(
            (20..40).all(|v| side[v] != first_clique_side),
            "clique B split"
        );
    }

    #[test]
    fn tiny_graphs() {
        let g = CsrGraph::empty(1);
        assert_eq!(bisect(&g, 1), vec![false]);
        let g2 = CsrGraph::from_undirected_edges(2, &[(0, 1, 1.0)]);
        let s = bisect(&g2, 1);
        assert_ne!(s[0], s[1]);
    }
}
