//! Boundary FM refinement: greedy gain-ordered vertex moves with a
//! balance constraint — the uncoarsening-phase refinement of the
//! multilevel scheme [24] (simplified Fiduccia–Mattheyses).

use crate::graph::csr::CsrGraph;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Refine a bisection in place. `side[v]` false=left/true=right;
/// `target_left` is the desired total left vertex weight; `passes`
/// bounds the number of full sweeps. Only moves that keep
/// `|left - target| <= max(3, slack_frac of total)` are allowed.
pub fn fm_refine(
    g: &CsrGraph,
    vwgt: &[u32],
    side: &mut [bool],
    target_left: u64,
    passes: usize,
) {
    fm_refine_slack(g, vwgt, side, target_left, passes, 0.05)
}

/// `fm_refine` with an explicit balance slack fraction.
pub fn fm_refine_slack(
    g: &CsrGraph,
    vwgt: &[u32],
    side: &mut [bool],
    target_left: u64,
    passes: usize,
    slack_frac: f64,
) {
    let n = g.n();
    if n < 4 {
        return;
    }
    let total: u64 = vwgt.iter().map(|&w| w as u64).sum();
    let slack = ((total as f64) * slack_frac).max(3.0) as i64;
    let mut left_weight: i64 = (0..n).filter(|&v| !side[v]).map(|v| vwgt[v] as i64).sum();
    let target = target_left as i64;

    for _ in 0..passes {
        // gain[v] = cut reduction if v moves to the other side
        let gain = |v: usize, side: &[bool]| -> f64 {
            let mut ext = 0.0f64;
            let mut int = 0.0f64;
            for (u, w) in g.neighbors(v) {
                if side[u] == side[v] {
                    int += w as f64;
                } else {
                    ext += w as f64;
                }
            }
            ext - int
        };
        // max-heap of boundary vertices by gain
        let mut heap: BinaryHeap<(i64, Reverse<usize>)> = BinaryHeap::new();
        for v in 0..n {
            let on_boundary = g.neighbors(v).any(|(u, _)| side[u] != side[v]);
            if on_boundary {
                heap.push(((gain(v, side) * 1024.0) as i64, Reverse(v)));
            }
        }
        let mut moved = vec![false; n];
        let mut improved = false;
        while let Some((g1024, Reverse(v))) = heap.pop() {
            if moved[v] {
                continue;
            }
            // recompute (lazy invalidation)
            let cur = (gain(v, side) * 1024.0) as i64;
            if cur < g1024 {
                if cur > 0 {
                    heap.push((cur, Reverse(v)));
                }
                continue;
            }
            if cur <= 0 {
                break; // no positive-gain moves left
            }
            // balance check
            let delta = if side[v] { vwgt[v] as i64 } else { -(vwgt[v] as i64) };
            let new_left = left_weight + delta;
            if (new_left - target).abs() > slack {
                continue;
            }
            // apply move
            side[v] = !side[v];
            left_weight = new_left;
            moved[v] = true;
            improved = true;
            // neighbors' gains changed; re-push
            for (u, _) in g.neighbors(v) {
                if !moved[u] {
                    let ug = (gain(u, side) * 1024.0) as i64;
                    if ug > 0 {
                        heap.push((ug, Reverse(u)));
                    }
                }
            }
        }
        if !improved {
            break;
        }
    }
}

/// Edge cut of a bisection (test helper, exported for kway tests).
pub fn cut_of(g: &CsrGraph, side: &[bool]) -> f64 {
    let mut cut = 0.0;
    for (u, v, w) in g.edges() {
        if side[u as usize] != side[v as usize] {
            cut += w as f64;
        }
    }
    cut / 2.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, Weights};
    use crate::util::rng::Rng;

    #[test]
    fn refinement_never_worsens_cut() {
        for seed in 0..5u64 {
            let g =
                generators::newman_watts_strogatz(200, 4, 0.1, Weights::Uniform(1.0, 4.0), seed);
            let mut rng = Rng::new(seed);
            let mut side: Vec<bool> = (0..g.n()).map(|_| rng.gen_bool(0.5)).collect();
            let before = cut_of(&g, &side);
            let vwgt = vec![1u32; g.n()];
            fm_refine(&g, &vwgt, &mut side, (g.n() / 2) as u64, 6);
            let after = cut_of(&g, &side);
            assert!(after <= before + 1e-9, "seed {seed}: {before} -> {after}");
        }
    }

    #[test]
    fn refinement_respects_balance_slack() {
        let g = generators::random_connected(300, 200, Weights::Unit, 9);
        let mut side: Vec<bool> = (0..g.n()).map(|v| v % 2 == 1).collect();
        let vwgt = vec![1u32; g.n()];
        fm_refine(&g, &vwgt, &mut side, 150, 6);
        let left = side.iter().filter(|&&s| !s).count() as i64;
        assert!((left - 150).abs() <= 15, "left={left}");
    }

    #[test]
    fn fixes_obvious_misassignment() {
        // two cliques with one vertex planted on the wrong side
        let mut edges = Vec::new();
        for u in 0..10u32 {
            for v in (u + 1)..10 {
                edges.push((u, v, 1.0f32));
            }
        }
        for u in 10..20u32 {
            for v in (u + 1)..20 {
                edges.push((u, v, 1.0));
            }
        }
        edges.push((0, 10, 1.0));
        let g = CsrGraph::from_undirected_edges(20, &edges);
        let mut side: Vec<bool> = (0..20).map(|v| v >= 10).collect();
        side[5] = true; // misplace one clique-A vertex
        side[15] = false; // and one clique-B vertex (keeps balance)
        let vwgt = vec![1u32; 20];
        let before = cut_of(&g, &side);
        fm_refine(&g, &vwgt, &mut side, 10, 4);
        let after = cut_of(&g, &side);
        assert!(after < before);
        assert_eq!(after, 1.0, "should recover the single-bridge cut");
    }
}
