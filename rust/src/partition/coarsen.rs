//! Coarsening phase: heavy-edge matching (HEM) + coarse-graph build,
//! the first phase of the multilevel scheme [24].

use crate::graph::csr::CsrGraph;
use crate::util::rng::Rng;

/// One coarsening level: the coarse graph, the fine→coarse vertex map,
//  and coarse vertex weights (number of original vertices merged).
#[derive(Debug, Clone)]
pub struct CoarseLevel {
    pub graph: CsrGraph,
    /// `map[fine_v] = coarse_v`
    pub map: Vec<u32>,
    /// vertices merged into each coarse vertex
    pub vwgt: Vec<u32>,
}

/// Heavy-edge matching: visit vertices in random order; match each
/// unmatched vertex with its unmatched neighbor of maximum edge weight.
/// Returns `match_of[v]` (== v for unmatched singletons).
pub fn heavy_edge_matching(g: &CsrGraph, rng: &mut Rng) -> Vec<u32> {
    let n = g.n();
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut match_of: Vec<u32> = (0..n as u32).collect();
    let mut matched = vec![false; n];
    for &v in &order {
        let v = v as usize;
        if matched[v] {
            continue;
        }
        let mut best: Option<(usize, f32)> = None;
        for (u, w) in g.neighbors(v) {
            if !matched[u] && u != v {
                match best {
                    Some((_, bw)) if bw >= w => {}
                    _ => best = Some((u, w)),
                }
            }
        }
        if let Some((u, _)) = best {
            matched[v] = true;
            matched[u] = true;
            match_of[v] = u as u32;
            match_of[u] = v as u32;
        }
    }
    match_of
}

/// Build the coarse graph from a matching, with vertex weights carried
/// through (`vwgt_fine` may be `None` for the first level = all 1).
pub fn contract(g: &CsrGraph, match_of: &[u32], vwgt_fine: Option<&[u32]>) -> CoarseLevel {
    let n = g.n();
    // assign coarse ids: matched pair gets one id (owner = smaller index)
    let mut map = vec![u32::MAX; n];
    let mut next = 0u32;
    for v in 0..n {
        if map[v] != u32::MAX {
            continue;
        }
        let m = match_of[v] as usize;
        map[v] = next;
        if m != v {
            map[m] = next;
        }
        next += 1;
    }
    let nc = next as usize;
    let mut vwgt = vec![0u32; nc];
    for v in 0..n {
        vwgt[map[v] as usize] += vwgt_fine.map(|w| w[v]).unwrap_or(1);
    }
    // aggregate edges (summing parallel edge weights — heavier coarse
    // edges attract the next matching round, like METIS)
    let mut edges: Vec<(u32, u32, f32)> = Vec::with_capacity(g.m());
    for (u, v, w) in g.edges() {
        let cu = map[u as usize];
        let cv = map[v as usize];
        if cu != cv {
            edges.push((cu, cv, w));
        }
    }
    // CsrGraph::from_edges dedups by min; we need SUM for coarsening.
    let graph = csr_from_edges_sum(nc, &mut edges);
    CoarseLevel { graph, map, vwgt }
}

/// CSR build that SUMS duplicate edge weights (coarsening semantics)
/// instead of taking the min.
fn csr_from_edges_sum(n: usize, edges: &mut Vec<(u32, u32, f32)>) -> CsrGraph {
    edges.sort_unstable_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
    let mut rowptr = vec![0usize; n + 1];
    let mut col = Vec::with_capacity(edges.len());
    let mut val: Vec<f32> = Vec::with_capacity(edges.len());
    let mut prev: Option<(u32, u32)> = None;
    for &(u, v, w) in edges.iter() {
        if prev == Some((u, v)) {
            *val.last_mut().unwrap() += w;
        } else {
            col.push(v);
            val.push(w);
            rowptr[u as usize + 1] += 1;
            prev = Some((u, v));
        }
    }
    for i in 0..n {
        rowptr[i + 1] += rowptr[i];
    }
    CsrGraph { rowptr, col, val }
}

/// Coarsen until the graph has at most `target_n` vertices or matching
/// stalls. Returns levels fine→coarse (level 0 built from `g`).
pub fn coarsen_to(g: &CsrGraph, target_n: usize, rng: &mut Rng) -> Vec<CoarseLevel> {
    let mut levels: Vec<CoarseLevel> = Vec::new();
    let mut cur = g.clone();
    let mut vwgt: Option<Vec<u32>> = None;
    while cur.n() > target_n {
        let match_of = heavy_edge_matching(&cur, rng);
        let lvl = contract(&cur, &match_of, vwgt.as_deref());
        // matching stalled (e.g. edgeless graph): stop
        if lvl.graph.n() as f64 > 0.95 * cur.n() as f64 {
            levels.push(lvl);
            break;
        }
        cur = lvl.graph.clone();
        vwgt = Some(lvl.vwgt.clone());
        levels.push(lvl);
    }
    levels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, Weights};

    #[test]
    fn matching_is_symmetric_and_disjoint() {
        let g = generators::newman_watts_strogatz(200, 3, 0.1, Weights::Uniform(1.0, 5.0), 1);
        let mut rng = Rng::new(2);
        let m = heavy_edge_matching(&g, &mut rng);
        for v in 0..g.n() {
            let u = m[v] as usize;
            assert_eq!(m[u] as usize, v, "matching not symmetric at {v}");
        }
    }

    #[test]
    fn contract_preserves_total_vertex_weight() {
        let g = generators::random_connected(150, 100, Weights::Unit, 3);
        let mut rng = Rng::new(4);
        let m = heavy_edge_matching(&g, &mut rng);
        let lvl = contract(&g, &m, None);
        let total: u32 = lvl.vwgt.iter().sum();
        assert_eq!(total as usize, g.n());
        assert!(lvl.graph.n() < g.n());
        lvl.graph.validate().unwrap();
    }

    #[test]
    fn contract_sums_parallel_edges() {
        // triangle 0-1-2; match (0,1) -> coarse edge {01}-2 weight 1+1=2
        let g = CsrGraph::from_undirected_edges(
            3,
            &[(0, 1, 5.0), (0, 2, 1.0), (1, 2, 1.0)],
        );
        let match_of = vec![1, 0, 2];
        let lvl = contract(&g, &match_of, None);
        assert_eq!(lvl.graph.n(), 2);
        let c01 = lvl.map[0];
        let c2 = lvl.map[2];
        assert_eq!(
            lvl.graph.edge_weight(c01 as usize, c2 as usize),
            Some(2.0)
        );
    }

    #[test]
    fn coarsen_reaches_target() {
        let g = generators::newman_watts_strogatz(1000, 4, 0.05, Weights::Unit, 5);
        let mut rng = Rng::new(6);
        let levels = coarsen_to(&g, 100, &mut rng);
        assert!(!levels.is_empty());
        let last = &levels.last().unwrap().graph;
        assert!(last.n() <= 150, "coarsest has {} vertices", last.n());
        // every level maps onto the next
        let mut n_prev = g.n();
        for lvl in &levels {
            assert_eq!(lvl.map.len(), n_prev);
            n_prev = lvl.graph.n();
        }
    }
}
