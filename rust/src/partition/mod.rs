//! Multilevel k-way graph partitioner — the from-scratch METIS [24]
//! substitute (the paper partitions with METIS 5.1.0, unavailable
//! offline; this implements the same multilevel scheme: heavy-edge
//! matching coarsening, greedy-growing initial bisection, and boundary
//! FM refinement, applied recursively).
//!
//! The paper's requirement is specific: decompose into components of
//! `|V| <= 1024` (one PIM tile) while minimizing the boundary set
//! (§III-A). [`partition_by_max_size`] does exactly that;
//! [`partition_kway`] exposes the classic fixed-k interface.

pub mod bisect;
pub mod boundary;
pub mod coarsen;
pub mod refine;

use crate::graph::csr::CsrGraph;
use crate::util::rng::Rng;

/// A k-way vertex partition: `assign[v]` is the part id of vertex `v`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    pub assign: Vec<u32>,
    pub k: usize,
}

impl Partition {
    pub fn n(&self) -> usize {
        self.assign.len()
    }

    /// Vertex count per part.
    pub fn part_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.k];
        for &p in &self.assign {
            sizes[p as usize] += 1;
        }
        sizes
    }

    /// Vertices of each part, in ascending vertex order.
    pub fn part_members(&self) -> Vec<Vec<u32>> {
        let mut parts = vec![Vec::new(); self.k];
        for (v, &p) in self.assign.iter().enumerate() {
            parts[p as usize].push(v as u32);
        }
        parts
    }

    /// Total weight of edges crossing parts (each undirected edge counted
    /// once if the graph stores both directions).
    pub fn edge_cut(&self, g: &CsrGraph) -> f64 {
        let mut cut = 0.0;
        for (u, v, w) in g.edges() {
            if self.assign[u as usize] != self.assign[v as usize] {
                cut += w as f64;
            }
        }
        cut / 2.0
    }

    /// Number of cut edges (unit-weight edge cut).
    pub fn cut_edges(&self, g: &CsrGraph) -> usize {
        let mut cut = 0usize;
        for (u, v, _) in g.edges() {
            if self.assign[u as usize] != self.assign[v as usize] {
                cut += 1;
            }
        }
        cut / 2
    }

    /// Validate: every vertex assigned to a part `< k`, no empty parts
    /// (unless the graph is smaller than k).
    pub fn validate(&self, g: &CsrGraph) -> Result<(), String> {
        if self.assign.len() != g.n() {
            return Err("assign length != n".into());
        }
        let sizes = self.part_sizes();
        for (v, &p) in self.assign.iter().enumerate() {
            if (p as usize) >= self.k {
                return Err(format!("vertex {v} assigned to part {p} >= k={}", self.k));
            }
        }
        if g.n() >= self.k && sizes.iter().any(|&s| s == 0) {
            return Err(format!("empty part in sizes {sizes:?}"));
        }
        Ok(())
    }
}

/// Partition so every part has at most `max_size` vertices, minimizing
/// edge cut via recursive multilevel bisection. This is the paper's
/// "partition each component at |V| <= 1024" operation.
pub fn partition_by_max_size(g: &CsrGraph, max_size: usize, seed: u64) -> Partition {
    assert!(max_size >= 1);
    let n = g.n();
    let mut assign = vec![0u32; n];
    let mut next_part = 0u32;
    let mut rng = Rng::new(seed);
    // worklist of (vertex set) to split
    let mut work: Vec<Vec<u32>> = vec![(0..n as u32).collect()];
    while let Some(verts) = work.pop() {
        if verts.len() <= max_size {
            let p = next_part;
            next_part += 1;
            for &v in &verts {
                assign[v as usize] = p;
            }
            continue;
        }
        let sub = g.induced_subgraph(&verts);
        let side = bisect::bisect(&sub, rng.next_u64());
        let mut left = Vec::new();
        let mut right = Vec::new();
        for (local, &v) in verts.iter().enumerate() {
            if side[local] {
                right.push(v);
            } else {
                left.push(v);
            }
        }
        // Degenerate split guard (can only happen on pathological inputs):
        // fall back to an even split.
        if left.is_empty() || right.is_empty() {
            let mid = verts.len() / 2;
            left = verts[..mid].to_vec();
            right = verts[mid..].to_vec();
        }
        work.push(left);
        work.push(right);
    }
    Partition {
        assign,
        k: next_part as usize,
    }
}

/// Classic fixed-k interface: recursive bisection until `k` parts exist.
/// `k` must be >= 1; parts are balanced within ~5%.
pub fn partition_kway(g: &CsrGraph, k: usize, seed: u64) -> Partition {
    assert!(k >= 1);
    let n = g.n();
    let mut assign = vec![0u32; n];
    let mut rng = Rng::new(seed);
    // (verts, parts_to_create, first_part_id)
    let mut work: Vec<(Vec<u32>, usize, u32)> = vec![((0..n as u32).collect(), k, 0)];
    while let Some((verts, parts, first)) = work.pop() {
        if parts <= 1 || verts.len() <= 1 {
            for &v in &verts {
                assign[v as usize] = first;
            }
            continue;
        }
        let left_parts = parts / 2;
        let right_parts = parts - left_parts;
        let target_left = verts.len() * left_parts / parts;
        let sub = g.induced_subgraph(&verts);
        let side = bisect::bisect_with_target(&sub, target_left, rng.next_u64());
        let mut left = Vec::new();
        let mut right = Vec::new();
        for (local, &v) in verts.iter().enumerate() {
            if side[local] {
                right.push(v);
            } else {
                left.push(v);
            }
        }
        if left.is_empty() || right.is_empty() {
            let mid = verts.len() * left_parts / parts;
            left = verts[..mid].to_vec();
            right = verts[mid..].to_vec();
        }
        work.push((left, left_parts, first));
        work.push((right, right_parts, first + left_parts as u32));
    }
    Partition { assign, k }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, Weights};

    #[test]
    fn max_size_respected() {
        let g = generators::newman_watts_strogatz(500, 4, 0.05, Weights::Unit, 1);
        let p = partition_by_max_size(&g, 64, 42);
        p.validate(&g).unwrap();
        for s in p.part_sizes() {
            assert!(s <= 64, "part size {s} > 64");
        }
    }

    #[test]
    fn small_graph_single_part() {
        let g = generators::complete(10, Weights::Unit, 1);
        let p = partition_by_max_size(&g, 1024, 1);
        assert_eq!(p.k, 1);
        assert!(p.assign.iter().all(|&a| a == 0));
    }

    #[test]
    fn kway_produces_k_parts() {
        let g = generators::newman_watts_strogatz(400, 4, 0.05, Weights::Unit, 2);
        for k in [2usize, 3, 5, 8] {
            let p = partition_kway(&g, k, 7);
            p.validate(&g).unwrap();
            assert_eq!(p.k, k);
            let sizes = p.part_sizes();
            let max = *sizes.iter().max().unwrap() as f64;
            let min = *sizes.iter().min().unwrap() as f64;
            assert!(
                max / min.max(1.0) < 2.0,
                "k={k}: imbalance {sizes:?}"
            );
        }
    }

    #[test]
    fn clustered_graph_cut_beats_random_assign() {
        // communities of 32..128 vertices fit whole inside 256-vertex
        // tiles, so a good partitioner must find a far-below-random cut
        let g = generators::ogbn_proxy_with(2000, 16.0, 32, 128, 0.92, Weights::Unit, 3);
        let p = partition_by_max_size(&g, 256, 3);
        p.validate(&g).unwrap();
        let cut = p.cut_edges(&g);
        // random assignment with same k
        let mut rng = crate::util::rng::Rng::new(4);
        let rand_p = Partition {
            assign: (0..g.n()).map(|_| rng.gen_range(p.k) as u32).collect(),
            k: p.k,
        };
        let rand_cut = rand_p.cut_edges(&g);
        assert!(
            (cut as f64) < 0.5 * rand_cut as f64,
            "partitioner cut {cut} should beat random {rand_cut} by 2x+"
        );
    }

    #[test]
    fn partition_covers_every_vertex_exactly_once() {
        crate::util::prop::assert_prop(
            10,
            |r| {
                let n = 50 + r.gen_range(200);
                let extra = r.gen_range(n);
                let seed = r.next_u64();
                (
                    generators::random_connected(n, extra, Weights::Unit, seed),
                    seed,
                )
            },
            |(g, seed)| {
                let p = partition_by_max_size(g, 32, *seed);
                p.validate(g).map_err(|e| e)?;
                let total: usize = p.part_sizes().iter().sum();
                if total != g.n() {
                    return Err(format!("sizes sum {total} != n {}", g.n()));
                }
                for s in p.part_sizes() {
                    if s > 32 {
                        return Err(format!("part size {s} > 32"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn edge_cut_counts_undirected_once() {
        let g = CsrGraph::from_undirected_edges(4, &[(0, 1, 2.0), (1, 2, 3.0), (2, 3, 4.0)]);
        let p = Partition {
            assign: vec![0, 0, 1, 1],
            k: 2,
        };
        assert_eq!(p.edge_cut(&g), 3.0);
        assert_eq!(p.cut_edges(&g), 1);
    }
}
