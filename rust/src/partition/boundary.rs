//! Component/boundary structure for partitioned APSP (paper §II-B2).
//!
//! Within each component, a *boundary* vertex has an edge to another
//! component; internal vertices only connect within their component. For
//! computational efficiency, boundary vertices are reordered before
//! internal vertices (paper: "boundary vertices are reordered before
//! internal vertices") — the distance matrix of a component then has its
//! boundary block in the top-left corner, which is what the injection
//! and merge steps slice.

use super::Partition;
use crate::graph::csr::CsrGraph;

/// One component with boundary-first vertex ordering.
#[derive(Debug, Clone)]
pub struct Component {
    /// Global vertex ids; the first `n_boundary` are boundary vertices.
    pub verts: Vec<u32>,
    pub n_boundary: usize,
}

impl Component {
    pub fn n(&self) -> usize {
        self.verts.len()
    }
    /// Boundary vertices (global ids).
    pub fn boundary(&self) -> &[u32] {
        &self.verts[..self.n_boundary]
    }
    /// Internal vertices (global ids).
    pub fn internal(&self) -> &[u32] {
        &self.verts[self.n_boundary..]
    }
}

/// The decomposition of a graph into components plus the boundary set B.
#[derive(Debug, Clone)]
pub struct ComponentSet {
    pub components: Vec<Component>,
    /// All boundary vertices in boundary-graph id order (component 0's
    /// boundary first, then component 1's, ...).
    pub boundary_verts: Vec<u32>,
    /// `boundary_id[v]` = id in the boundary graph, or `u32::MAX`.
    pub boundary_id: Vec<u32>,
    /// Component id of each vertex (copied from the partition).
    pub comp_of: Vec<u32>,
}

impl ComponentSet {
    /// Total boundary vertices |B|.
    pub fn n_boundary(&self) -> usize {
        self.boundary_verts.len()
    }

    /// Largest component size (must be <= tile limit after partitioning).
    pub fn max_component(&self) -> usize {
        self.components.iter().map(|c| c.n()).max().unwrap_or(0)
    }

    /// Check the defining invariants.
    pub fn validate(&self, g: &CsrGraph) -> Result<(), String> {
        let n = g.n();
        let mut seen = vec![false; n];
        for (ci, c) in self.components.iter().enumerate() {
            if c.n_boundary > c.n() {
                return Err(format!("component {ci}: n_boundary > n"));
            }
            for (idx, &v) in c.verts.iter().enumerate() {
                let v = v as usize;
                if seen[v] {
                    return Err(format!("vertex {v} in two components"));
                }
                seen[v] = true;
                if self.comp_of[v] as usize != ci {
                    return Err(format!("comp_of[{v}] mismatch"));
                }
                let is_boundary = g.neighbors(v).any(|(u, _)| self.comp_of[u] != ci as u32);
                let marked = idx < c.n_boundary;
                if is_boundary != marked {
                    return Err(format!(
                        "vertex {v} boundary flag mismatch (is {is_boundary}, marked {marked})"
                    ));
                }
                let bid = self.boundary_id[v];
                if marked != (bid != u32::MAX) {
                    return Err(format!("boundary_id[{v}] inconsistent"));
                }
                if marked && self.boundary_verts[bid as usize] as usize != v {
                    return Err(format!("boundary_verts[{bid}] != {v}"));
                }
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("not all vertices covered".into());
        }
        Ok(())
    }
}

/// Build the component set from a partition, reordering boundary-first.
pub fn build_components(g: &CsrGraph, p: &Partition) -> ComponentSet {
    let n = g.n();
    let comp_of = p.assign.clone();
    let members = p.part_members();
    let mut components = Vec::with_capacity(p.k);
    let mut boundary_verts = Vec::new();
    let mut boundary_id = vec![u32::MAX; n];
    for (ci, verts) in members.into_iter().enumerate() {
        let mut bnd = Vec::new();
        let mut int = Vec::new();
        for &v in &verts {
            let is_boundary = g
                .neighbors(v as usize)
                .any(|(u, _)| comp_of[u] != ci as u32);
            if is_boundary {
                bnd.push(v);
            } else {
                int.push(v);
            }
        }
        for &v in &bnd {
            boundary_id[v as usize] = boundary_verts.len() as u32;
            boundary_verts.push(v);
        }
        let n_boundary = bnd.len();
        bnd.extend(int);
        components.push(Component {
            verts: bnd,
            n_boundary,
        });
    }
    ComponentSet {
        components,
        boundary_verts,
        boundary_id,
        comp_of,
    }
}

/// Build the boundary graph G_B (paper Step 2): vertices are all boundary
/// vertices; edges are (i) cross-component edges of `g` and (ii) virtual
/// intra-component edges weighted by `d_intra(comp, bi, bj)` (local
/// boundary indices within that component's matrix). Pass
/// `|_, _, _| 1.0` for topology-only planning.
pub fn boundary_graph(
    g: &CsrGraph,
    cs: &ComponentSet,
    d_intra: &dyn Fn(usize, usize, usize) -> f32,
) -> CsrGraph {
    let nb = cs.n_boundary();
    let mut edges: Vec<(u32, u32, f32)> = Vec::new();
    // (i) cross-component edges
    for (u, v, w) in g.edges() {
        if cs.comp_of[u as usize] != cs.comp_of[v as usize] {
            let bu = cs.boundary_id[u as usize];
            let bv = cs.boundary_id[v as usize];
            debug_assert!(bu != u32::MAX && bv != u32::MAX);
            edges.push((bu, bv, w));
        }
    }
    // (ii) virtual intra-component edges between boundary vertices
    for (ci, c) in cs.components.iter().enumerate() {
        for bi in 0..c.n_boundary {
            let gu = c.verts[bi] as usize;
            let bu = cs.boundary_id[gu];
            for bj in (bi + 1)..c.n_boundary {
                let gv = c.verts[bj] as usize;
                let bv = cs.boundary_id[gv];
                let w = d_intra(ci, bi, bj);
                if w.is_finite() {
                    edges.push((bu, bv, w));
                    edges.push((bv, bu, d_intra(ci, bj, bi)));
                }
            }
        }
    }
    CsrGraph::from_edges(nb, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, Weights};
    use crate::partition::partition_by_max_size;

    fn setup(n: usize, seed: u64) -> (CsrGraph, ComponentSet) {
        let g = generators::newman_watts_strogatz(n, 4, 0.1, Weights::Uniform(1.0, 5.0), seed);
        let p = partition_by_max_size(&g, 64, seed);
        let cs = build_components(&g, &p);
        (g, cs)
    }

    #[test]
    fn components_valid() {
        let (g, cs) = setup(300, 1);
        cs.validate(&g).unwrap();
        assert!(cs.max_component() <= 64);
    }

    #[test]
    fn boundary_first_ordering() {
        let (g, cs) = setup(300, 2);
        for c in &cs.components {
            for (idx, &v) in c.verts.iter().enumerate() {
                let ci = cs.comp_of[v as usize];
                let is_b = g.neighbors(v as usize).any(|(u, _)| cs.comp_of[u] != ci);
                assert_eq!(is_b, idx < c.n_boundary);
            }
        }
    }

    #[test]
    fn boundary_graph_topology_valid() {
        let (g, cs) = setup(200, 3);
        let gb = boundary_graph(&g, &cs, &|_, _, _| 1.0);
        gb.validate().unwrap();
        assert_eq!(gb.n(), cs.n_boundary());
        assert!(gb.n() > 0, "NWS partitions must have boundaries");
    }

    #[test]
    fn boundary_graph_contains_cross_edges() {
        let (g, cs) = setup(200, 4);
        let gb = boundary_graph(&g, &cs, &|_, _, _| f32::INFINITY);
        // with infinite virtual edges, only cross edges remain
        for (u, v, w) in g.edges() {
            if cs.comp_of[u as usize] != cs.comp_of[v as usize] {
                let bu = cs.boundary_id[u as usize] as usize;
                let bv = cs.boundary_id[v as usize] as usize;
                let got = gb.edge_weight(bu, bv).unwrap();
                assert!(got <= w, "cross edge ({u},{v}) missing or heavier");
            }
        }
    }

    #[test]
    fn single_component_has_no_boundary() {
        let g = generators::complete(20, Weights::Unit, 5);
        let p = partition_by_max_size(&g, 1024, 5);
        let cs = build_components(&g, &p);
        cs.validate(&g).unwrap();
        assert_eq!(cs.n_boundary(), 0);
        assert_eq!(cs.components.len(), 1);
    }

    #[test]
    fn two_cliques_bridge_boundary() {
        let mut edges = Vec::new();
        for u in 0..10u32 {
            for v in (u + 1)..10 {
                edges.push((u, v, 1.0f32));
            }
        }
        for u in 10..20u32 {
            for v in (u + 1)..20 {
                edges.push((u, v, 1.0));
            }
        }
        edges.push((3, 13, 9.0));
        let g = CsrGraph::from_undirected_edges(20, &edges);
        let p = partition_by_max_size(&g, 10, 1);
        let cs = build_components(&g, &p);
        cs.validate(&g).unwrap();
        // exactly the two bridge endpoints are boundary
        assert_eq!(cs.n_boundary(), 2);
        let bset: std::collections::HashSet<u32> =
            cs.boundary_verts.iter().copied().collect();
        assert!(bset.contains(&3) && bset.contains(&13));
    }
}
