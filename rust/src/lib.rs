//! # RAPID-Graph
//!
//! Reproduction of *RAPID-Graph: Recursive All-Pairs Shortest Paths Using
//! Processing-in-Memory for Dynamic Programming on Graphs* (CS.AR 2025).
//!
//! The crate is the Layer-3 rust coordinator of a three-layer stack:
//!
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`): the FW
//!   pivot-panel update and blocked min-plus matmul, AOT-lowered.
//! * **Layer 2** — JAX tile model (`python/compile/model.py`): dense-block
//!   Floyd–Warshall and two-stage MP merge, exported as HLO text.
//! * **Layer 3** — this crate: recursive partitioner, multi-die PIM
//!   simulator, dataflow scheduler, PJRT runtime, baselines, benches.
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

pub mod apsp;
pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod graph;
pub mod partition;
pub mod runtime;
pub mod sim;
pub mod util;

pub use coordinator::config::SystemConfig;
pub use coordinator::executor::Executor;
pub use graph::csr::CsrGraph;
pub use graph::dense::DistMatrix;

/// Infinity sentinel for 32-bit float distances. The paper stores 32-bit
/// distances in PCM rows; we use IEEE f32 with `+inf` for "no path".
pub const INF: f32 = f32::INFINITY;

/// Maximum vertices per PIM tile (paper §III-A: components are partitioned
/// at |V| <= 1024, matching the 1024x1024 PCM unit dimension).
pub const TILE_LIMIT: usize = 1024;
