//! Serve-side read path: immutable query snapshots published through a
//! lock-free cell, and the batched query executor that drains request
//! streams against them.
//!
//! # Snapshot lifecycle
//!
//! A solved result is frozen into one [`QuerySnapshot`] — distance
//! matrix + packed next-hop map + a build-time checksum — held by a
//! single `Arc`. Readers obtain it through [`SnapshotCell::load`],
//! writers publish a replacement with [`SnapshotCell::swap`] when a
//! delta repair lands. Because the snapshot is one immutable allocation
//! behind one pointer, a torn read (distances from one epoch, next
//! hops from another) is structurally impossible: a reader holds
//! either the whole old snapshot or the whole new one.
//!
//! # Why readers never block
//!
//! [`SnapshotCell`] is a fixed-slot hazard-pointer scheme, std-only:
//!
//! * a reader publishes the pointer it intends to use in one of
//!   [`READER_SLOTS`] hazard slots (a CAS on a null slot), re-validates
//!   the cell still points there, takes its own strong count, and
//!   releases the slot — no lock anywhere on the path;
//! * the writer swaps the current pointer, pushes the old one onto a
//!   writer-side graveyard, and reclaims exactly those retirees no
//!   hazard slot protects.
//!
//! The only reader retry is a swap racing the validate load (or all
//! slots momentarily claimed); both are counted in
//! [`SnapshotCell::stalls`] — the serve bench snapshots that counter as
//! `snapshot_swap_stalls`. Readers never take the graveyard mutex and
//! never wait on the writer, so a mid-repair reader simply keeps the
//! consistent pre-repair snapshot (its `Arc` pins it until dropped).
//!
//! # Batching policy
//!
//! [`BatchExec`] drains a request batch source-major: requests are
//! ordered by source row, the sources' rows are copied panel-at-a-time
//! (panel width configurable, arena-leased scratch) and every query on
//! a panel is answered from the hot copy — point lookups and
//! reachability scans touch only the panel, path reconstruction walks
//! the packed next-hop map one lookup per hop, k-nearest selects from
//! the resident row. Answers come back in request order.

use super::query::{NextHopMatrix, Query, QueryReq};
use super::semiring::SemiringId;
use crate::graph::dense::DistMatrix;
use crate::util::arena;
use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Fixed hazard-slot count: the maximum number of readers that can be
/// mid-claim at the same instant (not the maximum reader threads —
/// slots are held for a few loads each).
pub const READER_SLOTS: usize = 64;

/// One immutable published view of a solved graph: everything a reader
/// needs, behind a single `Arc`.
#[derive(Debug)]
pub struct QuerySnapshot {
    /// Publication epoch (0 = initial solve, +1 per delta repair).
    pub epoch: u64,
    /// Semiring the distances were computed in: drives the k-nearest
    /// rank order and the reachability predicate.
    pub sr: SemiringId,
    pub dist: DistMatrix,
    /// Packed path-reconstruction map; `(min, +)` snapshots only — no
    /// other shipped semiring has a meaningful hop predecessor.
    pub next: Option<NextHopMatrix>,
    /// Build-time checksum over epoch + sampled payload bits; readers
    /// re-derive it to prove a snapshot was never observed torn.
    check: u64,
}

impl QuerySnapshot {
    /// A `(min, +)` snapshot with its next-hop map — the classic APSP
    /// serve payload.
    pub fn new(epoch: u64, dist: DistMatrix, next: NextHopMatrix) -> Self {
        Self::new_sr(epoch, SemiringId::MinPlus, dist, Some(next))
    }

    /// A snapshot over any semiring's solved matrix; `next` is `None`
    /// for every workload without path reconstruction.
    pub fn new_sr(
        epoch: u64,
        sr: SemiringId,
        dist: DistMatrix,
        next: Option<NextHopMatrix>,
    ) -> Self {
        let check = Self::fingerprint(epoch, sr, &dist, next.as_ref());
        Self {
            epoch,
            sr,
            dist,
            next,
            check,
        }
    }

    /// FNV-1a over the epoch and a bounded sample of distance bits and
    /// next-hop ids — cheap enough for readers to re-derive per load.
    fn fingerprint(epoch: u64, sr: SemiringId, dist: &DistMatrix, next: Option<&NextHopMatrix>) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        let mut mix = |x: u64| {
            h ^= x;
            h = h.wrapping_mul(0x100000001b3);
        };
        mix(epoch);
        mix(sr as u64);
        let n = dist.n();
        mix(n as u64);
        let cells = dist.as_slice();
        let stride = (cells.len() / 256).max(1);
        for idx in (0..cells.len()).step_by(stride) {
            mix(cells[idx].to_bits() as u64);
            let (u, v) = (idx / n.max(1), idx % n.max(1));
            let hop = next.and_then(|nh| nh.next_hop(u, v));
            mix(hop.map_or(u64::MAX, |hop| hop as u64));
        }
        h
    }

    /// Re-derive the checksum: `true` iff the snapshot's fields are the
    /// ones it was built with (the torn-read probe).
    pub fn verify(&self) -> bool {
        Self::fingerprint(self.epoch, self.sr, &self.dist, self.next.as_ref()) == self.check
    }

    /// Resident bytes of the published payload.
    pub fn bytes(&self) -> usize {
        self.dist.dense_bytes() + self.next.as_ref().map_or(0, |n| n.bytes())
    }
}

/// Lock-free publication cell for `Arc` snapshots (hazard-pointer
/// reclamation; see the module docs for the protocol and its safety
/// argument).
pub struct SnapshotCell<T: Send + Sync> {
    current: AtomicPtr<T>,
    slots: Vec<AtomicPtr<T>>,
    /// Writer-side graveyard: retired pointers awaiting quiescence.
    retired: Mutex<Vec<*const T>>,
    swaps: AtomicU64,
    stalls: AtomicU64,
}

// SAFETY: every raw pointer in `current`, `slots`, and `retired` came
// from `Arc::into_raw` on an `Arc<T>`; they are reconstituted or
// dereferenced only under the hazard protocol (readers re-validate
// after publishing a hazard, the writer reclaims only unhazarded
// retirees), so moving/sharing the cell across threads demands exactly
// what `Arc<T>: Send + Sync` demands: `T: Send + Sync`.
unsafe impl<T: Send + Sync> Send for SnapshotCell<T> {}
unsafe impl<T: Send + Sync> Sync for SnapshotCell<T> {}

impl<T: Send + Sync> SnapshotCell<T> {
    pub fn new(initial: Arc<T>) -> Self {
        Self {
            current: AtomicPtr::new(Arc::into_raw(initial) as *mut T),
            slots: (0..READER_SLOTS)
                .map(|_| AtomicPtr::new(std::ptr::null_mut()))
                .collect(),
            retired: Mutex::new(Vec::new()),
            swaps: AtomicU64::new(0),
            stalls: AtomicU64::new(0),
        }
    }

    /// Lock-free read: returns the current snapshot with its own strong
    /// count. Retries (never blocks) when a swap races the hazard
    /// publish; every retry increments [`SnapshotCell::stalls`].
    pub fn load(&self) -> Arc<T> {
        loop {
            let p = self.current.load(Ordering::SeqCst);
            let mut claimed = None;
            for slot in &self.slots {
                if slot
                    .compare_exchange(
                        std::ptr::null_mut(),
                        p,
                        Ordering::SeqCst,
                        Ordering::Relaxed,
                    )
                    .is_ok()
                {
                    claimed = Some(slot);
                    break;
                }
            }
            let Some(slot) = claimed else {
                // all slots mid-claim by other readers; not a writer wait
                self.stalls.fetch_add(1, Ordering::Relaxed);
                std::hint::spin_loop();
                continue;
            };
            if self.current.load(Ordering::SeqCst) == p {
                // SAFETY: `p` came from `Arc::into_raw`, and the
                // published hazard keeps the writer from reclaiming it
                // until the slot clears — we take our own strong count
                // first, so the returned Arc is self-sufficient.
                unsafe {
                    Arc::increment_strong_count(p);
                    slot.store(std::ptr::null_mut(), Ordering::SeqCst);
                    return Arc::from_raw(p);
                }
            }
            // a swap landed between the read and the hazard publish
            slot.store(std::ptr::null_mut(), Ordering::SeqCst);
            self.stalls.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Publish `next` and retire the previous snapshot; reclaims every
    /// retiree no reader hazard protects. Writer-only mutex — readers
    /// never touch it.
    pub fn swap(&self, next: Arc<T>) {
        let fresh = Arc::into_raw(next) as *mut T;
        let old = self.current.swap(fresh, Ordering::SeqCst);
        self.swaps.fetch_add(1, Ordering::Relaxed);
        let mut retired = self.retired.lock().unwrap();
        retired.push(old as *const T);
        retired.retain(|&p| {
            let hazarded = self
                .slots
                .iter()
                .any(|s| s.load(Ordering::SeqCst) as *const T == p);
            if !hazarded {
                // SAFETY: `p` holds the cell's own strong count from
                // its publication; no hazard slot names it, and any
                // reader that validated `p` already took its own count
                // before clearing its slot.
                unsafe { drop(Arc::from_raw(p)) };
            }
            hazarded
        });
    }

    /// Number of swaps published.
    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }

    /// Reader retries (hazard re-validation misses + brief slot
    /// exhaustion) — the serve report's `snapshot_swap_stalls`.
    pub fn stalls(&self) -> u64 {
        self.stalls.load(Ordering::Relaxed)
    }
}

impl<T: Send + Sync> Drop for SnapshotCell<T> {
    fn drop(&mut self) {
        // &mut self: no readers can exist, every pointer is ours
        let cur = self.current.load(Ordering::SeqCst);
        // SAFETY: exclusive access; `cur` and all retirees each hold
        // exactly one outstanding strong count from publication.
        unsafe {
            drop(Arc::from_raw(cur as *const T));
            for p in self.retired.get_mut().unwrap().drain(..) {
                drop(Arc::from_raw(p));
            }
        }
    }
}

/// One answered request (same order as the submitted batch).
#[derive(Debug, Clone, PartialEq)]
pub enum Answer {
    /// `dist(u, v)` (`INF` = unreachable).
    Dist(f32),
    /// Hop list `[u, ..., v]` and its distance; empty hops + `INF`
    /// weight for unreachable pairs.
    Path { hops: Vec<u32>, weight: f32 },
    /// `(distance, node)` pairs, ascending, ties by node id.
    KNearest(Vec<(f32, u32)>),
    /// Count of reachable other nodes.
    Reach(u32),
}

/// Batched source-major query executor. Holds its reusable ordering /
/// hop / candidate buffers so a long-running serve loop reaches an
/// allocation-free steady state (the row panels come from the arena).
pub struct BatchExec {
    panel_rows: usize,
    order: Vec<u32>,
    hops: Vec<u32>,
    cand: Vec<(f32, u32)>,
}

impl BatchExec {
    /// `panel_rows`: how many consecutive matrix rows one leased panel
    /// holds (the serve config's `panel_rows`; panels are aligned to
    /// multiples of it).
    pub fn new(panel_rows: usize) -> Self {
        Self {
            panel_rows: panel_rows.max(1),
            order: Vec::new(),
            hops: Vec::new(),
            cand: Vec::new(),
        }
    }

    /// Answer every request in `reqs` against one snapshot. Requests
    /// are drained source-major over aligned row panels; answers are
    /// returned in request order.
    pub fn run(&mut self, snap: &QuerySnapshot, reqs: &[QueryReq]) -> Vec<Answer> {
        let n = snap.dist.n();
        let pr = self.panel_rows;
        self.order.clear();
        self.order.extend(0..reqs.len() as u32);
        self.order
            .sort_by_key(|&i| reqs[i as usize].query.source());
        let mut answers: Vec<Answer> = reqs.iter().map(|_| Answer::Dist(f32::INFINITY)).collect();
        let mut panel = arena::scratch_filled(pr * n, 0.0);
        let mut at = 0usize;
        while at < self.order.len() {
            let p0 = (reqs[self.order[at] as usize].query.source() as usize / pr) * pr;
            let rows = pr.min(n - p0);
            for r in 0..rows {
                panel[r * n..r * n + n].copy_from_slice(snap.dist.row(p0 + r));
            }
            while at < self.order.len() {
                let ridx = self.order[at] as usize;
                let q = reqs[ridx].query;
                let u = q.source() as usize;
                if u >= p0 + rows {
                    break;
                }
                let row = &panel[(u - p0) * n..(u - p0) * n + n];
                answers[ridx] = Self::answer_one(
                    q,
                    u,
                    row,
                    snap.sr,
                    snap.next.as_ref(),
                    &mut self.hops,
                    &mut self.cand,
                );
                at += 1;
            }
        }
        answers
    }

    #[allow(clippy::too_many_arguments)]
    fn answer_one(
        q: Query,
        u: usize,
        row: &[f32],
        sr: SemiringId,
        next: Option<&NextHopMatrix>,
        hops: &mut Vec<u32>,
        cand: &mut Vec<(f32, u32)>,
    ) -> Answer {
        match q {
            Query::Dist { v, .. } => Answer::Dist(row[v as usize]),
            Query::Path { v, .. } => match next {
                Some(next) if next.path_into(u, v as usize, hops) => Answer::Path {
                    hops: hops.clone(),
                    weight: row[v as usize],
                },
                // unreachable pair, or a snapshot without a next-hop
                // map (non-(min,+) workloads reject path queries
                // upstream; answering the sentinel keeps this total)
                _ => Answer::Path {
                    hops: Vec::new(),
                    weight: f32::INFINITY,
                },
            },
            Query::KNearest { k, .. } => {
                cand.clear();
                for (j, &d) in row.iter().enumerate() {
                    if j != u && !sr.is_absorbing(d) {
                        cand.push((d, j as u32));
                    }
                }
                // partial selection: O(n) split at k, then sort only
                // the head — the full sort would dominate the drain.
                // "Nearest" means best under ⊕: ascending for (min,+),
                // descending for the max-style semirings.
                let larger = sr.prefers_larger();
                let cmp = move |a: &(f32, u32), b: &(f32, u32)| {
                    let ord = a.0.total_cmp(&b.0);
                    let ord = if larger { ord.reverse() } else { ord };
                    ord.then(a.1.cmp(&b.1))
                };
                let k = (k as usize).min(cand.len());
                if k > 0 && k < cand.len() {
                    cand.select_nth_unstable_by(k - 1, cmp);
                }
                cand.truncate(k);
                cand.sort_unstable_by(cmp);
                Answer::KNearest(cand.clone())
            }
            Query::Reach { .. } => Answer::Reach(
                row.iter()
                    .enumerate()
                    .filter(|&(j, d)| j != u && !sr.is_absorbing(*d))
                    .count() as u32,
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::query::{self, solve_next_hops};
    use crate::graph::csr::CsrGraph;
    use crate::graph::generators::{self, Weights};
    use std::sync::atomic::AtomicBool;

    fn snapshot_of(g: &CsrGraph, epoch: u64) -> QuerySnapshot {
        let (dist, next) = solve_next_hops(g);
        QuerySnapshot::new(epoch, dist, next)
    }

    #[test]
    fn snapshot_checksum_roundtrip() {
        let g = generators::random_connected(40, 90, Weights::Uniform(0.5, 3.0), 1);
        let snap = snapshot_of(&g, 7);
        assert!(snap.verify());
        assert_eq!(snap.epoch, 7);
        assert!(snap.bytes() > 0);
    }

    #[test]
    fn cell_load_swap_reclaims() {
        let g = generators::random_connected(30, 60, Weights::Uniform(0.5, 3.0), 2);
        let cell = SnapshotCell::new(Arc::new(snapshot_of(&g, 0)));
        let a = cell.load();
        assert_eq!(a.epoch, 0);
        cell.swap(Arc::new(snapshot_of(&g, 1)));
        // the pinned pre-swap snapshot stays fully valid
        assert!(a.verify());
        let b = cell.load();
        assert_eq!(b.epoch, 1);
        assert_eq!(cell.swaps(), 1);
        drop(a);
        // a second swap reclaims the unpinned epoch-1 retiree later
        cell.swap(Arc::new(snapshot_of(&g, 2)));
        assert_eq!(cell.load().epoch, 2);
    }

    #[test]
    fn concurrent_readers_never_torn_never_blocked() {
        let g = generators::random_connected(50, 110, Weights::Uniform(0.5, 3.0), 3);
        let snaps: Vec<Arc<QuerySnapshot>> =
            (0..4).map(|e| Arc::new(snapshot_of(&g, e))).collect();
        let cell = SnapshotCell::new(snaps[0].clone());
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            let mut readers = Vec::new();
            for _ in 0..4 {
                readers.push(s.spawn(|| {
                    let mut loads = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let snap = cell.load();
                        // single-Arc snapshot: fields can never be torn
                        assert!(snap.verify(), "torn snapshot observed");
                        assert!(snap.epoch < 4);
                        loads += 1;
                    }
                    loads
                }));
            }
            for round in 0..200u64 {
                cell.swap(snaps[(1 + round as usize % 3).min(3)].clone());
            }
            stop.store(true, Ordering::Relaxed);
            for r in readers {
                assert!(r.join().unwrap() > 0, "reader made no progress");
            }
        });
        assert_eq!(cell.swaps(), 200);
    }

    #[test]
    fn batch_answers_match_naive_queries() {
        let g = generators::random_connected(70, 160, Weights::Uniform(0.5, 4.0), 4);
        let n = g.n();
        let snap = snapshot_of(&g, 0);
        let mut rng = crate::util::rng::Rng::new(99);
        let mut reqs = Vec::new();
        for _ in 0..200 {
            let u = rng.gen_range(n) as u32;
            let v = rng.gen_range(n) as u32;
            let q = match rng.gen_range(4) {
                0 => Query::Dist { u, v },
                1 => Query::Path { u, v },
                2 => Query::KNearest {
                    u,
                    k: 1 + rng.gen_range(8) as u32,
                },
                _ => Query::Reach { u },
            };
            reqs.push(QueryReq { tenant: 0, query: q });
        }
        let mut exec = BatchExec::new(8);
        let answers = exec.run(&snap, &reqs);
        assert_eq!(answers.len(), reqs.len());
        for (req, ans) in reqs.iter().zip(&answers) {
            match (req.query, ans) {
                (Query::Dist { u, v }, Answer::Dist(d)) => {
                    assert_eq!(*d, snap.dist.get(u as usize, v as usize));
                }
                (Query::Path { u, v }, Answer::Path { hops, weight }) => {
                    match snap.next.as_ref().unwrap().path(u as usize, v as usize) {
                        Some(p) => {
                            assert_eq!(hops, &p);
                            assert_eq!(*weight, snap.dist.get(u as usize, v as usize));
                        }
                        None => {
                            assert!(hops.is_empty());
                            assert!(weight.is_infinite());
                        }
                    }
                }
                (Query::KNearest { u, k }, Answer::KNearest(nn)) => {
                    assert_eq!(nn.len(), (k as usize).min(n - 1));
                    for w in nn.windows(2) {
                        assert!(w[0].0 <= w[1].0);
                    }
                    for &(d, v) in nn {
                        assert_eq!(d, snap.dist.get(u as usize, v as usize));
                    }
                }
                (Query::Reach { u }, Answer::Reach(c)) => {
                    let want = (0..n)
                        .filter(|&j| j != u as usize && snap.dist.get(u as usize, j).is_finite())
                        .count();
                    assert_eq!(*c as usize, want);
                }
                (q, a) => panic!("answer kind mismatch: {q:?} -> {a:?}"),
            }
        }
    }

    #[test]
    fn non_minplus_snapshot_serves_dist_knear_reach() {
        use crate::apsp::floyd_warshall;
        let g = generators::random_connected(40, 90, Weights::Uniform(0.5, 6.0), 8);
        let sr = SemiringId::MaxMin;
        let mut dist = g.to_dense_sr(sr);
        floyd_warshall::fw_rowwise_dyn(&mut dist, sr);
        let snap = QuerySnapshot::new_sr(3, sr, dist, None);
        assert!(snap.verify());
        assert_eq!(snap.sr, SemiringId::MaxMin);
        assert!(snap.next.is_none());
        let reqs: Vec<QueryReq> = [
            Query::Dist { u: 0, v: 7 },
            Query::KNearest { u: 2, k: 5 },
            Query::Reach { u: 4 },
        ]
        .into_iter()
        .map(|query| QueryReq { tenant: 0, query })
        .collect();
        let mut exec = BatchExec::new(4);
        let answers = exec.run(&snap, &reqs);
        assert_eq!(answers[0], Answer::Dist(snap.dist.get(0, 7)));
        // widest-path "nearest" ranks by descending bottleneck capacity
        match &answers[1] {
            Answer::KNearest(nn) => {
                assert_eq!(nn.len(), 5);
                for w in nn.windows(2) {
                    assert!(w[0].0 >= w[1].0, "max-min rank must descend: {nn:?}");
                }
                for &(d, v) in nn {
                    assert_eq!(d, snap.dist.get(2, v as usize));
                    assert!(!sr.is_absorbing(d));
                }
            }
            a => panic!("expected KNearest, got {a:?}"),
        }
        // reachability counts non-absorbing entries (0.0 = no path)
        match &answers[2] {
            Answer::Reach(c) => {
                let want = (0..snap.dist.n())
                    .filter(|&j| j != 4 && !sr.is_absorbing(snap.dist.get(4, j)))
                    .count();
                assert_eq!(*c as usize, want);
            }
            a => panic!("expected Reach, got {a:?}"),
        }
        // a path query against a map-less snapshot answers the
        // unreachable sentinel instead of panicking
        let path = exec.run(&snap, &[QueryReq { tenant: 0, query: Query::Path { u: 0, v: 7 } }]);
        assert_eq!(
            path[0],
            Answer::Path { hops: Vec::new(), weight: f32::INFINITY }
        );
    }

    #[test]
    fn validate_then_serve_full_pipeline() {
        let g = generators::random_connected(25, 50, Weights::Uniform(1.0, 2.0), 5);
        let script = query::parse_query_script("dist 0 5\npath 1 9 @gold\nknear 2 3\nreach 0\n")
            .unwrap();
        query::validate_queries(g.n(), &script).unwrap();
        let snap = snapshot_of(&g, 0);
        let mut exec = BatchExec::new(4);
        let answers = exec.run(&snap, &script.batches[0]);
        assert_eq!(answers.len(), 4);
    }
}
