//! Tile compute backends: who actually executes an FW pass or MP merge.
//!
//! * [`NativeBackend`] — multithreaded rust kernels (always available).
//! * `runtime::PjrtBackend` — the AOT-compiled JAX/Pallas HLO artifacts
//!   executed through PJRT (the three-layer architecture's L1/L2).
//!
//! The recursive solver is generic over this trait, so the same
//! algorithm code runs against either engine and tests can assert they
//! agree bit-for-bit on semiring results.

use crate::apsp::semiring::SemiringId;
use crate::apsp::{floyd_warshall, minplus};
use crate::graph::dense::DistMatrix;
use crate::util::arena;

/// A tile-granular compute engine.
pub trait TileBackend: Sync {
    /// In-place Floyd–Warshall (⊕/⊗ closure) over a dense block
    /// (<= tile-size + eps; backends may pad internally).
    fn fw(&self, d: &mut DistMatrix);

    /// `C = C ⊕ (A ⊗ B)` over rectangular row-major buffers (for the
    /// default `(min, +)` semiring: `C = min(C, A (+) B)`).
    fn minplus_into(&self, c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize);

    fn name(&self) -> &'static str;

    /// The semiring this backend's `fw`/`minplus_into` evaluate. The
    /// element-agnostic layers (scheduler, recursive walk, blocked
    /// composition) read identities and merges from here, so a
    /// semiring-parameterized backend retunes them all at once.
    fn semiring(&self) -> SemiringId {
        SemiringId::MinPlus
    }

    /// Largest block `fw`/`minplus_into` accept directly (`None` =
    /// unlimited). Larger FW solves are composed by
    /// [`fw_blocked`] from tile-sized calls — exactly how the PCM dies
    /// handle a terminal boundary graph bigger than one array.
    fn max_block(&self) -> Option<usize> {
        None
    }
}

/// Blocked Floyd–Warshall composed from tile-granular `fw` +
/// `minplus_into` calls (Katz–Kider scheme): for each diagonal block k —
/// (1) FW the diagonal block, (2) relax row/column panels against it,
/// (3) ⊗-update the remainder. Exact for any backend whose two
/// primitives are exact. Generic over the backend's semiring: the
/// panel scratch resets to the ⊕-identity and panel merges go through
/// ⊕ (for `(min, +)` both are bit-identical to the old INF-fill +
/// `if o < *p` form).
pub fn fw_blocked(be: &dyn TileBackend, d: &mut DistMatrix, block: usize) {
    let n = d.n();
    let sr = be.semiring();
    let zero = sr.zero();
    if n <= block {
        return be.fw(d);
    }
    let nb = n.div_ceil(block);
    let dim = |i: usize| -> usize { (n - i * block).min(block) };
    // extract a (rows x cols) block at block-coords (bi, bj) into an
    // arena-leased buffer (recycled by the caller after `put`)
    let get = |d: &DistMatrix, bi: usize, bj: usize| -> Vec<f32> {
        let (r0, c0) = (bi * block, bj * block);
        let (rs, cs) = (dim(bi), dim(bj));
        let mut out = arena::lease_filled(rs * cs, 0.0);
        for r in 0..rs {
            out[r * cs..(r + 1) * cs].copy_from_slice(&d.row(r0 + r)[c0..c0 + cs]);
        }
        out
    };
    let put = |d: &mut DistMatrix, bi: usize, bj: usize, v: &[f32]| {
        let (r0, c0) = (bi * block, bj * block);
        let (rs, cs) = (dim(bi), dim(bj));
        debug_assert_eq!(v.len(), rs * cs);
        for r in 0..rs {
            d.row_mut(r0 + r)[c0..c0 + cs].copy_from_slice(&v[r * cs..(r + 1) * cs]);
        }
    };
    // one scratch buffer reused for every panel relax (replaces the
    // per-panel `orig` clone the old code allocated), and the row
    // panels of the current pivot kept resident so step (3) does not
    // re-extract them once per block-row; all block buffers are
    // arena-leased, so a steady-state pivot loop performs no heap
    // allocation at all
    let mut scratch = arena::scratch_filled(block * block, 0.0);
    let mut row_panels: Vec<Vec<f32>> = vec![Vec::new(); nb];
    for k in 0..nb {
        let ks = dim(k);
        // (1) diagonal block
        let mut diag = DistMatrix::from_vec(ks, get(d, k, k));
        be.fw(&mut diag);
        let diag = diag.into_vec();
        put(d, k, k, &diag);
        // (2) row panels: D[k][j] = min(D[k][j], diag (+) D[k][j]);
        // `minplus_into` accumulates into its output, so relax via the
        // INF-reset scratch and min-merge back — no aliasing, no clone
        for j in 0..nb {
            if j == k {
                continue;
            }
            let js = dim(j);
            let mut panel = get(d, k, j);
            let out = &mut scratch[..ks * js];
            out.fill(zero);
            be.minplus_into(out, &diag, &panel, ks, ks, js);
            for (p, &o) in panel.iter_mut().zip(out.iter()) {
                *p = sr.combine(*p, o);
            }
            put(d, k, j, &panel);
            let stale = std::mem::replace(&mut row_panels[j], panel);
            if stale.capacity() > 0 {
                arena::recycle(stale);
            }
        }
        //     column panels: D[i][k] = min(D[i][k], D[i][k] (+) diag)
        for i in 0..nb {
            if i == k {
                continue;
            }
            let is = dim(i);
            let mut panel = get(d, i, k);
            let out = &mut scratch[..is * ks];
            out.fill(zero);
            be.minplus_into(out, &panel, &diag, is, ks, ks);
            for (p, &o) in panel.iter_mut().zip(out.iter()) {
                *p = sr.combine(*p, o);
            }
            put(d, i, k, &panel);
            arena::recycle(panel);
        }
        arena::recycle(diag);
        // (3) outer update: D[i][j] = min(D[i][j], D[i][k] (+) D[k][j]),
        // with the row panels hoisted out of the i loop
        for i in 0..nb {
            if i == k {
                continue;
            }
            let is = dim(i);
            let col_panel = get(d, i, k);
            for j in 0..nb {
                if j == k {
                    continue;
                }
                let js = dim(j);
                let mut blk = get(d, i, j);
                be.minplus_into(&mut blk, &col_panel, &row_panels[j], is, ks, js);
                put(d, i, j, &blk);
                arena::recycle(blk);
            }
            arena::recycle(col_panel);
        }
    }
    for panel in row_panels {
        if panel.capacity() > 0 {
            arena::recycle(panel);
        }
    }
}

/// FW dispatch that respects the backend's block limit.
pub fn fw_any(be: &dyn TileBackend, d: &mut DistMatrix) {
    match be.max_block() {
        Some(mx) if d.n() > mx => fw_blocked(be, d, mx),
        _ => be.fw(d),
    }
}

/// Pure-rust parallel backend.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBackend;

impl TileBackend for NativeBackend {
    fn fw(&self, d: &mut DistMatrix) {
        floyd_warshall::fw_parallel(d);
    }

    fn minplus_into(&self, c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
        minplus::minplus_into_parallel(c, a, b, m, k, n);
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Serial reference backend (tests).
#[derive(Debug, Default, Clone, Copy)]
pub struct SerialBackend;

impl TileBackend for SerialBackend {
    fn fw(&self, d: &mut DistMatrix) {
        floyd_warshall::fw_rowwise(d);
    }

    fn minplus_into(&self, c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
        minplus::minplus_into(c, a, b, m, k, n);
    }

    fn name(&self) -> &'static str {
        "serial"
    }
}

/// Always-available scalar oracle: kernels pinned to the plain scalar
/// microkernels (never the explicit-SIMD dispatch), regardless of CPU.
/// Every other backend is required to agree with this one bit-for-bit.
#[derive(Debug, Default, Clone, Copy)]
pub struct ScalarBackend;

impl TileBackend for ScalarBackend {
    fn fw(&self, d: &mut DistMatrix) {
        floyd_warshall::fw_inplace(d);
    }

    fn minplus_into(&self, c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
        minplus::minplus_into_scalar(c, a, b, m, k, n);
    }

    fn name(&self) -> &'static str {
        "scalar"
    }
}

/// Explicit-SIMD variant of the serial backend: the same register-tiled
/// kernels, routed through the `#[cfg]`-gated AVX2 relax microkernel
/// when the CPU supports it (elsewhere it degrades to the identical
/// auto-vectorized scalar path — results are bit-equal either way, see
/// `tests/kernel_properties.rs`).
#[derive(Debug, Default, Clone, Copy)]
pub struct SimdBackend;

impl TileBackend for SimdBackend {
    fn fw(&self, d: &mut DistMatrix) {
        floyd_warshall::fw_rowwise(d);
    }

    fn minplus_into(&self, c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
        minplus::minplus_into(c, a, b, m, k, n);
    }

    fn name(&self) -> &'static str {
        "simd"
    }
}

/// Execution flavor of a [`DpBackend`] — mirrors the four unit
/// backends above.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Multithreaded kernels (the [`NativeBackend`] flavor).
    Native,
    /// Serial register-tiled kernels ([`SerialBackend`]).
    Serial,
    /// Scalar-oracle kernels ([`ScalarBackend`]).
    Scalar,
    /// Explicit-SIMD-dispatching serial kernels ([`SimdBackend`]).
    Simd,
}

/// Semiring-parameterized tile backend: the engine the executor hands
/// to the scheduler once a `--workload` is chosen. For
/// `SemiringId::MinPlus` every dispatch lands on the exact concrete
/// kernel the matching unit backend uses (same `name()`, same code),
/// so the MinPlus instantiation is bit-identical to the pre-refactor
/// path; other semirings route to the generic `_sr` kernels.
#[derive(Debug, Clone, Copy)]
pub struct DpBackend {
    pub kind: BackendKind,
    pub sr: SemiringId,
}

impl DpBackend {
    pub fn new(kind: BackendKind, sr: SemiringId) -> Self {
        Self { kind, sr }
    }

    pub fn native(sr: SemiringId) -> Self {
        Self::new(BackendKind::Native, sr)
    }

    pub fn serial(sr: SemiringId) -> Self {
        Self::new(BackendKind::Serial, sr)
    }

    pub fn scalar(sr: SemiringId) -> Self {
        Self::new(BackendKind::Scalar, sr)
    }

    pub fn simd(sr: SemiringId) -> Self {
        Self::new(BackendKind::Simd, sr)
    }
}

impl TileBackend for DpBackend {
    fn fw(&self, d: &mut DistMatrix) {
        match (self.sr, self.kind) {
            (SemiringId::MinPlus, BackendKind::Native) => floyd_warshall::fw_parallel(d),
            (SemiringId::MinPlus, BackendKind::Serial | BackendKind::Simd) => {
                floyd_warshall::fw_rowwise(d)
            }
            (SemiringId::MinPlus, BackendKind::Scalar) => floyd_warshall::fw_inplace(d),
            (sr, kind) => crate::dispatch_semiring!(sr, S => match kind {
                BackendKind::Native => floyd_warshall::fw_parallel_sr::<S>(d),
                BackendKind::Serial | BackendKind::Simd => floyd_warshall::fw_rowwise_sr::<S>(d),
                BackendKind::Scalar => floyd_warshall::fw_inplace_sr::<S>(d),
            }),
        }
    }

    fn minplus_into(&self, c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
        match (self.sr, self.kind) {
            (SemiringId::MinPlus, BackendKind::Native) => {
                minplus::minplus_into_parallel(c, a, b, m, k, n)
            }
            (SemiringId::MinPlus, BackendKind::Serial | BackendKind::Simd) => {
                minplus::minplus_into(c, a, b, m, k, n)
            }
            (SemiringId::MinPlus, BackendKind::Scalar) => {
                minplus::minplus_into_scalar(c, a, b, m, k, n)
            }
            (sr, kind) => crate::dispatch_semiring!(sr, S => match kind {
                BackendKind::Native => minplus::product_into_parallel::<S>(c, a, b, m, k, n),
                BackendKind::Serial | BackendKind::Simd => {
                    minplus::product_into::<S>(c, a, b, m, k, n)
                }
                BackendKind::Scalar => minplus::product_into_scalar::<S>(c, a, b, m, k, n),
            }),
        }
    }

    /// Same names as the unit backends — the scheduler's
    /// serial-batch-kernel heuristic keys on `"native"`, and reports
    /// stay stable across the redesign.
    fn name(&self) -> &'static str {
        match self.kind {
            BackendKind::Native => "native",
            BackendKind::Serial => "serial",
            BackendKind::Scalar => "scalar",
            BackendKind::Simd => "simd",
        }
    }

    fn semiring(&self) -> SemiringId {
        self.sr
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, Weights};
    use crate::INF;

    #[test]
    fn backends_agree_on_fw() {
        let g = generators::random_connected(90, 200, Weights::Uniform(0.5, 4.0), 1);
        let base = g.to_dense();
        let mut a = base.clone();
        NativeBackend.fw(&mut a);
        for be in [&SerialBackend as &dyn TileBackend, &ScalarBackend, &SimdBackend] {
            let mut b = base.clone();
            be.fw(&mut b);
            assert_eq!(a.max_diff(&b), 0.0, "backend {}", be.name());
        }
    }

    #[test]
    fn fw_blocked_matches_direct() {
        for (n, block) in [(50usize, 16usize), (64, 32), (97, 32), (130, 64)] {
            let g = generators::random_connected(n, 2 * n, Weights::Uniform(0.5, 4.0), n as u64);
            let mut direct = g.to_dense();
            SerialBackend.fw(&mut direct);
            let mut blocked = g.to_dense();
            fw_blocked(&SerialBackend, &mut blocked, block);
            let diff = direct.max_diff(&blocked);
            assert!(diff < 1e-4, "n={n} block={block}: diff {diff}");
        }
    }

    #[test]
    fn fw_any_respects_limit() {
        struct Limited;
        impl TileBackend for Limited {
            fn fw(&self, d: &mut DistMatrix) {
                assert!(d.n() <= 32, "fw called with n={} > limit", d.n());
                crate::apsp::floyd_warshall::fw_rowwise(d);
            }
            fn minplus_into(
                &self,
                c: &mut [f32],
                a: &[f32],
                b: &[f32],
                m: usize,
                k: usize,
                n: usize,
            ) {
                assert!(m <= 32 && k <= 32 && n <= 32);
                crate::apsp::minplus::minplus_into(c, a, b, m, k, n);
            }
            fn name(&self) -> &'static str {
                "limited"
            }
            fn max_block(&self) -> Option<usize> {
                Some(32)
            }
        }
        let g = generators::random_connected(90, 200, Weights::Uniform(0.5, 3.0), 5);
        let mut via_limited = g.to_dense();
        fw_any(&Limited, &mut via_limited);
        let mut direct = g.to_dense();
        SerialBackend.fw(&mut direct);
        assert!(via_limited.max_diff(&direct) < 1e-4);
    }

    #[test]
    fn dp_backend_minplus_matches_unit_backends() {
        let g = generators::random_connected(90, 200, Weights::Uniform(0.5, 4.0), 3);
        let base = g.to_dense();
        let pairs: [(&dyn TileBackend, DpBackend); 4] = [
            (&NativeBackend, DpBackend::native(SemiringId::MinPlus)),
            (&SerialBackend, DpBackend::serial(SemiringId::MinPlus)),
            (&ScalarBackend, DpBackend::scalar(SemiringId::MinPlus)),
            (&SimdBackend, DpBackend::simd(SemiringId::MinPlus)),
        ];
        for (unit, dp) in pairs {
            assert_eq!(unit.name(), dp.name());
            let mut a = base.clone();
            unit.fw(&mut a);
            let mut b = base.clone();
            dp.fw(&mut b);
            let bits = a.as_slice().iter().zip(b.as_slice());
            assert!(bits.clone().all(|(x, y)| x.to_bits() == y.to_bits()), "{}", dp.name());
        }
    }

    #[test]
    fn fw_blocked_matches_direct_every_semiring() {
        use crate::apsp::semiring::ALL_SEMIRINGS;
        for sr in ALL_SEMIRINGS {
            let g = generators::random_connected(97, 250, Weights::Uniform(0.5, 4.0), 9);
            let g = if sr == SemiringId::MaxPlus { g.dag_oriented() } else { g };
            let be = DpBackend::serial(sr);
            let mut direct = g.to_dense_sr(sr);
            be.fw(&mut direct);
            let mut blocked = g.to_dense_sr(sr);
            fw_blocked(&be, &mut blocked, 32);
            let diff = direct.max_diff(&blocked);
            assert!(diff < 1e-4, "{}: blocked diff {diff}", sr.name());
        }
    }

    #[test]
    fn backends_agree_on_minplus() {
        let mut rng = crate::util::rng::Rng::new(2);
        let (m, k, n) = (33usize, 47usize, 29usize);
        let mk: Vec<f32> = (0..m * k)
            .map(|_| {
                if rng.gen_bool(0.3) {
                    INF
                } else {
                    rng.gen_f32_range(0.0, 9.0)
                }
            })
            .collect();
        let kn: Vec<f32> = (0..k * n).map(|_| rng.gen_f32_range(0.0, 9.0)).collect();
        let mut c1 = vec![INF; m * n];
        NativeBackend.minplus_into(&mut c1, &mk, &kn, m, k, n);
        for be in [&SerialBackend as &dyn TileBackend, &ScalarBackend, &SimdBackend] {
            let mut c2 = vec![INF; m * n];
            be.minplus_into(&mut c2, &mk, &kn, m, k, n);
            assert_eq!(c1, c2, "backend {}", be.name());
        }
    }
}
