//! The semiring element API the tile kernels are generic over.
//!
//! RAPID-Graph's FW/min-plus pair is `(min, +)`-specific only at the
//! innermost loop (GenDRAM / GEN-Graph generalize exactly this PIM
//! architecture to arbitrary graph dynamic programming). This module
//! abstracts that loop: a [`Semiring`] supplies the two operators —
//! `combine` (⊕, the reduction across candidate paths) and `extend`
//! (⊗, the extension of a path by one more hop) — plus their
//! identities, an absorbing-element early-out, and optional SIMD hooks
//! for the row microkernels. Everything above the kernels (taskgraph
//! lowering, list scheduler, arena, store, admission) is element-
//! agnostic and applies unchanged.
//!
//! Shipped instances (all over `f32` storage):
//!
//! | instance      | ⊕   | ⊗   | zero  | one  | workload                 |
//! |---------------|-----|-----|-------|------|--------------------------|
//! | [`MinPlus`]   | min | +   | +inf  | 0    | APSP (shortest paths)    |
//! | [`BoolAndOr`] | or  | and | 0     | 1    | reachability / closure   |
//! | [`MaxMin`]    | max | min | 0     | +inf | widest path (bottleneck) |
//! | [`MaxPlus`]   | max | +   | -inf  | 0    | critical path (DAG only) |
//!
//! # Laws the kernels rely on
//!
//! * `combine` is associative, commutative, idempotent, with identity
//!   `zero`; `extend` is associative with identity `one`.
//! * `extend` distributes over `combine` and `zero` annihilates:
//!   `extend(zero, x) = zero` — this is what lets the row sweep skip
//!   absorbing pivots (`is_absorbing`) and lets the fused 4-row kernel
//!   process an absorbing lane unconditionally (`combine(c, zero) = c`).
//! * The closure (fixed point) of the FW recurrence exists on every
//!   input the workload admits; `MaxPlus` has no fixed point on cyclic
//!   inputs, so its workload DAG-restricts the graph first (the
//!   executor orients edges and runs a Kahn cycle guard).
//!
//! `MinPlus` is required to be *bit-identical* to the pre-refactor
//! concrete kernels: its `combine`/`is_absorbing` mirror the exact
//! comparisons the kernels used (`if b < a`, `!(x < INF)`) and its SIMD
//! hooks delegate to the unchanged AVX2-dispatching microkernels.
//! `tests/kernel_properties.rs` pins all of this.

use crate::INF;

/// Runtime tag for a shipped semiring instance. The config/CLI layer
/// stores this; kernels monomorphize through [`dispatch_semiring!`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SemiringId {
    /// `(min, +)` — shortest paths (APSP).
    MinPlus,
    /// `(or, and)` on {0, 1} — transitive closure / reachability.
    BoolAndOr,
    /// `(max, min)` — widest path / bottleneck bandwidth.
    MaxMin,
    /// `(max, +)` — critical path; requires DAG-restricted input.
    MaxPlus,
}

impl SemiringId {
    pub fn name(self) -> &'static str {
        match self {
            SemiringId::MinPlus => "min-plus",
            SemiringId::BoolAndOr => "bool-and-or",
            SemiringId::MaxMin => "max-min",
            SemiringId::MaxPlus => "max-plus",
        }
    }

    /// ⊕-identity (the "no path" element, matrix background fill).
    #[inline]
    pub fn zero(self) -> f32 {
        crate::dispatch_semiring!(self, S => S::zero())
    }

    /// ⊗-identity (the "empty path" element, matrix diagonal).
    #[inline]
    pub fn one(self) -> f32 {
        crate::dispatch_semiring!(self, S => S::one())
    }

    /// ⊕ — reduce two path values (runtime-dispatched form).
    #[inline]
    pub fn combine(self, a: f32, b: f32) -> f32 {
        crate::dispatch_semiring!(self, S => S::combine(a, b))
    }

    /// ⊗ — extend a path value by another (runtime-dispatched form).
    #[inline]
    pub fn extend(self, a: f32, b: f32) -> f32 {
        crate::dispatch_semiring!(self, S => S::extend(a, b))
    }

    /// `true` iff `x` can never improve any ⊕ (early-out for pivots).
    #[inline]
    pub fn is_absorbing(self, x: f32) -> bool {
        crate::dispatch_semiring!(self, S => S::is_absorbing(x))
    }

    /// Map a raw edge weight into the element domain.
    #[inline]
    pub fn from_weight(self, w: f32) -> f32 {
        crate::dispatch_semiring!(self, S => S::from_weight(w))
    }

    /// `true` when ⊕ prefers the numerically larger value (the
    /// max-style semirings); rank order for the serve loop's k-nearest.
    #[inline]
    pub fn prefers_larger(self) -> bool {
        !matches!(self, SemiringId::MinPlus)
    }
}

/// A semiring the tile kernels can run the FW/closure DP over.
///
/// The associated `Elem` keeps the door open for wider elements; every
/// shipped instance uses `f32`, and the kernel layer is generic over
/// `S: Semiring<Elem = f32>` so [`crate::graph::dense::DistMatrix`]
/// storage stays a flat `Vec<f32>`.
pub trait Semiring: Copy + Send + Sync + 'static {
    /// Element domain of the DP values.
    type Elem: Copy + PartialEq + Send + Sync + 'static;

    /// The runtime tag for this instance.
    const ID: SemiringId;

    /// ⊕-identity: combine(x, zero()) == x for all x.
    fn zero() -> Self::Elem;

    /// ⊗-identity: extend(x, one()) == x for all x.
    fn one() -> Self::Elem;

    /// ⊕ — reduce two candidate path values.
    fn combine(a: Self::Elem, b: Self::Elem) -> Self::Elem;

    /// ⊗ — extend a path value by another.
    fn extend(a: Self::Elem, b: Self::Elem) -> Self::Elem;

    /// `true` iff `x` is the absorbing zero (extend(x, _) == zero()),
    /// so a row sweep against pivot value `x` is a no-op and may be
    /// skipped. Must also return `true` for NaN so a poisoned element
    /// never enters the fast path.
    fn is_absorbing(x: Self::Elem) -> bool;

    /// Map a raw (finite, non-negative) edge weight into the element
    /// domain when materializing a graph into a DP matrix.
    fn from_weight(w: f32) -> Self::Elem;

    /// SIMD hook: one FW row update, `row_i[j] = combine(row_i[j],
    /// extend(dik, row_k[j]))`. The default is the portable scalar
    /// loop; instances with an explicit vector kernel (MinPlus → AVX2)
    /// override it. `dik` is guaranteed non-absorbing by callers.
    #[inline]
    fn relax_row(row_i: &mut [Self::Elem], dik: Self::Elem, row_k: &[Self::Elem]) {
        let m = row_i.len().min(row_k.len());
        for (x, &b) in row_i[..m].iter_mut().zip(&row_k[..m]) {
            *x = Self::combine(*x, Self::extend(dik, b));
        }
    }

    /// SIMD hook: fused 4-row relax (one pass over `row_k` feeds four
    /// accumulator rows). `dik` lanes may be absorbing — the zero law
    /// (`combine(c, extend(zero, b)) = c`) makes processing such a
    /// lane a no-op, so the fused form stays equal to four sequential
    /// [`Semiring::relax_row`] calls with absorbing lanes skipped.
    #[inline]
    fn relax_rows4(
        r0: &mut [Self::Elem],
        r1: &mut [Self::Elem],
        r2: &mut [Self::Elem],
        r3: &mut [Self::Elem],
        dik: [Self::Elem; 4],
        row_k: &[Self::Elem],
    ) {
        let m = row_k
            .len()
            .min(r0.len())
            .min(r1.len())
            .min(r2.len())
            .min(r3.len());
        let rk = &row_k[..m];
        for j in 0..m {
            let b = rk[j];
            r0[j] = Self::combine(r0[j], Self::extend(dik[0], b));
            r1[j] = Self::combine(r1[j], Self::extend(dik[1], b));
            r2[j] = Self::combine(r2[j], Self::extend(dik[2], b));
            r3[j] = Self::combine(r3[j], Self::extend(dik[3], b));
        }
    }
}

/// `(min, +)` — today's APSP. Bit-identical to the pre-refactor
/// kernels: `combine` keeps the first argument on ties (the exact
/// `if b < a { b } else { a }` select every kernel merge used, with no
/// `f32::min` ±0.0 subtleties), `is_absorbing` is the literal
/// `!(x < INF)` guard, and the SIMD hooks delegate to the unchanged
/// AVX2-dispatching microkernels.
#[derive(Debug, Default, Clone, Copy)]
pub struct MinPlus;

impl Semiring for MinPlus {
    type Elem = f32;
    const ID: SemiringId = SemiringId::MinPlus;

    #[inline]
    fn zero() -> f32 {
        INF
    }

    #[inline]
    fn one() -> f32 {
        0.0
    }

    #[inline]
    fn combine(a: f32, b: f32) -> f32 {
        if b < a {
            b
        } else {
            a
        }
    }

    #[inline]
    fn extend(a: f32, b: f32) -> f32 {
        a + b
    }

    #[inline]
    fn is_absorbing(x: f32) -> bool {
        !(x < INF)
    }

    #[inline]
    fn from_weight(w: f32) -> f32 {
        w
    }

    #[inline]
    fn relax_row(row_i: &mut [f32], dik: f32, row_k: &[f32]) {
        crate::apsp::floyd_warshall::relax_row(row_i, dik, row_k);
    }

    #[inline]
    fn relax_rows4(
        r0: &mut [f32],
        r1: &mut [f32],
        r2: &mut [f32],
        r3: &mut [f32],
        dik: [f32; 4],
        row_k: &[f32],
    ) {
        crate::apsp::floyd_warshall::relax_rows4(r0, r1, r2, r3, dik, row_k);
    }
}

/// `(or, and)` on {0.0, 1.0} — transitive closure / reachability.
/// Encoded as max/min over {0, 1} so the element stays `f32` and the
/// generic kernels apply unchanged; `from_weight` maps every edge to
/// 1.0 (present).
#[derive(Debug, Default, Clone, Copy)]
pub struct BoolAndOr;

impl Semiring for BoolAndOr {
    type Elem = f32;
    const ID: SemiringId = SemiringId::BoolAndOr;

    #[inline]
    fn zero() -> f32 {
        0.0
    }

    #[inline]
    fn one() -> f32 {
        1.0
    }

    #[inline]
    fn combine(a: f32, b: f32) -> f32 {
        // or == max on {0, 1}
        if b > a {
            b
        } else {
            a
        }
    }

    #[inline]
    fn extend(a: f32, b: f32) -> f32 {
        // and == min on {0, 1}
        if b < a {
            b
        } else {
            a
        }
    }

    #[inline]
    fn is_absorbing(x: f32) -> bool {
        !(x > 0.0)
    }

    #[inline]
    fn from_weight(_w: f32) -> f32 {
        1.0
    }
}

/// `(max, min)` — widest path / maximum bottleneck bandwidth. The
/// value of a path is its narrowest edge; ⊕ picks the widest
/// alternative. Unreachable is width 0 (the annihilator for min over
/// non-negative capacities); the self-path has unbounded width (+inf).
#[derive(Debug, Default, Clone, Copy)]
pub struct MaxMin;

impl Semiring for MaxMin {
    type Elem = f32;
    const ID: SemiringId = SemiringId::MaxMin;

    #[inline]
    fn zero() -> f32 {
        0.0
    }

    #[inline]
    fn one() -> f32 {
        INF
    }

    #[inline]
    fn combine(a: f32, b: f32) -> f32 {
        if b > a {
            b
        } else {
            a
        }
    }

    #[inline]
    fn extend(a: f32, b: f32) -> f32 {
        if b < a {
            b
        } else {
            a
        }
    }

    #[inline]
    fn is_absorbing(x: f32) -> bool {
        !(x > 0.0)
    }

    #[inline]
    fn from_weight(w: f32) -> f32 {
        w
    }
}

/// `(max, +)` — longest path / critical path. Only a valid DP on DAGs
/// (a positive cycle has no fixed point), so the `critical` workload
/// DAG-orients its input and runs a Kahn cycle guard before solving.
/// The absorbing zero is `-inf` — the sign-of-infinity hazard the
/// store compression and validation tolerance checks are audited for.
#[derive(Debug, Default, Clone, Copy)]
pub struct MaxPlus;

impl Semiring for MaxPlus {
    type Elem = f32;
    const ID: SemiringId = SemiringId::MaxPlus;

    #[inline]
    fn zero() -> f32 {
        f32::NEG_INFINITY
    }

    #[inline]
    fn one() -> f32 {
        0.0
    }

    #[inline]
    fn combine(a: f32, b: f32) -> f32 {
        if b > a {
            b
        } else {
            a
        }
    }

    #[inline]
    fn extend(a: f32, b: f32) -> f32 {
        a + b
    }

    #[inline]
    fn is_absorbing(x: f32) -> bool {
        !(x > f32::NEG_INFINITY)
    }

    #[inline]
    fn from_weight(w: f32) -> f32 {
        w
    }
}

/// Monomorphize a semiring-generic expression from a runtime
/// [`SemiringId`]: `dispatch_semiring!(id, S => expr_using_S)`.
#[macro_export]
macro_rules! dispatch_semiring {
    ($id:expr, $S:ident => $body:expr) => {
        match $id {
            $crate::apsp::semiring::SemiringId::MinPlus => {
                type $S = $crate::apsp::semiring::MinPlus;
                $body
            }
            $crate::apsp::semiring::SemiringId::BoolAndOr => {
                type $S = $crate::apsp::semiring::BoolAndOr;
                $body
            }
            $crate::apsp::semiring::SemiringId::MaxMin => {
                type $S = $crate::apsp::semiring::MaxMin;
                $body
            }
            $crate::apsp::semiring::SemiringId::MaxPlus => {
                type $S = $crate::apsp::semiring::MaxPlus;
                $body
            }
        }
    };
}

/// All shipped instances, for exhaustive law/property tests.
pub const ALL_SEMIRINGS: [SemiringId; 4] = [
    SemiringId::MinPlus,
    SemiringId::BoolAndOr,
    SemiringId::MaxMin,
    SemiringId::MaxPlus,
];

#[cfg(test)]
mod tests {
    use super::*;

    /// Representative element sample per semiring (includes the
    /// identities and the absorbing zero).
    fn sample(sr: SemiringId) -> Vec<f32> {
        let mut v = match sr {
            SemiringId::BoolAndOr => vec![0.0, 1.0],
            _ => vec![0.5, 1.0, 2.5, 7.0],
        };
        v.push(sr.zero());
        v.push(sr.one());
        v
    }

    #[test]
    fn identity_laws() {
        for sr in ALL_SEMIRINGS {
            for &x in &sample(sr) {
                assert_eq!(
                    sr.combine(x, sr.zero()).to_bits(),
                    x.to_bits(),
                    "{}: combine zero identity at {x}",
                    sr.name()
                );
                assert_eq!(
                    sr.extend(x, sr.one()).to_bits(),
                    x.to_bits(),
                    "{}: extend one identity at {x}",
                    sr.name()
                );
            }
        }
    }

    #[test]
    fn zero_annihilates_extend() {
        for sr in ALL_SEMIRINGS {
            assert!(sr.is_absorbing(sr.zero()), "{}", sr.name());
            for &x in &sample(sr) {
                let z = sr.extend(sr.zero(), x);
                assert!(
                    sr.is_absorbing(z),
                    "{}: extend(zero, {x}) = {z} not absorbing",
                    sr.name()
                );
            }
        }
    }

    #[test]
    fn combine_assoc_comm_idempotent() {
        for sr in ALL_SEMIRINGS {
            let s = sample(sr);
            for &a in &s {
                assert_eq!(sr.combine(a, a), a, "{}: idempotence", sr.name());
                for &b in &s {
                    assert_eq!(
                        sr.combine(a, b),
                        sr.combine(b, a),
                        "{}: commutativity",
                        sr.name()
                    );
                    for &c in &s {
                        assert_eq!(
                            sr.combine(sr.combine(a, b), c),
                            sr.combine(a, sr.combine(b, c)),
                            "{}: associativity",
                            sr.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn extend_distributes_over_combine() {
        for sr in ALL_SEMIRINGS {
            let s = sample(sr);
            for &a in &s {
                for &b in &s {
                    for &c in &s {
                        let lhs = sr.extend(a, sr.combine(b, c));
                        let rhs = sr.combine(sr.extend(a, b), sr.extend(a, c));
                        // MaxPlus adds reals: compare with a float eps;
                        // the other instances are exact selections
                        let ok = lhs == rhs || (lhs - rhs).abs() < 1e-6;
                        assert!(ok, "{}: distributivity {a} {b} {c}", sr.name());
                    }
                }
            }
        }
    }

    #[test]
    fn absorbing_matches_pinned_guards() {
        // MinPlus must use the literal `!(x < INF)` guard the concrete
        // kernels use, including for NaN
        assert!(SemiringId::MinPlus.is_absorbing(INF));
        assert!(SemiringId::MinPlus.is_absorbing(f32::NAN));
        assert!(!SemiringId::MinPlus.is_absorbing(1e30));
        assert!(SemiringId::MaxPlus.is_absorbing(f32::NEG_INFINITY));
        assert!(SemiringId::MaxPlus.is_absorbing(f32::NAN));
        assert!(!SemiringId::MaxPlus.is_absorbing(-1e30));
        for sr in [SemiringId::BoolAndOr, SemiringId::MaxMin] {
            assert!(sr.is_absorbing(0.0));
            assert!(sr.is_absorbing(-0.0));
            assert!(sr.is_absorbing(f32::NAN));
            assert!(!sr.is_absorbing(1.0));
        }
    }

    #[test]
    fn minplus_combine_keeps_first_on_ties() {
        // the exact select the kernels' merge loops used: ties (and
        // ±0.0) keep the accumulator bits
        assert_eq!(MinPlus::combine(0.0, -0.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(MinPlus::combine(-0.0, 0.0).to_bits(), (-0.0f32).to_bits());
        assert_eq!(MinPlus::combine(3.0, 3.0), 3.0);
        assert_eq!(MinPlus::combine(INF, 5.0), 5.0);
        assert_eq!(MinPlus::combine(5.0, INF), 5.0);
    }

    #[test]
    fn dispatch_macro_reaches_every_instance() {
        for sr in ALL_SEMIRINGS {
            let z = crate::dispatch_semiring!(sr, S => S::zero());
            assert_eq!(z.to_bits(), sr.zero().to_bits());
        }
    }

    #[test]
    fn default_rows4_matches_sequential_relax() {
        let mut rng = crate::util::rng::Rng::new(29);
        for sr in ALL_SEMIRINGS {
            for _ in 0..10 {
                let n = 1 + rng.gen_range(30);
                let mk = |rng: &mut crate::util::rng::Rng| -> Vec<f32> {
                    (0..n)
                        .map(|_| {
                            if rng.gen_bool(0.2) {
                                sr.zero()
                            } else {
                                sr.from_weight(rng.gen_f32_range(0.1, 9.0))
                            }
                        })
                        .collect()
                };
                let rows: Vec<Vec<f32>> = (0..4).map(|_| mk(&mut rng)).collect();
                let rk = mk(&mut rng);
                let dik = [
                    sr.from_weight(rng.gen_f32_range(0.1, 5.0)),
                    sr.zero(),
                    sr.from_weight(rng.gen_f32_range(0.1, 5.0)),
                    sr.from_weight(rng.gen_f32_range(0.1, 5.0)),
                ];
                let mut fused = rows.clone();
                {
                    let (a, rest) = fused.split_at_mut(1);
                    let (b, rest2) = rest.split_at_mut(1);
                    let (c, e) = rest2.split_at_mut(1);
                    crate::dispatch_semiring!(sr, S => S::relax_rows4(
                        &mut a[0], &mut b[0], &mut c[0], &mut e[0], dik, &rk,
                    ));
                }
                let mut seq = rows.clone();
                for (r, &dk) in seq.iter_mut().zip(&dik) {
                    if !sr.is_absorbing(dk) {
                        crate::dispatch_semiring!(sr, S => S::relax_row(r, dk, &rk));
                    }
                }
                for (f, s) in fused.iter().zip(&seq) {
                    let same = f.iter().zip(s.iter()).all(|(x, y)| x.to_bits() == y.to_bits());
                    assert!(same, "{}: fused diverged from sequential", sr.name());
                }
            }
        }
    }
}
