//! Multi-graph batch engine: merge N independent task graphs into one
//! shared-resource schedule.
//!
//! One `Executor::run` keeps the modeled dies busy only along a single
//! graph's critical path — its bubbles leave FW tiles and channels
//! idle. Independent graphs have zero cross dependencies, so their
//! tile-task DAGs ([`super::taskgraph`]) can be unioned and interleaved
//! on one resource model: while graph A waits on its boundary merge,
//! graph B's component FW fills the die.
//!
//! [`BatchGraph::build`] lowers each graph's recursion plan via
//! [`super::taskgraph::lower`], offsets every task id and trace-step id
//! into a disjoint per-graph namespace, tags every node with its owning
//! graph, and unions the results into a single [`TaskGraph`]. No
//! cross-graph edge exists by construction (debug-asserted), so any
//! schedule of the merged graph is a legal interleaving of the N solo
//! schedules. Two consumers:
//!
//! * the host executor ([`super::scheduler::execute_batch`]) runs the
//!   merged graph with one work-stealing worker pool — per-graph buffer
//!   namespaces (each graph owns its own slot set) keep the runs
//!   isolated, and per-graph results are **bit-identical** to N
//!   sequential solo runs;
//! * the simulator ([`crate::sim::engine::simulate_batch`]) costs the
//!   interleaving on the shared FW-die slots / MP die / UCIe-HBM-FeNAND
//!   channels and attributes makespan, busy time, and dynamic energy
//!   back to each graph by node ownership.

use super::plan::ApspPlan;
use super::taskgraph::{lower, TaskGraph, TaskId};

/// N independent task graphs merged into one schedulable workload.
#[derive(Debug, Clone)]
pub struct BatchGraph {
    /// The solo lowering of each submitted graph, in submission order
    /// (kept for per-graph baselines: solo simulation, trace assembly).
    pub per_graph: Vec<TaskGraph>,
    /// Disjoint union of `per_graph` with task and step ids offset into
    /// per-graph namespaces.
    pub merged: TaskGraph,
    /// Owning graph index of every merged node (parallel to
    /// `merged.nodes`).
    pub owner: Vec<u32>,
    /// Merged-id range of graph `i`: `node_offset[i]..node_offset[i+1]`
    /// (length `n_graphs + 1`).
    pub node_offset: Vec<TaskId>,
}

impl Default for BatchGraph {
    /// The empty batch — `node_offset` carries its length-`n + 1`
    /// sentinel shape from the start, so every construction path
    /// ([`BatchGraph::push`] and friends) upholds the
    /// `node_offset[i]..node_offset[i + 1]` range contract.
    fn default() -> Self {
        BatchGraph {
            per_graph: Vec::new(),
            merged: TaskGraph::default(),
            owner: Vec::new(),
            node_offset: vec![0],
        }
    }
}

impl BatchGraph {
    /// Lower every plan and merge the results.
    pub fn build(plans: &[&ApspPlan]) -> BatchGraph {
        Self::merge(plans.iter().map(|p| lower(p)).collect())
    }

    /// Merge already-lowered graphs into one batch.
    pub fn merge(per_graph: Vec<TaskGraph>) -> BatchGraph {
        let mut batch = BatchGraph::default();
        for tg in per_graph {
            batch.push(tg);
        }
        debug_assert!(
            batch.merged.validate().is_ok(),
            "{:?}",
            batch.merged.validate()
        );
        batch
    }

    /// Append one more lowered graph to the union, in its own task and
    /// step id namespace (the admission pipeline grows its merged
    /// schedule one admitted graph at a time with exactly this call).
    /// Returns the new graph's index.
    pub fn push(&mut self, tg: TaskGraph) -> u32 {
        let gi = self.per_graph.len() as u32;
        let (noff, _) = self.merged.append_offset(&tg);
        debug_assert_eq!(noff, self.node_offset[gi as usize]);
        // disjoint namespaces: append_offset asserts no edge leaves the
        // new graph's id range
        self.owner.resize(self.merged.nodes.len(), gi);
        self.node_offset.push(self.merged.nodes.len() as TaskId);
        self.per_graph.push(tg);
        gi
    }

    pub fn n_graphs(&self) -> usize {
        self.per_graph.len()
    }

    /// Owning graph and graph-local task id of a merged node.
    pub fn local(&self, id: TaskId) -> (u32, TaskId) {
        let g = self.owner[id as usize];
        (g, id - self.node_offset[g as usize])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::plan::{build_plan, PlanOptions};
    use crate::graph::generators::{self, Topology, Weights};

    fn lowered(topo: Topology, n: usize, tile: usize, seed: u64) -> TaskGraph {
        let g = generators::generate(topo, n, 10.0, Weights::Uniform(1.0, 5.0), seed);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: tile,
                max_depth: usize::MAX,
                seed,
            },
        );
        lower(&plan)
    }

    #[test]
    fn merge_is_disjoint_union() {
        let a = lowered(Topology::Nws, 500, 48, 1);
        let b = lowered(Topology::Er, 350, 32, 2);
        let c = lowered(Topology::Grid, 400, 40, 3);
        let (na, nb, nc) = (a.n_tasks(), b.n_tasks(), c.n_tasks());
        let batch = BatchGraph::merge(vec![a, b, c]);
        batch.merged.validate().unwrap();
        assert_eq!(batch.n_graphs(), 3);
        assert_eq!(batch.merged.n_tasks(), na + nb + nc);
        assert_eq!(batch.node_offset, vec![0, na as u32, (na + nb) as u32, (na + nb + nc) as u32]);
        // ownership matches the id ranges, and edges never cross graphs
        for node in &batch.merged.nodes {
            let (gi, local) = batch.local(node.id);
            let lo = batch.node_offset[gi as usize];
            let hi = batch.node_offset[gi as usize + 1];
            assert!(node.id >= lo && node.id < hi);
            for &d in &node.deps {
                assert!(d >= lo && d < hi, "edge {d}->{} crosses graphs", node.id);
            }
            // the merged node is the solo node shifted by the offset
            let solo = &batch.per_graph[gi as usize].nodes[local as usize];
            assert_eq!(node.kind, solo.kind);
            assert_eq!(node.ops, solo.ops);
            assert_eq!(node.deps.len(), solo.deps.len());
            for (&d, &sd) in node.deps.iter().zip(&solo.deps) {
                assert_eq!(d, sd + lo);
            }
        }
    }

    #[test]
    fn merged_trace_is_concatenation_of_solo_traces() {
        let a = lowered(Topology::Nws, 400, 48, 4);
        let b = lowered(Topology::OgbnProxy, 600, 64, 5);
        let ta = a.to_trace();
        let tb = b.to_trace();
        let batch = BatchGraph::merge(vec![a, b]);
        let merged = batch.merged.to_trace();
        assert_eq!(merged.steps.len(), ta.steps.len() + tb.steps.len());
        for (i, s) in ta.steps.iter().enumerate() {
            assert_eq!(&merged.steps[i], s);
        }
        for (i, s) in tb.steps.iter().enumerate() {
            assert_eq!(&merged.steps[ta.steps.len() + i], s);
        }
    }

    #[test]
    fn incremental_push_equals_merge() {
        let a = lowered(Topology::Nws, 400, 48, 7);
        let b = lowered(Topology::Er, 300, 32, 8);
        let c = lowered(Topology::Grid, 350, 40, 9);
        let merged = BatchGraph::merge(vec![a.clone(), b.clone(), c.clone()]);
        let mut inc = BatchGraph::default();
        assert_eq!(inc.push(a), 0);
        assert_eq!(inc.push(b), 1);
        assert_eq!(inc.push(c), 2);
        assert_eq!(inc.node_offset, merged.node_offset);
        assert_eq!(inc.owner, merged.owner);
        assert_eq!(inc.merged.n_tasks(), merged.merged.n_tasks());
        assert_eq!(inc.merged.to_trace(), merged.merged.to_trace());
        inc.merged.validate().unwrap();
    }

    #[test]
    fn empty_merge_is_well_formed() {
        let batch = BatchGraph::merge(Vec::new());
        assert_eq!(batch.n_graphs(), 0);
        assert_eq!(batch.merged.n_tasks(), 0);
        assert_eq!(batch.node_offset, vec![0]);
    }

    #[test]
    fn single_graph_batch_is_identity() {
        let a = lowered(Topology::Nws, 300, 48, 6);
        let batch = BatchGraph::merge(vec![a.clone()]);
        assert_eq!(batch.merged.n_tasks(), a.n_tasks());
        assert!(batch.owner.iter().all(|&o| o == 0));
        assert_eq!(batch.merged.to_trace(), a.to_trace());
    }
}
