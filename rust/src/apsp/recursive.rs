//! Recursive partitioned APSP — the paper's Algorithm 2, executed over a
//! [`plan::ApspPlan`] with a pluggable [`backend::TileBackend`].
//!
//! The plan is first lowered to the tile-task DAG
//! ([`super::taskgraph::lower`]); the [`trace::Trace`] every solution
//! carries is the deterministic topological lowering of that graph, so
//! it is identical across:
//!
//! * **functional barrier** (`solve` with `backend = Some(..)`) — the
//!   legacy step-barrier walk in this module: every FW pass and MP merge
//!   actually runs, level by level.
//! * **functional dag** ([`super::scheduler::solve_dag`]) — the
//!   work-stealing executor that runs ready tasks concurrently; results
//!   are bit-identical to the barrier walk.
//! * **estimate** (`backend = None`) — no numerics at all; only the
//!   trace, which is what lets the simulator cost OGBN-Products-scale
//!   runs without materializing any O(n^2) state.

use super::backend::TileBackend;
use super::plan::{ApspPlan, PlanLevel};
use super::semiring::SemiringId;
use super::taskgraph;
use super::trace::Trace;
use crate::graph::csr::CsrGraph;
use crate::graph::dense::DistMatrix;
use crate::util::arena;
use crate::util::threads;
use std::sync::Arc;

/// Solution of one level's graph.
#[derive(Debug, Clone)]
pub enum LevelSolution {
    /// Full dense APSP matrix (terminal dense solve). Refcounted so the
    /// batch scheduler can serve one materialization to every store hit
    /// of the same fingerprint without cloning `n*n` floats per hit.
    Direct(Arc<DistMatrix>),
    /// Partitioned solution: exact per-component matrices (post
    /// injection) plus the exact boundary-boundary matrix dB.
    Partitioned {
        level: usize,
        comp_dist: Vec<DistMatrix>,
        db: DistMatrix,
    },
}

/// Result of a recursive APSP run.
pub struct ApspSolution<'p> {
    pub plan: &'p ApspPlan,
    pub trace: Trace,
    /// `None` in estimate mode.
    pub(crate) top: Option<LevelSolution>,
    /// level-0 vertex -> (component, local index).
    pub(crate) vert_loc: Vec<(u32, u32)>,
    /// Semiring the numerics were computed in (MinPlus in estimate mode,
    /// where no numerics exist). Cross-component queries merge with its
    /// ⊕/⊗ instead of hard-coded min/+.
    pub(crate) sr: SemiringId,
}

impl<'p> ApspSolution<'p> {
    /// Exact distance u -> v (functional mode only).
    pub fn query(&self, u: usize, v: usize) -> f32 {
        let top = self
            .top
            .as_ref()
            .expect("query requires functional mode (backend = Some)");
        match top {
            LevelSolution::Direct(d) => d.get(u, v),
            LevelSolution::Partitioned { comp_dist, db, .. } => {
                let (c1, m) = self.vert_loc[u];
                let (c2, n) = self.vert_loc[v];
                if c1 == c2 {
                    return comp_dist[c1 as usize].get(m as usize, n as usize);
                }
                let lvl = &self.plan.levels[0];
                let b1 = lvl.cs.components[c1 as usize].n_boundary;
                let b2 = lvl.cs.components[c2 as usize].n_boundary;
                let gs1 = lvl.group_start[c1 as usize];
                let gs2 = lvl.group_start[c2 as usize];
                let d1 = &comp_dist[c1 as usize];
                let d2 = &comp_dist[c2 as usize];
                let sr = self.sr;
                let mut best = sr.zero();
                for i in 0..b1 {
                    let dmi = d1.get(m as usize, i);
                    if sr.is_absorbing(dmi) {
                        continue;
                    }
                    for j in 0..b2 {
                        let through = sr.extend(dmi, db.get(gs1 + i, gs2 + j));
                        let cand = sr.extend(through, d2.get(j, n as usize));
                        best = sr.combine(best, cand);
                    }
                }
                best
            }
        }
    }

    /// Materialize the full n x n matrix (functional mode, small n).
    pub fn materialize_full(&self, backend: &dyn TileBackend) -> DistMatrix {
        let top = self.top.as_ref().expect("functional mode required");
        materialize(top, self.plan, 0, backend)
    }

    /// Whether numerics were computed.
    pub fn is_functional(&self) -> bool {
        self.top.is_some()
    }

    /// Access the level-0 solution (tests).
    pub fn top(&self) -> Option<&LevelSolution> {
        self.top.as_ref()
    }
}

/// Options for a solve run.
#[derive(Debug, Clone, Copy)]
pub struct SolveOptions {
    /// Refuse functional runs whose projected peak matrix footprint
    /// exceeds this many bytes (guards against accidental OGBN-sized
    /// functional runs). Estimate mode ignores it.
    pub memory_limit_bytes: u64,
}

impl Default for SolveOptions {
    fn default() -> Self {
        Self {
            memory_limit_bytes: 12 << 30,
        }
    }
}

/// Run recursive partitioned APSP with the legacy step-barrier schedule.
///
/// `backend = Some(engine)` → functional; `None` → estimate (trace only).
/// For dependency-aware concurrent execution of the same work, see
/// [`super::scheduler::solve_dag`] (bit-identical results).
pub fn solve<'p>(
    g: &CsrGraph,
    plan: &'p ApspPlan,
    backend: Option<&dyn TileBackend>,
    opts: SolveOptions,
) -> ApspSolution<'p> {
    if backend.is_some() {
        check_memory_guard(plan, g, &opts);
    }
    let trace = taskgraph::lower(plan).to_trace();
    match backend {
        None => estimate_solution(g, plan, trace),
        Some(be) => {
            let mut walk = Walk {
                g,
                plan,
                backend: be,
                d_intra: vec![Vec::new(); plan.depth()],
            };
            let top = walk.solve_level(0);
            ApspSolution {
                plan,
                trace,
                top: Some(top),
                vert_loc: vert_locations(plan, g),
                sr: be.semiring(),
            }
        }
    }
}

/// Estimate-mode solution (trace only, no numerics) from an existing
/// trace lowering — lets the coordinator reuse one `taskgraph::lower`
/// for the executor, the simulator, and the solution.
pub fn estimate_solution<'p>(g: &CsrGraph, plan: &'p ApspPlan, trace: Trace) -> ApspSolution<'p> {
    ApspSolution {
        plan,
        trace,
        top: None,
        vert_loc: vert_locations(plan, g),
        sr: SemiringId::MinPlus,
    }
}

/// Enforce the functional-mode memory guard (shared by both schedulers).
pub(crate) fn check_memory_guard(plan: &ApspPlan, g: &CsrGraph, opts: &SolveOptions) {
    let need = projected_bytes(plan, g);
    assert!(
        need <= opts.memory_limit_bytes,
        "functional solve needs ~{need} bytes of matrices \
         (> limit {}); use estimate mode",
        opts.memory_limit_bytes
    );
}

/// level-0 vertex -> (component, local index) map for queries.
pub(crate) fn vert_locations(plan: &ApspPlan, g: &CsrGraph) -> Vec<(u32, u32)> {
    if plan.depth() == 0 {
        return Vec::new();
    }
    let lvl = &plan.levels[0];
    let mut loc = vec![(0u32, 0u32); g.n()];
    for (ci, c) in lvl.cs.components.iter().enumerate() {
        for (idx, &v) in c.verts.iter().enumerate() {
            loc[v as usize] = (ci as u32, idx as u32);
        }
    }
    loc
}

/// Rough peak matrix footprint for the functional-mode guard (the
/// batch executor sums it across all co-resident graphs).
pub(crate) fn projected_bytes(plan: &ApspPlan, g: &CsrGraph) -> u64 {
    let mut total = 0u64;
    for lvl in &plan.levels {
        let comp: u64 = lvl
            .cs
            .components
            .iter()
            .map(|c| (c.n() * c.n() * 4) as u64)
            .sum();
        let nb = lvl.n_boundary() as u64;
        total += comp + nb * nb * 4;
    }
    if plan.depth() == 0 {
        total += (g.n() * g.n() * 4) as u64;
    }
    total + (plan.final_n * plan.final_n * 4) as u64
}

/// The step-barrier functional walk (numerics only; the trace comes from
/// the task graph).
struct Walk<'a, 'p> {
    g: &'a CsrGraph,
    plan: &'p ApspPlan,
    backend: &'a dyn TileBackend,
    /// Pre-injection intra matrices per level (needed to build the next
    /// level's dense blocks).
    d_intra: Vec<Vec<DistMatrix>>,
}

impl<'a, 'p> Walk<'a, 'p> {
    /// Solve the graph at `level` (level == depth → terminal direct solve).
    fn solve_level(&mut self, level: usize) -> LevelSolution {
        let depth = self.plan.depth();
        if level == depth {
            return self.solve_terminal(level);
        }
        let nb = self.plan.levels[level].n_boundary();

        // ---- Step 1: load + local FW per component
        let mut blocks = self.fill_level_blocks(level);
        self.fw_batch(blocks.iter_mut().collect());
        self.d_intra[level] = blocks;

        // ---- Step 2: recursive boundary solve
        if nb == 0 {
            // no cross edges at all: components are mutually unreachable
            let comp_dist = std::mem::take(&mut self.d_intra[level]);
            let sr = self.backend.semiring();
            return LevelSolution::Partitioned {
                level,
                comp_dist,
                db: DistMatrix::new_full(0, sr.zero()),
            };
        }
        let sub = self.solve_level(level + 1);
        // dB = full APSP matrix of the boundary graph
        let db = materialize(&sub, self.plan, level + 1, self.backend);

        // ---- Step 3: inject dB + rerun FW on boundary components (the
        // same set the trace's RerunFw ops name)
        let mut comp_dist = std::mem::take(&mut self.d_intra[level]);
        let lvl = &self.plan.levels[level];
        let sr = self.backend.semiring();
        for (ci, c) in lvl.cs.components.iter().enumerate() {
            let b = c.n_boundary;
            if b == 0 {
                continue;
            }
            let gs = lvl.group_start[ci];
            let dc = &mut comp_dist[ci];
            for i in 0..b {
                for j in 0..b {
                    dc.relax_sr(i, j, db.get(gs + i, gs + j), sr);
                }
            }
        }
        let rerun: Vec<&mut DistMatrix> = comp_dist
            .iter_mut()
            .zip(&lvl.cs.components)
            .filter(|(_, c)| c.n_boundary > 0 && c.n() > 1)
            .map(|(d, _)| d)
            .collect();
        self.fw_batch(rerun);

        LevelSolution::Partitioned {
            level,
            comp_dist,
            db,
        }
    }

    /// Terminal dense solve of the deepest boundary graph.
    fn solve_terminal(&mut self, level: usize) -> LevelSolution {
        let n = self.plan.final_n;
        if n == 0 {
            let sr = self.backend.semiring();
            return LevelSolution::Direct(Arc::new(DistMatrix::new_full(0, sr.zero())));
        }
        let mut d = self.fill_terminal_dense(level);
        // the terminal boundary graph can exceed one tile (random
        // topologies); compose blocked FW from tile-sized calls,
        // like the PCM die does
        super::backend::fw_any(self.backend, &mut d);
        LevelSolution::Direct(Arc::new(d))
    }

    /// Dense blocks for all components of `level`.
    fn fill_level_blocks(&self, level: usize) -> Vec<DistMatrix> {
        let lvl = &self.plan.levels[level];
        let k = lvl.cs.components.len();
        let sr = self.backend.semiring();
        if level == 0 {
            threads::par_map(k, |ci| {
                let c = &lvl.cs.components[ci];
                fill_block_from_graph(self.g, &c.verts, &lvl.cs.comp_of, ci as u32, sr)
            })
        } else {
            let prev = &self.plan.levels[level - 1];
            let d_prev = &self.d_intra[level - 1];
            threads::par_map(k, |ci| {
                let c = &lvl.cs.components[ci];
                fill_block_from_boundary(
                    &prev.next_cross,
                    prev,
                    |gi| &d_prev[gi],
                    &c.verts,
                    &lvl.cs.comp_of,
                    ci as u32,
                    sr,
                )
            })
        }
    }

    /// Dense matrix for the terminal graph.
    fn fill_terminal_dense(&self, level: usize) -> DistMatrix {
        let n = self.plan.final_n;
        let all: Vec<u32> = (0..n as u32).collect();
        let sr = self.backend.semiring();
        if level == 0 {
            // whole original graph in one tile
            let comp_of = vec![0u32; self.g.n()];
            fill_block_from_graph(self.g, &all, &comp_of, 0, sr)
        } else {
            let prev = &self.plan.levels[level - 1];
            let d_prev = &self.d_intra[level - 1];
            let comp_of = vec![0u32; n];
            fill_block_from_boundary(
                &prev.next_cross,
                prev,
                |gi| &d_prev[gi],
                &all,
                &comp_of,
                0,
                sr,
            )
        }
    }

    /// Run FW on many blocks: parallel across blocks with the serial
    /// kernel when there are enough blocks, else the backend's own
    /// (internally parallel) FW.
    fn fw_batch(&self, blocks: Vec<&mut DistMatrix>) {
        run_fw_batch(self.backend, blocks)
    }
}

/// Batch-FW kernel selection shared by both schedulers so their results
/// stay bit-identical: >= 2 native blocks run the serial row-wise kernel
/// in parallel across blocks; otherwise each block gets the backend's
/// own (internally parallel, block-limited) FW.
pub(crate) fn batch_uses_serial_kernel(backend: &dyn TileBackend, batch_len: usize) -> bool {
    batch_len >= 2 && backend.name() == "native"
}

pub(crate) fn run_fw_batch(backend: &dyn TileBackend, blocks: Vec<&mut DistMatrix>) {
    if batch_uses_serial_kernel(backend, blocks.len()) {
        let sr = backend.semiring();
        let nblocks = blocks.len();
        let items = std::sync::Mutex::new(blocks);
        threads::par_for(nblocks, |_| {
            let item = items.lock().unwrap().pop();
            if let Some(b) = item {
                super::floyd_warshall::fw_rowwise_dyn(b, sr);
            }
        });
    } else {
        for b in blocks {
            super::backend::fw_any(backend, b);
        }
    }
}

/// Fill a dense block for a level-0 component from the weighted graph.
/// Edge weights pass through `sr.from_weight`, the canvas uses the
/// semiring identities (bit-identical to the historical diag-0/INF fill
/// for MinPlus).
pub(crate) fn fill_block_from_graph(
    g: &CsrGraph,
    verts: &[u32],
    comp_of: &[u32],
    ci: u32,
    sr: SemiringId,
) -> DistMatrix {
    let n = verts.len();
    let mut pos = std::collections::HashMap::with_capacity(n);
    for (idx, &v) in verts.iter().enumerate() {
        pos.insert(v, idx as u32);
    }
    let mut d = DistMatrix::new_ident_sr_pooled(n, sr);
    for (i, &v) in verts.iter().enumerate() {
        for (u, w) in g.neighbors(v as usize) {
            if comp_of[u] == ci {
                if let Some(&j) = pos.get(&(u as u32)) {
                    d.relax_sr(i, j as usize, sr.from_weight(w), sr);
                }
            }
        }
    }
    d
}

/// Fill a dense block for a level-l (l >= 1) component: vertices are
/// boundary ids of level l-1; adjacency = virtual d_intra edges within
/// the same level-(l-1) component plus inherited cross edges. `d_prev`
/// resolves a level-(l-1) component index to its (pre-injection) intra
/// matrix — a closure so the DAG scheduler can serve blocks from its
/// slot table.
pub(crate) fn fill_block_from_boundary<'m>(
    cross: &CsrGraph,
    prev: &PlanLevel,
    d_prev: impl Fn(usize) -> &'m DistMatrix,
    verts: &[u32],
    comp_of: &[u32],
    ci: u32,
    sr: SemiringId,
) -> DistMatrix {
    let n = verts.len();
    let mut pos = std::collections::HashMap::with_capacity(n);
    for (idx, &v) in verts.iter().enumerate() {
        pos.insert(v, idx as u32);
    }
    let mut d = DistMatrix::new_ident_sr_pooled(n, sr);
    // cross edges within this component (raw graph weights: map them)
    for (i, &v) in verts.iter().enumerate() {
        for (u, w) in cross.neighbors(v as usize) {
            if comp_of[u] == ci {
                if let Some(&j) = pos.get(&(u as u32)) {
                    d.relax_sr(i, j as usize, sr.from_weight(w), sr);
                }
            }
        }
    }
    // virtual d_intra edges: whole groups (prev components' boundary
    // ranges) lie inside this component by construction
    let group_of = |bid: usize| -> usize {
        // binary search the group_start prefix array
        match prev.group_start.binary_search(&bid) {
            Ok(g) => {
                // bid is exactly a group start; skip empty groups
                let mut g = g;
                while g + 1 < prev.group_start.len() && prev.group_start[g + 1] == bid {
                    g += 1;
                }
                g
            }
            Err(g) => g - 1,
        }
    };
    let mut seen_groups = std::collections::HashSet::new();
    for &v in verts {
        let g = group_of(v as usize);
        if !seen_groups.insert(g) {
            continue;
        }
        let gs = prev.group_start[g];
        let b = prev.group_start[g + 1] - gs;
        let dg = d_prev(g);
        for bi in 0..b {
            let i = pos[&((gs + bi) as u32)] as usize;
            for bj in 0..b {
                if bi == bj {
                    continue;
                }
                let j = pos[&((gs + bj) as u32)] as usize;
                // virtual edges are already semiring values: no mapping
                d.relax_sr(i, j, dg.get(bi, bj), sr);
            }
        }
    }
    d
}

/// Materialize the full matrix of a level solution (Algorithm step 4:
/// intra entries from the component matrices, cross entries via
/// two-stage MP merges).
pub fn materialize(
    sol: &LevelSolution,
    plan: &ApspPlan,
    level: usize,
    backend: &dyn TileBackend,
) -> DistMatrix {
    match sol {
        LevelSolution::Direct(d) => d.as_ref().clone(),
        LevelSolution::Partitioned { comp_dist, db, .. } => {
            materialize_partitioned(plan, level, |ci| &comp_dist[ci], db, backend)
        }
    }
}

/// [`materialize`] for a partitioned level, with the component matrices
/// resolved through a closure (shared with the DAG scheduler).
pub(crate) fn materialize_partitioned<'m>(
    plan: &ApspPlan,
    level: usize,
    comp_dist: impl Fn(usize) -> &'m DistMatrix,
    db: &DistMatrix,
    backend: &dyn TileBackend,
) -> DistMatrix {
    let lvl = &plan.levels[level];
    let n = lvl.n;
    let sr = backend.semiring();
    let zero = sr.zero();
    let mut out = DistMatrix::new_zero_sr_pooled(n, sr);
    // intra entries
    for (ci, c) in lvl.cs.components.iter().enumerate() {
        let dc = comp_dist(ci);
        for (i, &u) in c.verts.iter().enumerate() {
            let urow = out.row_mut(u as usize);
            for (j, &v) in c.verts.iter().enumerate() {
                let val = dc.get(i, j);
                urow[v as usize] = sr.combine(urow[v as usize], val);
            }
        }
    }
    // cross entries per ordered component pair
    let k = lvl.cs.components.len();
    for c1 in 0..k {
        let comp1 = &lvl.cs.components[c1];
        let b1 = comp1.n_boundary;
        if b1 == 0 {
            continue;
        }
        let n1 = comp1.n();
        let gs1 = lvl.group_start[c1];
        // A = D_c1[:, 0..b1] (m x b1) — all merge temporaries below are
        // arena-leased and recycled, so a steady-state materialization
        // loop performs no heap allocation
        let d1 = comp_dist(c1);
        let mut a = arena::lease_filled(n1 * b1, zero);
        for i in 0..n1 {
            a[i * b1..(i + 1) * b1].copy_from_slice(&d1.row(i)[..b1]);
        }
        for c2 in 0..k {
            if c1 == c2 {
                continue;
            }
            let comp2 = &lvl.cs.components[c2];
            let b2 = comp2.n_boundary;
            if b2 == 0 {
                continue;
            }
            let n2 = comp2.n();
            let gs2 = lvl.group_start[c2];
            // DB block (b1 x b2)
            let mut dbb = arena::lease_filled(b1 * b2, zero);
            for i in 0..b1 {
                for j in 0..b2 {
                    dbb[i * b2 + j] = db.get(gs1 + i, gs2 + j);
                }
            }
            // B = D_c2[0..b2, :] (b2 x n2) — boundary rows
            let d2 = comp_dist(c2);
            let mut bmat = arena::lease_filled(b2 * n2, zero);
            for j in 0..b2 {
                bmat[j * n2..(j + 1) * n2].copy_from_slice(d2.row(j));
            }
            // two-stage merge
            let mut stage1 = arena::lease_filled(n1 * b2, zero);
            backend.minplus_into(&mut stage1, &a, &dbb, n1, b1, b2);
            let mut strip = arena::lease_filled(n1 * n2, zero);
            backend.minplus_into(&mut strip, &stage1, &bmat, n1, b2, n2);
            // scatter into out
            for (i, &u) in comp1.verts.iter().enumerate() {
                let urow = out.row_mut(u as usize);
                for (j, &v) in comp2.verts.iter().enumerate() {
                    let val = strip[i * n2 + j];
                    urow[v as usize] = sr.combine(urow[v as usize], val);
                }
            }
            for buf in [dbb, bmat, stage1, strip] {
                arena::recycle(buf);
            }
        }
        arena::recycle(a);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::backend::NativeBackend;
    use crate::apsp::plan::{build_plan, PlanOptions};
    use crate::apsp::{dijkstra, floyd_warshall};
    use crate::graph::generators::{self, Topology, Weights};

    fn solve_and_check(g: &CsrGraph, tile: usize, seed: u64) {
        let plan = build_plan(
            g,
            PlanOptions {
                tile_limit: tile,
                max_depth: usize::MAX,
                seed,
            },
        );
        let be = NativeBackend;
        let sol = solve(g, &plan, Some(&be), SolveOptions::default());
        let oracle = dijkstra::apsp(g);
        // full materialization matches the oracle
        let full = sol.materialize_full(&be);
        let diff = full.max_diff(&oracle);
        assert!(
            diff < 1e-3,
            "materialized diff {diff} (tile {tile}, seed {seed}, depth {})",
            plan.depth()
        );
        // spot queries match too
        let mut rng = crate::util::rng::Rng::new(seed ^ 0xABCD);
        for _ in 0..200 {
            let u = rng.gen_range(g.n());
            let v = rng.gen_range(g.n());
            let q = sol.query(u, v);
            let o = oracle.get(u, v);
            assert!(
                (q - o).abs() < 1e-3 || (q.is_infinite() && o.is_infinite()),
                "query({u},{v}) = {q}, oracle {o}"
            );
        }
    }

    #[test]
    fn exact_on_small_nws() {
        let g = generators::newman_watts_strogatz(150, 3, 0.15, Weights::Uniform(1.0, 5.0), 1);
        solve_and_check(&g, 32, 1);
    }

    #[test]
    fn exact_on_er() {
        let g = generators::erdos_renyi(120, 500, Weights::Uniform(0.5, 3.0), 2);
        solve_and_check(&g, 24, 2);
    }

    #[test]
    fn exact_on_clustered() {
        let g = generators::ogbn_proxy(300, 12.0, Weights::Uniform(1.0, 2.0), 3);
        solve_and_check(&g, 48, 3);
    }

    #[test]
    fn exact_on_grid() {
        let g = generators::grid2d(14, 14, Weights::Uniform(1.0, 4.0), 4);
        solve_and_check(&g, 40, 4);
    }

    #[test]
    fn exact_on_disconnected() {
        let g = CsrGraph::from_undirected_edges(
            50,
            &(0..24u32)
                .map(|i| (i, i + 1, 1.0f32))
                .chain((26..49u32).map(|i| (i, i + 1, 2.0)))
                .collect::<Vec<_>>(),
        );
        solve_and_check(&g, 16, 5);
    }

    #[test]
    fn exact_with_deep_recursion() {
        // A chain of cliques has tiny per-component boundary sets (the
        // bridge endpoints), so the recursion gets several levels even
        // with a small tile: level-0 components are cliques, level-1
        // packs many 2-vertex boundary groups per tile.
        let mut edges: Vec<(u32, u32, f32)> = Vec::new();
        let cliques = 40u32;
        let size = 12u32;
        let mut rng = crate::util::rng::Rng::new(6);
        for c in 0..cliques {
            let base = c * size;
            for i in 0..size {
                for j in (i + 1)..size {
                    edges.push((base + i, base + j, rng.gen_f32_range(1.0, 5.0)));
                }
            }
            if c + 1 < cliques {
                edges.push((base + size - 1, base + size, rng.gen_f32_range(1.0, 5.0)));
            }
        }
        let g = CsrGraph::from_undirected_edges((cliques * size) as usize, &edges);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 16,
                max_depth: usize::MAX,
                seed: 6,
            },
        );
        assert!(plan.depth() >= 2, "want depth >= 2, got {}", plan.depth());
        solve_and_check(&g, 16, 6);
    }

    #[test]
    fn direct_when_graph_fits() {
        let g = generators::complete(20, Weights::Uniform(1.0, 2.0), 7);
        let plan = build_plan(&g, PlanOptions::default());
        let be = NativeBackend;
        let sol = solve(&g, &plan, Some(&be), SolveOptions::default());
        let mut fw = g.to_dense();
        floyd_warshall::fw_rowwise(&mut fw);
        assert_eq!(sol.query(3, 17), fw.get(3, 17));
        assert_eq!(sol.materialize_full(&be).max_diff(&fw), 0.0);
    }

    #[test]
    fn estimate_trace_equals_functional_trace() {
        for topo in [Topology::Nws, Topology::Er, Topology::OgbnProxy] {
            let g = generators::generate(topo, 400, 10.0, Weights::Uniform(1.0, 3.0), 8);
            let plan = build_plan(
                &g,
                PlanOptions {
                    tile_limit: 48,
                    max_depth: usize::MAX,
                    seed: 8,
                },
            );
            let be = NativeBackend;
            let func = solve(&g, &plan, Some(&be), SolveOptions::default());
            let est = solve(&g, &plan, None, SolveOptions::default());
            assert_eq!(
                func.trace, est.trace,
                "traces must be identical ({})",
                topo.name()
            );
            assert!(!est.is_functional());
        }
    }

    #[test]
    fn trace_has_expected_phases() {
        let g = generators::newman_watts_strogatz(200, 3, 0.1, Weights::Unit, 9);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 32,
                max_depth: usize::MAX,
                seed: 9,
            },
        );
        let est = solve(&g, &plan, None, SolveOptions::default());
        let counts = est.trace.phase_op_counts();
        use crate::apsp::trace::Phase::*;
        for phase in [Load, LocalFw, BoundaryBuild, Inject, RerunFw, CrossMerge, Store] {
            assert!(
                counts.contains_key(&phase),
                "missing phase {phase:?} in trace:\n{}",
                est.trace.summary()
            );
        }
        assert!(est.trace.total_madds() > 0);
    }

    #[test]
    #[should_panic(expected = "functional solve needs")]
    fn memory_guard_trips() {
        let g = generators::newman_watts_strogatz(500, 4, 0.1, Weights::Unit, 10);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 64,
                max_depth: usize::MAX,
                seed: 10,
            },
        );
        let be = NativeBackend;
        let _ = solve(
            &g,
            &plan,
            Some(&be),
            SolveOptions {
                memory_limit_bytes: 1024,
            },
        );
    }
}
