//! Repeated Dijkstra (paper §I: "super-quadratic complexity with poor
//! memory locality") — used here as the *exactness oracle* for every
//! other APSP implementation, and as the algorithm the PIM-APSP baseline
//! [16] accelerates.

use crate::graph::csr::CsrGraph;
use crate::graph::dense::DistMatrix;
use crate::util::threads;
use crate::INF;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// f32 wrapper with a total order for the heap.
#[derive(PartialEq, PartialOrd)]
struct TotalF32(f32);
impl Eq for TotalF32 {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for TotalF32 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Single-source shortest paths from `src` (binary-heap Dijkstra).
/// Requires non-negative weights (guaranteed by `CsrGraph::validate`).
pub fn sssp(g: &CsrGraph, src: usize) -> Vec<f32> {
    let n = g.n();
    let mut dist = vec![INF; n];
    let mut done = vec![false; n];
    let mut heap: BinaryHeap<Reverse<(TotalF32, u32)>> = BinaryHeap::new();
    dist[src] = 0.0;
    heap.push(Reverse((TotalF32(0.0), src as u32)));
    while let Some(Reverse((TotalF32(d), v))) = heap.pop() {
        let v = v as usize;
        if done[v] {
            continue;
        }
        done[v] = true;
        for (u, w) in g.neighbors(v) {
            let cand = d + w;
            if cand < dist[u] {
                dist[u] = cand;
                heap.push(Reverse((TotalF32(cand), u as u32)));
            }
        }
    }
    dist
}

/// Full APSP by repeated Dijkstra, parallel over sources.
pub fn apsp(g: &CsrGraph) -> DistMatrix {
    let n = g.n();
    let mut out = DistMatrix::new_inf(n);
    {
        let data = out.as_mut_slice();
        let rows = std::sync::Mutex::new(data.chunks_mut(n).enumerate().collect::<Vec<_>>());
        threads::par_for(n, |_| {
            let item = rows.lock().unwrap().pop();
            if let Some((src, row)) = item {
                row.copy_from_slice(&sssp(g, src));
            }
        });
    }
    out
}

/// Distances from a sampled set of sources: `(sources, rows)` where
/// `rows[s]` is the distance vector from `sources[s]`. The scalable
/// validation path for graphs whose full n^2 matrix does not fit.
pub fn sampled_rows(g: &CsrGraph, sources: &[usize]) -> Vec<Vec<f32>> {
    threads::par_map(sources.len(), |s| sssp(g, sources[s]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::floyd_warshall;
    use crate::graph::generators::{self, Weights};

    #[test]
    fn line_graph_distances() {
        let g = CsrGraph::from_undirected_edges(
            4,
            &[(0, 1, 1.0), (1, 2, 2.0), (2, 3, 4.0)],
        );
        let d = sssp(&g, 0);
        assert_eq!(d, vec![0.0, 1.0, 3.0, 7.0]);
    }

    #[test]
    fn unreachable_is_inf() {
        let g = CsrGraph::from_undirected_edges(3, &[(0, 1, 1.0)]);
        let d = sssp(&g, 0);
        assert_eq!(d[1], 1.0);
        assert!(d[2].is_infinite());
    }

    #[test]
    fn prefers_multi_hop_when_shorter() {
        let g = CsrGraph::from_edges(3, &[(0, 2, 10.0), (0, 1, 3.0), (1, 2, 3.0)]);
        assert_eq!(sssp(&g, 0)[2], 6.0);
    }

    #[test]
    fn apsp_matches_fw() {
        for seed in 0..4 {
            let g = generators::random_connected(70, 150, Weights::Uniform(0.5, 5.0), seed);
            let dij = apsp(&g);
            let mut fw = g.to_dense();
            floyd_warshall::fw_parallel(&mut fw);
            let diff = dij.max_diff(&fw);
            assert!(diff < 1e-4, "seed {seed}: diff {diff}");
        }
    }

    #[test]
    fn apsp_matches_fw_disconnected() {
        let g = CsrGraph::from_undirected_edges(
            6,
            &[(0, 1, 1.0), (1, 2, 1.0), (3, 4, 1.0), (4, 5, 1.0)],
        );
        let dij = apsp(&g);
        let mut fw = g.to_dense();
        floyd_warshall::fw_rowwise(&mut fw);
        assert_eq!(dij.max_diff(&fw), 0.0);
    }

    #[test]
    fn sampled_rows_match_full() {
        let g = generators::newman_watts_strogatz(120, 4, 0.1, Weights::Uniform(1.0, 3.0), 8);
        let full = apsp(&g);
        let sources = vec![0usize, 17, 63, 119];
        let rows = sampled_rows(&g, &sources);
        for (s, &src) in sources.iter().enumerate() {
            for j in 0..g.n() {
                let a = rows[s][j];
                let b = full.get(src, j);
                assert!((a - b).abs() < 1e-5 || (a.is_infinite() && b.is_infinite()));
            }
        }
    }
}
