//! Edge-delta engine: incremental APSP on dynamic graphs.
//!
//! A batch of [`EdgeDelta`]s (insert / delete / reweight) is mapped onto
//! the existing tile plan instead of forcing a cubic re-solve:
//!
//! 1. **Plan repair** ([`repair_plan`]): the partition, boundary sets,
//!    and group layout of the old plan are *reused* — only the per-level
//!    cross-edge graphs and edge counts are rebuilt against the mutated
//!    graph. This succeeds exactly when no previously-internal vertex
//!    gains a cross edge; otherwise the structure changed and the caller
//!    falls back to a full re-plan + re-solve (the `replan` path).
//! 2. **Dirty closure** ([`dirty_spec`]): a delta inside a zero-boundary
//!    component dirties only that tile. Any delta touching a boundary
//!    component or crossing components invalidates the boundary
//!    recursion — levels >= 1, the terminal solve, and every merge are
//!    downstream of a boundary edge in the recursion's dependency
//!    order, so they re-solve as a unit while clean zero-boundary tiles
//!    are served from the retained solution untouched.
//! 3. **Repair lowering** ([`super::taskgraph::lower_repair`]): the
//!    closure lowers to a sub-DAG that the scheduler splices into a live
//!    pool ([`super::scheduler::execute_delta`]), running the *same*
//!    kernels a fresh solve would — repaired tiles are bit-identical to
//!    a full solve on the same plan by construction.
//!
//! Improving batches (inserts and weight decreases, [`DeltaClass`])
//! additionally let the executor skip the inject + rerun of any
//! boundary tile whose dB diagonal block is bit-unchanged — the cheap
//! min-plus repair path that propagates improvements outward from the
//! dirty tiles only as far as they actually reach. Deletes and weight
//! increases force every boundary tile through inject + rerun (the
//! conservative re-solve of the dirty closure).

use super::plan::{ApspPlan, PlanLevel};
use super::recursive::{vert_locations, ApspSolution, LevelSolution};
use super::taskgraph::RepairSpec;
use super::trace::Trace;
use crate::graph::csr::CsrGraph;
use crate::graph::dense::DistMatrix;
use crate::util::error::Result;
use crate::{bail, ensure};
use std::collections::HashMap;
use std::sync::Arc;

/// One edge mutation. Graphs are undirected: every delta applies to
/// both directions of the edge.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EdgeDelta {
    /// Add a new edge (must not already exist).
    Insert { u: u32, v: u32, w: f32 },
    /// Remove an existing edge.
    Delete { u: u32, v: u32 },
    /// Change the weight of an existing edge.
    Reweight { u: u32, v: u32, w: f32 },
}

impl EdgeDelta {
    pub fn endpoints(&self) -> (u32, u32) {
        match *self {
            EdgeDelta::Insert { u, v, .. }
            | EdgeDelta::Delete { u, v }
            | EdgeDelta::Reweight { u, v, .. } => (u, v),
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            EdgeDelta::Insert { .. } => "insert",
            EdgeDelta::Delete { .. } => "delete",
            EdgeDelta::Reweight { .. } => "reweight",
        }
    }
}

/// How a validated batch interacts with shortest paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeltaClass {
    /// Only inserts and weight decreases: distances can only improve,
    /// so unchanged dB blocks prove their tiles need no rerun.
    Improve,
    /// Contains a delete or a weight increase: distances may grow, so
    /// every boundary tile re-solves against the refreshed dB.
    Resolve,
}

impl DeltaClass {
    pub fn name(&self) -> &'static str {
        match self {
            DeltaClass::Improve => "improve",
            DeltaClass::Resolve => "resolve",
        }
    }
}

/// Parse a delta script: one delta per line (`insert u v w`,
/// `delete u v`, `reweight u v w`), `#` comments, blank lines separate
/// batches. Returns the non-empty batches in order.
pub fn parse_script(text: &str) -> Result<Vec<Vec<EdgeDelta>>> {
    let mut batches: Vec<Vec<EdgeDelta>> = Vec::new();
    let mut cur: Vec<EdgeDelta> = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            if !cur.is_empty() {
                batches.push(std::mem::take(&mut cur));
            }
            continue;
        }
        let mut it = line.split_whitespace();
        let op = it.next().unwrap_or("");
        let mut field = |name: &str| -> Result<&str> {
            it.next()
                .ok_or_else(|| crate::err!("line {}: {op} missing {name}", ln + 1))
        };
        let parse_u32 = |s: &str, name: &str| -> Result<u32> {
            s.parse()
                .map_err(|_| crate::err!("line {}: bad {name} {s:?}", ln + 1))
        };
        let parse_w = |s: &str| -> Result<f32> {
            s.parse()
                .map_err(|_| crate::err!("line {}: bad weight {s:?}", ln + 1))
        };
        let delta = match op {
            "insert" | "reweight" => {
                let u = parse_u32(field("u")?, "u")?;
                let v = parse_u32(field("v")?, "v")?;
                let w = parse_w(field("w")?)?;
                if op == "insert" {
                    EdgeDelta::Insert { u, v, w }
                } else {
                    EdgeDelta::Reweight { u, v, w }
                }
            }
            "delete" => {
                let u = parse_u32(field("u")?, "u")?;
                let v = parse_u32(field("v")?, "v")?;
                EdgeDelta::Delete { u, v }
            }
            other => bail!("line {}: unknown delta op {other:?}", ln + 1),
        };
        ensure!(
            it.next().is_none(),
            "line {}: trailing tokens after {op}",
            ln + 1
        );
        cur.push(delta);
    }
    if !cur.is_empty() {
        batches.push(cur);
    }
    ensure!(!batches.is_empty(), "delta script contains no deltas");
    Ok(batches)
}

/// Validate a batch against the graph it will be applied to: endpoints
/// in range and distinct, weights finite and non-negative, deletes and
/// reweights name an existing edge, inserts a missing one. Clean
/// errors, no panics — the executor runs this before touching any
/// state.
pub fn validate_deltas(g: &CsrGraph, deltas: &[EdgeDelta]) -> Result<()> {
    ensure!(!deltas.is_empty(), "empty delta batch");
    for (i, d) in deltas.iter().enumerate() {
        let (u, v) = d.endpoints();
        let kind = d.kind();
        ensure!(
            (u as usize) < g.n() && (v as usize) < g.n(),
            "delta {i} ({kind} {u} {v}): endpoint out of range (graph has {} vertices)",
            g.n()
        );
        ensure!(u != v, "delta {i} ({kind} {u} {v}): self-loop");
        match *d {
            EdgeDelta::Insert { w, .. } | EdgeDelta::Reweight { w, .. } => {
                ensure!(
                    w.is_finite() && w >= 0.0,
                    "delta {i} ({kind} {u} {v}): weight {w} must be finite and non-negative"
                );
            }
            EdgeDelta::Delete { .. } => {}
        }
        let exists = g.edge_weight(u as usize, v as usize).is_some();
        match d {
            EdgeDelta::Insert { .. } => ensure!(
                !exists,
                "delta {i} (insert {u} {v}): edge already exists — use reweight"
            ),
            EdgeDelta::Delete { .. } | EdgeDelta::Reweight { .. } => ensure!(
                exists,
                "delta {i} ({kind} {u} {v}): edge does not exist"
            ),
        }
    }
    Ok(())
}

/// Classify a validated batch (see [`DeltaClass`]). Reweights compare
/// against the current weight; equal weights count as improving (a
/// no-op cannot grow a distance).
pub fn classify_deltas(g: &CsrGraph, deltas: &[EdgeDelta]) -> DeltaClass {
    for d in deltas {
        match *d {
            EdgeDelta::Insert { .. } => {}
            EdgeDelta::Delete { .. } => return DeltaClass::Resolve,
            EdgeDelta::Reweight { u, v, w } => {
                let old = g
                    .edge_weight(u as usize, v as usize)
                    .expect("validated reweight targets an existing edge");
                if w > old {
                    return DeltaClass::Resolve;
                }
            }
        }
    }
    DeltaClass::Improve
}

/// Apply a validated batch, returning the mutated graph in canonical
/// CSR form (sorted adjacency, symmetric) so its fingerprint is stable.
pub fn apply_deltas(g: &CsrGraph, deltas: &[EdgeDelta]) -> CsrGraph {
    let mut edges: HashMap<(u32, u32), f32> = g.edges().map(|(u, v, w)| ((u, v), w)).collect();
    for d in deltas {
        match *d {
            EdgeDelta::Insert { u, v, w } | EdgeDelta::Reweight { u, v, w } => {
                edges.insert((u, v), w);
                edges.insert((v, u), w);
            }
            EdgeDelta::Delete { u, v } => {
                edges.remove(&(u, v));
                edges.remove(&(v, u));
            }
        }
    }
    let list: Vec<(u32, u32, f32)> = edges.into_iter().map(|((u, v), w)| (u, v, w)).collect();
    CsrGraph::from_edges(g.n(), &list)
}

/// Reuse `old`'s partition structure against the mutated graph: every
/// level keeps its component set, boundary flags, and group layout, and
/// only the cross-edge graphs / edge counts are rebuilt from `g_new`.
///
/// Returns `None` when the deltas changed the *structure* — some new
/// cross-component edge has an endpoint that was internal under the old
/// plan (`boundary_id == u32::MAX`), so the boundary sets no longer
/// cover the cut and the caller must re-plan from scratch. The reverse
/// direction is safe: a vertex whose last cross edge was deleted stays
/// flagged boundary (a conservative superset never breaks correctness,
/// it only keeps a slightly larger boundary graph).
pub fn repair_plan(old: &ApspPlan, g_new: &CsrGraph) -> Option<ApspPlan> {
    if old.depth() == 0 {
        return Some(ApspPlan {
            levels: Vec::new(),
            final_n: g_new.n(),
            final_nnz: g_new.m() as u64,
            tile_limit: old.tile_limit,
        });
    }
    let mut levels: Vec<PlanLevel> = Vec::with_capacity(old.depth());
    let mut cur: Option<CsrGraph> = None; // level l's input graph (None = g_new)
    for lvl in &old.levels {
        let g = cur.as_ref().unwrap_or(g_new);
        if g.n() != lvl.n {
            return None; // vertex count changed (defensive; deltas can't)
        }
        let cs = &lvl.cs;
        let mut cross_edges: Vec<(u32, u32, f32)> = Vec::new();
        let mut comp_nnz = vec![0u64; cs.components.len()];
        for (u, v, w) in g.edges() {
            let cu = cs.comp_of[u as usize];
            let cv = cs.comp_of[v as usize];
            if cu != cv {
                let bu = cs.boundary_id[u as usize];
                let bv = cs.boundary_id[v as usize];
                if bu == u32::MAX || bv == u32::MAX {
                    return None; // an internal vertex gained a cross edge
                }
                cross_edges.push((bu, bv, w));
            } else {
                comp_nnz[cu as usize] += 1;
            }
        }
        let next_cross = CsrGraph::from_edges(lvl.n_boundary(), &cross_edges);
        cur = Some(next_cross.clone());
        levels.push(PlanLevel {
            n: lvl.n,
            cs: cs.clone(),
            next_cross,
            group_start: lvl.group_start.clone(),
            comp_nnz,
        });
    }
    let terminal = cur.expect("depth >= 1");
    Some(ApspPlan {
        final_n: old.final_n,
        final_nnz: terminal.m() as u64,
        levels,
        tile_limit: old.tile_limit,
    })
}

/// Compute the conservative dirty closure of a batch against the plan's
/// level-0 tiling: tiles containing an intra-component delta reload +
/// re-solve locally; any delta crossing components or touching a
/// boundary tile invalidates the boundary recursion, making every
/// boundary tile an inject/rerun candidate (the executor may still skip
/// ones whose dB block comes back bit-unchanged).
pub fn dirty_spec(plan: &ApspPlan, deltas: &[EdgeDelta]) -> RepairSpec {
    if plan.depth() == 0 {
        return RepairSpec {
            dirty: Vec::new(),
            rerun: Vec::new(),
            boundary_dirty: true,
        };
    }
    let lvl0 = &plan.levels[0];
    let k0 = lvl0.n_components();
    let mut dirty = vec![false; k0];
    let mut boundary_dirty = false;
    for d in deltas {
        let (u, v) = d.endpoints();
        let cu = lvl0.cs.comp_of[u as usize];
        let cv = lvl0.cs.comp_of[v as usize];
        if cu != cv {
            boundary_dirty = true;
        } else {
            dirty[cu as usize] = true;
            if lvl0.cs.components[cu as usize].n_boundary > 0 {
                boundary_dirty = true;
            }
        }
    }
    let rerun: Vec<bool> = if boundary_dirty {
        lvl0.cs.components.iter().map(|c| c.n_boundary > 0).collect()
    } else {
        vec![false; k0]
    };
    RepairSpec {
        dirty,
        rerun,
        boundary_dirty,
    }
}

/// The retained numeric state of a solved graph, shaped for repair:
/// level-0 blocks are refcounted so a repair can hand clean tiles to
/// the next generation without copying a float.
#[derive(Clone)]
pub struct DeltaState {
    /// Post-injection level-0 component matrices (the solution tiles).
    pub(crate) comp_dist: Vec<Arc<DistMatrix>>,
    /// Pre-injection level-0 matrices (snapshotted at inject time):
    /// the inputs a repair re-injects the refreshed dB into. Shares the
    /// `comp_dist` allocation for tiles that were never injected.
    pub(crate) pre_inj: Vec<Arc<DistMatrix>>,
    /// The level-0 dB (empty matrix when the plan has no boundary).
    pub(crate) db: Arc<DistMatrix>,
    /// Terminal matrix of a depth-0 (single-tile) plan.
    pub(crate) direct: Option<Arc<DistMatrix>>,
}

impl DeltaState {
    /// View the retained state as an [`ApspSolution`] for querying,
    /// validation, and store write-back. Clones the tile matrices (the
    /// solution type owns plain matrices); used on validation and
    /// reporting paths, never inside the repair hot loop.
    pub fn as_solution<'p>(
        &self,
        plan: &'p ApspPlan,
        g: &CsrGraph,
        trace: Trace,
    ) -> ApspSolution<'p> {
        let top = if let Some(direct) = &self.direct {
            LevelSolution::Direct(Arc::clone(direct))
        } else {
            LevelSolution::Partitioned {
                level: 0,
                comp_dist: self.comp_dist.iter().map(|m| m.as_ref().clone()).collect(),
                db: self.db.as_ref().clone(),
            }
        };
        ApspSolution {
            plan,
            trace,
            top: Some(top),
            vert_loc: vert_locations(plan, g),
            // the delta engine repairs shortest paths only
            sr: crate::apsp::semiring::SemiringId::MinPlus,
        }
    }

    /// Bit-compare against another state (repair vs fresh solve on the
    /// same plan). Returns the max per-tile difference — `0.0` means
    /// bit-identical everywhere (INF == INF counts as equal).
    pub fn max_diff(&self, other: &DeltaState) -> f32 {
        let mut worst = 0f32;
        match (&self.direct, &other.direct) {
            (Some(a), Some(b)) => return a.max_diff(b),
            (None, None) => {}
            _ => return f32::INFINITY,
        }
        if self.comp_dist.len() != other.comp_dist.len() {
            return f32::INFINITY;
        }
        for (a, b) in self.comp_dist.iter().zip(&other.comp_dist) {
            worst = worst.max(a.max_diff(b));
        }
        worst.max(self.db.max_diff(&other.db))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::plan::{build_plan, PlanOptions};
    use crate::graph::generators::{self, Weights};

    fn setup(n: usize, tile: usize, seed: u64) -> (CsrGraph, ApspPlan) {
        let g = generators::newman_watts_strogatz(n, 4, 0.1, Weights::Uniform(1.0, 5.0), seed);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: tile,
                max_depth: usize::MAX,
                seed,
            },
        );
        (g, plan)
    }

    #[test]
    fn parse_script_batches_and_comments() {
        let text = "# warmup\ninsert 1 2 3.5\nreweight 4 5 1.0 # inline\n\ndelete 6 7\n";
        let batches = parse_script(text).unwrap();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].len(), 2);
        assert_eq!(batches[1], vec![EdgeDelta::Delete { u: 6, v: 7 }]);
    }

    #[test]
    fn parse_script_rejects_garbage() {
        assert!(parse_script("").is_err());
        assert!(parse_script("frobnicate 1 2").is_err());
        assert!(parse_script("insert 1 2").is_err()); // missing weight
        assert!(parse_script("insert 1 2 nan?").is_err());
        assert!(parse_script("delete 1 2 3").is_err()); // trailing token
    }

    #[test]
    fn validate_rejects_bad_deltas() {
        let (g, _) = setup(100, 32, 1);
        let (u, v, _) = g.edges().next().unwrap();
        // out of range
        assert!(validate_deltas(&g, &[EdgeDelta::Insert { u: 0, v: 1000, w: 1.0 }]).is_err());
        // self loop
        assert!(validate_deltas(&g, &[EdgeDelta::Insert { u: 3, v: 3, w: 1.0 }]).is_err());
        // NaN / negative / infinite weights
        for w in [f32::NAN, -1.0, f32::INFINITY] {
            assert!(validate_deltas(&g, &[EdgeDelta::Reweight { u, v, w }]).is_err());
        }
        // insert of an existing edge, delete/reweight of a missing one
        assert!(validate_deltas(&g, &[EdgeDelta::Insert { u, v, w: 1.0 }]).is_err());
        let (mu, mv) = missing_edge(&g);
        assert!(validate_deltas(&g, &[EdgeDelta::Delete { u: mu, v: mv }]).is_err());
        assert!(validate_deltas(&g, &[EdgeDelta::Reweight { u: mu, v: mv, w: 1.0 }]).is_err());
        assert!(validate_deltas(&g, &[]).is_err());
        // and a well-formed batch passes
        assert!(validate_deltas(&g, &[EdgeDelta::Reweight { u, v, w: 2.0 }]).is_ok());
    }

    fn missing_edge(g: &CsrGraph) -> (u32, u32) {
        for u in 0..g.n() {
            for v in (u + 1)..g.n() {
                if g.edge_weight(u, v).is_none() {
                    return (u as u32, v as u32);
                }
            }
        }
        panic!("graph is complete");
    }

    #[test]
    fn apply_is_symmetric_and_canonical() {
        let (g, _) = setup(80, 32, 2);
        let (mu, mv) = missing_edge(&g);
        let g2 = apply_deltas(&g, &[EdgeDelta::Insert { u: mu, v: mv, w: 2.5 }]);
        assert_eq!(g2.edge_weight(mu as usize, mv as usize), Some(2.5));
        assert_eq!(g2.edge_weight(mv as usize, mu as usize), Some(2.5));
        assert_eq!(g2.m(), g.m() + 2);
        let g3 = apply_deltas(&g2, &[EdgeDelta::Delete { u: mu, v: mv }]);
        assert_eq!(g3.m(), g.m());
        // applying the identity (rebuild from the same edges) is stable
        let same = apply_deltas(
            &g3,
            &[EdgeDelta::Reweight {
                u: g.edges().next().unwrap().0,
                v: g.edges().next().unwrap().1,
                w: g.edges().next().unwrap().2,
            }],
        );
        assert_eq!(
            crate::apsp::store::fingerprint(&same),
            crate::apsp::store::fingerprint(&g3)
        );
    }

    #[test]
    fn classify_improve_vs_resolve() {
        let (g, _) = setup(80, 32, 3);
        let (u, v, w) = g.edges().next().unwrap();
        let (mu, mv) = missing_edge(&g);
        assert_eq!(
            classify_deltas(&g, &[EdgeDelta::Insert { u: mu, v: mv, w: 1.0 }]),
            DeltaClass::Improve
        );
        assert_eq!(
            classify_deltas(&g, &[EdgeDelta::Reweight { u, v, w: w * 0.5 }]),
            DeltaClass::Improve
        );
        assert_eq!(
            classify_deltas(&g, &[EdgeDelta::Reweight { u, v, w: w * 2.0 }]),
            DeltaClass::Resolve
        );
        assert_eq!(
            classify_deltas(&g, &[EdgeDelta::Delete { u, v }]),
            DeltaClass::Resolve
        );
    }

    #[test]
    fn repair_plan_matches_fresh_plan_on_reweight() {
        // a reweight keeps the topology, so the fresh plan (partitioned
        // on unit weights) is structurally identical and the repaired
        // plan must match it level by level
        let (g, plan) = setup(400, 48, 4);
        let (u, v, w) = g.edges().next().unwrap();
        let g2 = apply_deltas(&g, &[EdgeDelta::Reweight { u, v, w: w + 1.0 }]);
        let repaired = repair_plan(&plan, &g2).expect("reweight never changes structure");
        let fresh = build_plan(
            &g2,
            PlanOptions {
                tile_limit: 48,
                max_depth: usize::MAX,
                seed: 4,
            },
        );
        assert_eq!(repaired.depth(), fresh.depth());
        assert_eq!(repaired.final_n, fresh.final_n);
        assert_eq!(repaired.final_nnz, fresh.final_nnz);
        for (a, b) in repaired.levels.iter().zip(&fresh.levels) {
            assert_eq!(a.comp_nnz, b.comp_nnz);
            assert_eq!(a.group_start, b.group_start);
            assert_eq!(a.next_cross.rowptr, b.next_cross.rowptr);
            assert_eq!(a.next_cross.col, b.next_cross.col);
            assert_eq!(a.next_cross.val, b.next_cross.val);
        }
    }

    #[test]
    fn repair_plan_detects_structural_change() {
        let (g, plan) = setup(400, 48, 5);
        let lvl0 = &plan.levels[0];
        // find an internal vertex and a vertex in another component
        let (iu, other) = 'found: {
            for (ci, c) in lvl0.cs.components.iter().enumerate() {
                if let Some(&internal) = c.internal().first() {
                    for (cj, c2) in lvl0.cs.components.iter().enumerate() {
                        if ci != cj && c2.n() > 0 {
                            break 'found (internal, c2.verts[0]);
                        }
                    }
                }
            }
            panic!("no internal vertex found");
        };
        let g2 = apply_deltas(&g, &[EdgeDelta::Insert { u: iu, v: other, w: 1.0 }]);
        assert!(
            repair_plan(&plan, &g2).is_none(),
            "internal vertex gained a cross edge: structure changed"
        );
    }

    #[test]
    fn dirty_spec_closure_rules() {
        let (g, plan) = setup(400, 48, 6);
        let lvl0 = &plan.levels[0];
        // cross-component delta: boundary dirty, no locally-dirty tile
        let (cu, cv, _) = g
            .edges()
            .find(|&(u, v, _)| lvl0.cs.comp_of[u as usize] != lvl0.cs.comp_of[v as usize])
            .expect("nws plans have cross edges");
        let spec = dirty_spec(&plan, &[EdgeDelta::Delete { u: cu, v: cv }]);
        assert!(spec.boundary_dirty);
        assert!(spec.dirty.iter().all(|d| !d));
        for (ci, c) in lvl0.cs.components.iter().enumerate() {
            assert_eq!(spec.rerun[ci], c.n_boundary > 0);
        }
        // intra-component delta in a boundary tile: that tile dirty +
        // boundary recursion dirty
        if let Some((iu, iv, _)) = g.edges().find(|&(u, v, _)| {
            let cu = lvl0.cs.comp_of[u as usize];
            cu == lvl0.cs.comp_of[v as usize] && lvl0.cs.components[cu as usize].n_boundary > 0
        }) {
            let spec = dirty_spec(&plan, &[EdgeDelta::Delete { u: iu, v: iv }]);
            assert!(spec.boundary_dirty);
            let ci = lvl0.cs.comp_of[iu as usize] as usize;
            assert!(spec.dirty[ci]);
            assert_eq!(spec.dirty.iter().filter(|d| **d).count(), 1);
        }
    }

    #[test]
    fn dirty_spec_is_monotone() {
        // a superset batch never dirties fewer tiles
        let (g, plan) = setup(400, 48, 7);
        let edges: Vec<(u32, u32, f32)> = g.edges().filter(|(u, v, _)| u < v).collect();
        let mut prev = 0usize;
        for take in [1usize, 4, 16, 64] {
            let batch: Vec<EdgeDelta> = edges
                .iter()
                .take(take)
                .map(|&(u, v, w)| EdgeDelta::Reweight { u, v, w: w * 0.9 })
                .collect();
            let spec = dirty_spec(&plan, &batch);
            let tiles = spec.dirty_tiles();
            assert!(tiles >= prev, "superset batch dirtied fewer tiles");
            prev = tiles;
        }
    }
}
