//! Sharded multi-stack execution: partition one over-large graph across
//! `S` modeled PIM stacks and run it as a single task graph with
//! explicit inter-stack transfers.
//!
//! The batch engine ([`super::batch`]) merges *independent* graphs —
//! zero cross edges by construction. Shards are the generalization:
//! level-0 components of one graph are placed whole on a stack
//! ([`shard_assignment`], balanced by tile work with the cross-shard
//! edge cut minimized via [`crate::partition::partition_kway`] over the
//! component quotient graph), while the shared boundary recursion
//! (boundary build, deeper levels, terminal solve, cross merges, sync,
//! store) runs on a designated **hub** stack. Every edge of the solo
//! task graph whose producer and consumer live on different stacks gets
//! an explicit [`TaskKind::StackXfer`] node carrying the payload bytes
//! over the capacity-1 inter-stack interconnect
//! ([`crate::sim::params::HwParams::interstack_bytes_per_s`]) — one
//! physical transfer per (producer, destination stack) for the gather
//! direction, none at all for zero-byte payloads.
//!
//! Only two kinds of data ever cross stacks — this is debug-asserted in
//! [`ShardGraph::build`]:
//!
//! * **boundary matrices** flowing *into* the hub's aggregation nodes
//!   (`BoundaryBuild`, `Sync`, `CrossMerge`: the component's b x b
//!   boundary block; `Store`: an internal-only component's full matrix
//!   bound for the hub's FeNAND);
//! * **dB injections** flowing *out of* the hub's `CrossMerge` into a
//!   component's `Inject` on its home stack.
//!
//! Two consumers mirror the batch engine's split:
//!
//! * the host executor ([`super::scheduler::execute_sharded`]) runs the
//!   sharded graph with per-stack worker pools — `StackXfer` nodes are
//!   pure ordering on the host, so results are **bit-identical** to the
//!   solo run;
//! * the simulator ([`crate::sim::engine::simulate_sharded`]) replicates
//!   the FW/MP/channel resource set per stack, serializes `StackXfer`
//!   ops on the shared interconnect channel, and attributes makespan /
//!   busy work / dynamic energy per stack by node affinity, exactly as
//!   `simulate_batch` does by owner.

use super::plan::ApspPlan;
use super::taskgraph::{lower, TaskGraph, TaskId, TaskKind, TaskNode};
use super::trace::{Op, Phase};
use crate::graph::csr::CsrGraph;
use crate::partition::partition_kway;

/// One graph's task DAG split across `num_stacks` modeled stacks.
#[derive(Debug, Clone)]
pub struct ShardGraph {
    /// The unmodified solo lowering (baselines, trace assembly).
    pub solo: TaskGraph,
    /// The solo graph with `StackXfer` nodes spliced into every
    /// cross-stack edge. `to_trace()` is only meaningful on `solo`.
    pub sharded: TaskGraph,
    /// Stack affinity of every sharded node (parallel to
    /// `sharded.nodes`; xfer nodes carry their *source* stack).
    pub affinity: Vec<u32>,
    /// Level-0 component -> stack (empty for a depth-0 direct solve).
    pub comp_stack: Vec<u32>,
    /// The stack hosting the shared boundary recursion.
    pub hub: u32,
    /// Modeled stack count (stacks beyond the component count idle).
    pub num_stacks: usize,
    /// Number of inserted inter-stack transfers.
    pub n_xfers: usize,
    /// Total bytes crossing the inter-stack interconnect.
    pub xfer_bytes: u64,
}

/// Number of leaf tiles the plan produced (level-0 components; 1 for a
/// direct solve). A stack needs at least one tile to be non-trivial, so
/// the coordinator rejects `num_stacks` above this.
pub fn plan_tiles(plan: &ApspPlan) -> usize {
    plan.levels
        .first()
        .map(|l| l.n_components())
        .unwrap_or(1)
        .max(1)
}

/// Tile-work estimate per level-0 component: the FW cost is cubic in the
/// block size, and boundary components pay the post-injection rerun too.
fn comp_work(plan: &ApspPlan) -> Vec<f64> {
    let Some(lvl) = plan.levels.first() else {
        return Vec::new();
    };
    lvl.cs
        .components
        .iter()
        .map(|c| {
            let n = c.n() as f64;
            let mut w = n * n * n;
            if c.n_boundary > 0 {
                w *= 2.0;
            }
            w.max(1.0)
        })
        .collect()
}

/// Summed tile work per stack under an assignment (shared by the
/// rebalance pass and the hub choice, so they optimize one objective).
fn stack_loads(work: &[f64], assign: &[u32], num_stacks: usize) -> Vec<f64> {
    let mut load = vec![0.0f64; num_stacks];
    for (ci, &s) in assign.iter().enumerate() {
        load[s as usize] += work[ci];
    }
    load
}

/// Place every level-0 component whole on one of `num_stacks` stacks:
/// [`partition_kway`] over the component quotient graph (one vertex per
/// component, one edge per cross-component edge) minimizes the
/// cross-shard cut, then a greedy pass rebalances by tile work (move
/// the component that best narrows the max/min load gap, until no move
/// helps). Deterministic for a fixed seed.
pub fn shard_assignment(plan: &ApspPlan, num_stacks: usize, seed: u64) -> Vec<u32> {
    assert!(num_stacks >= 1, "num_stacks must be >= 1");
    let Some(lvl) = plan.levels.first() else {
        return Vec::new();
    };
    let k = lvl.n_components();
    if num_stacks == 1 || k <= 1 {
        return vec![0; k];
    }
    // component of each boundary id (boundary ids are component-major)
    let mut comp_of_bid = vec![0u32; lvl.n_boundary()];
    for ci in 0..k {
        for b in lvl.group_start[ci]..lvl.group_start[ci + 1] {
            comp_of_bid[b] = ci as u32;
        }
    }
    // quotient graph: one vertex per component, cross edges collapsed
    let edges: Vec<(u32, u32, f32)> = lvl
        .next_cross
        .edges()
        .map(|(u, v, _)| (comp_of_bid[u as usize], comp_of_bid[v as usize], 1.0))
        .filter(|(cu, cv, _)| cu != cv)
        .collect();
    let quotient = CsrGraph::from_edges(k, &edges);
    let parts = num_stacks.min(k);
    let mut stack_of = partition_kway(&quotient, parts, seed).assign;

    // rebalance by tile work: partition_kway balances vertex counts,
    // but a stack's FW load is the sum of its components' cubic work
    let work = comp_work(plan);
    let mut load = stack_loads(&work, &stack_of, num_stacks);
    for _ in 0..k {
        let hi = (0..num_stacks)
            .max_by(|&a, &b| load[a].total_cmp(&load[b]))
            .unwrap();
        let lo = (0..num_stacks)
            .min_by(|&a, &b| load[a].total_cmp(&load[b]))
            .unwrap();
        // best single-component move from the most to the least loaded
        // stack: minimize the resulting pairwise gap, require progress
        let mut best: Option<(usize, f64)> = None;
        for (ci, &s) in stack_of.iter().enumerate() {
            if s as usize != hi {
                continue;
            }
            let w = work[ci];
            let new_hi = load[hi] - w;
            let new_lo = load[lo] + w;
            if new_hi.max(new_lo) >= load[hi] {
                continue; // no progress on the max load
            }
            let gap = (new_hi - new_lo).abs();
            if best.map(|(_, g)| gap < g).unwrap_or(true) {
                best = Some((ci, gap));
            }
        }
        let Some((ci, _)) = best else { break };
        load[hi] -= work[ci];
        load[lo] += work[ci];
        stack_of[ci] = lo as u32;
    }
    stack_of
}

impl ShardGraph {
    /// Lower `plan` and split the result across `num_stacks` stacks.
    /// Stacks beyond the component count simply idle; the coordinator
    /// rejects that configuration before it gets here.
    pub fn build(plan: &ApspPlan, num_stacks: usize, seed: u64) -> ShardGraph {
        assert!(num_stacks >= 1, "num_stacks must be >= 1");
        let solo = lower(plan);
        let comp_stack = shard_assignment(plan, num_stacks, seed);

        // hub = least-loaded stack: the shared boundary recursion is
        // serial work, so park it where the level-0 FW load is lightest
        let load = stack_loads(&comp_work(plan), &comp_stack, num_stacks);
        let hub = (0..num_stacks)
            .min_by(|&a, &b| load[a].total_cmp(&load[b]))
            .unwrap_or(0) as u32;

        let stack_of = |kind: &TaskKind| -> u32 {
            match *kind {
                TaskKind::Load { level: 0, comp }
                | TaskKind::LocalFw { level: 0, comp }
                | TaskKind::Inject { level: 0, comp }
                | TaskKind::RerunFw { level: 0, comp } => {
                    comp_stack.get(comp as usize).copied().unwrap_or(hub)
                }
                _ => hub,
            }
        };

        // splice a StackXfer node into every cross-stack edge, keeping
        // node order (and therefore step monotonicity) intact
        let mut sharded = TaskGraph {
            nodes: Vec::with_capacity(solo.nodes.len()),
            steps: solo.steps.clone(),
        };
        let mut affinity: Vec<u32> = Vec::with_capacity(solo.nodes.len());
        let mut new_id: Vec<TaskId> = Vec::with_capacity(solo.nodes.len());
        let mut n_xfers = 0usize;
        let mut xfer_bytes = 0u64;
        // One physical transfer per (producer, destination stack) for
        // the gather direction: once a producer's output reached the
        // hub, later hub consumers (e.g. Sync then CrossMerge reading
        // the same post-rerun boundary block) reuse the copy instead of
        // re-crossing the serialized interconnect. dB injections are
        // never deduplicated — each carries a distinct per-component
        // slice.
        let mut gather_xfer: std::collections::HashMap<(TaskId, u32), TaskId> =
            std::collections::HashMap::new();
        for node in &solo.nodes {
            let a = stack_of(&node.kind);
            let mut deps = Vec::with_capacity(node.deps.len());
            for &d in &node.deps {
                let producer = &solo.nodes[d as usize];
                let pa = stack_of(&producer.kind);
                if pa == a {
                    deps.push(new_id[d as usize]);
                    continue;
                }
                // the only legal crossers: boundary matrices gathered
                // into the hub's aggregation nodes, and dB injections
                // flowing back out of a hub CrossMerge
                let gather = matches!(
                    node.kind,
                    TaskKind::BoundaryBuild { .. }
                        | TaskKind::Sync { .. }
                        | TaskKind::Store { .. }
                        | TaskKind::CrossMerge { .. }
                );
                debug_assert!(
                    gather
                        || (matches!(producer.kind, TaskKind::CrossMerge { .. })
                            && matches!(node.kind, TaskKind::Inject { .. })),
                    "illegal cross-stack edge {:?} -> {:?}",
                    producer.kind,
                    node.kind
                );
                if gather {
                    if let Some(&xid) = gather_xfer.get(&(d, a)) {
                        deps.push(xid);
                        continue;
                    }
                }
                let bytes = xfer_payload_bytes(plan, producer, node);
                if bytes == 0 {
                    // nothing actually moves (e.g. a zero-boundary
                    // component feeding the top-level merge): keep the
                    // plain dependency, report no transfer
                    deps.push(new_id[d as usize]);
                    continue;
                }
                xfer_bytes += bytes;
                n_xfers += 1;
                let xid = sharded.nodes.len() as TaskId;
                sharded.nodes.push(TaskNode {
                    id: xid,
                    kind: TaskKind::StackXfer { from: pa, to: a },
                    level: node.level,
                    phase: Phase::StackXfer,
                    step: node.step,
                    ops: vec![Op::StackXfer { bytes }],
                    deps: vec![new_id[d as usize]],
                });
                affinity.push(pa); // the source stack drives the link
                if gather {
                    gather_xfer.insert((d, a), xid);
                }
                deps.push(xid);
            }
            let id = sharded.nodes.len() as TaskId;
            new_id.push(id);
            let mut n = node.clone();
            n.id = id;
            n.deps = deps;
            sharded.nodes.push(n);
            affinity.push(a);
        }
        debug_assert!(sharded.validate().is_ok(), "{:?}", sharded.validate());

        ShardGraph {
            solo,
            sharded,
            affinity,
            comp_stack,
            hub,
            num_stacks,
            n_xfers,
            xfer_bytes,
        }
    }

    /// Components placed on each stack.
    pub fn comps_per_stack(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.num_stacks];
        for &s in &self.comp_stack {
            counts[s as usize] += 1;
        }
        counts
    }
}

/// Payload of one cross-stack edge: what the consumer actually pulls
/// over the interconnect.
fn xfer_payload_bytes(plan: &ApspPlan, producer: &TaskNode, consumer: &TaskNode) -> u64 {
    let comp_dims = |comp: u32| -> (u64, u64) {
        let c = &plan.levels[0].cs.components[comp as usize];
        (c.n() as u64, c.n_boundary as u64)
    };
    match consumer.kind {
        // the hub gathers a component's boundary matrix (pre-injection
        // for the build, post-rerun for the sync and the top-level
        // merges — the n x b panels the merges consume stay resident
        // where the interleaved boundary matrices live, exactly as the
        // solo model's FetchBoundary charges them from FeNAND)
        TaskKind::BoundaryBuild { .. } | TaskKind::Sync { .. } | TaskKind::CrossMerge { .. } => {
            match producer.kind {
                TaskKind::Load { comp, .. }
                | TaskKind::LocalFw { comp, .. }
                | TaskKind::Inject { comp, .. }
                | TaskKind::RerunFw { comp, .. } => {
                    let (_, b) = comp_dims(comp);
                    b * b * 4
                }
                _ => 0,
            }
        }
        // an internal-only component's final matrix crossing to the
        // hub's FeNAND store
        TaskKind::Store { .. } => match producer.kind {
            TaskKind::Load { comp, .. }
            | TaskKind::LocalFw { comp, .. }
            | TaskKind::Inject { comp, .. }
            | TaskKind::RerunFw { comp, .. } => {
                let (n, _) = comp_dims(comp);
                n * n * 4
            }
            _ => 0,
        },
        // dB injection: the component's b x b slice of the sub-level
        // solution flows from the hub back to the component's stack
        TaskKind::Inject { comp, .. } => {
            let (_, b) = comp_dims(comp);
            b * b * 4
        }
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::plan::{build_plan, PlanOptions};
    use crate::graph::generators::{self, Topology, Weights};

    fn plan_for(topo: Topology, n: usize, tile: usize, seed: u64) -> ApspPlan {
        let g = generators::generate(topo, n, 10.0, Weights::Uniform(1.0, 5.0), seed);
        build_plan(
            &g,
            PlanOptions {
                tile_limit: tile,
                max_depth: usize::MAX,
                seed,
            },
        )
    }

    #[test]
    fn one_stack_shard_is_the_solo_graph() {
        let plan = plan_for(Topology::Nws, 600, 48, 1);
        let s = ShardGraph::build(&plan, 1, 1);
        assert_eq!(s.n_xfers, 0);
        assert_eq!(s.xfer_bytes, 0);
        assert_eq!(s.sharded.n_tasks(), s.solo.n_tasks());
        assert!(s.affinity.iter().all(|&a| a == 0));
        assert_eq!(s.sharded.to_trace(), s.solo.to_trace());
    }

    #[test]
    fn sharded_graph_preserves_every_solo_node() {
        let plan = plan_for(Topology::OgbnProxy, 800, 64, 2);
        for stacks in [2usize, 4] {
            let s = ShardGraph::build(&plan, stacks, 2);
            s.sharded.validate().unwrap();
            // every non-xfer node is a solo node with identical payload,
            // in the same relative order
            let real: Vec<_> = s
                .sharded
                .nodes
                .iter()
                .filter(|n| !matches!(n.kind, TaskKind::StackXfer { .. }))
                .collect();
            assert_eq!(real.len(), s.solo.n_tasks());
            for (r, sn) in real.iter().zip(&s.solo.nodes) {
                assert_eq!(r.kind, sn.kind);
                assert_eq!(r.ops, sn.ops);
                assert_eq!(r.step, sn.step);
                assert_eq!(r.deps.len(), sn.deps.len());
            }
            assert!(s.n_xfers > 0, "partitioned graph must cross stacks");
            assert!(s.xfer_bytes > 0);
        }
    }

    #[test]
    fn xfers_are_boundary_matrices_or_db_injections_only() {
        let plan = plan_for(Topology::Nws, 900, 48, 3);
        let s = ShardGraph::build(&plan, 4, 3);
        for node in &s.sharded.nodes {
            let TaskKind::StackXfer { from, to } = node.kind else {
                continue;
            };
            assert_ne!(from, to, "self-transfer");
            assert!((from as usize) < s.num_stacks && (to as usize) < s.num_stacks);
            assert_eq!(node.deps.len(), 1, "xfer has exactly one producer");
            assert!(!node.ops.is_empty(), "zero-byte edges must not splice a transfer");
            // classify every consumer (a deduplicated gather transfer
            // may feed several hub nodes, e.g. Sync and CrossMerge)
            let consumers: Vec<_> = s
                .sharded
                .nodes
                .iter()
                .filter(|n| n.deps.contains(&node.id))
                .collect();
            assert!(!consumers.is_empty());
            let producer = &s.sharded.nodes[node.deps[0] as usize];
            for c in consumers {
                let boundary_gather = matches!(
                    c.kind,
                    TaskKind::BoundaryBuild { .. }
                        | TaskKind::Sync { .. }
                        | TaskKind::Store { .. }
                        | TaskKind::CrossMerge { .. }
                );
                let db_injection = matches!(producer.kind, TaskKind::CrossMerge { .. })
                    && matches!(c.kind, TaskKind::Inject { .. });
                assert!(
                    boundary_gather || db_injection,
                    "unexpected crosser {:?} -> {:?}",
                    producer.kind,
                    c.kind
                );
            }
        }
    }

    #[test]
    fn assignment_places_components_whole_and_balances_work() {
        let plan = plan_for(Topology::OgbnProxy, 1500, 64, 4);
        let k = plan_tiles(&plan);
        assert!(k >= 4, "workload must have enough tiles");
        for stacks in [2usize, 4] {
            let assign = shard_assignment(&plan, stacks, 4);
            assert_eq!(assign.len(), k);
            assert!(assign.iter().all(|&s| (s as usize) < stacks));
            // every stack gets something
            let mut counts = vec![0usize; stacks];
            for &s in &assign {
                counts[s as usize] += 1;
            }
            assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
            // work balance: max load within 3x of min (cubic work over
            // heterogeneous components is lumpy; gross skew is the bug)
            let work = comp_work(&plan);
            let mut load = vec![0.0f64; stacks];
            for (ci, &s) in assign.iter().enumerate() {
                load[s as usize] += work[ci];
            }
            let max = load.iter().cloned().fold(0.0f64, f64::max);
            let min = load.iter().cloned().fold(f64::INFINITY, f64::min);
            assert!(max / min.max(1.0) < 3.0, "load skew {load:?}");
        }
        // deterministic
        assert_eq!(shard_assignment(&plan, 4, 4), shard_assignment(&plan, 4, 4));
    }

    #[test]
    fn more_stacks_than_components_idle_gracefully() {
        // single-tile direct solve sharded across 4 stacks: everything
        // lands on the hub, no transfers
        let g = generators::complete(20, Weights::Uniform(1.0, 2.0), 5);
        let plan = build_plan(&g, PlanOptions::default());
        assert_eq!(plan.depth(), 0);
        let s = ShardGraph::build(&plan, 4, 5);
        assert_eq!(s.n_xfers, 0);
        assert!(s.affinity.iter().all(|&a| a == s.hub));
        assert_eq!(s.sharded.to_trace(), s.solo.to_trace());
    }

    #[test]
    fn disconnected_graph_shards_without_traffic() {
        // two cliques, no bridge: no boundary, no dB — the only cross
        // edges carry zero-byte payloads (empty boundary blocks), so no
        // transfer is spliced and the interconnect stays silent
        let mut edges = Vec::new();
        for u in 0..40u32 {
            for v in (u + 1)..40 {
                edges.push((u, v, 1.0f32));
            }
        }
        for u in 40..80u32 {
            for v in (u + 1)..80 {
                edges.push((u, v, 1.0));
            }
        }
        let g = crate::graph::csr::CsrGraph::from_undirected_edges(80, &edges);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 48,
                max_depth: usize::MAX,
                seed: 6,
            },
        );
        assert_eq!(plan.levels[0].n_boundary(), 0);
        let s = ShardGraph::build(&plan, 2, 6);
        s.sharded.validate().unwrap();
        assert_eq!(s.n_xfers, 0, "zero-byte edges must stay plain deps");
        assert_eq!(s.xfer_bytes, 0);
        assert_eq!(s.sharded.n_tasks(), s.solo.n_tasks());
    }

    #[test]
    fn gather_transfers_deduplicate_per_producer() {
        // a boundary component's post-rerun block feeds both Sync and
        // the top-level CrossMerge on the hub: one physical transfer,
        // reused by every hub consumer
        let plan = plan_for(Topology::Nws, 700, 48, 8);
        let s = ShardGraph::build(&plan, 3, 8);
        let mut seen = std::collections::HashSet::new();
        let mut consumer_count: std::collections::HashMap<TaskId, usize> =
            std::collections::HashMap::new();
        for node in &s.sharded.nodes {
            for &d in &node.deps {
                if matches!(s.sharded.nodes[d as usize].kind, TaskKind::StackXfer { .. }) {
                    *consumer_count.entry(d).or_insert(0) += 1;
                }
            }
            let TaskKind::StackXfer { to, .. } = node.kind else {
                continue;
            };
            let producer = node.deps[0];
            let is_db = matches!(
                s.sharded.nodes[producer as usize].kind,
                TaskKind::CrossMerge { .. }
            );
            if !is_db {
                assert!(
                    seen.insert((producer, to)),
                    "duplicate gather transfer of task {producer} to stack {to}"
                );
            }
        }
        // the dedup actually fires: some transfer serves >= 2 consumers
        assert!(
            consumer_count.values().any(|&c| c >= 2),
            "expected a reused gather transfer (Sync + CrossMerge)"
        );
    }
}
