//! APSP algorithm library: the paper's recursive partitioned APSP
//! (Algorithms 1 & 2) plus every kernel and baseline it builds on.
//!
//! * [`floyd_warshall`] — classic / row-vectorized / parallel FW (§II-B1).
//! * [`dijkstra`] — repeated Dijkstra: the exactness oracle.
//! * [`minplus`] — min-plus (tropical) matrix products (MP kernels).
//! * [`plan`] — recursion-aware partition planning (topology only).
//! * [`partitioned`] — single-level partitioned APSP (Algorithm 1).
//! * [`recursive`] — recursive partitioned APSP (Algorithm 2) over a
//!   pluggable [`backend::TileBackend`].
//! * [`trace`] — the operation trace consumed by the PIM simulator.
//! * [`validate`] — cross-implementation validation helpers.

pub mod backend;
pub mod dijkstra;
pub mod floyd_warshall;
pub mod minplus;
pub mod partitioned;
pub mod plan;
pub mod recursive;
pub mod trace;
pub mod validate;
