//! APSP algorithm library: the paper's recursive partitioned APSP
//! (Algorithms 1 & 2) plus every kernel and baseline it builds on.
//!
//! * [`floyd_warshall`] — classic / row-vectorized / parallel FW (§II-B1).
//! * [`dijkstra`] — repeated Dijkstra: the exactness oracle.
//! * [`minplus`] — min-plus (tropical) matrix products (MP kernels).
//! * [`plan`] — recursion-aware partition planning (topology only).
//! * [`partitioned`] — single-level partitioned APSP (Algorithm 1).
//! * [`recursive`] — recursive partitioned APSP (Algorithm 2) over a
//!   pluggable [`backend::TileBackend`], barrier-stepped walk.
//! * [`taskgraph`] — the tile-task DAG: lowering of a plan into tile
//!   ops + true data dependencies (the IR shared by both executors and
//!   the simulator).
//! * [`scheduler`] — dependency-aware work-stealing host executor over
//!   the task graph (bit-identical to the barrier walk).
//! * [`delta`] — edge-delta engine: incremental APSP that maps
//!   insert/delete/reweight batches onto the tile plan and re-solves
//!   only the dirty tile closure.
//! * [`batch`] — multi-graph batch engine: union of independent task
//!   graphs into one shared-resource schedule.
//! * [`admission`] — async admission pipeline: admit arrival-stamped
//!   graphs into a live schedule without draining it (bounded queue,
//!   deterministic rejection verdicts).
//! * [`shard`] — sharded multi-stack execution: one over-large graph
//!   partitioned across modeled PIM stacks with explicit inter-stack
//!   boundary/dB transfers.
//! * [`query`] — packed next-hop maps ([`query::NextHopMatrix`]) and
//!   the query-script front-end: O(1) `dist(u,v)`, O(path-len)
//!   `path(u,v)` with no Dijkstra fallback.
//! * [`serve`] — serve-side read path: lock-free snapshot publication
//!   ([`serve::SnapshotCell`]) and the batched source-major query
//!   executor ([`serve::BatchExec`]).
//! * [`semiring`] — the element API ([`semiring::Semiring`]) the tile
//!   kernels are generic over: `(min,+)` APSP plus boolean and-or
//!   (reachability), max-min (widest path) and max-plus (critical
//!   path) instances behind one trait and a monomorphizing dispatch.
//! * [`store`] — content-addressed result store: fingerprinted,
//!   compressed APSP results persisted to modeled FeNAND so duplicate
//!   submissions are served instead of re-solved.
//! * [`trace`] — the operation trace consumed by the PIM simulator
//!   (a deterministic topological lowering of the task graph).
//! * [`validate`] — cross-implementation validation helpers.

pub mod admission;
pub mod backend;
pub mod batch;
pub mod delta;
pub mod dijkstra;
pub mod floyd_warshall;
pub mod minplus;
pub mod partitioned;
pub mod plan;
pub mod query;
pub mod recursive;
pub mod semiring;
pub mod serve;
pub mod scheduler;
pub mod shard;
pub mod store;
pub mod taskgraph;
pub mod trace;
pub mod validate;
