//! Async admission pipeline: admit new graphs into a live batch
//! schedule without draining it.
//!
//! The batch engine ([`super::batch`]) merges a workload known up
//! front; a serving system does not get that luxury — requests arrive
//! while the schedule is running, and draining the machine for every
//! arrival throws away exactly the always-busy property the PIM stack
//! is built around. Admission is cheap here because independent graphs
//! share no edges: admitting one is a lock-scoped graph union — lower
//! the plan into a fresh task/step id namespace
//! ([`super::taskgraph::TaskGraph::append_offset`] via
//! [`BatchGraph::push`]) and splice the new roots into the live ready
//! queue. No barrier, no drain, nothing running is disturbed.
//!
//! [`AdmissionGraph::build`] runs the admission *policy* over an
//! arrival-ordered workload: a bounded queue (at most `queue_depth`
//! graphs in flight) plus deterministic per-graph verdicts — empty
//! graphs, graphs that could never fit the stack's functional-matrix
//! capacity, and graphs that would overflow the aggregate memory guard
//! next to their worst-case co-resident predecessors are rejected
//! cleanly while the pipeline keeps running. Two consumers execute the
//! admitted schedule:
//!
//! * the host executor ([`super::scheduler::execute_admission`])
//!   splices each admitted graph into a long-lived worker pool
//!   ([`crate::util::threads::dag_pool_scope`]) in arrival order, with
//!   per-graph completion callbacks and results **bit-identical** to
//!   solo runs;
//! * the simulator ([`crate::sim::engine::simulate_admission`]) costs
//!   the workload on the shared resource model through the same
//!   bounded queue: each graph enters at `max(arrival, first free
//!   slot)` (arrivals come from config, never wall-clock) and its
//!   admit-to-complete latency — queue wait included — is attributed
//!   alongside the energy partition.

use super::batch::BatchGraph;
use super::plan::ApspPlan;
use super::recursive::projected_bytes;
use super::store::{fingerprint, CompressedMatrix, ResultStore, StoreEntry};
use super::taskgraph::{append_store_writeback, csr_bytes_estimate, lower, store_hit_graph};
use crate::graph::csr::CsrGraph;
use std::collections::HashMap;

/// Admission-control policy of the pipeline.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Max graphs in flight (admitted, not yet complete). The next
    /// arrival waits for a slot; the bound also caps the worst-case
    /// co-resident footprint the aggregate memory guard checks.
    pub queue_depth: usize,
    /// Functional-matrix capacity of one modeled stack. Admission
    /// rejects graphs that would let the in-flight footprint exceed it
    /// under the queue bound; the host executor honors the window by
    /// dropping a graph's intermediate buffers the moment it completes.
    pub memory_limit_bytes: u64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self {
            queue_depth: 4,
            memory_limit_bytes: 12 << 30,
        }
    }
}

/// Why a submission was turned away (the pipeline keeps running).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// 0 vertices: no schedulable work.
    Empty,
    /// The graph alone exceeds the stack's functional-matrix capacity —
    /// it could never be resident, even with the queue to itself.
    StackCapacity,
    /// The graph fits alone, but next to the worst-case set of
    /// co-resident predecessors (the `queue_depth - 1` largest admitted
    /// graphs) it would overflow the aggregate memory guard.
    MemoryGuard,
}

impl RejectReason {
    pub fn name(&self) -> &'static str {
        match self {
            RejectReason::Empty => "empty graph",
            RejectReason::StackCapacity => "exceeds stack capacity",
            RejectReason::MemoryGuard => "trips aggregate memory guard",
        }
    }
}

/// Admission verdict of one submission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Admitted as graph `admitted_index` of the merged schedule.
    Admitted { admitted_index: u32 },
    Rejected(RejectReason),
}

impl Verdict {
    pub fn admitted(&self) -> bool {
        matches!(self, Verdict::Admitted { .. })
    }
}

/// Result-store outcome of one *admitted* submission (store-enabled
/// builds only; rejected submissions never consult the store).
///
/// Outcomes never influence verdicts — admission control sees the same
/// footprints either way, so a store-enabled build admits exactly the
/// same set as the no-store baseline (apples-to-apples `cache_speedup`).
#[derive(Debug, Clone, PartialEq)]
pub enum StoreOutcome {
    /// Fingerprint hit: lowering is skipped entirely and the graph's
    /// schedule is one modeled FeNAND read of the stored result.
    /// `source` is the admitted index whose solve produced the entry in
    /// this build (the executor serves that solution bit-identically);
    /// `payload` carries the compressed solution when the store was
    /// pre-warmed with one.
    Hit {
        source: Option<u32>,
        payload: Option<CompressedMatrix>,
    },
    /// Miss: solved, then the result is programmed back into the store
    /// (the lowered graph gains a FeNAND write-back node).
    MissStored,
    /// Miss that was not persisted — the store is disabled (capacity 0)
    /// or rejected the entry (over budget); the pipeline keeps running.
    MissUncached,
}

impl StoreOutcome {
    pub fn is_hit(&self) -> bool {
        matches!(self, StoreOutcome::Hit { .. })
    }

    pub fn name(&self) -> &'static str {
        match self {
            StoreOutcome::Hit { .. } => "HIT",
            StoreOutcome::MissStored => "miss",
            StoreOutcome::MissUncached => "miss*",
        }
    }
}

/// An arrival-stamped workload run through admission control and
/// lowered into one growable merged schedule.
#[derive(Debug, Clone)]
pub struct AdmissionGraph {
    /// Verdict per submission, in arrival order.
    pub verdicts: Vec<Verdict>,
    /// Merged union of the admitted graphs — disjoint task/step id
    /// namespaces, the same invariant [`BatchGraph`] maintains, built
    /// incrementally here ([`BatchGraph::push`]).
    pub batch: BatchGraph,
    /// Submission index of each admitted graph.
    pub submission_of: Vec<usize>,
    /// Modeled arrival time of each admitted graph (seconds on the
    /// simulated timeline, non-decreasing).
    pub arrivals: Vec<f64>,
    /// The in-flight bound the host executor enforces.
    pub queue_depth: usize,
}

impl AdmissionGraph {
    /// Run admission control over an arrival-ordered workload and lower
    /// every admitted graph into the merged schedule.
    ///
    /// Verdicts are deterministic: the aggregate memory guard is
    /// checked against the worst-case co-resident set the queue bound
    /// permits (the `queue_depth - 1` largest previously admitted
    /// graphs), never against execution timing — the same submission
    /// sequence always draws the same verdicts, in functional and
    /// estimate mode alike.
    pub fn build(
        subs: &[(&CsrGraph, &ApspPlan)],
        arrivals: &[f64],
        cfg: &AdmissionConfig,
    ) -> AdmissionGraph {
        Self::build_inner(subs, arrivals, cfg, None).0
    }

    /// [`build`](Self::build) with a content-addressed result store in
    /// the loop: every *admitted* submission is fingerprinted first. A
    /// hit skips lowering entirely — its schedule is a single modeled
    /// FeNAND read of the stored result — while a miss lowers as usual
    /// and (when the store accepts the entry) gains a FeNAND write-back
    /// node. `compression` selects the modeled stored size: worst-case
    /// CSR bytes (on, the default — matches the `Op::StoreCsr` model)
    /// or dense bytes (off). Returns the admission graph plus one
    /// outcome per submission (`None` for rejected submissions).
    ///
    /// Verdicts are identical to a plain [`build`](Self::build) of the
    /// same workload: the store changes what admitted graphs *cost*,
    /// never whether they are admitted.
    pub fn build_with_store(
        subs: &[(&CsrGraph, &ApspPlan)],
        arrivals: &[f64],
        cfg: &AdmissionConfig,
        store: &mut dyn ResultStore,
        compression: bool,
    ) -> (AdmissionGraph, Vec<Option<StoreOutcome>>) {
        Self::build_inner(subs, arrivals, cfg, Some((store, compression)))
    }

    fn build_inner(
        subs: &[(&CsrGraph, &ApspPlan)],
        arrivals: &[f64],
        cfg: &AdmissionConfig,
        mut store: Option<(&mut dyn ResultStore, bool)>,
    ) -> (AdmissionGraph, Vec<Option<StoreOutcome>>) {
        assert!(cfg.queue_depth >= 1, "queue_depth must be >= 1");
        assert_eq!(
            subs.len(),
            arrivals.len(),
            "one arrival time per submission"
        );
        assert!(
            arrivals.windows(2).all(|w| w[0] <= w[1]),
            "arrival schedule must be non-decreasing"
        );
        assert!(
            arrivals.iter().all(|a| a.is_finite() && *a >= 0.0),
            "arrival times must be finite and non-negative"
        );
        let mut out = AdmissionGraph {
            verdicts: Vec::with_capacity(subs.len()),
            batch: BatchGraph::default(),
            submission_of: Vec::new(),
            arrivals: Vec::new(),
            queue_depth: cfg.queue_depth,
        };
        let mut outcomes: Vec<Option<StoreOutcome>> = Vec::with_capacity(subs.len());
        // footprints of the already-admitted graphs, for the
        // worst-case co-resident sum
        let mut admitted_bytes: Vec<u64> = Vec::new();
        // fingerprint -> admitted index of the miss that will produce
        // the stored result in this build (serves same-run duplicates)
        let mut producer: HashMap<u64, u32> = HashMap::new();
        for (si, &(g, plan)) in subs.iter().enumerate() {
            let verdict = if g.n() == 0 {
                outcomes.push(None);
                Verdict::Rejected(RejectReason::Empty)
            } else {
                let need = projected_bytes(plan, g);
                let resident = worst_case_resident(&admitted_bytes, cfg.queue_depth);
                if need > cfg.memory_limit_bytes {
                    outcomes.push(None);
                    Verdict::Rejected(RejectReason::StackCapacity)
                } else if need + resident > cfg.memory_limit_bytes {
                    outcomes.push(None);
                    Verdict::Rejected(RejectReason::MemoryGuard)
                } else {
                    let mut produced_fp: Option<u64> = None;
                    let (tg, outcome) = match store.as_mut() {
                        None => (lower(plan), None),
                        Some((s, compression)) => {
                            let fp = fingerprint(g);
                            let cached = s.get(fp).map(|e| (e.bytes, e.payload.clone()));
                            match cached {
                                // servable hit: a producer in this run,
                                // or a pre-warmed payload
                                Some((bytes, payload))
                                    if producer.contains_key(&fp) || payload.is_some() =>
                                {
                                    (
                                        store_hit_graph(bytes),
                                        Some(StoreOutcome::Hit {
                                            source: producer.get(&fp).copied(),
                                            payload,
                                        }),
                                    )
                                }
                                _ => {
                                    let mut tg = lower(plan);
                                    let n = g.n() as u64;
                                    let bytes = if *compression {
                                        csr_bytes_estimate(n * n)
                                    } else {
                                        n * n * 4
                                    };
                                    let cost = tg.to_trace().total_madds() as f64;
                                    match s.put(fp, StoreEntry::new(bytes, cost, None)) {
                                        Ok(true) => {
                                            append_store_writeback(&mut tg, bytes);
                                            produced_fp = Some(fp);
                                            (tg, Some(StoreOutcome::MissStored))
                                        }
                                        // disabled or over-budget: the
                                        // pipeline keeps running uncached
                                        Ok(false) | Err(_) => {
                                            (tg, Some(StoreOutcome::MissUncached))
                                        }
                                    }
                                }
                            }
                        }
                    };
                    let gi = out.batch.push(tg);
                    if let Some(fp) = produced_fp {
                        producer.insert(fp, gi);
                    }
                    outcomes.push(outcome);
                    out.submission_of.push(si);
                    out.arrivals.push(arrivals[si]);
                    admitted_bytes.push(need);
                    Verdict::Admitted { admitted_index: gi }
                }
            };
            out.verdicts.push(verdict);
        }
        debug_assert!(
            out.batch.merged.validate().is_ok(),
            "{:?}",
            out.batch.merged.validate()
        );
        (out, outcomes)
    }

    pub fn n_submissions(&self) -> usize {
        self.verdicts.len()
    }

    pub fn n_admitted(&self) -> usize {
        self.batch.n_graphs()
    }

    pub fn n_rejected(&self) -> usize {
        self.n_submissions() - self.n_admitted()
    }
}

/// Worst-case footprint co-resident with a new admission: the
/// `queue_depth - 1` largest already-admitted graphs. The queue bound
/// guarantees no more than that many predecessors can still be in
/// flight; *which* ones is timing-dependent, so the guard takes the
/// largest — sound for every execution, and deterministic.
fn worst_case_resident(admitted_bytes: &[u64], queue_depth: usize) -> u64 {
    let mut v = admitted_bytes.to_vec();
    v.sort_unstable_by(|a, b| b.cmp(a));
    v.iter().take(queue_depth.saturating_sub(1)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::plan::{build_plan, PlanOptions};
    use crate::graph::generators::{self, Topology, Weights};

    fn workload(n: usize, tile: usize, seed: u64) -> (CsrGraph, ApspPlan) {
        let g = generators::generate(Topology::Nws, n, 10.0, Weights::Uniform(1.0, 5.0), seed);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: tile,
                max_depth: usize::MAX,
                seed,
            },
        );
        (g, plan)
    }

    #[test]
    fn admits_everything_under_a_loose_guard() {
        let ws: Vec<_> = (0..3).map(|i| workload(300 + 50 * i, 48, i as u64)).collect();
        let subs: Vec<(&CsrGraph, &ApspPlan)> = ws.iter().map(|(g, p)| (g, p)).collect();
        let arrivals = [0.0, 1e-3, 2e-3];
        let adm = AdmissionGraph::build(&subs, &arrivals, &AdmissionConfig::default());
        assert_eq!(adm.n_submissions(), 3);
        assert_eq!(adm.n_admitted(), 3);
        assert_eq!(adm.n_rejected(), 0);
        assert_eq!(adm.submission_of, vec![0, 1, 2]);
        assert_eq!(adm.arrivals, arrivals);
        assert!(adm.verdicts.iter().all(|v| v.admitted()));
        // the merged schedule is the batch union of the admitted solos
        let solos: Vec<_> = ws
            .iter()
            .map(|(_, p)| crate::apsp::taskgraph::lower(p))
            .collect();
        let batch = BatchGraph::merge(solos);
        assert_eq!(adm.batch.merged.n_tasks(), batch.merged.n_tasks());
        assert_eq!(adm.batch.node_offset, batch.node_offset);
    }

    #[test]
    fn empty_graph_rejected_pipeline_continues() {
        let (g0, p0) = workload(300, 48, 1);
        let empty = CsrGraph::from_edges(0, &[]);
        let pe = build_plan(&empty, PlanOptions::default());
        let (g2, p2) = workload(250, 48, 2);
        let subs: Vec<(&CsrGraph, &ApspPlan)> = vec![(&g0, &p0), (&empty, &pe), (&g2, &p2)];
        let adm = AdmissionGraph::build(&subs, &[0.0, 0.0, 0.0], &AdmissionConfig::default());
        assert_eq!(adm.verdicts[1], Verdict::Rejected(RejectReason::Empty));
        assert_eq!(adm.n_admitted(), 2);
        assert_eq!(adm.submission_of, vec![0, 2]);
    }

    #[test]
    fn oversized_graph_rejected_as_stack_capacity() {
        let (g0, p0) = workload(300, 48, 3);
        let (g1, p1) = workload(600, 48, 4);
        let subs: Vec<(&CsrGraph, &ApspPlan)> = vec![(&g0, &p0), (&g1, &p1)];
        // limit below the second graph's solo footprint: it can never
        // be resident, even with the queue to itself
        let limit = projected_bytes(&p1, &g1) - 1;
        assert!(projected_bytes(&p0, &g0) <= limit);
        let cfg = AdmissionConfig {
            queue_depth: 4,
            memory_limit_bytes: limit,
        };
        let adm = AdmissionGraph::build(&subs, &[0.0, 1e-3], &cfg);
        assert!(adm.verdicts[0].admitted());
        assert_eq!(
            adm.verdicts[1],
            Verdict::Rejected(RejectReason::StackCapacity)
        );
        assert_eq!(adm.n_admitted(), 1);
    }

    #[test]
    fn aggregate_guard_rejects_but_pipeline_keeps_running() {
        // each graph fits the limit alone; two co-resident do not. With
        // queue_depth = 2 the second submission trips the aggregate
        // guard; a later, smaller graph is still admitted.
        let (g0, p0) = workload(500, 64, 5);
        let (g1, p1) = workload(500, 64, 6);
        let (g2, p2) = workload(120, 64, 7);
        let b0 = projected_bytes(&p0, &g0);
        let b1 = projected_bytes(&p1, &g1);
        let b2 = projected_bytes(&p2, &g2);
        let limit = b0.max(b1) + b2 + 1;
        assert!(b0 + b1 > limit, "workload must exceed the paired limit");
        let cfg = AdmissionConfig {
            queue_depth: 2,
            memory_limit_bytes: limit,
        };
        let subs: Vec<(&CsrGraph, &ApspPlan)> = vec![(&g0, &p0), (&g1, &p1), (&g2, &p2)];
        let adm = AdmissionGraph::build(&subs, &[0.0, 1e-4, 2e-4], &cfg);
        assert!(adm.verdicts[0].admitted());
        assert_eq!(
            adm.verdicts[1],
            Verdict::Rejected(RejectReason::MemoryGuard)
        );
        assert!(adm.verdicts[2].admitted(), "pipeline must keep running");
        assert_eq!(adm.submission_of, vec![0, 2]);
        // queue_depth = 1 serializes residency: the same workload is
        // fully admitted
        let cfg1 = AdmissionConfig {
            queue_depth: 1,
            memory_limit_bytes: limit,
        };
        let adm1 = AdmissionGraph::build(&subs, &[0.0, 1e-4, 2e-4], &cfg1);
        assert_eq!(adm1.n_admitted(), 3);
    }

    #[test]
    fn zero_length_arrival_queue_is_well_formed() {
        let adm = AdmissionGraph::build(&[], &[], &AdmissionConfig::default());
        assert_eq!(adm.n_submissions(), 0);
        assert_eq!(adm.n_admitted(), 0);
        assert_eq!(adm.batch.node_offset, vec![0]);
        assert_eq!(adm.batch.merged.n_tasks(), 0);
    }

    #[test]
    #[should_panic(expected = "non-decreasing")]
    fn decreasing_arrivals_rejected() {
        let (g0, p0) = workload(200, 48, 8);
        let subs: Vec<(&CsrGraph, &ApspPlan)> = vec![(&g0, &p0), (&g0, &p0)];
        let _ = AdmissionGraph::build(&subs, &[1.0, 0.5], &AdmissionConfig::default());
    }

    #[test]
    fn duplicate_submission_hits_the_store() {
        use crate::apsp::store::MemoryStore;
        use crate::apsp::taskgraph::TaskKind;
        use crate::apsp::trace::Op;
        let (g0, p0) = workload(300, 48, 9);
        let (g1, p1) = workload(250, 48, 10);
        let subs: Vec<(&CsrGraph, &ApspPlan)> =
            vec![(&g0, &p0), (&g1, &p1), (&g0, &p0)];
        let arrivals = [0.0, 1e-3, 2e-3];
        let mut store = MemoryStore::new(8, u64::MAX);
        let (adm, outcomes) = AdmissionGraph::build_with_store(
            &subs,
            &arrivals,
            &AdmissionConfig::default(),
            &mut store,
            true,
        );
        assert_eq!(adm.n_admitted(), 3);
        assert_eq!(outcomes[0], Some(StoreOutcome::MissStored));
        assert_eq!(outcomes[1], Some(StoreOutcome::MissStored));
        assert_eq!(
            outcomes[2],
            Some(StoreOutcome::Hit {
                source: Some(0),
                payload: None
            })
        );
        // the hit's schedule is a single FeNAND read, no lowering
        let hit_tg = &adm.batch.per_graph[2];
        assert_eq!(hit_tg.n_tasks(), 1);
        assert!(matches!(hit_tg.nodes[0].kind, TaskKind::Store { .. }));
        assert!(matches!(hit_tg.nodes[0].ops[..], [Op::StoreRead { .. }]));
        // misses gained a terminal write-back node
        let miss_tg = &adm.batch.per_graph[0];
        let last = miss_tg.nodes.last().unwrap();
        assert!(matches!(last.ops[..], [Op::StoreWrite { .. }]));
        // verdicts are byte-identical to the no-store build
        let plain = AdmissionGraph::build(&subs, &arrivals, &AdmissionConfig::default());
        assert_eq!(adm.verdicts, plain.verdicts);
        assert_eq!(adm.submission_of, plain.submission_of);
    }

    #[test]
    fn disabled_store_yields_all_uncached_misses_and_identical_schedule() {
        use crate::apsp::store::MemoryStore;
        let (g0, p0) = workload(300, 48, 11);
        let subs: Vec<(&CsrGraph, &ApspPlan)> = vec![(&g0, &p0), (&g0, &p0)];
        let arrivals = [0.0, 1e-3];
        let mut store = MemoryStore::new(0, u64::MAX);
        let (adm, outcomes) = AdmissionGraph::build_with_store(
            &subs,
            &arrivals,
            &AdmissionConfig::default(),
            &mut store,
            true,
        );
        assert!(outcomes
            .iter()
            .all(|o| *o == Some(StoreOutcome::MissUncached)));
        // no write-backs, no hit graphs: the schedule matches plain build
        let plain = AdmissionGraph::build(&subs, &arrivals, &AdmissionConfig::default());
        assert_eq!(adm.batch.merged.n_tasks(), plain.batch.merged.n_tasks());
    }

    #[test]
    fn prewarmed_payload_serves_without_a_run_local_producer() {
        use crate::apsp::store::{fingerprint, CompressedMatrix, MemoryStore, StoreEntry};
        use crate::graph::dense::DistMatrix;
        let (g0, p0) = workload(120, 48, 12);
        let d = DistMatrix::new_diag0(g0.n());
        let cm = CompressedMatrix::compress(&d);
        let mut store = MemoryStore::new(8, u64::MAX);
        store
            .put(fingerprint(&g0), StoreEntry::new(64, 1.0, Some(cm.clone())))
            .unwrap();
        let subs: Vec<(&CsrGraph, &ApspPlan)> = vec![(&g0, &p0)];
        let (_, outcomes) = AdmissionGraph::build_with_store(
            &subs,
            &[0.0],
            &AdmissionConfig::default(),
            &mut store,
            true,
        );
        assert_eq!(
            outcomes[0],
            Some(StoreOutcome::Hit {
                source: None,
                payload: Some(cm)
            })
        );
    }
}
