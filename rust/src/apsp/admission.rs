//! Async admission pipeline: admit new graphs into a live batch
//! schedule without draining it.
//!
//! The batch engine ([`super::batch`]) merges a workload known up
//! front; a serving system does not get that luxury — requests arrive
//! while the schedule is running, and draining the machine for every
//! arrival throws away exactly the always-busy property the PIM stack
//! is built around. Admission is cheap here because independent graphs
//! share no edges: admitting one is a lock-scoped graph union — lower
//! the plan into a fresh task/step id namespace
//! ([`super::taskgraph::TaskGraph::append_offset`] via
//! [`BatchGraph::push`]) and splice the new roots into the live ready
//! queue. No barrier, no drain, nothing running is disturbed.
//!
//! [`AdmissionGraph::build`] runs the admission *policy* over an
//! arrival-ordered workload: a bounded queue (at most `queue_depth`
//! graphs in flight) plus deterministic per-graph verdicts — empty
//! graphs, graphs that could never fit the stack's functional-matrix
//! capacity, and graphs that would overflow the aggregate memory guard
//! next to their worst-case co-resident predecessors are rejected
//! cleanly while the pipeline keeps running. Two consumers execute the
//! admitted schedule:
//!
//! * the host executor ([`super::scheduler::execute_admission`])
//!   splices each admitted graph into a long-lived worker pool
//!   ([`crate::util::threads::dag_pool_scope`]) in arrival order, with
//!   per-graph completion callbacks and results **bit-identical** to
//!   solo runs;
//! * the simulator ([`crate::sim::engine::simulate_admission`]) costs
//!   the workload on the shared resource model through the same
//!   bounded queue: each graph enters at `max(arrival, first free
//!   slot)` (arrivals come from config, never wall-clock) and its
//!   admit-to-complete latency — queue wait included — is attributed
//!   alongside the energy partition.

use super::batch::BatchGraph;
use super::plan::ApspPlan;
use super::recursive::projected_bytes;
use super::taskgraph::lower;
use crate::graph::csr::CsrGraph;

/// Admission-control policy of the pipeline.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Max graphs in flight (admitted, not yet complete). The next
    /// arrival waits for a slot; the bound also caps the worst-case
    /// co-resident footprint the aggregate memory guard checks.
    pub queue_depth: usize,
    /// Functional-matrix capacity of one modeled stack. Admission
    /// rejects graphs that would let the in-flight footprint exceed it
    /// under the queue bound; the host executor honors the window by
    /// dropping a graph's intermediate buffers the moment it completes.
    pub memory_limit_bytes: u64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self {
            queue_depth: 4,
            memory_limit_bytes: 12 << 30,
        }
    }
}

/// Why a submission was turned away (the pipeline keeps running).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// 0 vertices: no schedulable work.
    Empty,
    /// The graph alone exceeds the stack's functional-matrix capacity —
    /// it could never be resident, even with the queue to itself.
    StackCapacity,
    /// The graph fits alone, but next to the worst-case set of
    /// co-resident predecessors (the `queue_depth - 1` largest admitted
    /// graphs) it would overflow the aggregate memory guard.
    MemoryGuard,
}

impl RejectReason {
    pub fn name(&self) -> &'static str {
        match self {
            RejectReason::Empty => "empty graph",
            RejectReason::StackCapacity => "exceeds stack capacity",
            RejectReason::MemoryGuard => "trips aggregate memory guard",
        }
    }
}

/// Admission verdict of one submission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Admitted as graph `admitted_index` of the merged schedule.
    Admitted { admitted_index: u32 },
    Rejected(RejectReason),
}

impl Verdict {
    pub fn admitted(&self) -> bool {
        matches!(self, Verdict::Admitted { .. })
    }
}

/// An arrival-stamped workload run through admission control and
/// lowered into one growable merged schedule.
#[derive(Debug, Clone)]
pub struct AdmissionGraph {
    /// Verdict per submission, in arrival order.
    pub verdicts: Vec<Verdict>,
    /// Merged union of the admitted graphs — disjoint task/step id
    /// namespaces, the same invariant [`BatchGraph`] maintains, built
    /// incrementally here ([`BatchGraph::push`]).
    pub batch: BatchGraph,
    /// Submission index of each admitted graph.
    pub submission_of: Vec<usize>,
    /// Modeled arrival time of each admitted graph (seconds on the
    /// simulated timeline, non-decreasing).
    pub arrivals: Vec<f64>,
    /// The in-flight bound the host executor enforces.
    pub queue_depth: usize,
}

impl AdmissionGraph {
    /// Run admission control over an arrival-ordered workload and lower
    /// every admitted graph into the merged schedule.
    ///
    /// Verdicts are deterministic: the aggregate memory guard is
    /// checked against the worst-case co-resident set the queue bound
    /// permits (the `queue_depth - 1` largest previously admitted
    /// graphs), never against execution timing — the same submission
    /// sequence always draws the same verdicts, in functional and
    /// estimate mode alike.
    pub fn build(
        subs: &[(&CsrGraph, &ApspPlan)],
        arrivals: &[f64],
        cfg: &AdmissionConfig,
    ) -> AdmissionGraph {
        assert!(cfg.queue_depth >= 1, "queue_depth must be >= 1");
        assert_eq!(
            subs.len(),
            arrivals.len(),
            "one arrival time per submission"
        );
        assert!(
            arrivals.windows(2).all(|w| w[0] <= w[1]),
            "arrival schedule must be non-decreasing"
        );
        assert!(
            arrivals.iter().all(|a| a.is_finite() && *a >= 0.0),
            "arrival times must be finite and non-negative"
        );
        let mut out = AdmissionGraph {
            verdicts: Vec::with_capacity(subs.len()),
            batch: BatchGraph::default(),
            submission_of: Vec::new(),
            arrivals: Vec::new(),
            queue_depth: cfg.queue_depth,
        };
        // footprints of the already-admitted graphs, for the
        // worst-case co-resident sum
        let mut admitted_bytes: Vec<u64> = Vec::new();
        for (si, &(g, plan)) in subs.iter().enumerate() {
            let verdict = if g.n() == 0 {
                Verdict::Rejected(RejectReason::Empty)
            } else {
                let need = projected_bytes(plan, g);
                let resident = worst_case_resident(&admitted_bytes, cfg.queue_depth);
                if need > cfg.memory_limit_bytes {
                    Verdict::Rejected(RejectReason::StackCapacity)
                } else if need + resident > cfg.memory_limit_bytes {
                    Verdict::Rejected(RejectReason::MemoryGuard)
                } else {
                    let gi = out.batch.push(lower(plan));
                    out.submission_of.push(si);
                    out.arrivals.push(arrivals[si]);
                    admitted_bytes.push(need);
                    Verdict::Admitted { admitted_index: gi }
                }
            };
            out.verdicts.push(verdict);
        }
        debug_assert!(
            out.batch.merged.validate().is_ok(),
            "{:?}",
            out.batch.merged.validate()
        );
        out
    }

    pub fn n_submissions(&self) -> usize {
        self.verdicts.len()
    }

    pub fn n_admitted(&self) -> usize {
        self.batch.n_graphs()
    }

    pub fn n_rejected(&self) -> usize {
        self.n_submissions() - self.n_admitted()
    }
}

/// Worst-case footprint co-resident with a new admission: the
/// `queue_depth - 1` largest already-admitted graphs. The queue bound
/// guarantees no more than that many predecessors can still be in
/// flight; *which* ones is timing-dependent, so the guard takes the
/// largest — sound for every execution, and deterministic.
fn worst_case_resident(admitted_bytes: &[u64], queue_depth: usize) -> u64 {
    let mut v = admitted_bytes.to_vec();
    v.sort_unstable_by(|a, b| b.cmp(a));
    v.iter().take(queue_depth.saturating_sub(1)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::plan::{build_plan, PlanOptions};
    use crate::graph::generators::{self, Topology, Weights};

    fn workload(n: usize, tile: usize, seed: u64) -> (CsrGraph, ApspPlan) {
        let g = generators::generate(Topology::Nws, n, 10.0, Weights::Uniform(1.0, 5.0), seed);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: tile,
                max_depth: usize::MAX,
                seed,
            },
        );
        (g, plan)
    }

    #[test]
    fn admits_everything_under_a_loose_guard() {
        let ws: Vec<_> = (0..3).map(|i| workload(300 + 50 * i, 48, i as u64)).collect();
        let subs: Vec<(&CsrGraph, &ApspPlan)> = ws.iter().map(|(g, p)| (g, p)).collect();
        let arrivals = [0.0, 1e-3, 2e-3];
        let adm = AdmissionGraph::build(&subs, &arrivals, &AdmissionConfig::default());
        assert_eq!(adm.n_submissions(), 3);
        assert_eq!(adm.n_admitted(), 3);
        assert_eq!(adm.n_rejected(), 0);
        assert_eq!(adm.submission_of, vec![0, 1, 2]);
        assert_eq!(adm.arrivals, arrivals);
        assert!(adm.verdicts.iter().all(|v| v.admitted()));
        // the merged schedule is the batch union of the admitted solos
        let solos: Vec<_> = ws
            .iter()
            .map(|(_, p)| crate::apsp::taskgraph::lower(p))
            .collect();
        let batch = BatchGraph::merge(solos);
        assert_eq!(adm.batch.merged.n_tasks(), batch.merged.n_tasks());
        assert_eq!(adm.batch.node_offset, batch.node_offset);
    }

    #[test]
    fn empty_graph_rejected_pipeline_continues() {
        let (g0, p0) = workload(300, 48, 1);
        let empty = CsrGraph::from_edges(0, &[]);
        let pe = build_plan(&empty, PlanOptions::default());
        let (g2, p2) = workload(250, 48, 2);
        let subs: Vec<(&CsrGraph, &ApspPlan)> = vec![(&g0, &p0), (&empty, &pe), (&g2, &p2)];
        let adm = AdmissionGraph::build(&subs, &[0.0, 0.0, 0.0], &AdmissionConfig::default());
        assert_eq!(adm.verdicts[1], Verdict::Rejected(RejectReason::Empty));
        assert_eq!(adm.n_admitted(), 2);
        assert_eq!(adm.submission_of, vec![0, 2]);
    }

    #[test]
    fn oversized_graph_rejected_as_stack_capacity() {
        let (g0, p0) = workload(300, 48, 3);
        let (g1, p1) = workload(600, 48, 4);
        let subs: Vec<(&CsrGraph, &ApspPlan)> = vec![(&g0, &p0), (&g1, &p1)];
        // limit below the second graph's solo footprint: it can never
        // be resident, even with the queue to itself
        let limit = projected_bytes(&p1, &g1) - 1;
        assert!(projected_bytes(&p0, &g0) <= limit);
        let cfg = AdmissionConfig {
            queue_depth: 4,
            memory_limit_bytes: limit,
        };
        let adm = AdmissionGraph::build(&subs, &[0.0, 1e-3], &cfg);
        assert!(adm.verdicts[0].admitted());
        assert_eq!(
            adm.verdicts[1],
            Verdict::Rejected(RejectReason::StackCapacity)
        );
        assert_eq!(adm.n_admitted(), 1);
    }

    #[test]
    fn aggregate_guard_rejects_but_pipeline_keeps_running() {
        // each graph fits the limit alone; two co-resident do not. With
        // queue_depth = 2 the second submission trips the aggregate
        // guard; a later, smaller graph is still admitted.
        let (g0, p0) = workload(500, 64, 5);
        let (g1, p1) = workload(500, 64, 6);
        let (g2, p2) = workload(120, 64, 7);
        let b0 = projected_bytes(&p0, &g0);
        let b1 = projected_bytes(&p1, &g1);
        let b2 = projected_bytes(&p2, &g2);
        let limit = b0.max(b1) + b2 + 1;
        assert!(b0 + b1 > limit, "workload must exceed the paired limit");
        let cfg = AdmissionConfig {
            queue_depth: 2,
            memory_limit_bytes: limit,
        };
        let subs: Vec<(&CsrGraph, &ApspPlan)> = vec![(&g0, &p0), (&g1, &p1), (&g2, &p2)];
        let adm = AdmissionGraph::build(&subs, &[0.0, 1e-4, 2e-4], &cfg);
        assert!(adm.verdicts[0].admitted());
        assert_eq!(
            adm.verdicts[1],
            Verdict::Rejected(RejectReason::MemoryGuard)
        );
        assert!(adm.verdicts[2].admitted(), "pipeline must keep running");
        assert_eq!(adm.submission_of, vec![0, 2]);
        // queue_depth = 1 serializes residency: the same workload is
        // fully admitted
        let cfg1 = AdmissionConfig {
            queue_depth: 1,
            memory_limit_bytes: limit,
        };
        let adm1 = AdmissionGraph::build(&subs, &[0.0, 1e-4, 2e-4], &cfg1);
        assert_eq!(adm1.n_admitted(), 3);
    }

    #[test]
    fn zero_length_arrival_queue_is_well_formed() {
        let adm = AdmissionGraph::build(&[], &[], &AdmissionConfig::default());
        assert_eq!(adm.n_submissions(), 0);
        assert_eq!(adm.n_admitted(), 0);
        assert_eq!(adm.batch.node_offset, vec![0]);
        assert_eq!(adm.batch.merged.n_tasks(), 0);
    }

    #[test]
    #[should_panic(expected = "non-decreasing")]
    fn decreasing_arrivals_rejected() {
        let (g0, p0) = workload(200, 48, 8);
        let subs: Vec<(&CsrGraph, &ApspPlan)> = vec![(&g0, &p0), (&g0, &p0)];
        let _ = AdmissionGraph::build(&subs, &[1.0, 0.5], &AdmissionConfig::default());
    }
}
