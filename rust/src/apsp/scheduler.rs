//! Dependency-aware host executor: runs the tile-task DAG
//! ([`super::taskgraph`]) concurrently against any [`TileBackend`] with
//! work-stealing workers ([`crate::util::threads::par_dag`]).
//!
//! Unlike the legacy barrier walk ([`super::recursive::solve`]), which
//! joins every phase before starting the next, this executor starts a
//! task the moment its true data dependencies are done — a straggler
//! component's FW no longer holds up the boundary solve it never feeds
//! (a disconnected component overlaps the *entire* sub-recursion), and
//! load/FW chains of independent components pipeline freely.
//!
//! Results are **bit-identical** to the barrier walk: every task runs
//! the same kernel on the same inputs in the same rounding order, only
//! the schedule differs. Buffer safety follows the graph — each matrix
//! slot has exactly one writer task at a time, and every reader is
//! ordered behind that writer by a dependency path (documented per
//! access below).

use super::admission::{AdmissionGraph, StoreOutcome};
use super::backend::{fw_any, TileBackend};
use super::batch::BatchGraph;
use super::delta::DeltaState;
use super::plan::ApspPlan;
use super::semiring::SemiringId;
use super::shard::ShardGraph;
use super::recursive::{
    batch_uses_serial_kernel, check_memory_guard, fill_block_from_boundary,
    fill_block_from_graph, materialize_partitioned, projected_bytes, vert_locations,
    ApspSolution, LevelSolution, SolveOptions,
};
use super::taskgraph::{lower, lower_repair, RepairSpec, TaskGraph, TaskKind};
use super::trace::Trace;
use crate::apsp::floyd_warshall;
use crate::graph::csr::CsrGraph;
use crate::graph::dense::DistMatrix;
use crate::util::arena;
use crate::util::threads;
use std::cell::UnsafeCell;
use std::sync::Arc;

/// One exclusively-owned matrix buffer. Ownership transfers along task
/// edges; the graph guarantees a single writer at a time.
struct Slot(UnsafeCell<Option<DistMatrix>>);

impl Slot {
    fn new() -> Self {
        Slot(UnsafeCell::new(None))
    }

    /// SAFETY: caller must be the slot's current owner task (no
    /// concurrent reader or writer — enforced by the task graph).
    #[allow(clippy::mut_from_ref)]
    unsafe fn put(&self, v: DistMatrix) {
        *self.0.get() = Some(v);
    }

    /// SAFETY: a writer task that the caller transitively depends on
    /// must have filled the slot, and no concurrent writer may exist.
    unsafe fn get(&self) -> &DistMatrix {
        (*self.0.get()).as_ref().expect("slot not yet filled")
    }

    /// SAFETY: as [`Slot::get`], plus no concurrent *reader*.
    #[allow(clippy::mut_from_ref)]
    unsafe fn get_mut(&self) -> &mut DistMatrix {
        (*self.0.get()).as_mut().expect("slot not yet filled")
    }

    fn take(&mut self) -> Option<DistMatrix> {
        self.0.get_mut().take()
    }
}

/// All matrix state of one DAG run.
struct Slots {
    /// `d[level][comp]`: the component block (written by Load, advanced
    /// in place by LocalFw → Inject → RerunFw).
    d: Vec<Vec<Slot>>,
    /// `db[level]`: the dB injected into `level` (written by the
    /// sub-level's CrossMerge task).
    db: Vec<Slot>,
    /// Terminal dense solve result.
    terminal: Slot,
}

// SAFETY: interior mutability is disciplined by the task graph — see
// the per-access SAFETY notes in `run_task`.
unsafe impl Sync for Slots {}

impl Slots {
    fn new(plan: &ApspPlan) -> Self {
        Slots {
            d: plan
                .levels
                .iter()
                .map(|l| (0..l.n_components()).map(|_| Slot::new()).collect())
                .collect(),
            db: (0..plan.depth()).map(|_| Slot::new()).collect(),
            terminal: Slot::new(),
        }
    }

    /// Drop every buffer the final solution will not keep: the deeper
    /// levels' component blocks and dBs, and (for a partitioned solve)
    /// the terminal matrix, which CrossMerge already copied into the
    /// last dB. The admission pipeline calls this the moment a graph
    /// completes, so a finished graph's working set leaves the bounded
    /// queue window instead of lingering until the run ends (on the
    /// modeled stack the same bytes leave PCM/HBM for FeNAND at the
    /// Store phase).
    ///
    /// SAFETY: caller must be the unique accessor — every task of the
    /// owning graph has finished, and `assemble` (which only reads
    /// level 0, `db[0]`, and — for direct solves — the terminal) has
    /// not run yet.
    unsafe fn release_intermediate(&self) {
        // released buffers go back to the tile arena (joining this
        // worker's pool), so the next admitted graph's Loads lease them
        // instead of hitting the allocator
        let mut drop_slot = |s: &Slot| {
            if let Some(m) = (*s.0.get()).take() {
                arena::recycle(m.into_vec());
            }
        };
        for lvl in self.d.iter().skip(1) {
            for s in lvl {
                drop_slot(s);
            }
        }
        for s in self.db.iter().skip(1) {
            drop_slot(s);
        }
        if !self.db.is_empty() {
            // partitioned solve: the solution keeps db[0], not the
            // terminal (depth-0 direct solves keep the terminal)
            drop_slot(&self.terminal);
        }
    }
}

/// Lower `plan` and execute it with the dependency-aware scheduler.
pub fn solve_dag<'p>(
    g: &CsrGraph,
    plan: &'p ApspPlan,
    backend: &dyn TileBackend,
    opts: SolveOptions,
) -> ApspSolution<'p> {
    let tg = lower(plan);
    execute(g, plan, &tg, backend, opts)
}

/// Execute an already-lowered task graph (the coordinator lowers once
/// and shares the graph with the simulator).
pub fn execute<'p>(
    g: &CsrGraph,
    plan: &'p ApspPlan,
    tg: &TaskGraph,
    backend: &dyn TileBackend,
    opts: SolveOptions,
) -> ApspSolution<'p> {
    check_memory_guard(plan, g, &opts);
    size_arena_for(plan_tile_census(plan));
    let mut slots = Slots::new(plan);
    let (local_serial, rerun_serial) = kernel_choices(plan, backend);

    {
        let slots = &slots;
        let deps = tg.dep_lists();
        threads::par_dag(&deps, |ti| {
            run_task(
                &tg.nodes[ti].kind,
                g,
                plan,
                backend,
                slots,
                &local_serial,
                &rerun_serial,
            )
        });
    }

    assemble(g, plan, tg.to_trace(), &mut slots, backend.semiring())
}

/// Execute a merged batch of independent graphs ([`BatchGraph`]) with
/// one work-stealing worker pool over the union DAG. Each graph owns a
/// private slot namespace, so the interleaved execution is isolated per
/// graph and every returned solution is **bit-identical** to a solo
/// [`execute`] of that graph (same kernels, same inputs, same rounding
/// order — only the schedule differs).
pub fn execute_batch<'p>(
    graphs: &[(&CsrGraph, &'p ApspPlan)],
    batch: &BatchGraph,
    backend: &dyn TileBackend,
    opts: SolveOptions,
) -> Vec<ApspSolution<'p>> {
    assert_eq!(
        graphs.len(),
        batch.n_graphs(),
        "batch graph count mismatch"
    );
    // every graph's slots are resident concurrently, so the memory
    // guard applies to the batch's aggregate footprint
    let need: u64 = graphs
        .iter()
        .map(|&(g, plan)| projected_bytes(plan, g))
        .sum();
    assert!(
        need <= opts.memory_limit_bytes,
        "functional solve needs ~{need} bytes of matrices across the {}-graph batch \
         (> limit {}); use estimate mode or a smaller batch",
        graphs.len(),
        opts.memory_limit_bytes
    );
    size_arena_for(graphs.iter().map(|&(_, p)| plan_tile_census(p)).sum());
    let mut slots: Vec<Slots> = graphs.iter().map(|&(_, plan)| Slots::new(plan)).collect();
    let choices: Vec<(Vec<bool>, Vec<bool>)> = graphs
        .iter()
        .map(|&(_, plan)| kernel_choices(plan, backend))
        .collect();

    {
        let slots = &slots;
        let deps = batch.merged.dep_lists();
        threads::par_dag(&deps, |ti| {
            let gi = batch.owner[ti] as usize;
            let (g, plan) = graphs[gi];
            let (local_serial, rerun_serial) = &choices[gi];
            run_task(
                &batch.merged.nodes[ti].kind,
                g,
                plan,
                backend,
                &slots[gi],
                local_serial,
                rerun_serial,
            )
        });
    }

    graphs
        .iter()
        .zip(slots.iter_mut())
        .zip(&batch.per_graph)
        .map(|((&(g, plan), s), tg)| assemble(g, plan, tg.to_trace(), s, backend.semiring()))
        .collect()
}

/// Execute an admission workload ([`AdmissionGraph`]) with one
/// long-lived work-stealing pool ([`threads::dag_pool_scope`]): the
/// admitted graphs are spliced into the live ready queue in arrival
/// order — tasks of earlier graphs keep running across every admission
/// (no drain, no barrier) — with at most `queue_depth` graphs in
/// flight. `on_complete(submission_index)` fires from a worker thread
/// the moment a graph's last task retires.
///
/// Host execution follows admission *order* and the queue bound, never
/// wall-clock arrival times — the modeled arrival timeline lives in the
/// simulator ([`crate::sim::engine::simulate_admission`]).
///
/// Returns one entry per submission: `Some(solution)` for admitted
/// graphs — each **bit-identical** to a solo [`execute`] run, because
/// per-graph slot namespaces isolate the numerics exactly as in
/// [`execute_batch`] — and `None` for rejected ones. The memory guard
/// was enforced at admission time ([`AdmissionGraph::build`]) against
/// the queue window, and the executor honors that window: a completed
/// graph's intermediate buffers are dropped on its last task (only the
/// level-0 result blocks the caller receives accumulate, mirroring the
/// modeled stack where finished results leave PCM/HBM for FeNAND).
pub fn execute_admission<'p>(
    subs: &[(&CsrGraph, &'p ApspPlan)],
    adm: &AdmissionGraph,
    backend: &dyn TileBackend,
    on_complete: impl Fn(usize) + Sync,
) -> Vec<Option<ApspSolution<'p>>> {
    let no_store: Vec<Option<StoreOutcome>> = subs.iter().map(|_| None).collect();
    execute_admission_stored(subs, adm, &no_store, backend, on_complete)
}

/// [`execute_admission`] with result-store outcomes
/// ([`AdmissionGraph::build_with_store`]): a submission whose verdict is
/// a store *hit* carries a degenerate one-task graph (the modeled FeNAND
/// read), so no numerics run for it — its solution is served after the
/// pool drains, either from the run-local producer's solution
/// (`Hit { source: Some(gi), .. }`, materialized once and shared across
/// all hits of the same fingerprint) or from a pre-warmed compressed
/// payload (`Hit { payload: Some(..), .. }`, decompressed bit-exactly).
/// Either way the served matrix is **bit-identical** to a fresh solo
/// solve of the same graph, because the producer itself is bit-identical
/// to solo and the store codec is lossless. `outcomes` is indexed by
/// submission (as returned by `build_with_store`); all-`None` outcomes
/// reproduce [`execute_admission`] exactly.
pub fn execute_admission_stored<'p>(
    subs: &[(&CsrGraph, &'p ApspPlan)],
    adm: &AdmissionGraph,
    outcomes: &[Option<StoreOutcome>],
    backend: &dyn TileBackend,
    on_complete: impl Fn(usize) + Sync,
) -> Vec<Option<ApspSolution<'p>>> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    assert_eq!(
        subs.len(),
        outcomes.len(),
        "store outcome count mismatch"
    );
    assert_eq!(
        subs.len(),
        adm.n_submissions(),
        "admission graph count mismatch"
    );
    let batch = &adm.batch;
    size_arena_for(
        adm.submission_of
            .iter()
            .map(|&si| plan_tile_census(subs[si].1))
            .sum(),
    );
    let mut slots: Vec<Slots> = adm
        .submission_of
        .iter()
        .map(|&si| Slots::new(subs[si].1))
        .collect();
    let choices: Vec<(Vec<bool>, Vec<bool>)> = adm
        .submission_of
        .iter()
        .map(|&si| kernel_choices(subs[si].1, backend))
        .collect();
    // per-graph outstanding-task counters: the worker that retires a
    // graph's last task frees its queue slot and fires the callback
    let remaining: Vec<AtomicUsize> = batch
        .per_graph
        .iter()
        .map(|tg| AtomicUsize::new(tg.n_tasks()))
        .collect();
    let in_flight = AtomicUsize::new(0);

    {
        let slots = &slots;
        let choices = &choices;
        let remaining = &remaining;
        let in_flight = &in_flight;
        let on_complete = &on_complete;
        threads::dag_pool_scope(
            threads::num_threads(),
            |ti| {
                let gi = batch.owner[ti] as usize;
                let (g, plan) = subs[adm.submission_of[gi]];
                let (local_serial, rerun_serial) = &choices[gi];
                run_task(
                    &batch.merged.nodes[ti].kind,
                    g,
                    plan,
                    backend,
                    &slots[gi],
                    local_serial,
                    rerun_serial,
                );
                if remaining[gi].fetch_sub(1, Ordering::AcqRel) == 1 {
                    // every task of this graph is done, so this worker
                    // is the unique accessor of its slots: drop what
                    // the solution won't keep before freeing the queue
                    // slot — a completed graph's working set leaves the
                    // bounded in-flight window.
                    // SAFETY: see `Slots::release_intermediate`.
                    unsafe { slots[gi].release_intermediate() };
                    in_flight.fetch_sub(1, Ordering::AcqRel);
                    on_complete(adm.submission_of[gi]);
                }
            },
            |pool| {
                for gi in 0..batch.n_graphs() {
                    // bounded admission queue: wait for a free slot
                    // (woken on every task completion)
                    pool.wait(|_| in_flight.load(Ordering::Acquire) < adm.queue_depth);
                    in_flight.fetch_add(1, Ordering::AcqRel);
                    // lock-scoped graph union: splice this graph's DAG
                    // into the live ready queue in its own id namespace
                    let off = batch.node_offset[gi];
                    let deps: Vec<Vec<u32>> = batch.per_graph[gi]
                        .nodes
                        .iter()
                        .map(|n| n.deps.iter().map(|&d| d + off).collect())
                        .collect();
                    let range = pool.inject(&deps);
                    debug_assert_eq!(range.start, off as usize);
                }
            },
        );
    }

    let mut out: Vec<Option<ApspSolution<'p>>> = subs.iter().map(|_| None).collect();
    // full matrices materialized on demand for run-local hit serving,
    // computed once per producer graph; `Direct` holds an `Arc`, so all
    // hits of one fingerprint share the single materialization instead
    // of each cloning an n*n matrix
    let mut full_of: Vec<Option<Arc<DistMatrix>>> = (0..batch.n_graphs()).map(|_| None).collect();
    // ascending gi: a hit's run-local producer always has a smaller
    // admitted index (the admission build saw it first), so its
    // solution is already in `out` when the hit is served
    for (gi, s) in slots.iter_mut().enumerate() {
        let si = adm.submission_of[gi];
        let (g, plan) = subs[si];
        let sol = match &outcomes[si] {
            Some(StoreOutcome::Hit { source, payload }) => {
                let full = match (source, payload) {
                    (Some(&src), _) => {
                        let src = src as usize;
                        if full_of[src].is_none() {
                            let src_sol = out[adm.submission_of[src]]
                                .as_ref()
                                .expect("store hit's producer must precede it");
                            full_of[src] = Some(Arc::new(src_sol.materialize_full(backend)));
                        }
                        Arc::clone(full_of[src].as_ref().unwrap())
                    }
                    (None, Some(cm)) => Arc::new(cm.decompress()),
                    (None, None) => {
                        unreachable!("admission never declares an unservable hit")
                    }
                };
                // served hits bypass `assemble` (their one-task graph
                // filled no slots); a full dense matrix is a valid
                // Direct solution at any plan depth
                ApspSolution {
                    plan,
                    trace: batch.per_graph[gi].to_trace(),
                    top: Some(LevelSolution::Direct(full)),
                    vert_loc: vert_locations(plan, g),
                    sr: backend.semiring(),
                }
            }
            _ => assemble(g, plan, batch.per_graph[gi].to_trace(), s, backend.semiring()),
        };
        out[si] = Some(sol);
    }
    out
}

/// Execute a sharded task graph ([`ShardGraph`]) with **per-stack
/// worker pools** ([`threads::par_dag_grouped`]): every task runs on a
/// worker pinned to its stack affinity, modeling each stack's own host
/// executor, while dependency edges (including the spliced `StackXfer`
/// nodes) cross pools freely.
///
/// Slot namespaces are per-stack by construction: each stack owns
/// exactly the `d[0][c]` slots of its assigned components, and the hub
/// stack owns everything else (deeper levels, `db`, the terminal).
/// `StackXfer` nodes carry no host numerics — they only order the
/// cross-stack reads the simulator charges — so the solution is
/// **bit-identical** to a solo [`execute`] run (same kernels, same
/// inputs, same rounding order; asserted `max_diff == 0.0` in the
/// integration tests for every tested stack count).
pub fn execute_sharded<'p>(
    g: &CsrGraph,
    plan: &'p ApspPlan,
    shard: &ShardGraph,
    backend: &dyn TileBackend,
    opts: SolveOptions,
) -> ApspSolution<'p> {
    check_memory_guard(plan, g, &opts);
    size_arena_for(plan_tile_census(plan));
    let mut slots = Slots::new(plan);
    let (local_serial, rerun_serial) = kernel_choices(plan, backend);

    {
        let slots = &slots;
        let deps = shard.sharded.dep_lists();
        threads::par_dag_grouped(&deps, &shard.affinity, shard.num_stacks, |ti| {
            run_task(
                &shard.sharded.nodes[ti].kind,
                g,
                plan,
                backend,
                slots,
                &local_serial,
                &rerun_serial,
            )
        });
    }

    // the reported trace is the solo lowering's — sharding changes the
    // schedule and adds transfers, not the algorithmic work
    assemble(g, plan, shard.solo.to_trace(), &mut slots, backend.semiring())
}

/// Per-component snapshot slots used by the retained-solve paths.
///
/// SAFETY: each slot has exactly one writer — the component's Inject
/// task, which owns the component's matrix at that point — and no
/// reader until the worker pool has drained.
struct SnapSlots(Vec<Slot>);

unsafe impl Sync for SnapSlots {}

impl SnapSlots {
    fn new(k: usize) -> Self {
        SnapSlots((0..k).map(|_| Slot::new()).collect())
    }
}

/// [`solve_dag`] that additionally retains the numeric state a later
/// delta repair needs ([`DeltaState`]): refcounted level-0 blocks, the
/// level-0 dB, and — snapshotted at Inject time, the only moment it
/// exists — each boundary component's *pre-injection* matrix, which is
/// exactly the input a repair re-injects a refreshed dB into.
///
/// The solution (viewed via [`DeltaState::as_solution`]) is
/// bit-identical to [`solve_dag`]: the snapshot is a clone taken by the
/// Inject task before it relaxes the block in place, so no kernel sees
/// different inputs.
pub fn solve_dag_retained(
    g: &CsrGraph,
    plan: &ApspPlan,
    backend: &dyn TileBackend,
    opts: SolveOptions,
) -> (Trace, DeltaState) {
    check_memory_guard(plan, g, &opts);
    size_arena_for(plan_tile_census(plan));
    let tg = lower(plan);
    let mut slots = Slots::new(plan);
    let (local_serial, rerun_serial) = kernel_choices(plan, backend);
    let k0 = if plan.depth() == 0 {
        0
    } else {
        plan.levels[0].n_components()
    };
    let mut pre_snap = SnapSlots::new(k0);

    {
        let slots = &slots;
        let pre_snap = &pre_snap;
        let deps = tg.dep_lists();
        threads::par_dag(&deps, |ti| {
            let kind = &tg.nodes[ti].kind;
            if let TaskKind::Inject { level: 0, comp } = *kind {
                // SAFETY (read): the Inject task owns this block (its
                // LocalFw chain is done, no other writer is live); the
                // snapshot is taken before the in-place relax below.
                let pre = unsafe { slots.d[0][comp as usize].get() }.clone();
                // SAFETY (write): sole writer of this snapshot slot.
                unsafe { pre_snap.0[comp as usize].put(pre) };
            }
            run_task(kind, g, plan, backend, slots, &local_serial, &rerun_serial)
        });
    }

    (tg.to_trace(), retain_state(plan, &mut slots, &mut pre_snap))
}

/// Assemble a [`DeltaState`] out of a finished retained run's slots.
fn retain_state(plan: &ApspPlan, slots: &mut Slots, pre_snap: &mut SnapSlots) -> DeltaState {
    if plan.depth() == 0 {
        let direct = Arc::new(
            slots
                .terminal
                .take()
                .unwrap_or_else(|| DistMatrix::new_inf(0)),
        );
        return DeltaState {
            comp_dist: Vec::new(),
            pre_inj: Vec::new(),
            db: Arc::new(DistMatrix::new_inf(0)),
            direct: Some(direct),
        };
    }
    let comp_dist: Vec<Arc<DistMatrix>> = slots.d[0]
        .iter_mut()
        .map(|s| Arc::new(s.take().expect("level-0 component never filled")))
        .collect();
    // components that were never injected (zero boundary) share the
    // post-solve allocation: pre- and post-injection states coincide
    let pre_inj: Vec<Arc<DistMatrix>> = pre_snap
        .0
        .iter_mut()
        .zip(&comp_dist)
        .map(|(s, post)| match s.take() {
            Some(pre) => Arc::new(pre),
            None => Arc::clone(post),
        })
        .collect();
    let db = Arc::new(
        slots.db[0]
            .take()
            .unwrap_or_else(|| DistMatrix::new_inf(0)),
    );
    DeltaState {
        comp_dist,
        pre_inj,
        db,
        direct: None,
    }
}

/// `true` iff the `b x b` diagonal block at `gs` is bit-equal between
/// the two dB matrices (INF == INF; the solver produces no NaNs).
fn db_block_unchanged(old: &DistMatrix, new: &DistMatrix, gs: usize, b: usize) -> bool {
    if old.n() != new.n() {
        return false;
    }
    for i in 0..b {
        let or = &old.row(gs + i)[gs..gs + b];
        let nr = &new.row(gs + i)[gs..gs + b];
        if or
            .iter()
            .zip(nr)
            .any(|(a, z)| a.to_bits() != z.to_bits())
        {
            return false;
        }
    }
    true
}

/// Execute a repair sub-DAG ([`lower_repair`]) against the retained
/// state of the pre-delta solve: dirty tiles are reloaded from `g_new`
/// and re-solved, the boundary recursion (when dirty) re-runs with
/// clean tiles' *pre-injection* blocks served from `state` by `Arc`
/// without copying, and every untouched tile flows into the returned
/// state as a refcounted handle of the old one.
///
/// On the improve path (`allow_skip`, inserts + weight decreases) a
/// clean boundary tile whose refreshed dB diagonal block comes back
/// bit-unchanged skips its Inject + RerunFw entirely — determinism
/// guarantees the rerun would reproduce the retained block bit-for-bit
/// (same kernel, same pre-injection input, same dB block). Deletes and
/// weight increases must not skip: an unchanged diagonal block does not
/// prove unchanged *off*-diagonal paths through other tiles, so the
/// conservative closure re-solves every boundary tile.
///
/// Every tile the repair does compute runs the *same* kernel with the
/// same inputs in the same rounding order as a fresh [`solve_dag`] on
/// `(g_new, plan)` — kernel choices come from the full plan, not the
/// repair subset — so the returned state is bit-identical to a fresh
/// full solve (asserted in tests and on the CLI path).
///
/// Returns the repaired state plus the *actual* repair spec: `spec`
/// with the skipped tiles' rerun flags cleared, which re-lowers into
/// the sub-DAG the simulator attributes.
pub fn execute_delta(
    g_new: &CsrGraph,
    plan: &ApspPlan,
    spec: &RepairSpec,
    state: &DeltaState,
    allow_skip: bool,
    backend: &dyn TileBackend,
    opts: SolveOptions,
) -> (DeltaState, RepairSpec) {
    use std::sync::atomic::{AtomicBool, Ordering};
    check_memory_guard(plan, g_new, &opts);
    size_arena_for(plan_tile_census(plan));
    let tg = lower_repair(plan, spec);
    let mut slots = Slots::new(plan);
    let (local_serial, rerun_serial) = kernel_choices(plan, backend);
    let k0 = if plan.depth() == 0 {
        0
    } else {
        plan.levels[0].n_components()
    };
    let mut pre_snap = SnapSlots::new(k0);
    let skipped: Vec<AtomicBool> = (0..k0).map(|_| AtomicBool::new(false)).collect();

    {
        let slots = &slots;
        let pre_snap = &pre_snap;
        let skipped = &skipped;
        // serve a level-0 block to a boundary fill: dirty tiles from
        // the repair slots, clean tiles from the retained state
        // (pre-injection — exactly what a fresh solve's fill would see)
        let deps = tg.dep_lists();
        threads::par_dag(&deps, |ti| {
            let kind = &tg.nodes[ti].kind;
            match *kind {
                TaskKind::Load { level: 1, comp } => {
                    let lvl = &plan.levels[1];
                    let c = &lvl.cs.components[comp as usize];
                    let prev = &plan.levels[0];
                    // SAFETY (read): dirty tiles' LocalFw precedes
                    // BoundaryBuild(0), which precedes this Load; their
                    // next writer, Inject(0), is behind CrossMerge(1).
                    let block = fill_block_from_boundary(
                        &prev.next_cross,
                        prev,
                        |gi| {
                            if spec.dirty[gi] {
                                unsafe { slots.d[0][gi].get() }
                            } else {
                                state.pre_inj[gi].as_ref()
                            }
                        },
                        &c.verts,
                        &lvl.cs.comp_of,
                        comp,
                        backend.semiring(),
                    );
                    // SAFETY (write): first writer of this slot.
                    unsafe { slots.d[1][comp as usize].put(block) };
                }
                TaskKind::FinalLoad if plan.depth() == 1 => {
                    let n = plan.final_n;
                    let all: Vec<u32> = (0..n as u32).collect();
                    let prev = &plan.levels[0];
                    let comp_of = vec![0u32; n];
                    // SAFETY (read/write): as the Load arm above.
                    let block = fill_block_from_boundary(
                        &prev.next_cross,
                        prev,
                        |gi| {
                            if spec.dirty[gi] {
                                unsafe { slots.d[0][gi].get() }
                            } else {
                                state.pre_inj[gi].as_ref()
                            }
                        },
                        &all,
                        &comp_of,
                        0,
                        backend.semiring(),
                    );
                    unsafe { slots.terminal.put(block) };
                }
                TaskKind::Inject { level: 0, comp } => {
                    let ci = comp as usize;
                    let lvl = &plan.levels[0];
                    let b = lvl.cs.components[ci].n_boundary;
                    let gs = lvl.group_start[ci];
                    // SAFETY (read): db[0] was written by this task's
                    // CrossMerge dependency.
                    let db_new = unsafe { slots.db[0].get() };
                    if spec.dirty[ci] {
                        // freshly re-solved tile: snapshot its
                        // pre-injection state for the next repair
                        // generation, then inject as usual.
                        // SAFETY: as in `solve_dag_retained`.
                        let pre = unsafe { slots.d[0][ci].get() }.clone();
                        unsafe { pre_snap.0[ci].put(pre) };
                    } else {
                        if allow_skip && db_block_unchanged(state.db.as_ref(), db_new, gs, b) {
                            skipped[ci].store(true, Ordering::Release);
                            return;
                        }
                        // clean tile with a changed dB block: stage a
                        // copy of the retained pre-injection matrix and
                        // let the normal inject + rerun run on it.
                        // SAFETY (write): this Inject is the slot's
                        // first toucher in the repair DAG.
                        unsafe { slots.d[0][ci].put(state.pre_inj[ci].as_ref().clone()) };
                    }
                    run_task(kind, g_new, plan, backend, slots, &local_serial, &rerun_serial);
                }
                TaskKind::RerunFw { level: 0, comp } => {
                    if skipped[comp as usize].load(Ordering::Acquire) {
                        return;
                    }
                    run_task(kind, g_new, plan, backend, slots, &local_serial, &rerun_serial);
                }
                _ => run_task(kind, g_new, plan, backend, slots, &local_serial, &rerun_serial),
            }
        });
    }

    let mut comp_dist: Vec<Arc<DistMatrix>> = Vec::with_capacity(k0);
    let mut pre_inj: Vec<Arc<DistMatrix>> = Vec::with_capacity(k0);
    let mut rerun_actual = spec.rerun.clone();
    for ci in 0..k0 {
        if skipped[ci].load(Ordering::Acquire) {
            rerun_actual[ci] = false;
        }
        // a slot is filled exactly for the tiles the repair touched;
        // everything else is served from the old state by refcount
        let post = match slots.d[0][ci].take() {
            Some(m) => Arc::new(m),
            None => Arc::clone(&state.comp_dist[ci]),
        };
        let pre = match pre_snap.0[ci].take() {
            Some(m) => Arc::new(m), // dirty boundary tile: fresh snapshot
            None if spec.dirty[ci] => Arc::clone(&post), // dirty, never injected
            None => Arc::clone(&state.pre_inj[ci]),      // clean: unchanged
        };
        comp_dist.push(post);
        pre_inj.push(pre);
    }
    let db = if spec.boundary_dirty && !slots.db.is_empty() {
        Arc::new(
            slots.db[0]
                .take()
                .unwrap_or_else(|| DistMatrix::new_inf(0)),
        )
    } else {
        Arc::clone(&state.db)
    };
    let direct = if plan.depth() == 0 {
        Some(Arc::new(
            slots
                .terminal
                .take()
                .unwrap_or_else(|| DistMatrix::new_inf(0)),
        ))
    } else {
        None
    };
    (
        DeltaState {
            comp_dist,
            pre_inj,
            db,
            direct,
        },
        RepairSpec {
            dirty: spec.dirty.clone(),
            rerun: rerun_actual,
            boundary_dirty: spec.boundary_dirty,
        },
    )
}

/// Tile-buffer census of one plan's DAG run, in `f32` elements: every
/// matrix slot that can be live at once — the component blocks of every
/// level, each level's dB (the materialization of the level below), and
/// the terminal block. The executor sizes the tile arena's idle-cache
/// cap from this so a whole run's working set can round-trip through
/// the pool, and the kernel property suite bounds the pool's high-water
/// mark with it.
pub fn plan_tile_census(plan: &ApspPlan) -> usize {
    let depth = plan.depth();
    let mut elems = plan.final_n * plan.final_n; // terminal block
    for (l, lvl) in plan.levels.iter().enumerate() {
        for c in &lvl.cs.components {
            elems += c.n() * c.n();
        }
        // db[l] is written by CrossMerge(l+1): the full matrix of level
        // l+1, or a copy of the terminal when l+1 is the deepest level
        elems += if l + 1 < depth {
            plan.levels[l + 1].n * plan.levels[l + 1].n
        } else {
            plan.final_n * plan.final_n
        };
    }
    elems
}

/// Raise the calling thread's arena cache cap to hold a run's census
/// (with 2x slack for merge temporaries). Matters mostly for the
/// `RAPID_THREADS=1` / serial paths where the calling thread's pool is
/// the only pool; worker threads keep the default cap.
fn size_arena_for(census_elems: usize) {
    arena::set_thread_cache_cap(arena::DEFAULT_CACHE_CAP_BYTES.max(8 * census_elems));
}

/// Mirror the barrier walk's per-batch kernel choice (serial rowwise FW
/// vs the backend's own FW) so results stay bit-identical even where
/// the two kernels could differ in rounding. Returns the per-level
/// choices for the LocalFw and RerunFw phases.
fn kernel_choices(plan: &ApspPlan, backend: &dyn TileBackend) -> (Vec<bool>, Vec<bool>) {
    let local_serial: Vec<bool> = plan
        .levels
        .iter()
        .map(|l| batch_uses_serial_kernel(backend, l.n_components()))
        .collect();
    let rerun_serial: Vec<bool> = plan
        .levels
        .iter()
        .map(|l| {
            let reruns = l
                .cs
                .components
                .iter()
                .filter(|c| c.n_boundary > 0 && c.n() > 1)
                .count();
            batch_uses_serial_kernel(backend, reruns)
        })
        .collect();
    (local_serial, rerun_serial)
}

/// Assemble the level-0 solution out of a finished run's slots.
fn assemble<'p>(
    g: &CsrGraph,
    plan: &'p ApspPlan,
    trace: Trace,
    slots: &mut Slots,
    sr: SemiringId,
) -> ApspSolution<'p> {
    let top = if plan.depth() == 0 {
        LevelSolution::Direct(Arc::new(
            slots
                .terminal
                .take()
                .unwrap_or_else(|| DistMatrix::new_inf(0)),
        ))
    } else {
        let comp_dist: Vec<DistMatrix> = slots.d[0]
            .iter_mut()
            .map(|s| s.take().expect("level-0 component never filled"))
            .collect();
        let db = slots.db[0]
            .take()
            .unwrap_or_else(|| DistMatrix::new_inf(0));
        LevelSolution::Partitioned {
            level: 0,
            comp_dist,
            db,
        }
    };
    ApspSolution {
        plan,
        trace,
        top: Some(top),
        vert_loc: vert_locations(plan, g),
        sr,
    }
}

#[allow(clippy::too_many_arguments)]
fn run_task(
    kind: &TaskKind,
    g: &CsrGraph,
    plan: &ApspPlan,
    backend: &dyn TileBackend,
    slots: &Slots,
    local_serial: &[bool],
    rerun_serial: &[bool],
) {
    let depth = plan.depth();
    let sr = backend.semiring();
    match *kind {
        TaskKind::Load { level, comp } => {
            let (l, ci) = (level as usize, comp as usize);
            let lvl = &plan.levels[l];
            let c = &lvl.cs.components[ci];
            let block = if l == 0 {
                fill_block_from_graph(g, &c.verts, &lvl.cs.comp_of, comp, sr)
            } else {
                let prev = &plan.levels[l - 1];
                // SAFETY (read): Load(l, c) is ordered behind
                // BoundaryBuild(l-1), which is behind every boundary
                // component's LocalFw — the only groups this fill
                // reads. The next writer of those slots, Inject(l-1),
                // is ordered behind this task via CrossMerge(l).
                fill_block_from_boundary(
                    &prev.next_cross,
                    prev,
                    |gi| unsafe { slots.d[l - 1][gi].get() },
                    &c.verts,
                    &lvl.cs.comp_of,
                    comp,
                    sr,
                )
            };
            // SAFETY (write): Load is the slot's first writer; every
            // other toucher depends on it.
            unsafe { slots.d[l][ci].put(block) };
        }
        TaskKind::LocalFw { level, comp } => {
            let (l, ci) = (level as usize, comp as usize);
            // SAFETY (write): exclusive — ordered after Load(l, c);
            // all readers depend on this task.
            let d = unsafe { slots.d[l][ci].get_mut() };
            if local_serial[l] {
                floyd_warshall::fw_rowwise_dyn(d, sr);
            } else {
                fw_any(backend, d);
            }
        }
        TaskKind::Inject { level, comp } => {
            let (l, ci) = (level as usize, comp as usize);
            let lvl = &plan.levels[l];
            let b = lvl.cs.components[ci].n_boundary;
            let gs = lvl.group_start[ci];
            // SAFETY (read): db[l] was written by CrossMerge(l+1), a
            // direct dependency; its only writer is done.
            let db = unsafe { slots.db[l].get() };
            // SAFETY (write): exclusive — every pre-injection reader of
            // this block (sub-level Loads, CrossMerge(l+1)) is ordered
            // before this task through the dB chain.
            let dc = unsafe { slots.d[l][ci].get_mut() };
            for i in 0..b {
                for j in 0..b {
                    dc.relax_sr(i, j, db.get(gs + i, gs + j), sr);
                }
            }
        }
        TaskKind::RerunFw { level, comp } => {
            let (l, ci) = (level as usize, comp as usize);
            // SAFETY (write): exclusive — ordered after Inject(l, c);
            // post-injection readers (Sync, CrossMerge(l), the final
            // solution) depend on this task.
            let d = unsafe { slots.d[l][ci].get_mut() };
            if rerun_serial[l] {
                floyd_warshall::fw_rowwise_dyn(d, sr);
            } else {
                fw_any(backend, d);
            }
        }
        TaskKind::FinalLoad => {
            let n = plan.final_n;
            let all: Vec<u32> = (0..n as u32).collect();
            let block = if depth == 0 {
                let comp_of = vec![0u32; g.n()];
                fill_block_from_graph(g, &all, &comp_of, 0, sr)
            } else {
                let prev = &plan.levels[depth - 1];
                let comp_of = vec![0u32; n];
                // SAFETY (read): as the Load arm — ordered behind
                // BoundaryBuild(depth-1).
                fill_block_from_boundary(
                    &prev.next_cross,
                    prev,
                    |gi| unsafe { slots.d[depth - 1][gi].get() },
                    &all,
                    &comp_of,
                    0,
                    sr,
                )
            };
            // SAFETY (write): first writer of the terminal slot.
            unsafe { slots.terminal.put(block) };
        }
        TaskKind::FinalSolve => {
            // SAFETY (write): exclusive — ordered after FinalLoad; all
            // readers (CrossMerge(depth), the final solution) depend on
            // this task.
            let d = unsafe { slots.terminal.get_mut() };
            fw_any(backend, d);
        }
        TaskKind::CrossMerge { level } => {
            let m = level as usize;
            if m == 0 {
                // top-level merges are computed-but-not-persisted on
                // the real hardware (Fig. 4a step 7); numerics for them
                // run on demand in `materialize_full`
                return;
            }
            let out = if m == depth {
                // SAFETY (read): FinalSolve, the terminal's last
                // writer, is a direct dependency.
                unsafe { slots.terminal.get() }.clone()
            } else {
                let empty = DistMatrix::new_inf(0);
                let db_m = if plan.levels[m].n_boundary() > 0 {
                    // SAFETY (read): written by CrossMerge(m+1), a
                    // direct dependency.
                    unsafe { slots.db[m].get() }
                } else {
                    &empty
                };
                // SAFETY (read): every component's final writer at
                // level m is a direct dependency; no later writer
                // exists.
                materialize_partitioned(
                    plan,
                    m,
                    |ci| unsafe { slots.d[m][ci].get() },
                    db_m,
                    backend,
                )
            };
            // SAFETY (write): sole writer of db[m-1]; readers
            // (Inject(m-1, *), CrossMerge(m-1), the final solution)
            // depend on this task.
            unsafe { slots.db[m - 1].put(out) };
        }
        // pure transfer/bookkeeping nodes: no host numerics
        TaskKind::BoundaryBuild { .. }
        | TaskKind::Sync { .. }
        | TaskKind::Store { .. }
        | TaskKind::StackXfer { .. } => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::backend::{NativeBackend, SerialBackend};
    use crate::apsp::plan::{build_plan, PlanOptions};
    use crate::apsp::recursive::solve;
    use crate::graph::generators::{self, Weights};

    fn check_bit_identical(g: &CsrGraph, tile: usize, seed: u64) {
        let plan = build_plan(
            g,
            PlanOptions {
                tile_limit: tile,
                max_depth: usize::MAX,
                seed,
            },
        );
        let be = NativeBackend;
        let barrier = solve(g, &plan, Some(&be), SolveOptions::default());
        let dag = solve_dag(g, &plan, &be, SolveOptions::default());
        assert_eq!(barrier.trace, dag.trace, "traces must be identical");
        // full materializations agree bit-for-bit
        let fb = barrier.materialize_full(&be);
        let fd = dag.materialize_full(&be);
        assert_eq!(fb.max_diff(&fd), 0.0, "schedulers disagree (tile {tile})");
        // per-slot equality (component matrices and dB), not just the
        // merged view
        match (barrier.top().unwrap(), dag.top().unwrap()) {
            (LevelSolution::Direct(a), LevelSolution::Direct(b)) => {
                assert_eq!(a.max_diff(b), 0.0)
            }
            (
                LevelSolution::Partitioned {
                    comp_dist: ca,
                    db: da,
                    ..
                },
                LevelSolution::Partitioned {
                    comp_dist: cb,
                    db: dbb,
                    ..
                },
            ) => {
                assert_eq!(ca.len(), cb.len());
                for (x, y) in ca.iter().zip(cb) {
                    assert_eq!(x.max_diff(y), 0.0);
                }
                assert_eq!(da.max_diff(dbb), 0.0);
            }
            _ => panic!("solution shapes differ between schedulers"),
        }
        // and the dag solution is actually *correct*, not just consistent
        let oracle = crate::apsp::dijkstra::apsp(g);
        assert!(fd.max_diff(&oracle) < 1e-3);
    }

    #[test]
    fn bit_identical_on_nws() {
        let g = generators::newman_watts_strogatz(300, 4, 0.12, Weights::Uniform(1.0, 5.0), 21);
        check_bit_identical(&g, 48, 21);
    }

    #[test]
    fn bit_identical_on_clustered() {
        let g = generators::ogbn_proxy(500, 12.0, Weights::Uniform(1.0, 3.0), 22);
        check_bit_identical(&g, 64, 22);
    }

    #[test]
    fn bit_identical_on_er() {
        let g = generators::erdos_renyi(250, 900, Weights::Uniform(0.5, 4.0), 23);
        check_bit_identical(&g, 40, 23);
    }

    #[test]
    fn bit_identical_with_deep_recursion() {
        // chain of cliques forces depth >= 2 (see recursive.rs test)
        let mut edges: Vec<(u32, u32, f32)> = Vec::new();
        let mut rng = crate::util::rng::Rng::new(24);
        for c in 0..30u32 {
            let base = c * 12;
            for i in 0..12 {
                for j in (i + 1)..12 {
                    edges.push((base + i, base + j, rng.gen_f32_range(1.0, 5.0)));
                }
            }
            if c < 29 {
                edges.push((base + 11, base + 12, rng.gen_f32_range(1.0, 5.0)));
            }
        }
        let g = CsrGraph::from_undirected_edges(360, &edges);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 16,
                max_depth: usize::MAX,
                seed: 24,
            },
        );
        assert!(plan.depth() >= 2);
        check_bit_identical(&g, 16, 24);
    }

    #[test]
    fn bit_identical_on_disconnected_mix() {
        // bridged communities plus an isolated clique (the zero-boundary
        // fast path: its FW overlaps the whole boundary recursion)
        let mut edges: Vec<(u32, u32, f32)> = Vec::new();
        let mut rng = crate::util::rng::Rng::new(25);
        for c in 0..6u32 {
            let base = c * 20;
            for i in 0..20 {
                for j in (i + 1)..20 {
                    edges.push((base + i, base + j, rng.gen_f32_range(1.0, 4.0)));
                }
            }
            if c < 5 {
                edges.push((base + 19, base + 20, 2.0));
            }
        }
        for i in 120..170u32 {
            for j in (i + 1)..170 {
                edges.push((i, j, rng.gen_f32_range(1.0, 2.0)));
            }
        }
        let g = CsrGraph::from_undirected_edges(170, &edges);
        check_bit_identical(&g, 64, 25);
    }

    #[test]
    fn bit_identical_direct_solve() {
        let g = generators::complete(24, Weights::Uniform(1.0, 2.0), 26);
        check_bit_identical(&g, 128, 26);
    }

    #[test]
    fn serial_backend_agrees_too() {
        let g = generators::newman_watts_strogatz(200, 3, 0.1, Weights::Uniform(1.0, 4.0), 27);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 32,
                max_depth: usize::MAX,
                seed: 27,
            },
        );
        let be = SerialBackend;
        let barrier = solve(&g, &plan, Some(&be), SolveOptions::default());
        let dag = solve_dag(&g, &plan, &be, SolveOptions::default());
        assert_eq!(
            barrier
                .materialize_full(&be)
                .max_diff(&dag.materialize_full(&be)),
            0.0
        );
    }

    #[test]
    fn repeated_runs_deterministic() {
        let g = generators::ogbn_proxy(400, 10.0, Weights::Uniform(1.0, 3.0), 28);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 48,
                max_depth: usize::MAX,
                seed: 28,
            },
        );
        let be = NativeBackend;
        let a = solve_dag(&g, &plan, &be, SolveOptions::default());
        let b = solve_dag(&g, &plan, &be, SolveOptions::default());
        assert_eq!(
            a.materialize_full(&be).max_diff(&b.materialize_full(&be)),
            0.0
        );
    }

    #[test]
    fn batch_execution_bit_identical_to_solo() {
        use crate::apsp::batch::BatchGraph;
        // heterogeneous batch: partitioned, clustered, and a
        // single-tile direct solve
        let gs = vec![
            generators::newman_watts_strogatz(300, 4, 0.12, Weights::Uniform(1.0, 5.0), 31),
            generators::ogbn_proxy(400, 10.0, Weights::Uniform(1.0, 3.0), 32),
            generators::complete(24, Weights::Uniform(1.0, 2.0), 33),
        ];
        let plans: Vec<_> = gs
            .iter()
            .map(|g| {
                build_plan(
                    g,
                    PlanOptions {
                        tile_limit: 48,
                        max_depth: usize::MAX,
                        seed: 31,
                    },
                )
            })
            .collect();
        let batch = BatchGraph::build(&plans.iter().collect::<Vec<_>>());
        let pairs: Vec<(&CsrGraph, &ApspPlan)> = gs.iter().zip(&plans).collect();
        let be = NativeBackend;
        let sols = execute_batch(&pairs, &batch, &be, SolveOptions::default());
        assert_eq!(sols.len(), gs.len());
        for (i, sol) in sols.iter().enumerate() {
            let solo = solve_dag(&gs[i], &plans[i], &be, SolveOptions::default());
            assert_eq!(solo.trace, sol.trace, "graph {i}: traces differ");
            let diff = solo
                .materialize_full(&be)
                .max_diff(&sol.materialize_full(&be));
            assert_eq!(diff, 0.0, "graph {i}: batch differs from solo");
        }
    }

    #[test]
    fn admission_execution_bit_identical_to_solo() {
        use crate::apsp::admission::{AdmissionConfig, AdmissionGraph};
        use std::sync::atomic::{AtomicUsize, Ordering};
        let gs = vec![
            generators::newman_watts_strogatz(300, 4, 0.12, Weights::Uniform(1.0, 5.0), 51),
            generators::ogbn_proxy(400, 10.0, Weights::Uniform(1.0, 3.0), 52),
            generators::complete(24, Weights::Uniform(1.0, 2.0), 53),
        ];
        let plans: Vec<ApspPlan> = gs
            .iter()
            .map(|g| {
                build_plan(
                    g,
                    PlanOptions {
                        tile_limit: 48,
                        max_depth: usize::MAX,
                        seed: 51,
                    },
                )
            })
            .collect();
        let subs: Vec<(&CsrGraph, &ApspPlan)> = gs.iter().zip(&plans).collect();
        let be = NativeBackend;
        // queue depth 1 forces strictly serial admission: every graph
        // is spliced into a fully drained (parked) pool
        for queue_depth in [1usize, 2, 8] {
            let cfg = AdmissionConfig {
                queue_depth,
                ..AdmissionConfig::default()
            };
            let adm = AdmissionGraph::build(&subs, &[0.0, 1e-4, 2e-4], &cfg);
            assert_eq!(adm.n_admitted(), 3);
            let completions = AtomicUsize::new(0);
            let sols = execute_admission(&subs, &adm, &be, |_| {
                completions.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(completions.load(Ordering::SeqCst), 3);
            for (i, sol) in sols.iter().enumerate() {
                let sol = sol.as_ref().expect("admitted graph must produce a solution");
                let solo = solve_dag(&gs[i], &plans[i], &be, SolveOptions::default());
                assert_eq!(solo.trace, sol.trace, "graph {i}: traces differ");
                let diff = solo
                    .materialize_full(&be)
                    .max_diff(&sol.materialize_full(&be));
                assert_eq!(diff, 0.0, "graph {i} depth {queue_depth}: differs from solo");
            }
        }
    }

    #[test]
    fn admission_rejected_graphs_yield_none() {
        use crate::apsp::admission::{AdmissionConfig, AdmissionGraph};
        let good = generators::newman_watts_strogatz(200, 4, 0.1, Weights::Uniform(1.0, 4.0), 54);
        let empty = CsrGraph::from_edges(0, &[]);
        let pg = build_plan(
            &good,
            PlanOptions {
                tile_limit: 48,
                max_depth: usize::MAX,
                seed: 54,
            },
        );
        let pe = build_plan(&empty, PlanOptions::default());
        let subs: Vec<(&CsrGraph, &ApspPlan)> = vec![(&good, &pg), (&empty, &pe)];
        let adm = AdmissionGraph::build(&subs, &[0.0, 0.0], &AdmissionConfig::default());
        let sols = execute_admission(&subs, &adm, &NativeBackend, |_| {});
        assert!(sols[0].is_some());
        assert!(sols[1].is_none(), "rejected submission must yield None");
    }

    #[test]
    fn admission_store_hit_served_bit_identical() {
        use crate::apsp::admission::{AdmissionConfig, AdmissionGraph, StoreOutcome};
        use crate::apsp::store::MemoryStore;
        // submission 2 is byte-identical to submission 0 (same generator
        // seed), so the store serves it instead of re-solving
        let g = generators::newman_watts_strogatz(260, 4, 0.12, Weights::Uniform(1.0, 5.0), 61);
        let dup = generators::newman_watts_strogatz(260, 4, 0.12, Weights::Uniform(1.0, 5.0), 61);
        let other = generators::ogbn_proxy(300, 10.0, Weights::Uniform(1.0, 3.0), 62);
        let popt = |seed| PlanOptions {
            tile_limit: 48,
            max_depth: usize::MAX,
            seed,
        };
        let pg = build_plan(&g, popt(61));
        let po = build_plan(&other, popt(62));
        let pd = build_plan(&dup, popt(61));
        let subs: Vec<(&CsrGraph, &ApspPlan)> = vec![(&g, &pg), (&other, &po), (&dup, &pd)];
        let mut store = MemoryStore::new(8, 1 << 32);
        let (adm, outcomes) = AdmissionGraph::build_with_store(
            &subs,
            &[0.0, 1e-4, 2e-4],
            &AdmissionConfig::default(),
            &mut store,
            true,
        );
        assert!(matches!(outcomes[2], Some(StoreOutcome::Hit { .. })));
        let be = NativeBackend;
        let sols = execute_admission_stored(&subs, &adm, &outcomes, &be, |_| {});
        let hit = sols[2].as_ref().expect("hit submission must be served");
        assert!(hit.is_functional());
        let solo = solve_dag(&dup, &pd, &be, SolveOptions::default());
        assert_eq!(
            hit.materialize_full(&be).max_diff(&solo.materialize_full(&be)),
            0.0,
            "served hit must be bit-identical to a fresh solve"
        );
        // the miss submissions are untouched by the store
        let solo0 = solve_dag(&g, &pg, &be, SolveOptions::default());
        assert_eq!(
            sols[0]
                .as_ref()
                .unwrap()
                .materialize_full(&be)
                .max_diff(&solo0.materialize_full(&be)),
            0.0
        );
    }

    #[test]
    #[should_panic(expected = "smaller batch")]
    fn batch_memory_guard_is_aggregate() {
        use crate::apsp::batch::BatchGraph;
        use crate::apsp::recursive::projected_bytes;
        // every graph fits the limit alone; the co-resident batch must
        // still be rejected
        let gs: Vec<CsrGraph> = (0..4u64)
            .map(|i| generators::newman_watts_strogatz(400, 4, 0.1, Weights::Unit, 40 + i))
            .collect();
        let plans: Vec<ApspPlan> = gs
            .iter()
            .map(|g| {
                build_plan(
                    g,
                    PlanOptions {
                        tile_limit: 64,
                        max_depth: usize::MAX,
                        seed: 40,
                    },
                )
            })
            .collect();
        let limit = gs
            .iter()
            .zip(&plans)
            .map(|(g, p)| projected_bytes(p, g))
            .max()
            .unwrap();
        let batch = BatchGraph::build(&plans.iter().collect::<Vec<_>>());
        let pairs: Vec<(&CsrGraph, &ApspPlan)> = gs.iter().zip(&plans).collect();
        let _ = execute_batch(
            &pairs,
            &batch,
            &NativeBackend,
            SolveOptions {
                memory_limit_bytes: limit,
            },
        );
    }

    fn check_repair(
        g: &CsrGraph,
        plan: &ApspPlan,
        state: &crate::apsp::delta::DeltaState,
        batch: &[crate::apsp::delta::EdgeDelta],
        be: &dyn TileBackend,
    ) {
        use crate::apsp::delta::{self, DeltaClass};
        delta::validate_deltas(g, batch).unwrap();
        let allow_skip = delta::classify_deltas(g, batch) == DeltaClass::Improve;
        let g2 = delta::apply_deltas(g, batch);
        let plan2 = delta::repair_plan(plan, &g2).expect("no structural change");
        let spec = delta::dirty_spec(&plan2, batch);
        let (repaired, actual) =
            execute_delta(&g2, &plan2, &spec, state, allow_skip, be, SolveOptions::default());
        let (_, fresh) = solve_dag_retained(&g2, &plan2, be, SolveOptions::default());
        assert_eq!(
            repaired.max_diff(&fresh),
            0.0,
            "repair must be bit-identical to a fresh solve on the repaired plan"
        );
        assert!(actual.dirty_tiles() <= spec.dirty_tiles());
    }

    #[test]
    fn retained_solve_matches_dag_solve() {
        let g = generators::newman_watts_strogatz(300, 4, 0.12, Weights::Uniform(1.0, 5.0), 71);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 48,
                max_depth: usize::MAX,
                seed: 71,
            },
        );
        let be = NativeBackend;
        let dag = solve_dag(&g, &plan, &be, SolveOptions::default());
        let (trace, state) = solve_dag_retained(&g, &plan, &be, SolveOptions::default());
        assert_eq!(dag.trace, trace, "retained solve must lower identically");
        let sol = state.as_solution(&plan, &g, trace);
        assert_eq!(
            dag.materialize_full(&be).max_diff(&sol.materialize_full(&be)),
            0.0,
            "retained solution must be bit-identical to solve_dag"
        );
    }

    #[test]
    fn delta_repair_bit_identical_to_fresh_solve() {
        use crate::apsp::delta::EdgeDelta;
        let g = generators::newman_watts_strogatz(400, 4, 0.12, Weights::Uniform(1.0, 5.0), 72);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 48,
                max_depth: usize::MAX,
                seed: 72,
            },
        );
        let be = NativeBackend;
        let (_, state) = solve_dag_retained(&g, &plan, &be, SolveOptions::default());
        let edges: Vec<(u32, u32, f32)> = g.edges().filter(|&(u, v, _)| u < v).take(6).collect();
        // improve path: weight decreases (skip eligible)
        let improve: Vec<EdgeDelta> = edges
            .iter()
            .map(|&(u, v, w)| EdgeDelta::Reweight { u, v, w: w * 0.5 })
            .collect();
        check_repair(&g, &plan, &state, &improve, &be);
        // resolve path: a delete forces the conservative closure
        let resolve = vec![EdgeDelta::Delete {
            u: edges[0].0,
            v: edges[0].1,
        }];
        check_repair(&g, &plan, &state, &resolve, &be);
        // mixed batch: insert + increase + delete
        let (mu, mv) = 'found: {
            for u in 0..g.n() as u32 {
                for v in (u + 1)..g.n() as u32 {
                    if g.edge_weight(u as usize, v as usize).is_none() {
                        break 'found (u, v);
                    }
                }
            }
            panic!("graph is complete");
        };
        let mixed = vec![
            EdgeDelta::Insert { u: mu, v: mv, w: 1.5 },
            EdgeDelta::Reweight {
                u: edges[1].0,
                v: edges[1].1,
                w: edges[1].2 * 3.0,
            },
            EdgeDelta::Delete {
                u: edges[2].0,
                v: edges[2].1,
            },
        ];
        check_repair(&g, &plan, &state, &mixed, &be);
    }

    #[test]
    fn delta_repair_on_direct_solve() {
        use crate::apsp::delta::{self, EdgeDelta};
        let g = generators::complete(24, Weights::Uniform(1.0, 2.0), 73);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 128,
                max_depth: usize::MAX,
                seed: 73,
            },
        );
        assert_eq!(plan.depth(), 0);
        let be = NativeBackend;
        let (_, state) = solve_dag_retained(&g, &plan, &be, SolveOptions::default());
        let (u, v, w) = g.edges().next().unwrap();
        check_repair(&g, &plan, &state, &[EdgeDelta::Reweight { u, v, w: w * 0.5 }], &be);
        let g2 = delta::apply_deltas(&g, &[EdgeDelta::Delete { u, v }]);
        assert!(delta::repair_plan(&plan, &g2).is_some(), "depth-0 plans always repair");
        assert!(state.direct.is_some());
    }

    #[test]
    #[should_panic(expected = "functional solve needs")]
    fn memory_guard_applies_to_dag_too() {
        let g = generators::newman_watts_strogatz(500, 4, 0.1, Weights::Unit, 29);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 64,
                max_depth: usize::MAX,
                seed: 29,
            },
        );
        let _ = solve_dag(
            &g,
            &plan,
            &NativeBackend,
            SolveOptions {
                memory_limit_bytes: 1024,
            },
        );
    }
}
