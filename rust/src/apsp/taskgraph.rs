//! Tile-task DAG: the shared intermediate representation between the
//! APSP algorithm, the execution backends, and the PIM simulator.
//!
//! [`lower`] walks an [`ApspPlan`] and emits a [`TaskGraph`] whose nodes
//! are tile-granular operations (carrying the same [`Op`] payloads the
//! legacy trace used) and whose edges are *true data dependencies*:
//!
//! * a component's `LocalFw` blocks only the gathers that read it — a
//!   zero-boundary component never gates the boundary build;
//! * `Inject` needs exactly the sub-level's merged dB plus the
//!   component's own local FW result;
//! * the cross merges of a level need that level's final component
//!   matrices and its dB, nothing else.
//!
//! Two consumers walk the graph: the work-stealing host executor
//! ([`super::scheduler`]) runs ready tasks concurrently against any
//! `TileBackend`, and the simulator's dependency-aware list scheduler
//! ([`crate::sim::engine::simulate_dag`]) computes a critical-path
//! makespan under the modeled resource constraints.
//!
//! The legacy [`Trace`] is a *deterministic topological lowering* of the
//! graph: every node records the trace step it belongs to, and
//! [`TaskGraph::to_trace`] regroups the ops in exactly the order the old
//! barrier-stepped recursive walk emitted them — estimate mode and the
//! barrier simulator keep working unchanged. (Figure code defaults to
//! the dag scheduler, so its modeled makespans improve by the overlap;
//! `run.scheduler = "barrier"` reproduces the legacy numbers exactly.
//! See DESIGN.md "TaskGraph IR".)

use super::plan::{ApspPlan, PlanLevel};
use super::trace::{Op, Phase, Trace};

pub type TaskId = u32;

/// What a task node does. `level`/`comp` index into the plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Stream one component in and densify its block.
    Load { level: u32, comp: u32 },
    /// Local (pre-injection) FW pass on one component.
    LocalFw { level: u32, comp: u32 },
    /// Assemble the level's boundary graph in HBM (gathers the boundary
    /// blocks of every component that *has* boundary vertices).
    BoundaryBuild { level: u32 },
    /// Stream the terminal graph into the die.
    FinalLoad,
    /// Dense FW solve of the terminal graph.
    FinalSolve,
    /// Materialize the full matrix of `level`'s graph — intra entries
    /// from the component matrices plus the two-stage cross merges on
    /// the MP die. Its output is the dB injected into `level - 1`.
    /// `level == depth` materializes the terminal solution (no merge
    /// work); `level == 0` is the top-level merge pass (computed, never
    /// persisted — Fig. 4a step 7).
    CrossMerge { level: u32 },
    /// Min-merge the dB rows/cols into one component's tile.
    Inject { level: u32, comp: u32 },
    /// Boundary-aware FW rerun after injection.
    RerunFw { level: u32, comp: u32 },
    /// HBM boundary synchronization for a level.
    Sync { level: u32 },
    /// CSR-compress + FeNAND-program a level's results (also the
    /// terminal store of a direct, unpartitioned solve).
    Store { level: u32 },
    /// Inter-stack transfer in a sharded run: move a producer's output
    /// from stack `from` to stack `to` over the shared interconnect.
    /// Never emitted by [`lower`]; inserted by [`super::shard`] on
    /// every edge whose producer and consumer carry different stack
    /// affinities. Pure data movement — no host numerics.
    StackXfer { from: u32, to: u32 },
}

/// One node of the tile-task DAG.
#[derive(Debug, Clone)]
pub struct TaskNode {
    pub id: TaskId,
    pub kind: TaskKind,
    /// Recursion level of the trace step this node's ops belong to.
    pub level: u32,
    pub phase: Phase,
    /// Trace step index ([`TaskGraph::to_trace`] grouping).
    pub step: u32,
    /// Hardware ops (empty for pure-dependency nodes, e.g. the terminal
    /// materialization or an empty component's load).
    pub ops: Vec<Op>,
    /// Direct data dependencies (always lower task ids — the graph is
    /// acyclic by construction).
    pub deps: Vec<TaskId>,
}

/// The full tile-task DAG of one APSP run.
#[derive(Debug, Clone, Default)]
pub struct TaskGraph {
    pub nodes: Vec<TaskNode>,
    /// `(level, phase)` of each trace step, in emission order
    /// (crate-visible so [`super::batch`] can union graphs).
    pub(crate) steps: Vec<(u32, Phase)>,
}

impl TaskGraph {
    fn begin_step(&mut self, level: u32, phase: Phase) -> u32 {
        self.steps.push((level, phase));
        (self.steps.len() - 1) as u32
    }

    fn add(&mut self, kind: TaskKind, step: u32, ops: Vec<Op>, deps: Vec<TaskId>) -> TaskId {
        let id = self.nodes.len() as TaskId;
        let (level, phase) = self.steps[step as usize];
        debug_assert!(deps.iter().all(|&d| d < id), "deps must point backward");
        self.nodes.push(TaskNode {
            id,
            kind,
            level,
            phase,
            step,
            ops,
            deps,
        });
        id
    }

    pub fn n_tasks(&self) -> usize {
        self.nodes.len()
    }

    /// Successor adjacency (inverse of `deps`).
    pub fn successors(&self) -> Vec<Vec<TaskId>> {
        let mut succ = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &d in &n.deps {
                succ[d as usize].push(n.id);
            }
        }
        succ
    }

    /// Dependency lists in task-id order (the shape `threads::par_dag`
    /// consumes).
    pub fn dep_lists(&self) -> Vec<Vec<u32>> {
        self.nodes.iter().map(|n| n.deps.clone()).collect()
    }

    /// Deterministic topological lowering to the legacy step-barrier
    /// trace: nodes grouped by their recorded step, ops in node-creation
    /// order — bit-for-bit the trace the old recursive walk emitted.
    pub fn to_trace(&self) -> Trace {
        let mut per_step: Vec<Vec<Op>> = vec![Vec::new(); self.steps.len()];
        for n in &self.nodes {
            per_step[n.step as usize].extend(n.ops.iter().cloned());
        }
        let mut trace = Trace::default();
        for (si, ops) in per_step.into_iter().enumerate() {
            let (level, phase) = self.steps[si];
            trace.push(level, phase, ops);
        }
        trace
    }

    /// Splice `other` into `self` as a fresh task/step id namespace:
    /// every step is appended and every node re-homed with its task ids
    /// and step index offset past the existing contents. Returns the
    /// `(task, step)` offsets the new nodes received. The union gains
    /// no cross-namespace edge — `other`'s dependencies stay inside its
    /// own id range (debug-asserted) — so any schedule of the result is
    /// a legal interleaving of the originals. This is the primitive
    /// both [`super::batch`] and the admission pipeline
    /// ([`super::admission`]) build their merged schedules from.
    pub(crate) fn append_offset(&mut self, other: &TaskGraph) -> (TaskId, u32) {
        let noff = self.nodes.len() as TaskId;
        let soff = self.steps.len() as u32;
        self.steps.extend(other.steps.iter().copied());
        for n in &other.nodes {
            let mut node = n.clone();
            node.id += noff;
            node.step += soff;
            for d in &mut node.deps {
                *d += noff;
            }
            debug_assert!(
                node.deps.iter().all(|&d| d >= noff && d < node.id),
                "cross-namespace edge in task-graph union"
            );
            self.nodes.push(node);
        }
        (noff, soff)
    }

    /// Structural invariants: forward-only edges (acyclicity), in-range
    /// deps, monotone step assignment.
    pub fn validate(&self) -> Result<(), String> {
        let mut last_step = 0u32;
        for n in &self.nodes {
            for &d in &n.deps {
                if d >= n.id {
                    return Err(format!("task {} depends on non-earlier task {d}", n.id));
                }
            }
            if (n.step as usize) >= self.steps.len() {
                return Err(format!("task {} has out-of-range step {}", n.id, n.step));
            }
            if n.step < last_step {
                return Err(format!(
                    "task {} emitted into step {} after step {last_step}",
                    n.id, n.step
                ));
            }
            last_step = n.step;
        }
        Ok(())
    }
}

/// Worst-case CSR byte estimate for storing `dense_elems` result
/// entries (full reachability: 8 bytes per `(col, val)` pair).
pub(crate) fn csr_bytes_estimate(dense_elems: u64) -> u64 {
    dense_elems * 8
}

/// The degenerate task graph of a result-store hit: one FeNAND read of
/// the cached (compressed) distance matrix, no lowering, no compute.
/// Never emitted by [`lower`]; built by [`super::admission`] when a
/// submission's fingerprint matches a stored result.
pub(crate) fn store_hit_graph(bytes: u64) -> TaskGraph {
    let mut tg = TaskGraph::default();
    let step = tg.begin_step(0, Phase::Store);
    tg.add(
        TaskKind::Store { level: 0 },
        step,
        vec![Op::StoreRead { bytes }],
        Vec::new(),
    );
    tg
}

/// Append the result-store write-back to a lowered graph (admission
/// miss path): one FeNAND program of the compressed solution, gated on
/// every current sink so it models the post-solve persist.
pub(crate) fn append_store_writeback(tg: &mut TaskGraph, bytes: u64) {
    let succ = tg.successors();
    let sinks: Vec<TaskId> = tg
        .nodes
        .iter()
        .filter(|n| succ[n.id as usize].is_empty())
        .map(|n| n.id)
        .collect();
    let step = tg.begin_step(0, Phase::Store);
    tg.add(
        TaskKind::Store { level: 0 },
        step,
        vec![Op::StoreWrite { bytes }],
        sinks,
    );
    debug_assert!(tg.validate().is_ok(), "{:?}", tg.validate());
}

/// The aggregated cross-merge ops of one partitioned level (Algorithm
/// step 4 / dataflow step 7) — fetch the interleaved boundary matrices,
/// then the two-stage MP merges for every ordered component pair.
fn cross_merge_ops(lvl: &PlanLevel) -> Vec<Op> {
    let comps = &lvl.cs.components;
    let k = comps.len();
    if k < 2 {
        return Vec::new();
    }
    let nvec: Vec<u64> = comps.iter().map(|c| c.n() as u64).collect();
    let bvec: Vec<u64> = comps.iter().map(|c| c.n_boundary as u64).collect();
    let ntot: u64 = nvec.iter().sum();
    let btot: u64 = bvec.iter().sum();
    let s_nb: u64 = nvec.iter().zip(&bvec).map(|(n, b)| n * b).sum();
    let s_bn: u64 = s_nb;
    let s_nn: u64 = nvec.iter().map(|n| n * n).sum();
    // Σ_{c1≠c2} n1*b1*b2 = Σ n1*b1*(B - b1)
    let stage1: u64 = nvec
        .iter()
        .zip(&bvec)
        .map(|(n, b)| n * b * (btot - b))
        .sum();
    // Σ_{c1≠c2} n1*b2*n2 = Σ_c1 n1 * (S - b1*n1), S = Σ b*n
    let stage2: u64 = nvec
        .iter()
        .zip(&bvec)
        .map(|(n, b)| n * (s_bn - b * n))
        .sum();
    let out_elems = ntot * ntot - s_nn;
    // stage-1 intermediate rows + stage-2 output rows through the
    // comparator tree
    let stage1_rows: u64 = nvec
        .iter()
        .map(|n| n * btot)
        .sum::<u64>()
        .saturating_sub(s_nb);
    let rows = stage1_rows + out_elems;
    let pairs = (k * (k - 1)) as u64;
    let fetch_bytes = btot * btot * 4;
    vec![
        Op::FetchBoundary { bytes: fetch_bytes },
        Op::MpMergeAgg {
            pairs,
            stage1_madds: stage1,
            stage2_madds: stage2,
            out_elems,
            rows,
        },
    ]
}

/// The cross-merge ops restricted to ordered component pairs that touch
/// at least one *changed* component — the modeled cost of refreshing
/// only the cross-block query products a delta repair invalidated.
/// With every component changed this reduces exactly to
/// [`cross_merge_ops`] (property-tested below).
fn cross_merge_ops_subset(lvl: &PlanLevel, changed: &[bool]) -> Vec<Op> {
    let comps = &lvl.cs.components;
    let k = comps.len();
    debug_assert_eq!(changed.len(), k);
    if k < 2 {
        return Vec::new();
    }
    let nvec: Vec<u64> = comps.iter().map(|c| c.n() as u64).collect();
    let bvec: Vec<u64> = comps.iter().map(|c| c.n_boundary as u64).collect();
    let ntot: u64 = nvec.iter().sum();
    let btot: u64 = bvec.iter().sum();
    let s_nb: u64 = nvec.iter().zip(&bvec).map(|(n, b)| n * b).sum();
    let s_nn: u64 = nvec.iter().map(|n| n * n).sum();
    // sums over the *unchanged* component set U; every pair with both
    // ends in U is skipped, so each Σ_{c1≠c2} f(c1)·g(c2) term shrinks
    // by (Σ_U f)(Σ_U g) − Σ_U f·g
    let (mut ku, mut u_n, mut u_b, mut u_nb, mut u_nn) = (0u64, 0u64, 0u64, 0u64, 0u64);
    let (mut u_nbb, mut u_nnb) = (0u64, 0u64);
    for ci in 0..k {
        if changed[ci] {
            continue;
        }
        let (n, b) = (nvec[ci], bvec[ci]);
        ku += 1;
        u_n += n;
        u_b += b;
        u_nb += n * b;
        u_nn += n * n;
        u_nbb += n * b * b;
        u_nnb += n * n * b;
    }
    let pairs = (k * (k - 1)) as u64 - ku * ku.saturating_sub(1);
    if pairs == 0 {
        return Vec::new();
    }
    let stage1_full: u64 = nvec
        .iter()
        .zip(&bvec)
        .map(|(n, b)| n * b * (btot - b))
        .sum();
    let stage2_full: u64 = nvec.iter().zip(&bvec).map(|(n, b)| n * (s_nb - b * n)).sum();
    let stage1 = stage1_full - (u_nb * u_b - u_nbb);
    let stage2 = stage2_full - (u_n * u_nb - u_nnb);
    let out_elems = (ntot * ntot - s_nn) - (u_n * u_n - u_nn);
    let s1rows_full: u64 = nvec
        .iter()
        .map(|n| n * btot)
        .sum::<u64>()
        .saturating_sub(s_nb);
    let rows = (s1rows_full - (u_n * u_b - u_nb)) + out_elems;
    // only the dB slices some changed pair reads leave the die
    let fetch_bytes = (btot * btot - u_b * u_b) * 4;
    vec![
        Op::FetchBoundary { bytes: fetch_bytes },
        Op::MpMergeAgg {
            pairs,
            stage1_madds: stage1,
            stage2_madds: stage2,
            out_elems,
            rows,
        },
    ]
}

/// Which tiles a delta repair must recompute, expressed against the
/// (repaired) plan's level-0 components. Built by [`super::delta`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RepairSpec {
    /// Components whose *local* solve is stale — their block is
    /// reloaded from the graph and re-FW'd (an intra-component delta
    /// lands here).
    pub dirty: Vec<bool>,
    /// Components whose *post-injection* result must be rebuilt
    /// (Inject + RerunFw against the refreshed dB). Meaningful only
    /// when `boundary_dirty`; the conservative closure marks every
    /// boundary component, the post-execution spec only those whose dB
    /// diagonal block actually changed.
    pub rerun: Vec<bool>,
    /// Whether the boundary recursion (levels ≥ 1, the terminal solve,
    /// and every merge) is stale. False only when every delta is
    /// confined to zero-boundary components.
    pub boundary_dirty: bool,
}

impl RepairSpec {
    /// Number of stale level-0 tiles (the report's dirty-tile count):
    /// locally-dirty tiles plus rerun tiles, counted once each.
    pub fn dirty_tiles(&self) -> usize {
        self.dirty
            .iter()
            .zip(&self.rerun)
            .filter(|(d, r)| **d || (self.boundary_dirty && **r))
            .count()
    }
}

/// Lower a delta-repair sub-DAG: the subset of [`lower`]'s emission
/// that recomputes exactly the dirty closure in `spec`, leaving every
/// clean tile to be served from the retained solution. With an
/// everything-dirty spec the emitted trace is bit-identical to the full
/// lowering (tested below) — the repair path runs the *same* kernels in
/// the same order, just skipping work whose inputs didn't change.
pub fn lower_repair(plan: &ApspPlan, spec: &RepairSpec) -> TaskGraph {
    let depth = plan.depth();
    let mut tg = TaskGraph::default();

    if depth == 0 {
        // single-tile plan: the repair is a terminal re-solve
        let n = plan.final_n as u64;
        let step = tg.begin_step(0, Phase::Load);
        let fl = tg.add(
            TaskKind::FinalLoad,
            step,
            vec![Op::LoadComponent {
                n,
                nnz: plan.final_nnz,
            }],
            Vec::new(),
        );
        let step = tg.begin_step(0, Phase::FinalSolve);
        let fs = tg.add(
            TaskKind::FinalSolve,
            step,
            vec![Op::TileFw { n, rerun: false }],
            vec![fl],
        );
        let step = tg.begin_step(0, Phase::Store);
        tg.add(
            TaskKind::Store { level: 0 },
            step,
            vec![Op::StoreCsr {
                dense_elems: n * n,
                csr_bytes: csr_bytes_estimate(n * n),
            }],
            vec![fs],
        );
        debug_assert!(tg.validate().is_ok(), "{:?}", tg.validate());
        return tg;
    }

    let lvl0 = &plan.levels[0];
    let k0 = lvl0.n_components();
    debug_assert_eq!(spec.dirty.len(), k0);
    debug_assert_eq!(spec.rerun.len(), k0);

    // ---- level 0 descent: reload + re-solve only the dirty tiles
    let step = tg.begin_step(0, Phase::Load);
    let mut loads: Vec<Option<TaskId>> = vec![None; k0];
    for (ci, c) in lvl0.cs.components.iter().enumerate() {
        if !spec.dirty[ci] {
            continue;
        }
        let ops = if c.n() > 0 {
            vec![Op::LoadComponent {
                n: c.n() as u64,
                nnz: lvl0.comp_nnz[ci],
            }]
        } else {
            Vec::new()
        };
        loads[ci] = Some(tg.add(
            TaskKind::Load {
                level: 0,
                comp: ci as u32,
            },
            step,
            ops,
            Vec::new(),
        ));
    }
    let step = tg.begin_step(0, Phase::LocalFw);
    let mut pre0: Vec<Option<TaskId>> = loads.clone();
    for (ci, c) in lvl0.cs.components.iter().enumerate() {
        if spec.dirty[ci] && c.n() > 1 {
            pre0[ci] = Some(tg.add(
                TaskKind::LocalFw {
                    level: 0,
                    comp: ci as u32,
                },
                step,
                vec![Op::TileFw {
                    n: c.n() as u64,
                    rerun: false,
                }],
                vec![loads[ci].expect("dirty component was loaded")],
            ));
        }
    }

    if !spec.boundary_dirty {
        // internal-only repair: every dirty tile is zero-boundary, so
        // its local FW is final — dB and all other tiles are retained
        debug_assert!(lvl0
            .cs
            .components
            .iter()
            .enumerate()
            .all(|(ci, c)| !spec.dirty[ci] || c.n_boundary == 0));
        let step = tg.begin_step(0, Phase::Store);
        let dense: u64 = lvl0
            .cs
            .components
            .iter()
            .enumerate()
            .filter(|(ci, _)| spec.dirty[*ci])
            .map(|(_, c)| (c.n() * c.n()) as u64)
            .sum();
        let deps: Vec<TaskId> = pre0.iter().flatten().copied().collect();
        tg.add(
            TaskKind::Store { level: 0 },
            step,
            vec![Op::StoreCsr {
                dense_elems: dense,
                csr_bytes: csr_bytes_estimate(dense),
            }],
            deps,
        );
        debug_assert!(tg.validate().is_ok(), "{:?}", tg.validate());
        return tg;
    }

    // ---- boundary build 0: regather only the dirty boundary blocks
    // (clean blocks are already resident); the cross-edge stream is
    // re-read in full because any weight may have changed
    let nb0 = lvl0.n_boundary();
    debug_assert!(nb0 > 0, "boundary_dirty on a boundary-free plan");
    let step = tg.begin_step(0, Phase::BoundaryBuild);
    let gather: u64 = lvl0
        .cs
        .components
        .iter()
        .enumerate()
        .filter(|(ci, _)| spec.dirty[*ci])
        .map(|(_, c)| (c.n_boundary * c.n_boundary) as u64)
        .sum();
    let bb_deps: Vec<TaskId> = lvl0
        .cs
        .components
        .iter()
        .enumerate()
        .filter(|(ci, c)| c.n_boundary > 0 && spec.dirty[*ci])
        .filter_map(|(ci, _)| pre0[ci])
        .collect();
    let mut bb_prev = tg.add(
        TaskKind::BoundaryBuild { level: 0 },
        step,
        vec![Op::BuildBoundary {
            nb: nb0 as u64,
            cross_nnz: lvl0.next_cross.m() as u64,
            gather_elems: gather,
        }],
        bb_deps,
    );

    // ---- levels ≥ 1 descend + terminal + unwind in full, exactly as
    // [`lower`]: the boundary recursion is monolithic — any stale dB
    // entry invalidates the whole reduced problem
    let mut pre_writer: Vec<Vec<TaskId>> = vec![Vec::new()];
    let mut bb_id: Vec<Option<TaskId>> = vec![None; depth];
    bb_id[0] = Some(bb_prev);
    for (l, lvl) in plan.levels.iter().enumerate().skip(1) {
        let lu = l as u32;
        let step = tg.begin_step(lu, Phase::Load);
        let mut lds = Vec::with_capacity(lvl.n_components());
        for (ci, c) in lvl.cs.components.iter().enumerate() {
            let ops = if c.n() > 0 {
                vec![Op::LoadComponent {
                    n: c.n() as u64,
                    nnz: lvl.comp_nnz[ci],
                }]
            } else {
                Vec::new()
            };
            lds.push(tg.add(
                TaskKind::Load {
                    level: lu,
                    comp: ci as u32,
                },
                step,
                ops,
                vec![bb_prev],
            ));
        }
        let step = tg.begin_step(lu, Phase::LocalFw);
        let mut pw = Vec::with_capacity(lvl.n_components());
        for (ci, c) in lvl.cs.components.iter().enumerate() {
            if c.n() > 1 {
                pw.push(tg.add(
                    TaskKind::LocalFw {
                        level: lu,
                        comp: ci as u32,
                    },
                    step,
                    vec![Op::TileFw {
                        n: c.n() as u64,
                        rerun: false,
                    }],
                    vec![lds[ci]],
                ));
            } else {
                pw.push(lds[ci]);
            }
        }
        pre_writer.push(pw);

        let nb = lvl.n_boundary();
        if nb == 0 {
            break;
        }
        let step = tg.begin_step(lu, Phase::BoundaryBuild);
        let gather: u64 = lvl
            .cs
            .components
            .iter()
            .map(|c| (c.n_boundary * c.n_boundary) as u64)
            .sum();
        let deps: Vec<TaskId> = lvl
            .cs
            .components
            .iter()
            .enumerate()
            .filter(|(_, c)| c.n_boundary > 0)
            .map(|(ci, _)| pre_writer[l][ci])
            .collect();
        bb_prev = tg.add(
            TaskKind::BoundaryBuild { level: lu },
            step,
            vec![Op::BuildBoundary {
                nb: nb as u64,
                cross_nnz: lvl.next_cross.m() as u64,
                gather_elems: gather,
            }],
            deps,
        );
        bb_id[l] = Some(bb_prev);
    }

    let reached_terminal = plan.levels[depth - 1].n_boundary() > 0;
    let mut final_solve: Option<TaskId> = None;
    if reached_terminal && plan.final_n > 0 {
        let du = depth as u32;
        let step = tg.begin_step(du, Phase::Load);
        let fl = tg.add(
            TaskKind::FinalLoad,
            step,
            vec![Op::LoadComponent {
                n: plan.final_n as u64,
                nnz: plan.final_nnz,
            }],
            vec![bb_id[depth - 1].expect("reached terminal")],
        );
        let step = tg.begin_step(du, Phase::FinalSolve);
        final_solve = Some(tg.add(
            TaskKind::FinalSolve,
            step,
            vec![Op::TileFw {
                n: plan.final_n as u64,
                rerun: false,
            }],
            vec![fl],
        ));
    }

    let mut final_writer: Vec<Vec<TaskId>> = vec![Vec::new(); depth];
    let mut db_of: Vec<Option<TaskId>> = vec![None; depth];
    for l in (1..depth).rev() {
        let lvl = &plan.levels[l];
        let lu = l as u32;
        let nb = lvl.n_boundary();
        if nb == 0 {
            final_writer[l] = pre_writer[l].clone();
            continue;
        }
        let sub = l + 1;
        let cm = if sub == depth {
            let step = tg.begin_step(sub as u32, Phase::CrossMerge);
            tg.add(
                TaskKind::CrossMerge { level: sub as u32 },
                step,
                Vec::new(),
                final_solve.into_iter().collect(),
            )
        } else {
            let step = tg.begin_step(sub as u32, Phase::CrossMerge);
            let mut deps = final_writer[sub].clone();
            deps.extend(db_of[sub]);
            tg.add(
                TaskKind::CrossMerge { level: sub as u32 },
                step,
                cross_merge_ops(&plan.levels[sub]),
                deps,
            )
        };
        db_of[l] = Some(cm);

        let step = tg.begin_step(lu, Phase::Inject);
        let mut inject_id: Vec<Option<TaskId>> = vec![None; lvl.n_components()];
        for (ci, c) in lvl.cs.components.iter().enumerate() {
            if c.n_boundary == 0 {
                continue;
            }
            inject_id[ci] = Some(tg.add(
                TaskKind::Inject {
                    level: lu,
                    comp: ci as u32,
                },
                step,
                vec![Op::Inject {
                    n: c.n() as u64,
                    nb: c.n_boundary as u64,
                }],
                vec![cm, pre_writer[l][ci]],
            ));
        }
        let step = tg.begin_step(lu, Phase::RerunFw);
        let mut fw = pre_writer[l].clone();
        for (ci, c) in lvl.cs.components.iter().enumerate() {
            if let Some(inj) = inject_id[ci] {
                fw[ci] = inj;
                if c.n() > 1 {
                    fw[ci] = tg.add(
                        TaskKind::RerunFw {
                            level: lu,
                            comp: ci as u32,
                        },
                        step,
                        vec![Op::TileFw {
                            n: c.n() as u64,
                            rerun: true,
                        }],
                        vec![inj],
                    );
                }
            }
        }
        final_writer[l] = fw;

        let nb64 = nb as u64;
        let step = tg.begin_step(lu, Phase::Sync);
        let sync_deps: Vec<TaskId> = lvl
            .cs
            .components
            .iter()
            .enumerate()
            .filter(|(_, c)| c.n_boundary > 0)
            .map(|(ci, _)| final_writer[l][ci])
            .collect();
        let sync = tg.add(
            TaskKind::Sync { level: lu },
            step,
            vec![Op::SyncBoundary {
                bytes: nb64 * nb64 * 4,
            }],
            sync_deps,
        );
        let step = tg.begin_step(lu, Phase::Store);
        let dense: u64 = lvl
            .cs
            .components
            .iter()
            .map(|c| (c.n() * c.n()) as u64)
            .sum();
        let mut store_deps = vec![sync];
        for (ci, c) in lvl.cs.components.iter().enumerate() {
            if c.n_boundary == 0 {
                store_deps.push(final_writer[l][ci]);
            }
        }
        tg.add(
            TaskKind::Store { level: lu },
            step,
            vec![
                Op::StoreCsr {
                    dense_elems: dense,
                    csr_bytes: csr_bytes_estimate(dense),
                },
                Op::StoreDense {
                    bytes: nb64 * nb64 * 4,
                },
            ],
            store_deps,
        );
    }

    // ---- level-0 unwind: refresh dB, then inject + rerun only the
    // components in the rerun set (clean tiles keep their retained
    // post-injection matrices)
    let sub = 1;
    let cm = if sub == depth {
        let step = tg.begin_step(sub as u32, Phase::CrossMerge);
        tg.add(
            TaskKind::CrossMerge { level: sub as u32 },
            step,
            Vec::new(),
            final_solve.into_iter().collect(),
        )
    } else {
        let step = tg.begin_step(sub as u32, Phase::CrossMerge);
        let mut deps = final_writer[sub].clone();
        deps.extend(db_of[sub]);
        tg.add(
            TaskKind::CrossMerge { level: sub as u32 },
            step,
            cross_merge_ops(&plan.levels[sub]),
            deps,
        )
    };

    let step = tg.begin_step(0, Phase::Inject);
    let mut inject_id: Vec<Option<TaskId>> = vec![None; k0];
    for (ci, c) in lvl0.cs.components.iter().enumerate() {
        if c.n_boundary == 0 || !spec.rerun[ci] {
            continue;
        }
        let mut deps = vec![cm];
        deps.extend(pre0[ci]);
        inject_id[ci] = Some(tg.add(
            TaskKind::Inject {
                level: 0,
                comp: ci as u32,
            },
            step,
            vec![Op::Inject {
                n: c.n() as u64,
                nb: c.n_boundary as u64,
            }],
            deps,
        ));
    }
    let step = tg.begin_step(0, Phase::RerunFw);
    let mut fw0: Vec<Option<TaskId>> = pre0.clone();
    for (ci, c) in lvl0.cs.components.iter().enumerate() {
        if let Some(inj) = inject_id[ci] {
            fw0[ci] = Some(inj);
            if c.n() > 1 {
                fw0[ci] = Some(tg.add(
                    TaskKind::RerunFw {
                        level: 0,
                        comp: ci as u32,
                    },
                    step,
                    vec![Op::TileFw {
                        n: c.n() as u64,
                        rerun: true,
                    }],
                    vec![inj],
                ));
            }
        }
    }

    // sync/store only the rows the repair rewrote
    let nb64 = nb0 as u64;
    let sync_rows: u64 = lvl0
        .cs
        .components
        .iter()
        .enumerate()
        .filter(|(ci, _)| spec.rerun[*ci])
        .map(|(_, c)| c.n_boundary as u64)
        .sum();
    let step = tg.begin_step(0, Phase::Sync);
    let sync_deps: Vec<TaskId> = lvl0
        .cs
        .components
        .iter()
        .enumerate()
        .filter(|(ci, c)| c.n_boundary > 0 && spec.rerun[*ci])
        .filter_map(|(ci, _)| fw0[ci])
        .collect();
    let sync = tg.add(
        TaskKind::Sync { level: 0 },
        step,
        vec![Op::SyncBoundary {
            bytes: sync_rows * nb64 * 4,
        }],
        sync_deps,
    );
    let step = tg.begin_step(0, Phase::Store);
    let dense: u64 = lvl0
        .cs
        .components
        .iter()
        .enumerate()
        .filter(|(ci, _)| spec.dirty[*ci] || spec.rerun[*ci])
        .map(|(_, c)| (c.n() * c.n()) as u64)
        .sum();
    let mut store_deps = vec![sync];
    for (ci, c) in lvl0.cs.components.iter().enumerate() {
        if c.n_boundary == 0 && spec.dirty[ci] {
            store_deps.extend(fw0[ci]);
        }
    }
    tg.add(
        TaskKind::Store { level: 0 },
        step,
        vec![
            Op::StoreCsr {
                dense_elems: dense,
                csr_bytes: csr_bytes_estimate(dense),
            },
            Op::StoreDense {
                bytes: sync_rows * nb64 * 4,
            },
        ],
        store_deps,
    );

    // ---- top: cross merges over pairs touching a changed component
    let changed: Vec<bool> = spec
        .dirty
        .iter()
        .zip(&spec.rerun)
        .map(|(d, r)| *d || *r)
        .collect();
    let step = tg.begin_step(0, Phase::CrossMerge);
    let mut deps: Vec<TaskId> = fw0.iter().flatten().copied().collect();
    deps.push(cm);
    tg.add(
        TaskKind::CrossMerge { level: 0 },
        step,
        cross_merge_ops_subset(lvl0, &changed),
        deps,
    );

    debug_assert!(tg.validate().is_ok(), "{:?}", tg.validate());
    tg
}

/// Lower a recursion plan to the tile-task DAG. Pure plan walk — no
/// graph data, no numerics; both execution modes share the result.
pub fn lower(plan: &ApspPlan) -> TaskGraph {
    let depth = plan.depth();
    let mut tg = TaskGraph::default();

    // Per level: the pre-injection last writer of every component's
    // block (LocalFw, or Load for single-vertex components).
    let mut pre_writer: Vec<Vec<TaskId>> = Vec::with_capacity(depth);
    let mut bb_id: Vec<Option<TaskId>> = vec![None; depth];

    // ---- descent: Load + LocalFw (+ BoundaryBuild) per level
    for (l, lvl) in plan.levels.iter().enumerate() {
        let lu = l as u32;
        let step = tg.begin_step(lu, Phase::Load);
        let mut loads = Vec::with_capacity(lvl.n_components());
        for (ci, c) in lvl.cs.components.iter().enumerate() {
            let deps = if l == 0 {
                Vec::new()
            } else {
                vec![bb_id[l - 1].expect("parent level recursed")]
            };
            let ops = if c.n() > 0 {
                vec![Op::LoadComponent {
                    n: c.n() as u64,
                    nnz: lvl.comp_nnz[ci],
                }]
            } else {
                Vec::new()
            };
            loads.push(tg.add(
                TaskKind::Load {
                    level: lu,
                    comp: ci as u32,
                },
                step,
                ops,
                deps,
            ));
        }

        let step = tg.begin_step(lu, Phase::LocalFw);
        let mut pw = Vec::with_capacity(lvl.n_components());
        for (ci, c) in lvl.cs.components.iter().enumerate() {
            if c.n() > 1 {
                pw.push(tg.add(
                    TaskKind::LocalFw {
                        level: lu,
                        comp: ci as u32,
                    },
                    step,
                    vec![Op::TileFw {
                        n: c.n() as u64,
                        rerun: false,
                    }],
                    vec![loads[ci]],
                ));
            } else {
                pw.push(loads[ci]);
            }
        }
        pre_writer.push(pw);

        let nb = lvl.n_boundary();
        if nb == 0 {
            // mutually unreachable components: no boundary graph, no
            // deeper levels (the plan guarantees this is the last one)
            break;
        }
        let step = tg.begin_step(lu, Phase::BoundaryBuild);
        let gather: u64 = lvl
            .cs
            .components
            .iter()
            .map(|c| (c.n_boundary * c.n_boundary) as u64)
            .sum();
        let deps: Vec<TaskId> = lvl
            .cs
            .components
            .iter()
            .enumerate()
            .filter(|(_, c)| c.n_boundary > 0)
            .map(|(ci, _)| pre_writer[l][ci])
            .collect();
        bb_id[l] = Some(tg.add(
            TaskKind::BoundaryBuild { level: lu },
            step,
            vec![Op::BuildBoundary {
                nb: nb as u64,
                cross_nnz: lvl.next_cross.m() as u64,
                gather_elems: gather,
            }],
            deps,
        ));
    }

    let reached_terminal = depth == 0 || plan.levels[depth - 1].n_boundary() > 0;

    // ---- terminal dense solve
    let mut final_solve: Option<TaskId> = None;
    if reached_terminal && plan.final_n > 0 {
        let du = depth as u32;
        let step = tg.begin_step(du, Phase::Load);
        let deps = if depth > 0 {
            vec![bb_id[depth - 1].expect("reached terminal")]
        } else {
            Vec::new()
        };
        let fl = tg.add(
            TaskKind::FinalLoad,
            step,
            vec![Op::LoadComponent {
                n: plan.final_n as u64,
                nnz: plan.final_nnz,
            }],
            deps,
        );
        let step = tg.begin_step(du, Phase::FinalSolve);
        final_solve = Some(tg.add(
            TaskKind::FinalSolve,
            step,
            vec![Op::TileFw {
                n: plan.final_n as u64,
                rerun: false,
            }],
            vec![fl],
        ));
    }

    // ---- unwind: per level (innermost out) the sub-level's cross
    // merges, then inject + rerun + sync + store
    let mut final_writer: Vec<Vec<TaskId>> = vec![Vec::new(); depth];
    // dB producer per level (None where the level has no boundary).
    let mut db_of: Vec<Option<TaskId>> = vec![None; depth];
    for l in (0..depth).rev() {
        let lvl = &plan.levels[l];
        let lu = l as u32;
        let nb = lvl.n_boundary();
        if nb == 0 {
            // early-returned level: components are final after LocalFw
            final_writer[l] = pre_writer[l].clone();
            continue;
        }
        // dB of level l = materialization of the sub-level's solution
        let sub = l + 1;
        let cm = if sub == depth {
            // terminal: plain matrix clone, no merge ops
            let step = tg.begin_step(sub as u32, Phase::CrossMerge);
            tg.add(
                TaskKind::CrossMerge { level: sub as u32 },
                step,
                Vec::new(),
                final_solve.into_iter().collect(),
            )
        } else {
            let step = tg.begin_step(sub as u32, Phase::CrossMerge);
            let mut deps = final_writer[sub].clone();
            deps.extend(db_of[sub]);
            tg.add(
                TaskKind::CrossMerge { level: sub as u32 },
                step,
                cross_merge_ops(&plan.levels[sub]),
                deps,
            )
        };
        db_of[l] = Some(cm);

        // Inject + RerunFw per boundary component
        let step = tg.begin_step(lu, Phase::Inject);
        let mut inject_id: Vec<Option<TaskId>> = vec![None; lvl.n_components()];
        for (ci, c) in lvl.cs.components.iter().enumerate() {
            if c.n_boundary == 0 {
                continue;
            }
            inject_id[ci] = Some(tg.add(
                TaskKind::Inject {
                    level: lu,
                    comp: ci as u32,
                },
                step,
                vec![Op::Inject {
                    n: c.n() as u64,
                    nb: c.n_boundary as u64,
                }],
                vec![cm, pre_writer[l][ci]],
            ));
        }
        let step = tg.begin_step(lu, Phase::RerunFw);
        let mut fw = pre_writer[l].clone();
        for (ci, c) in lvl.cs.components.iter().enumerate() {
            if let Some(inj) = inject_id[ci] {
                fw[ci] = inj;
                if c.n() > 1 {
                    fw[ci] = tg.add(
                        TaskKind::RerunFw {
                            level: lu,
                            comp: ci as u32,
                        },
                        step,
                        vec![Op::TileFw {
                            n: c.n() as u64,
                            rerun: true,
                        }],
                        vec![inj],
                    );
                }
            }
        }
        final_writer[l] = fw;

        // Sync + Store (dataflow steps 5-6)
        let nb64 = nb as u64;
        let step = tg.begin_step(lu, Phase::Sync);
        let sync_deps: Vec<TaskId> = lvl
            .cs
            .components
            .iter()
            .enumerate()
            .filter(|(_, c)| c.n_boundary > 0)
            .map(|(ci, _)| final_writer[l][ci])
            .collect();
        let sync = tg.add(
            TaskKind::Sync { level: lu },
            step,
            vec![Op::SyncBoundary {
                bytes: nb64 * nb64 * 4,
            }],
            sync_deps,
        );
        let step = tg.begin_step(lu, Phase::Store);
        let dense: u64 = lvl
            .cs
            .components
            .iter()
            .map(|c| (c.n() * c.n()) as u64)
            .sum();
        let mut store_deps = vec![sync];
        // internal-only components aren't covered by the sync edge
        for (ci, c) in lvl.cs.components.iter().enumerate() {
            if c.n_boundary == 0 {
                store_deps.push(final_writer[l][ci]);
            }
        }
        tg.add(
            TaskKind::Store { level: lu },
            step,
            vec![
                Op::StoreCsr {
                    dense_elems: dense,
                    csr_bytes: csr_bytes_estimate(dense),
                },
                Op::StoreDense {
                    bytes: nb64 * nb64 * 4,
                },
            ],
            store_deps,
        );
    }

    // ---- top of the recursion: final cross merges (dataflow step 7),
    // or the direct solve's result store
    if depth > 0 {
        let step = tg.begin_step(0, Phase::CrossMerge);
        let mut deps = final_writer[0].clone();
        deps.extend(db_of[0]);
        tg.add(
            TaskKind::CrossMerge { level: 0 },
            step,
            cross_merge_ops(&plan.levels[0]),
            deps,
        );
    } else {
        let step = tg.begin_step(0, Phase::Store);
        let n = plan.final_n as u64;
        tg.add(
            TaskKind::Store { level: 0 },
            step,
            vec![Op::StoreCsr {
                dense_elems: n * n,
                csr_bytes: csr_bytes_estimate(n * n),
            }],
            final_solve.into_iter().collect(),
        );
    }

    debug_assert!(tg.validate().is_ok(), "{:?}", tg.validate());
    tg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::plan::{build_plan, PlanOptions};
    use crate::graph::csr::CsrGraph;
    use crate::graph::generators::{self, Topology, Weights};

    fn plan_for(n: usize, tile: usize, seed: u64, topo: Topology) -> ApspPlan {
        let g = generators::generate(topo, n, 10.0, Weights::Uniform(1.0, 5.0), seed);
        build_plan(
            &g,
            PlanOptions {
                tile_limit: tile,
                max_depth: usize::MAX,
                seed,
            },
        )
    }

    /// Reference reimplementation of the legacy barrier-walk trace
    /// emission (the code `lower` replaced) — guards that `to_trace` is
    /// bit-identical to what the old recursive walk produced.
    fn legacy_trace(plan: &ApspPlan) -> Trace {
        fn emit_level(plan: &ApspPlan, level: usize, t: &mut Trace) {
            let depth = plan.depth();
            if level == depth {
                let n = plan.final_n;
                if n == 0 {
                    return;
                }
                t.push(
                    level as u32,
                    Phase::Load,
                    vec![Op::LoadComponent {
                        n: n as u64,
                        nnz: plan.final_nnz,
                    }],
                );
                t.push(
                    level as u32,
                    Phase::FinalSolve,
                    vec![Op::TileFw {
                        n: n as u64,
                        rerun: false,
                    }],
                );
                return;
            }
            let lvl = &plan.levels[level];
            let load = lvl
                .cs
                .components
                .iter()
                .zip(&lvl.comp_nnz)
                .filter(|(c, _)| c.n() > 0)
                .map(|(c, &nnz)| Op::LoadComponent {
                    n: c.n() as u64,
                    nnz,
                })
                .collect();
            t.push(level as u32, Phase::Load, load);
            let fw = lvl
                .cs
                .components
                .iter()
                .filter(|c| c.n() > 1)
                .map(|c| Op::TileFw {
                    n: c.n() as u64,
                    rerun: false,
                })
                .collect();
            t.push(level as u32, Phase::LocalFw, fw);
            let nb = lvl.n_boundary();
            if nb == 0 {
                return;
            }
            let gather: u64 = lvl
                .cs
                .components
                .iter()
                .map(|c| (c.n_boundary * c.n_boundary) as u64)
                .sum();
            t.push(
                level as u32,
                Phase::BoundaryBuild,
                vec![Op::BuildBoundary {
                    nb: nb as u64,
                    cross_nnz: lvl.next_cross.m() as u64,
                    gather_elems: gather,
                }],
            );
            emit_level(plan, level + 1, t);
            if level + 1 < depth {
                let ops = cross_merge_ops(&plan.levels[level + 1]);
                t.push((level + 1) as u32, Phase::CrossMerge, ops);
            }
            let inj = lvl
                .cs
                .components
                .iter()
                .filter(|c| c.n_boundary > 0)
                .map(|c| Op::Inject {
                    n: c.n() as u64,
                    nb: c.n_boundary as u64,
                })
                .collect();
            t.push(level as u32, Phase::Inject, inj);
            let rer = lvl
                .cs
                .components
                .iter()
                .filter(|c| c.n_boundary > 0 && c.n() > 1)
                .map(|c| Op::TileFw {
                    n: c.n() as u64,
                    rerun: true,
                })
                .collect();
            t.push(level as u32, Phase::RerunFw, rer);
            let nb64 = nb as u64;
            t.push(
                level as u32,
                Phase::Sync,
                vec![Op::SyncBoundary {
                    bytes: nb64 * nb64 * 4,
                }],
            );
            let dense: u64 = lvl
                .cs
                .components
                .iter()
                .map(|c| (c.n() * c.n()) as u64)
                .sum();
            t.push(
                level as u32,
                Phase::Store,
                vec![
                    Op::StoreCsr {
                        dense_elems: dense,
                        csr_bytes: csr_bytes_estimate(dense),
                    },
                    Op::StoreDense {
                        bytes: nb64 * nb64 * 4,
                    },
                ],
            );
        }
        let mut t = Trace::default();
        emit_level(plan, 0, &mut t);
        if plan.depth() > 0 {
            t.push(0, Phase::CrossMerge, cross_merge_ops(&plan.levels[0]));
        } else {
            let n = plan.final_n as u64;
            t.push(
                0,
                Phase::Store,
                vec![Op::StoreCsr {
                    dense_elems: n * n,
                    csr_bytes: csr_bytes_estimate(n * n),
                }],
            );
        }
        t
    }

    #[test]
    fn trace_matches_legacy_emission() {
        for (topo, n, tile, seed) in [
            (Topology::Nws, 500usize, 48usize, 1u64),
            (Topology::Er, 350, 32, 2),
            (Topology::OgbnProxy, 800, 96, 3),
            (Topology::Grid, 400, 40, 4),
            (Topology::Nws, 60, 128, 5), // direct solve (depth 0)
        ] {
            let plan = plan_for(n, tile, seed, topo);
            let tg = lower(&plan);
            tg.validate().unwrap();
            assert_eq!(
                tg.to_trace(),
                legacy_trace(&plan),
                "{} n={n} tile={tile}",
                topo.name()
            );
        }
    }

    #[test]
    fn trace_matches_legacy_on_disconnected() {
        // two cliques, no bridge: level 0 has zero boundary
        let mut edges = Vec::new();
        for u in 0..30u32 {
            for v in (u + 1)..30 {
                edges.push((u, v, 1.0f32));
            }
        }
        for u in 30..60u32 {
            for v in (u + 1)..60 {
                edges.push((u, v, 1.0));
            }
        }
        let g = CsrGraph::from_undirected_edges(60, &edges);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 32,
                max_depth: usize::MAX,
                seed: 1,
            },
        );
        assert_eq!(plan.levels[0].n_boundary(), 0);
        let tg = lower(&plan);
        assert_eq!(tg.to_trace(), legacy_trace(&plan));
    }

    #[test]
    fn zero_boundary_component_does_not_gate_boundary_build() {
        // 8 bridged communities + 1 disconnected clique: the clique's
        // LocalFw must not be a dependency of BoundaryBuild
        let mut edges: Vec<(u32, u32, f32)> = Vec::new();
        for c in 0..8u32 {
            let base = c * 20;
            for i in 0..20 {
                for j in (i + 1)..20 {
                    edges.push((base + i, base + j, 1.0));
                }
            }
            if c > 0 {
                edges.push((base - 1, base, 2.0));
            }
        }
        for i in 160..220u32 {
            for j in (i + 1)..220 {
                edges.push((i, j, 1.0));
            }
        }
        let g = CsrGraph::from_undirected_edges(220, &edges);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 64,
                max_depth: usize::MAX,
                seed: 9,
            },
        );
        let lvl0 = &plan.levels[0];
        let isolated: Vec<u32> = lvl0
            .cs
            .components
            .iter()
            .enumerate()
            .filter(|(_, c)| c.n() > 1 && c.n_boundary == 0)
            .map(|(ci, _)| ci as u32)
            .collect();
        assert!(!isolated.is_empty(), "expected a zero-boundary component");
        let tg = lower(&plan);
        let bb = tg
            .nodes
            .iter()
            .find(|n| n.kind == TaskKind::BoundaryBuild { level: 0 })
            .expect("boundary build node");
        for dep in &bb.deps {
            let dn = &tg.nodes[*dep as usize];
            if let TaskKind::LocalFw { level: 0, comp } = dn.kind {
                assert!(
                    !isolated.contains(&comp),
                    "BoundaryBuild depends on isolated component {comp}"
                );
            }
        }
    }

    #[test]
    fn cross_merge_depends_on_db_and_final_writers_only() {
        let plan = plan_for(900, 48, 7, Topology::Nws);
        assert!(plan.depth() >= 1);
        let tg = lower(&plan);
        let top = tg
            .nodes
            .iter()
            .find(|n| n.kind == TaskKind::CrossMerge { level: 0 })
            .expect("top-level cross merge");
        for dep in &top.deps {
            let dn = &tg.nodes[*dep as usize];
            assert!(
                matches!(
                    dn.kind,
                    TaskKind::LocalFw { level: 0, .. }
                        | TaskKind::Load { level: 0, .. }
                        | TaskKind::Inject { level: 0, .. }
                        | TaskKind::RerunFw { level: 0, .. }
                        | TaskKind::CrossMerge { .. }
                ),
                "unexpected dep kind {:?}",
                dn.kind
            );
        }
    }

    #[test]
    fn repair_with_everything_dirty_matches_full_lowering() {
        // the all-dirty repair must emit bit-for-bit the ops of the
        // full lowering: same kernels, same order — the repair path is
        // a strict subset, never a different algorithm
        for (topo, n, tile, seed) in [
            (Topology::Nws, 500usize, 48usize, 1u64),
            (Topology::Er, 350, 32, 2),
            (Topology::OgbnProxy, 800, 96, 3),
            (Topology::Nws, 60, 128, 5), // direct solve (depth 0)
        ] {
            let plan = plan_for(n, tile, seed, topo);
            let k0 = if plan.depth() == 0 {
                0
            } else {
                plan.levels[0].n_components()
            };
            let spec = RepairSpec {
                dirty: vec![true; k0],
                rerun: vec![true; k0],
                boundary_dirty: plan.depth() > 0,
            };
            let tg = lower_repair(&plan, &spec);
            tg.validate().unwrap();
            if plan.depth() == 0 {
                // depth-0 repair is a terminal re-solve; compare madds
                assert_eq!(
                    tg.to_trace().total_madds(),
                    lower(&plan).to_trace().total_madds()
                );
            } else {
                assert_eq!(
                    tg.to_trace(),
                    lower(&plan).to_trace(),
                    "{} n={n} tile={tile}",
                    topo.name()
                );
            }
        }
    }

    #[test]
    fn subset_cross_merge_matches_full_when_all_changed() {
        let plan = plan_for(900, 48, 7, Topology::Nws);
        let lvl = &plan.levels[0];
        let all = vec![true; lvl.n_components()];
        assert_eq!(cross_merge_ops_subset(lvl, &all), cross_merge_ops(lvl));
        // no changed components → no merge work
        let none = vec![false; lvl.n_components()];
        assert_eq!(cross_merge_ops_subset(lvl, &none), Vec::new());
    }

    #[test]
    fn subset_cross_merge_is_monotone_in_changed_set() {
        let plan = plan_for(900, 48, 7, Topology::Nws);
        let lvl = &plan.levels[0];
        let k = lvl.n_components();
        assert!(k >= 2);
        let mut changed = vec![false; k];
        let mut prev_madds = 0u64;
        for ci in 0..k {
            changed[ci] = true;
            let madds: u64 = cross_merge_ops_subset(lvl, &changed)
                .iter()
                .map(|op| op.madds())
                .sum();
            assert!(
                madds >= prev_madds,
                "cross-merge madds shrank as changed set grew"
            );
            prev_madds = madds;
        }
    }

    #[test]
    fn repair_scales_with_dirty_tile_count() {
        let plan = plan_for(900, 48, 7, Topology::Nws);
        assert!(plan.depth() >= 1);
        let k0 = plan.levels[0].n_components();
        let full = lower(&plan).to_trace().total_madds();
        // one dirty boundary tile, conservative rerun of all boundary
        // components: still strictly less merge work than a full solve
        let mut dirty = vec![false; k0];
        dirty[0] = true;
        let rerun: Vec<bool> = plan.levels[0]
            .cs
            .components
            .iter()
            .map(|c| c.n_boundary > 0)
            .collect();
        let one = lower_repair(
            &plan,
            &RepairSpec {
                dirty,
                rerun,
                boundary_dirty: true,
            },
        );
        one.validate().unwrap();
        let one_madds = one.to_trace().total_madds();
        assert!(one_madds < full, "repair of one tile must cost under full");
        // internal-only repair of one zero-boundary tile, if any
        if let Some(ci) = plan.levels[0]
            .cs
            .components
            .iter()
            .position(|c| c.n_boundary == 0 && c.n() > 1)
        {
            let mut dirty = vec![false; k0];
            dirty[ci] = true;
            let internal = lower_repair(
                &plan,
                &RepairSpec {
                    dirty,
                    rerun: vec![false; k0],
                    boundary_dirty: false,
                },
            );
            internal.validate().unwrap();
            assert!(internal.to_trace().total_madds() < one_madds);
        }
    }

    #[test]
    fn graph_is_acyclic_and_steps_monotone() {
        for seed in 1..6u64 {
            let plan = plan_for(700, 64, seed, Topology::OgbnProxy);
            let tg = lower(&plan);
            tg.validate().unwrap();
            // every task reachable: topological count == n_tasks
            let mut indeg: Vec<usize> = tg.nodes.iter().map(|n| n.deps.len()).collect();
            let succ = tg.successors();
            let mut ready: Vec<TaskId> = tg
                .nodes
                .iter()
                .filter(|n| n.deps.is_empty())
                .map(|n| n.id)
                .collect();
            let mut seen = 0;
            while let Some(t) = ready.pop() {
                seen += 1;
                for &s in &succ[t as usize] {
                    indeg[s as usize] -= 1;
                    if indeg[s as usize] == 0 {
                        ready.push(s);
                    }
                }
            }
            assert_eq!(seen, tg.n_tasks(), "cycle or orphan in task graph");
        }
    }
}
