//! Min-plus (tropical) matrix products — the MP kernel the PCM-MP die
//! executes (paper §III-D, Fig. 6d): `C[m][n] = min(C[m][n],
//! min_k(A[m][k] + B[k][n]))`.
//!
//! Matrices here are rectangular row-major `&[f32]` slices with explicit
//! dims, because the cross-component merges operate on `|C| x |B|` strips
//! rather than square tiles.

use crate::apsp::semiring::{Semiring, SemiringId};
use crate::util::threads;

/// `C = min(C, A (+) B)` where `A` is `m x k`, `B` is `k x n`, `C` is
/// `m x n`, all row-major. Accumulating (keeps existing C entries).
///
/// Loop order is i-k-j with a row snapshot of `B[k]`, the min-plus
/// analogue of the cache-friendly GEMM ikj order; rows of C go through
/// the 4-row register-tiled relax microkernel
/// (`floyd_warshall::relax_rows4`) so each loaded panel of `B[k]` feeds
/// four accumulator rows — one quarter the B traffic of a plain row
/// loop, bit-identical results (an `INF` coefficient contributes only
/// `min(c, INF) = c`, exactly like skipping the row).
pub fn minplus_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "A dims");
    assert_eq!(b.len(), k * n, "B dims");
    assert_eq!(c.len(), m * n, "C dims");
    minplus_rows(c, a, b, 0, k, n);
}

/// Microkernel body shared by the serial and parallel entry points:
/// relax the rows of `c` (a contiguous strip of C starting at row `i0`)
/// against the full `a`/`b`, four rows per pass.
fn minplus_rows(c: &mut [f32], a: &[f32], b: &[f32], i0: usize, k: usize, n: usize) {
    if n == 0 || k == 0 {
        return;
    }
    debug_assert_eq!(c.len() % n, 0);
    let mut i = i0;
    for quad in c.chunks_mut(4 * n) {
        if quad.len() == 4 * n {
            let (c0, rest) = quad.split_at_mut(n);
            let (c1, rest) = rest.split_at_mut(n);
            let (c2, c3) = rest.split_at_mut(n);
            let (a0, a1, a2, a3) = (
                &a[i * k..(i + 1) * k],
                &a[(i + 1) * k..(i + 2) * k],
                &a[(i + 2) * k..(i + 3) * k],
                &a[(i + 3) * k..(i + 4) * k],
            );
            for kk in 0..k {
                let dik = [a0[kk], a1[kk], a2[kk], a3[kk]];
                if !(dik[0] < f32::INFINITY
                    || dik[1] < f32::INFINITY
                    || dik[2] < f32::INFINITY
                    || dik[3] < f32::INFINITY)
                {
                    continue;
                }
                let row_b = &b[kk * n..(kk + 1) * n];
                crate::apsp::floyd_warshall::relax_rows4(c0, c1, c2, c3, dik, row_b);
            }
            i += 4;
        } else {
            for row_c in quad.chunks_mut(n) {
                let row_a = &a[i * k..(i + 1) * k];
                for (kk, &aik) in row_a.iter().enumerate() {
                    if !(aik < f32::INFINITY) {
                        continue;
                    }
                    let row_b = &b[kk * n..(kk + 1) * n];
                    crate::apsp::floyd_warshall::relax_row(row_c, aik, row_b);
                }
                i += 1;
            }
        }
    }
}

/// Parallel `minplus_into` (rows of C split across workers).
pub fn minplus_into_parallel(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m * n < 64 * 64 {
        return minplus_into(c, a, b, m, k, n);
    }
    let workers = threads::num_threads();
    let rows_per = m.div_ceil(workers * 4).max(8);
    threads::par_chunks_mut(c, rows_per * n, |chunk_idx, rows| {
        minplus_rows(rows, a, b, chunk_idx * rows_per, k, n);
    });
}

/// Scalar-oracle `minplus_into`: same contract, but pinned to the
/// auto-vectorized scalar relax microkernel (never the explicit-SIMD
/// path) and the plain one-row-at-a-time loop. This is the reference
/// the blocked/SIMD kernels are property-tested against.
pub fn minplus_into_scalar(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "A dims");
    assert_eq!(b.len(), k * n, "B dims");
    assert_eq!(c.len(), m * n, "C dims");
    if n == 0 {
        return;
    }
    for (i, row_c) in c.chunks_mut(n).enumerate() {
        let row_a = &a[i * k..(i + 1) * k];
        for (kk, &aik) in row_a.iter().enumerate() {
            if !(aik < f32::INFINITY) {
                continue;
            }
            let row_b = &b[kk * n..(kk + 1) * n];
            crate::apsp::floyd_warshall::relax_row_scalar(row_c, aik, row_b);
        }
    }
}

/// Fresh min-plus product `A (+) B` (C initialized to +inf).
pub fn minplus(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![f32::INFINITY; m * n];
    minplus_into(&mut c, a, b, m, k, n);
    c
}

/// Two-stage MP merge (paper Fig. 6d): `min_{i,j}(A[m,i] + DB[i,j] +
/// B[j,n])` computed as `(A (+) DB) (+) B`. This is the PCM-MP tile's
/// whole job in Step 4 of Algorithm 1/2.
pub fn two_stage_merge(
    a: &[f32],
    db: &[f32],
    b: &[f32],
    m: usize,
    b1: usize,
    b2: usize,
    n: usize,
) -> Vec<f32> {
    let stage1 = minplus(a, db, m, b1, b2);
    minplus(&stage1, b, m, b2, n)
}

// ---------------------------------------------------------------------
// Semiring-generic ⊗-products. `minplus_into*` above are the concrete
// `(min, +)` instantiations and stay untouched (they are the
// `--host-perf` gated hot path); the `product_*` functions below are
// the same kernels over any `Semiring`, and `product_into::<MinPlus>`
// is bit-identical to `minplus_into` because MinPlus's relax hooks
// delegate to the same concrete microkernels.
// ---------------------------------------------------------------------

/// Semiring-generic [`minplus_into`]: `C = C ⊕ (A ⊗ B)` where `A` is
/// `m x k`, `B` is `k x n`, `C` is `m x n`, all row-major.
/// Accumulating (keeps existing C entries).
pub fn product_into<S: Semiring<Elem = f32>>(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k, "A dims");
    assert_eq!(b.len(), k * n, "B dims");
    assert_eq!(c.len(), m * n, "C dims");
    product_rows::<S>(c, a, b, 0, k, n);
}

/// Generic microkernel body shared by the serial and parallel entry
/// points — the per-semiring analogue of `minplus_rows`.
fn product_rows<S: Semiring<Elem = f32>>(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    i0: usize,
    k: usize,
    n: usize,
) {
    if n == 0 || k == 0 {
        return;
    }
    debug_assert_eq!(c.len() % n, 0);
    let mut i = i0;
    for quad in c.chunks_mut(4 * n) {
        if quad.len() == 4 * n {
            let (c0, rest) = quad.split_at_mut(n);
            let (c1, rest) = rest.split_at_mut(n);
            let (c2, c3) = rest.split_at_mut(n);
            let (a0, a1, a2, a3) = (
                &a[i * k..(i + 1) * k],
                &a[(i + 1) * k..(i + 2) * k],
                &a[(i + 2) * k..(i + 3) * k],
                &a[(i + 3) * k..(i + 4) * k],
            );
            for kk in 0..k {
                let dik = [a0[kk], a1[kk], a2[kk], a3[kk]];
                if S::is_absorbing(dik[0])
                    && S::is_absorbing(dik[1])
                    && S::is_absorbing(dik[2])
                    && S::is_absorbing(dik[3])
                {
                    continue;
                }
                let row_b = &b[kk * n..(kk + 1) * n];
                S::relax_rows4(c0, c1, c2, c3, dik, row_b);
            }
            i += 4;
        } else {
            for row_c in quad.chunks_mut(n) {
                let row_a = &a[i * k..(i + 1) * k];
                for (kk, &aik) in row_a.iter().enumerate() {
                    if S::is_absorbing(aik) {
                        continue;
                    }
                    let row_b = &b[kk * n..(kk + 1) * n];
                    S::relax_row(row_c, aik, row_b);
                }
                i += 1;
            }
        }
    }
}

/// Semiring-generic [`minplus_into_parallel`].
pub fn product_into_parallel<S: Semiring<Elem = f32>>(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m * n < 64 * 64 {
        return product_into::<S>(c, a, b, m, k, n);
    }
    let workers = threads::num_threads();
    let rows_per = m.div_ceil(workers * 4).max(8);
    threads::par_chunks_mut(c, rows_per * n, |chunk_idx, rows| {
        product_rows::<S>(rows, a, b, chunk_idx * rows_per, k, n);
    });
}

/// Semiring-generic [`minplus_into_scalar`]: pinned to the portable
/// ⊕/⊗ loop (never an instance's SIMD hook) — the per-semiring
/// reference the generic kernels are property-tested against.
pub fn product_into_scalar<S: Semiring<Elem = f32>>(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k, "A dims");
    assert_eq!(b.len(), k * n, "B dims");
    assert_eq!(c.len(), m * n, "C dims");
    if n == 0 {
        return;
    }
    for (i, row_c) in c.chunks_mut(n).enumerate() {
        let row_a = &a[i * k..(i + 1) * k];
        for (kk, &aik) in row_a.iter().enumerate() {
            if S::is_absorbing(aik) {
                continue;
            }
            let row_b = &b[kk * n..(kk + 1) * n];
            crate::apsp::floyd_warshall::relax_row_scalar_sr::<S>(row_c, aik, row_b);
        }
    }
}

/// Runtime-dispatched accumulating ⊗-product: the MinPlus case routes
/// to the concrete parallel kernel, every other semiring to the
/// generic parallel kernel.
pub fn product_into_dyn(
    sr: SemiringId,
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    match sr {
        SemiringId::MinPlus => minplus_into_parallel(c, a, b, m, k, n),
        _ => crate::dispatch_semiring!(sr, S => product_into_parallel::<S>(c, a, b, m, k, n)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::INF;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![INF; m * n];
        for i in 0..m {
            for j in 0..n {
                for kk in 0..k {
                    let cand = a[i * k + kk] + b[kk * n + j];
                    if cand < c[i * n + j] {
                        c[i * n + j] = cand;
                    }
                }
            }
        }
        c
    }

    fn rand_mat(rng: &mut Rng, len: usize, inf_frac: f64) -> Vec<f32> {
        (0..len)
            .map(|_| {
                if rng.gen_bool(inf_frac) {
                    INF
                } else {
                    rng.gen_f32_range(0.0, 10.0)
                }
            })
            .collect()
    }

    #[test]
    fn known_small_product() {
        // A = [[1, INF], [2, 3]]; B = [[10, 20], [30, 40]]
        let a = vec![1.0, INF, 2.0, 3.0];
        let b = vec![10.0, 20.0, 30.0, 40.0];
        let c = minplus(&a, &b, 2, 2, 2);
        assert_eq!(c, vec![11.0, 21.0, 12.0, 22.0]);
    }

    #[test]
    fn matches_naive_random() {
        let mut rng = Rng::new(3);
        for &(m, k, n) in &[(5usize, 7usize, 3usize), (16, 16, 16), (1, 9, 4), (8, 1, 8)] {
            let a = rand_mat(&mut rng, m * k, 0.2);
            let b = rand_mat(&mut rng, k * n, 0.2);
            let expect = naive(&a, &b, m, k, n);
            assert_eq!(minplus(&a, &b, m, k, n), expect);
            let mut c2 = vec![INF; m * n];
            minplus_into_parallel(&mut c2, &a, &b, m, k, n);
            assert_eq!(c2, expect);
            let mut c3 = vec![INF; m * n];
            minplus_into_scalar(&mut c3, &a, &b, m, k, n);
            assert_eq!(c3, expect);
        }
    }

    #[test]
    fn parallel_matches_serial_large() {
        let mut rng = Rng::new(5);
        let (m, k, n) = (130usize, 90usize, 110usize);
        let a = rand_mat(&mut rng, m * k, 0.3);
        let b = rand_mat(&mut rng, k * n, 0.3);
        let mut c1 = rand_mat(&mut rng, m * n, 0.5);
        let mut c2 = c1.clone();
        minplus_into(&mut c1, &a, &b, m, k, n);
        minplus_into_parallel(&mut c2, &a, &b, m, k, n);
        assert_eq!(c1, c2);
    }

    #[test]
    fn accumulates_existing_minimum() {
        let a = vec![5.0];
        let b = vec![5.0];
        let mut c = vec![3.0];
        minplus_into(&mut c, &a, &b, 1, 1, 1);
        assert_eq!(c, vec![3.0]); // existing 3 < 10
        let mut c = vec![30.0];
        minplus_into(&mut c, &a, &b, 1, 1, 1);
        assert_eq!(c, vec![10.0]);
    }

    #[test]
    fn all_inf_propagates() {
        let a = vec![INF; 4];
        let b = vec![1.0, 2.0, 3.0, 4.0];
        let c = minplus(&a, &b, 2, 2, 2);
        assert!(c.iter().all(|x| x.is_infinite()));
    }

    #[test]
    fn two_stage_matches_composed() {
        let mut rng = Rng::new(7);
        let (m, b1, b2, n) = (6usize, 4usize, 5usize, 7usize);
        let a = rand_mat(&mut rng, m * b1, 0.2);
        let db = rand_mat(&mut rng, b1 * b2, 0.2);
        let b = rand_mat(&mut rng, b2 * n, 0.2);
        let got = two_stage_merge(&a, &db, &b, m, b1, b2, n);
        // brute force
        for i in 0..m {
            for j in 0..n {
                let mut best = INF;
                for x in 0..b1 {
                    for y in 0..b2 {
                        let cand = a[i * b1 + x] + db[x * b2 + y] + b[y * n + j];
                        if cand < best {
                            best = cand;
                        }
                    }
                }
                let g = got[i * n + j];
                assert!(
                    (g - best).abs() < 1e-4 || (g.is_infinite() && best.is_infinite()),
                    "({i},{j}): {g} vs {best}"
                );
            }
        }
    }

    #[test]
    fn minplus_associativity_property() {
        // (A ⊗ B) ⊗ C == A ⊗ (B ⊗ C) — semiring associativity
        crate::util::prop::assert_prop(
            20,
            |r| {
                let (m, k, l, n) = (
                    1 + r.gen_range(6),
                    1 + r.gen_range(6),
                    1 + r.gen_range(6),
                    1 + r.gen_range(6),
                );
                let mut rr = r.fork();
                (
                    rand_mat(&mut rr, m * k, 0.2),
                    rand_mat(&mut rr, k * l, 0.2),
                    rand_mat(&mut rr, l * n, 0.2),
                    (m, k, l, n),
                )
            },
            |(a, b, c, (m, k, l, n))| {
                let ab = minplus(a, b, *m, *k, *l);
                let left = minplus(&ab, c, *m, *l, *n);
                let bc = minplus(b, c, *k, *l, *n);
                let right = minplus(a, &bc, *m, *k, *n);
                for (x, y) in left.iter().zip(&right) {
                    let ok = (x - y).abs() < 1e-3 || (x.is_infinite() && y.is_infinite());
                    if !ok {
                        return Err(format!("{x} != {y}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn generic_product_minplus_bit_identical() {
        use crate::apsp::semiring::MinPlus;
        let mut rng = Rng::new(31);
        for &(m, k, n) in &[(5usize, 7usize, 3usize), (16, 16, 16), (33, 20, 29)] {
            let a = rand_mat(&mut rng, m * k, 0.25);
            let b = rand_mat(&mut rng, k * n, 0.25);
            let mut c1 = rand_mat(&mut rng, m * n, 0.5);
            let mut c2 = c1.clone();
            minplus_into(&mut c1, &a, &b, m, k, n);
            product_into::<MinPlus>(&mut c2, &a, &b, m, k, n);
            let same = c1.iter().zip(&c2).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "({m},{k},{n}): generic MinPlus product diverged");
        }
    }

    #[test]
    fn generic_product_matches_scalar_all_semirings() {
        use crate::apsp::semiring::ALL_SEMIRINGS;
        let mut rng = Rng::new(37);
        for sr in ALL_SEMIRINGS {
            for &(m, k, n) in &[(6usize, 9usize, 5usize), (17, 12, 20), (64, 70, 64)] {
                let mk_mat = |rng: &mut Rng, len: usize| -> Vec<f32> {
                    (0..len)
                        .map(|_| {
                            if rng.gen_bool(0.25) {
                                sr.zero()
                            } else {
                                sr.from_weight(rng.gen_f32_range(0.1, 9.0))
                            }
                        })
                        .collect()
                };
                let a = mk_mat(&mut rng, m * k);
                let b = mk_mat(&mut rng, k * n);
                let mut c1 = vec![sr.zero(); m * n];
                let mut c2 = c1.clone();
                let mut c3 = c1.clone();
                crate::dispatch_semiring!(sr, S => {
                    product_into::<S>(&mut c1, &a, &b, m, k, n);
                    product_into_scalar::<S>(&mut c2, &a, &b, m, k, n);
                    product_into_parallel::<S>(&mut c3, &a, &b, m, k, n);
                });
                let same12 = c1.iter().zip(&c2).all(|(x, y)| x.to_bits() == y.to_bits());
                let same13 = c1.iter().zip(&c3).all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(same12, "{}: tiled vs scalar diverged", sr.name());
                assert!(same13, "{}: serial vs parallel diverged", sr.name());
            }
        }
    }
}
