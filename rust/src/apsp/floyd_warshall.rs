//! Floyd–Warshall kernels (paper §II-B1).
//!
//! `D[i][j] = min(D[i][j], D[i][k] + D[k][j])` for every pivot `k` — the
//! dense dynamic program the PCM-FW die executes in-place. Three
//! implementations with identical results:
//!
//! * [`fw_inplace`] — straightforward triple loop (the always-available
//!   scalar oracle every other kernel is tested against).
//! * [`fw_rowwise`] — pivot-row snapshot + register-tiled row sweep; this
//!   is the same "Panel_Row broadcast into the Main_Block" structure the
//!   paper's remapping uses (Fig. 6b), expressed for a CPU cache.
//! * [`fw_parallel`] — `fw_rowwise` with the row sweep fanned out across
//!   threads per pivot (used by the native tile backend and the CPU
//!   baseline).
//!
//! # Microkernel structure
//!
//! The hot loop is [`relax_row`]: `row_i[j] = min(row_i[j], dik +
//! row_k[j])`. It dispatches once (cached feature probe) between a
//! scalar path written so LLVM auto-vectorizes it — equal-length
//! re-sliced iterators, no bounds checks, branchless `f32::min` — and an
//! explicit AVX2 path (`vaddps`/`vminps`). Both are elementwise IEEE
//! min/add over the same operands in the same order, so results are
//! bit-identical; the property suite in `tests/kernel_properties.rs`
//! pins this. Row sweeps go 4 rows per pass ([`relax_rows4`]) so one
//! load of the pivot-row panel feeds four accumulator rows — rows are
//! independent within a pivot, so the tiling cannot change results.
//!
//! [`relax_row_succ`] is the successor-threaded sibling used by the
//! query layer (`apsp::query`): the same row update, but where the
//! candidate strictly improves the distance it also records the first
//! hop of the `i -> k` path into a packed u32 next-hop row, so path
//! reconstruction falls out of the solve for free.
//!
//! Pivot-row / panel scratch comes from [`crate::util::arena`]; the
//! `_scratch` variants take caller-provided buffers for callers that
//! hold their own (the blocked backend, the property suite).

use crate::apsp::semiring::{Semiring, SemiringId};
use crate::graph::dense::DistMatrix;
use crate::util::{arena, threads};

#[cfg(test)]
use std::sync::atomic::{AtomicU64, Ordering};

/// Counts entries into the bounds-check-free relax microkernel, so tests
/// can assert the hot path is actually the one being exercised.
#[cfg(test)]
pub(crate) static RELAX_FAST_PATH_ENTRIES: AtomicU64 = AtomicU64::new(0);

/// Reference triple-loop FW. O(n^3) time, in-place. Deliberately naive:
/// this is the scalar oracle the vectorized kernels are compared to.
pub fn fw_inplace(d: &mut DistMatrix) {
    let n = d.n();
    for k in 0..n {
        for i in 0..n {
            let dik = d.get(i, k);
            if !(dik < f32::INFINITY) {
                continue;
            }
            for j in 0..n {
                let cand = dik + d.get(k, j);
                if cand < d.get(i, j) {
                    d.set(i, j, cand);
                }
            }
        }
    }
}

/// Row-wise FW: snapshot the pivot row once per `k`, then stream every
/// row `i` against it through the register-tiled microkernel.
pub fn fw_rowwise(d: &mut DistMatrix) {
    let mut row_k = arena::scratch_filled(d.n(), 0.0);
    fw_rowwise_scratch(d, &mut row_k);
}

/// [`fw_rowwise`] with caller-provided pivot-row scratch (`row_k.len()
/// >= d.n()`); no allocation inside the pivot loop.
pub fn fw_rowwise_scratch(d: &mut DistMatrix, row_k: &mut [f32]) {
    let n = d.n();
    let row_k = &mut row_k[..n];
    for k in 0..n {
        row_k.copy_from_slice(d.row(k));
        relax_rows_against(d.as_mut_slice(), n, k, row_k);
    }
}

/// Sweep all rows of `data` (`rows x n`, row-major) against the pivot-row
/// snapshot `row_k`, reading each row's `dik` from column `k`. Rows are
/// processed 4 at a time so one pass over `row_k` feeds four register
/// accumulators; rows are mutually independent within a pivot, so the
/// grouping is bit-identical to a plain row loop.
fn relax_rows_against(data: &mut [f32], n: usize, k: usize, row_k: &[f32]) {
    debug_assert_eq!(data.len() % n, 0);
    for quad in data.chunks_mut(4 * n) {
        if quad.len() == 4 * n {
            let (r0, rest) = quad.split_at_mut(n);
            let (r1, rest) = rest.split_at_mut(n);
            let (r2, r3) = rest.split_at_mut(n);
            let (d0, d1, d2, d3) = (r0[k], r1[k], r2[k], r3[k]);
            if d0 < f32::INFINITY
                && d1 < f32::INFINITY
                && d2 < f32::INFINITY
                && d3 < f32::INFINITY
            {
                relax_rows4(r0, r1, r2, r3, [d0, d1, d2, d3], row_k);
                continue;
            }
            for (r, dk) in [(r0, d0), (r1, d1), (r2, d2), (r3, d3)] {
                if dk < f32::INFINITY {
                    relax_row(r, dk, row_k);
                }
            }
        } else {
            for r in quad.chunks_mut(n) {
                let dk = r[k];
                if dk < f32::INFINITY {
                    relax_row(r, dk, row_k);
                }
            }
        }
    }
}

/// One FW row update: `row_i[j] = min(row_i[j], dik + row_k[j])`.
/// `dik` must be finite. This is the hot loop of the whole crate.
///
/// Dispatches to the explicit AVX2 kernel when the CPU supports it
/// (probe cached; `RAPID_SIMD=0` forces scalar), otherwise the
/// auto-vectorizing scalar path. Both are bit-identical — elementwise
/// IEEE add/min, same operands, same order. NaN caveat does not apply:
/// `dik` is finite and `row_k[j]` is never NaN, so `cand` is never NaN.
/// `min(x, inf+w) = x` keeps infinity semantics.
#[inline]
pub fn relax_row(row_i: &mut [f32], dik: f32, row_k: &[f32]) {
    debug_assert_eq!(row_i.len(), row_k.len());
    let m = row_i.len().min(row_k.len());
    let (ri, rk) = (&mut row_i[..m], &row_k[..m]);
    #[cfg(test)]
    RELAX_FAST_PATH_ENTRIES.fetch_add(1, Ordering::Relaxed);
    #[cfg(target_arch = "x86_64")]
    if simd::enabled() {
        // SAFETY: AVX2 support verified by the cached runtime probe.
        unsafe { simd::relax_row_avx2(ri, dik, rk) };
        return;
    }
    relax_row_scalar(ri, dik, rk);
}

/// Scalar relax microkernel — the always-available oracle. Branchless
/// form: `f32::min` compiles to `minps` so LLVM vectorizes the whole
/// loop (the earlier `if cand < row_i[j]` store-guard blocked
/// vectorization — 2x slower; EXPERIMENTS.md §Perf). The equal-length
/// zip over re-sliced operands carries no bounds checks.
#[inline]
pub fn relax_row_scalar(row_i: &mut [f32], dik: f32, row_k: &[f32]) {
    let m = row_i.len().min(row_k.len());
    let (ri, rk) = (&mut row_i[..m], &row_k[..m]);
    for (x, &b) in ri.iter_mut().zip(rk.iter()) {
        *x = x.min(dik + b);
    }
}

/// Fused 4-row relax: one pass over `row_k` updates four rows. `dik`
/// entries may be `INF` — an infinite candidate never wins a min, so the
/// fused form stays bit-identical to four sequential [`relax_row`]s
/// (with infinite rows skipped).
#[inline]
pub fn relax_rows4(
    r0: &mut [f32],
    r1: &mut [f32],
    r2: &mut [f32],
    r3: &mut [f32],
    dik: [f32; 4],
    row_k: &[f32],
) {
    let m = row_k
        .len()
        .min(r0.len())
        .min(r1.len())
        .min(r2.len())
        .min(r3.len());
    #[cfg(test)]
    RELAX_FAST_PATH_ENTRIES.fetch_add(1, Ordering::Relaxed);
    #[cfg(target_arch = "x86_64")]
    if simd::enabled() {
        // SAFETY: AVX2 support verified by the cached runtime probe.
        unsafe {
            simd::relax_rows4_avx2(
                &mut r0[..m],
                &mut r1[..m],
                &mut r2[..m],
                &mut r3[..m],
                dik,
                &row_k[..m],
            )
        };
        return;
    }
    let (r0, r1, r2, r3) = (&mut r0[..m], &mut r1[..m], &mut r2[..m], &mut r3[..m]);
    let rk = &row_k[..m];
    for j in 0..m {
        let b = rk[j];
        r0[j] = r0[j].min(dik[0] + b);
        r1[j] = r1[j].min(dik[1] + b);
        r2[j] = r2[j].min(dik[2] + b);
        r3[j] = r3[j].min(dik[3] + b);
    }
}

/// Successor-threaded FW row update: where `dik + row_k[j]` is
/// *strictly* smaller than `row_i[j]`, write the improved distance and
/// record `sik` (the first hop of the `i -> k` path) into `succ_i[j]`.
/// The next-hop recurrence is `succ[i][j] := succ[i][k]` whenever the
/// pivot improves `d[i][j]`, so one scalar `sik` broadcast per row is
/// all the successor state the kernel needs — no successor pivot-row
/// snapshot. Ties never update (an equal-length path is already
/// recorded), and strict `<` is what keeps the scalar and AVX2 paths
/// bit-identical: both select on exactly the `cand < cur` mask, with no
/// `min` tie-order subtleties. `dik` must be finite.
#[inline]
pub fn relax_row_succ(row_i: &mut [f32], dik: f32, row_k: &[f32], succ_i: &mut [u32], sik: u32) {
    let m = row_i.len().min(row_k.len()).min(succ_i.len());
    let (ri, rk, si) = (&mut row_i[..m], &row_k[..m], &mut succ_i[..m]);
    #[cfg(test)]
    RELAX_FAST_PATH_ENTRIES.fetch_add(1, Ordering::Relaxed);
    #[cfg(target_arch = "x86_64")]
    if simd::enabled() {
        // SAFETY: AVX2 support verified by the cached runtime probe.
        unsafe { simd::relax_row_succ_avx2(ri, dik, rk, si, sik) };
        return;
    }
    relax_row_succ_scalar(ri, dik, rk, si, sik);
}

/// Scalar successor-threaded relax — the feature-parity oracle for
/// [`relax_row_succ`]. Written as an explicit compare-and-select (not
/// `f32::min`) so the update condition is the same strict `<` the SIMD
/// blend mask uses.
#[inline]
pub fn relax_row_succ_scalar(
    row_i: &mut [f32],
    dik: f32,
    row_k: &[f32],
    succ_i: &mut [u32],
    sik: u32,
) {
    let m = row_i.len().min(row_k.len()).min(succ_i.len());
    let (ri, rk, si) = (&mut row_i[..m], &row_k[..m], &mut succ_i[..m]);
    for j in 0..m {
        let cand = dik + rk[j];
        if cand < ri[j] {
            ri[j] = cand;
            si[j] = sik;
        }
    }
}

/// Name of the relax microkernel variant in use (for bench reports).
pub fn relax_kernel_name() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    if simd::enabled() {
        return "avx2";
    }
    "scalar"
}

/// Explicit-SIMD relax microkernels (x86-64 AVX2). Each lane computes
/// the same IEEE single-rounded `dik + row_k[j]` and elementwise min as
/// the scalar path, so outputs are bit-identical; the scalar tail uses
/// `f32::min` to match exactly.
#[cfg(target_arch = "x86_64")]
mod simd {
    use std::arch::x86_64::*;
    use std::sync::atomic::{AtomicU8, Ordering};

    /// 0 = unprobed, 1 = AVX2 on, 2 = off.
    static STATE: AtomicU8 = AtomicU8::new(0);

    #[inline]
    pub fn enabled() -> bool {
        match STATE.load(Ordering::Relaxed) {
            1 => true,
            2 => false,
            _ => {
                let on = is_x86_feature_detected!("avx2")
                    && !matches!(std::env::var("RAPID_SIMD").as_deref(), Ok("0"));
                STATE.store(if on { 1 } else { 2 }, Ordering::Relaxed);
                on
            }
        }
    }

    /// # Safety
    /// Caller must ensure AVX2 is available (see [`enabled`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn relax_row_avx2(ri: &mut [f32], dik: f32, rk: &[f32]) {
        let n = ri.len().min(rk.len());
        let rip = ri.as_mut_ptr();
        let rkp = rk.as_ptr();
        let va = _mm256_set1_ps(dik);
        let mut j = 0;
        while j + 8 <= n {
            let cand = _mm256_add_ps(va, _mm256_loadu_ps(rkp.add(j)));
            let cur = _mm256_loadu_ps(rip.add(j));
            _mm256_storeu_ps(rip.add(j), _mm256_min_ps(cur, cand));
            j += 8;
        }
        while j < n {
            let x = *rip.add(j);
            *rip.add(j) = x.min(dik + *rkp.add(j));
            j += 1;
        }
    }

    /// # Safety
    /// Caller must ensure AVX2 is available (see [`enabled`]).
    ///
    /// The distance lanes blend on the strict `cand < cur` mask
    /// (`_CMP_LT_OQ`) rather than `vminps`, so the update condition is
    /// the literal scalar-oracle branch; the same mask, cast to integer
    /// lanes, blends the broadcast successor id into the u32 row.
    #[target_feature(enable = "avx2")]
    pub unsafe fn relax_row_succ_avx2(
        ri: &mut [f32],
        dik: f32,
        rk: &[f32],
        si: &mut [u32],
        sik: u32,
    ) {
        let n = ri.len().min(rk.len()).min(si.len());
        let rip = ri.as_mut_ptr();
        let rkp = rk.as_ptr();
        let sip = si.as_mut_ptr();
        let va = _mm256_set1_ps(dik);
        let vs = _mm256_set1_epi32(sik as i32);
        let mut j = 0;
        while j + 8 <= n {
            let cand = _mm256_add_ps(va, _mm256_loadu_ps(rkp.add(j)));
            let cur = _mm256_loadu_ps(rip.add(j));
            let mask = _mm256_cmp_ps::<_CMP_LT_OQ>(cand, cur);
            _mm256_storeu_ps(rip.add(j), _mm256_blendv_ps(cur, cand, mask));
            let cur_s = _mm256_loadu_si256(sip.add(j) as *const __m256i);
            let new_s = _mm256_blendv_epi8(cur_s, vs, _mm256_castps_si256(mask));
            _mm256_storeu_si256(sip.add(j) as *mut __m256i, new_s);
            j += 8;
        }
        while j < n {
            let cand = dik + *rkp.add(j);
            if cand < *rip.add(j) {
                *rip.add(j) = cand;
                *sip.add(j) = sik;
            }
            j += 1;
        }
    }

    /// # Safety
    /// Caller must ensure AVX2 is available (see [`enabled`]). All four
    /// row slices and `rk` must have equal length.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn relax_rows4_avx2(
        r0: &mut [f32],
        r1: &mut [f32],
        r2: &mut [f32],
        r3: &mut [f32],
        dik: [f32; 4],
        rk: &[f32],
    ) {
        let n = rk.len();
        let (p0, p1, p2, p3) = (
            r0.as_mut_ptr(),
            r1.as_mut_ptr(),
            r2.as_mut_ptr(),
            r3.as_mut_ptr(),
        );
        let rkp = rk.as_ptr();
        let (v0, v1, v2, v3) = (
            _mm256_set1_ps(dik[0]),
            _mm256_set1_ps(dik[1]),
            _mm256_set1_ps(dik[2]),
            _mm256_set1_ps(dik[3]),
        );
        let mut j = 0;
        while j + 8 <= n {
            let b = _mm256_loadu_ps(rkp.add(j));
            _mm256_storeu_ps(
                p0.add(j),
                _mm256_min_ps(_mm256_loadu_ps(p0.add(j)), _mm256_add_ps(v0, b)),
            );
            _mm256_storeu_ps(
                p1.add(j),
                _mm256_min_ps(_mm256_loadu_ps(p1.add(j)), _mm256_add_ps(v1, b)),
            );
            _mm256_storeu_ps(
                p2.add(j),
                _mm256_min_ps(_mm256_loadu_ps(p2.add(j)), _mm256_add_ps(v2, b)),
            );
            _mm256_storeu_ps(
                p3.add(j),
                _mm256_min_ps(_mm256_loadu_ps(p3.add(j)), _mm256_add_ps(v3, b)),
            );
            j += 8;
        }
        while j < n {
            let b = *rkp.add(j);
            *p0.add(j) = (*p0.add(j)).min(dik[0] + b);
            *p1.add(j) = (*p1.add(j)).min(dik[1] + b);
            *p2.add(j) = (*p2.add(j)).min(dik[2] + b);
            *p3.add(j) = (*p3.add(j)).min(dik[3] + b);
            j += 1;
        }
    }
}

/// Parallel FW: worker threads are spawned once for the whole solve and
/// synchronize per pivot with a barrier (two barriers per pivot: one
/// after the pivot-row snapshot, one after the row sweep). Spawning per
/// pivot would cost more than the pivot itself — see EXPERIMENTS.md
/// §Perf. Matches `fw_rowwise` bit-for-bit (same per-row operation
/// order).
pub fn fw_parallel(d: &mut DistMatrix) {
    let n = d.n();
    let workers = threads::num_threads().min(n / 128).max(1);
    if n < 384 || workers == 1 {
        return fw_rowwise(d);
    }
    let data_ptr = d.as_mut_slice().as_mut_ptr() as usize;
    let mut row_k = arena::scratch_filled(n, 0.0);
    let row_k_ptr = row_k.as_mut_ptr() as usize;
    let barrier = std::sync::Barrier::new(workers);
    // static row ranges per worker
    let rows_per = n.div_ceil(workers);
    std::thread::scope(|s| {
        for w in 0..workers {
            let barrier = &barrier;
            s.spawn(move || {
                let lo = w * rows_per;
                let hi = ((w + 1) * rows_per).min(n);
                // SAFETY: workers write disjoint row ranges; the shared
                // pivot-row buffer is written only by worker 0, between
                // two barriers that order it against all reads.
                let data = data_ptr as *mut f32;
                let row_k = row_k_ptr as *mut f32;
                for k in 0..n {
                    // close the previous pivot's sweep before snapshotting
                    // row k (its owner may still be relaxing it)
                    barrier.wait();
                    if w == 0 {
                        unsafe {
                            std::ptr::copy_nonoverlapping(data.add(k * n), row_k, n);
                        }
                    }
                    barrier.wait();
                    let row_k_slice =
                        unsafe { std::slice::from_raw_parts(row_k as *const f32, n) };
                    if lo < hi {
                        let rows = unsafe {
                            std::slice::from_raw_parts_mut(data.add(lo * n), (hi - lo) * n)
                        };
                        relax_rows_against(rows, n, k, row_k_slice);
                    }
                }
            });
        }
    });
    drop(row_k);
}

/// FW with a panel decomposition (paper Fig. 6b): the pivot row and
/// column are peeled into panels, and the main block is updated with one
/// add + one min per pivot. Functionally identical to `fw_rowwise`; kept
/// as the direct software analogue of the PCM-FW tile schedule so the
/// simulator's op costs map 1:1 onto code.
pub fn fw_panel(d: &mut DistMatrix) {
    let n = d.n();
    let mut panel_row = arena::scratch_filled(n, 0.0);
    let mut panel_col = arena::scratch_filled(n, 0.0);
    fw_panel_scratch(d, &mut panel_row, &mut panel_col);
}

/// [`fw_panel`] with caller-provided panel scratch (both `>= d.n()`).
pub fn fw_panel_scratch(d: &mut DistMatrix, panel_row: &mut [f32], panel_col: &mut [f32]) {
    let n = d.n();
    let panel_row = &mut panel_row[..n];
    let panel_col = &mut panel_col[..n];
    for k in 0..n {
        // Panel extraction (permutation unit, Fig. 5d)
        panel_row.copy_from_slice(d.row(k));
        for (i, pc) in panel_col.iter_mut().enumerate() {
            *pc = d.get(i, k);
        }
        // Main_Block update: Temp = Panel_Col + Panel_Row (bit-serial
        // add), then selective write where Temp < Main_Block (bit-serial
        // min via sign bit). Pivot row/col are also updated through the
        // same pass (d[k][k] = 0 keeps them fixed).
        let data = d.as_mut_slice();
        for (i, row_i) in data.chunks_exact_mut(n).enumerate() {
            let dik = panel_col[i];
            if !(dik < f32::INFINITY) {
                continue;
            }
            relax_row(row_i, dik, panel_row);
        }
    }
}

// ---------------------------------------------------------------------
// Semiring-generic kernels. These mirror the concrete `(min, +)`
// functions above line for line, with the pinned `< INF` guards and
// min/add bodies routed through the `Semiring` hooks. `MinPlus`'s hooks
// delegate back to the concrete AVX2-dispatching microkernels, so the
// `_sr::<MinPlus>` instantiations are bit-identical to the concrete
// entry points (pinned in `tests/kernel_properties.rs`); the concrete
// functions stay untouched so the `--host-perf` hot paths and the
// next-hop successor kernels are exactly the pre-refactor code.
// ---------------------------------------------------------------------

/// Semiring-generic [`fw_inplace`]: reference triple loop over ⊕/⊗.
pub fn fw_inplace_sr<S: Semiring<Elem = f32>>(d: &mut DistMatrix) {
    let n = d.n();
    for k in 0..n {
        for i in 0..n {
            let dik = d.get(i, k);
            if S::is_absorbing(dik) {
                continue;
            }
            for j in 0..n {
                let cand = S::extend(dik, d.get(k, j));
                d.set(i, j, S::combine(d.get(i, j), cand));
            }
        }
    }
}

/// Semiring-generic [`fw_rowwise`].
pub fn fw_rowwise_sr<S: Semiring<Elem = f32>>(d: &mut DistMatrix) {
    let mut row_k = arena::scratch_filled(d.n(), 0.0);
    fw_rowwise_scratch_sr::<S>(d, &mut row_k);
}

/// Semiring-generic [`fw_rowwise_scratch`].
pub fn fw_rowwise_scratch_sr<S: Semiring<Elem = f32>>(d: &mut DistMatrix, row_k: &mut [f32]) {
    let n = d.n();
    let row_k = &mut row_k[..n];
    for k in 0..n {
        row_k.copy_from_slice(d.row(k));
        relax_rows_against_sr::<S>(d.as_mut_slice(), n, k, row_k);
    }
}

/// Semiring-generic [`relax_rows_against`]: same 4-row register tiling,
/// with the all-lanes-live fast path gated on `is_absorbing`.
fn relax_rows_against_sr<S: Semiring<Elem = f32>>(
    data: &mut [f32],
    n: usize,
    k: usize,
    row_k: &[f32],
) {
    debug_assert_eq!(data.len() % n, 0);
    for quad in data.chunks_mut(4 * n) {
        if quad.len() == 4 * n {
            let (r0, rest) = quad.split_at_mut(n);
            let (r1, rest) = rest.split_at_mut(n);
            let (r2, r3) = rest.split_at_mut(n);
            let (d0, d1, d2, d3) = (r0[k], r1[k], r2[k], r3[k]);
            if !S::is_absorbing(d0)
                && !S::is_absorbing(d1)
                && !S::is_absorbing(d2)
                && !S::is_absorbing(d3)
            {
                S::relax_rows4(r0, r1, r2, r3, [d0, d1, d2, d3], row_k);
                continue;
            }
            for (r, dk) in [(r0, d0), (r1, d1), (r2, d2), (r3, d3)] {
                if !S::is_absorbing(dk) {
                    S::relax_row(r, dk, row_k);
                }
            }
        } else {
            for r in quad.chunks_mut(n) {
                let dk = r[k];
                if !S::is_absorbing(dk) {
                    S::relax_row(r, dk, row_k);
                }
            }
        }
    }
}

/// Semiring-generic scalar relax — the always-available per-semiring
/// oracle, pinned to the portable ⊕/⊗ loop (never an instance's SIMD
/// hook). The per-semiring analogue of [`relax_row_scalar`].
#[inline]
pub fn relax_row_scalar_sr<S: Semiring<Elem = f32>>(row_i: &mut [f32], dik: f32, row_k: &[f32]) {
    let m = row_i.len().min(row_k.len());
    for (x, &b) in row_i[..m].iter_mut().zip(&row_k[..m]) {
        *x = S::combine(*x, S::extend(dik, b));
    }
}

/// Semiring-generic [`fw_parallel`]: identical barrier structure, the
/// row sweep routed through the generic microkernels.
pub fn fw_parallel_sr<S: Semiring<Elem = f32>>(d: &mut DistMatrix) {
    let n = d.n();
    let workers = threads::num_threads().min(n / 128).max(1);
    if n < 384 || workers == 1 {
        return fw_rowwise_sr::<S>(d);
    }
    let data_ptr = d.as_mut_slice().as_mut_ptr() as usize;
    let mut row_k = arena::scratch_filled(n, 0.0);
    let row_k_ptr = row_k.as_mut_ptr() as usize;
    let barrier = std::sync::Barrier::new(workers);
    let rows_per = n.div_ceil(workers);
    std::thread::scope(|s| {
        for w in 0..workers {
            let barrier = &barrier;
            s.spawn(move || {
                let lo = w * rows_per;
                let hi = ((w + 1) * rows_per).min(n);
                // SAFETY: identical discipline to `fw_parallel` — workers
                // write disjoint row ranges; the shared pivot-row buffer
                // is written only by worker 0 between two barriers.
                let data = data_ptr as *mut f32;
                let row_k = row_k_ptr as *mut f32;
                for k in 0..n {
                    barrier.wait();
                    if w == 0 {
                        unsafe {
                            std::ptr::copy_nonoverlapping(data.add(k * n), row_k, n);
                        }
                    }
                    barrier.wait();
                    let row_k_slice =
                        unsafe { std::slice::from_raw_parts(row_k as *const f32, n) };
                    if lo < hi {
                        let rows = unsafe {
                            std::slice::from_raw_parts_mut(data.add(lo * n), (hi - lo) * n)
                        };
                        relax_rows_against_sr::<S>(rows, n, k, row_k_slice);
                    }
                }
            });
        }
    });
    drop(row_k);
}

/// Runtime-dispatched serial FW over any shipped semiring (the batch
/// scheduler's serial path uses this when the backend is non-MinPlus).
pub fn fw_rowwise_dyn(d: &mut DistMatrix, sr: SemiringId) {
    match sr {
        SemiringId::MinPlus => fw_rowwise(d),
        _ => crate::dispatch_semiring!(sr, S => fw_rowwise_sr::<S>(d)),
    }
}

/// Runtime-dispatched parallel FW over any shipped semiring.
pub fn fw_parallel_dyn(d: &mut DistMatrix, sr: SemiringId) {
    match sr {
        SemiringId::MinPlus => fw_parallel(d),
        _ => crate::dispatch_semiring!(sr, S => fw_parallel_sr::<S>(d)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, Weights};
    use crate::INF;

    /// Fixture: run every FW variant on its own copy of `d` and return
    /// the results (reference `fw_inplace` first).
    fn fw_all(d: &DistMatrix) -> Vec<DistMatrix> {
        let variants: [fn(&mut DistMatrix); 4] = [fw_inplace, fw_rowwise, fw_parallel, fw_panel];
        variants
            .iter()
            .map(|f| {
                let mut m = d.clone();
                f(&mut m);
                m
            })
            .collect()
    }

    #[test]
    fn tiny_known_answer() {
        // 0 -1-> 1 -2-> 2, plus direct 0->2 weight 5 (shortcut via 1 = 3)
        let mut d = DistMatrix::new_diag0(3);
        d.set(0, 1, 1.0);
        d.set(1, 2, 2.0);
        d.set(0, 2, 5.0);
        let out = fw_all(&d);
        for m in &out {
            assert_eq!(m.get(0, 2), 3.0);
            assert_eq!(m.get(0, 1), 1.0);
            assert!(m.get(2, 0).is_infinite()); // directed
        }
    }

    #[test]
    fn disconnected_stays_inf() {
        let d = DistMatrix::new_diag0(4);
        for m in fw_all(&d) {
            for i in 0..4 {
                for j in 0..4 {
                    if i == j {
                        assert_eq!(m.get(i, j), 0.0);
                    } else {
                        assert_eq!(m.get(i, j), INF);
                    }
                }
            }
        }
    }

    #[test]
    fn implementations_agree_random() {
        for seed in 0..3 {
            let g = generators::random_connected(60, 120, Weights::Uniform(0.5, 3.0), seed);
            let d = g.to_dense();
            let out = fw_all(&d);
            for m in &out[1..] {
                assert_eq!(out[0].max_diff(m), 0.0, "seed {seed}");
            }
        }
    }

    #[test]
    fn parallel_matches_on_larger_matrix() {
        let g = generators::newman_watts_strogatz(400, 5, 0.1, Weights::Uniform(1.0, 9.0), 5);
        let d = g.to_dense();
        let mut a = d.clone();
        fw_rowwise(&mut a);
        let mut b = d.clone();
        fw_parallel(&mut b);
        assert_eq!(a.max_diff(&b), 0.0);
    }

    #[test]
    fn idempotent() {
        // FW(FW(D)) == FW(D): the DP fixed point (up to f32 summation
        // order — a second pass may re-derive a path with different
        // rounding, so allow one ulp-scale epsilon)
        let g = generators::random_connected(40, 80, Weights::Uniform(0.5, 2.0), 7);
        let mut d = g.to_dense();
        fw_rowwise(&mut d);
        let once = d.clone();
        fw_rowwise(&mut d);
        assert!(once.max_diff(&d) < 1e-5);
    }

    #[test]
    fn triangle_inequality_holds() {
        let g = generators::random_connected(50, 100, Weights::Uniform(0.5, 2.0), 9);
        let mut d = g.to_dense();
        fw_parallel(&mut d);
        let n = d.n();
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    let lhs = d.get(i, j);
                    let rhs = d.get(i, k) + d.get(k, j);
                    assert!(
                        lhs <= rhs + 1e-4,
                        "triangle violated: d[{i}][{j}]={lhs} > {rhs}"
                    );
                }
            }
        }
    }

    #[test]
    fn undirected_input_gives_symmetric_output() {
        let g = generators::newman_watts_strogatz(80, 3, 0.2, Weights::Uniform(1.0, 4.0), 3);
        let mut d = g.to_dense();
        fw_parallel(&mut d);
        for i in 0..80 {
            for j in 0..80 {
                assert_eq!(d.get(i, j), d.get(j, i));
            }
        }
    }

    #[test]
    fn relax_row_vector_semantics() {
        let mut row_i = vec![10.0, INF, 3.0, 0.0];
        let row_k = vec![1.0, 2.0, INF, -0.0];
        relax_row(&mut row_i, 4.0, &row_k);
        assert_eq!(row_i, vec![5.0, 6.0, 3.0, 0.0]);
    }

    #[test]
    fn relax_dispatch_uses_fast_path() {
        // the dispatching microkernel (not some bounds-checked detour)
        // must be what the row sweep drives
        let g = generators::random_connected(20, 40, Weights::Uniform(0.5, 2.0), 11);
        let mut d = g.to_dense();
        let before = RELAX_FAST_PATH_ENTRIES.load(Ordering::Relaxed);
        fw_rowwise(&mut d);
        let after = RELAX_FAST_PATH_ENTRIES.load(Ordering::Relaxed);
        assert!(after > before, "row sweep bypassed the relax microkernel");
    }

    #[test]
    fn scratch_variants_match_owned() {
        let g = generators::random_connected(50, 150, Weights::Uniform(0.5, 3.0), 13);
        let d = g.to_dense();
        let n = d.n();
        let mut a = d.clone();
        fw_rowwise(&mut a);
        let mut b = d.clone();
        let mut row_k = vec![0f32; n];
        fw_rowwise_scratch(&mut b, &mut row_k);
        assert_eq!(a.max_diff(&b), 0.0);
        let mut c = d.clone();
        let (mut pr, mut pc) = (vec![0f32; n], vec![0f32; n]);
        fw_panel_scratch(&mut c, &mut pr, &mut pc);
        assert_eq!(a.max_diff(&c), 0.0);
    }

    #[test]
    fn rows4_matches_sequential_relax() {
        let mut rng = crate::util::rng::Rng::new(17);
        for _ in 0..20 {
            let n = 1 + rng.gen_range(40);
            let mk = |rng: &mut crate::util::rng::Rng| -> Vec<f32> {
                (0..n)
                    .map(|_| {
                        if rng.gen_bool(0.2) {
                            INF
                        } else {
                            rng.gen_f32_range(0.0, 9.0)
                        }
                    })
                    .collect()
            };
            let rows: Vec<Vec<f32>> = (0..4).map(|_| mk(&mut rng)).collect();
            let rk = mk(&mut rng);
            let dik = [
                rng.gen_f32_range(0.0, 5.0),
                rng.gen_f32_range(0.0, 5.0),
                INF,
                rng.gen_f32_range(0.0, 5.0),
            ];
            let mut fused = rows.clone();
            {
                let (a, rest) = fused.split_at_mut(1);
                let (b, rest2) = rest.split_at_mut(1);
                let (c, e) = rest2.split_at_mut(1);
                relax_rows4(&mut a[0], &mut b[0], &mut c[0], &mut e[0], dik, &rk);
            }
            let mut seq = rows.clone();
            for (r, &dk) in seq.iter_mut().zip(&dik) {
                if dk < INF {
                    relax_row(r, dk, &rk);
                }
            }
            for (f, s) in fused.iter().zip(&seq) {
                assert_eq!(f, s);
            }
        }
    }

    #[test]
    fn relax_succ_dispatch_matches_scalar_oracle() {
        let mut rng = crate::util::rng::Rng::new(23);
        for case in 0..40 {
            let n = 1 + rng.gen_range(50);
            let mk = |rng: &mut crate::util::rng::Rng| -> Vec<f32> {
                (0..n)
                    .map(|_| {
                        if rng.gen_bool(0.2) {
                            INF
                        } else {
                            rng.gen_f32_range(0.0, 9.0)
                        }
                    })
                    .collect()
            };
            let row = mk(&mut rng);
            let rk = mk(&mut rng);
            let succ: Vec<u32> = (0..n).map(|_| rng.gen_range(n + 1) as u32).collect();
            let dik = rng.gen_f32_range(0.0, 5.0);
            let sik = rng.gen_range(n) as u32;

            let (mut r_a, mut s_a) = (row.clone(), succ.clone());
            relax_row_succ(&mut r_a, dik, &rk, &mut s_a, sik);
            let (mut r_b, mut s_b) = (row.clone(), succ.clone());
            relax_row_succ_scalar(&mut r_b, dik, &rk, &mut s_b, sik);
            assert_eq!(r_a, r_b, "case {case}: dist rows diverged");
            assert_eq!(s_a, s_b, "case {case}: succ rows diverged");

            // cross-check the branch semantics against relax_row: the
            // distances must equal the plain (min-based) kernel's
            let mut r_c = row.clone();
            relax_row(&mut r_c, dik, &rk);
            assert_eq!(r_a, r_c, "case {case}: succ kernel changed distances");
        }
    }

    fn bits_eq(a: &DistMatrix, b: &DistMatrix) -> bool {
        let (x, y) = (a.as_slice(), b.as_slice());
        x.len() == y.len() && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
    }

    #[test]
    fn generic_minplus_is_bit_identical_to_concrete() {
        use crate::apsp::semiring::MinPlus;
        for seed in 0..3 {
            let g = generators::random_connected(70, 160, Weights::Uniform(0.5, 4.0), seed);
            let d = g.to_dense();
            let mut a = d.clone();
            fw_rowwise(&mut a);
            let mut b = d.clone();
            fw_rowwise_sr::<MinPlus>(&mut b);
            assert!(bits_eq(&a, &b), "seed {seed}: rowwise diverged");
            let mut c = d.clone();
            fw_inplace_sr::<MinPlus>(&mut c);
            let mut r = d.clone();
            fw_inplace(&mut r);
            assert!(bits_eq(&r, &c), "seed {seed}: inplace diverged");
        }
    }

    #[test]
    fn generic_parallel_matches_generic_rowwise() {
        use crate::apsp::semiring::ALL_SEMIRINGS;
        for sr in ALL_SEMIRINGS {
            let g = generators::newman_watts_strogatz(400, 5, 0.1, Weights::Uniform(1.0, 9.0), 5);
            let mut a = g.to_dense_sr(sr);
            let mut b = a.clone();
            fw_rowwise_dyn(&mut a, sr);
            fw_parallel_dyn(&mut b, sr);
            assert!(bits_eq(&a, &b), "{:?} parallel diverged from rowwise", sr);
        }
    }

    #[test]
    fn relax_succ_ties_never_update() {
        // cand == cur exactly: strict < must leave both dist and succ
        // untouched on every code path
        let mut row = vec![5.0f32, 3.0, 7.0, 1.0, 5.0, 3.0, 7.0, 1.0, 2.5];
        let rk: Vec<f32> = row.iter().map(|x| x - 2.0).collect();
        let succ0: Vec<u32> = (0..row.len() as u32).collect();
        let mut succ = succ0.clone();
        let before = row.clone();
        relax_row_succ(&mut row, 2.0, &rk, &mut succ, 99);
        assert_eq!(row, before);
        assert_eq!(succ, succ0);
    }
}
