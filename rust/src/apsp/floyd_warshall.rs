//! Floyd–Warshall kernels (paper §II-B1).
//!
//! `D[i][j] = min(D[i][j], D[i][k] + D[k][j])` for every pivot `k` — the
//! dense dynamic program the PCM-FW die executes in-place. Three
//! implementations with identical results:
//!
//! * [`fw_inplace`] — straightforward triple loop (reference).
//! * [`fw_rowwise`] — pivot-row snapshot + vectorizable inner loop; this
//!   is the same "Panel_Row broadcast into the Main_Block" structure the
//!   paper's remapping uses (Fig. 6b), expressed for a CPU cache.
//! * [`fw_parallel`] — `fw_rowwise` with the row sweep fanned out across
//!   threads per pivot (used by the native tile backend and the CPU
//!   baseline).

use crate::graph::dense::DistMatrix;
use crate::util::threads;

/// Reference triple-loop FW. O(n^3) time, in-place.
pub fn fw_inplace(d: &mut DistMatrix) {
    let n = d.n();
    for k in 0..n {
        for i in 0..n {
            let dik = d.get(i, k);
            if !(dik < f32::INFINITY) {
                continue;
            }
            for j in 0..n {
                let cand = dik + d.get(k, j);
                if cand < d.get(i, j) {
                    d.set(i, j, cand);
                }
            }
        }
    }
}

/// Row-wise FW: snapshot the pivot row once per `k`, then stream every
/// row `i` against it. The inner loop is a pure `min(a, b + c)` map that
/// the compiler auto-vectorizes.
pub fn fw_rowwise(d: &mut DistMatrix) {
    let n = d.n();
    let mut row_k = vec![0f32; n];
    for k in 0..n {
        row_k.copy_from_slice(d.row(k));
        let data = d.as_mut_slice();
        for i in 0..n {
            let row_i = &mut data[i * n..(i + 1) * n];
            let dik = row_i[k];
            if !(dik < f32::INFINITY) {
                continue;
            }
            relax_row(row_i, dik, &row_k);
        }
    }
}

/// One FW row update: `row_i[j] = min(row_i[j], dik + row_k[j])`.
/// `dik` must be finite. This is the hot loop of the whole crate.
///
/// Branchless form: `f32::min` compiles to `minps` so LLVM vectorizes
/// the whole loop (the earlier `if cand < row_i[j]` store-guard blocked
/// vectorization — 2x slower; EXPERIMENTS.md §Perf). NaN caveat does not
/// apply: `dik` is finite and `row_k[j]` is never NaN, so `cand` is
/// never NaN. `min(x, inf+w) = x` keeps infinity semantics.
#[inline]
pub fn relax_row(row_i: &mut [f32], dik: f32, row_k: &[f32]) {
    debug_assert_eq!(row_i.len(), row_k.len());
    let m = row_i.len().min(row_k.len());
    let (ri, rk) = (&mut row_i[..m], &row_k[..m]);
    for j in 0..m {
        ri[j] = ri[j].min(dik + rk[j]);
    }
}

/// Parallel FW: worker threads are spawned once for the whole solve and
/// synchronize per pivot with a barrier (two barriers per pivot: one
/// after the pivot-row snapshot, one after the row sweep). Spawning per
/// pivot would cost more than the pivot itself — see EXPERIMENTS.md
/// §Perf. Matches `fw_rowwise` bit-for-bit (same per-row operation
/// order).
pub fn fw_parallel(d: &mut DistMatrix) {
    let n = d.n();
    let workers = threads::num_threads().min(n / 128).max(1);
    if n < 384 || workers == 1 {
        return fw_rowwise(d);
    }
    let data_ptr = d.as_mut_slice().as_mut_ptr() as usize;
    let row_k = vec![0f32; n];
    let row_k_ptr = row_k.as_ptr() as usize;
    let barrier = std::sync::Barrier::new(workers);
    // static row ranges per worker
    let rows_per = n.div_ceil(workers);
    std::thread::scope(|s| {
        for w in 0..workers {
            let barrier = &barrier;
            s.spawn(move || {
                let lo = w * rows_per;
                let hi = ((w + 1) * rows_per).min(n);
                // SAFETY: workers write disjoint row ranges; the shared
                // pivot-row buffer is written only by worker 0, between
                // two barriers that order it against all reads.
                let data = data_ptr as *mut f32;
                let row_k = row_k_ptr as *mut f32;
                for k in 0..n {
                    // close the previous pivot's sweep before snapshotting
                    // row k (its owner may still be relaxing it)
                    barrier.wait();
                    if w == 0 {
                        unsafe {
                            std::ptr::copy_nonoverlapping(data.add(k * n), row_k, n);
                        }
                    }
                    barrier.wait();
                    let row_k_slice =
                        unsafe { std::slice::from_raw_parts(row_k as *const f32, n) };
                    for i in lo..hi {
                        let row_i =
                            unsafe { std::slice::from_raw_parts_mut(data.add(i * n), n) };
                        let dik = row_i[k];
                        if dik < f32::INFINITY {
                            relax_row(row_i, dik, row_k_slice);
                        }
                    }
                }
            });
        }
    });
    drop(row_k);
}

/// FW with a panel decomposition (paper Fig. 6b): the pivot row and
/// column are peeled into panels, and the main block is updated with one
/// add + one min per pivot. Functionally identical to `fw_rowwise`; kept
/// as the direct software analogue of the PCM-FW tile schedule so the
/// simulator's op costs map 1:1 onto code.
pub fn fw_panel(d: &mut DistMatrix) {
    let n = d.n();
    let mut panel_row = vec![0f32; n];
    let mut panel_col = vec![0f32; n];
    for k in 0..n {
        // Panel extraction (permutation unit, Fig. 5d)
        panel_row.copy_from_slice(d.row(k));
        for i in 0..n {
            panel_col[i] = d.get(i, k);
        }
        // Main_Block update: Temp = Panel_Col + Panel_Row (bit-serial
        // add), then selective write where Temp < Main_Block (bit-serial
        // min via sign bit). Pivot row/col are also updated through the
        // same pass (d[k][k] = 0 keeps them fixed).
        let data = d.as_mut_slice();
        for i in 0..n {
            let dik = panel_col[i];
            if !(dik < f32::INFINITY) {
                continue;
            }
            relax_row(&mut data[i * n..(i + 1) * n], dik, &panel_row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, Weights};
    use crate::INF;

    fn fw_all(d: &DistMatrix) -> Vec<DistMatrix> {
        let mut a = d.clone();
        fw_inplace(&mut a);
        let mut b = d.clone();
        fw_rowwise(&mut b);
        let mut c = d.clone();
        fw_parallel(&mut c);
        let mut e = d.clone();
        fw_panel(&mut e);
        vec![a, b, c, e]
    }

    #[test]
    fn tiny_known_answer() {
        // 0 -1-> 1 -2-> 2, plus direct 0->2 weight 5 (shortcut via 1 = 3)
        let mut d = DistMatrix::new_diag0(3);
        d.set(0, 1, 1.0);
        d.set(1, 2, 2.0);
        d.set(0, 2, 5.0);
        let out = fw_all(&d);
        for m in &out {
            assert_eq!(m.get(0, 2), 3.0);
            assert_eq!(m.get(0, 1), 1.0);
            assert!(m.get(2, 0).is_infinite()); // directed
        }
    }

    #[test]
    fn disconnected_stays_inf() {
        let d = DistMatrix::new_diag0(4);
        for m in fw_all(&d) {
            for i in 0..4 {
                for j in 0..4 {
                    if i == j {
                        assert_eq!(m.get(i, j), 0.0);
                    } else {
                        assert_eq!(m.get(i, j), INF);
                    }
                }
            }
        }
    }

    #[test]
    fn implementations_agree_random() {
        for seed in 0..3 {
            let g = generators::random_connected(60, 120, Weights::Uniform(0.5, 3.0), seed);
            let d = g.to_dense();
            let out = fw_all(&d);
            for m in &out[1..] {
                assert_eq!(out[0].max_diff(m), 0.0, "seed {seed}");
            }
        }
    }

    #[test]
    fn parallel_matches_on_larger_matrix() {
        let g = generators::newman_watts_strogatz(400, 5, 0.1, Weights::Uniform(1.0, 9.0), 5);
        let d = g.to_dense();
        let mut a = d.clone();
        fw_rowwise(&mut a);
        let mut b = d.clone();
        fw_parallel(&mut b);
        assert_eq!(a.max_diff(&b), 0.0);
    }

    #[test]
    fn idempotent() {
        // FW(FW(D)) == FW(D): the DP fixed point (up to f32 summation
        // order — a second pass may re-derive a path with different
        // rounding, so allow one ulp-scale epsilon)
        let g = generators::random_connected(40, 80, Weights::Uniform(0.5, 2.0), 7);
        let mut d = g.to_dense();
        fw_rowwise(&mut d);
        let once = d.clone();
        fw_rowwise(&mut d);
        assert!(once.max_diff(&d) < 1e-5);
    }

    #[test]
    fn triangle_inequality_holds() {
        let g = generators::random_connected(50, 100, Weights::Uniform(0.5, 2.0), 9);
        let mut d = g.to_dense();
        fw_parallel(&mut d);
        let n = d.n();
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    let lhs = d.get(i, j);
                    let rhs = d.get(i, k) + d.get(k, j);
                    assert!(
                        lhs <= rhs + 1e-4,
                        "triangle violated: d[{i}][{j}]={lhs} > {rhs}"
                    );
                }
            }
        }
    }

    #[test]
    fn undirected_input_gives_symmetric_output() {
        let g = generators::newman_watts_strogatz(80, 3, 0.2, Weights::Uniform(1.0, 4.0), 3);
        let mut d = g.to_dense();
        fw_parallel(&mut d);
        for i in 0..80 {
            for j in 0..80 {
                assert_eq!(d.get(i, j), d.get(j, i));
            }
        }
    }

    #[test]
    fn relax_row_vector_semantics() {
        let mut row_i = vec![10.0, INF, 3.0, 0.0];
        let row_k = vec![1.0, 2.0, INF, -0.0];
        relax_row(&mut row_i, 4.0, &row_k);
        assert_eq!(row_i, vec![5.0, 6.0, 3.0, 0.0]);
    }
}
