//! Query front-end over solved APSP results: packed next-hop maps for
//! O(path-len) reconstruction, plus the query-script grammar the serve
//! mode executes.
//!
//! # Next-hop encoding
//!
//! [`NextHopMatrix`] stores `succ[u][v]` — the first hop on a shortest
//! `u -> v` path — as packed successor ids (bit_gossip's packed
//! next-node maps are the idiom: near-constant-time `next_node`,
//! `path_to` as repeated lookup). Ids are u32 with a u16 small-graph
//! specialization (`n <= 65535` leaves `u16::MAX` free as the "no
//! path" sentinel), halving the resident bytes for the graphs that fit.
//!
//! The map is computed *alongside* the FW solve by
//! [`solve_next_hops`]: the row sweep drives the successor-threaded
//! relax microkernel ([`super::floyd_warshall::relax_row_succ`]), whose
//! recurrence is `succ[i][j] := succ[i][k]` exactly where the pivot
//! strictly improves `d[i][j]`. One scalar `succ[i][k]` broadcast per
//! row is the only successor state the kernel reads, so the sweep keeps
//! the same pivot-row-snapshot shape as `fw_rowwise`.
//! [`solve_next_hops_oracle`] is the feature-parity scalar build; the
//! two are bit-identical (pinned by `tests/query_properties.rs`).
//!
//! `dist(u,v)` is one load; `path(u,v)` is one lookup per hop — no
//! Dijkstra fallback anywhere on the read path.
//!
//! # Query scripts
//!
//! One query per line, `#` comments, blank lines separate batches (the
//! serve loop applies one delta batch between query batches):
//!
//! ```text
//! dist 0 17            # point lookup
//! path 3 9 @gold       # reconstruct the full hop list (tenant "gold")
//! knear 4 8            # the 8 nearest other nodes by distance
//! reach 2              # how many nodes are reachable from 2
//! ```
//!
//! A trailing `@name` token assigns the query to a tenant stream
//! (default tenant otherwise); [`validate_queries`] rejects
//! out-of-range endpoints and degenerate k-nearest parameters with
//! clean `util::error`s before the serve loop touches any state.

use super::floyd_warshall;
use crate::graph::csr::CsrGraph;
use crate::graph::dense::DistMatrix;
use crate::util::arena;
use crate::util::error::Result;
use crate::{bail, ensure};

/// Successor id meaning "no next hop" (unreachable pair).
pub const NO_HOP: u32 = u32::MAX;

/// Packed successor ids: u16 when every id plus the sentinel fits,
/// u32 otherwise. The unpacked accessor always speaks u32.
#[derive(Debug, Clone, PartialEq, Eq)]
enum SuccStore {
    U16(Vec<u16>),
    U32(Vec<u32>),
}

/// Packed next-hop matrix: `succ[u][v]` is the first hop on a shortest
/// `u -> v` path (`v` itself for a direct edge, `u` on the diagonal),
/// or the sentinel for unreachable pairs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NextHopMatrix {
    n: usize,
    store: SuccStore,
}

impl NextHopMatrix {
    /// Pack a row-major u32 successor buffer (`NO_HOP` sentinel),
    /// choosing the u16 specialization when the graph is small enough.
    pub fn from_raw(n: usize, raw: Vec<u32>) -> Self {
        assert_eq!(raw.len(), n * n);
        let store = if n <= u16::MAX as usize {
            // ids are < n <= 65535, so u16::MAX is free as the sentinel
            SuccStore::U16(
                raw.iter()
                    .map(|&s| if s == NO_HOP { u16::MAX } else { s as u16 })
                    .collect(),
            )
        } else {
            SuccStore::U32(raw)
        };
        Self { n, store }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// First hop on a shortest `u -> v` path, `None` if unreachable.
    #[inline]
    pub fn next_hop(&self, u: usize, v: usize) -> Option<u32> {
        debug_assert!(u < self.n && v < self.n);
        match &self.store {
            SuccStore::U16(s) => match s[u * self.n + v] {
                u16::MAX => None,
                hop => Some(hop as u32),
            },
            SuccStore::U32(s) => match s[u * self.n + v] {
                NO_HOP => None,
                hop => Some(hop),
            },
        }
    }

    /// Reconstruct the full hop list `[u, ..., v]` into `out`
    /// (cleared first). Returns `false` for unreachable pairs. One
    /// next-hop lookup per hop — O(path-len), no allocation beyond
    /// `out`'s capacity.
    pub fn path_into(&self, u: usize, v: usize, out: &mut Vec<u32>) -> bool {
        out.clear();
        let mut cur = u;
        out.push(u as u32);
        // hop budget: a consistent successor map over non-negative
        // weights can't revisit a node, so > n hops means corruption
        for _ in 0..self.n {
            if cur == v {
                return true;
            }
            match self.next_hop(cur, v) {
                None => {
                    out.clear();
                    return false;
                }
                Some(hop) => {
                    out.push(hop);
                    cur = hop as usize;
                }
            }
        }
        cur == v
    }

    /// [`NextHopMatrix::path_into`] returning an owned hop list.
    pub fn path(&self, u: usize, v: usize) -> Option<Vec<u32>> {
        let mut out = Vec::new();
        if self.path_into(u, v, &mut out) {
            Some(out)
        } else {
            None
        }
    }

    /// Resident bytes of the packed store.
    pub fn bytes(&self) -> usize {
        match &self.store {
            SuccStore::U16(s) => s.len() * 2,
            SuccStore::U32(s) => s.len() * 4,
        }
    }

    /// Bit width of the packed ids (16 for the small-graph
    /// specialization, 32 otherwise) — for reports.
    pub fn width_bits(&self) -> usize {
        match &self.store {
            SuccStore::U16(_) => 16,
            SuccStore::U32(_) => 32,
        }
    }
}

/// Dense FW solve that threads successor updates through the
/// dispatched (SIMD-capable) relax microkernel. Returns the distance
/// matrix and the packed next-hop map together — the pair a serve
/// snapshot publishes.
pub fn solve_next_hops(g: &CsrGraph) -> (DistMatrix, NextHopMatrix) {
    solve_next_hops_impl(g, false)
}

/// Feature-parity scalar oracle for [`solve_next_hops`]: the same
/// sweep driving only `relax_row_succ_scalar`. Bit-identical output
/// (strict-`<` update on both paths) — the property suite pins it.
pub fn solve_next_hops_oracle(g: &CsrGraph) -> (DistMatrix, NextHopMatrix) {
    solve_next_hops_impl(g, true)
}

fn solve_next_hops_impl(g: &CsrGraph, force_scalar: bool) -> (DistMatrix, NextHopMatrix) {
    let n = g.n();
    let mut dist = g.to_dense();
    let mut succ = vec![NO_HOP; n * n];
    // base cases: the first hop of a direct edge is the edge itself,
    // and the diagonal points at itself (path reconstruction stops on
    // arrival anyway, but a self-hop keeps `succ[i][k]` well-defined
    // for the k == i pivot reads)
    for u in 0..n {
        succ[u * n + u] = u as u32;
        let row = dist.row(u);
        for (v, s) in succ[u * n..(u + 1) * n].iter_mut().enumerate() {
            if v != u && row[v].is_finite() {
                *s = v as u32;
            }
        }
    }
    let mut row_k = arena::scratch_filled(n, 0.0);
    for k in 0..n {
        row_k[..n].copy_from_slice(dist.row(k));
        let data = dist.as_mut_slice();
        for i in 0..n {
            let dik = data[i * n + k];
            if !(dik < f32::INFINITY) {
                continue;
            }
            let sik = succ[i * n + k];
            let row_i = &mut data[i * n..(i + 1) * n];
            let succ_i = &mut succ[i * n..(i + 1) * n];
            if force_scalar {
                floyd_warshall::relax_row_succ_scalar(row_i, dik, &row_k[..n], succ_i, sik);
            } else {
                floyd_warshall::relax_row_succ(row_i, dik, &row_k[..n], succ_i, sik);
            }
        }
    }
    drop(row_k);
    (dist, NextHopMatrix::from_raw(n, succ))
}

/// One read request against a solved graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Query {
    /// Point lookup: `dist(u, v)`.
    Dist { u: u32, v: u32 },
    /// Full path reconstruction `u -> v` over the next-hop map.
    Path { u: u32, v: u32 },
    /// The `k` nearest other nodes from `u`, by (distance, id).
    KNearest { u: u32, k: u32 },
    /// How many other nodes are reachable from `u`.
    Reach { u: u32 },
}

impl Query {
    /// Source node — the batching key (source-major row reuse).
    pub fn source(&self) -> u32 {
        match *self {
            Query::Dist { u, .. }
            | Query::Path { u, .. }
            | Query::KNearest { u, .. }
            | Query::Reach { u } => u,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Query::Dist { .. } => "dist",
            Query::Path { .. } => "path",
            Query::KNearest { .. } => "knear",
            Query::Reach { .. } => "reach",
        }
    }
}

/// A query tagged with its tenant stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueryReq {
    /// Index into [`QueryScript::tenants`].
    pub tenant: u16,
    pub query: Query,
}

/// A parsed query script: interned tenant names plus the query batches
/// in script order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QueryScript {
    pub tenants: Vec<String>,
    pub batches: Vec<Vec<QueryReq>>,
}

impl QueryScript {
    pub fn total_queries(&self) -> usize {
        self.batches.iter().map(|b| b.len()).sum()
    }
}

/// Parse a query script (grammar in the module docs): `dist u v`,
/// `path u v`, `knear u k`, `reach u`, optional trailing `@tenant`,
/// `#` comments, blank lines separate batches.
pub fn parse_query_script(text: &str) -> Result<QueryScript> {
    let mut tenants: Vec<String> = vec!["default".to_string()];
    let mut batches: Vec<Vec<QueryReq>> = Vec::new();
    let mut cur: Vec<QueryReq> = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            if !cur.is_empty() {
                batches.push(std::mem::take(&mut cur));
            }
            continue;
        }
        let mut toks: Vec<&str> = line.split_whitespace().collect();
        let tenant = match toks.last() {
            Some(last) if last.starts_with('@') => {
                let name = &last[1..];
                ensure!(!name.is_empty(), "line {}: empty tenant name", ln + 1);
                toks.pop();
                match tenants.iter().position(|t| t == name) {
                    Some(i) => i as u16,
                    None => {
                        ensure!(
                            tenants.len() < u16::MAX as usize,
                            "line {}: too many tenants",
                            ln + 1
                        );
                        tenants.push(name.to_string());
                        (tenants.len() - 1) as u16
                    }
                }
            }
            _ => 0,
        };
        let op = *toks.first().unwrap_or(&"");
        let parse_u32 = |s: Option<&&str>, name: &str| -> Result<u32> {
            let s = s.ok_or_else(|| crate::err!("line {}: {op} missing {name}", ln + 1))?;
            s.parse()
                .map_err(|_| crate::err!("line {}: bad {name} {s:?}", ln + 1))
        };
        let query = match op {
            "dist" | "path" => {
                let u = parse_u32(toks.get(1), "u")?;
                let v = parse_u32(toks.get(2), "v")?;
                if op == "dist" {
                    Query::Dist { u, v }
                } else {
                    Query::Path { u, v }
                }
            }
            "knear" => Query::KNearest {
                u: parse_u32(toks.get(1), "u")?,
                k: parse_u32(toks.get(2), "k")?,
            },
            "reach" => Query::Reach {
                u: parse_u32(toks.get(1), "u")?,
            },
            other => bail!("line {}: unknown query op {other:?}", ln + 1),
        };
        let expected = match query {
            Query::Dist { .. } | Query::Path { .. } | Query::KNearest { .. } => 3,
            Query::Reach { .. } => 2,
        };
        ensure!(
            toks.len() == expected,
            "line {}: trailing tokens after {op}",
            ln + 1
        );
        cur.push(QueryReq { tenant, query });
    }
    if !cur.is_empty() {
        batches.push(cur);
    }
    ensure!(!batches.is_empty(), "query script contains no queries");
    Ok(QueryScript { tenants, batches })
}

/// Validate a parsed script against the graph it will be served from:
/// endpoints in range, `1 <= k < n` for k-nearest. Clean errors before
/// the serve loop touches any state.
pub fn validate_queries(n: usize, script: &QueryScript) -> Result<()> {
    ensure!(n > 0, "cannot serve queries: base graph is empty");
    for (b, batch) in script.batches.iter().enumerate() {
        ensure!(!batch.is_empty(), "query batch {b} is empty");
        for (i, req) in batch.iter().enumerate() {
            let q = &req.query;
            let kind = q.kind();
            let check = |node: u32| -> Result<()> {
                ensure!(
                    (node as usize) < n,
                    "query {i} in batch {b} ({kind}): node {node} out of range \
                     (graph has {n} vertices)"
                );
                Ok(())
            };
            match *q {
                Query::Dist { u, v } | Query::Path { u, v } => {
                    check(u)?;
                    check(v)?;
                }
                Query::KNearest { u, k } => {
                    check(u)?;
                    ensure!(
                        k >= 1,
                        "query {i} in batch {b} (knear): k = 0 is degenerate (no neighbors asked)"
                    );
                    ensure!(
                        (k as usize) < n,
                        "query {i} in batch {b} (knear): k = {k} but the graph has only {} \
                         other nodes",
                        n - 1
                    );
                }
                Query::Reach { u } => check(u)?,
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, Weights};
    use crate::INF;

    #[test]
    fn next_hops_on_line_graph() {
        // 0 -1- 1 -2- 2 -4- 3 (undirected)
        let g = CsrGraph::from_undirected_edges(
            4,
            &[(0, 1, 1.0), (1, 2, 2.0), (2, 3, 4.0)],
        );
        let (dist, next) = solve_next_hops(&g);
        assert_eq!(dist.get(0, 3), 7.0);
        assert_eq!(next.next_hop(0, 3), Some(1));
        assert_eq!(next.next_hop(1, 3), Some(2));
        assert_eq!(next.path(0, 3), Some(vec![0, 1, 2, 3]));
        assert_eq!(next.path(3, 0), Some(vec![3, 2, 1, 0]));
        assert_eq!(next.path(2, 2), Some(vec![2]));
    }

    #[test]
    fn shortcut_beats_direct_edge() {
        // direct 0->2 weight 5, via 1 = 3: the next hop must be 1
        let g = CsrGraph::from_edges(3, &[(0, 2, 5.0), (0, 1, 1.0), (1, 2, 2.0)]);
        let (dist, next) = solve_next_hops(&g);
        assert_eq!(dist.get(0, 2), 3.0);
        assert_eq!(next.next_hop(0, 2), Some(1));
    }

    #[test]
    fn unreachable_has_no_hop() {
        let g = CsrGraph::from_undirected_edges(4, &[(0, 1, 1.0), (2, 3, 1.0)]);
        let (dist, next) = solve_next_hops(&g);
        assert_eq!(dist.get(0, 2), INF);
        assert_eq!(next.next_hop(0, 2), None);
        assert_eq!(next.path(0, 2), None);
        let mut buf = vec![99];
        assert!(!next.path_into(0, 3, &mut buf));
        assert!(buf.is_empty());
    }

    #[test]
    fn paths_are_real_and_weights_match_dist() {
        for seed in 0..3 {
            let g = generators::random_connected(60, 140, Weights::Uniform(0.5, 4.0), seed);
            let (dist, next) = solve_next_hops(&g);
            let fw = {
                let mut d = g.to_dense();
                super::floyd_warshall::fw_rowwise(&mut d);
                d
            };
            // distances agree with the plain kernel up to f32 path
            // association (strict-< vs min tie handling can pick a
            // different but equal-cost association)
            assert!(dist.max_diff(&fw) < 1e-4, "seed {seed}");
            for u in (0..g.n()).step_by(7) {
                for v in (0..g.n()).step_by(5) {
                    let p = next.path(u, v).expect("connected graph");
                    assert_eq!(p[0], u as u32);
                    assert_eq!(*p.last().unwrap(), v as u32);
                    let mut w = 0f32;
                    for hop in p.windows(2) {
                        let ew = g
                            .edge_weight(hop[0] as usize, hop[1] as usize)
                            .expect("path hop must be a real edge");
                        w += ew;
                    }
                    assert!(
                        (w - dist.get(u, v)).abs() < 1e-4,
                        "seed {seed}: path weight {w} vs dist {}",
                        dist.get(u, v)
                    );
                }
            }
        }
    }

    #[test]
    fn small_graph_uses_u16_store() {
        let g = CsrGraph::from_undirected_edges(3, &[(0, 1, 1.0)]);
        let (_, next) = solve_next_hops(&g);
        assert_eq!(next.width_bits(), 16);
        assert_eq!(next.bytes(), 9 * 2);
    }

    #[test]
    fn parse_script_batches_and_tenants() {
        let s = parse_query_script(
            "# header\n\
             dist 0 1\n\
             path 2 3 @gold\n\
             \n\
             knear 1 4 @gold\n\
             reach 0 @bronze\n",
        )
        .unwrap();
        assert_eq!(s.tenants, vec!["default", "gold", "bronze"]);
        assert_eq!(s.batches.len(), 2);
        assert_eq!(s.batches[0].len(), 2);
        assert_eq!(s.batches[0][1].tenant, 1);
        assert_eq!(s.batches[1][0].query, Query::KNearest { u: 1, k: 4 });
        assert_eq!(s.total_queries(), 4);
    }

    #[test]
    fn parse_rejects_malformed_lines() {
        for (script, needle) in [
            ("warp 0 1\n", "unknown query op"),
            ("dist 0\n", "missing v"),
            ("dist 0 x\n", "bad v"),
            ("path 1 2 3\n", "trailing tokens"),
            ("knear 1 2 @\n", "empty tenant"),
            ("# only comments\n\n", "no queries"),
        ] {
            let e = parse_query_script(script).unwrap_err().to_string();
            assert!(e.contains(needle), "script {script:?}: {e}");
        }
    }

    #[test]
    fn validate_rejects_bad_queries() {
        let script = |line: &str| parse_query_script(line).unwrap();
        for (line, needle) in [
            ("dist 0 99\n", "out of range"),
            ("knear 0 0\n", "k = 0"),
            ("knear 0 10\n", "other nodes"),
        ] {
            let e = validate_queries(10, &script(line)).unwrap_err().to_string();
            assert!(e.contains(needle), "line {line:?}: {e}");
        }
        assert!(validate_queries(10, &script("dist 0 9\nknear 3 9\n")).is_ok());
        let e = validate_queries(0, &script("dist 0 1\n"))
            .unwrap_err()
            .to_string();
        assert!(e.contains("base graph is empty"), "{e}");
    }
}
