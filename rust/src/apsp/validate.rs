//! Cross-implementation validation: every DP path in the crate can be
//! checked against an independent scalar oracle, either exhaustively
//! (full matrix) or by sampling (scalable).
//!
//! Each semiring workload has its own oracle, none of which share code
//! with the tile kernels:
//!
//! * min-plus — repeated Dijkstra ([`super::dijkstra`])
//! * bool-and-or — breadth-first search
//! * max-min — modified Dijkstra maximizing the bottleneck edge
//! * max-plus — longest-path DP over a Kahn topological order (DAGs)

use super::dijkstra;
use super::recursive::ApspSolution;
use super::semiring::SemiringId;
use crate::graph::csr::CsrGraph;
use crate::graph::dense::DistMatrix;
use crate::util::rng::Rng;
use std::collections::VecDeque;

/// Result of a validation pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Validation {
    pub checked: usize,
    pub max_abs_err: f32,
    pub mismatches: usize,
}

impl Validation {
    pub fn ok(&self, tol: f32) -> bool {
        self.mismatches == 0 && self.max_abs_err <= tol
    }
}

/// Compare one matrix entry against the oracle. Finite pairs contribute
/// to the error band; non-finite entries must agree *exactly* — `+INF`
/// vs `-INF` is a real mismatch (the max-plus background is `-INF`, so
/// "both infinite" no longer implies "both unreachable").
fn record(a: f32, b: f32, tol: f32, max_err: &mut f32, mismatches: &mut usize) {
    match (a.is_finite(), b.is_finite()) {
        (true, true) => {
            let e = (a - b).abs();
            if e > *max_err {
                *max_err = e;
            }
            if e > tol {
                *mismatches += 1;
            }
        }
        _ => {
            if a != b {
                *mismatches += 1;
            }
        }
    }
}

/// BFS reachability row: 1.0 for every vertex reachable from `src`
/// (including `src` itself), 0.0 otherwise.
fn reach_row(g: &CsrGraph, src: usize) -> Vec<f32> {
    let n = g.n();
    let mut seen = vec![false; n];
    let mut queue = VecDeque::new();
    seen[src] = true;
    queue.push_back(src);
    while let Some(u) = queue.pop_front() {
        for (v, _) in g.neighbors(u) {
            if !seen[v] {
                seen[v] = true;
                queue.push_back(v);
            }
        }
    }
    seen.iter().map(|&s| if s { 1.0 } else { 0.0 }).collect()
}

/// Max-heap key with a total order (the oracle graphs contain no NaN).
#[derive(PartialEq)]
struct Bottleneck(f32);
impl Eq for Bottleneck {}
impl PartialOrd for Bottleneck {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Bottleneck {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Widest-path row: modified Dijkstra maximizing the minimum edge
/// weight along the path. `src` itself gets `INF` (the max-min
/// multiplicative identity); unreachable vertices get 0.0.
fn widest_row(g: &CsrGraph, src: usize) -> Vec<f32> {
    let n = g.n();
    let mut best = vec![0f32; n];
    best[src] = f32::INFINITY;
    let mut heap = std::collections::BinaryHeap::new();
    heap.push((Bottleneck(f32::INFINITY), src as u32));
    while let Some((Bottleneck(w), u)) = heap.pop() {
        let u = u as usize;
        if w < best[u] {
            continue;
        }
        for (v, ew) in g.neighbors(u) {
            let cand = w.min(ew);
            if cand > best[v] {
                best[v] = cand;
                heap.push((Bottleneck(cand), v as u32));
            }
        }
    }
    best
}

/// Longest-path rows on a DAG: DP over one shared Kahn topological
/// order. `src` gets 0.0; unreachable vertices get `-INF`. Panics if
/// the graph has a cycle (the critical-path workload guards with
/// [`CsrGraph::assert_acyclic`] before solving).
fn critical_rows(g: &CsrGraph, srcs: &[usize]) -> Vec<Vec<f32>> {
    let n = g.n();
    let mut indeg = vec![0usize; n];
    for u in 0..n {
        for (v, _) in g.neighbors(u) {
            indeg[v] += 1;
        }
    }
    let mut queue: VecDeque<usize> = (0..n).filter(|&v| indeg[v] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while let Some(u) = queue.pop_front() {
        order.push(u);
        for (v, _) in g.neighbors(u) {
            indeg[v] -= 1;
            if indeg[v] == 0 {
                queue.push_back(v);
            }
        }
    }
    assert_eq!(order.len(), n, "critical-path oracle requires a DAG");
    srcs.iter()
        .map(|&src| {
            let mut best = vec![f32::NEG_INFINITY; n];
            best[src] = 0.0;
            for &u in &order {
                if best[u] == f32::NEG_INFINITY {
                    continue;
                }
                for (v, w) in g.neighbors(u) {
                    let cand = best[u] + w;
                    if cand > best[v] {
                        best[v] = cand;
                    }
                }
            }
            best
        })
        .collect()
}

/// Oracle rows for any workload semiring: one independent scalar
/// algorithm per instance, none of them sharing code with the tile
/// kernels under test.
pub fn oracle_rows(g: &CsrGraph, sr: SemiringId, srcs: &[usize]) -> Vec<Vec<f32>> {
    match sr {
        SemiringId::MinPlus => dijkstra::sampled_rows(g, srcs),
        SemiringId::BoolAndOr => srcs.iter().map(|&s| reach_row(g, s)).collect(),
        SemiringId::MaxMin => srcs.iter().map(|&s| widest_row(g, s)).collect(),
        SemiringId::MaxPlus => critical_rows(g, srcs),
    }
}

/// Exhaustive check of a full matrix against the Dijkstra oracle.
pub fn validate_full(g: &CsrGraph, got: &DistMatrix, tol: f32) -> Validation {
    let oracle = dijkstra::apsp(g);
    let n = g.n();
    let mut max_err = 0f32;
    let mut mismatches = 0usize;
    for i in 0..n {
        for j in 0..n {
            record(got.get(i, j), oracle.get(i, j), tol, &mut max_err, &mut mismatches);
        }
    }
    Validation {
        checked: n * n,
        max_abs_err: max_err,
        mismatches,
    }
}

/// Exhaustive check of a full matrix against the workload's own oracle.
pub fn validate_full_sr(g: &CsrGraph, sr: SemiringId, got: &DistMatrix, tol: f32) -> Validation {
    let n = g.n();
    let srcs: Vec<usize> = (0..n).collect();
    let rows = oracle_rows(g, sr, &srcs);
    let mut max_err = 0f32;
    let mut mismatches = 0usize;
    for i in 0..n {
        for j in 0..n {
            record(got.get(i, j), rows[i][j], tol, &mut max_err, &mut mismatches);
        }
    }
    Validation {
        checked: n * n,
        max_abs_err: max_err,
        mismatches,
    }
}

/// Sampled validation of a recursive solution: `sources` random rows are
/// solved with Dijkstra and compared against `sol.query` on `cols_per`
/// random columns each. Scales to any graph the solver handles.
pub fn validate_sampled(
    g: &CsrGraph,
    sol: &ApspSolution,
    sources: usize,
    cols_per: usize,
    tol: f32,
    seed: u64,
) -> Validation {
    validate_sampled_sr(g, SemiringId::MinPlus, sol, sources, cols_per, tol, seed)
}

/// [`validate_sampled`] against the workload's own oracle. The random
/// source/column draws are seed-stable across workloads.
#[allow(clippy::too_many_arguments)]
pub fn validate_sampled_sr(
    g: &CsrGraph,
    sr: SemiringId,
    sol: &ApspSolution,
    sources: usize,
    cols_per: usize,
    tol: f32,
    seed: u64,
) -> Validation {
    let n = g.n();
    let mut rng = Rng::new(seed);
    let srcs: Vec<usize> = (0..sources.min(n)).map(|_| rng.gen_range(n)).collect();
    let rows = oracle_rows(g, sr, &srcs);
    let mut max_err = 0f32;
    let mut mismatches = 0usize;
    let mut checked = 0usize;
    for (si, &src) in srcs.iter().enumerate() {
        for _ in 0..cols_per.min(n) {
            let v = rng.gen_range(n);
            let got = sol.query(src, v);
            let want = rows[si][v];
            checked += 1;
            record(got, want, tol, &mut max_err, &mut mismatches);
        }
    }
    Validation {
        checked,
        max_abs_err: max_err,
        mismatches,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::backend::NativeBackend;
    use crate::apsp::floyd_warshall::fw_rowwise_dyn;
    use crate::apsp::plan::{build_plan, PlanOptions};
    use crate::apsp::recursive::{solve, SolveOptions};
    use crate::apsp::semiring::ALL_SEMIRINGS;
    use crate::apsp::{floyd_warshall, partitioned};
    use crate::graph::generators::{self, Weights};
    use crate::INF;

    #[test]
    fn full_validation_passes_for_fw() {
        let g = generators::newman_watts_strogatz(100, 3, 0.1, Weights::Uniform(1.0, 4.0), 1);
        let mut d = g.to_dense();
        floyd_warshall::fw_parallel(&mut d);
        let v = validate_full(&g, &d, 1e-3);
        assert!(v.ok(1e-3), "{v:?}");
        assert_eq!(v.checked, 100 * 100);
    }

    #[test]
    fn full_validation_catches_corruption() {
        let g = generators::newman_watts_strogatz(60, 3, 0.1, Weights::Uniform(1.0, 4.0), 2);
        let mut d = g.to_dense();
        floyd_warshall::fw_parallel(&mut d);
        d.set(3, 7, d.get(3, 7) * 0.5); // corrupt one entry
        let v = validate_full(&g, &d, 1e-3);
        assert!(!v.ok(1e-3));
        assert!(v.mismatches >= 1);
    }

    #[test]
    fn validation_distinguishes_infinity_signs() {
        // two disconnected pairs: the oracle says +INF between them; a
        // -INF in the candidate (a max-plus background leaking into a
        // min-plus matrix) must count as a mismatch, not "both infinite"
        let g = CsrGraph::from_undirected_edges(4, &[(0, 1, 1.0), (2, 3, 1.0)]);
        let mut d = g.to_dense();
        floyd_warshall::fw_rowwise(&mut d);
        assert!(validate_full(&g, &d, 1e-6).ok(1e-6));
        d.set(0, 2, f32::NEG_INFINITY);
        let v = validate_full(&g, &d, 1e-6);
        assert_eq!(v.mismatches, 1, "{v:?}");
    }

    #[test]
    fn every_workload_oracle_agrees_with_generic_fw() {
        for sr in ALL_SEMIRINGS {
            let g = generators::newman_watts_strogatz(80, 3, 0.1, Weights::Uniform(1.0, 4.0), 5);
            let g = if sr == SemiringId::MaxPlus { g.dag_oriented() } else { g };
            let mut d = g.to_dense_sr(sr);
            fw_rowwise_dyn(&mut d, sr);
            let v = validate_full_sr(&g, sr, &d, 1e-3);
            assert!(v.ok(1e-3), "{}: {v:?}", sr.name());
        }
    }

    #[test]
    fn widest_oracle_on_known_graph() {
        // 0 -2.0- 1 -5.0- 2 plus direct 0 -3.0- 2: the widest 0->2 path
        // is the direct edge (bottleneck 3.0) vs min(2.0, 5.0) = 2.0
        let g = CsrGraph::from_undirected_edges(3, &[(0, 1, 2.0), (1, 2, 5.0), (0, 2, 3.0)]);
        let rows = oracle_rows(&g, SemiringId::MaxMin, &[0]);
        assert_eq!(rows[0][2], 3.0);
        assert_eq!(rows[0][1], 2.0);
        assert_eq!(rows[0][0], INF);
    }

    #[test]
    fn critical_oracle_on_known_dag() {
        // directed chain 0->1->2 (weights 1, 2) plus shortcut 0->2
        // (1.5): the *longest* 0->2 path scores 3.0
        let g = CsrGraph::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0), (0, 2, 1.5)]);
        let rows = oracle_rows(&g, SemiringId::MaxPlus, &[0, 2]);
        assert_eq!(rows[0][2], 3.0);
        assert_eq!(rows[0][0], 0.0);
        assert_eq!(rows[1][0], f32::NEG_INFINITY);
    }

    #[test]
    fn sampled_validation_passes_for_recursive() {
        let g = generators::ogbn_proxy(400, 10.0, Weights::Uniform(1.0, 3.0), 3);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 64,
                max_depth: usize::MAX,
                seed: 3,
            },
        );
        let be = NativeBackend;
        let sol = solve(&g, &plan, Some(&be), SolveOptions::default());
        let v = validate_sampled(&g, &sol, 20, 30, 1e-3, 99);
        assert!(v.ok(1e-3), "{v:?}");
        assert!(v.checked >= 400);
    }

    #[test]
    fn partitioned_and_recursive_agree() {
        let g = generators::newman_watts_strogatz(180, 3, 0.12, Weights::Uniform(1.0, 6.0), 4);
        let alg1 = partitioned::partitioned_apsp(&g, 32, 4);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 32,
                max_depth: usize::MAX,
                seed: 4,
            },
        );
        let be = NativeBackend;
        let sol = solve(&g, &plan, Some(&be), SolveOptions::default());
        let alg2 = sol.materialize_full(&be);
        assert!(alg1.max_diff(&alg2) < 1e-3);
    }
}
