//! Cross-implementation validation: every APSP path in the crate can be
//! checked against repeated Dijkstra, either exhaustively (full matrix)
//! or by sampling (scalable).

use super::dijkstra;
use super::recursive::ApspSolution;
use crate::graph::csr::CsrGraph;
use crate::graph::dense::DistMatrix;
use crate::util::rng::Rng;

/// Result of a validation pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Validation {
    pub checked: usize,
    pub max_abs_err: f32,
    pub mismatches: usize,
}

impl Validation {
    pub fn ok(&self, tol: f32) -> bool {
        self.mismatches == 0 && self.max_abs_err <= tol
    }
}

/// Exhaustive check of a full matrix against the Dijkstra oracle.
pub fn validate_full(g: &CsrGraph, got: &DistMatrix, tol: f32) -> Validation {
    let oracle = dijkstra::apsp(g);
    let n = g.n();
    let mut max_err = 0f32;
    let mut mismatches = 0usize;
    for i in 0..n {
        for j in 0..n {
            let a = got.get(i, j);
            let b = oracle.get(i, j);
            match (a.is_finite(), b.is_finite()) {
                (true, true) => {
                    let e = (a - b).abs();
                    if e > max_err {
                        max_err = e;
                    }
                    if e > tol {
                        mismatches += 1;
                    }
                }
                (false, false) => {}
                _ => mismatches += 1,
            }
        }
    }
    Validation {
        checked: n * n,
        max_abs_err: max_err,
        mismatches,
    }
}

/// Sampled validation of a recursive solution: `sources` random rows are
/// solved with Dijkstra and compared against `sol.query` on `cols_per`
/// random columns each. Scales to any graph the solver handles.
pub fn validate_sampled(
    g: &CsrGraph,
    sol: &ApspSolution,
    sources: usize,
    cols_per: usize,
    tol: f32,
    seed: u64,
) -> Validation {
    let n = g.n();
    let mut rng = Rng::new(seed);
    let srcs: Vec<usize> = (0..sources.min(n)).map(|_| rng.gen_range(n)).collect();
    let rows = dijkstra::sampled_rows(g, &srcs);
    let mut max_err = 0f32;
    let mut mismatches = 0usize;
    let mut checked = 0usize;
    for (si, &src) in srcs.iter().enumerate() {
        for _ in 0..cols_per.min(n) {
            let v = rng.gen_range(n);
            let got = sol.query(src, v);
            let want = rows[si][v];
            checked += 1;
            match (got.is_finite(), want.is_finite()) {
                (true, true) => {
                    let e = (got - want).abs();
                    if e > max_err {
                        max_err = e;
                    }
                    if e > tol {
                        mismatches += 1;
                    }
                }
                (false, false) => {}
                _ => mismatches += 1,
            }
        }
    }
    Validation {
        checked,
        max_abs_err: max_err,
        mismatches,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::backend::NativeBackend;
    use crate::apsp::plan::{build_plan, PlanOptions};
    use crate::apsp::recursive::{solve, SolveOptions};
    use crate::apsp::{floyd_warshall, partitioned};
    use crate::graph::generators::{self, Weights};

    #[test]
    fn full_validation_passes_for_fw() {
        let g = generators::newman_watts_strogatz(100, 3, 0.1, Weights::Uniform(1.0, 4.0), 1);
        let mut d = g.to_dense();
        floyd_warshall::fw_parallel(&mut d);
        let v = validate_full(&g, &d, 1e-3);
        assert!(v.ok(1e-3), "{v:?}");
        assert_eq!(v.checked, 100 * 100);
    }

    #[test]
    fn full_validation_catches_corruption() {
        let g = generators::newman_watts_strogatz(60, 3, 0.1, Weights::Uniform(1.0, 4.0), 2);
        let mut d = g.to_dense();
        floyd_warshall::fw_parallel(&mut d);
        d.set(3, 7, d.get(3, 7) * 0.5); // corrupt one entry
        let v = validate_full(&g, &d, 1e-3);
        assert!(!v.ok(1e-3));
        assert!(v.mismatches >= 1);
    }

    #[test]
    fn sampled_validation_passes_for_recursive() {
        let g = generators::ogbn_proxy(400, 10.0, Weights::Uniform(1.0, 3.0), 3);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 64,
                max_depth: usize::MAX,
                seed: 3,
            },
        );
        let be = NativeBackend;
        let sol = solve(&g, &plan, Some(&be), SolveOptions::default());
        let v = validate_sampled(&g, &sol, 20, 30, 1e-3, 99);
        assert!(v.ok(1e-3), "{v:?}");
        assert!(v.checked >= 400);
    }

    #[test]
    fn partitioned_and_recursive_agree() {
        let g = generators::newman_watts_strogatz(180, 3, 0.12, Weights::Uniform(1.0, 6.0), 4);
        let alg1 = partitioned::partitioned_apsp(&g, 32, 4);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 32,
                max_depth: usize::MAX,
                seed: 4,
            },
        );
        let be = NativeBackend;
        let sol = solve(&g, &plan, Some(&be), SolveOptions::default());
        let alg2 = sol.materialize_full(&be);
        assert!(alg1.max_diff(&alg2) < 1e-3);
    }
}
