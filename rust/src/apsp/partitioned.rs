//! Single-level partitioned APSP — the paper's Algorithm 1 (the [10]
//! four-stage scheme), implemented *independently* of the recursive
//! machinery as a cross-validation oracle: it uses the generic
//! `partition::boundary` helpers and dense FW directly, so a bug in the
//! plan/recursion code cannot hide in both implementations.

use super::floyd_warshall::fw_parallel;
use super::minplus::two_stage_merge;
use crate::graph::csr::CsrGraph;
use crate::graph::dense::DistMatrix;
use crate::partition::boundary::{boundary_graph, build_components};
use crate::partition::partition_by_max_size;
use crate::INF;

/// Exact APSP via Algorithm 1: partition once, solve the boundary graph
/// with one dense FW (whatever its size), inject, merge. Materializes
/// the full n x n result — small/medium graphs only.
pub fn partitioned_apsp(g: &CsrGraph, tile_limit: usize, seed: u64) -> DistMatrix {
    let n = g.n();
    if n <= tile_limit {
        let mut d = g.to_dense();
        fw_parallel(&mut d);
        return d;
    }
    // ---- preprocessing: partition + boundary structure (topology
    // affinity — distances are not affinities, see plan::build_plan)
    let unit = CsrGraph {
        rowptr: g.rowptr.clone(),
        col: g.col.clone(),
        val: vec![1.0; g.m()],
    };
    let p = partition_by_max_size(&unit, tile_limit, seed);
    let cs = build_components(g, &p);

    // ---- Step 1: local APSP per component (intra edges only)
    let mut d_intra: Vec<DistMatrix> = cs
        .components
        .iter()
        .enumerate()
        .map(|(ci, c)| {
            let mut d = DistMatrix::new_diag0(c.n());
            let pos: std::collections::HashMap<u32, usize> = c
                .verts
                .iter()
                .enumerate()
                .map(|(i, &v)| (v, i))
                .collect();
            for (i, &v) in c.verts.iter().enumerate() {
                for (u, w) in g.neighbors(v as usize) {
                    if cs.comp_of[u] == ci as u32 {
                        d.relax(i, pos[&(u as u32)], w);
                    }
                }
            }
            fw_parallel(&mut d);
            d
        })
        .collect();

    // ---- Step 2: boundary-graph APSP (single dense FW)
    let nb = cs.n_boundary();
    let db = if nb > 0 {
        let gb = boundary_graph(g, &cs, &|ci, bi, bj| d_intra[ci].get(bi, bj));
        let mut db = gb.to_dense();
        fw_parallel(&mut db);
        db
    } else {
        DistMatrix::new_inf(0)
    };

    // boundary-graph ids per component (prefix offsets: boundary ids are
    // assigned component-major by build_components)
    let mut group_start = Vec::with_capacity(cs.components.len());
    let mut acc = 0usize;
    for c in &cs.components {
        group_start.push(acc);
        acc += c.n_boundary;
    }

    // ---- Step 3: boundary injection + FW rerun
    for (ci, c) in cs.components.iter().enumerate() {
        let b = c.n_boundary;
        if b == 0 {
            continue;
        }
        let gs = group_start[ci];
        let dc = &mut d_intra[ci];
        for i in 0..b {
            for j in 0..b {
                dc.relax(i, j, db.get(gs + i, gs + j));
            }
        }
        fw_parallel(dc);
    }

    // ---- assemble intra entries
    let mut out = DistMatrix::new_inf(n);
    for (ci, c) in cs.components.iter().enumerate() {
        let dc = &d_intra[ci];
        for (i, &u) in c.verts.iter().enumerate() {
            for (j, &v) in c.verts.iter().enumerate() {
                let val = dc.get(i, j);
                if val < out.get(u as usize, v as usize) {
                    out.set(u as usize, v as usize, val);
                }
            }
        }
    }

    // ---- Step 4: cross-component MP merges
    let k = cs.components.len();
    for c1 in 0..k {
        let comp1 = &cs.components[c1];
        let (n1, b1) = (comp1.n(), comp1.n_boundary);
        if b1 == 0 {
            continue;
        }
        let gs1 = group_start[c1];
        let d1 = &d_intra[c1];
        let mut a = vec![INF; n1 * b1];
        for i in 0..n1 {
            a[i * b1..(i + 1) * b1].copy_from_slice(&d1.row(i)[..b1]);
        }
        for c2 in 0..k {
            if c1 == c2 {
                continue;
            }
            let comp2 = &cs.components[c2];
            let (n2, b2) = (comp2.n(), comp2.n_boundary);
            if b2 == 0 {
                continue;
            }
            let gs2 = group_start[c2];
            let mut dbb = vec![INF; b1 * b2];
            for i in 0..b1 {
                for j in 0..b2 {
                    dbb[i * b2 + j] = db.get(gs1 + i, gs2 + j);
                }
            }
            let d2 = &d_intra[c2];
            let mut bmat = vec![INF; b2 * n2];
            for j in 0..b2 {
                bmat[j * n2..(j + 1) * n2].copy_from_slice(d2.row(j));
            }
            let strip = two_stage_merge(&a, &dbb, &bmat, n1, b1, b2, n2);
            for (i, &u) in comp1.verts.iter().enumerate() {
                for (j, &v) in comp2.verts.iter().enumerate() {
                    let val = strip[i * n2 + j];
                    if val < out.get(u as usize, v as usize) {
                        out.set(u as usize, v as usize, val);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::dijkstra;
    use crate::graph::generators::{self, Weights};

    #[test]
    fn matches_dijkstra_nws() {
        let g = generators::newman_watts_strogatz(160, 3, 0.15, Weights::Uniform(1.0, 5.0), 1);
        let got = partitioned_apsp(&g, 32, 1);
        let oracle = dijkstra::apsp(&g);
        assert!(got.max_diff(&oracle) < 1e-3);
    }

    #[test]
    fn matches_dijkstra_er() {
        let g = generators::erdos_renyi(100, 420, Weights::Uniform(0.5, 2.0), 2);
        let got = partitioned_apsp(&g, 24, 2);
        let oracle = dijkstra::apsp(&g);
        assert!(got.max_diff(&oracle) < 1e-3);
    }

    #[test]
    fn small_graph_direct() {
        let g = generators::complete(12, Weights::Uniform(1.0, 3.0), 3);
        let got = partitioned_apsp(&g, 1024, 3);
        let oracle = dijkstra::apsp(&g);
        assert!(got.max_diff(&oracle) < 1e-4);
    }

    #[test]
    fn disconnected_components() {
        let g = CsrGraph::from_undirected_edges(
            30,
            &(0..14u32)
                .map(|i| (i, i + 1, 1.0f32))
                .chain((16..29u32).map(|i| (i, i + 1, 1.0)))
                .collect::<Vec<_>>(),
        );
        let got = partitioned_apsp(&g, 8, 4);
        let oracle = dijkstra::apsp(&g);
        assert_eq!(got.max_diff(&oracle), 0.0);
    }
}
