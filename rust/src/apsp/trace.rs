//! Operation trace: the interface between the APSP algorithm (what work
//! exists) and the PIM simulator (what it costs).
//!
//! Both execution modes — functional (real numerics) and estimate
//! (cost-only, for OGBN-scale graphs) — walk the same plan and emit the
//! *identical* trace; the simulator then schedules each step's ops onto
//! the modeled hardware (DESIGN.md "Execution modes").
//!
//! Ops within a [`Step`] are independent and may run in parallel across
//! tiles; steps are sequential (each step consumes the previous one's
//! results, mirroring Algorithm 2's level-by-level structure).

/// Dataflow phase (paper Fig. 4a steps).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// (1) CSR stream-in + densify into PCM compute region
    Load,
    /// (2) intra-component FW on the PCM-FW die
    LocalFw,
    /// (3i) boundary extraction + boundary-graph assembly in HBM3
    BoundaryBuild,
    /// dB injection back into component tiles
    Inject,
    /// boundary-aware FW rerun (Algorithm 1 step 3)
    RerunFw,
    /// (4)(7) cross-partition MP merges on the PCM-MP die
    CrossMerge,
    /// (5) boundary synchronization across partitions in HBM3
    Sync,
    /// (6) CSR compression + FeNAND program
    Store,
    /// terminal dense solve of the last boundary graph
    FinalSolve,
    /// Inter-stack transfer in a sharded run (boundary matrices and dB
    /// injections crossing the modeled stack-to-stack interconnect).
    /// Never emitted by [`super::taskgraph::lower`]; inserted by
    /// [`super::shard`] on cross-stack edges.
    StackXfer,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Load => "load",
            Phase::LocalFw => "local_fw",
            Phase::BoundaryBuild => "boundary_build",
            Phase::Inject => "inject",
            Phase::RerunFw => "rerun_fw",
            Phase::CrossMerge => "cross_merge",
            Phase::Sync => "sync",
            Phase::Store => "store",
            Phase::FinalSolve => "final_solve",
            Phase::StackXfer => "stack_xfer",
        }
    }
}

/// One hardware operation with the sizes the cost model needs.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Stream one component's CSR in and densify (logic-die stream
    /// engine + PCM write of the n x n block).
    LoadComponent { n: u64, nnz: u64 },
    /// One full FW pass over an n x n block on a PCM-FW tile
    /// (n pivots x (bit-serial add + min + permutation)).
    TileFw { n: u64, rerun: bool },
    /// Assemble the boundary graph in HBM3: `nb` vertices, `cross_nnz`
    /// cross edges, plus gathering the per-component boundary blocks
    /// (`gather_elems` distance values).
    BuildBoundary {
        nb: u64,
        cross_nnz: u64,
        gather_elems: u64,
    },
    /// Copy the dB rows/cols for one component back into its tile
    /// (HBM3 -> UCIe -> PCM write of nb^2 values, min-merged).
    Inject { n: u64, nb: u64 },
    /// Aggregated cross-component MP merges (two-stage, Fig. 6d).
    /// `pairs` strips totalling `stage1_madds + stage2_madds` min-add
    /// candidates and `out_elems` result entries. `rows` = total
    /// 1024-way comparator-tree reductions.
    MpMergeAgg {
        pairs: u64,
        stage1_madds: u64,
        stage2_madds: u64,
        out_elems: u64,
        rows: u64,
    },
    /// HBM3 boundary synchronization traffic.
    SyncBoundary { bytes: u64 },
    /// Compress to CSR on the logic die and program FeNAND.
    StoreCsr { dense_elems: u64, csr_bytes: u64 },
    /// Store a dense matrix to FeNAND (boundary matrices, step 6i).
    StoreDense { bytes: u64 },
    /// Fetch interleaved boundary matrices from FeNAND (step 7).
    FetchBoundary { bytes: u64 },
    /// Move `bytes` across the inter-stack interconnect (sharded
    /// execution: boundary matrices to the hub, dB slices back).
    StackXfer { bytes: u64 },
    /// Serve a cached APSP result from the FeNAND result store (a
    /// fingerprint hit in the admission pipeline reads the compressed
    /// distance matrix instead of re-solving). Never emitted by
    /// [`super::taskgraph::lower`]; inserted by [`super::admission`].
    StoreRead { bytes: u64 },
    /// Write a freshly solved distance matrix back into the FeNAND
    /// result store (admission-pipeline miss path). Never emitted by
    /// [`super::taskgraph::lower`]; inserted by [`super::admission`].
    StoreWrite { bytes: u64 },
}

impl Op {
    /// Upper-bound FLOP-equivalents (min-add candidate evaluations) —
    /// used for roofline reporting, not costing.
    pub fn madds(&self) -> u64 {
        match self {
            Op::TileFw { n, .. } => n * n * n,
            Op::MpMergeAgg {
                stage1_madds,
                stage2_madds,
                ..
            } => stage1_madds + stage2_madds,
            _ => 0,
        }
    }
}

/// A group of independent ops at one recursion level.
#[derive(Debug, Clone, PartialEq)]
pub struct Step {
    pub level: u32,
    pub phase: Phase,
    pub ops: Vec<Op>,
}

/// The full trace of one APSP run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub steps: Vec<Step>,
}

impl Trace {
    pub fn push(&mut self, level: u32, phase: Phase, ops: Vec<Op>) {
        if !ops.is_empty() {
            self.steps.push(Step { level, phase, ops });
        }
    }

    /// Total min-add candidates across the trace.
    pub fn total_madds(&self) -> u64 {
        self.steps
            .iter()
            .flat_map(|s| s.ops.iter())
            .map(|o| o.madds())
            .sum()
    }

    /// Count of ops of each phase (test/report helper).
    pub fn phase_op_counts(&self) -> std::collections::HashMap<Phase, usize> {
        let mut m = std::collections::HashMap::new();
        for s in &self.steps {
            *m.entry(s.phase).or_insert(0) += s.ops.len();
        }
        m
    }

    /// Deepest recursion level seen.
    pub fn max_level(&self) -> u32 {
        self.steps.iter().map(|s| s.level).max().unwrap_or(0)
    }

    /// Human-readable one-line-per-step summary.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for s in &self.steps {
            out.push_str(&format!(
                "L{} {:15} x{}\n",
                s.level,
                s.phase.name(),
                s.ops.len()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_skips_empty() {
        let mut t = Trace::default();
        t.push(0, Phase::Load, vec![]);
        assert!(t.steps.is_empty());
        t.push(0, Phase::Load, vec![Op::LoadComponent { n: 8, nnz: 10 }]);
        assert_eq!(t.steps.len(), 1);
    }

    #[test]
    fn madds_accounting() {
        let mut t = Trace::default();
        t.push(0, Phase::LocalFw, vec![Op::TileFw { n: 10, rerun: false }]);
        t.push(
            0,
            Phase::CrossMerge,
            vec![Op::MpMergeAgg {
                pairs: 2,
                stage1_madds: 100,
                stage2_madds: 200,
                out_elems: 50,
                rows: 5,
            }],
        );
        assert_eq!(t.total_madds(), 1000 + 300);
    }

    #[test]
    fn phase_counts() {
        let mut t = Trace::default();
        t.push(
            0,
            Phase::LocalFw,
            vec![
                Op::TileFw { n: 4, rerun: false },
                Op::TileFw { n: 5, rerun: false },
            ],
        );
        t.push(1, Phase::LocalFw, vec![Op::TileFw { n: 6, rerun: false }]);
        let c = t.phase_op_counts();
        assert_eq!(c[&Phase::LocalFw], 3);
        assert_eq!(t.max_level(), 1);
    }
}
