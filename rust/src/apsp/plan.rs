//! Recursion-aware partition planning (paper §III-A).
//!
//! The plan captures the *structure* of the recursive decomposition —
//! which vertices form each component at each level, who is boundary,
//! and the cross-edge graph each boundary level inherits — using
//! topology only. Both execution modes walk the same plan, which is what
//! guarantees estimate-mode cycle counts equal functional-mode counts.
//!
//! Level 0 uses the full multilevel partitioner on the input graph. For
//! levels >= 1 the paper's insight applies directly: the boundary graph
//! of a partitioned level consists of per-component boundary cliques
//! (virtual d_intra edges) plus cross edges, so a *recursion-aware*
//! partitioner can keep each component's boundary set intact and pack
//! whole boundary groups into tiles. Because every boundary group has at
//! most `tile_limit` members (it comes from a component of at most
//! `tile_limit` vertices), whole-group packing is always feasible, no
//! clique ever crosses a part, and the clique edges never need to be
//! materialized — the decomposition stays O(|B| + cut) per level, which
//! is what lets the planner reach OGBN-Products scale.

use crate::graph::csr::CsrGraph;
use crate::partition::boundary::{build_components, ComponentSet};
use crate::partition::{partition_by_max_size, Partition};

/// One level of the recursive decomposition.
#[derive(Debug, Clone)]
pub struct PlanLevel {
    /// Number of vertices in this level's graph.
    pub n: usize,
    /// Components (boundary-first vertex ordering) of this level.
    pub cs: ComponentSet,
    /// This level's graph restricted to cross-component edges, with
    /// vertices renumbered to *boundary ids* — i.e. the next level's
    /// graph minus the (implicit) boundary cliques.
    pub next_cross: CsrGraph,
    /// Start of each component's boundary-id range: component `c`'s
    /// boundary vertices are boundary ids `group_start[c] ..
    /// group_start[c+1]`.
    pub group_start: Vec<usize>,
    /// Intra-component edge count per component (for load costing).
    pub comp_nnz: Vec<u64>,
}

impl PlanLevel {
    pub fn n_boundary(&self) -> usize {
        self.next_cross.n()
    }
    pub fn n_components(&self) -> usize {
        self.cs.components.len()
    }
}

/// The full recursive plan.
#[derive(Debug, Clone)]
pub struct ApspPlan {
    /// Partitioned levels, outermost (original graph) first.
    pub levels: Vec<PlanLevel>,
    /// Size of the terminal graph solved directly by one dense FW
    /// (0 if the deepest boundary graph is empty).
    pub final_n: usize,
    /// Edge count of the terminal graph.
    pub final_nnz: u64,
    pub tile_limit: usize,
}

impl ApspPlan {
    /// Recursion depth (number of partitioned levels).
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// Boundary count per level (|B^l| in the paper's notation).
    pub fn boundary_sizes(&self) -> Vec<usize> {
        self.levels.iter().map(|l| l.n_boundary()).collect()
    }
}

/// Planning options.
#[derive(Debug, Clone, Copy)]
pub struct PlanOptions {
    /// Max vertices per tile (paper: 1024).
    pub tile_limit: usize,
    /// Max recursion depth: `usize::MAX` = Algorithm 2 (full recursion);
    /// `1` = Algorithm 1 (single-level, boundary graph solved densely
    /// whatever its size).
    pub max_depth: usize,
    /// Partitioner seed.
    pub seed: u64,
}

impl Default for PlanOptions {
    fn default() -> Self {
        Self {
            tile_limit: crate::TILE_LIMIT,
            max_depth: usize::MAX,
            seed: 0x5241_5049,
        }
    }
}

/// Build the recursive plan for graph `g`.
pub fn build_plan(g: &CsrGraph, opts: PlanOptions) -> ApspPlan {
    assert!(opts.tile_limit >= 2, "tile_limit must be >= 2");
    let mut levels: Vec<PlanLevel> = Vec::new();

    // ---- level 0: real multilevel partitioning of G
    if g.n() <= opts.tile_limit || opts.max_depth == 0 {
        return ApspPlan {
            levels,
            final_n: g.n(),
            final_nnz: g.m() as u64,
            tile_limit: opts.tile_limit,
        };
    }
    // Partition on *topology* (unit edge affinity): edge weights here are
    // distances, not affinities — METIS likewise cuts edge count when
    // no affinity weights are given. Cutting by distance weight would
    // preferentially cut short edges, exactly backwards.
    let unit = CsrGraph {
        rowptr: g.rowptr.clone(),
        col: g.col.clone(),
        val: vec![1.0; g.m()],
    };
    let p0 = partition_by_max_size(&unit, opts.tile_limit, opts.seed);
    let cs0 = build_components(g, &p0);
    let lvl0 = finish_level(g, cs0);
    let mut cur_cross = lvl0.next_cross.clone();
    let mut cur_groups = lvl0.group_start.clone();
    levels.push(lvl0);

    // ---- levels >= 1: group-packing partitioner over the cross graph
    // (guard: recursion depth is bounded because each level's graph is
    // its predecessor's boundary set; a hard cap protects pathological
    // inputs where the boundary refuses to shrink)
    const HARD_DEPTH_CAP: usize = 64;
    loop {
        let n = cur_cross.n();
        let depth = levels.len();
        if n <= opts.tile_limit || depth >= opts.max_depth || depth >= HARD_DEPTH_CAP {
            return ApspPlan {
                final_n: n,
                final_nnz: cur_cross.m() as u64,
                levels,
                tile_limit: opts.tile_limit,
            };
        }
        let p = pack_groups(&cur_cross, &cur_groups, opts.tile_limit);
        let cs = build_components(&cur_cross, &p);
        let lvl = finish_level(&cur_cross, cs);
        // no progress guard: if the boundary did not shrink at all we
        // would loop forever — solve the rest directly instead.
        if lvl.n_boundary() >= n {
            return ApspPlan {
                final_n: n,
                final_nnz: cur_cross.m() as u64,
                levels,
                tile_limit: opts.tile_limit,
            };
        }
        cur_cross = lvl.next_cross.clone();
        cur_groups = lvl.group_start.clone();
        levels.push(lvl);
    }
}

/// Compute the derived fields of a level from its component set.
fn finish_level(g: &CsrGraph, cs: ComponentSet) -> PlanLevel {
    let nb = cs.n_boundary();
    // group_start: boundary ids are assigned component-major by
    // build_components, so prefix sums of n_boundary give the ranges.
    let mut group_start = Vec::with_capacity(cs.components.len() + 1);
    let mut acc = 0usize;
    for c in &cs.components {
        group_start.push(acc);
        acc += c.n_boundary;
    }
    group_start.push(acc);
    debug_assert_eq!(acc, nb);

    // cross edges mapped to boundary ids
    let mut cross_edges: Vec<(u32, u32, f32)> = Vec::new();
    let mut comp_nnz = vec![0u64; cs.components.len()];
    for (u, v, w) in g.edges() {
        let cu = cs.comp_of[u as usize];
        let cv = cs.comp_of[v as usize];
        if cu != cv {
            cross_edges.push((
                cs.boundary_id[u as usize],
                cs.boundary_id[v as usize],
                w,
            ));
        } else {
            comp_nnz[cu as usize] += 1;
        }
    }
    let next_cross = CsrGraph::from_edges(nb, &cross_edges);
    PlanLevel {
        n: g.n(),
        cs,
        next_cross,
        group_start,
        comp_nnz,
    }
}

/// Pack whole boundary groups (contiguous vertex ranges) into parts of
/// at most `tile_limit` vertices, ordered by *group connectivity*: a
/// greedy agglomerative traversal that always appends the unpacked
/// group with the strongest cross-edge attachment to the current bin,
/// so cross edges collapse inside bins and the next level's boundary
/// actually shrinks (the recursion-aware partitioner of §III-A). Every
/// group has at most `tile_limit` members by construction.
fn pack_groups(cross: &CsrGraph, group_start: &[usize], tile_limit: usize) -> Partition {
    let n = cross.n();
    let ngroups = group_start.len() - 1;
    // cluster id per group; clusters merge agglomeratively
    let mut cluster_of: Vec<u32> = (0..ngroups as u32).collect();
    let mut cluster_size: Vec<usize> = (0..ngroups)
        .map(|g| group_start[g + 1] - group_start[g])
        .collect();
    // group of each vertex (groups are contiguous ranges)
    let mut group_of = vec![0u32; n];
    for gi in 0..ngroups {
        for v in group_start[gi]..group_start[gi + 1] {
            group_of[v] = gi as u32;
        }
    }
    // Agglomerative capacity-bounded matching: repeatedly merge the
    // cluster pairs with the heaviest cross-edge attachment whose
    // combined size still fits one tile. Log-many rounds coalesce
    // community *chains* (pair, then pair-of-pairs, ...), which a
    // single greedy pass cannot.
    loop {
        // cluster adjacency weights
        let mut w: std::collections::HashMap<(u32, u32), u64> = std::collections::HashMap::new();
        for (u, v, _) in cross.edges() {
            let cu = cluster_of[group_of[u as usize] as usize];
            let cv = cluster_of[group_of[v as usize] as usize];
            if cu != cv {
                let key = (cu.min(cv), cu.max(cv));
                *w.entry(key).or_insert(0) += 1;
            }
        }
        if w.is_empty() {
            break;
        }
        let mut pairs: Vec<((u32, u32), u64)> = w.into_iter().collect();
        pairs.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let mut merged_any = false;
        let mut taken: std::collections::HashSet<u32> = std::collections::HashSet::new();
        let mut remap: Vec<u32> = (0..ngroups as u32).collect();
        for ((a, b), _) in pairs {
            if taken.contains(&a) || taken.contains(&b) {
                continue;
            }
            if cluster_size[a as usize] + cluster_size[b as usize] > tile_limit {
                continue;
            }
            // merge b into a
            taken.insert(a);
            taken.insert(b);
            remap[b as usize] = a;
            cluster_size[a as usize] += cluster_size[b as usize];
            cluster_size[b as usize] = 0;
            merged_any = true;
        }
        if !merged_any {
            break;
        }
        for c in cluster_of.iter_mut() {
            *c = remap[*c as usize];
        }
    }
    // pack final clusters into dense part ids, folding tiny clusters
    // together first-fit to limit tile fragmentation
    let mut part_of_cluster: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
    let mut part_fill: Vec<usize> = Vec::new();
    let mut order: Vec<u32> = cluster_of
        .iter()
        .copied()
        .collect::<std::collections::HashSet<_>>()
        .into_iter()
        .collect();
    order.sort_unstable_by_key(|&c| std::cmp::Reverse(cluster_size[c as usize]));
    for c in order {
        let sz = cluster_size[c as usize];
        if sz == 0 {
            part_of_cluster.insert(c, 0);
            continue;
        }
        // first-fit-decreasing into existing parts
        let slot = part_fill.iter().position(|&f| f + sz <= tile_limit);
        let pid = match slot {
            Some(p) => {
                part_fill[p] += sz;
                p
            }
            None => {
                part_fill.push(sz);
                part_fill.len() - 1
            }
        };
        part_of_cluster.insert(c, pid as u32);
    }
    let mut assign = vec![0u32; n];
    for v in 0..n {
        assign[v] = part_of_cluster[&cluster_of[group_of[v] as usize]];
    }
    Partition {
        assign,
        k: part_fill.len().max(1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, Weights};

    fn plan_for(n: usize, tile: usize, seed: u64) -> (CsrGraph, ApspPlan) {
        let g = generators::newman_watts_strogatz(n, 4, 0.08, Weights::Uniform(1.0, 5.0), seed);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: tile,
                max_depth: usize::MAX,
                seed,
            },
        );
        (g, plan)
    }

    #[test]
    fn small_graph_is_direct() {
        let g = generators::complete(16, Weights::Unit, 1);
        let plan = build_plan(&g, PlanOptions::default());
        assert_eq!(plan.depth(), 0);
        assert_eq!(plan.final_n, 16);
    }

    #[test]
    fn level0_components_fit_tiles() {
        let (g, plan) = plan_for(600, 64, 2);
        assert!(plan.depth() >= 1);
        let l0 = &plan.levels[0];
        assert_eq!(l0.n, g.n());
        l0.cs.validate(&g).unwrap();
        assert!(l0.cs.max_component() <= 64);
    }

    #[test]
    fn deeper_levels_fit_tiles_and_shrink() {
        let (_, plan) = plan_for(1500, 48, 3);
        let sizes = plan.boundary_sizes();
        for w in sizes.windows(2) {
            assert!(w[1] <= w[0], "boundary must not grow: {sizes:?}");
        }
        for lvl in &plan.levels {
            assert!(lvl.cs.max_component() <= 48);
        }
        assert!(plan.final_n <= 48 || plan.depth() >= 1);
    }

    #[test]
    fn group_packing_keeps_groups_whole() {
        let (_, plan) = plan_for(1200, 32, 4);
        for li in 1..plan.depth() {
            let prev = &plan.levels[li - 1];
            let lvl = &plan.levels[li];
            // all vertices of one group (prev component boundary range)
            // must share a component at this level
            for gi in 0..prev.group_start.len() - 1 {
                let range = prev.group_start[gi]..prev.group_start[gi + 1];
                let mut comp = None;
                for v in range {
                    let c = lvl.cs.comp_of[v];
                    match comp {
                        None => comp = Some(c),
                        Some(c0) => assert_eq!(c0, c, "group {gi} split at level {li}"),
                    }
                }
            }
        }
    }

    #[test]
    fn cross_graph_excludes_intra_edges() {
        let (g, plan) = plan_for(400, 64, 5);
        let l0 = &plan.levels[0];
        // every cross edge of G appears in next_cross (mapped)
        let mut expect = 0usize;
        for (u, v, _) in g.edges() {
            if l0.cs.comp_of[u as usize] != l0.cs.comp_of[v as usize] {
                expect += 1;
            }
        }
        assert_eq!(l0.next_cross.m(), expect);
        // comp_nnz counts the rest
        let intra: u64 = l0.comp_nnz.iter().sum();
        assert_eq!(intra as usize + expect, g.m());
    }

    #[test]
    fn max_depth_one_is_algorithm_1() {
        let g = generators::newman_watts_strogatz(800, 4, 0.1, Weights::Unit, 6);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 64,
                max_depth: 1,
                seed: 6,
            },
        );
        assert_eq!(plan.depth(), 1);
        // terminal graph is the whole boundary graph regardless of size
        assert_eq!(plan.final_n, plan.levels[0].n_boundary());
    }

    #[test]
    fn plan_deterministic() {
        let (_, p1) = plan_for(700, 64, 9);
        let (_, p2) = plan_for(700, 64, 9);
        assert_eq!(p1.depth(), p2.depth());
        assert_eq!(p1.boundary_sizes(), p2.boundary_sizes());
        assert_eq!(p1.final_n, p2.final_n);
    }

    #[test]
    fn disconnected_graph_zero_boundary() {
        // two cliques, no bridge: partitioner should find the split and
        // the boundary graph is empty
        let mut edges = Vec::new();
        for u in 0..30u32 {
            for v in (u + 1)..30 {
                edges.push((u, v, 1.0f32));
            }
        }
        for u in 30..60u32 {
            for v in (u + 1)..60 {
                edges.push((u, v, 1.0));
            }
        }
        let g = CsrGraph::from_undirected_edges(60, &edges);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 32,
                max_depth: usize::MAX,
                seed: 1,
            },
        );
        assert!(plan.depth() >= 1);
        assert_eq!(plan.levels[0].n_boundary(), 0);
        assert_eq!(plan.final_n, 0);
    }
}
