//! Content-addressed APSP result store: the modeled FeNAND persistence
//! layer that serves repeated submissions instead of re-solving them
//! (paper §III-B: the external storage stack exists so large results
//! persist across queries).
//!
//! A result is keyed by [`fingerprint`] — a stable hash of the graph's
//! canonical CSR structure plus edge-weight bits. `CsrGraph::from_edges`
//! sorts, dedups, and drops self-loops, so the fingerprint is invariant
//! to edge insertion order and batch-order permutation, but any single
//! edge insert/delete/reweight changes it.
//!
//! The store sits behind the [`ResultStore`] trait (SurrealDB-kvs
//! style: an in-memory backend now, a file-backed one later can slot in
//! without touching the admission pipeline). Payloads are
//! [`CompressedMatrix`] — a sparse finite-entry codec over the dense
//! distance matrix that round-trips bit-exactly, including `INF`
//! (unreachable) entries of disconnected graphs. Eviction is cost-aware
//! LRU: when over capacity, the entry that is *cheapest to recompute*
//! goes first (ties broken oldest-use-first, then by key), so the store
//! keeps the results whose cache hits save the most modeled work.

use crate::ensure;
use crate::graph::csr::CsrGraph;
use crate::graph::dense::DistMatrix;
use crate::util::error::Result;
use crate::INF;

// ---------------------------------------------------------------------
// Fingerprinting
// ---------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv1a(h: u64, word: u64) -> u64 {
    let mut h = h;
    for b in word.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Content fingerprint of a graph: FNV-1a over the vertex count, the
/// CSR row pointers, the column indices, and the raw weight bits.
/// Stable across clones and admission order (the CSR form is canonical);
/// sensitive to any structural edit or reweight.
pub fn fingerprint(g: &CsrGraph) -> u64 {
    let mut h = fnv1a(FNV_OFFSET, g.n() as u64);
    for &r in &g.rowptr {
        h = fnv1a(h, r as u64);
    }
    for &c in &g.col {
        h = fnv1a(h, c as u64);
    }
    for &v in &g.val {
        h = fnv1a(h, v.to_bits() as u64);
    }
    h
}

// ---------------------------------------------------------------------
// Compressed payload
// ---------------------------------------------------------------------

/// Sparse (CSR-style) compression of a dense DP matrix: entries whose
/// raw bits differ from the *background* element are kept as
/// `(flat index, raw f32 bits)` pairs, and decompression rebuilds the
/// matrix onto a background-filled canvas — a bit-exact round trip for
/// any matrix over any semiring.
///
/// The background is the semiring's ⊕-identity ("no path"): `+INF` for
/// `(min, +)`, `-INF` for max-plus, `0.0` for reachability/widest. The
/// pre-semiring codec kept `is_finite()` entries against a hardwired
/// `+INF` canvas, which silently corrupted max-plus results: a `-INF`
/// (unreachable) entry was dropped on compress and resurrected as
/// `+INF` — the sign-of-infinity hazard pinned by
/// `compress_roundtrip_negative_infinity_background`.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressedMatrix {
    n: usize,
    bg_bits: u32,
    idx: Vec<u64>,
    bits: Vec<u32>,
}

impl CompressedMatrix {
    /// Compress a `(min, +)` distance matrix (background `+INF`).
    pub fn compress(d: &DistMatrix) -> Self {
        Self::compress_with_background(d, INF)
    }

    /// Compress against an explicit background element. Entries are
    /// compared bitwise, so `-0.0` vs `0.0` backgrounds stay exact.
    pub fn compress_with_background(d: &DistMatrix, bg: f32) -> Self {
        let n = d.n();
        let bg_bits = bg.to_bits();
        let mut idx = Vec::new();
        let mut bits = Vec::new();
        for (i, &v) in d.as_slice().iter().enumerate() {
            if v.to_bits() != bg_bits {
                idx.push(i as u64);
                bits.push(v.to_bits());
            }
        }
        Self {
            n,
            bg_bits,
            idx,
            bits,
        }
    }

    /// Rebuild the dense matrix onto the background canvas.
    pub fn decompress(&self) -> DistMatrix {
        let mut data = vec![f32::from_bits(self.bg_bits); self.n * self.n];
        for (&i, &b) in self.idx.iter().zip(&self.bits) {
            data[i as usize] = f32::from_bits(b);
        }
        DistMatrix::from_vec(self.n, data)
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The background element this payload was compressed against.
    pub fn background(&self) -> f32 {
        f32::from_bits(self.bg_bits)
    }

    /// Stored (non-background) entries.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Payload bytes of the compressed form (8 per stored entry: a
    /// 4-byte column index + 4-byte value, matching the worst-case CSR
    /// model in [`super::taskgraph`]).
    pub fn payload_bytes(&self) -> u64 {
        self.idx.len() as u64 * 8
    }
}

// ---------------------------------------------------------------------
// The store trait + in-memory backend
// ---------------------------------------------------------------------

/// One stored result with its modeled footprint and recompute cost.
#[derive(Debug, Clone)]
pub struct StoreEntry {
    /// Modeled FeNAND bytes of the stored (compressed) result — what a
    /// hit reads back and a miss programs.
    pub bytes: u64,
    /// Recompute-cost proxy (the lowered task graph's total min-add
    /// candidates): eviction drops the *cheapest-to-recompute* first.
    pub cost: f64,
    /// The actual compressed solution (functional runs; `None` in
    /// estimate mode, where only the cost model is exercised).
    pub payload: Option<CompressedMatrix>,
    /// LRU clock value of the last touch (managed by the store).
    last_used: u64,
}

impl StoreEntry {
    pub fn new(bytes: u64, cost: f64, payload: Option<CompressedMatrix>) -> Self {
        Self {
            bytes,
            cost,
            payload,
            last_used: 0,
        }
    }
}

/// A content-addressed result store (SurrealDB-kvs-style trait: the
/// admission pipeline codes against this, backends are swappable).
pub trait ResultStore {
    /// Look up a fingerprint, refreshing its LRU position on a hit.
    fn get(&mut self, key: u64) -> Option<&StoreEntry>;
    /// Insert (or refresh) an entry. Returns `Ok(true)` when stored —
    /// evicting cheapest-to-recompute entries as needed — `Ok(false)`
    /// when the store is disabled (zero capacity), and a clean error
    /// when the entry alone exceeds the byte budget (nothing evicted).
    fn put(&mut self, key: u64, entry: StoreEntry) -> Result<bool>;
    /// Explicitly invalidate a fingerprint, freeing its byte budget
    /// immediately (the delta path calls this on the pre-delta
    /// fingerprint: the entry is provably stale, so waiting for LRU to
    /// chance-evict it would squat budget a live result could use).
    /// Returns whether an entry was removed.
    fn remove(&mut self, key: u64) -> bool;
    /// Whether a fingerprint is present (no LRU refresh).
    fn contains(&self, key: u64) -> bool;
    /// Stored entry count.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Entry-capacity knob (0 = disabled).
    fn capacity(&self) -> usize;
    /// Backend name for reports.
    fn name(&self) -> &'static str;
}

/// The in-memory backend: a flat association list (deterministic
/// iteration order) with an LRU clock and a byte budget.
#[derive(Debug, Default)]
pub struct MemoryStore {
    capacity: usize,
    byte_budget: u64,
    tick: u64,
    entries: Vec<(u64, StoreEntry)>,
}

impl MemoryStore {
    pub fn new(capacity: usize, byte_budget: u64) -> Self {
        Self {
            capacity,
            byte_budget,
            tick: 0,
            entries: Vec::new(),
        }
    }

    /// Total modeled bytes currently resident.
    pub fn bytes_used(&self) -> u64 {
        self.entries.iter().map(|(_, e)| e.bytes).sum()
    }

    /// Stored fingerprints in eviction-safe (insertion) order.
    pub fn keys(&self) -> Vec<u64> {
        self.entries.iter().map(|(k, _)| *k).collect()
    }

    /// Evict one entry: cheapest to recompute first, ties broken by
    /// least-recent use, then by key — fully deterministic.
    fn evict_one(&mut self) -> Option<u64> {
        let victim = self
            .entries
            .iter()
            .enumerate()
            .min_by(|(_, (ka, a)), (_, (kb, b))| {
                a.cost
                    .total_cmp(&b.cost)
                    .then(a.last_used.cmp(&b.last_used))
                    .then(ka.cmp(kb))
            })
            .map(|(i, _)| i)?;
        Some(self.entries.remove(victim).0)
    }
}

impl ResultStore for MemoryStore {
    fn get(&mut self, key: u64) -> Option<&StoreEntry> {
        self.tick += 1;
        let tick = self.tick;
        let e = self.entries.iter_mut().find(|(k, _)| *k == key)?;
        e.1.last_used = tick;
        Some(&e.1)
    }

    fn put(&mut self, key: u64, mut entry: StoreEntry) -> Result<bool> {
        if self.capacity == 0 {
            return Ok(false);
        }
        ensure!(
            entry.bytes <= self.byte_budget,
            "result store: entry of {} bytes exceeds the store byte budget ({} bytes); \
             rejecting instead of evicting everything",
            entry.bytes,
            self.byte_budget
        );
        self.tick += 1;
        entry.last_used = self.tick;
        if let Some(slot) = self.entries.iter_mut().find(|(k, _)| *k == key) {
            slot.1 = entry;
            return Ok(true);
        }
        while self.entries.len() >= self.capacity
            || self.bytes_used() + entry.bytes > self.byte_budget
        {
            if self.evict_one().is_none() {
                break;
            }
        }
        self.entries.push((key, entry));
        Ok(true)
    }

    fn remove(&mut self, key: u64) -> bool {
        match self.entries.iter().position(|(k, _)| *k == key) {
            Some(i) => {
                self.entries.remove(i);
                true
            }
            None => false,
        }
    }

    fn contains(&self, key: u64) -> bool {
        self.entries.iter().any(|(k, _)| *k == key)
    }

    fn len(&self) -> usize {
        self.entries.len()
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn name(&self) -> &'static str {
        "memory"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, Topology, Weights};

    fn entry(bytes: u64, cost: f64) -> StoreEntry {
        StoreEntry::new(bytes, cost, None)
    }

    #[test]
    fn fingerprint_stable_and_sensitive() {
        let g = generators::generate(Topology::Nws, 200, 8.0, Weights::Uniform(1.0, 4.0), 7);
        let h = fingerprint(&g);
        assert_eq!(h, fingerprint(&g.clone()));
        // rebuilding from a reversed edge list canonicalizes to the
        // same CSR, hence the same fingerprint
        let mut edges: Vec<(u32, u32, f32)> = g.edges().collect();
        edges.reverse();
        let g2 = CsrGraph::from_edges(g.n(), &edges);
        assert_eq!(h, fingerprint(&g2));
        // a single reweight changes it
        let mut g3 = g.clone();
        g3.val[0] += 0.25;
        assert_ne!(h, fingerprint(&g3));
    }

    #[test]
    fn compress_roundtrip_bit_exact() {
        let mut d = DistMatrix::new_diag0(5);
        d.set(0, 1, 1.5);
        d.set(3, 2, 7.25);
        // row 4 left unreachable
        let c = CompressedMatrix::compress(&d);
        let back = c.decompress();
        assert_eq!(back.max_diff(&d), 0.0);
        assert_eq!(back.as_slice(), d.as_slice());
        assert_eq!(c.nnz(), d.finite_count());
    }

    #[test]
    fn compress_roundtrip_negative_infinity_background() {
        // the MaxPlus sign-of-infinity hazard: -INF unreachable entries
        // must survive the round trip, not resurrect as +INF
        use crate::apsp::semiring::SemiringId;
        let sr = SemiringId::MaxPlus;
        let mut d = DistMatrix::new_ident_sr(4, sr);
        d.set(0, 1, 3.5);
        d.set(1, 2, 0.0);
        // (3, *) stays -INF (unreachable in the DAG)
        let c = CompressedMatrix::compress_with_background(&d, sr.zero());
        assert_eq!(c.background().to_bits(), f32::NEG_INFINITY.to_bits());
        let back = c.decompress();
        assert_eq!(back.as_slice(), d.as_slice());
        assert_eq!(back.max_diff(&d), 0.0);
        // the old +INF-background codec drops the -INF entries and
        // rebuilds them with the wrong sign — max_diff now catches it
        let wrong = CompressedMatrix::compress(&d).decompress();
        assert!(wrong.max_diff(&d).is_infinite());
    }

    #[test]
    fn compress_roundtrip_every_semiring_background() {
        use crate::apsp::semiring::ALL_SEMIRINGS;
        for sr in ALL_SEMIRINGS {
            let mut d = DistMatrix::new_ident_sr(5, sr);
            d.set(0, 1, sr.from_weight(2.5));
            d.set(2, 3, sr.from_weight(0.5));
            let c = CompressedMatrix::compress_with_background(&d, sr.zero());
            let back = c.decompress();
            assert_eq!(back.as_slice(), d.as_slice(), "{}", sr.name());
        }
    }

    #[test]
    fn lru_hit_refresh_and_cost_aware_eviction() {
        let mut s = MemoryStore::new(2, u64::MAX);
        s.put(1, entry(10, 5.0)).unwrap();
        s.put(2, entry(10, 1.0)).unwrap();
        // key 2 is cheaper to recompute: it is the victim even though
        // key 1 is older
        s.put(3, entry(10, 9.0)).unwrap();
        assert!(s.contains(1) && s.contains(3) && !s.contains(2));
        // equal costs fall back to LRU: touch 1, then 3 is the victim
        let mut s = MemoryStore::new(2, u64::MAX);
        s.put(1, entry(10, 2.0)).unwrap();
        s.put(3, entry(10, 2.0)).unwrap();
        assert!(s.get(1).is_some());
        s.put(4, entry(10, 2.0)).unwrap();
        assert!(s.contains(1) && s.contains(4) && !s.contains(3));
    }

    #[test]
    fn capacity_zero_disables() {
        let mut s = MemoryStore::new(0, u64::MAX);
        assert!(!s.put(1, entry(10, 1.0)).unwrap());
        assert!(s.is_empty());
        assert!(s.get(1).is_none());
    }

    #[test]
    fn oversized_entry_rejected_cleanly() {
        let mut s = MemoryStore::new(4, 100);
        s.put(1, entry(60, 1.0)).unwrap();
        let err = s.put(2, entry(101, 9.0)).unwrap_err();
        assert!(format!("{err}").contains("exceeds the store byte budget"));
        // nothing was evicted
        assert!(s.contains(1));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn byte_budget_evicts_until_fit() {
        let mut s = MemoryStore::new(10, 100);
        s.put(1, entry(40, 1.0)).unwrap();
        s.put(2, entry(40, 2.0)).unwrap();
        s.put(3, entry(40, 3.0)).unwrap(); // evicts key 1 (cheapest)
        assert!(!s.contains(1));
        assert_eq!(s.bytes_used(), 80);
    }

    #[test]
    fn remove_frees_byte_budget_immediately() {
        // the delta-path bug this guards: putting the post-delta
        // fingerprint without removing the stale one left both entries
        // squatting the byte budget until LRU chance-evicted the old one
        let mut s = MemoryStore::new(10, 100);
        s.put(1, entry(60, 9.0)).unwrap();
        assert!(s.remove(1), "present entry must report removal");
        assert!(!s.remove(1), "second removal is a no-op");
        assert_eq!(s.bytes_used(), 0);
        // the freed budget is immediately usable: both the new
        // fingerprint and an unrelated entry now fit without eviction
        s.put(2, entry(60, 9.0)).unwrap();
        s.put(3, entry(40, 1.0)).unwrap();
        assert!(s.contains(2) && s.contains(3));
        assert_eq!(s.bytes_used(), 100);
    }

    #[test]
    fn put_same_key_replaces() {
        let mut s = MemoryStore::new(2, u64::MAX);
        s.put(1, entry(10, 1.0)).unwrap();
        s.put(1, entry(20, 2.0)).unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s.get(1).unwrap().bytes, 20);
    }
}
