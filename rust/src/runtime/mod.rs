//! PJRT runtime: loads the AOT-compiled JAX/Pallas HLO artifacts and
//! executes them as the tile compute engines (the three-layer stack's
//! serve path — Python never runs here).
//!
//! The real engine needs the XLA/PJRT bindings and is gated behind the
//! off-by-default `pjrt` cargo feature; without it, [`stub`] provides
//! the same API surface with loud load-time errors (DESIGN.md
//! "Execution backends").

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "stub.rs"]
pub mod engine;

pub use artifacts::Manifest;
pub use engine::{PjrtBackend, PjrtRuntime};
