//! PJRT runtime: loads the AOT-compiled JAX/Pallas HLO artifacts and
//! executes them as the tile compute engines (the three-layer stack's
//! serve path — Python never runs here).

pub mod artifacts;
pub mod engine;

pub use artifacts::Manifest;
pub use engine::{PjrtBackend, PjrtRuntime};
