//! Artifact manifest: locates and describes the HLO text files emitted
//! by `python/compile/aot.py` (see `artifacts/manifest.json`).

use crate::bail;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One artifact entry.
#[derive(Debug, Clone, PartialEq)]
pub struct Artifact {
    pub kind: ArtifactKind,
    pub n: usize,
    pub path: PathBuf,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// Floyd–Warshall over one (n, n) block.
    Fw,
    /// Accumulating min-plus product over (n, n) blocks.
    MinPlus,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<Artifact>,
    pub jax_version: String,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        let json = Json::parse(&text).map_err(|e| crate::err!("parse manifest: {e}"))?;
        let mut artifacts = Vec::new();
        for a in json
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .context("manifest missing artifacts[]")?
        {
            let kind = match a.get("kind").and_then(|k| k.as_str()) {
                Some("fw") => ArtifactKind::Fw,
                Some("minplus") => ArtifactKind::MinPlus,
                other => bail!("unknown artifact kind {other:?}"),
            };
            let n = a
                .get("n")
                .and_then(|n| n.as_usize())
                .context("artifact missing n")?;
            let rel = a
                .get("path")
                .and_then(|p| p.as_str())
                .context("artifact missing path")?;
            let path = dir.join(rel);
            if !path.exists() {
                bail!("artifact file missing: {}", path.display());
            }
            artifacts.push(Artifact { kind, n, path });
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            artifacts,
            jax_version: json
                .get("jax_version")
                .and_then(|v| v.as_str())
                .unwrap_or("?")
                .to_string(),
        })
    }

    /// Default artifacts directory: `$RAPID_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("RAPID_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Available size classes for a kind, ascending.
    pub fn sizes(&self, kind: ArtifactKind) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == kind)
            .map(|a| a.n)
            .collect();
        v.sort_unstable();
        v
    }

    /// Smallest size class that fits `n`, if any.
    pub fn size_class(&self, kind: ArtifactKind, n: usize) -> Option<usize> {
        self.sizes(kind).into_iter().find(|&s| s >= n)
    }

    pub fn find(&self, kind: ArtifactKind, n: usize) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.kind == kind && a.n == n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, entries: &[(&str, usize)]) {
        std::fs::create_dir_all(dir).unwrap();
        let mut arts = Vec::new();
        for (kind, n) in entries {
            let name = format!("{kind}_{n}.hlo.txt");
            std::fs::write(dir.join(&name), "HloModule fake").unwrap();
            arts.push(format!(
                "{{\"kind\": \"{kind}\", \"n\": {n}, \"path\": \"{name}\"}}"
            ));
        }
        let text = format!(
            "{{\"artifacts\": [{}], \"jax_version\": \"0.0-test\"}}",
            arts.join(",")
        );
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn loads_and_queries_size_classes() {
        let dir = std::env::temp_dir().join("rapid_manifest_test1");
        write_manifest(&dir, &[("fw", 64), ("fw", 256), ("minplus", 64)]);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.sizes(ArtifactKind::Fw), vec![64, 256]);
        assert_eq!(m.size_class(ArtifactKind::Fw, 65), Some(256));
        assert_eq!(m.size_class(ArtifactKind::Fw, 64), Some(64));
        assert_eq!(m.size_class(ArtifactKind::Fw, 257), None);
        assert_eq!(m.size_class(ArtifactKind::MinPlus, 10), Some(64));
        assert_eq!(m.jax_version, "0.0-test");
    }

    #[test]
    fn missing_file_rejected() {
        let dir = std::env::temp_dir().join("rapid_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [{"kind": "fw", "n": 64, "path": "nope.hlo.txt"}]}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn missing_manifest_mentions_make() {
        let dir = std::env::temp_dir().join("rapid_manifest_test3_nonexistent");
        let err = Manifest::load(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn real_artifacts_if_present() {
        // integration: parse the real manifest when `make artifacts` ran
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.size_class(ArtifactKind::Fw, 1024).is_some());
            assert!(m.size_class(ArtifactKind::MinPlus, 1024).is_some());
        }
    }
}
