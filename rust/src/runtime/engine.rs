//! PJRT tile engines: compile the HLO artifacts once, then execute FW
//! and MP tile ops from the rust hot path with INF padding to the
//! nearest size class.
//!
//! Padding safety: a padded vertex has +inf to/from everything and 0 to
//! itself, so it can never lie on a shortest path — FW and min-plus
//! results on the valid corner are unchanged (property-tested on the
//! python side in `test_padding_with_inf_is_safe` and here in
//! `padded_matches_native`).

use super::artifacts::{ArtifactKind, Manifest};
use crate::apsp::backend::TileBackend;
use crate::graph::dense::DistMatrix;
use crate::INF;
use crate::util::error::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

/// Compiled executables for every artifact size class.
pub struct PjrtRuntime {
    inner: Mutex<Inner>,
    fw_sizes: Vec<usize>,
    mp_sizes: Vec<usize>,
    pub manifest: Manifest,
}

struct Inner {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    fw: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    mp: BTreeMap<usize, xla::PjRtLoadedExecutable>,
}

// SAFETY: all PJRT access is serialized through the Mutex; the CPU PJRT
// client itself is thread-safe, but we stay conservative.
unsafe impl Send for Inner {}

impl PjrtRuntime {
    /// Load and compile every artifact in `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut fw = BTreeMap::new();
        let mut mp = BTreeMap::new();
        for a in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(&a.path)
                .with_context(|| format!("parse {}", a.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile {}", a.path.display()))?;
            match a.kind {
                ArtifactKind::Fw => fw.insert(a.n, exe),
                ArtifactKind::MinPlus => mp.insert(a.n, exe),
            };
        }
        let fw_sizes: Vec<usize> = fw.keys().copied().collect();
        let mp_sizes: Vec<usize> = mp.keys().copied().collect();
        crate::ensure!(!fw_sizes.is_empty(), "no fw artifacts");
        crate::ensure!(!mp_sizes.is_empty(), "no minplus artifacts");
        Ok(Self {
            inner: Mutex::new(Inner { client, fw, mp }),
            fw_sizes,
            mp_sizes,
            manifest,
        })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Self> {
        Self::load(&Manifest::default_dir())
    }

    /// Largest FW tile this runtime can execute.
    pub fn max_fw_tile(&self) -> usize {
        *self.fw_sizes.last().unwrap()
    }

    fn fw_class(&self, n: usize) -> Result<usize> {
        self.fw_sizes
            .iter()
            .copied()
            .find(|&s| s >= n)
            .with_context(|| format!("no fw artifact fits n={n} (have {:?})", self.fw_sizes))
    }

    fn mp_class(&self, n: usize) -> Result<usize> {
        self.mp_sizes
            .iter()
            .copied()
            .find(|&s| s >= n)
            .with_context(|| format!("no minplus artifact fits n={n} (have {:?})", self.mp_sizes))
    }

    /// In-place FW over a dense block via the AOT artifact.
    pub fn fw_block(&self, d: &mut DistMatrix) -> Result<()> {
        let n = d.n();
        if n <= 1 {
            return Ok(());
        }
        let class = self.fw_class(n)?;
        // pad to the class size (isolated INF vertices, 0 diagonal)
        let padded = if class == n { d.clone() } else { d.pad_to(class) };
        let lit = xla::Literal::vec1(padded.as_slice())
            .reshape(&[class as i64, class as i64])
            .context("reshape input literal")?;
        let out = {
            let inner = self.inner.lock().unwrap();
            let exe = &inner.fw[&class];
            let result = exe.execute::<xla::Literal>(&[lit]).context("execute fw")?;
            result[0][0]
                .to_literal_sync()
                .context("fetch fw result")?
        };
        let tuple = out.to_tuple1().context("unwrap fw tuple")?;
        let vals: Vec<f32> = tuple.to_vec().context("fw result to_vec")?;
        debug_assert_eq!(vals.len(), class * class);
        for i in 0..n {
            d.row_mut(i)
                .copy_from_slice(&vals[i * class..i * class + n]);
        }
        Ok(())
    }

    /// `C = min(C, A (+) B)` via the AOT artifact (square-padded).
    pub fn minplus_into(
        &self,
        c: &mut [f32],
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) -> Result<()> {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        assert_eq!(c.len(), m * n);
        if m == 0 || n == 0 {
            return Ok(());
        }
        if k == 0 {
            return Ok(()); // nothing to merge
        }
        let class = self.mp_class(m.max(k).max(n))?;
        let pad = |src: &[f32], rows: usize, cols: usize| -> Vec<f32> {
            let mut out = vec![INF; class * class];
            for i in 0..rows {
                out[i * class..i * class + cols].copy_from_slice(&src[i * cols..(i + 1) * cols]);
            }
            out
        };
        let lc = xla::Literal::vec1(&pad(c, m, n))
            .reshape(&[class as i64, class as i64])?;
        let la = xla::Literal::vec1(&pad(a, m, k))
            .reshape(&[class as i64, class as i64])?;
        let lb = xla::Literal::vec1(&pad(b, k, n))
            .reshape(&[class as i64, class as i64])?;
        let out = {
            let inner = self.inner.lock().unwrap();
            let exe = &inner.mp[&class];
            let result = exe
                .execute::<xla::Literal>(&[lc, la, lb])
                .context("execute minplus")?;
            result[0][0]
                .to_literal_sync()
                .context("fetch minplus result")?
        };
        let tuple = out.to_tuple1().context("unwrap minplus tuple")?;
        let vals: Vec<f32> = tuple.to_vec().context("minplus result to_vec")?;
        for i in 0..m {
            c[i * n..(i + 1) * n].copy_from_slice(&vals[i * class..i * class + n]);
        }
        Ok(())
    }
}

/// [`TileBackend`] adapter over a [`PjrtRuntime`].
pub struct PjrtBackend<'a> {
    pub runtime: &'a PjrtRuntime,
}

// SAFETY: PjrtRuntime serializes PJRT access through its Mutex.
unsafe impl<'a> Sync for PjrtBackend<'a> {}

impl<'a> PjrtBackend<'a> {
    pub fn new(runtime: &'a PjrtRuntime) -> Self {
        Self { runtime }
    }
}

impl<'a> TileBackend for PjrtBackend<'a> {
    fn fw(&self, d: &mut DistMatrix) {
        self.runtime
            .fw_block(d)
            .expect("PJRT fw_block failed (artifacts stale? run `make artifacts`)");
    }

    fn minplus_into(&self, c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
        self.runtime
            .minplus_into(c, a, b, m, k, n)
            .expect("PJRT minplus failed");
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn max_block(&self) -> Option<usize> {
        Some(self.runtime.max_fw_tile())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::backend::NativeBackend;
    use crate::apsp::floyd_warshall;
    use crate::graph::generators::{self, Weights};
    use crate::util::rng::Rng;
    use std::sync::OnceLock;

    /// Compiling artifacts takes ~seconds; share one runtime per test
    /// process. Tests are skipped when artifacts are absent (CI runs
    /// `make artifacts` first).
    fn runtime() -> Option<&'static PjrtRuntime> {
        static RT: OnceLock<Option<PjrtRuntime>> = OnceLock::new();
        RT.get_or_init(|| {
            let dir = Manifest::default_dir();
            if dir.join("manifest.json").exists() {
                Some(PjrtRuntime::load(&dir).expect("artifacts exist but failed to load"))
            } else {
                eprintln!("skipping PJRT tests: no artifacts (run `make artifacts`)");
                None
            }
        })
        .as_ref()
    }

    #[test]
    fn fw_exact_vs_native() {
        let Some(rt) = runtime() else { return };
        for &n in &[5usize, 30, 64, 100] {
            let g = generators::random_connected(n, n, Weights::Uniform(0.5, 4.0), n as u64);
            let mut d_pjrt = g.to_dense();
            rt.fw_block(&mut d_pjrt).unwrap();
            let mut d_native = g.to_dense();
            floyd_warshall::fw_rowwise(&mut d_native);
            let diff = d_pjrt.max_diff(&d_native);
            assert!(diff < 1e-4, "n={n}: diff {diff}");
        }
    }

    #[test]
    fn minplus_exact_vs_native() {
        let Some(rt) = runtime() else { return };
        let mut rng = Rng::new(3);
        for &(m, k, n) in &[(7usize, 9usize, 5usize), (64, 64, 64), (50, 20, 70)] {
            let gen = |len: usize, rng: &mut Rng| -> Vec<f32> {
                (0..len)
                    .map(|_| {
                        if rng.gen_bool(0.3) {
                            INF
                        } else {
                            rng.gen_f32_range(0.0, 9.0)
                        }
                    })
                    .collect()
            };
            let a = gen(m * k, &mut rng);
            let b = gen(k * n, &mut rng);
            let mut c1 = gen(m * n, &mut rng);
            let mut c2 = c1.clone();
            rt.minplus_into(&mut c1, &a, &b, m, k, n).unwrap();
            NativeBackend.minplus_into(&mut c2, &a, &b, m, k, n);
            assert_eq!(c1, c2, "({m},{k},{n})");
        }
    }

    #[test]
    fn padded_matches_native() {
        // sizes straddling class boundaries
        let Some(rt) = runtime() else { return };
        for &n in &[63usize, 65, 127, 129] {
            let g = generators::newman_watts_strogatz(
                n,
                3,
                0.2,
                Weights::Uniform(1.0, 5.0),
                n as u64,
            );
            let mut d_pjrt = g.to_dense();
            rt.fw_block(&mut d_pjrt).unwrap();
            let mut d_native = g.to_dense();
            floyd_warshall::fw_rowwise(&mut d_native);
            assert!(d_pjrt.max_diff(&d_native) < 1e-4, "n={n}");
        }
    }

    #[test]
    fn oversize_is_an_error() {
        let Some(rt) = runtime() else { return };
        let max = rt.max_fw_tile();
        let mut d = DistMatrix::new_diag0(max + 1);
        assert!(rt.fw_block(&mut d).is_err());
    }

    #[test]
    fn backend_trait_roundtrip() {
        let Some(rt) = runtime() else { return };
        let be = PjrtBackend::new(rt);
        assert_eq!(be.name(), "pjrt");
        let g = generators::complete(12, Weights::Uniform(1.0, 3.0), 4);
        let mut d = g.to_dense();
        be.fw(&mut d);
        let v = crate::apsp::validate::validate_full(&g, &d, 1e-4);
        assert!(v.ok(1e-4), "{v:?}");
    }
}
