//! No-op stand-in for [`super::engine`] when the crate is built without
//! the `pjrt` feature (the default: no XLA toolchain, no libpjrt).
//!
//! The types keep the full API surface so callers (`Executor`, benches,
//! examples) compile unchanged; every load attempt fails loudly with a
//! pointer at the feature flag, and the execution methods are
//! unreachable because a `PjrtRuntime` can never be constructed.

use super::artifacts::Manifest;
use crate::apsp::backend::TileBackend;
use crate::graph::dense::DistMatrix;
use crate::util::error::Result;
use std::marker::PhantomData;
use std::path::Path;

const DISABLED: &str =
    "PJRT backend unavailable: rebuild with `--features pjrt` (requires the XLA toolchain)";

/// Stand-in for the compiled-artifact runtime; cannot be constructed.
pub struct PjrtRuntime {
    pub manifest: Manifest,
    _no_construct: PhantomData<()>,
}

impl PjrtRuntime {
    pub fn load(_dir: &Path) -> Result<Self> {
        Err(crate::err!("{DISABLED}"))
    }

    pub fn load_default() -> Result<Self> {
        Err(crate::err!("{DISABLED}"))
    }

    pub fn max_fw_tile(&self) -> usize {
        unreachable!("{DISABLED}")
    }

    pub fn fw_block(&self, _d: &mut DistMatrix) -> Result<()> {
        unreachable!("{DISABLED}")
    }

    pub fn minplus_into(
        &self,
        _c: &mut [f32],
        _a: &[f32],
        _b: &[f32],
        _m: usize,
        _k: usize,
        _n: usize,
    ) -> Result<()> {
        unreachable!("{DISABLED}")
    }
}

/// Stand-in [`TileBackend`] adapter; only exists so call sites typecheck.
pub struct PjrtBackend<'a> {
    pub runtime: &'a PjrtRuntime,
}

impl<'a> PjrtBackend<'a> {
    pub fn new(runtime: &'a PjrtRuntime) -> Self {
        Self { runtime }
    }
}

impl<'a> TileBackend for PjrtBackend<'a> {
    fn fw(&self, _d: &mut DistMatrix) {
        unreachable!("{DISABLED}")
    }

    fn minplus_into(
        &self,
        _c: &mut [f32],
        _a: &[f32],
        _b: &[f32],
        _m: usize,
        _k: usize,
        _n: usize,
    ) {
        unreachable!("{DISABLED}")
    }

    fn name(&self) -> &'static str {
        "pjrt-disabled"
    }
}
