//! Dense distance matrix (paper Fig. 1b): the working representation for
//! FW and MP kernels. Row-major `f32` with the semiring's ⊕-identity for
//! "no path" (`+inf` for the default `(min, +)` instance).

use crate::apsp::semiring::SemiringId;
use crate::INF;

/// An `n x n` row-major distance matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct DistMatrix {
    n: usize,
    data: Vec<f32>,
}

impl DistMatrix {
    /// All-INF matrix with a zero diagonal NOT set (use `new_diag0`).
    pub fn new_inf(n: usize) -> Self {
        Self {
            n,
            data: vec![INF; n * n],
        }
    }

    /// All-INF with zero diagonal — the FW identity element.
    pub fn new_diag0(n: usize) -> Self {
        let mut m = Self::new_inf(n);
        for i in 0..n {
            m.set(i, i, 0.0);
        }
        m
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(n: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), n * n);
        Self { n, data }
    }

    /// [`DistMatrix::new_inf`] backed by an arena-leased buffer. The
    /// matrix is a normal owned value; callers on a hot path can return
    /// the backing store with `arena::recycle(m.into_vec())` when done.
    pub fn new_inf_pooled(n: usize) -> Self {
        Self {
            n,
            data: crate::util::arena::lease_filled(n * n, INF),
        }
    }

    /// [`DistMatrix::new_diag0`] backed by an arena-leased buffer.
    pub fn new_diag0_pooled(n: usize) -> Self {
        let mut m = Self::new_inf_pooled(n);
        for i in 0..n {
            m.set(i, i, 0.0);
        }
        m
    }

    /// All entries set to `fill` (the generic analogue of `new_inf`).
    pub fn new_full(n: usize, fill: f32) -> Self {
        Self {
            n,
            data: vec![fill; n * n],
        }
    }

    /// The DP identity matrix of semiring `sr`: ⊕-identity background,
    /// ⊗-identity diagonal (for `(min, +)` this is `new_diag0`).
    pub fn new_ident_sr(n: usize, sr: SemiringId) -> Self {
        let mut m = Self::new_full(n, sr.zero());
        for i in 0..n {
            m.set(i, i, sr.one());
        }
        m
    }

    /// [`DistMatrix::new_ident_sr`] backed by an arena-leased buffer.
    pub fn new_ident_sr_pooled(n: usize, sr: SemiringId) -> Self {
        let mut m = Self {
            n,
            data: crate::util::arena::lease_filled(n * n, sr.zero()),
        };
        for i in 0..n {
            m.set(i, i, sr.one());
        }
        m
    }

    /// All entries set to `sr`'s ⊕-identity, arena-leased (the generic
    /// analogue of `new_inf_pooled`).
    pub fn new_zero_sr_pooled(n: usize, sr: SemiringId) -> Self {
        Self {
            n,
            data: crate::util::arena::lease_filled(n * n, sr.zero()),
        }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.n && j < self.n);
        self.data[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.n && j < self.n);
        self.data[i * self.n + j] = v;
    }

    /// `D[i][j] = min(D[i][j], v)` — the `(min, +)` accumulate.
    #[inline]
    pub fn relax(&mut self, i: usize, j: usize, v: f32) {
        let slot = &mut self.data[i * self.n + j];
        if v < *slot {
            *slot = v;
        }
    }

    /// `D[i][j] = D[i][j] ⊕ v` — the semiring accumulate. For
    /// `SemiringId::MinPlus` this is bit-identical to [`relax`](Self::relax)
    /// (same tie-keeps-accumulator select).
    #[inline]
    pub fn relax_sr(&mut self, i: usize, j: usize, v: f32, sr: SemiringId) {
        let slot = &mut self.data[i * self.n + j];
        *slot = sr.combine(*slot, v);
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.n..(i + 1) * self.n]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.n..(i + 1) * self.n]
    }

    /// Column `j` copied out (rows are the contiguous axis).
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.n).map(|i| self.get(i, j)).collect()
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Copy the `rows x cols` block at `(r0, c0)` out of this matrix.
    pub fn block(&self, r0: usize, c0: usize, rows: usize, cols: usize) -> DistMatrix {
        assert!(r0 + rows <= self.n && c0 + cols <= self.n);
        assert_eq!(rows, cols, "block() returns square blocks");
        let mut out = DistMatrix::new_inf(rows);
        for i in 0..rows {
            out.row_mut(i)
                .copy_from_slice(&self.data[(r0 + i) * self.n + c0..(r0 + i) * self.n + c0 + cols]);
        }
        out
    }

    /// Gather the sub-matrix on index sets `rows x cols`.
    pub fn gather(&self, rows: &[usize], cols: &[usize]) -> Vec<f32> {
        let mut out = Vec::with_capacity(rows.len() * cols.len());
        for &i in rows {
            for &j in cols {
                out.push(self.get(i, j));
            }
        }
        out
    }

    /// Scatter-min `vals` (a `rows.len() x cols.len()` row-major block)
    /// into this matrix at the given index sets.
    pub fn scatter_min(&mut self, rows: &[usize], cols: &[usize], vals: &[f32]) {
        assert_eq!(vals.len(), rows.len() * cols.len());
        for (bi, &i) in rows.iter().enumerate() {
            for (bj, &j) in cols.iter().enumerate() {
                self.relax(i, j, vals[bi * cols.len() + bj]);
            }
        }
    }

    /// Scatter-⊕ `vals` (a `rows.len() x cols.len()` row-major block)
    /// into this matrix at the given index sets — the semiring
    /// analogue of [`scatter_min`](Self::scatter_min).
    pub fn scatter_sr(&mut self, rows: &[usize], cols: &[usize], vals: &[f32], sr: SemiringId) {
        assert_eq!(vals.len(), rows.len() * cols.len());
        for (bi, &i) in rows.iter().enumerate() {
            for (bj, &j) in cols.iter().enumerate() {
                self.relax_sr(i, j, vals[bi * cols.len() + bj], sr);
            }
        }
    }

    /// Pad to `m >= n` with INF off-diagonal, 0 on the new diagonal.
    /// Padding vertices are isolated, so FW/MP results on the top-left
    /// `n x n` corner are unchanged — this is how ragged components map
    /// onto fixed-size tile kernels.
    pub fn pad_to(&self, m: usize) -> DistMatrix {
        assert!(m >= self.n);
        let mut out = DistMatrix::new_diag0(m);
        for i in 0..self.n {
            out.row_mut(i)[..self.n].copy_from_slice(self.row(i));
        }
        out
    }

    /// Semiring-aware [`pad_to`](Self::pad_to): padding vertices are
    /// isolated in `sr`'s element domain (⊕-identity off-diagonal,
    /// ⊗-identity on the new diagonal).
    pub fn pad_to_sr(&self, m: usize, sr: SemiringId) -> DistMatrix {
        assert!(m >= self.n);
        let mut out = DistMatrix::new_ident_sr(m, sr);
        for i in 0..self.n {
            out.row_mut(i)[..self.n].copy_from_slice(self.row(i));
        }
        out
    }

    /// Take the top-left `k x k` corner.
    pub fn truncate(&self, k: usize) -> DistMatrix {
        assert!(k <= self.n);
        let mut out = DistMatrix::new_inf(k);
        for i in 0..k {
            out.row_mut(i).copy_from_slice(&self.row(i)[..k]);
        }
        out
    }

    /// Max finite absolute difference against another matrix (equal
    /// infinities count as equal). Returns INF if one side is finite
    /// and the other is not, or if the two sides hold infinities of
    /// opposite sign (`+inf` vs `-inf` is a real mismatch — the
    /// max-plus semiring uses `-inf` as its "no path" sentinel, so the
    /// old any-non-finite-pair-is-equal rule would mask corruption).
    pub fn max_diff(&self, other: &DistMatrix) -> f32 {
        assert_eq!(self.n, other.n);
        let mut worst = 0f32;
        for (a, b) in self.data.iter().zip(&other.data) {
            let d = match (a.is_finite(), b.is_finite()) {
                (true, true) => (a - b).abs(),
                (false, false) => {
                    if a == b {
                        0.0
                    } else {
                        INF
                    }
                }
                _ => INF,
            };
            if d > worst {
                worst = d;
            }
        }
        worst
    }

    /// Count finite (reachable) entries.
    pub fn finite_count(&self) -> usize {
        self.data.iter().filter(|x| x.is_finite()).count()
    }

    /// Bytes of the dense payload.
    pub fn dense_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

impl std::fmt::Display for DistMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for i in 0..self.n.min(16) {
            for j in 0..self.n.min(16) {
                let v = self.get(i, j);
                if v.is_finite() {
                    write!(f, "{v:7.2} ")?;
                } else {
                    write!(f, "    inf ")?;
                }
            }
            writeln!(f)?;
        }
        if self.n > 16 {
            writeln!(f, "... ({n} x {n})", n = self.n)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diag0_identity() {
        let d = DistMatrix::new_diag0(4);
        for i in 0..4 {
            for j in 0..4 {
                if i == j {
                    assert_eq!(d.get(i, j), 0.0);
                } else {
                    assert!(d.get(i, j).is_infinite());
                }
            }
        }
    }

    #[test]
    fn relax_takes_min() {
        let mut d = DistMatrix::new_inf(2);
        d.relax(0, 1, 5.0);
        d.relax(0, 1, 3.0);
        d.relax(0, 1, 9.0);
        assert_eq!(d.get(0, 1), 3.0);
    }

    #[test]
    fn block_extraction() {
        let mut d = DistMatrix::new_diag0(4);
        d.set(1, 2, 7.0);
        d.set(2, 1, 8.0);
        let b = d.block(1, 1, 2, 2);
        assert_eq!(b.get(0, 1), 7.0);
        assert_eq!(b.get(1, 0), 8.0);
        assert_eq!(b.get(0, 0), 0.0);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut d = DistMatrix::new_diag0(5);
        d.set(0, 3, 2.0);
        d.set(3, 0, 4.0);
        let rows = [0usize, 3];
        let cols = [0usize, 3];
        let vals = d.gather(&rows, &cols);
        assert_eq!(vals, vec![0.0, 2.0, 4.0, 0.0]);

        let mut e = DistMatrix::new_inf(5);
        e.scatter_min(&rows, &cols, &vals);
        assert_eq!(e.get(0, 3), 2.0);
        assert_eq!(e.get(3, 0), 4.0);
        // scatter_min keeps existing smaller values
        e.set(0, 3, 1.0);
        e.scatter_min(&rows, &cols, &vals);
        assert_eq!(e.get(0, 3), 1.0);
    }

    #[test]
    fn pad_preserves_corner_and_isolates() {
        let mut d = DistMatrix::new_diag0(2);
        d.set(0, 1, 5.0);
        let p = d.pad_to(4);
        assert_eq!(p.get(0, 1), 5.0);
        assert_eq!(p.get(2, 2), 0.0);
        assert!(p.get(0, 2).is_infinite());
        assert!(p.get(3, 1).is_infinite());
        let t = p.truncate(2);
        assert_eq!(t, d);
    }

    #[test]
    fn max_diff_semantics() {
        let mut a = DistMatrix::new_diag0(2);
        let mut b = DistMatrix::new_diag0(2);
        assert_eq!(a.max_diff(&b), 0.0);
        a.set(0, 1, 5.0);
        assert!(a.max_diff(&b).is_infinite()); // finite vs inf
        b.set(0, 1, 5.5);
        assert!((a.max_diff(&b) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn finite_count() {
        let d = DistMatrix::new_diag0(3);
        assert_eq!(d.finite_count(), 3);
    }

    #[test]
    fn max_diff_distinguishes_infinity_signs() {
        // regression: +inf vs -inf is a mismatch, not "both non-finite
        // so equal" — max-plus uses -inf as its absorbing zero
        let mut a = DistMatrix::new_diag0(2);
        let mut b = DistMatrix::new_diag0(2);
        a.set(0, 1, INF);
        b.set(0, 1, f32::NEG_INFINITY);
        assert!(a.max_diff(&b).is_infinite());
        b.set(0, 1, INF);
        assert_eq!(a.max_diff(&b), 0.0);
        a.set(1, 0, f32::NEG_INFINITY);
        b.set(1, 0, f32::NEG_INFINITY);
        assert_eq!(a.max_diff(&b), 0.0);
    }

    #[test]
    fn ident_sr_matches_semiring_identities() {
        use crate::apsp::semiring::ALL_SEMIRINGS;
        for sr in ALL_SEMIRINGS {
            let d = DistMatrix::new_ident_sr(3, sr);
            for i in 0..3 {
                for j in 0..3 {
                    let want = if i == j { sr.one() } else { sr.zero() };
                    assert_eq!(d.get(i, j).to_bits(), want.to_bits(), "{}", sr.name());
                }
            }
        }
        // MinPlus identity must be bit-identical to the concrete ctor
        let a = DistMatrix::new_ident_sr(4, SemiringId::MinPlus);
        let b = DistMatrix::new_diag0(4);
        assert_eq!(a, b);
    }

    #[test]
    fn relax_sr_minplus_matches_relax() {
        let mut a = DistMatrix::new_inf(2);
        let mut b = DistMatrix::new_inf(2);
        for v in [5.0, 3.0, 9.0] {
            a.relax(0, 1, v);
            b.relax_sr(0, 1, v, SemiringId::MinPlus);
        }
        assert_eq!(a, b);
        // max-min keeps the widest value instead
        let mut w = DistMatrix::new_full(2, 0.0);
        for v in [5.0, 3.0, 9.0] {
            w.relax_sr(0, 1, v, SemiringId::MaxMin);
        }
        assert_eq!(w.get(0, 1), 9.0);
    }

    #[test]
    fn rows_and_cols() {
        let mut d = DistMatrix::new_diag0(3);
        d.set(0, 1, 1.0);
        d.set(0, 2, 2.0);
        assert_eq!(d.row(0), &[0.0, 1.0, 2.0]);
        assert_eq!(d.col(0), vec![0.0, INF, INF]);
    }
}
