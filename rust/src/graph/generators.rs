//! Synthetic graph generators — the paper's workloads (substituting for
//! NiemaGraphGen [34] and the OGBN-Products download, unavailable
//! offline):
//!
//! * `newman_watts_strogatz` — NWS small-world [32]: ring lattice plus
//!   random shortcuts; "dense intra-community but sparse inter-community
//!   links" (paper §IV-A).
//! * `erdos_renyi` — ER [33]: uniformly random edges.
//! * `ogbn_proxy` — planted-partition clustered graph sized like
//!   OGBN-Products (2,449,029 vertices, avg degree 25.25): the co-purchase
//!   network's community structure is what the paper's partitioner
//!   exploits, and a planted partition reproduces exactly that property.
//! * `grid2d` — road-network-like 2D lattice (the urban-planning
//!   motivation in the paper's intro).

use super::csr::CsrGraph;
use crate::util::rng::Rng;

/// Weight distribution for generated edges.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Weights {
    /// All edges weight 1 (hop counts).
    Unit,
    /// Uniform in `[lo, hi)`.
    Uniform(f32, f32),
}

impl Weights {
    fn sample(&self, rng: &mut Rng) -> f32 {
        match *self {
            Weights::Unit => 1.0,
            Weights::Uniform(lo, hi) => rng.gen_f32_range(lo, hi),
        }
    }
}

/// Named topology used by the Fig. 9(c,f) sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// clustered (NWS)
    Nws,
    /// real-world proxy (OGBN-like planted partition)
    OgbnProxy,
    /// random (ER)
    Er,
    /// road-network grid
    Grid,
}

impl Topology {
    pub fn name(&self) -> &'static str {
        match self {
            Topology::Nws => "NWS",
            Topology::OgbnProxy => "OGBN-proxy",
            Topology::Er => "ER",
            Topology::Grid => "Grid",
        }
    }

    pub fn parse(s: &str) -> Option<Topology> {
        match s.to_ascii_lowercase().as_str() {
            "nws" | "clustered" => Some(Topology::Nws),
            "ogbn" | "ogbn-proxy" | "real" => Some(Topology::OgbnProxy),
            "er" | "random" => Some(Topology::Er),
            "grid" | "road" => Some(Topology::Grid),
            _ => None,
        }
    }
}

/// Generate a graph of the given topology with ~`avg_degree` and `n`
/// vertices (undirected; avg degree counts both directions).
pub fn generate(
    topo: Topology,
    n: usize,
    avg_degree: f64,
    weights: Weights,
    seed: u64,
) -> CsrGraph {
    match topo {
        Topology::Nws => {
            // degree is carried by the ring half-width k; the shortcut
            // probability stays a fixed topology constant (avg = 2k(1+p))
            // so that a degree sweep changes edge density, not the
            // small-world structure — matching the paper's Fig. 9(a)
            // setup where degree varies at fixed topology
            let p = 0.05;
            let k = ((avg_degree / (2.0 * (1.0 + p))).round() as usize).max(1);
            newman_watts_strogatz(n, k, p, weights, seed)
        }
        Topology::OgbnProxy => ogbn_proxy(n, avg_degree, weights, seed),
        Topology::Er => {
            let m = (n as f64 * avg_degree / 2.0).round() as usize;
            erdos_renyi(n, m, weights, seed)
        }
        Topology::Grid => {
            let side = (n as f64).sqrt().round() as usize;
            grid2d(side.max(2), side.max(2), weights, seed)
        }
    }
}

/// Newman–Watts–Strogatz small world: a ring lattice where each vertex
/// connects to its `k` nearest neighbors on each side, plus random
/// shortcuts added with probability `p` per lattice edge (NWS adds
/// shortcuts rather than rewiring, so the lattice stays connected).
///
/// Shortcut endpoints snap to *junction* vertices (every 16th), the way
/// long-range links concentrate on hubs/interchanges in the clustered
/// networks the paper evaluates ("NWS preserves dense intra-community
/// but sparse inter-community links", §IV-A). This is what gives the
/// partitioner small boundary sets on NWS — a uniform-endpoint variant
/// behaves like ER for boundary purposes and is available as
/// [`nws_uniform`].
pub fn newman_watts_strogatz(n: usize, k: usize, p: f64, weights: Weights, seed: u64) -> CsrGraph {
    nws_impl(n, k, p, weights, seed, 16)
}

/// NWS with uniform shortcut endpoints (no junction concentration).
pub fn nws_uniform(n: usize, k: usize, p: f64, weights: Weights, seed: u64) -> CsrGraph {
    nws_impl(n, k, p, weights, seed, 1)
}

fn nws_impl(
    n: usize,
    k: usize,
    p: f64,
    weights: Weights,
    seed: u64,
    junction_spacing: usize,
) -> CsrGraph {
    assert!(n > 2 * k, "n must exceed 2k (n={n}, k={k})");
    let mut rng = Rng::new(seed);
    let snap = |v: usize| -> usize { v / junction_spacing * junction_spacing % n };
    let mut edges: Vec<(u32, u32, f32)> =
        Vec::with_capacity(n * k + (n as f64 * k as f64 * p) as usize + 16);
    for u in 0..n {
        for d in 1..=k {
            let v = (u + d) % n;
            edges.push((u as u32, v as u32, weights.sample(&mut rng)));
            if rng.gen_bool(p) {
                // shortcut between junction vertices, with ring-distance
                // decay (Kleinberg navigable small world): length is
                // log-uniform in [spacing, n/2], so most shortcuts are
                // regional and a few span the ring — transportation
                // networks look like this, and it keeps the boundary
                // graph recursively partitionable
                let s = snap(u);
                let lo = junction_spacing.max(2) as f64;
                let hi = (n / 2).max(junction_spacing * 2) as f64;
                let dist = (lo * (hi / lo).powf(rng.gen_f64())) as usize;
                let t = if rng.gen_bool(0.5) {
                    snap((s + dist) % n)
                } else {
                    snap((s + n - dist % n) % n)
                };
                if t != s {
                    edges.push((s as u32, t as u32, weights.sample(&mut rng)));
                }
            }
        }
    }
    CsrGraph::from_undirected_edges(n, &edges)
}

/// Erdős–Rényi G(n, m): `m` undirected edges sampled uniformly.
pub fn erdos_renyi(n: usize, m: usize, weights: Weights, seed: u64) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let mut edges: Vec<(u32, u32, f32)> = Vec::with_capacity(m);
    let mut attempts = 0usize;
    while edges.len() < m && attempts < m * 4 + 64 {
        attempts += 1;
        let u = rng.gen_range(n);
        let v = rng.gen_range(n);
        if u != v {
            edges.push((u as u32, v as u32, weights.sample(&mut rng)));
        }
    }
    CsrGraph::from_undirected_edges(n, &edges)
}

/// Planted-partition "OGBN proxy": communities whose sizes follow a
/// heavy-tailed distribution (like product categories), dense inside,
/// sparse between — the structure the paper's recursive partitioner
/// exploits (small boundary sets). `intra_frac` of edge endpoints stay
/// within the community.
pub fn ogbn_proxy(n: usize, avg_degree: f64, weights: Weights, seed: u64) -> CsrGraph {
    // OGBN-Products has strong community locality; community sizes span
    // a heavy-tailed range like product categories. Communities are
    // capped at one PIM tile (1024) — the regime where METIS recovers
    // whole clusters, which is what gives the paper's partitioner its
    // small boundary sets on real-world graphs (a community larger than
    // a tile with no internal structure forces an unavoidable dense cut
    // no partitioner can dodge).
    ogbn_proxy_with(n, avg_degree, 64, 1024, 0.92, weights, seed)
}

/// Planted partition with explicit community-size range `[comm_lo,
/// comm_hi]` (log-uniform) and intra-community edge fraction.
pub fn ogbn_proxy_with(
    n: usize,
    avg_degree: f64,
    comm_lo: usize,
    comm_hi: usize,
    intra_frac: f64,
    weights: Weights,
    seed: u64,
) -> CsrGraph {
    assert!(comm_lo >= 2 && comm_hi >= comm_lo);
    let mut rng = Rng::new(seed);
    let spread = (comm_hi as f64 / comm_lo as f64).log2();
    let mut comm_of = vec![0u32; n];
    let mut comm_start = Vec::new();
    let mut next = 0usize;
    let mut cid = 0u32;
    while next < n {
        let lg = rng.gen_f64() * spread;
        let size = ((comm_lo as f64 * 2f64.powf(lg)) as usize)
            .min(n - next)
            .max(2.min(n - next));
        comm_start.push(next);
        for v in next..next + size {
            comm_of[v] = cid;
        }
        next += size;
        cid += 1;
    }
    comm_start.push(n);
    let ncomm = cid as usize;

    let m_total = (n as f64 * avg_degree / 2.0).round() as usize;
    // Inter-community edges attach to community *hubs* (the first ~8% of
    // each community) on both sides — real-world clustered graphs
    // concentrate cross-community connectivity on high-degree vertices,
    // which is exactly why their partition boundaries stay small (the
    // property the paper's Fig. 9(c) exploits).
    let hub_of = |c: usize, rng: &mut Rng| -> usize {
        let (lo, hi) = (comm_start[c], comm_start[c + 1]);
        let hubs = ((hi - lo) / 12).max(1);
        lo + rng.gen_range(hubs)
    };
    let mut edges: Vec<(u32, u32, f32)> = Vec::with_capacity(m_total);
    for _ in 0..m_total {
        if rng.gen_bool(intra_frac) {
            // intra-community edge
            let c = {
                let u = rng.gen_range(n);
                comm_of[u] as usize
            };
            let (lo, hi) = (comm_start[c], comm_start[c + 1]);
            if hi - lo < 2 {
                continue;
            }
            let u = lo + rng.gen_range(hi - lo);
            let mut v = lo + rng.gen_range(hi - lo);
            if v == u {
                v = lo + (v - lo + 1) % (hi - lo);
            }
            edges.push((u as u32, v as u32, weights.sample(&mut rng)));
        } else {
            // inter-community hub-to-hub edge. Most cross links go to
            // *nearby* communities (related product categories): this
            // meta-locality is what lets the boundary graph itself stay
            // partitionable, which the recursion (paper §III-A) depends
            // on — with uniformly random category links no partitioner
            // could shrink the boundary at any level.
            let c1 = rng.gen_range(ncomm);
            let c2 = if ncomm > 2 && rng.gen_bool(0.9) {
                let window = 3.min(ncomm - 1);
                let off = 1 + rng.gen_range(window);
                if rng.gen_bool(0.5) {
                    (c1 + off) % ncomm
                } else {
                    (c1 + ncomm - off) % ncomm
                }
            } else {
                rng.gen_range(ncomm)
            };
            if c1 == c2 {
                continue;
            }
            let u = hub_of(c1, &mut rng);
            let v = hub_of(c2, &mut rng);
            edges.push((u as u32, v as u32, weights.sample(&mut rng)));
        }
    }
    // Ensure connectivity between consecutive communities (a thin spanning
    // chain through the hubs, like the co-purchase giant component).
    for c in 1..ncomm {
        let u = hub_of(c - 1, &mut rng);
        let v = hub_of(c, &mut rng);
        edges.push((u as u32, v as u32, weights.sample(&mut rng)));
    }
    CsrGraph::from_undirected_edges(n, &edges)
}

/// 2D grid (road-network proxy): `rows x cols` lattice, 4-neighbor.
pub fn grid2d(rows: usize, cols: usize, weights: Weights, seed: u64) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let n = rows * cols;
    let mut edges = Vec::with_capacity(2 * n);
    let at = |r: usize, c: usize| (r * cols + c) as u32;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                edges.push((at(r, c), at(r, c + 1), weights.sample(&mut rng)));
            }
            if r + 1 < rows {
                edges.push((at(r, c), at(r + 1, c), weights.sample(&mut rng)));
            }
        }
    }
    CsrGraph::from_undirected_edges(n, &edges)
}

/// A complete graph (small n only) — used by kernel tests.
pub fn complete(n: usize, weights: Weights, seed: u64) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(n * (n - 1) / 2);
    for u in 0..n {
        for v in (u + 1)..n {
            edges.push((u as u32, v as u32, weights.sample(&mut rng)));
        }
    }
    CsrGraph::from_undirected_edges(n, &edges)
}

/// Random connected graph: a random spanning tree plus `extra` random
/// edges — guarantees one component (used heavily by property tests).
pub fn random_connected(n: usize, extra: usize, weights: Weights, seed: u64) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(n + extra);
    // random attachment spanning tree
    for v in 1..n {
        let u = rng.gen_range(v);
        edges.push((u as u32, v as u32, weights.sample(&mut rng)));
    }
    for _ in 0..extra {
        let u = rng.gen_range(n);
        let v = rng.gen_range(n);
        if u != v {
            edges.push((u as u32, v as u32, weights.sample(&mut rng)));
        }
    }
    CsrGraph::from_undirected_edges(n, &edges)
}

/// OGBN-Products' published size: 2,449,029 vertices, 61,859,140 edges
/// (avg degree 25.26 counting each undirected edge once per endpoint... the
/// paper reports degree 25.25 in Fig. 9).
pub const OGBN_PRODUCTS_N: usize = 2_449_029;
pub const OGBN_PRODUCTS_AVG_DEGREE: f64 = 25.25;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::properties;

    #[test]
    fn nws_shape() {
        let g = newman_watts_strogatz(200, 4, 0.1, Weights::Unit, 1);
        g.validate().unwrap();
        assert_eq!(g.n(), 200);
        // ring degree 8 plus some shortcuts
        assert!(g.avg_degree() >= 8.0, "deg={}", g.avg_degree());
        assert!(g.avg_degree() < 11.0, "deg={}", g.avg_degree());
        assert!(properties::is_connected(&g));
    }

    #[test]
    fn er_edge_count() {
        let g = erdos_renyi(500, 2000, Weights::Uniform(1.0, 10.0), 2);
        g.validate().unwrap();
        // ~2000 undirected edges stored twice, minus dup collisions
        assert!(g.m() > 3600 && g.m() <= 4000, "m={}", g.m());
    }

    #[test]
    fn ogbn_proxy_clustered() {
        let g = ogbn_proxy(4000, 20.0, Weights::Unit, 3);
        g.validate().unwrap();
        let d = g.avg_degree();
        assert!(d > 15.0 && d < 25.0, "deg={d}");
        assert!(properties::is_connected(&g));
    }

    #[test]
    fn grid_degree_bounds() {
        let g = grid2d(10, 10, Weights::Unit, 4);
        g.validate().unwrap();
        assert_eq!(g.n(), 100);
        for v in 0..100 {
            assert!(g.degree(v) >= 2 && g.degree(v) <= 4);
        }
        assert!(properties::is_connected(&g));
    }

    #[test]
    fn complete_graph() {
        let g = complete(10, Weights::Unit, 5);
        assert_eq!(g.m(), 90);
        for v in 0..10 {
            assert_eq!(g.degree(v), 9);
        }
    }

    #[test]
    fn random_connected_is_connected() {
        for seed in 0..5 {
            let g = random_connected(100, 50, Weights::Uniform(0.5, 2.0), seed);
            g.validate().unwrap();
            assert!(properties::is_connected(&g), "seed {seed}");
        }
    }

    #[test]
    fn generate_dispatch_hits_target_degree() {
        for topo in [Topology::Nws, Topology::OgbnProxy, Topology::Er] {
            let g = generate(topo, 3000, 24.0, Weights::Unit, 7);
            let d = g.avg_degree();
            assert!(
                d > 16.0 && d < 32.0,
                "{}: degree {d} too far from 24",
                topo.name()
            );
        }
    }

    #[test]
    fn generators_deterministic() {
        let a = newman_watts_strogatz(100, 3, 0.2, Weights::Uniform(1.0, 5.0), 42);
        let b = newman_watts_strogatz(100, 3, 0.2, Weights::Uniform(1.0, 5.0), 42);
        assert_eq!(a, b);
        let c = newman_watts_strogatz(100, 3, 0.2, Weights::Uniform(1.0, 5.0), 43);
        assert_ne!(a, c);
    }

    #[test]
    fn topology_parse() {
        assert_eq!(Topology::parse("nws"), Some(Topology::Nws));
        assert_eq!(Topology::parse("ER"), Some(Topology::Er));
        assert_eq!(Topology::parse("ogbn"), Some(Topology::OgbnProxy));
        assert_eq!(Topology::parse("bogus"), None);
    }
}
