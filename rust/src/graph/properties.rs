//! Structural graph properties: connectivity, degree statistics, and a
//! clustering-coefficient estimate (used by the Fig. 9 topology analysis
//! to verify the generators produce the intended structure).

use super::csr::CsrGraph;
use crate::util::rng::Rng;

/// BFS reachability from vertex 0 — true iff the graph is connected
/// (treats edges as undirected: follows stored arcs only, so generators
/// must emit symmetric edge sets, which ours do).
pub fn is_connected(g: &CsrGraph) -> bool {
    let n = g.n();
    if n == 0 {
        return true;
    }
    connected_component(g, 0).len() == n
}

/// Vertices reachable from `src` following stored arcs.
pub fn connected_component(g: &CsrGraph, src: usize) -> Vec<u32> {
    let n = g.n();
    let mut seen = vec![false; n];
    let mut queue = std::collections::VecDeque::new();
    seen[src] = true;
    queue.push_back(src);
    let mut out = vec![src as u32];
    while let Some(v) = queue.pop_front() {
        for (u, _) in g.neighbors(v) {
            if !seen[u] {
                seen[u] = true;
                out.push(u as u32);
                queue.push_back(u);
            }
        }
    }
    out
}

/// All connected components, each a vertex list.
pub fn connected_components(g: &CsrGraph) -> Vec<Vec<u32>> {
    let n = g.n();
    let mut seen = vec![false; n];
    let mut comps = Vec::new();
    for s in 0..n {
        if seen[s] {
            continue;
        }
        let comp = connected_component(g, s);
        for &v in &comp {
            seen[v as usize] = true;
        }
        comps.push(comp);
    }
    comps
}

/// Degree statistics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DegreeStats {
    pub min: usize,
    pub max: usize,
    pub mean: f64,
    pub p50: usize,
    pub p99: usize,
}

pub fn degree_stats(g: &CsrGraph) -> DegreeStats {
    let n = g.n();
    if n == 0 {
        return DegreeStats {
            min: 0,
            max: 0,
            mean: 0.0,
            p50: 0,
            p99: 0,
        };
    }
    let mut degs: Vec<usize> = (0..n).map(|v| g.degree(v)).collect();
    degs.sort_unstable();
    DegreeStats {
        min: degs[0],
        max: degs[n - 1],
        mean: g.avg_degree(),
        p50: degs[n / 2],
        p99: degs[(n as f64 * 0.99) as usize],
    }
}

/// Sampled local clustering coefficient (average over `samples` random
/// vertices of degree >= 2). Clustered topologies (NWS, OGBN-proxy)
/// score high; ER scores ~degree/n.
pub fn clustering_coefficient(g: &CsrGraph, samples: usize, seed: u64) -> f64 {
    let n = g.n();
    if n == 0 {
        return 0.0;
    }
    let mut rng = Rng::new(seed);
    let mut total = 0.0;
    let mut count = 0usize;
    for _ in 0..samples {
        let v = rng.gen_range(n);
        let nbrs: Vec<usize> = g.neighbors(v).map(|(u, _)| u).collect();
        if nbrs.len() < 2 {
            continue;
        }
        let mut links = 0usize;
        for (i, &a) in nbrs.iter().enumerate() {
            for &b in &nbrs[i + 1..] {
                if g.edge_weight(a, b).is_some() {
                    links += 1;
                }
            }
        }
        let possible = nbrs.len() * (nbrs.len() - 1) / 2;
        total += links as f64 / possible as f64;
        count += 1;
    }
    if count == 0 {
        0.0
    } else {
        total / count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, Weights};

    #[test]
    fn path_graph_connected() {
        let g = CsrGraph::from_undirected_edges(4, &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0)]);
        assert!(is_connected(&g));
    }

    #[test]
    fn disconnected_detected() {
        let g = CsrGraph::from_undirected_edges(4, &[(0, 1, 1.0), (2, 3, 1.0)]);
        assert!(!is_connected(&g));
        let comps = connected_components(&g);
        assert_eq!(comps.len(), 2);
        assert_eq!(comps[0].len(), 2);
    }

    #[test]
    fn empty_and_singleton() {
        assert!(is_connected(&CsrGraph::empty(0)));
        assert!(is_connected(&CsrGraph::empty(1)));
        assert!(!is_connected(&CsrGraph::empty(2)));
    }

    #[test]
    fn degree_stats_basic() {
        let g = generators::grid2d(8, 8, Weights::Unit, 1);
        let s = degree_stats(&g);
        assert_eq!(s.min, 2); // corners
        assert_eq!(s.max, 4); // interior
        assert!(s.mean > 2.0 && s.mean < 4.0);
    }

    #[test]
    fn clustering_separates_topologies() {
        let nws = generators::newman_watts_strogatz(2000, 6, 0.05, Weights::Unit, 2);
        let er = generators::erdos_renyi(2000, 12000, Weights::Unit, 2);
        let c_nws = clustering_coefficient(&nws, 300, 3);
        let c_er = clustering_coefficient(&er, 300, 3);
        assert!(
            c_nws > 3.0 * c_er,
            "NWS clustering {c_nws} should dominate ER {c_er}"
        );
    }
}
