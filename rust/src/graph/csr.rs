//! Compressed sparse row (CSR) weighted graph (paper §II-A, Fig. 1c).
//!
//! Storage layout matches the paper: `rowptr`, `col`, `val`. Graphs are
//! directed internally; the generators emit symmetric edge sets for the
//! undirected workloads the paper evaluates.

use crate::graph::dense::DistMatrix;
use crate::INF;

/// A weighted graph in CSR form.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrGraph {
    /// `rowptr[v]..rowptr[v+1]` indexes `col`/`val` for vertex `v`.
    pub rowptr: Vec<usize>,
    /// Neighbor vertex ids.
    pub col: Vec<u32>,
    /// Edge weights (non-negative, finite).
    pub val: Vec<f32>,
}

impl CsrGraph {
    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.rowptr.len() - 1
    }

    /// Number of directed edges stored.
    #[inline]
    pub fn m(&self) -> usize {
        self.col.len()
    }

    /// Average out-degree.
    pub fn avg_degree(&self) -> f64 {
        if self.n() == 0 {
            0.0
        } else {
            self.m() as f64 / self.n() as f64
        }
    }

    /// Neighbors of `v` as `(neighbor, weight)` pairs.
    #[inline]
    pub fn neighbors(&self, v: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let lo = self.rowptr[v];
        let hi = self.rowptr[v + 1];
        self.col[lo..hi]
            .iter()
            .zip(&self.val[lo..hi])
            .map(|(&c, &w)| (c as usize, w))
    }

    /// Out-degree of `v`.
    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        self.rowptr[v + 1] - self.rowptr[v]
    }

    /// Build from an edge list. Duplicate `(u,v)` edges keep the minimum
    /// weight; self-loops are dropped (distance to self is always 0).
    pub fn from_edges(n: usize, edges: &[(u32, u32, f32)]) -> Self {
        let mut deg = vec![0usize; n];
        for &(u, v, _) in edges {
            assert!((u as usize) < n && (v as usize) < n, "edge out of range");
            if u != v {
                deg[u as usize] += 1;
            }
        }
        let mut rowptr = vec![0usize; n + 1];
        for v in 0..n {
            rowptr[v + 1] = rowptr[v] + deg[v];
        }
        let m = rowptr[n];
        let mut col = vec![0u32; m];
        let mut val = vec![0f32; m];
        let mut fill = rowptr.clone();
        for &(u, v, w) in edges {
            if u == v {
                continue;
            }
            debug_assert!(w >= 0.0 && w.is_finite(), "weights must be finite >= 0");
            let slot = fill[u as usize];
            col[slot] = v;
            val[slot] = w;
            fill[u as usize] += 1;
        }
        let mut g = Self { rowptr, col, val };
        g.sort_and_dedup_min();
        g
    }

    /// Build an undirected graph from an edge list (adds both directions).
    pub fn from_undirected_edges(n: usize, edges: &[(u32, u32, f32)]) -> Self {
        let mut both = Vec::with_capacity(edges.len() * 2);
        for &(u, v, w) in edges {
            both.push((u, v, w));
            both.push((v, u, w));
        }
        Self::from_edges(n, &both)
    }

    /// Sort adjacency lists by neighbor id, keeping the min weight for
    /// duplicates.
    fn sort_and_dedup_min(&mut self) {
        let n = self.n();
        let mut new_rowptr = vec![0usize; n + 1];
        let mut new_col = Vec::with_capacity(self.m());
        let mut new_val = Vec::with_capacity(self.m());
        let mut scratch: Vec<(u32, f32)> = Vec::new();
        for v in 0..n {
            scratch.clear();
            scratch.extend(
                self.col[self.rowptr[v]..self.rowptr[v + 1]]
                    .iter()
                    .zip(&self.val[self.rowptr[v]..self.rowptr[v + 1]])
                    .map(|(&c, &w)| (c, w)),
            );
            scratch.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.partial_cmp(&b.1).unwrap()));
            let mut last: Option<u32> = None;
            for &(c, w) in scratch.iter() {
                if last == Some(c) {
                    continue; // keep first (min, due to sort)
                }
                last = Some(c);
                new_col.push(c);
                new_val.push(w);
            }
            new_rowptr[v + 1] = new_col.len();
        }
        self.rowptr = new_rowptr;
        self.col = new_col;
        self.val = new_val;
    }

    /// Weight of edge `(u, v)` if present (binary search).
    pub fn edge_weight(&self, u: usize, v: usize) -> Option<f32> {
        let lo = self.rowptr[u];
        let hi = self.rowptr[u + 1];
        let slice = &self.col[lo..hi];
        slice
            .binary_search(&(v as u32))
            .ok()
            .map(|i| self.val[lo + i])
    }

    /// Extract the vertex-induced subgraph on `verts` (graph vertex ids).
    /// Returns the subgraph with vertices renumbered `0..verts.len()` in
    /// the given order.
    pub fn induced_subgraph(&self, verts: &[u32]) -> CsrGraph {
        let mut inv = std::collections::HashMap::with_capacity(verts.len());
        for (local, &g) in verts.iter().enumerate() {
            inv.insert(g, local as u32);
        }
        let mut edges = Vec::new();
        for (local, &g) in verts.iter().enumerate() {
            for (nbr, w) in self.neighbors(g as usize) {
                if let Some(&nl) = inv.get(&(nbr as u32)) {
                    edges.push((local as u32, nl, w));
                }
            }
        }
        CsrGraph::from_edges(verts.len(), &edges)
    }

    /// Dense adjacency matrix (paper Fig. 1b): `A[i][j] = w(i,j)` or INF,
    /// diagonal 0. Only valid for small `n`.
    pub fn to_dense(&self) -> crate::graph::dense::DistMatrix {
        let n = self.n();
        let mut d = crate::graph::dense::DistMatrix::new_inf(n);
        for v in 0..n {
            d.set(v, v, 0.0);
            for (u, w) in self.neighbors(v) {
                if w < d.get(v, u) {
                    d.set(v, u, w);
                }
            }
        }
        d
    }

    /// Semiring-aware dense materialization: background = ⊕-identity,
    /// diagonal = ⊗-identity, and each stored edge contributes
    /// `from_weight(w)` through a ⊕-accumulate (parallel edges were
    /// already min-deduped at build; the ⊕ here handles the identity
    /// diagonal vs self-adjacent entries uniformly). For
    /// `SemiringId::MinPlus` this is bit-identical to [`to_dense`].
    pub fn to_dense_sr(&self, sr: crate::apsp::semiring::SemiringId) -> DistMatrix {
        let n = self.n();
        let mut d = DistMatrix::new_full(n, sr.zero());
        for v in 0..n {
            d.set(v, v, sr.one());
            for (u, w) in self.neighbors(v) {
                d.relax_sr(v, u, sr.from_weight(w), sr);
            }
        }
        d
    }

    /// Restrict to the DAG orientation `u -> v` with `u < v`: every
    /// stored edge whose target id is larger than its source survives,
    /// the rest are dropped. The result is acyclic by construction —
    /// the input transform the `critical` (max-plus) workload applies
    /// before solving, double-checked by [`assert_acyclic`].
    pub fn dag_oriented(&self) -> CsrGraph {
        let edges: Vec<(u32, u32, f32)> = self.edges().filter(|&(u, v, _)| u < v).collect();
        CsrGraph::from_edges(self.n(), &edges)
    }

    /// Kahn's-algorithm cycle guard: `Ok` iff the directed graph is
    /// acyclic (max-plus has no fixed point on a cyclic input).
    pub fn assert_acyclic(&self) -> Result<(), String> {
        let n = self.n();
        let mut indeg = vec![0usize; n];
        for (_, v, _) in self.edges() {
            indeg[v as usize] += 1;
        }
        let mut ready: Vec<usize> = (0..n).filter(|&v| indeg[v] == 0).collect();
        let mut seen = 0usize;
        while let Some(v) = ready.pop() {
            seen += 1;
            for (u, _) in self.neighbors(v) {
                indeg[u] -= 1;
                if indeg[u] == 0 {
                    ready.push(u);
                }
            }
        }
        if seen == n {
            Ok(())
        } else {
            Err(format!(
                "graph has a cycle: {} of {} vertices topologically ordered",
                seen, n
            ))
        }
    }

    /// Total bytes of the CSR arrays (the paper stores results compressed
    /// in FeNAND; this sizes those transfers).
    pub fn csr_bytes(&self) -> usize {
        self.rowptr.len() * std::mem::size_of::<usize>()
            + self.col.len() * 4
            + self.val.len() * 4
    }

    /// Check structural invariants (used by tests and generators).
    pub fn validate(&self) -> Result<(), String> {
        let n = self.n();
        if self.rowptr[0] != 0 {
            return Err("rowptr[0] != 0".into());
        }
        if *self.rowptr.last().unwrap() != self.col.len() {
            return Err("rowptr[n] != m".into());
        }
        if self.col.len() != self.val.len() {
            return Err("col/val length mismatch".into());
        }
        for v in 0..n {
            if self.rowptr[v] > self.rowptr[v + 1] {
                return Err(format!("rowptr not monotone at {v}"));
            }
            let lo = self.rowptr[v];
            let hi = self.rowptr[v + 1];
            for i in lo..hi {
                if self.col[i] as usize >= n {
                    return Err(format!("edge target out of range at row {v}"));
                }
                if self.col[i] as usize == v {
                    return Err(format!("self loop at {v}"));
                }
                if !(self.val[i] >= 0.0) || !self.val[i].is_finite() {
                    return Err(format!("bad weight at row {v}"));
                }
                if i > lo && self.col[i - 1] >= self.col[i] {
                    return Err(format!("row {v} not sorted/deduped"));
                }
            }
        }
        Ok(())
    }

    /// An empty graph with `n` isolated vertices.
    pub fn empty(n: usize) -> Self {
        Self {
            rowptr: vec![0; n + 1],
            col: Vec::new(),
            val: Vec::new(),
        }
    }

    /// Iterate all directed edges `(u, v, w)`.
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32, f32)> + '_ {
        (0..self.n()).flat_map(move |u| {
            self.neighbors(u)
                .map(move |(v, w)| (u as u32, v as u32, w))
        })
    }

    /// Shortest edge weight in the graph, INF if edgeless.
    pub fn min_weight(&self) -> f32 {
        self.val.iter().copied().fold(INF, f32::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> CsrGraph {
        // the paper's Fig. 1 toy graph shape: 8 vertices, sparse
        CsrGraph::from_undirected_edges(
            8,
            &[
                (0, 1, 3.0),
                (0, 2, 1.0),
                (1, 3, 2.0),
                (2, 3, 5.0),
                (3, 4, 1.5),
                (4, 5, 2.5),
                (5, 6, 1.0),
                (6, 7, 4.0),
            ],
        )
    }

    #[test]
    fn builds_valid_csr() {
        let g = toy();
        assert_eq!(g.n(), 8);
        assert_eq!(g.m(), 16); // both directions
        g.validate().unwrap();
    }

    #[test]
    fn neighbors_sorted() {
        let g = toy();
        let nbrs: Vec<usize> = g.neighbors(3).map(|(v, _)| v).collect();
        assert_eq!(nbrs, vec![1, 2, 4]);
    }

    #[test]
    fn duplicate_edges_keep_min() {
        let g = CsrGraph::from_edges(3, &[(0, 1, 5.0), (0, 1, 2.0), (0, 1, 9.0)]);
        assert_eq!(g.m(), 1);
        assert_eq!(g.edge_weight(0, 1), Some(2.0));
    }

    #[test]
    fn self_loops_dropped() {
        let g = CsrGraph::from_edges(3, &[(0, 0, 1.0), (1, 2, 1.0)]);
        assert_eq!(g.m(), 1);
        g.validate().unwrap();
    }

    #[test]
    fn edge_weight_lookup() {
        let g = toy();
        assert_eq!(g.edge_weight(0, 2), Some(1.0));
        assert_eq!(g.edge_weight(2, 0), Some(1.0));
        assert_eq!(g.edge_weight(0, 7), None);
    }

    #[test]
    fn induced_subgraph_renumbers() {
        let g = toy();
        let sub = g.induced_subgraph(&[0, 1, 3]);
        assert_eq!(sub.n(), 3);
        // edges kept: 0-1 (3.0), 1-3 (2.0) => local (0,1) and (1,2)
        assert_eq!(sub.edge_weight(0, 1), Some(3.0));
        assert_eq!(sub.edge_weight(1, 2), Some(2.0));
        assert_eq!(sub.edge_weight(0, 2), None); // 0-3 not an edge
        sub.validate().unwrap();
    }

    #[test]
    fn to_dense_matches_edges() {
        let g = toy();
        let d = g.to_dense();
        assert_eq!(d.get(0, 0), 0.0);
        assert_eq!(d.get(0, 1), 3.0);
        assert_eq!(d.get(1, 0), 3.0);
        assert!(d.get(0, 7).is_infinite());
    }

    #[test]
    fn to_dense_sr_minplus_bit_identical() {
        use crate::apsp::semiring::SemiringId;
        let g = toy();
        let a = g.to_dense();
        let b = g.to_dense_sr(SemiringId::MinPlus);
        assert_eq!(a, b);
    }

    #[test]
    fn to_dense_sr_backgrounds() {
        use crate::apsp::semiring::SemiringId;
        let g = toy();
        let r = g.to_dense_sr(SemiringId::BoolAndOr);
        assert_eq!(r.get(0, 1), 1.0); // edge present
        assert_eq!(r.get(0, 7), 0.0); // no edge
        assert_eq!(r.get(0, 0), 1.0); // self reachable
        let w = g.to_dense_sr(SemiringId::MaxMin);
        assert_eq!(w.get(0, 1), 3.0);
        assert_eq!(w.get(0, 7), 0.0);
        assert!(w.get(0, 0).is_infinite());
        let c = g.to_dense_sr(SemiringId::MaxPlus);
        assert_eq!(c.get(0, 1), 3.0);
        assert_eq!(c.get(0, 7), f32::NEG_INFINITY);
        assert_eq!(c.get(0, 0), 0.0);
    }

    #[test]
    fn dag_orientation_is_acyclic() {
        let g = toy();
        assert!(g.assert_acyclic().is_err(), "undirected graph has 2-cycles");
        let dag = g.dag_oriented();
        dag.validate().unwrap();
        dag.assert_acyclic().unwrap();
        // only the u < v direction survives
        assert_eq!(dag.edge_weight(0, 1), Some(3.0));
        assert_eq!(dag.edge_weight(1, 0), None);
        assert_eq!(dag.m(), 8);
    }

    #[test]
    fn empty_graph() {
        let g = CsrGraph::empty(5);
        assert_eq!(g.n(), 5);
        assert_eq!(g.m(), 0);
        g.validate().unwrap();
    }

    #[test]
    fn edges_iterator_roundtrip() {
        let g = toy();
        let edges: Vec<_> = g.edges().collect();
        let g2 = CsrGraph::from_edges(8, &edges);
        assert_eq!(g, g2);
    }

    #[test]
    fn csr_bytes_positive() {
        assert!(toy().csr_bytes() > 0);
    }
}
