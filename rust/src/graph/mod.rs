//! Graph substrate: CSR storage, dense distance matrices, synthetic
//! generators (the paper's NWS / ER / OGBN-proxy workloads), IO, and
//! structural properties.

pub mod csr;
pub mod dense;
pub mod generators;
pub mod io;
pub mod properties;

pub use csr::CsrGraph;
pub use dense::DistMatrix;
