//! Graph IO: a plain edge-list text format and a compact binary CSR
//! format (used to persist generated workloads and final APSP results —
//! the functional stand-in for the paper's FeNAND CSR storage).

use super::csr::CsrGraph;
use crate::bail;
use crate::util::error::{Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Write a text edge list: first line `n m`, then `u v w` per line.
pub fn write_edge_list(g: &CsrGraph, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "{} {}", g.n(), g.m())?;
    for (u, v, wt) in g.edges() {
        writeln!(w, "{u} {v} {wt}")?;
    }
    Ok(())
}

/// Read the text edge-list format written by `write_edge_list`.
pub fn read_edge_list(path: &Path) -> Result<CsrGraph> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut lines = BufReader::new(f).lines();
    let header = lines.next().context("empty file")??;
    let mut it = header.split_whitespace();
    let n: usize = it.next().context("missing n")?.parse()?;
    let m: usize = it.next().context("missing m")?.parse()?;
    let mut edges = Vec::with_capacity(m);
    for line in lines {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let u: u32 = it.next().context("missing u")?.parse()?;
        let v: u32 = it.next().context("missing v")?.parse()?;
        let w: f32 = it.next().map(|s| s.parse()).transpose()?.unwrap_or(1.0);
        edges.push((u, v, w));
    }
    Ok(CsrGraph::from_edges(n, &edges))
}

const BIN_MAGIC: &[u8; 8] = b"RAPIDCSR";

/// Write the compact binary CSR format (little-endian):
/// magic, n (u64), m (u64), rowptr (u64 * (n+1)), col (u32 * m), val (f32 * m).
pub fn write_binary(g: &CsrGraph, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(g.n() as u64).to_le_bytes())?;
    w.write_all(&(g.m() as u64).to_le_bytes())?;
    for &r in &g.rowptr {
        w.write_all(&(r as u64).to_le_bytes())?;
    }
    for &c in &g.col {
        w.write_all(&c.to_le_bytes())?;
    }
    for &v in &g.val {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read the binary CSR format.
pub fn read_binary(path: &Path) -> Result<CsrGraph> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BIN_MAGIC {
        bail!("bad magic in {}", path.display());
    }
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let n = u64::from_le_bytes(u64buf) as usize;
    r.read_exact(&mut u64buf)?;
    let m = u64::from_le_bytes(u64buf) as usize;
    let mut rowptr = Vec::with_capacity(n + 1);
    for _ in 0..=n {
        r.read_exact(&mut u64buf)?;
        rowptr.push(u64::from_le_bytes(u64buf) as usize);
    }
    let mut buf4 = [0u8; 4];
    let mut col = Vec::with_capacity(m);
    for _ in 0..m {
        r.read_exact(&mut buf4)?;
        col.push(u32::from_le_bytes(buf4));
    }
    let mut val = Vec::with_capacity(m);
    for _ in 0..m {
        r.read_exact(&mut buf4)?;
        val.push(f32::from_le_bytes(buf4));
    }
    let g = CsrGraph { rowptr, col, val };
    g.validate().map_err(crate::util::error::Error::msg)?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, Weights};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("rapid_graph_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn edge_list_roundtrip() {
        let g = generators::random_connected(50, 30, Weights::Uniform(0.5, 4.0), 9);
        let p = tmp("roundtrip.txt");
        write_edge_list(&g, &p).unwrap();
        let h = read_edge_list(&p).unwrap();
        assert_eq!(g, h);
    }

    #[test]
    fn binary_roundtrip() {
        let g = generators::newman_watts_strogatz(120, 4, 0.1, Weights::Uniform(1.0, 2.0), 11);
        let p = tmp("roundtrip.bin");
        write_binary(&g, &p).unwrap();
        let h = read_binary(&p).unwrap();
        assert_eq!(g, h);
    }

    #[test]
    fn edge_list_default_weight() {
        let p = tmp("unweighted.txt");
        std::fs::write(&p, "3 2\n0 1\n1 2\n").unwrap();
        let g = read_edge_list(&p).unwrap();
        assert_eq!(g.edge_weight(0, 1), Some(1.0));
        assert_eq!(g.edge_weight(1, 2), Some(1.0));
    }

    #[test]
    fn binary_rejects_garbage() {
        let p = tmp("garbage.bin");
        std::fs::write(&p, b"NOTMAGIC????").unwrap();
        assert!(read_binary(&p).is_err());
    }

    #[test]
    fn edge_list_skips_comments() {
        let p = tmp("comments.txt");
        std::fs::write(&p, "2 1\n# comment\n0 1 2.5\n\n").unwrap();
        let g = read_edge_list(&p).unwrap();
        assert_eq!(g.edge_weight(0, 1), Some(2.5));
    }
}
