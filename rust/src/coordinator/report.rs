//! Human-readable run reports: the coordinator's metrics output.

use super::executor::RunResult;
use crate::apsp::trace::Phase;
use crate::util::table::{fmt_count, fmt_energy, fmt_time, Table};

/// Render a full report for one run.
pub fn render(r: &RunResult) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "RAPID-Graph run: n={} m={} mode={} backend={} scheduler={}\n",
        fmt_count(r.graph_n),
        fmt_count(r.graph_m),
        r.mode.name(),
        r.backend_name,
        r.scheduler.name(),
    ));
    out.push_str(&format!(
        "recursion: depth={} components(L0)={} boundary={:?} final_n={}\n",
        r.depth,
        r.components_l0,
        r.boundary_sizes.iter().map(|&b| fmt_count(b)).collect::<Vec<_>>(),
        r.final_n,
    ));
    out.push_str(&format!(
        "modeled hardware: time={} energy={} (dynamic {}), FW util {:.1}%, MP util {:.1}%, prefetch hid {}\n",
        fmt_time(r.sim.seconds),
        fmt_energy(r.sim.joules),
        fmt_energy(r.sim.dynamic_joules),
        100.0 * r.sim.fw_utilization(),
        100.0 * r.sim.mp_utilization(),
        fmt_time(r.sim.prefetch_hidden),
    ));
    out.push_str(&format!(
        "work: {:.3e} min-adds, {:.3e} madds/s modeled\n",
        r.sim.madds as f64,
        r.sim.madds_per_sec(),
    ));
    if r.host_solve_seconds > 0.0 {
        out.push_str(&format!(
            "host numerics: {}\n",
            fmt_time(r.host_solve_seconds)
        ));
    }
    if let Some(v) = &r.validation {
        out.push_str(&format!(
            "validation: {} samples, max err {:.2e}, {} mismatches -> {}\n",
            v.checked,
            v.max_abs_err,
            v.mismatches,
            if v.ok(1e-3) { "EXACT" } else { "FAILED" },
        ));
    }
    // per-phase table. Shares are of the summed per-phase busy time:
    // under the barrier scheduler that equals wall time, under the dag
    // scheduler phases overlap, so wall time would make rows exceed
    // 100%.
    let phase_total: f64 = r.sim.per_phase.values().map(|s| s.secs).sum();
    let mut t = Table::new(
        "modeled per-phase breakdown",
        &["phase", "ops", "busy time", "energy", "% busy"],
    );
    let mut phases: Vec<(&Phase, _)> = r.sim.per_phase.iter().collect();
    phases.sort_by(|a, b| {
        b.1.secs
            .partial_cmp(&a.1.secs)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    for (phase, stat) in phases {
        t.row(&[
            phase.name().to_string(),
            stat.ops.to_string(),
            fmt_time(stat.secs),
            fmt_energy(stat.joules),
            format!("{:.1}%", 100.0 * stat.secs / phase_total.max(1e-30)),
        ]);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use crate::coordinator::config::SystemConfig;
    use crate::coordinator::executor::Executor;
    use crate::graph::generators::{self, Topology, Weights};

    #[test]
    fn report_contains_key_sections() {
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 64;
        let ex = Executor::new(cfg).unwrap();
        let g = generators::generate(Topology::Nws, 400, 8.0, Weights::Unit, 1);
        let r = ex.run(&g).unwrap();
        let text = super::render(&r);
        assert!(text.contains("RAPID-Graph run"));
        assert!(text.contains("recursion: depth="));
        assert!(text.contains("modeled hardware"));
        assert!(text.contains("validation"));
        assert!(text.contains("local_fw"));
    }
}
