//! Human-readable run reports: the coordinator's metrics output.

use super::executor::{
    AdmissionRunResult, BatchRunResult, DeltaRunResult, RunResult, ServeRunResult, ShardRunResult,
};
use crate::apsp::admission::Verdict;
use crate::apsp::trace::Phase;
use crate::util::bench::percentile;
use crate::util::table::{fmt_count, fmt_energy, fmt_ratio, fmt_time, Table};

/// Render a full report for one run.
pub fn render(r: &RunResult) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "RAPID-Graph run: n={} m={} workload={} mode={} backend={} scheduler={}\n",
        fmt_count(r.graph_n),
        fmt_count(r.graph_m),
        r.workload,
        r.mode.name(),
        r.backend_name,
        r.scheduler.name(),
    ));
    out.push_str(&format!(
        "recursion: depth={} components(L0)={} boundary={:?} final_n={}\n",
        r.depth,
        r.components_l0,
        r.boundary_sizes.iter().map(|&b| fmt_count(b)).collect::<Vec<_>>(),
        r.final_n,
    ));
    out.push_str(&format!(
        "modeled hardware: time={} energy={} (dynamic {}), FW util {:.1}%, MP util {:.1}%, prefetch hid {}\n",
        fmt_time(r.sim.seconds),
        fmt_energy(r.sim.joules),
        fmt_energy(r.sim.dynamic_joules),
        100.0 * r.sim.fw_utilization(),
        100.0 * r.sim.mp_utilization(),
        fmt_time(r.sim.prefetch_hidden),
    ));
    out.push_str(&format!(
        "work: {:.3e} min-adds, {:.3e} madds/s modeled\n",
        r.sim.madds as f64,
        r.sim.madds_per_sec(),
    ));
    if r.host_solve_seconds > 0.0 {
        out.push_str(&format!(
            "host numerics: {}\n",
            fmt_time(r.host_solve_seconds)
        ));
    }
    if let Some(v) = &r.validation {
        out.push_str(&format!(
            "validation: {} samples, max err {:.2e}, {} mismatches -> {}\n",
            v.checked,
            v.max_abs_err,
            v.mismatches,
            if v.ok(r.validate_tolerance) {
                "EXACT"
            } else {
                "FAILED"
            },
        ));
    }
    // per-phase table. Shares are of the summed per-phase busy time:
    // under the barrier scheduler that equals wall time, under the dag
    // scheduler phases overlap, so wall time would make rows exceed
    // 100%.
    let phase_total: f64 = r.sim.per_phase.values().map(|s| s.secs).sum();
    let mut t = Table::new(
        "modeled per-phase breakdown",
        &["phase", "ops", "busy time", "energy", "% busy"],
    );
    let mut phases: Vec<(&Phase, _)> = r.sim.per_phase.iter().collect();
    phases.sort_by(|a, b| {
        b.1.secs
            .partial_cmp(&a.1.secs)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    for (phase, stat) in phases {
        t.row(&[
            phase.name().to_string(),
            stat.ops.to_string(),
            fmt_time(stat.secs),
            fmt_energy(stat.joules),
            format!("{:.1}%", 100.0 * stat.secs / phase_total.max(1e-30)),
        ]);
    }
    out.push_str(&t.render());
    out
}

/// Render the report for one batched workload set: a per-graph table
/// (solo latency vs completion inside the shared schedule) plus the
/// batch-level utilization and speedup summary.
pub fn render_batch(b: &BatchRunResult) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "RAPID-Graph batch: {} graphs, workload={} mode={} backend={}\n",
        b.batch_size(),
        b.per_graph.first().map(|r| r.workload).unwrap_or("?"),
        b.per_graph.first().map(|r| r.mode.name()).unwrap_or("?"),
        b.per_graph.first().map(|r| r.backend_name).unwrap_or("?"),
    ));
    let mut t = Table::new(
        "batch schedule (per graph)",
        &[
            "graph", "n", "m", "depth", "solo time", "batch finish", "busy work", "dyn energy",
            "valid",
        ],
    );
    for (i, (r, s)) in b.per_graph.iter().zip(&b.batch_stats).enumerate() {
        t.row(&[
            i.to_string(),
            fmt_count(r.graph_n),
            fmt_count(r.graph_m),
            r.depth.to_string(),
            fmt_time(r.sim.seconds),
            fmt_time(s.makespan),
            fmt_time(s.busy),
            fmt_energy(s.dynamic_joules),
            match &r.validation {
                Some(v) if v.ok(r.validate_tolerance) => "EXACT".to_string(),
                Some(_) => "FAILED".to_string(),
                None => "-".to_string(),
            },
        ]);
    }
    out.push_str(&t.render());
    out.push_str(&format!(
        "batch: makespan={} vs serial {} -> speedup {}; FW util {:.1}%, MP util {:.1}%, energy={}\n",
        fmt_time(b.batch_sim.seconds),
        fmt_time(b.solo_makespan_sum()),
        fmt_ratio(b.batch_speedup()),
        100.0 * b.batch_sim.fw_utilization(),
        100.0 * b.batch_sim.mp_utilization(),
        fmt_energy(b.batch_sim.joules),
    ));
    if b.host_solve_seconds > 0.0 {
        out.push_str(&format!(
            "host numerics (merged): {}\n",
            fmt_time(b.host_solve_seconds)
        ));
    }
    out
}

/// Render the report for one admission run: a per-submission table
/// (arrival, verdict, completion, admit-to-complete latency vs the
/// drain-and-rebatch baseline), the latency percentiles, and the
/// utilization/speedup summary against the drain baseline.
pub fn render_admission(a: &AdmissionRunResult) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "RAPID-Graph admission pipeline: {} submissions ({} admitted, {} rejected), \
         queue depth {}\n",
        a.n_submissions(),
        a.n_admitted(),
        a.n_rejected(),
        a.queue_depth,
    ));
    let mut t = Table::new(
        "admission schedule (per submission)",
        &[
            "graph", "arrival", "n", "verdict", "store", "solo", "finish", "latency",
            "drain lat", "valid",
        ],
    );
    for (i, r) in a.per_graph.iter().enumerate() {
        // store column: `-` (store off), HIT, miss (stored), miss*
        // (solved but not cached — disabled or rejected by the store)
        let store = r.store.as_ref().map(|o| o.name()).unwrap_or("-");
        match (&r.solo, &r.stat) {
            (Some(solo), Some(stat)) => t.row(&[
                i.to_string(),
                fmt_time(r.arrival),
                fmt_count(solo.graph_n),
                "admitted".to_string(),
                store.to_string(),
                fmt_time(solo.sim.seconds),
                fmt_time(stat.makespan),
                fmt_time(r.latency),
                fmt_time(r.drain_latency),
                match &solo.validation {
                    Some(v) if v.ok(solo.validate_tolerance) => "EXACT".to_string(),
                    Some(_) => "FAILED".to_string(),
                    None => "-".to_string(),
                },
            ]),
            _ => {
                let reason = match r.verdict {
                    Verdict::Rejected(why) => why.name(),
                    Verdict::Admitted { .. } => "admitted",
                };
                t.row(&[
                    i.to_string(),
                    fmt_time(r.arrival),
                    "-".to_string(),
                    format!("REJECTED: {reason}"),
                    store.to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                ]);
            }
        }
    }
    out.push_str(&t.render());
    let lats = a.latencies();
    if !lats.is_empty() {
        out.push_str(&format!(
            "latency (admit -> complete): p50 {} p90 {} max {}\n",
            fmt_time(percentile(&lats, 0.5)),
            fmt_time(percentile(&lats, 0.9)),
            fmt_time(percentile(&lats, 1.0)),
        ));
    }
    out.push_str(&format!(
        "admission: makespan={} vs drain-and-rebatch {} -> speedup {}; \
         FW util {:.1}%, MP util {:.1}%, energy={}\n",
        fmt_time(a.admission_sim.seconds),
        fmt_time(a.drain_makespan),
        fmt_ratio(a.admission_speedup()),
        100.0 * a.admission_sim.fw_utilization(),
        100.0 * a.admission_sim.mp_utilization(),
        fmt_energy(a.admission_sim.joules),
    ));
    if let (Some(ms), Some(cs)) = (a.no_store_makespan, a.cache_speedup()) {
        out.push_str(&format!(
            "result store: {} hit(s) / {} admitted; makespan vs no-store {} -> cache_speedup {}\n",
            a.n_store_hits(),
            a.n_admitted(),
            fmt_time(ms),
            fmt_ratio(cs),
        ));
    }
    if a.host_solve_seconds > 0.0 {
        out.push_str(&format!(
            "host numerics (admission): {}\n",
            fmt_time(a.host_solve_seconds)
        ));
    }
    out
}

/// Render the report for one sharded run: a per-stack table (placed
/// components, busy work, energy, finish time) plus the scale-out
/// summary against the 1-stack solo baseline.
pub fn render_sharded(r: &ShardRunResult) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "RAPID-Graph sharded run: n={} m={} stacks={} workload={} mode={} backend={}\n",
        fmt_count(r.solo.graph_n),
        fmt_count(r.solo.graph_m),
        r.num_stacks,
        r.solo.workload,
        r.solo.mode.name(),
        r.solo.backend_name,
    ));
    out.push_str(&format!(
        "recursion: depth={} components(L0)={} boundary={:?} final_n={}\n",
        r.solo.depth,
        r.solo.components_l0,
        r.solo
            .boundary_sizes
            .iter()
            .map(|&b| fmt_count(b))
            .collect::<Vec<_>>(),
        r.solo.final_n,
    ));
    let mut t = Table::new(
        "sharded schedule (per stack)",
        &["stack", "components", "busy work", "dyn energy", "finish"],
    );
    for (s, (stat, &comps)) in r.stack_stats.iter().zip(&r.comps_per_stack).enumerate() {
        t.row(&[
            s.to_string(),
            comps.to_string(),
            fmt_time(stat.busy),
            fmt_energy(stat.dynamic_joules),
            fmt_time(stat.makespan),
        ]);
    }
    out.push_str(&t.render());
    out.push_str(&format!(
        "sharded: makespan={} vs 1-stack solo {} -> shard_speedup {}; \
         FW util {:.1}%/stack, interconnect busy {} ({} transfers, {} B), energy={}\n",
        fmt_time(r.shard_sim.seconds),
        fmt_time(r.solo.sim.seconds),
        fmt_ratio(r.shard_speedup()),
        100.0 * r.shard_sim.fw_utilization(),
        fmt_time(r.shard_sim.interconnect_busy),
        r.n_xfers,
        fmt_count(r.xfer_bytes as usize),
        fmt_energy(r.shard_sim.joules),
    ));
    if let Some(v) = &r.solo.validation {
        out.push_str(&format!(
            "validation (sharded host run): {} samples, max err {:.2e}, {} mismatches -> {}\n",
            v.checked,
            v.max_abs_err,
            v.mismatches,
            if v.ok(r.solo.validate_tolerance) {
                "EXACT"
            } else {
                "FAILED"
            },
        ));
    }
    if r.host_solve_seconds > 0.0 {
        out.push_str(&format!(
            "host numerics (sharded): {}\n",
            fmt_time(r.host_solve_seconds)
        ));
    }
    out
}

/// Render the report for one delta replay: the base solve summary, a
/// per-batch table (class, repair path, dirty-tile closure, repair
/// latency vs the full re-solve baseline), and the aggregate
/// `delta_speedup` line the CI smoke greps for.
pub fn render_delta(d: &DeltaRunResult) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "RAPID-Graph delta replay: base n={} m={} mode={} backend={}, {} batch(es) / {} delta(s)\n",
        fmt_count(d.initial.graph_n),
        fmt_count(d.initial.graph_m),
        d.initial.mode.name(),
        d.initial.backend_name,
        d.n_batches(),
        d.n_deltas(),
    ));
    out.push_str(&format!(
        "base solve: modeled {} ({} tiles at L0, depth {})\n",
        fmt_time(d.initial.sim.seconds),
        d.initial.components_l0,
        d.initial.depth,
    ));
    if let Some(v) = &d.initial.validation {
        out.push_str(&format!(
            "base validation: {} samples, max err {:.2e} -> {}\n",
            v.checked,
            v.max_abs_err,
            if v.ok(d.initial.validate_tolerance) {
                "EXACT"
            } else {
                "FAILED"
            },
        ));
    }
    let mut t = Table::new(
        "delta repairs (per batch)",
        &[
            "batch", "deltas", "class", "path", "dirty", "skipped", "repair", "re-solve",
            "speedup", "bit-valid",
        ],
    );
    for (i, b) in d.batches.iter().enumerate() {
        t.row(&[
            i.to_string(),
            b.n_deltas.to_string(),
            b.class.to_string(),
            b.path.to_string(),
            format!("{}/{}", b.dirty_tiles, b.total_tiles),
            b.skipped_tiles.to_string(),
            fmt_time(b.repair_sim.seconds),
            fmt_time(b.resolve_sim.seconds),
            fmt_ratio(b.delta_speedup()),
            match b.max_diff {
                Some(dmax) if dmax == 0.0 => "EXACT".to_string(),
                Some(dmax) => format!("FAILED ({dmax:.2e})"),
                None => "-".to_string(),
            },
        ]);
    }
    out.push_str(&t.render());
    let speedups: Vec<f64> = d.batches.iter().map(|b| b.delta_speedup()).collect();
    if !speedups.is_empty() {
        out.push_str(&format!(
            "delta_speedup (re-solve / repair): p50 {} max {}\n",
            fmt_ratio(percentile(&speedups, 0.5)),
            fmt_ratio(percentile(&speedups, 1.0)),
        ));
    }
    if d.store_enabled {
        let inv = d.batches.iter().filter(|b| b.store_invalidated).count();
        let wrote = d.batches.iter().filter(|b| b.store_written).count();
        out.push_str(&format!(
            "result store: {inv} stale entr(ies) invalidated, {wrote} repaired result(s) \
             written back, {} live at exit\n",
            d.store_len,
        ));
    }
    let host: f64 = d.batches.iter().map(|b| b.host_repair_seconds).sum();
    if host > 0.0 {
        out.push_str(&format!(
            "host numerics: base {} + repairs {}\n",
            fmt_time(d.initial.host_solve_seconds),
            fmt_time(host),
        ));
    }
    out
}

/// Render the report for one serve run: the published snapshot's
/// shape, the throughput/latency summary (the CI smoke greps the
/// literal `QPS` and `serve_qps` names), a per-tenant SLO table, the
/// concurrent-swap evidence, and a sample reconstructed path.
pub fn render_serve(s: &ServeRunResult) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "RAPID-Graph serve loop: n={} m={}, {} query batch(es) / {} measured quer(ies), \
         {} epoch(s)\n",
        fmt_count(s.graph_n),
        fmt_count(s.graph_m),
        s.query_batches,
        fmt_count(s.total_queries),
        s.epochs,
    ));
    let hop_desc = if s.next_hop_bits > 0 {
        format!("{}-bit next-hop map", s.next_hop_bits)
    } else {
        "no next-hop map (non-(min,+) workload)".to_string()
    };
    out.push_str(&format!(
        "snapshot: workload={} dist + {}, {} B resident; initial solve {}\n",
        s.workload,
        hop_desc,
        fmt_count(s.snapshot_bytes),
        fmt_time(s.host_solve_seconds),
    ));
    out.push_str(&format!(
        "throughput: serve_qps={:.3e} QPS ({} per query); latency p50 {} p90 {} p99 {}\n",
        s.qps(),
        fmt_time(s.per_query_seconds()),
        fmt_time(s.latency_percentile(0.50)),
        fmt_time(s.latency_percentile(0.90)),
        fmt_time(s.latency_percentile(0.99)),
    ));
    let mut t = Table::new(
        "serve latency (per tenant)",
        &["tenant", "queries", "p50", "p99", "SLO attained"],
    );
    for ten in &s.tenants {
        t.row(&[
            ten.name.clone(),
            ten.queries.to_string(),
            fmt_time(ten.p50),
            fmt_time(ten.p99),
            format!("{:.1}%", 100.0 * ten.slo_attained),
        ]);
    }
    out.push_str(&t.render());
    if s.epochs > 1 {
        out.push_str(&format!(
            "concurrent repair: {} swap(s), {} reader loads landed mid-swap, \
             snapshot_swap_stalls={}, torn_reads={} -> {}\n",
            s.epochs - 1,
            fmt_count(s.reader_loads as usize),
            s.swap_stalls,
            s.torn_reads,
            if s.torn_reads == 0 { "EXACT" } else { "FAILED" },
        ));
    }
    if let Some(speedup) = s.path_speedup_vs_dijkstra() {
        out.push_str(&format!(
            "paths: {} reconstructed + edge-walked -> {}; batched vs per-query Dijkstra \
             ({} per query) -> path_speedup {}\n",
            s.paths_checked,
            if s.paths_checked > 0 { "EXACT" } else { "-" },
            fmt_time(s.dijkstra_seconds_per_query.unwrap_or(0.0)),
            fmt_ratio(speedup),
        ));
    }
    if let Some((u, v, hops, weight)) = &s.sample_path {
        let shown: Vec<String> = hops.iter().take(12).map(|h| h.to_string()).collect();
        let ellipsis = if hops.len() > 12 { " -> ..." } else { "" };
        out.push_str(&format!(
            "sample path {u} -> {v} ({} hops, weight {weight:.4}): {}{}\n",
            hops.len() - 1,
            shown.join(" -> "),
            ellipsis,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use crate::coordinator::config::SystemConfig;
    use crate::coordinator::executor::Executor;
    use crate::graph::generators::{self, Topology, Weights};

    #[test]
    fn report_contains_key_sections() {
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 64;
        let ex = Executor::new(cfg).unwrap();
        let g = generators::generate(Topology::Nws, 400, 8.0, Weights::Unit, 1);
        let r = ex.run(&g).unwrap();
        let text = super::render(&r);
        assert!(text.contains("RAPID-Graph run"));
        assert!(text.contains("recursion: depth="));
        assert!(text.contains("modeled hardware"));
        assert!(text.contains("validation"));
        assert!(text.contains("local_fw"));
    }

    #[test]
    fn batch_report_contains_key_sections() {
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 64;
        let ex = Executor::new(cfg).unwrap();
        let graphs = vec![
            generators::generate(Topology::Nws, 300, 8.0, Weights::Unit, 1),
            generators::generate(Topology::Er, 250, 8.0, Weights::Unit, 2),
        ];
        let b = ex.run_batch(&graphs).unwrap();
        let text = super::render_batch(&b);
        assert!(text.contains("RAPID-Graph batch: 2 graphs"));
        assert!(text.contains("batch schedule"));
        assert!(text.contains("speedup"));
        assert!(text.contains("EXACT"));
    }

    #[test]
    fn admission_report_contains_key_sections() {
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 64;
        cfg.admission_queue_depth = 2;
        cfg.admission_interval = 1e-4;
        // reject the middle graph: it alone exceeds the guard
        cfg.memory_limit_bytes = 1 << 20;
        let ex = Executor::new(cfg).unwrap();
        let graphs = vec![
            generators::generate(Topology::Nws, 200, 8.0, Weights::Unit, 1),
            generators::generate(Topology::OgbnProxy, 6_000, 10.0, Weights::Unit, 2),
            generators::generate(Topology::Er, 180, 8.0, Weights::Unit, 3),
        ];
        let a = ex.run_admission(&graphs).unwrap();
        assert_eq!(a.n_rejected(), 1);
        let text = super::render_admission(&a);
        assert!(text.contains("RAPID-Graph admission pipeline"));
        assert!(text.contains("admission schedule"));
        assert!(text.contains("admitted"));
        assert!(text.contains("REJECTED"));
        assert!(text.contains("latency (admit -> complete)"));
        assert!(text.contains("drain-and-rebatch"));
        assert!(text.contains("speedup"));
        assert!(text.contains("EXACT"));
    }

    #[test]
    fn admission_report_shows_store_verdicts() {
        use crate::coordinator::config::Mode;
        let mut cfg = SystemConfig::default();
        cfg.mode = Mode::Estimate;
        cfg.tile_limit = 64;
        cfg.admission_interval = 1e-4;
        cfg.store_enabled = true;
        let ex = Executor::new(cfg).unwrap();
        let g = generators::generate(Topology::Nws, 300, 8.0, Weights::Unit, 7);
        let graphs = vec![g.clone(), g];
        let a = ex.run_admission(&graphs).unwrap();
        assert_eq!(a.n_store_hits(), 1);
        let text = super::render_admission(&a);
        assert!(text.contains("store"), "{text}");
        assert!(text.contains("HIT"), "{text}");
        assert!(text.contains("miss"), "{text}");
        assert!(text.contains("cache_speedup"), "{text}");
        assert!(text.contains("result store: 1 hit(s) / 2 admitted"), "{text}");
    }

    #[test]
    fn delta_report_contains_key_sections() {
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 64;
        cfg.store_enabled = true;
        let ex = Executor::new(cfg).unwrap();
        let g = generators::generate(Topology::Nws, 500, 8.0, Weights::Uniform(1.0, 4.0), 9);
        let (u, v, w) = g.edges().next().unwrap();
        let script = format!("reweight {u} {v} {}\n\ndelete {u} {v}\n", w * 0.5);
        let d = ex.run_delta(&g, &script).unwrap();
        let text = super::render_delta(&d);
        assert!(text.contains("RAPID-Graph delta replay"), "{text}");
        assert!(text.contains("delta repairs (per batch)"), "{text}");
        assert!(text.contains("improve"), "{text}");
        assert!(text.contains("resolve"), "{text}");
        // the CI smoke greps this literal metric name
        assert!(text.contains("delta_speedup"), "{text}");
        assert!(text.contains("EXACT"), "{text}");
        assert!(text.contains("result store"), "{text}");
        assert!(!text.contains("FAILED"), "{text}");
    }

    #[test]
    fn serve_report_contains_key_sections() {
        let mut cfg = SystemConfig::default();
        cfg.serve_readers = 2;
        let ex = Executor::new(cfg).unwrap();
        let g = generators::generate(Topology::Nws, 300, 8.0, Weights::Uniform(1.0, 4.0), 11);
        let (u, v, w) = g.edges().next().unwrap();
        let queries = "dist 0 9\npath 2 200 @gold\nknear 4 3\n\nreach 7\npath 8 150\n";
        let deltas = format!("reweight {u} {v} {}\n", w * 0.5);
        let s = ex.run_serve(&g, queries, Some(&deltas)).unwrap();
        let text = super::render_serve(&s);
        assert!(text.contains("RAPID-Graph serve loop"), "{text}");
        // the CI smoke greps these literal metric names
        assert!(text.contains("QPS"), "{text}");
        assert!(text.contains("serve_qps"), "{text}");
        assert!(text.contains("snapshot_swap_stalls"), "{text}");
        assert!(text.contains("torn_reads=0"), "{text}");
        assert!(text.contains("path_speedup"), "{text}");
        assert!(text.contains("serve latency (per tenant)"), "{text}");
        assert!(text.contains("gold"), "{text}");
        assert!(text.contains("sample path"), "{text}");
        assert!(text.contains(" -> "), "{text}");
        assert!(text.contains("EXACT"), "{text}");
        assert!(!text.contains("FAILED"), "{text}");
    }

    #[test]
    fn reports_name_the_workload() {
        use crate::coordinator::config::Workload;
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 64;
        cfg.workload = Workload::Reach;
        let ex = Executor::new(cfg).unwrap();
        let g = generators::generate(Topology::Nws, 300, 8.0, Weights::Unit, 5);
        let r = ex.run(&g).unwrap();
        let text = super::render(&r);
        assert!(text.contains("workload=reach"), "{text}");
        assert!(text.contains("EXACT"), "{text}");
        // a widest serve run reports the map-less snapshot
        let mut cfg = SystemConfig::default();
        cfg.workload = Workload::Widest;
        let ex = Executor::new(cfg).unwrap();
        let g = generators::generate(Topology::Nws, 200, 8.0, Weights::Uniform(1.0, 4.0), 6);
        let s = ex
            .run_serve(&g, "dist 0 5\nknear 1 3\nreach 2\n", None)
            .unwrap();
        let text = super::render_serve(&s);
        assert!(text.contains("workload=widest"), "{text}");
        assert!(text.contains("no next-hop map"), "{text}");
        assert!(text.contains("serve_qps"), "{text}");
    }

    #[test]
    fn sharded_report_contains_key_sections() {
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 64;
        cfg.num_stacks = 2;
        let ex = Executor::new(cfg).unwrap();
        let g = generators::generate(Topology::OgbnProxy, 500, 10.0, Weights::Unit, 3);
        let r = ex.run_sharded(&g).unwrap();
        let text = super::render_sharded(&r);
        assert!(text.contains("RAPID-Graph sharded run"));
        assert!(text.contains("stacks=2"));
        assert!(text.contains("sharded schedule"));
        assert!(text.contains("shard_speedup"));
        assert!(text.contains("interconnect busy"));
        assert!(text.contains("EXACT"));
    }
}
