//! The Layer-3 coordinator: configuration, end-to-end orchestration
//! (partition → recursive APSP → simulation → validation), and
//! reporting. This is the paper's "logic base die serves as the central
//! controller" role, mapped onto the host process.

pub mod config;
pub mod executor;
pub mod report;

pub use config::{BackendKind, Mode, SchedulerKind, SystemConfig};
pub use executor::{Executor, RunResult};
