//! End-to-end orchestration: partition → recursive APSP → PIM
//! simulation → validation. One `Executor::run` call is one experiment.

use super::config::{BackendKind, Mode, SchedulerKind, SystemConfig};
use crate::apsp::backend::{NativeBackend, TileBackend};
use crate::apsp::plan::{build_plan, ApspPlan};
use crate::apsp::recursive::{self, solve, ApspSolution, SolveOptions};
use crate::apsp::validate::{validate_sampled, Validation};
use crate::apsp::{scheduler, taskgraph};
use crate::graph::csr::CsrGraph;
use crate::runtime::{PjrtBackend, PjrtRuntime};
use crate::sim::engine::{simulate, simulate_dag, SimReport};
use crate::util::error::Result;

/// Everything one run produces.
pub struct RunResult {
    /// Modeled hardware time/energy.
    pub sim: SimReport,
    /// Recursion structure statistics.
    pub depth: usize,
    pub boundary_sizes: Vec<usize>,
    pub final_n: usize,
    pub components_l0: usize,
    /// Host wall time spent computing numerics (functional mode).
    pub host_solve_seconds: f64,
    /// Sampled exactness validation (functional mode with validation on).
    pub validation: Option<Validation>,
    /// Which backend executed the numerics.
    pub backend_name: &'static str,
    /// Which scheduler ordered the tile work.
    pub scheduler: SchedulerKind,
    pub mode: Mode,
    pub graph_n: usize,
    pub graph_m: usize,
}

impl RunResult {
    /// Total modeled speedup measure used by the figures: modeled
    /// seconds on RAPID-Graph hardware.
    pub fn rapid_seconds(&self) -> f64 {
        self.sim.seconds
    }
    pub fn rapid_joules(&self) -> f64 {
        self.sim.joules
    }
}

/// The coordinator entry point.
pub struct Executor {
    pub config: SystemConfig,
    pjrt: Option<PjrtRuntime>,
}

impl Executor {
    pub fn new(config: SystemConfig) -> Result<Self> {
        let pjrt = match (config.mode, config.backend) {
            (Mode::Functional, BackendKind::Pjrt) => Some(PjrtRuntime::load_default()?),
            _ => None,
        };
        Ok(Self { config, pjrt })
    }

    /// Build the recursion plan for a graph (exposed for benches).
    pub fn plan(&self, g: &CsrGraph) -> ApspPlan {
        build_plan(g, self.config.plan_options())
    }

    /// Run the full pipeline on a graph.
    pub fn run(&self, g: &CsrGraph) -> Result<RunResult> {
        let plan = self.plan(g);
        self.run_with_plan(g, &plan)
    }

    /// Run with a pre-built plan (benches reuse plans across configs).
    pub fn run_with_plan(&self, g: &CsrGraph, plan: &ApspPlan) -> Result<RunResult> {
        let solve_opts = SolveOptions {
            memory_limit_bytes: self.config.memory_limit_bytes,
        };
        let native = NativeBackend;
        let pjrt_adapter = self.pjrt.as_ref().map(PjrtBackend::new);
        let backend: Option<&dyn TileBackend> = match (self.config.mode, self.config.backend) {
            (Mode::Estimate, _) => None,
            (Mode::Functional, BackendKind::Native) => Some(&native),
            (Mode::Functional, BackendKind::Pjrt) => Some(
                pjrt_adapter
                    .as_ref()
                    .expect("pjrt runtime not loaded (Executor::new loads it)"),
            ),
        };

        // in dag mode one lowering of the plan feeds the executor, the
        // solution's trace, and the simulator; barrier mode lowers once
        // inside `solve`
        let tg = (self.config.scheduler == SchedulerKind::Dag)
            .then(|| taskgraph::lower(plan));

        let t0 = std::time::Instant::now();
        let sol: ApspSolution = match (backend, &tg) {
            (Some(be), Some(tg)) => scheduler::execute(g, plan, tg, be, solve_opts),
            (None, Some(tg)) => recursive::estimate_solution(g, plan, tg.to_trace()),
            (be, None) => solve(g, plan, be, solve_opts),
        };
        let host_solve_seconds = t0.elapsed().as_secs_f64();

        let sim = match &tg {
            Some(tg) => simulate_dag(tg, &self.config.hw),
            None => simulate(&sol.trace, &self.config.hw),
        };

        let validation = match (self.config.mode, self.config.validate_sources) {
            (Mode::Functional, s) if s > 0 => Some(validate_sampled(
                g,
                &sol,
                s,
                self.config.validate_cols,
                1e-3,
                self.config.seed ^ 0xFEED,
            )),
            _ => None,
        };

        Ok(RunResult {
            sim,
            depth: plan.depth(),
            boundary_sizes: plan.boundary_sizes(),
            final_n: plan.final_n,
            components_l0: plan
                .levels
                .first()
                .map(|l| l.cs.components.len())
                .unwrap_or(1),
            host_solve_seconds,
            validation,
            backend_name: match (self.config.mode, self.config.backend) {
                (Mode::Estimate, _) => "estimate",
                (_, BackendKind::Native) => "native",
                (_, BackendKind::Pjrt) => "pjrt",
            },
            scheduler: self.config.scheduler,
            mode: self.config.mode,
            graph_n: g.n(),
            graph_m: g.m(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, Topology, Weights};

    fn graph(n: usize, seed: u64) -> CsrGraph {
        generators::generate(Topology::Nws, n, 10.0, Weights::Uniform(1.0, 4.0), seed)
    }

    #[test]
    fn functional_run_validates() {
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 128;
        let ex = Executor::new(cfg).unwrap();
        let g = graph(800, 1);
        let r = ex.run(&g).unwrap();
        assert_eq!(r.mode, Mode::Functional);
        let v = r.validation.expect("validation requested");
        assert!(v.ok(1e-3), "{v:?}");
        assert!(r.sim.seconds > 0.0);
        assert!(r.host_solve_seconds > 0.0);
        assert!(r.depth >= 1);
    }

    #[test]
    fn estimate_run_matches_functional_sim() {
        let g = graph(1200, 2);
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 128;
        let func = Executor::new(cfg.clone()).unwrap().run(&g).unwrap();
        cfg.mode = Mode::Estimate;
        let est = Executor::new(cfg).unwrap().run(&g).unwrap();
        // identical traces => identical modeled time/energy
        assert!((func.sim.seconds - est.sim.seconds).abs() < 1e-12);
        assert!((func.sim.joules - est.sim.joules).abs() < 1e-12);
        assert!(est.validation.is_none());
    }

    #[test]
    fn estimate_scales_past_functional_memory() {
        // 50k vertices would need GBs of matrices in functional mode;
        // estimate mode must handle it quickly
        let g = generators::generate(
            Topology::OgbnProxy,
            50_000,
            16.0,
            Weights::Unit,
            3,
        );
        let mut cfg = SystemConfig::default();
        cfg.mode = Mode::Estimate;
        let t0 = std::time::Instant::now();
        let r = Executor::new(cfg).unwrap().run(&g).unwrap();
        assert!(r.sim.seconds > 0.0);
        assert!(
            t0.elapsed().as_secs_f64() < 60.0,
            "estimate mode too slow: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn dag_scheduler_matches_barrier_functionally_and_is_no_slower() {
        let g = graph(1_000, 7);
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 128;
        let dag = Executor::new(cfg.clone()).unwrap().run(&g).unwrap();
        cfg.scheduler = crate::coordinator::config::SchedulerKind::Barrier;
        let barrier = Executor::new(cfg).unwrap().run(&g).unwrap();
        // both validate exactly
        assert!(dag.validation.as_ref().unwrap().ok(1e-3));
        assert!(barrier.validation.as_ref().unwrap().ok(1e-3));
        // overlap can only help the modeled makespan
        assert!(
            dag.sim.seconds <= barrier.sim.seconds * (1.0 + 1e-9),
            "dag {} > barrier {}",
            dag.sim.seconds,
            barrier.sim.seconds
        );
        // identical dynamic work
        assert!((dag.sim.dynamic_joules - barrier.sim.dynamic_joules).abs() < 1e-9);
        assert_eq!(dag.scheduler.name(), "dag");
        assert_eq!(barrier.scheduler.name(), "barrier");
    }

    #[test]
    fn algorithm1_vs_algorithm2_sim() {
        // recursion (Alg 2) must beat single-level (Alg 1) when the
        // boundary graph exceeds one tile — the paper's §III-A argument
        let g = graph(3000, 4);
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 128;
        cfg.mode = Mode::Estimate;
        let alg2 = Executor::new(cfg.clone()).unwrap().run(&g).unwrap();
        cfg.max_depth = 1;
        let alg1 = Executor::new(cfg).unwrap().run(&g).unwrap();
        assert!(alg2.depth >= 1 && alg1.depth == 1);
        // Alg 1's terminal FW is a giant dense solve
        assert!(alg1.final_n >= alg2.final_n);
    }
}
