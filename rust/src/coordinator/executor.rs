//! End-to-end orchestration: partition → recursive APSP → PIM
//! simulation → validation. One `Executor::run` call is one
//! experiment; one `Executor::run_batch` call is one scheduled
//! workload set — N independent graphs merged into a single
//! shared-resource schedule; one `Executor::run_sharded` call is one
//! over-large graph split across `run.num_stacks` modeled PIM stacks;
//! one `Executor::run_admission` call is one arrival-stamped serving
//! workload admitted into a live schedule without draining it; one
//! `Executor::run_serve` call is one query-script drain through the
//! batched serve loop against lock-free published snapshots.

use super::config::{BackendKind, Mode, SchedulerKind, SystemConfig, Workload};
use crate::apsp::admission::{AdmissionConfig, AdmissionGraph, StoreOutcome, Verdict};
use crate::apsp::backend::{DpBackend, TileBackend};
use crate::apsp::batch::BatchGraph;
use crate::apsp::delta::{self, DeltaClass, DeltaState};
use crate::apsp::dijkstra;
use crate::apsp::plan::{build_plan, ApspPlan};
use crate::apsp::query::{self, Query};
use crate::apsp::recursive::{self, solve, ApspSolution, SolveOptions};
use crate::apsp::semiring::SemiringId;
use crate::apsp::serve::{Answer, BatchExec, QuerySnapshot, SnapshotCell};
use crate::apsp::shard::{plan_tiles, ShardGraph};
use crate::apsp::store::{fingerprint, MemoryStore, ResultStore, StoreEntry};
use crate::apsp::taskgraph::{csr_bytes_estimate, TaskGraph};
use crate::apsp::validate::{validate_sampled_sr, Validation};
use crate::apsp::{scheduler, taskgraph};
use crate::graph::csr::CsrGraph;
use crate::graph::dense::DistMatrix;
use crate::runtime::{PjrtBackend, PjrtRuntime};
use crate::sim::engine::{
    simulate, simulate_admission, simulate_batch, simulate_dag, simulate_delta,
    simulate_drain_rebatch, simulate_sharded, GraphSimStat, SimReport,
};
use crate::util::error::Result;
use crate::{ensure, err};
use std::borrow::Cow;
use std::sync::Arc;

/// Measured drains per query batch in the serve loop: enough samples
/// that the latency percentiles see more than one drain per batch
/// shape, small enough that CLI smoke runs stay fast.
const SERVE_REPS: usize = 5;

/// Everything one run produces.
pub struct RunResult {
    /// Modeled hardware time/energy.
    pub sim: SimReport,
    /// Recursion structure statistics.
    pub depth: usize,
    pub boundary_sizes: Vec<usize>,
    pub final_n: usize,
    pub components_l0: usize,
    /// Host wall time spent computing numerics (functional mode).
    pub host_solve_seconds: f64,
    /// Sampled exactness validation (functional mode with validation on).
    pub validation: Option<Validation>,
    /// Tolerance the validation was / should be judged at
    /// (`SystemConfig::validate_tolerance`).
    pub validate_tolerance: f32,
    /// Which backend executed the numerics.
    pub backend_name: &'static str,
    /// Which DP workload (semiring) the numerics solved.
    pub workload: &'static str,
    /// Which scheduler ordered the tile work.
    pub scheduler: SchedulerKind,
    pub mode: Mode,
    pub graph_n: usize,
    pub graph_m: usize,
}

impl RunResult {
    /// Total modeled speedup measure used by the figures: modeled
    /// seconds on RAPID-Graph hardware.
    pub fn rapid_seconds(&self) -> f64 {
        self.sim.seconds
    }
    pub fn rapid_joules(&self) -> f64 {
        self.sim.joules
    }
}

/// The coordinator entry point.
pub struct Executor {
    pub config: SystemConfig,
    pjrt: Option<PjrtRuntime>,
}

impl Executor {
    pub fn new(config: SystemConfig) -> Result<Self> {
        ensure!(
            config.backend != BackendKind::Pjrt || config.workload == Workload::Apsp,
            "the pjrt backend lowers (min,+) tile kernels only; --workload {} needs \
             --backend native",
            config.workload.name()
        );
        let pjrt = match (config.mode, config.backend) {
            (Mode::Functional, BackendKind::Pjrt) => Some(PjrtRuntime::load_default()?),
            _ => None,
        };
        Ok(Self { config, pjrt })
    }

    /// Build the recursion plan for a graph (exposed for benches).
    pub fn plan(&self, g: &CsrGraph) -> ApspPlan {
        build_plan(g, self.config.plan_options())
    }

    /// Semiring the configured workload computes in.
    fn sr(&self) -> SemiringId {
        self.config.workload.semiring()
    }

    /// The native tile backend for the configured workload. `(min, +)`
    /// routes through the same concrete AVX2/scalar microkernels as the
    /// pre-semiring `NativeBackend` (bit-identical, asserted in
    /// `apsp::backend` tests); the other semirings dispatch the generic
    /// kernels.
    fn dp_backend(&self) -> DpBackend {
        DpBackend::native(self.sr())
    }

    /// Workload-specific input transform. The `critical` (max-plus)
    /// workload has no fixed point on a cyclic graph: a directed DAG
    /// input passes through, anything else is restricted to its
    /// low-to-high orientation ([`CsrGraph::dag_oriented`]), and the
    /// Kahn guard double-checks before any solve runs.
    fn workload_graph<'g>(&self, g: &'g CsrGraph) -> Result<Cow<'g, CsrGraph>> {
        if self.config.workload != Workload::Critical {
            return Ok(Cow::Borrowed(g));
        }
        if g.assert_acyclic().is_ok() {
            return Ok(Cow::Borrowed(g));
        }
        let dag = g.dag_oriented();
        dag.assert_acyclic()
            .map_err(|e| err!("--workload critical needs a DAG: {e}"))?;
        Ok(Cow::Owned(dag))
    }

    /// [`Executor::workload_graph`] over a whole submission set:
    /// `Some(transformed)` when the workload rewrites its inputs,
    /// `None` when the originals serve as-is.
    fn workload_graphs(&self, graphs: &[CsrGraph]) -> Result<Option<Vec<CsrGraph>>> {
        if self.config.workload != Workload::Critical {
            return Ok(None);
        }
        graphs
            .iter()
            .map(|g| self.workload_graph(g).map(Cow::into_owned))
            .collect::<Result<_>>()
            .map(Some)
    }

    /// Run the full pipeline on a graph.
    pub fn run(&self, g: &CsrGraph) -> Result<RunResult> {
        let g = self.workload_graph(g)?;
        let plan = self.plan(&g);
        self.run_with_plan(&g, &plan)
    }

    /// Run with a pre-built plan (benches reuse plans across configs).
    pub fn run_with_plan(&self, g: &CsrGraph, plan: &ApspPlan) -> Result<RunResult> {
        let solve_opts = SolveOptions {
            memory_limit_bytes: self.config.memory_limit_bytes,
        };
        let native = self.dp_backend();
        let pjrt_adapter = self.pjrt.as_ref().map(PjrtBackend::new);
        let backend = self.select_backend(&native, &pjrt_adapter)?;

        // in dag mode one lowering of the plan feeds the executor, the
        // solution's trace, and the simulator; barrier mode lowers once
        // inside `solve`
        let tg = (self.config.scheduler == SchedulerKind::Dag)
            .then(|| taskgraph::lower(plan));

        let t0 = std::time::Instant::now();
        let sol: ApspSolution = match (backend, &tg) {
            (Some(be), Some(tg)) => scheduler::execute(g, plan, tg, be, solve_opts),
            (None, Some(tg)) => recursive::estimate_solution(g, plan, tg.to_trace()),
            (be, None) => solve(g, plan, be, solve_opts),
        };
        let host_solve_seconds = t0.elapsed().as_secs_f64();

        let sim = match &tg {
            Some(tg) => simulate_dag(tg, &self.config.hw),
            None => simulate(&sol.trace, &self.config.hw),
        };

        let validation = match (self.config.mode, self.config.validate_sources) {
            (Mode::Functional, s) if s > 0 => Some(validate_sampled_sr(
                g,
                self.sr(),
                &sol,
                s,
                self.config.validate_cols,
                self.config.validate_tolerance,
                self.config.seed ^ 0xFEED,
            )),
            _ => None,
        };

        Ok(self.make_result(g, plan, sim, validation, host_solve_seconds))
    }

    /// Run N independent graphs as **one scheduled workload set**: the
    /// tile-task DAGs are merged into a single [`BatchGraph`], executed
    /// by one work-stealing pool (functional mode), and costed on one
    /// shared resource model. Per-graph numerics are bit-identical to N
    /// sequential [`Executor::run`] calls; the modeled batch interleaves
    /// every graph's tasks on the same FW/MP dies and channels, which is
    /// where the utilization/throughput gain comes from. The merged
    /// execution is inherently dependency-driven (the `scheduler` knob
    /// cannot reorder it), but each graph's solo baseline honors the
    /// knob so it matches what an individual `run` reports.
    pub fn run_batch(&self, graphs: &[CsrGraph]) -> Result<BatchRunResult> {
        ensure!(
            !graphs.is_empty(),
            "run_batch needs at least one graph (an empty batch has no \
             makespan to schedule, so batch_speedup would be 0/0)"
        );
        for (i, g) in graphs.iter().enumerate() {
            ensure!(
                g.n() > 0,
                "run_batch: graph {i} of {} is empty (0 vertices) — it \
                 contributes no schedulable work",
                graphs.len()
            );
        }
        let prepped = self.workload_graphs(graphs)?;
        let graphs: &[CsrGraph] = prepped.as_deref().unwrap_or(graphs);
        let plans: Vec<ApspPlan> = graphs.iter().map(|g| self.plan(g)).collect();
        let plan_refs: Vec<&ApspPlan> = plans.iter().collect();
        let batch = BatchGraph::build(&plan_refs);

        let solve_opts = SolveOptions {
            memory_limit_bytes: self.config.memory_limit_bytes,
        };
        let native = self.dp_backend();
        let pjrt_adapter = self.pjrt.as_ref().map(PjrtBackend::new);
        let backend = self.select_backend(&native, &pjrt_adapter)?;

        let t0 = std::time::Instant::now();
        let sols: Option<Vec<ApspSolution>> = backend.map(|be| {
            let pairs: Vec<(&CsrGraph, &ApspPlan)> = graphs.iter().zip(&plans).collect();
            scheduler::execute_batch(&pairs, &batch, be, solve_opts)
        });
        // estimate mode runs no host numerics — don't report the
        // Instant overhead as solve time
        let host_solve_seconds = if sols.is_some() {
            t0.elapsed().as_secs_f64()
        } else {
            0.0
        };

        let (batch_sim, batch_stats) = simulate_batch(&batch, &self.config.hw);

        let mut per_graph = Vec::with_capacity(graphs.len());
        for (i, (g, plan)) in graphs.iter().zip(&plans).enumerate() {
            // solo baseline on the same hardware model — the latency
            // this graph would see submitted alone, under the
            // configured scheduler (identical to an individual `run`)
            let sim = match self.config.scheduler {
                SchedulerKind::Dag => simulate_dag(&batch.per_graph[i], &self.config.hw),
                SchedulerKind::Barrier => {
                    simulate(&batch.per_graph[i].to_trace(), &self.config.hw)
                }
            };
            let validation = match (&sols, self.config.validate_sources) {
                (Some(sols), s) if s > 0 => Some(validate_sampled_sr(
                    g,
                    self.sr(),
                    &sols[i],
                    s,
                    self.config.validate_cols,
                    self.config.validate_tolerance,
                    self.config.seed ^ 0xFEED ^ (i as u64),
                )),
                _ => None,
            };
            // host time is attributed to the merged run, not per graph
            per_graph.push(self.make_result(g, plan, sim, validation, 0.0));
        }
        Ok(BatchRunResult {
            per_graph,
            batch_stats,
            batch_sim,
            host_solve_seconds,
        })
    }

    /// Shard one over-large graph across `run.num_stacks` modeled PIM
    /// stacks ([`ShardGraph`]): level-0 components are placed whole on
    /// a stack (cut-minimized, work-balanced), the boundary recursion
    /// runs on the hub stack, and every cross-stack edge becomes an
    /// explicit transfer on the modeled interconnect. Host numerics run
    /// with per-stack worker pools and are **bit-identical** to a solo
    /// [`Executor::run`]; the simulator replicates the resource set per
    /// stack and reports the sharded makespan against the 1-stack solo
    /// baseline (`shard_speedup = solo makespan / sharded makespan`).
    pub fn run_sharded(&self, g: &CsrGraph) -> Result<ShardRunResult> {
        let s = self.config.num_stacks;
        ensure!(
            s >= 1,
            "run.num_stacks must be >= 1 (got 0); use --stacks 1 for the solo baseline"
        );
        let prepped = self.workload_graph(g)?;
        let g: &CsrGraph = &prepped;
        let plan = self.plan(g);
        let tiles = plan_tiles(&plan);
        ensure!(
            s <= tiles,
            "run.num_stacks = {s} exceeds the plan's {tiles} tile(s) — every stack \
             needs at least one component; lower --stacks or shrink --tile"
        );
        let shard = ShardGraph::build(&plan, s, self.config.seed);

        let solve_opts = SolveOptions {
            memory_limit_bytes: self.config.memory_limit_bytes,
        };
        let native = self.dp_backend();
        let pjrt_adapter = self.pjrt.as_ref().map(PjrtBackend::new);
        let backend = self.select_backend(&native, &pjrt_adapter)?;

        let t0 = std::time::Instant::now();
        let sol: Option<ApspSolution> =
            backend.map(|be| scheduler::execute_sharded(g, &plan, &shard, be, solve_opts));
        let host_solve_seconds = if sol.is_some() {
            t0.elapsed().as_secs_f64()
        } else {
            0.0
        };

        let (shard_sim, stack_stats) = simulate_sharded(&shard, &self.config.hw);
        // 1-stack solo baseline on the same lowering. Sharded execution
        // is inherently dependency-driven (the `scheduler` knob cannot
        // reorder it), so the baseline is always the DAG schedule too —
        // otherwise `shard_speedup` would fold the barrier-vs-dag
        // scheduler gap into the sharding gain. At S = 1 the sharded
        // graph *is* the solo graph, so the schedule is reused.
        let solo_sim = if s == 1 {
            shard_sim.clone()
        } else {
            simulate_dag(&shard.solo, &self.config.hw)
        };
        let validation = match (&sol, self.config.validate_sources) {
            (Some(sol), n) if n > 0 => Some(validate_sampled_sr(
                g,
                self.sr(),
                sol,
                n,
                self.config.validate_cols,
                self.config.validate_tolerance,
                self.config.seed ^ 0xFEED,
            )),
            _ => None,
        };
        let comps_per_stack = shard.comps_per_stack();
        Ok(ShardRunResult {
            solo: self.make_result(g, &plan, solo_sim, validation, 0.0),
            stack_stats,
            shard_sim,
            num_stacks: s,
            comps_per_stack,
            n_xfers: shard.n_xfers,
            xfer_bytes: shard.xfer_bytes,
            host_solve_seconds,
        })
    }

    /// Submit N graphs to the **async admission pipeline**: arrivals
    /// (modeled seconds, from `run.admission` — never wall-clock) are
    /// run through admission control ([`AdmissionGraph::build`]:
    /// bounded queue, deterministic memory-guard/capacity verdicts),
    /// every admitted graph is spliced into the live schedule without
    /// draining what is already running, and the simulator attributes
    /// each graph's admit-to-complete latency on the shared timeline.
    /// Functional mode executes the admitted workload on a long-lived
    /// worker pool ([`scheduler::execute_admission`]) with per-graph
    /// completion callbacks; results are bit-identical to solo runs.
    /// The drain-and-rebatch baseline
    /// ([`simulate_drain_rebatch`]) quantifies what mid-flight
    /// admission buys over draining the schedule for every arrival.
    pub fn run_admission(&self, graphs: &[CsrGraph]) -> Result<AdmissionRunResult> {
        let arrivals = self.config.admission_schedule(graphs.len());
        ensure!(
            arrivals.len() == graphs.len(),
            "arrival schedule has {} entries for {} graphs",
            arrivals.len(),
            graphs.len()
        );
        ensure!(
            arrivals.windows(2).all(|w| w[0] <= w[1]),
            "arrival schedule must be non-decreasing (submission order is arrival order)"
        );
        ensure!(
            arrivals.iter().all(|a| a.is_finite() && *a >= 0.0),
            "arrival times must be finite and non-negative"
        );
        ensure!(
            self.config.admission_queue_depth >= 1,
            "run.admission.queue_depth must be >= 1 (got 0)"
        );
        let prepped = self.workload_graphs(graphs)?;
        let graphs: &[CsrGraph] = prepped.as_deref().unwrap_or(graphs);
        let plans: Vec<ApspPlan> = graphs.iter().map(|g| self.plan(g)).collect();
        let subs: Vec<(&CsrGraph, &ApspPlan)> = graphs.iter().zip(&plans).collect();
        let adm_cfg = AdmissionConfig {
            queue_depth: self.config.admission_queue_depth,
            memory_limit_bytes: self.config.memory_limit_bytes,
        };
        // the result store never changes admission verdicts (both paths
        // run the same capacity/memory-guard checks), so the with-store
        // and no-store schedules admit the same set and cache_speedup
        // compares apples to apples
        let mut store = MemoryStore::new(self.config.store_capacity, self.config.store_bytes);
        let (adm, outcomes) = if self.config.store_enabled {
            AdmissionGraph::build_with_store(
                &subs,
                &arrivals,
                &adm_cfg,
                &mut store,
                self.config.store_compression,
            )
        } else {
            let adm = AdmissionGraph::build(&subs, &arrivals, &adm_cfg);
            let none = subs.iter().map(|_| None).collect();
            (adm, none)
        };

        let native = self.dp_backend();
        let pjrt_adapter = self.pjrt.as_ref().map(PjrtBackend::new);
        let backend = self.select_backend(&native, &pjrt_adapter)?;

        let completion_log = std::sync::Mutex::new(Vec::new());
        let t0 = std::time::Instant::now();
        let sols: Option<Vec<Option<ApspSolution>>> = backend.map(|be| {
            scheduler::execute_admission_stored(&subs, &adm, &outcomes, be, |si| {
                completion_log.lock().unwrap().push(si);
            })
        });
        let host_solve_seconds = if sols.is_some() {
            t0.elapsed().as_secs_f64()
        } else {
            0.0
        };
        let completion_order = completion_log.into_inner().unwrap();

        let (admission_sim, stats) = simulate_admission(
            &adm.batch,
            &adm.arrivals,
            self.config.admission_queue_depth,
            &self.config.hw,
        );
        let (drain_makespan, drain_completion) =
            simulate_drain_rebatch(&adm.batch.per_graph, &adm.arrivals, &self.config.hw);
        // no-store baseline: the identical workload with the store off
        // (same verdicts by construction), so the report can attribute
        // what the cache bought on the shared timeline
        let no_store_makespan = if self.config.store_enabled {
            let plain = AdmissionGraph::build(&subs, &arrivals, &adm_cfg);
            let (plain_sim, _) = simulate_admission(
                &plain.batch,
                &plain.arrivals,
                self.config.admission_queue_depth,
                &self.config.hw,
            );
            Some(plain_sim.seconds)
        } else {
            None
        };

        let mut per_graph = Vec::with_capacity(graphs.len());
        for (si, &(g, plan)) in subs.iter().enumerate() {
            let verdict = adm.verdicts[si];
            let row = match verdict {
                Verdict::Admitted { admitted_index } => {
                    let gi = admitted_index as usize;
                    // solo baseline under the configured scheduler —
                    // identical to an individual `run`. A store hit's
                    // admitted graph is the one-task FeNAND read, so
                    // its solo baseline is a fresh lowering (the solve
                    // this submission would run alone, store cold); a
                    // stored miss keeps its write-back in the baseline
                    // (persisting is part of that graph's work).
                    let is_hit =
                        matches!(outcomes[si], Some(StoreOutcome::Hit { .. }));
                    let solo_tg;
                    let tg = if is_hit {
                        solo_tg = taskgraph::lower(plan);
                        &solo_tg
                    } else {
                        &adm.batch.per_graph[gi]
                    };
                    let sim = match self.config.scheduler {
                        SchedulerKind::Dag => simulate_dag(tg, &self.config.hw),
                        SchedulerKind::Barrier => {
                            simulate(&tg.to_trace(), &self.config.hw)
                        }
                    };
                    let validation = match (&sols, self.config.validate_sources) {
                        (Some(sols), s) if s > 0 => sols[si].as_ref().map(|sol| {
                            validate_sampled_sr(
                                g,
                                self.sr(),
                                sol,
                                s,
                                self.config.validate_cols,
                                self.config.validate_tolerance,
                                self.config.seed ^ 0xFEED ^ (si as u64),
                            )
                        }),
                        _ => None,
                    };
                    AdmissionGraphResult {
                        verdict,
                        arrival: arrivals[si],
                        solo: Some(self.make_result(g, plan, sim, validation, 0.0)),
                        stat: Some(stats[gi]),
                        latency: stats[gi].makespan - adm.arrivals[gi],
                        drain_latency: drain_completion[gi] - adm.arrivals[gi],
                        store: outcomes[si].clone(),
                    }
                }
                Verdict::Rejected(_) => AdmissionGraphResult {
                    verdict,
                    arrival: arrivals[si],
                    solo: None,
                    stat: None,
                    latency: 0.0,
                    drain_latency: 0.0,
                    store: None,
                },
            };
            per_graph.push(row);
        }
        Ok(AdmissionRunResult {
            per_graph,
            admission_sim,
            drain_makespan,
            no_store_makespan,
            completion_order,
            queue_depth: self.config.admission_queue_depth,
            host_solve_seconds,
        })
    }

    /// Replay a script of edge-delta batches through the **incremental
    /// repair engine**. The base graph is solved once with retained
    /// repair state ([`scheduler::solve_dag_retained`] keeps the
    /// pre-injection blocks a plain solve discards), then each batch
    /// is validated, classified (improve vs resolve), applied, and
    /// repaired by re-solving only its dirty tile closure
    /// ([`scheduler::execute_delta`]) — clean tiles are served from
    /// the retained `Arc`s without copying. A structural change the
    /// plan repair cannot absorb ([`delta::repair_plan`] returns
    /// `None`) falls back to a full replan + re-solve and is reported
    /// as such. Each repaired result is bit-validated against a fresh
    /// full solve (`run.delta.validate`), and the simulator prices the
    /// repair sub-DAG against the full re-solve lowering
    /// (`delta_speedup = resolve makespan / repair makespan`). With
    /// the result store on, each batch invalidates the pre-delta
    /// fingerprint ([`ResultStore::remove`]) and writes back the
    /// repaired graph's entry; entries for other graphs survive.
    pub fn run_delta(&self, g: &CsrGraph, script: &str) -> Result<DeltaRunResult> {
        ensure!(
            self.config.workload == Workload::Apsp,
            "the delta engine repairs (min,+) shortest paths only; --workload {} runs \
             solo, --batch, --stacks, --admit, and --serve modes",
            self.config.workload.name()
        );
        ensure!(
            g.n() > 0,
            "the delta engine needs a solved base graph — the base graph is \
             empty (0 vertices), so there is no solution to repair"
        );
        let batches = delta::parse_script(script)?;

        let solve_opts = SolveOptions {
            memory_limit_bytes: self.config.memory_limit_bytes,
        };
        let native = self.dp_backend();
        let pjrt_adapter = self.pjrt.as_ref().map(PjrtBackend::new);
        let backend = self.select_backend(&native, &pjrt_adapter)?;

        // initial full solve. Delta repair is inherently
        // dependency-driven, so the DAG schedule is used regardless of
        // the `scheduler` knob (as in sharded runs).
        let mut cur_g = g.clone();
        let mut plan = self.plan(&cur_g);
        let tg0 = taskgraph::lower(&plan);
        let t0 = std::time::Instant::now();
        let mut state: Option<DeltaState> = None;
        let mut validation = None;
        if let Some(be) = backend {
            let (trace, st) = scheduler::solve_dag_retained(&cur_g, &plan, be, solve_opts);
            if self.config.validate_sources > 0 {
                let sol = st.as_solution(&plan, &cur_g, trace);
                validation = Some(validate_sampled_sr(
                    &cur_g,
                    self.sr(),
                    &sol,
                    self.config.validate_sources,
                    self.config.validate_cols,
                    self.config.validate_tolerance,
                    self.config.seed ^ 0xFEED,
                ));
            }
            state = Some(st);
        }
        let host_solve_seconds = if state.is_some() {
            t0.elapsed().as_secs_f64()
        } else {
            0.0
        };
        let sim0 = simulate_dag(&tg0, &self.config.hw);
        let initial = self.make_result(&cur_g, &plan, sim0, validation, host_solve_seconds);

        let mut store = self
            .config
            .store_enabled
            .then(|| MemoryStore::new(self.config.store_capacity, self.config.store_bytes));
        if let Some(s) = store.as_mut() {
            // persist the base solve so the first delta has an entry
            // to invalidate
            self.put_store_entry(s, &cur_g, &tg0);
        }

        let mut rows = Vec::with_capacity(batches.len());
        for batch in &batches {
            delta::validate_deltas(&cur_g, batch)?;
            let class = delta::classify_deltas(&cur_g, batch);
            let allow_skip = self.config.delta_skip && class == DeltaClass::Improve;
            let g_new = delta::apply_deltas(&cur_g, batch);
            let old_fp = fingerprint(&cur_g);

            let (path, new_plan) = match delta::repair_plan(&plan, &g_new) {
                Some(p) => ("repair", p),
                // a cross edge appeared between vertices the
                // partitioner never assigned boundary slots — the tile
                // plan itself is stale, so the honest repair is a full
                // replan + re-solve
                None => ("replan", self.plan(&g_new)),
            };
            let total_tiles = new_plan
                .levels
                .first()
                .map(|l| l.cs.components.len())
                .unwrap_or(1);
            let full_tg = taskgraph::lower(&new_plan);

            let (
                new_state,
                repair_sim,
                resolve_sim,
                dirty_tiles,
                skipped_tiles,
                host_repair_seconds,
                max_diff,
            );
            if path == "repair" {
                let spec = delta::dirty_spec(&new_plan, batch);
                match (backend, state.as_ref()) {
                    (Some(be), Some(st)) => {
                        let t1 = std::time::Instant::now();
                        let (ns, actual) = scheduler::execute_delta(
                            &g_new, &new_plan, &spec, st, allow_skip, be, solve_opts,
                        );
                        host_repair_seconds = t1.elapsed().as_secs_f64();
                        dirty_tiles = actual.dirty_tiles().max(1);
                        skipped_tiles = spec.rerun.iter().filter(|r| **r).count()
                            - actual.rerun.iter().filter(|r| **r).count();
                        let repair_tg = taskgraph::lower_repair(&new_plan, &actual);
                        let (rs, fs) = simulate_delta(&repair_tg, &full_tg, &self.config.hw);
                        repair_sim = rs;
                        resolve_sim = fs;
                        max_diff = if self.config.delta_validate {
                            let (_, fresh) =
                                scheduler::solve_dag_retained(&g_new, &new_plan, be, solve_opts);
                            let d = ns.max_diff(&fresh);
                            ensure!(
                                d == 0.0,
                                "delta repair diverged from a fresh full solve \
                                 (max |Δ| = {d:e}); this is a repair-engine bug"
                            );
                            Some(d)
                        } else {
                            None
                        };
                        new_state = Some(ns);
                    }
                    _ => {
                        // estimate mode: no host numerics — price the
                        // conservative (pre-execution) repair closure
                        let repair_tg = taskgraph::lower_repair(&new_plan, &spec);
                        let (rs, fs) = simulate_delta(&repair_tg, &full_tg, &self.config.hw);
                        repair_sim = rs;
                        resolve_sim = fs;
                        dirty_tiles = spec.dirty_tiles().max(1);
                        skipped_tiles = 0;
                        host_repair_seconds = 0.0;
                        max_diff = None;
                        new_state = None;
                    }
                }
            } else {
                dirty_tiles = total_tiles;
                skipped_tiles = 0;
                max_diff = None;
                let t1 = std::time::Instant::now();
                new_state = backend
                    .map(|be| scheduler::solve_dag_retained(&g_new, &new_plan, be, solve_opts).1);
                host_repair_seconds = if new_state.is_some() {
                    t1.elapsed().as_secs_f64()
                } else {
                    0.0
                };
                // the fallback *is* the full solve — repair cost and
                // re-solve baseline coincide (delta_speedup = 1)
                let s = simulate_dag(&full_tg, &self.config.hw);
                repair_sim = s.clone();
                resolve_sim = s;
            }

            let (store_invalidated, store_written) = match store.as_mut() {
                Some(s) => {
                    // the pre-delta entry answers a graph that no
                    // longer exists — drop it before its bytes crowd
                    // out the write-back
                    let inv = s.remove(old_fp);
                    (inv, self.put_store_entry(s, &g_new, &full_tg))
                }
                None => (false, false),
            };

            rows.push(DeltaBatchResult {
                n_deltas: batch.len(),
                class: class.name(),
                path,
                dirty_tiles,
                total_tiles,
                skipped_tiles,
                repair_sim,
                resolve_sim,
                host_repair_seconds,
                max_diff,
                store_invalidated,
                store_written,
                graph_m: g_new.m(),
            });

            cur_g = g_new;
            plan = new_plan;
            state = new_state;
        }

        let store_len = store.as_ref().map(|s| s.len()).unwrap_or(0);
        Ok(DeltaRunResult {
            initial,
            batches: rows,
            store_enabled: self.config.store_enabled,
            store_len,
        })
    }

    /// Drain a query script through the **batched serve loop**. The
    /// base graph is solved once with next-hop threading
    /// ([`query::solve_next_hops`]), published as an immutable
    /// [`QuerySnapshot`] in a lock-free [`SnapshotCell`], and every
    /// query batch is answered source-major by one [`BatchExec`] (a
    /// query's served latency is its batch's drain time). With a delta
    /// script, one delta batch is applied between consecutive query
    /// batches: the mutated graph is re-solved and epoch-swapped into
    /// the cell while `run.serve.readers` threads hammer `load()`,
    /// proving readers never block (loads keep landing) and never see a
    /// torn snapshot (every load re-derives the build-time checksum).
    /// With `run.serve.validate` on, every reconstructed path is walked
    /// edge-by-edge against the current graph, and per-query Dijkstra
    /// is timed on the same sources as the throughput baseline.
    pub fn run_serve(
        &self,
        g: &CsrGraph,
        query_script: &str,
        delta_script: Option<&str>,
    ) -> Result<ServeRunResult> {
        ensure!(
            g.n() > 0,
            "cannot serve queries: the base graph is empty (0 vertices), \
             so there is no solution to query"
        );
        ensure!(
            self.config.mode == Mode::Functional,
            "the serve loop answers real queries, which needs functional \
             numerics; run.mode = estimate has none"
        );
        let apsp = self.config.workload == Workload::Apsp;
        let script = query::parse_query_script(query_script)?;
        query::validate_queries(g.n(), &script)?;
        if !apsp {
            // path reconstruction walks the packed (min,+) next-hop
            // map, which no other shipped semiring defines
            let has_path = script
                .batches
                .iter()
                .any(|b| b.iter().any(|r| matches!(r.query, Query::Path { .. })));
            ensure!(
                !has_path,
                "path queries need the (min,+) next-hop map; --workload {} serves \
                 dist/knear/reach only",
                self.config.workload.name()
            );
        }
        let delta_batches = match delta_script {
            Some(s) => delta::parse_script(s)?,
            None => Vec::new(),
        };
        ensure!(
            delta_batches.is_empty() || apsp,
            "--deltas with --serve re-solves and swaps (min,+) snapshots; \
             --workload {} serves a static snapshot",
            self.config.workload.name()
        );
        let prepped = self.workload_graph(g)?;
        let g: &CsrGraph = &prepped;
        // memory guard: a swap briefly holds two snapshots co-resident
        let n = g.n() as u64;
        let hop_bytes = match (apsp, g.n() <= u16::MAX as usize) {
            (false, _) => 0,
            (true, true) => 2,
            (true, false) => 4,
        };
        let per_snapshot = n * n * (4 + hop_bytes);
        ensure!(
            2 * per_snapshot <= self.config.memory_limit_bytes,
            "serving {} vertices needs ~{} bytes for two co-resident \
             snapshots (dist + next-hop), over the {} byte memory limit",
            n,
            2 * per_snapshot,
            self.config.memory_limit_bytes
        );

        let t0 = std::time::Instant::now();
        let (dist, next) = if apsp {
            let (dist, next) = query::solve_next_hops(g);
            (dist, Some(next))
        } else {
            (self.solve_workload_dist(g), None)
        };
        let host_solve_seconds = t0.elapsed().as_secs_f64();
        let next_hop_bits = next.as_ref().map_or(0, |nh| nh.width_bits());
        let cell = SnapshotCell::new(Arc::new(QuerySnapshot::new_sr(0, self.sr(), dist, next)));
        let snapshot_bytes = cell.load().bytes();

        let mut exec = BatchExec::new(self.config.serve_panel_rows);
        let mut cur_g = g.clone();
        let mut latencies: Vec<f64> = Vec::new();
        let mut tenant_lat: Vec<Vec<f64>> = vec![Vec::new(); script.tenants.len()];
        let mut total_queries = 0usize;
        let mut serve_seconds = 0.0f64;
        let mut paths_checked = 0usize;
        let mut sample_path: Option<(u32, u32, Vec<u32>, f32)> = None;
        let mut dijkstra_sources: Vec<usize> = Vec::new();
        let mut reader_loads = 0u64;
        let mut torn_reads = 0u64;
        let mut epoch = 0u64;
        let mut delta_iter = delta_batches.iter();

        for batch in &script.batches {
            let snap = cell.load();
            let mut answers = Vec::new();
            // a few measured drains per batch so the percentiles see
            // more than one sample per batch shape
            for _ in 0..SERVE_REPS {
                let t = std::time::Instant::now();
                answers = exec.run(&snap, batch);
                let drain = t.elapsed().as_secs_f64();
                serve_seconds += drain;
                total_queries += batch.len();
                for req in batch {
                    latencies.push(drain);
                    tenant_lat[req.tenant as usize].push(drain);
                }
            }
            for (req, ans) in batch.iter().zip(&answers) {
                if let (Query::Path { u, v }, Answer::Path { hops, weight }) = (req.query, ans) {
                    if self.config.serve_validate {
                        self.check_path(&cur_g, &snap, u, v, hops, *weight)?;
                        paths_checked += 1;
                        dijkstra_sources.push(u as usize);
                    }
                    if sample_path.is_none() && !hops.is_empty() {
                        sample_path = Some((u, v, hops.clone(), *weight));
                    }
                }
            }
            drop(snap);
            // interleave the next delta batch: re-solve + epoch-swap
            // while reader threads hammer the cell
            if let Some(db) = delta_iter.next() {
                delta::validate_deltas(&cur_g, db)?;
                let g2 = delta::apply_deltas(&cur_g, db);
                epoch += 1;
                let (loads, torn) = self.swap_under_readers(&cell, &g2, epoch);
                reader_loads += loads;
                torn_reads += torn;
                cur_g = g2;
            }
        }

        // per-query Dijkstra on the same sources the path queries hit:
        // the throughput baseline the packed next-hop map replaces
        let dijkstra_seconds_per_query = if self.config.serve_validate
            && !dijkstra_sources.is_empty()
        {
            dijkstra_sources.truncate(32);
            let t = std::time::Instant::now();
            for &src in &dijkstra_sources {
                std::hint::black_box(dijkstra::sssp(&cur_g, src));
            }
            Some(t.elapsed().as_secs_f64() / dijkstra_sources.len() as f64)
        } else {
            None
        };

        let tenants = script
            .tenants
            .iter()
            .zip(tenant_lat)
            .map(|(name, lat)| {
                let slo = self.config.serve_slo_ms * 1e-3;
                let (attained, p50, p99) = if lat.is_empty() {
                    (1.0, 0.0, 0.0)
                } else {
                    (
                        lat.iter().filter(|&&l| l <= slo).count() as f64 / lat.len() as f64,
                        crate::util::bench::percentile(&lat, 0.50),
                        crate::util::bench::percentile(&lat, 0.99),
                    )
                };
                TenantServeStat {
                    name: name.clone(),
                    queries: lat.len(),
                    p50,
                    p99,
                    slo_attained: attained,
                }
            })
            .collect();

        Ok(ServeRunResult {
            workload: self.config.workload.name(),
            graph_n: g.n(),
            graph_m: g.m(),
            host_solve_seconds,
            epochs: epoch + 1,
            query_batches: script.batches.len(),
            total_queries,
            serve_seconds,
            latencies,
            tenants,
            swap_stalls: cell.stalls(),
            reader_loads,
            torn_reads,
            paths_checked,
            dijkstra_seconds_per_query,
            next_hop_bits,
            snapshot_bytes,
            sample_path,
        })
    }

    /// Walk a reconstructed path edge-by-edge against the live graph:
    /// endpoints must match, every hop must be a real edge, and the
    /// edge-weight sum must agree with the answered weight (which
    /// [`BatchExec`] reads straight from the snapshot's dist row).
    fn check_path(
        &self,
        g: &CsrGraph,
        snap: &QuerySnapshot,
        u: u32,
        v: u32,
        hops: &[u32],
        weight: f32,
    ) -> Result<()> {
        if hops.is_empty() {
            ensure!(
                !snap.dist.get(u as usize, v as usize).is_finite(),
                "path {u} -> {v} answered unreachable but dist is finite"
            );
            return Ok(());
        }
        ensure!(
            hops.first() == Some(&u) && hops.last() == Some(&v),
            "reconstructed path {u} -> {v} has wrong endpoints {:?}",
            (hops.first(), hops.last())
        );
        let mut sum = 0.0f32;
        for pair in hops.windows(2) {
            let w = g
                .edge_weight(pair[0] as usize, pair[1] as usize)
                .ok_or_else(|| {
                    err!(
                        "reconstructed path {u} -> {v} uses a non-edge {} -> {}",
                        pair[0],
                        pair[1]
                    )
                })?;
            sum += w;
        }
        ensure!(
            (sum - weight).abs() <= 1e-3 * weight.abs().max(1.0),
            "reconstructed path {u} -> {v} sums to {sum} but dist says {weight}"
        );
        Ok(())
    }

    /// Re-solve the mutated graph and epoch-swap it into the cell while
    /// `run.serve.readers` threads hammer `load()`. Returns (loads,
    /// torn observations) — loads landing throughout the swap is the
    /// never-blocks evidence, and every load re-derives the snapshot
    /// checksum so a torn read cannot go unnoticed.
    fn swap_under_readers(
        &self,
        cell: &SnapshotCell<QuerySnapshot>,
        g2: &CsrGraph,
        epoch: u64,
    ) -> (u64, u64) {
        use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
        let stop = AtomicBool::new(false);
        let loads = AtomicU64::new(0);
        let torn = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..self.config.serve_readers {
                s.spawn(|| {
                    while !stop.load(Ordering::Relaxed) {
                        let snap = cell.load();
                        if !snap.verify() {
                            torn.fetch_add(1, Ordering::Relaxed);
                        }
                        loads.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
            let (dist, next) = query::solve_next_hops(g2);
            cell.swap(Arc::new(QuerySnapshot::new(epoch, dist, next)));
            stop.store(true, Ordering::Relaxed);
        });
        (loads.into_inner(), torn.into_inner())
    }

    /// Full workload-semiring closure matrix for a static serve
    /// snapshot: the same recursive engine a solo run uses, with the
    /// workload backend, materialized dense.
    fn solve_workload_dist(&self, g: &CsrGraph) -> DistMatrix {
        let be = self.dp_backend();
        let plan = self.plan(g);
        let sol = solve(
            g,
            &plan,
            Some(&be),
            SolveOptions {
                memory_limit_bytes: self.config.memory_limit_bytes,
            },
        );
        sol.materialize_full(&be)
    }

    /// Write a solved graph's entry into the result store under its
    /// fingerprint (same costing as the admission write-back path:
    /// modeled result bytes, the solve's madds as the re-solve cost).
    fn put_store_entry(&self, store: &mut MemoryStore, g: &CsrGraph, tg: &TaskGraph) -> bool {
        let n = g.n() as u64;
        let bytes = if self.config.store_compression {
            csr_bytes_estimate(n * n)
        } else {
            n * n * 4
        };
        let cost = tg.to_trace().total_madds() as f64;
        matches!(
            store.put(fingerprint(g), StoreEntry::new(bytes, cost, None)),
            Ok(true)
        )
    }

    /// Assemble one graph's [`RunResult`] (shared by `run_with_plan`
    /// and `run_batch` so solo and batch rows can't drift).
    fn make_result(
        &self,
        g: &CsrGraph,
        plan: &ApspPlan,
        sim: SimReport,
        validation: Option<Validation>,
        host_solve_seconds: f64,
    ) -> RunResult {
        RunResult {
            sim,
            depth: plan.depth(),
            boundary_sizes: plan.boundary_sizes(),
            final_n: plan.final_n,
            components_l0: plan
                .levels
                .first()
                .map(|l| l.cs.components.len())
                .unwrap_or(1),
            host_solve_seconds,
            validation,
            validate_tolerance: self.config.validate_tolerance,
            backend_name: self.backend_name(),
            workload: self.config.workload.name(),
            scheduler: self.config.scheduler,
            mode: self.config.mode,
            graph_n: g.n(),
            graph_m: g.m(),
        }
    }

    /// Resolve the tile backend for the configured mode. `None` means
    /// estimate mode (no numerics); a configured-but-unloaded pjrt
    /// runtime is a clean error, not a panic.
    fn select_backend<'a>(
        &self,
        native: &'a DpBackend,
        pjrt: &'a Option<PjrtBackend<'_>>,
    ) -> Result<Option<&'a dyn TileBackend>> {
        Ok(match (self.config.mode, self.config.backend) {
            (Mode::Estimate, _) => None,
            (Mode::Functional, BackendKind::Native) => Some(native),
            (Mode::Functional, BackendKind::Pjrt) => match pjrt.as_ref() {
                Some(p) => Some(p),
                None => {
                    return Err(err!(
                        "pjrt backend requested but the runtime is not loaded \
                         (the Executor must be constructed with backend = pjrt)"
                    ))
                }
            },
        })
    }

    fn backend_name(&self) -> &'static str {
        match (self.config.mode, self.config.backend) {
            (Mode::Estimate, _) => "estimate",
            (_, BackendKind::Native) => "native",
            (_, BackendKind::Pjrt) => "pjrt",
        }
    }
}

/// Everything one batched run produces.
pub struct BatchRunResult {
    /// Per-graph results in submission order. Each `sim` is the graph's
    /// **solo** baseline (identical to an individual `run`); the
    /// validation comes from the shared batch execution.
    pub per_graph: Vec<RunResult>,
    /// Per-graph attribution inside the shared schedule (completion
    /// time, busy work, dynamic energy by node ownership).
    pub batch_stats: Vec<GraphSimStat>,
    /// The merged workload on the shared resource model.
    pub batch_sim: SimReport,
    /// Host wall time of the merged functional execution.
    pub host_solve_seconds: f64,
}

impl BatchRunResult {
    pub fn batch_size(&self) -> usize {
        self.per_graph.len()
    }

    /// Σ solo makespans — the serial-submission baseline.
    pub fn solo_makespan_sum(&self) -> f64 {
        self.per_graph.iter().map(|r| r.sim.seconds).sum()
    }

    /// Batch throughput gain: Σ solo makespans / batch makespan.
    pub fn batch_speedup(&self) -> f64 {
        if self.batch_sim.seconds == 0.0 {
            1.0
        } else {
            self.solo_makespan_sum() / self.batch_sim.seconds
        }
    }
}

/// Everything one sharded run produces.
pub struct ShardRunResult {
    /// The 1-stack solo baseline (its `sim` is what a plain
    /// [`Executor::run`] would report; the validation comes from the
    /// sharded host execution).
    pub solo: RunResult,
    /// Per-stack attribution inside the sharded schedule (completion
    /// time, busy work, dynamic energy by node affinity).
    pub stack_stats: Vec<GraphSimStat>,
    /// The sharded workload on `num_stacks` replicated resource sets.
    pub shard_sim: SimReport,
    pub num_stacks: usize,
    /// Level-0 components placed on each stack.
    pub comps_per_stack: Vec<usize>,
    /// Inter-stack transfers inserted on cross-shard edges.
    pub n_xfers: usize,
    /// Total bytes over the inter-stack interconnect.
    pub xfer_bytes: u64,
    /// Host wall time of the sharded functional execution.
    pub host_solve_seconds: f64,
}

impl ShardRunResult {
    /// Scale-out gain: solo (1-stack) makespan / sharded makespan.
    pub fn shard_speedup(&self) -> f64 {
        if self.shard_sim.seconds == 0.0 {
            1.0
        } else {
            self.solo.sim.seconds / self.shard_sim.seconds
        }
    }
}

/// One submission's outcome in an admission run.
pub struct AdmissionGraphResult {
    /// Admission verdict (admitted, or the rejection reason).
    pub verdict: Verdict,
    /// Modeled arrival time from the configured schedule.
    pub arrival: f64,
    /// Solo-baseline result (admitted graphs only; identical to an
    /// individual [`Executor::run`]). The validation inside comes from
    /// the shared admission execution.
    pub solo: Option<RunResult>,
    /// Attribution inside the shared schedule (admitted only);
    /// `stat.makespan` is the completion time on the shared timeline.
    pub stat: Option<GraphSimStat>,
    /// Modeled admit-to-complete latency (0 for rejected graphs).
    pub latency: f64,
    /// Latency the same graph sees under the drain-and-rebatch
    /// baseline (0 for rejected graphs).
    pub drain_latency: f64,
    /// Result-store verdict for this submission (`None` when the store
    /// is off or the submission was rejected).
    pub store: Option<StoreOutcome>,
}

/// Everything one admission run produces.
pub struct AdmissionRunResult {
    /// Per-submission outcomes, in arrival order.
    pub per_graph: Vec<AdmissionGraphResult>,
    /// The admitted workload on the shared resource model, every
    /// graph's units released at its modeled arrival time.
    pub admission_sim: SimReport,
    /// Drain-and-rebatch baseline makespan for the same admitted
    /// workload and arrival schedule.
    pub drain_makespan: f64,
    /// Makespan of the identical workload with the result store
    /// disabled (same admitted set); `None` when the store was off.
    pub no_store_makespan: Option<f64>,
    /// Order in which graphs completed in the functional host run
    /// (submission indices; empty in estimate mode).
    pub completion_order: Vec<usize>,
    /// The in-flight bound the pipeline enforced.
    pub queue_depth: usize,
    /// Host wall time of the merged functional execution.
    pub host_solve_seconds: f64,
}

impl AdmissionRunResult {
    pub fn n_submissions(&self) -> usize {
        self.per_graph.len()
    }

    pub fn n_admitted(&self) -> usize {
        self.per_graph.iter().filter(|r| r.verdict.admitted()).count()
    }

    pub fn n_rejected(&self) -> usize {
        self.n_submissions() - self.n_admitted()
    }

    /// Throughput gain over the drain-and-rebatch baseline.
    pub fn admission_speedup(&self) -> f64 {
        if self.admission_sim.seconds == 0.0 {
            1.0
        } else {
            self.drain_makespan / self.admission_sim.seconds
        }
    }

    /// Store hits among the admitted submissions.
    pub fn n_store_hits(&self) -> usize {
        self.per_graph
            .iter()
            .filter(|r| matches!(&r.store, Some(o) if o.is_hit()))
            .count()
    }

    /// Throughput gain the result store delivered over the identical
    /// workload with the store off (`None` when the store was off).
    pub fn cache_speedup(&self) -> Option<f64> {
        self.no_store_makespan.map(|m| {
            if self.admission_sim.seconds == 0.0 {
                1.0
            } else {
                m / self.admission_sim.seconds
            }
        })
    }

    /// Admit-to-complete latencies of the admitted graphs, in arrival
    /// order.
    pub fn latencies(&self) -> Vec<f64> {
        self.per_graph
            .iter()
            .filter(|r| r.verdict.admitted())
            .map(|r| r.latency)
            .collect()
    }
}

/// One delta batch's outcome in an [`Executor::run_delta`] replay.
pub struct DeltaBatchResult {
    /// Edge deltas in the batch.
    pub n_deltas: usize,
    /// `"improve"` (cheap min-plus repair path) or `"resolve"`.
    pub class: &'static str,
    /// `"repair"` when the tile plan absorbed the batch, `"replan"`
    /// when a structural change forced a full replan + re-solve.
    pub path: &'static str,
    /// Level-0 tiles the repair actually re-solved (≥ 1; after
    /// improve-path skips).
    pub dirty_tiles: usize,
    /// Level-0 tiles in the plan.
    pub total_tiles: usize,
    /// Boundary tiles the improve path proved unchanged and skipped.
    pub skipped_tiles: usize,
    /// Modeled cost of the repair sub-DAG.
    pub repair_sim: SimReport,
    /// Modeled cost of re-solving the post-delta graph from scratch.
    pub resolve_sim: SimReport,
    /// Host wall time of the functional repair (0 in estimate mode).
    pub host_repair_seconds: f64,
    /// Bit-difference vs a fresh full solve (`Some(0.0)` when
    /// validation ran and passed; `None` when `run.delta.validate` is
    /// off or in estimate mode).
    pub max_diff: Option<f32>,
    /// The pre-delta fingerprint was found and evicted from the store.
    pub store_invalidated: bool,
    /// The repaired graph's entry was written back to the store.
    pub store_written: bool,
    /// Edges in the post-delta graph.
    pub graph_m: usize,
}

impl DeltaBatchResult {
    /// What incremental repair bought over re-solving from scratch:
    /// resolve makespan / repair makespan.
    pub fn delta_speedup(&self) -> f64 {
        if self.repair_sim.seconds == 0.0 {
            1.0
        } else {
            self.resolve_sim.seconds / self.repair_sim.seconds
        }
    }
}

/// Everything one delta replay produces.
pub struct DeltaRunResult {
    /// The base graph's full solve (identical shape to a plain
    /// [`Executor::run`] report).
    pub initial: RunResult,
    /// Per-batch outcomes, in script order.
    pub batches: Vec<DeltaBatchResult>,
    /// Whether the result store participated in the replay.
    pub store_enabled: bool,
    /// Entries alive in the store after the replay (stale pre-delta
    /// entries are invalidated in place, so this stays bounded).
    pub store_len: usize,
}

impl DeltaRunResult {
    pub fn n_batches(&self) -> usize {
        self.batches.len()
    }

    /// Total deltas applied across the script.
    pub fn n_deltas(&self) -> usize {
        self.batches.iter().map(|b| b.n_deltas).sum()
    }
}

/// One tenant's slice of a serve run.
pub struct TenantServeStat {
    pub name: String,
    /// Measured query executions attributed to this tenant.
    pub queries: usize,
    /// Latency percentiles in seconds (a query's latency is its
    /// batch's drain time).
    pub p50: f64,
    pub p99: f64,
    /// Fraction of queries answered within `run.serve.slo_ms`.
    pub slo_attained: f64,
}

/// Everything one serve run produces.
pub struct ServeRunResult {
    /// Which DP workload (semiring) the snapshot was solved in.
    pub workload: &'static str,
    pub graph_n: usize,
    pub graph_m: usize,
    /// Wall time of the initial next-hop-threaded solve.
    pub host_solve_seconds: f64,
    /// Snapshots published (1 + delta batches applied).
    pub epochs: u64,
    pub query_batches: usize,
    /// Measured query executions (batch drains × batch sizes).
    pub total_queries: usize,
    /// Total wall time inside batch drains.
    pub serve_seconds: f64,
    /// Per-query latency samples in seconds.
    pub latencies: Vec<f64>,
    /// Per-tenant stats, in script interning order ("default" first).
    pub tenants: Vec<TenantServeStat>,
    /// Reader retries observed by the snapshot cell across the run.
    pub swap_stalls: u64,
    /// Loads landed by the hammer threads during delta swaps.
    pub reader_loads: u64,
    /// Checksum mismatches observed by those loads (must be 0).
    pub torn_reads: u64,
    /// Reconstructed paths walked edge-by-edge against the live graph.
    pub paths_checked: usize,
    /// Per-query wall time of the Dijkstra baseline on the same
    /// sources (None with validation off or no path queries).
    pub dijkstra_seconds_per_query: Option<f64>,
    /// Packed successor width the graph size selected (16 or 32; 0
    /// when the workload publishes no next-hop map).
    pub next_hop_bits: usize,
    /// Resident bytes of one published snapshot.
    pub snapshot_bytes: usize,
    /// First reconstructed non-empty path: (u, v, hops, weight).
    pub sample_path: Option<(u32, u32, Vec<u32>, f32)>,
}

impl ServeRunResult {
    /// Measured queries per second across all batch drains.
    pub fn qps(&self) -> f64 {
        if self.serve_seconds == 0.0 {
            0.0
        } else {
            self.total_queries as f64 / self.serve_seconds
        }
    }

    /// Mean batched cost of one query in seconds.
    pub fn per_query_seconds(&self) -> f64 {
        if self.total_queries == 0 {
            0.0
        } else {
            self.serve_seconds / self.total_queries as f64
        }
    }

    /// Latency percentile (`p` in [0, 1]) over every per-query sample,
    /// in seconds.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        if self.latencies.is_empty() {
            0.0
        } else {
            crate::util::bench::percentile(&self.latencies, p)
        }
    }

    /// Batched-path throughput over the per-query Dijkstra baseline
    /// (the ISSUE's ≥10× acceptance metric).
    pub fn path_speedup_vs_dijkstra(&self) -> Option<f64> {
        let dij = self.dijkstra_seconds_per_query?;
        let per_q = self.per_query_seconds();
        (per_q > 0.0).then(|| dij / per_q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, Topology, Weights};

    fn graph(n: usize, seed: u64) -> CsrGraph {
        generators::generate(Topology::Nws, n, 10.0, Weights::Uniform(1.0, 4.0), seed)
    }

    #[test]
    fn functional_run_validates() {
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 128;
        let ex = Executor::new(cfg).unwrap();
        let g = graph(800, 1);
        let r = ex.run(&g).unwrap();
        assert_eq!(r.mode, Mode::Functional);
        let v = r.validation.expect("validation requested");
        assert!(v.ok(1e-3), "{v:?}");
        assert!(r.sim.seconds > 0.0);
        assert!(r.host_solve_seconds > 0.0);
        assert!(r.depth >= 1);
    }

    #[test]
    fn estimate_run_matches_functional_sim() {
        let g = graph(1200, 2);
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 128;
        let func = Executor::new(cfg.clone()).unwrap().run(&g).unwrap();
        cfg.mode = Mode::Estimate;
        let est = Executor::new(cfg).unwrap().run(&g).unwrap();
        // identical traces => identical modeled time/energy
        assert!((func.sim.seconds - est.sim.seconds).abs() < 1e-12);
        assert!((func.sim.joules - est.sim.joules).abs() < 1e-12);
        assert!(est.validation.is_none());
    }

    #[test]
    fn estimate_scales_past_functional_memory() {
        // 50k vertices would need GBs of matrices in functional mode;
        // estimate mode must handle it quickly
        let g = generators::generate(
            Topology::OgbnProxy,
            50_000,
            16.0,
            Weights::Unit,
            3,
        );
        let mut cfg = SystemConfig::default();
        cfg.mode = Mode::Estimate;
        let t0 = std::time::Instant::now();
        let r = Executor::new(cfg).unwrap().run(&g).unwrap();
        assert!(r.sim.seconds > 0.0);
        assert!(
            t0.elapsed().as_secs_f64() < 60.0,
            "estimate mode too slow: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn dag_scheduler_matches_barrier_functionally_and_is_no_slower() {
        let g = graph(1_000, 7);
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 128;
        let dag = Executor::new(cfg.clone()).unwrap().run(&g).unwrap();
        cfg.scheduler = crate::coordinator::config::SchedulerKind::Barrier;
        let barrier = Executor::new(cfg).unwrap().run(&g).unwrap();
        // both validate exactly
        assert!(dag.validation.as_ref().unwrap().ok(1e-3));
        assert!(barrier.validation.as_ref().unwrap().ok(1e-3));
        // overlap can only help the modeled makespan
        assert!(
            dag.sim.seconds <= barrier.sim.seconds * (1.0 + 1e-9),
            "dag {} > barrier {}",
            dag.sim.seconds,
            barrier.sim.seconds
        );
        // identical dynamic work
        assert!((dag.sim.dynamic_joules - barrier.sim.dynamic_joules).abs() < 1e-9);
        assert_eq!(dag.scheduler.name(), "dag");
        assert_eq!(barrier.scheduler.name(), "barrier");
    }

    #[test]
    fn run_batch_matches_solo_and_gains_throughput() {
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 128;
        let ex = Executor::new(cfg).unwrap();
        let graphs = vec![graph(700, 11), graph(900, 12), graph(500, 13)];
        let b = ex.run_batch(&graphs).unwrap();
        assert_eq!(b.batch_size(), 3);
        for (i, r) in b.per_graph.iter().enumerate() {
            let v = r.validation.as_ref().expect("validation on");
            assert!(v.ok(r.validate_tolerance), "graph {i}: {v:?}");
            // the per-graph solo baseline matches an individual run
            let solo = ex.run(&graphs[i]).unwrap();
            assert_eq!(r.sim.seconds, solo.sim.seconds, "graph {i}");
            assert_eq!(r.sim.dynamic_joules, solo.sim.dynamic_joules, "graph {i}");
        }
        // modeled batch bounded by the serial-submission baseline
        assert!(
            b.batch_sim.seconds <= b.solo_makespan_sum() * (1.0 + 1e-9),
            "batch {} > serial {}",
            b.batch_sim.seconds,
            b.solo_makespan_sum()
        );
        assert!(b.batch_speedup() >= 1.0 - 1e-9);
        // per-graph energy attribution partitions the batch total
        let esum: f64 = b.batch_stats.iter().map(|s| s.dynamic_joules).sum();
        assert_eq!(esum, b.batch_sim.dynamic_joules);
    }

    #[test]
    fn run_batch_estimate_mode_needs_no_numerics() {
        let mut cfg = SystemConfig::default();
        cfg.mode = Mode::Estimate;
        cfg.tile_limit = 128;
        let ex = Executor::new(cfg).unwrap();
        let graphs = vec![graph(1_000, 21), graph(1_500, 22)];
        let b = ex.run_batch(&graphs).unwrap();
        assert!(b.batch_sim.seconds > 0.0);
        assert!(b.per_graph.iter().all(|r| r.validation.is_none()));
        assert_eq!(b.batch_stats.len(), 2);
    }

    #[test]
    fn run_admission_end_to_end() {
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 128;
        cfg.admission_queue_depth = 2;
        cfg.admission_interval = 1e-4;
        let ex = Executor::new(cfg).unwrap();
        let graphs = vec![graph(700, 61), graph(900, 62), graph(500, 63)];
        let a = ex.run_admission(&graphs).unwrap();
        assert_eq!(a.n_submissions(), 3);
        assert_eq!(a.n_admitted(), 3);
        assert_eq!(a.n_rejected(), 0);
        assert_eq!(a.queue_depth, 2);
        // every admitted graph completed exactly once in the host run
        let mut order = a.completion_order.clone();
        order.sort_unstable();
        assert_eq!(order, vec![0, 1, 2]);
        assert!(a.host_solve_seconds > 0.0);
        for (i, r) in a.per_graph.iter().enumerate() {
            assert!(r.verdict.admitted());
            assert!((r.arrival - i as f64 * 1e-4).abs() < 1e-15);
            let solo = r.solo.as_ref().expect("admitted");
            let v = solo.validation.as_ref().expect("validation on");
            assert!(v.ok(solo.validate_tolerance), "graph {i}: {v:?}");
            // the solo baseline matches an individual run
            let plain = ex.run(&graphs[i]).unwrap();
            assert_eq!(solo.sim.seconds, plain.sim.seconds, "graph {i}");
            // latency is completion minus arrival on the shared timeline
            let stat = r.stat.as_ref().expect("admitted");
            assert!((r.latency - (stat.makespan - r.arrival)).abs() < 1e-15);
            assert!(r.latency > 0.0);
        }
        assert!(a.admission_sim.seconds > 0.0);
        assert!(a.drain_makespan > 0.0);
        assert!(a.admission_speedup() > 0.0);
        assert_eq!(a.latencies().len(), 3);
    }

    #[test]
    fn run_admission_with_store_serves_duplicates() {
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 128;
        cfg.admission_interval = 1e-4;
        cfg.store_enabled = true;
        cfg.store_capacity = 4;
        let ex = Executor::new(cfg).unwrap();
        // submission 2 duplicates submission 0 byte-for-byte
        let graphs = vec![graph(500, 91), graph(700, 92), graph(500, 91)];
        let a = ex.run_admission(&graphs).unwrap();
        assert_eq!(a.n_admitted(), 3);
        assert_eq!(a.n_store_hits(), 1);
        assert!(matches!(a.per_graph[0].store, Some(StoreOutcome::MissStored)));
        assert!(matches!(a.per_graph[2].store, Some(StoreOutcome::Hit { .. })));
        let hit = &a.per_graph[2];
        let solo = hit.solo.as_ref().expect("admitted");
        // the served solution validates exactly against Dijkstra
        let v = solo.validation.as_ref().expect("validation on");
        assert!(v.ok(solo.validate_tolerance), "{v:?}");
        // the modeled FeNAND read completes before the solve it skipped
        assert!(hit.latency > 0.0);
        assert!(
            hit.latency < solo.sim.seconds,
            "hit latency {} must beat the solo solve {}",
            hit.latency,
            solo.sim.seconds
        );
        // the no-store baseline exists and the ratio is well-formed (a
        // mixed workload may pay more in write-backs than one hit saves;
        // the >1 case is covered below with a duplicate-heavy stream)
        let cs = a.cache_speedup().expect("store on");
        assert!(cs.is_finite() && cs > 0.0, "cache speedup {cs}");
        // store off: no cache metrics, no hit verdicts
        let mut cfg = SystemConfig::default();
        cfg.mode = Mode::Estimate;
        cfg.admission_interval = 1e-4;
        let b = Executor::new(cfg).unwrap().run_admission(&graphs).unwrap();
        assert!(b.no_store_makespan.is_none());
        assert!(b.cache_speedup().is_none());
        assert_eq!(b.n_store_hits(), 0);
        assert!(b.per_graph.iter().all(|r| r.store.is_none()));
    }

    #[test]
    fn duplicate_heavy_stream_gains_cache_speedup() {
        // queue depth 1 serializes the schedule, so the no-store
        // baseline pays the full solve three times while the store
        // solves once and serves two FeNAND reads — the cache win must
        // clear the write-back overhead with room to spare
        let mut cfg = SystemConfig::default();
        cfg.mode = Mode::Estimate;
        cfg.tile_limit = 128;
        cfg.admission_queue_depth = 1;
        cfg.admission_interval = 1e-4;
        cfg.store_enabled = true;
        let ex = Executor::new(cfg).unwrap();
        let g = graph(600, 95);
        let graphs = vec![g.clone(), g.clone(), g];
        let a = ex.run_admission(&graphs).unwrap();
        assert_eq!(a.n_admitted(), 3);
        assert_eq!(a.n_store_hits(), 2);
        let cs = a.cache_speedup().expect("store on");
        assert!(cs > 1.0, "duplicate-heavy stream must gain, got {cs}");
        assert!(a.no_store_makespan.unwrap() > a.admission_sim.seconds);
    }

    #[test]
    fn run_admission_rejects_oversized_but_keeps_running() {
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 64;
        cfg.mode = Mode::Estimate;
        // fits the two small graphs, never the big one
        cfg.memory_limit_bytes = 4 << 20;
        cfg.admission_queue_depth = 1;
        let ex = Executor::new(cfg).unwrap();
        let graphs = vec![graph(200, 71), graph(6_000, 72), graph(250, 73)];
        let a = ex.run_admission(&graphs).unwrap();
        assert_eq!(a.n_admitted(), 2);
        assert_eq!(a.n_rejected(), 1);
        assert!(!a.per_graph[1].verdict.admitted());
        assert!(a.per_graph[0].verdict.admitted());
        assert!(a.per_graph[2].verdict.admitted(), "pipeline keeps running");
        assert!(a.per_graph[1].solo.is_none());
        assert_eq!(a.per_graph[1].latency, 0.0);
        assert!(a.admission_sim.seconds > 0.0);
    }

    #[test]
    fn run_admission_zero_length_queue_is_clean() {
        let mut cfg = SystemConfig::default();
        cfg.mode = Mode::Estimate;
        let ex = Executor::new(cfg).unwrap();
        let a = ex.run_admission(&[]).unwrap();
        assert_eq!(a.n_submissions(), 0);
        assert_eq!(a.n_admitted(), 0);
        assert_eq!(a.admission_sim.seconds, 0.0);
        assert_eq!(a.drain_makespan, 0.0);
        assert!((a.admission_speedup() - 1.0).abs() < 1e-12);
        assert!(a.completion_order.is_empty());
    }

    #[test]
    fn run_admission_validates_arrival_schedule() {
        let mut cfg = SystemConfig::default();
        cfg.mode = Mode::Estimate;
        cfg.admission_arrivals = vec![0.0, 2e-3];
        let ex = Executor::new(cfg).unwrap();
        // schedule length mismatch is a clean error
        let graphs = vec![graph(200, 81), graph(200, 82), graph(200, 83)];
        let err = ex.run_admission(&graphs).unwrap_err();
        assert!(format!("{err}").contains("entries"), "{err}");
        // decreasing schedule is a clean error
        let mut cfg = SystemConfig::default();
        cfg.mode = Mode::Estimate;
        cfg.admission_arrivals = vec![1e-3, 0.0];
        let ex = Executor::new(cfg).unwrap();
        let graphs = vec![graph(200, 84), graph(200, 85)];
        let err = ex.run_admission(&graphs).unwrap_err();
        assert!(format!("{err}").contains("non-decreasing"), "{err}");
    }

    #[test]
    fn run_sharded_end_to_end() {
        let g = graph(900, 41);
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 64;
        cfg.num_stacks = 2;
        let ex = Executor::new(cfg).unwrap();
        let r = ex.run_sharded(&g).unwrap();
        assert_eq!(r.num_stacks, 2);
        assert!(r.solo.validation.as_ref().unwrap().ok(1e-3));
        assert!(r.shard_sim.seconds > 0.0);
        assert!(r.host_solve_seconds > 0.0);
        assert_eq!(r.stack_stats.len(), 2);
        assert_eq!(r.comps_per_stack.iter().sum::<usize>(), r.solo.components_l0);
        assert!(r.n_xfers > 0 && r.xfer_bytes > 0);
        // per-stack energy partitions the sharded total exactly
        let esum: f64 = r.stack_stats.iter().map(|s| s.dynamic_joules).sum();
        assert_eq!(esum, r.shard_sim.dynamic_joules);
        assert!(r.shard_speedup() > 0.0);
    }

    #[test]
    fn run_sharded_one_stack_matches_solo_run() {
        let g = graph(700, 42);
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 64;
        cfg.num_stacks = 1;
        let ex = Executor::new(cfg).unwrap();
        let r = ex.run_sharded(&g).unwrap();
        let solo = ex.run(&g).unwrap();
        assert_eq!(r.shard_sim.seconds, solo.sim.seconds);
        assert_eq!(r.shard_sim.dynamic_joules, solo.sim.dynamic_joules);
        assert_eq!(r.n_xfers, 0);
        assert!((r.shard_speedup() - 1.0).abs() < 1e-12);
        // the baseline is scheduler-knob-independent: a barrier-config
        // 1-stack run must still report speedup 1.0 (not the
        // barrier-vs-dag scheduler gap)
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 64;
        cfg.num_stacks = 1;
        cfg.scheduler = crate::coordinator::config::SchedulerKind::Barrier;
        cfg.mode = Mode::Estimate;
        let rb = Executor::new(cfg).unwrap().run_sharded(&g).unwrap();
        assert!((rb.shard_speedup() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn algorithm1_vs_algorithm2_sim() {
        // recursion (Alg 2) must beat single-level (Alg 1) when the
        // boundary graph exceeds one tile — the paper's §III-A argument
        let g = graph(3000, 4);
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 128;
        cfg.mode = Mode::Estimate;
        let alg2 = Executor::new(cfg.clone()).unwrap().run(&g).unwrap();
        cfg.max_depth = 1;
        let alg1 = Executor::new(cfg).unwrap().run(&g).unwrap();
        assert!(alg2.depth >= 1 && alg1.depth == 1);
        // Alg 1's terminal FW is a giant dense solve
        assert!(alg1.final_n >= alg2.final_n);
    }

    #[test]
    fn run_delta_end_to_end_repairs_and_validates() {
        let g = graph(900, 51);
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 96;
        cfg.store_enabled = true;
        cfg.store_capacity = 4;
        let ex = Executor::new(cfg).unwrap();
        // batch 1 improves (halved weight, cheap repair path with
        // skips); batch 2 resolves (delete re-solves the closure)
        let (u, v, w) = g.edges().next().unwrap();
        let (u2, v2, _) = g.edges().nth(5).unwrap();
        let script = format!(
            "# improve\nreweight {u} {v} {}\n\n# resolve\ndelete {u2} {v2}\n",
            w * 0.5
        );
        let r = ex.run_delta(&g, &script).unwrap();
        assert!(r.initial.validation.as_ref().unwrap().ok(1e-3));
        assert!(r.initial.host_solve_seconds > 0.0);
        assert_eq!(r.n_batches(), 2);
        assert_eq!(r.n_deltas(), 2);
        assert_eq!(r.batches[0].class, "improve");
        assert_eq!(r.batches[1].class, "resolve");
        for (i, b) in r.batches.iter().enumerate() {
            // neither batch changes the cut structure
            assert_eq!(b.path, "repair", "batch {i}");
            // bit-identical to a fresh full solve of the new graph
            assert_eq!(b.max_diff, Some(0.0), "batch {i}");
            assert!(b.dirty_tiles >= 1 && b.dirty_tiles <= b.total_tiles);
            assert!(b.host_repair_seconds > 0.0);
            // the repair sub-DAG must beat re-solving from scratch
            assert!(
                b.delta_speedup() > 1.0,
                "batch {i}: speedup {}",
                b.delta_speedup()
            );
            // stale entry invalidated, repaired entry written back
            assert!(b.store_invalidated && b.store_written, "batch {i}");
        }
        // the store holds exactly the lineage head — no stale
        // pre-delta entries accumulate
        assert!(r.store_enabled);
        assert_eq!(r.store_len, 1);
    }

    #[test]
    fn run_delta_estimate_mode_models_without_numerics() {
        let g = graph(1_200, 52);
        let (u, v, w) = g.edges().next().unwrap();
        let script = format!("reweight {u} {v} {}\n", w * 0.5);
        let mut cfg = SystemConfig::default();
        cfg.mode = Mode::Estimate;
        cfg.tile_limit = 96;
        let r = Executor::new(cfg).unwrap().run_delta(&g, &script).unwrap();
        assert!(r.initial.validation.is_none());
        assert_eq!(r.initial.host_solve_seconds, 0.0);
        let b = &r.batches[0];
        assert!(b.max_diff.is_none());
        assert_eq!(b.host_repair_seconds, 0.0);
        assert!(b.repair_sim.seconds > 0.0);
        assert!(b.resolve_sim.seconds >= b.repair_sim.seconds);
        assert!(b.delta_speedup() >= 1.0);
        assert!(!r.store_enabled);
        assert_eq!(r.store_len, 0);
    }

    #[test]
    fn run_delta_structural_change_falls_back_to_replan() {
        let g = graph(800, 53);
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 96;
        let ex = Executor::new(cfg).unwrap();
        // connect an internal vertex to another component: the old
        // boundary sets no longer cover the cut
        let plan = ex.plan(&g);
        let lvl0 = &plan.levels[0];
        let (iu, other) = 'found: {
            for (ci, c) in lvl0.cs.components.iter().enumerate() {
                if let Some(&internal) = c.internal().first() {
                    for (cj, c2) in lvl0.cs.components.iter().enumerate() {
                        if ci != cj && c2.n() > 0 {
                            break 'found (internal, c2.verts[0]);
                        }
                    }
                }
            }
            panic!("no internal vertex found");
        };
        let script = format!("insert {iu} {other} 1.5\n");
        let r = ex.run_delta(&g, &script).unwrap();
        let b = &r.batches[0];
        assert_eq!(b.path, "replan");
        assert_eq!(b.dirty_tiles, b.total_tiles);
        // the fallback is the full solve: repair cost = baseline cost
        assert!((b.delta_speedup() - 1.0).abs() < 1e-12);
        assert!(b.host_repair_seconds > 0.0);
        assert_eq!(b.graph_m, g.m() + 2);
    }

    #[test]
    fn run_serve_end_to_end_with_interleaved_deltas() {
        let g = graph(300, 61);
        let mut cfg = SystemConfig::default();
        cfg.serve_readers = 2;
        let ex = Executor::new(cfg).unwrap();
        let (u, v, w) = g.edges().next().unwrap();
        let queries = "dist 0 7\npath 3 250 @gold\nknear 5 4\nreach 9\n\n\
                       path 12 200\ndist 1 2 @gold\n";
        let deltas = format!("reweight {u} {v} {}\n", w * 0.5);
        let r = ex.run_serve(&g, queries, Some(&deltas)).unwrap();
        assert_eq!(r.graph_n, 300);
        assert!(r.host_solve_seconds > 0.0);
        assert_eq!(r.query_batches, 2);
        // one delta batch applied between the two query batches
        assert_eq!(r.epochs, 2);
        assert_eq!(r.total_queries, 6 * SERVE_REPS);
        assert!(r.qps() > 0.0);
        assert!(r.latency_percentile(0.99) >= r.latency_percentile(0.50));
        // readers kept landing loads during the swap, none torn
        assert!(r.reader_loads > 0);
        assert_eq!(r.torn_reads, 0);
        // both path queries walked edge-by-edge against the live graph
        assert_eq!(r.paths_checked, 2);
        let (pu, _, hops, weight) = r.sample_path.as_ref().expect("a reconstructed path");
        assert_eq!(hops.first(), Some(pu));
        assert!(weight.is_finite());
        // the packed map beats per-query Dijkstra comfortably
        assert!(r.path_speedup_vs_dijkstra().unwrap() > 10.0);
        // tenants: "default" interned first, then @gold
        assert_eq!(r.tenants.len(), 2);
        assert_eq!(r.tenants[0].name, "default");
        assert_eq!(r.tenants[1].name, "gold");
        assert_eq!(r.tenants[1].queries, 2 * SERVE_REPS);
        assert!((0.0..=1.0).contains(&r.tenants[1].slo_attained));
        assert_eq!(r.next_hop_bits, 16);
        assert!(r.snapshot_bytes > 0);
    }

    #[test]
    fn run_serve_rejects_bad_input_cleanly() {
        let cfg = SystemConfig::default();
        let ex = Executor::new(cfg).unwrap();
        // empty base graph: nothing to query
        let empty = CsrGraph::from_edges(0, &[]);
        let err = ex.run_serve(&empty, "dist 0 1\n", None).unwrap_err();
        assert!(format!("{err}").contains("base graph is empty"), "{err}");
        let g = graph(200, 62);
        // query validation surfaces as a clean error, not a panic
        let err = ex.run_serve(&g, "dist 0 100000\n", None).unwrap_err();
        assert!(format!("{err}").contains("out of range"), "{err}");
        let err = ex.run_serve(&g, "# only comments\n", None).unwrap_err();
        assert!(format!("{err}").contains("no queries"), "{err}");
        // estimate mode has no numerics to serve
        let mut cfg = SystemConfig::default();
        cfg.mode = Mode::Estimate;
        let err = Executor::new(cfg)
            .unwrap()
            .run_serve(&g, "dist 0 1\n", None)
            .unwrap_err();
        assert!(format!("{err}").contains("functional"), "{err}");
        // a malformed delta feed is rejected before any serving
        let err = ex.run_serve(&g, "dist 0 1\n", Some("frobnicate 1 2\n")).unwrap_err();
        assert!(format!("{err}").contains("frobnicate"), "{err}");
    }

    #[test]
    fn every_workload_runs_solo_and_validates() {
        use crate::coordinator::config::Workload;
        let g = graph(500, 17);
        for w in [
            Workload::Apsp,
            Workload::Reach,
            Workload::Widest,
            Workload::Critical,
        ] {
            let mut cfg = SystemConfig::default();
            cfg.tile_limit = 96;
            cfg.workload = w;
            let ex = Executor::new(cfg).unwrap();
            let r = ex.run(&g).unwrap();
            assert_eq!(r.workload, w.name());
            let v = r.validation.as_ref().expect("validation on");
            assert!(v.ok(r.validate_tolerance), "{}: {v:?}", w.name());
            assert!(r.sim.seconds > 0.0);
            assert!(r.host_solve_seconds > 0.0);
        }
    }

    #[test]
    fn every_workload_admits_and_validates() {
        use crate::coordinator::config::Workload;
        for w in [Workload::Reach, Workload::Widest, Workload::Critical] {
            let mut cfg = SystemConfig::default();
            cfg.tile_limit = 96;
            cfg.workload = w;
            cfg.admission_interval = 1e-4;
            let ex = Executor::new(cfg).unwrap();
            let graphs = vec![graph(350, 31), graph(400, 32)];
            let a = ex.run_admission(&graphs).unwrap();
            assert_eq!(a.n_admitted(), 2, "{}", w.name());
            for (i, r) in a.per_graph.iter().enumerate() {
                let solo = r.solo.as_ref().expect("admitted");
                assert_eq!(solo.workload, w.name());
                let v = solo.validation.as_ref().expect("validation on");
                assert!(v.ok(solo.validate_tolerance), "{} graph {i}: {v:?}", w.name());
            }
        }
    }

    #[test]
    fn critical_workload_is_dag_restricted() {
        use crate::coordinator::config::Workload;
        // an undirected (symmetric) graph is auto-oriented low -> high
        let g = graph(300, 35);
        let mut cfg = SystemConfig::default();
        cfg.tile_limit = 96;
        cfg.workload = Workload::Critical;
        let ex = Executor::new(cfg).unwrap();
        let r = ex.run(&g).unwrap();
        assert!(r.graph_m > 0 && r.graph_m < g.m(), "orientation must drop edges");
        assert!(r.validation.as_ref().unwrap().ok(r.validate_tolerance));
    }

    #[test]
    fn non_apsp_serve_answers_dist_knear_reach() {
        use crate::coordinator::config::Workload;
        let g = graph(200, 33);
        let mut cfg = SystemConfig::default();
        cfg.workload = Workload::Widest;
        let ex = Executor::new(cfg).unwrap();
        let r = ex
            .run_serve(&g, "dist 0 9\nknear 3 4\nreach 5\n", None)
            .unwrap();
        assert_eq!(r.workload, "widest");
        assert_eq!(r.next_hop_bits, 0);
        assert!(r.total_queries > 0);
        assert!(r.qps() > 0.0);
        assert_eq!(r.paths_checked, 0);
        assert!(r.sample_path.is_none());
        // path queries and live deltas are (min,+)-pinned layers
        let err = ex.run_serve(&g, "path 0 9\n", None).unwrap_err();
        assert!(format!("{err}").contains("next-hop"), "{err}");
        let err = ex
            .run_serve(&g, "dist 0 1\n", Some("reweight 0 1 1.0\n"))
            .unwrap_err();
        assert!(format!("{err}").contains("static snapshot"), "{err}");
    }

    #[test]
    fn delta_and_pjrt_are_minplus_pinned() {
        use crate::coordinator::config::Workload;
        let g = graph(200, 34);
        let mut cfg = SystemConfig::default();
        cfg.workload = Workload::Reach;
        let ex = Executor::new(cfg).unwrap();
        let err = ex.run_delta(&g, "delete 0 1\n").unwrap_err();
        assert!(format!("{err}").contains("(min,+)"), "{err}");
        let mut cfg = SystemConfig::default();
        cfg.workload = Workload::Widest;
        cfg.backend = crate::coordinator::config::BackendKind::Pjrt;
        let err = Executor::new(cfg).unwrap_err();
        assert!(format!("{err}").contains("--backend native"), "{err}");
    }

    #[test]
    fn run_delta_rejects_bad_input_cleanly() {
        let mut cfg = SystemConfig::default();
        cfg.mode = Mode::Estimate;
        let ex = Executor::new(cfg).unwrap();
        // empty base graph: nothing to repair
        let empty = CsrGraph::from_edges(0, &[]);
        let err = ex.run_delta(&empty, "insert 0 1 1.0\n").unwrap_err();
        assert!(format!("{err}").contains("base graph"), "{err}");
        // a validator rejection surfaces as a clean error, not a panic
        let g = graph(200, 54);
        let err = ex.run_delta(&g, "insert 0 100000 1.0\n").unwrap_err();
        assert!(format!("{err}").contains("out of range"), "{err}");
        // so does a malformed script
        let err = ex.run_delta(&g, "frobnicate 1 2\n").unwrap_err();
        assert!(format!("{err}").contains("frobnicate"), "{err}");
    }
}
