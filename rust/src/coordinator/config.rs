//! System configuration: hardware model + algorithm knobs + run mode,
//! loadable from a TOML-subset config file with CLI overrides.

use crate::apsp::semiring::SemiringId;
use crate::sim::params::HwParams;
use crate::util::cli::Args;
use crate::util::config::ConfigFile;
use crate::util::error::Result;

/// Execution mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Real numerics through a tile backend, validated against Dijkstra.
    Functional,
    /// Cost model only (scales to OGBN-Products).
    Estimate,
}

impl Mode {
    pub fn parse(s: &str) -> Option<Mode> {
        match s.to_ascii_lowercase().as_str() {
            "functional" | "func" => Some(Mode::Functional),
            "estimate" | "est" => Some(Mode::Estimate),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Mode::Functional => "functional",
            Mode::Estimate => "estimate",
        }
    }
}

/// DP workload: which semiring the tile kernels run in and which scalar
/// oracle validates the result (`run.workload` / `--workload`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    /// All-pairs shortest paths over (min, +) — the default, and the
    /// only workload with next-hop path reconstruction.
    Apsp,
    /// Reachability closure over (or, and), validated against BFS.
    Reach,
    /// Widest (maximum-bottleneck) paths over (max, min), validated
    /// against a modified Dijkstra.
    Widest,
    /// Critical (longest) paths over (max, +). DAG-restricted: the
    /// executor reorients the input acyclically and refuses cycles.
    Critical,
}

impl Workload {
    pub fn parse(s: &str) -> Option<Workload> {
        match s.to_ascii_lowercase().as_str() {
            "apsp" | "shortest" | "minplus" => Some(Workload::Apsp),
            "reach" | "reachability" => Some(Workload::Reach),
            "widest" | "bottleneck" => Some(Workload::Widest),
            "critical" | "longest" => Some(Workload::Critical),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Workload::Apsp => "apsp",
            Workload::Reach => "reach",
            Workload::Widest => "widest",
            Workload::Critical => "critical",
        }
    }
    /// The semiring instance the kernels run for this workload.
    pub fn semiring(&self) -> SemiringId {
        match self {
            Workload::Apsp => SemiringId::MinPlus,
            Workload::Reach => SemiringId::BoolAndOr,
            Workload::Widest => SemiringId::MaxMin,
            Workload::Critical => SemiringId::MaxPlus,
        }
    }
}

/// Which tile compute engine executes FW/MP numerics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Multithreaded rust kernels.
    Native,
    /// AOT JAX/Pallas HLO artifacts through PJRT.
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "native" | "rust" => Some(BackendKind::Native),
            "pjrt" | "xla" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Which scheduler orders the tile work (host numerics and the
/// simulator's makespan model alike).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Legacy step-barrier walk: phases join before the next starts;
    /// the simulator costs the trace step by step.
    Barrier,
    /// Dependency-aware execution over the tile-task DAG: the host
    /// executor runs ready tasks concurrently (bit-identical results),
    /// and the simulator list-schedules ops under resource constraints.
    Dag,
}

impl SchedulerKind {
    pub fn parse(s: &str) -> Option<SchedulerKind> {
        match s.to_ascii_lowercase().as_str() {
            "barrier" | "step" | "legacy" => Some(SchedulerKind::Barrier),
            "dag" | "graph" => Some(SchedulerKind::Dag),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::Barrier => "barrier",
            SchedulerKind::Dag => "dag",
        }
    }
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub hw: HwParams,
    /// Max vertices per PIM tile (paper: 1024).
    pub tile_limit: usize,
    /// Recursion depth cap (usize::MAX = Algorithm 2; 1 = Algorithm 1).
    pub max_depth: usize,
    pub seed: u64,
    pub mode: Mode,
    /// DP workload (`run.workload` / `--workload`): the semiring the
    /// kernels run in and the oracle that validates the result.
    pub workload: Workload,
    pub backend: BackendKind,
    /// Tile-work scheduling: dependency-aware DAG (default) or the
    /// legacy step-barrier walk.
    pub scheduler: SchedulerKind,
    /// Sampled-validation effort (sources x cols); 0 disables.
    pub validate_sources: usize,
    pub validate_cols: usize,
    /// Absolute tolerance for exactness validation (vs Dijkstra).
    pub validate_tolerance: f32,
    /// Functional-mode matrix memory guard.
    pub memory_limit_bytes: u64,
    /// Graphs per batch submission (`Executor::run_batch` and the
    /// `--batch` CLI mode generate/accept this many).
    pub batch_size: usize,
    /// Modeled PIM stacks for sharded execution
    /// (`Executor::run_sharded` / `apsp --stacks`). 1 = solo run.
    pub num_stacks: usize,
    /// Admission pipeline: max graphs in flight
    /// (`run.admission.queue_depth` / `--admit-queue`). The next
    /// arrival waits for a slot; the bound also caps the worst-case
    /// co-resident footprint the aggregate memory guard checks.
    pub admission_queue_depth: usize,
    /// Admission pipeline: explicit arrival schedule in modeled seconds
    /// (`run.admission.arrivals = "0,1e-3,2e-3"` / `--arrivals`).
    /// Empty = derive a uniform schedule from `admission_interval`.
    /// Arrivals are simulation-timeline stamps, never wall-clock.
    pub admission_arrivals: Vec<f64>,
    /// Admission pipeline: uniform arrival spacing (modeled seconds)
    /// used when no explicit schedule is given
    /// (`run.admission.interval` / `--admit-interval`). 0 = everything
    /// arrives at t = 0 (a batch-shaped admission workload).
    pub admission_interval: f64,
    /// Result store: fingerprint-keyed cache of solved APSP results on
    /// modeled FeNAND, consulted at admission time
    /// (`run.store.enabled` / `--store-capacity`). Off by default; the
    /// CLI flag both sizes and enables it.
    pub store_enabled: bool,
    /// Result store: max cached results (`run.store.capacity` /
    /// `--store-capacity`). 0 disables cleanly: every submission is a
    /// miss and nothing is written.
    pub store_capacity: usize,
    /// Result store: total byte budget across cached payloads
    /// (`run.store.bytes`). An entry larger than the whole budget is
    /// rejected with a clean error instead of evicting everything.
    pub store_bytes: u64,
    /// Result store: persist compressed (finite-entry) payloads instead
    /// of dense f32 matrices (`run.store.compression`).
    pub store_compression: bool,
    /// Delta engine: bit-validate every repaired state against a fresh
    /// full solve of the mutated graph (`run.delta.validate`). On by
    /// default in functional mode — the repair path's contract is
    /// bit-identity, so validation is an equality check, not a
    /// tolerance band. Estimate mode has no numerics to compare.
    pub delta_validate: bool,
    /// Delta engine: allow the improve-path skip — a clean boundary
    /// tile whose refreshed dB block is bit-unchanged skips its
    /// inject + rerun (`run.delta.skip`). Disabling forces the
    /// conservative closure on every batch (a debugging knob; results
    /// are bit-identical either way).
    pub delta_skip: bool,
    /// Serve loop: consecutive matrix rows per leased row panel in the
    /// batched query executor (`run.serve.panel_rows` /
    /// `--serve-panel`).
    pub serve_panel_rows: usize,
    /// Serve loop: per-query latency SLO in milliseconds, reported as
    /// per-tenant attainment (`run.serve.slo_ms` / `--serve-slo`).
    pub serve_slo_ms: f64,
    /// Serve loop: concurrent reader threads hammering the snapshot
    /// cell while delta repairs swap it (`run.serve.readers` /
    /// `--serve-readers`). 0 skips the concurrent-read probe.
    pub serve_readers: usize,
    /// Serve loop: check every reconstructed path against the distance
    /// matrix and run the per-query Dijkstra throughput baseline
    /// (`run.serve.validate`; `--serve-no-validate` disables).
    pub serve_validate: bool,
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self {
            hw: HwParams::default(),
            tile_limit: crate::TILE_LIMIT,
            max_depth: usize::MAX,
            seed: 0x5241_5049,
            mode: Mode::Functional,
            workload: Workload::Apsp,
            backend: BackendKind::Native,
            scheduler: SchedulerKind::Dag,
            validate_sources: 16,
            validate_cols: 64,
            validate_tolerance: 1e-3,
            memory_limit_bytes: 12 << 30,
            batch_size: 4,
            num_stacks: 1,
            admission_queue_depth: 4,
            admission_arrivals: Vec::new(),
            admission_interval: 0.0,
            store_enabled: false,
            store_capacity: 8,
            store_bytes: 1 << 32,
            store_compression: true,
            delta_validate: true,
            delta_skip: true,
            serve_panel_rows: 8,
            serve_slo_ms: 1.0,
            serve_readers: 4,
            serve_validate: true,
        }
    }
}

impl SystemConfig {
    /// Load from a config file (all keys optional).
    pub fn from_file(cf: &ConfigFile) -> Self {
        let mut c = Self::default();
        c.apply_file(cf);
        c
    }

    pub fn apply_file(&mut self, cf: &ConfigFile) {
        self.tile_limit = cf.get_usize("algo.tile_limit", self.tile_limit);
        self.max_depth = cf.get_usize("algo.max_depth", self.max_depth);
        self.seed = cf.get_usize("algo.seed", self.seed as usize) as u64;
        if let Some(m) = cf.get("run.mode").and_then(Mode::parse) {
            self.mode = m;
        }
        if let Some(w) = cf.get("run.workload").and_then(Workload::parse) {
            self.workload = w;
        }
        if let Some(b) = cf.get("run.backend").and_then(BackendKind::parse) {
            self.backend = b;
        }
        if let Some(s) = cf.get("run.scheduler").and_then(SchedulerKind::parse) {
            self.scheduler = s;
        }
        self.validate_sources = cf.get_usize("run.validate_sources", self.validate_sources);
        self.validate_cols = cf.get_usize("run.validate_cols", self.validate_cols);
        self.validate_tolerance =
            cf.get_f64("run.validate_tolerance", self.validate_tolerance as f64) as f32;
        self.batch_size = cf.get_usize("run.batch_size", self.batch_size);
        self.num_stacks = cf.get_usize("run.num_stacks", self.num_stacks);
        // [run.admission] block. A malformed arrival list is a hard
        // error (not a silent fallback like the scalar knobs): quietly
        // substituting the uniform-interval schedule would report
        // latencies for arrivals the user never configured.
        self.admission_queue_depth =
            cf.get_usize("run.admission.queue_depth", self.admission_queue_depth);
        self.admission_interval = cf.get_f64("run.admission.interval", self.admission_interval);
        if let Some(list) = cf.get("run.admission.arrivals") {
            match parse_arrivals(list) {
                Some(v) => self.admission_arrivals = v,
                None => {
                    panic!("run.admission.arrivals expects comma-separated numbers, got {list:?}")
                }
            }
        }
        // [run.store] block
        self.store_enabled = cf.get_bool("run.store.enabled", self.store_enabled);
        self.store_capacity = cf.get_usize("run.store.capacity", self.store_capacity);
        self.store_bytes = cf.get_usize("run.store.bytes", self.store_bytes as usize) as u64;
        self.store_compression = cf.get_bool("run.store.compression", self.store_compression);
        // [run.delta] block
        self.delta_validate = cf.get_bool("run.delta.validate", self.delta_validate);
        self.delta_skip = cf.get_bool("run.delta.skip", self.delta_skip);
        // [run.serve] block
        self.serve_panel_rows = cf.get_usize("run.serve.panel_rows", self.serve_panel_rows);
        self.serve_slo_ms = cf.get_f64("run.serve.slo_ms", self.serve_slo_ms);
        self.serve_readers = cf.get_usize("run.serve.readers", self.serve_readers);
        self.serve_validate = cf.get_bool("run.serve.validate", self.serve_validate);
        // hardware overrides
        let hw = &mut self.hw;
        hw.tiles_per_die = cf.get_usize("hardware.tiles_per_die", hw.tiles_per_die);
        hw.units_per_tile = cf.get_usize("hardware.units_per_tile", hw.units_per_tile);
        hw.clock_hz = cf.get_f64("hardware.clock_ghz", hw.clock_hz / 1e9) * 1e9;
        hw.prefetch = cf.get_bool("hardware.prefetch", hw.prefetch);
        hw.permutation_unit = cf.get_bool("hardware.permutation_unit", hw.permutation_unit);
        hw.comparator_tree = cf.get_bool("hardware.comparator_tree", hw.comparator_tree);
    }

    /// Apply CLI overrides (`--tile`, `--mode`, `--backend`, `--seed`,
    /// `--max-depth`, `--no-prefetch`, ...).
    pub fn apply_args(&mut self, args: &Args) {
        self.tile_limit = args.get_usize("tile", self.tile_limit);
        self.max_depth = args.get_usize("max-depth", self.max_depth);
        self.seed = args.get_u64("seed", self.seed);
        if let Some(m) = args.get("mode").and_then(Mode::parse) {
            self.mode = m;
        }
        if let Some(w) = args.get("workload") {
            match Workload::parse(w) {
                Some(w) => self.workload = w,
                None => panic!("--workload expects apsp|reach|widest|critical, got {w:?}"),
            }
        }
        if let Some(b) = args.get("backend").and_then(BackendKind::parse) {
            self.backend = b;
        }
        if let Some(s) = args.get("scheduler").and_then(SchedulerKind::parse) {
            self.scheduler = s;
        }
        if args.flag("no-prefetch") {
            self.hw.prefetch = false;
        }
        if args.flag("no-permutation-unit") {
            self.hw.permutation_unit = false;
        }
        if args.flag("no-comparator-tree") {
            self.hw.comparator_tree = false;
        }
        if args.flag("no-validate") {
            self.validate_sources = 0;
        }
        self.validate_tolerance =
            args.get_f64("validate-tolerance", self.validate_tolerance as f64) as f32;
        self.batch_size = args.get_usize("batch-size", self.batch_size);
        self.num_stacks = args.get_usize("stacks", self.num_stacks);
        self.admission_queue_depth = args.get_usize("admit-queue", self.admission_queue_depth);
        self.admission_interval = args.get_f64("admit-interval", self.admission_interval);
        if let Some(list) = args.get("arrivals") {
            match parse_arrivals(list) {
                Some(v) => self.admission_arrivals = v,
                None => panic!("--arrivals expects comma-separated numbers, got {list:?}"),
            }
        }
        // --store-capacity both sizes and enables the result store
        if args.get("store-capacity").is_some() {
            self.store_enabled = true;
            self.store_capacity = args.get_usize("store-capacity", self.store_capacity);
        }
        if args.flag("delta-no-validate") {
            self.delta_validate = false;
        }
        if args.flag("delta-no-skip") {
            self.delta_skip = false;
        }
        self.serve_panel_rows = args.get_usize("serve-panel", self.serve_panel_rows);
        self.serve_slo_ms = args.get_f64("serve-slo", self.serve_slo_ms);
        self.serve_readers = args.get_usize("serve-readers", self.serve_readers);
        if args.flag("serve-no-validate") {
            self.serve_validate = false;
        }
    }

    pub fn plan_options(&self) -> crate::apsp::plan::PlanOptions {
        crate::apsp::plan::PlanOptions {
            tile_limit: self.tile_limit,
            max_depth: self.max_depth,
            seed: self.seed,
        }
    }

    /// The arrival schedule for an `n`-graph admission workload:
    /// the explicit `run.admission.arrivals` list when given, else
    /// uniform `admission_interval` spacing starting at t = 0.
    pub fn admission_schedule(&self, n: usize) -> Vec<f64> {
        if self.admission_arrivals.is_empty() {
            (0..n).map(|i| i as f64 * self.admission_interval).collect()
        } else {
            self.admission_arrivals.clone()
        }
    }
}

/// Parse a comma-separated arrival schedule (`"0,1e-3,2e-3"`); `None`
/// on any malformed entry.
pub fn parse_arrivals(s: &str) -> Option<Vec<f64>> {
    let mut out = Vec::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        out.push(tok.parse::<f64>().ok()?);
    }
    Some(out)
}

/// Which top-level execution shape the `apsp` CLI selects. The
/// selecting flags are mutually exclusive — combining them is a clean
/// error, never a silent priority pick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CliMode {
    /// One graph, one run.
    Solo,
    /// `--batch` / a bare `--graphs` list: merge N graphs known up
    /// front into one shared-resource schedule.
    Batch,
    /// `--stacks S` (or `run.num_stacks` from the config file): shard
    /// one graph across S modeled stacks.
    Sharded,
    /// `--admit`: submit N graphs to the async admission pipeline on a
    /// modeled arrival schedule.
    Admission,
    /// `--deltas FILE`: solve once, then replay the file's edge-delta
    /// batches through the incremental repair engine.
    Delta,
    /// `--serve` / `--queries FILE`: solve once with next-hop
    /// threading, publish the snapshot, and drain query batches through
    /// the batched executor. Composes with `--deltas FILE`: the delta
    /// script becomes the live mutation feed interleaved between query
    /// batches (snapshot-swapped, readers never block).
    Serve,
}

/// One row of the declarative mode-selection table: probe the CLI for
/// this selector and, when active, return the flag spelling to name in
/// conflict errors. Each probe owns its alias/claiming rules (e.g.
/// `--admit` claims `--graphs`; `--serve` claims `--deltas`), so the
/// resolver below is a pure table walk.
struct ModeSelector {
    mode: CliMode,
    probe: fn(&Args) -> Option<&'static str>,
}

fn admit_selected(a: &Args) -> bool {
    a.flag("admit") || a.get("admit").is_some()
}

fn serve_selected(a: &Args) -> bool {
    a.flag("serve") || a.get("serve").is_some() || a.get("queries").is_some()
}

fn probe_batch(a: &Args) -> Option<&'static str> {
    if a.flag("batch") || a.get("batch").is_some() {
        Some("--batch")
    } else if a.get("graphs").is_some() && !admit_selected(a) {
        // a bare --graphs list keeps its legacy batch meaning unless
        // --admit claims it for the admission workload
        Some("--graphs")
    } else {
        None
    }
}

fn probe_sharded(a: &Args) -> Option<&'static str> {
    a.get("stacks").is_some().then_some("--stacks")
}

fn probe_admit(a: &Args) -> Option<&'static str> {
    admit_selected(a).then_some("--admit")
}

fn probe_delta(a: &Args) -> Option<&'static str> {
    // --deltas composes with --serve (the serve loop's mutation feed);
    // alone it selects the delta replay shape
    (a.get("deltas").is_some() && !serve_selected(a)).then_some("--deltas")
}

fn probe_serve(a: &Args) -> Option<&'static str> {
    if a.flag("serve") || a.get("serve").is_some() {
        Some("--serve")
    } else if a.get("queries").is_some() {
        Some("--queries")
    } else {
        None
    }
}

/// The mode-selection table. Row order fixes the flag order inside
/// conflict error messages ("--batch and --admit select different
/// execution modes; pick one").
const MODE_SELECTORS: [ModeSelector; 5] = [
    ModeSelector { mode: CliMode::Batch, probe: probe_batch },
    ModeSelector { mode: CliMode::Sharded, probe: probe_sharded },
    ModeSelector { mode: CliMode::Admission, probe: probe_admit },
    ModeSelector { mode: CliMode::Delta, probe: probe_delta },
    ModeSelector { mode: CliMode::Serve, probe: probe_serve },
];

/// A non-selector flag that only composes with specific execution
/// shapes: using it under any other resolved mode is a clean error.
struct ComboRule {
    active: fn(&Args) -> bool,
    allowed: &'static [CliMode],
    msg: &'static str,
}

const COMBO_RULES: [ComboRule; 1] = [ComboRule {
    active: |a| a.get("store-capacity").is_some(),
    allowed: &[CliMode::Admission, CliMode::Delta],
    msg: "--store-capacity applies to the admission pipeline or the delta engine; \
          combine it with --admit or --deltas",
}];

/// Resolve the `apsp` execution mode from the CLI flags by walking the
/// declarative [`MODE_SELECTORS`] table: at most one selector may be
/// active (conflicts are a clean error naming every flag involved,
/// never a silent priority pick), and [`COMBO_RULES`] then vets the
/// non-selector flags against the resolved shape. `config_stacks` is
/// the config-file `run.num_stacks`, which selects sharded mode only
/// when no explicit flag overrides it.
pub fn resolve_cli_mode(args: &Args, config_stacks: usize) -> Result<CliMode> {
    let picked: Vec<(&'static str, CliMode)> = MODE_SELECTORS
        .iter()
        .filter_map(|s| (s.probe)(args).map(|flag| (flag, s.mode)))
        .collect();
    crate::ensure!(
        picked.len() <= 1,
        "{} select different execution modes; pick one",
        picked.iter().map(|&(f, _)| f).collect::<Vec<_>>().join(" and ")
    );
    let mode = match picked.first() {
        Some(&(_, m)) => m,
        None if config_stacks != 1 => CliMode::Sharded,
        None => CliMode::Solo,
    };
    for rule in &COMBO_RULES {
        crate::ensure!(!(rule.active)(args) || rule.allowed.contains(&mode), "{}", rule.msg);
    }
    Ok(mode)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_config() {
        let c = SystemConfig::default();
        assert_eq!(c.tile_limit, 1024);
        assert_eq!(c.mode, Mode::Functional);
        assert_eq!(c.backend, BackendKind::Native);
        assert_eq!(c.scheduler, SchedulerKind::Dag);
        assert!(c.hw.prefetch);
        assert_eq!(c.validate_tolerance, 1e-3);
        assert_eq!(c.batch_size, 4);
        assert_eq!(c.num_stacks, 1);
    }

    #[test]
    fn stacks_knob_parses_and_overrides() {
        let cf = ConfigFile::parse("[run]\nnum_stacks = 4").unwrap();
        let mut c = SystemConfig::from_file(&cf);
        assert_eq!(c.num_stacks, 4);
        let args = crate::util::cli::Args::parse(
            ["--stacks", "8"].iter().map(|s| s.to_string()),
        );
        c.apply_args(&args);
        assert_eq!(c.num_stacks, 8);
        // 0 parses (the executor rejects it with a clean error)
        let args = crate::util::cli::Args::parse(
            ["--stacks", "0"].iter().map(|s| s.to_string()),
        );
        c.apply_args(&args);
        assert_eq!(c.num_stacks, 0);
    }

    #[test]
    fn batch_and_tolerance_knobs() {
        let cf = ConfigFile::parse(
            "[run]\nbatch_size = 8\nvalidate_tolerance = 0.01",
        )
        .unwrap();
        let mut c = SystemConfig::from_file(&cf);
        assert_eq!(c.batch_size, 8);
        assert!((c.validate_tolerance - 0.01).abs() < 1e-9);
        let args = crate::util::cli::Args::parse(
            ["--batch-size", "3", "--validate-tolerance", "0.5"]
                .iter()
                .map(|s| s.to_string()),
        );
        c.apply_args(&args);
        assert_eq!(c.batch_size, 3);
        assert!((c.validate_tolerance - 0.5).abs() < 1e-9);
    }

    #[test]
    fn admission_block_parses_and_overrides() {
        let cf = ConfigFile::parse(
            "[run.admission]\nqueue_depth = 2\ninterval = 0.25\narrivals = \"0,1e-3,2e-3\"",
        )
        .unwrap();
        let mut c = SystemConfig::from_file(&cf);
        assert_eq!(c.admission_queue_depth, 2);
        assert!((c.admission_interval - 0.25).abs() < 1e-12);
        assert_eq!(c.admission_arrivals, vec![0.0, 1e-3, 2e-3]);
        assert_eq!(c.admission_schedule(3), vec![0.0, 1e-3, 2e-3]);
        let args = crate::util::cli::Args::parse(
            ["--admit-queue", "8", "--arrivals", "0,0.5", "--admit-interval", "1.0"]
                .iter()
                .map(|s| s.to_string()),
        );
        c.apply_args(&args);
        assert_eq!(c.admission_queue_depth, 8);
        assert_eq!(c.admission_arrivals, vec![0.0, 0.5]);
        // uniform fallback when no explicit list is configured
        c.admission_arrivals.clear();
        assert_eq!(c.admission_schedule(3), vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn store_block_parses_and_cli_enables() {
        let c = SystemConfig::default();
        assert!(!c.store_enabled, "store is opt-in");
        assert_eq!(c.store_capacity, 8);
        assert_eq!(c.store_bytes, 1 << 32);
        assert!(c.store_compression);
        let cf = ConfigFile::parse(
            "[run.store]\nenabled = true\ncapacity = 3\nbytes = 4096\ncompression = false",
        )
        .unwrap();
        let mut c = SystemConfig::from_file(&cf);
        assert!(c.store_enabled);
        assert_eq!(c.store_capacity, 3);
        assert_eq!(c.store_bytes, 4096);
        assert!(!c.store_compression);
        // --store-capacity both sizes and enables the store
        let args = crate::util::cli::Args::parse(
            ["--store-capacity", "5"].iter().map(|s| s.to_string()),
        );
        c.apply_args(&args);
        assert_eq!(c.store_capacity, 5);
        let mut d = SystemConfig::default();
        d.apply_args(&args);
        assert!(d.store_enabled);
        assert_eq!(d.store_capacity, 5);
    }

    #[test]
    fn store_capacity_flag_requires_admission_mode() {
        let parse = |v: &[&str]| crate::util::cli::Args::parse(v.iter().map(|s| s.to_string()));
        assert_eq!(
            resolve_cli_mode(&parse(&["--admit", "--store-capacity", "4"]), 1).unwrap(),
            CliMode::Admission
        );
        // non-admission shapes reject it (full combos in
        // tests/failure_injection.rs)
        let err = resolve_cli_mode(&parse(&["--store-capacity", "4"]), 1).unwrap_err();
        assert!(format!("{err}").contains("--admit"), "{err}");
    }

    #[test]
    fn delta_block_parses_and_cli_selects_mode() {
        let c = SystemConfig::default();
        assert!(c.delta_validate && c.delta_skip);
        let cf = ConfigFile::parse("[run.delta]\nvalidate = false\nskip = false").unwrap();
        let mut c = SystemConfig::from_file(&cf);
        assert!(!c.delta_validate && !c.delta_skip);
        let parse = |v: &[&str]| crate::util::cli::Args::parse(v.iter().map(|s| s.to_string()));
        c = SystemConfig::default();
        c.apply_args(&parse(&["--delta-no-validate", "--delta-no-skip"]));
        assert!(!c.delta_validate && !c.delta_skip);
        // --deltas selects the delta execution shape
        assert_eq!(
            resolve_cli_mode(&parse(&["--deltas", "d.txt"]), 1).unwrap(),
            CliMode::Delta
        );
        // ... and conflicts with the other mode selectors
        let err = resolve_cli_mode(&parse(&["--deltas", "d.txt", "--admit"]), 1).unwrap_err();
        assert!(format!("{err}").contains("pick one"), "{err}");
        // the store flag composes with the delta engine (write-back)
        assert_eq!(
            resolve_cli_mode(&parse(&["--deltas", "d.txt", "--store-capacity", "4"]), 1).unwrap(),
            CliMode::Delta
        );
    }

    #[test]
    fn serve_block_parses_and_cli_selects_mode() {
        let c = SystemConfig::default();
        assert_eq!(c.serve_panel_rows, 8);
        assert!((c.serve_slo_ms - 1.0).abs() < 1e-12);
        assert_eq!(c.serve_readers, 4);
        assert!(c.serve_validate);
        let cf = ConfigFile::parse(
            "[run.serve]\npanel_rows = 16\nslo_ms = 0.5\nreaders = 2\nvalidate = false",
        )
        .unwrap();
        let mut c = SystemConfig::from_file(&cf);
        assert_eq!(c.serve_panel_rows, 16);
        assert!((c.serve_slo_ms - 0.5).abs() < 1e-12);
        assert_eq!(c.serve_readers, 2);
        assert!(!c.serve_validate);
        let parse = |v: &[&str]| crate::util::cli::Args::parse(v.iter().map(|s| s.to_string()));
        c.apply_args(&parse(&["--serve-panel", "4", "--serve-slo", "2.0", "--serve-readers", "8"]));
        assert_eq!(c.serve_panel_rows, 4);
        assert!((c.serve_slo_ms - 2.0).abs() < 1e-12);
        assert_eq!(c.serve_readers, 8);
        // --serve / --queries select the serve execution shape ...
        assert_eq!(resolve_cli_mode(&parse(&["--serve"]), 1).unwrap(), CliMode::Serve);
        assert_eq!(
            resolve_cli_mode(&parse(&["--queries", "q.txt"]), 1).unwrap(),
            CliMode::Serve
        );
        // ... compose with --deltas (the serve loop's mutation feed) ...
        assert_eq!(
            resolve_cli_mode(&parse(&["--serve", "--deltas", "d.txt"]), 1).unwrap(),
            CliMode::Serve
        );
        // ... and conflict with the other mode selectors (full combos
        // in tests/failure_injection.rs)
        let err = resolve_cli_mode(&parse(&["--serve", "--admit"]), 1).unwrap_err();
        assert!(format!("{err}").contains("pick one"), "{err}");
    }

    #[test]
    fn arrivals_parser_accepts_lists_rejects_garbage() {
        assert_eq!(parse_arrivals("0, 1e-3 ,2e-3"), Some(vec![0.0, 1e-3, 2e-3]));
        assert_eq!(parse_arrivals(""), Some(vec![]));
        assert_eq!(parse_arrivals("1,two,3"), None);
    }

    #[test]
    #[should_panic(expected = "run.admission.arrivals")]
    fn malformed_config_arrival_list_is_a_hard_error() {
        // silently falling back to the uniform-interval schedule would
        // report latencies for arrivals the user never configured
        let cf = ConfigFile::parse("[run.admission]\narrivals = \"0;1e-3;2e-3\"").unwrap();
        let _ = SystemConfig::from_file(&cf);
    }

    // mode-flag conflict combos live in tests/failure_injection.rs
    // (the satellite's named home); this covers only the resolution
    // rules that aren't conflicts
    #[test]
    fn cli_mode_resolution_rules() {
        let parse = |v: &[&str]| crate::util::cli::Args::parse(v.iter().map(|s| s.to_string()));
        // a bare --graphs list keeps its legacy batch meaning
        assert_eq!(
            resolve_cli_mode(&parse(&["--graphs", "a.bin,b.bin"]), 1).unwrap(),
            CliMode::Batch
        );
        // --admit claims --graphs for the admission workload
        assert_eq!(
            resolve_cli_mode(&parse(&["--admit", "--graphs", "a.bin"]), 1).unwrap(),
            CliMode::Admission
        );
        // a config-file run.num_stacks selects sharded mode only when
        // no explicit flag overrides it
        assert_eq!(resolve_cli_mode(&parse(&[]), 4).unwrap(), CliMode::Sharded);
        assert_eq!(resolve_cli_mode(&parse(&["--batch"]), 4).unwrap(), CliMode::Batch);
        assert_eq!(resolve_cli_mode(&parse(&["--admit", "6"]), 4).unwrap(), CliMode::Admission);
    }

    #[test]
    fn scheduler_knob_parses_and_overrides() {
        assert_eq!(SchedulerKind::parse("DAG"), Some(SchedulerKind::Dag));
        assert_eq!(SchedulerKind::parse("barrier"), Some(SchedulerKind::Barrier));
        assert_eq!(SchedulerKind::parse("step"), Some(SchedulerKind::Barrier));
        assert_eq!(SchedulerKind::parse("??"), None);
        let cf = ConfigFile::parse("[run]\nscheduler = \"barrier\"").unwrap();
        let mut c = SystemConfig::from_file(&cf);
        assert_eq!(c.scheduler, SchedulerKind::Barrier);
        let args = crate::util::cli::Args::parse(
            ["--scheduler", "dag"].iter().map(|s| s.to_string()),
        );
        c.apply_args(&args);
        assert_eq!(c.scheduler, SchedulerKind::Dag);
    }

    #[test]
    fn file_overrides() {
        let cf = ConfigFile::parse(
            "[algo]\ntile_limit = 256\nmax_depth = 1\n[run]\nmode = \"estimate\"\n\
             [hardware]\ntiles_per_die = 60\nprefetch = false",
        )
        .unwrap();
        let c = SystemConfig::from_file(&cf);
        assert_eq!(c.tile_limit, 256);
        assert_eq!(c.max_depth, 1);
        assert_eq!(c.mode, Mode::Estimate);
        assert_eq!(c.hw.tiles_per_die, 60);
        assert!(!c.hw.prefetch);
    }

    #[test]
    fn cli_overrides_win() {
        let cf = ConfigFile::parse("[algo]\ntile_limit = 256").unwrap();
        let mut c = SystemConfig::from_file(&cf);
        let args = crate::util::cli::Args::parse(
            ["--tile", "128", "--mode", "estimate", "--no-prefetch"]
                .iter()
                .map(|s| s.to_string()),
        );
        c.apply_args(&args);
        assert_eq!(c.tile_limit, 128);
        assert_eq!(c.mode, Mode::Estimate);
        assert!(!c.hw.prefetch);
    }

    #[test]
    fn workload_knob_parses_and_overrides() {
        let c = SystemConfig::default();
        assert_eq!(c.workload, Workload::Apsp);
        assert_eq!(c.workload.semiring(), SemiringId::MinPlus);
        for (spelling, want) in [
            ("apsp", Workload::Apsp),
            ("REACH", Workload::Reach),
            ("bottleneck", Workload::Widest),
            ("longest", Workload::Critical),
        ] {
            assert_eq!(Workload::parse(spelling), Some(want));
        }
        assert_eq!(Workload::parse("??"), None);
        let cf = ConfigFile::parse("[run]\nworkload = \"widest\"").unwrap();
        let mut c = SystemConfig::from_file(&cf);
        assert_eq!(c.workload, Workload::Widest);
        assert_eq!(c.workload.semiring(), SemiringId::MaxMin);
        let args = crate::util::cli::Args::parse(
            ["--workload", "critical"].iter().map(|s| s.to_string()),
        );
        c.apply_args(&args);
        assert_eq!(c.workload, Workload::Critical);
        assert_eq!(c.workload.name(), "critical");
    }

    #[test]
    #[should_panic(expected = "--workload expects")]
    fn unknown_workload_is_a_hard_error() {
        let args = crate::util::cli::Args::parse(
            ["--workload", "speling"].iter().map(|s| s.to_string()),
        );
        SystemConfig::default().apply_args(&args);
    }

    #[test]
    fn mode_backend_parsing() {
        assert_eq!(Mode::parse("FUNCTIONAL"), Some(Mode::Functional));
        assert_eq!(Mode::parse("est"), Some(Mode::Estimate));
        assert_eq!(Mode::parse("x"), None);
        assert_eq!(BackendKind::parse("pjrt"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::parse("native"), Some(BackendKind::Native));
    }
}
