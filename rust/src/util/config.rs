//! Minimal TOML-subset parser (substitute for `serde` + `toml`).
//!
//! Supports what run configs need: `[section]` headers, `key = value`
//! with string / integer / float / boolean values, `#` comments.

use std::collections::BTreeMap;

/// Parsed config: `section.key -> raw value string`. Keys outside a
/// section live under the empty section `""`.
#[derive(Debug, Default, Clone)]
pub struct ConfigFile {
    values: BTreeMap<String, String>,
}

/// Error raised on malformed config text.
#[derive(Debug)]
pub struct ConfigError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ConfigError {}

impl ConfigFile {
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let line = raw.trim();
            let line = match line.find('#') {
                // allow '#' inside quoted strings
                Some(pos) if !in_string(line, pos) => line[..pos].trim(),
                _ => line,
            };
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or(ConfigError {
                line: idx + 1,
                msg: format!("expected `key = value`, got {line:?}"),
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let mut val = v.trim().to_string();
            if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
                val = val[1..val.len() - 1].to_string();
            }
            values.insert(key, val);
        }
        Ok(Self { values })
    }

    pub fn load(path: &str) -> crate::util::error::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::parse(&text)?)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.replace('_', "").parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.get(key)
            .map(|v| matches!(v, "true" | "1" | "yes" | "on"))
            .unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn in_string(line: &str, pos: usize) -> bool {
    line[..pos].bytes().filter(|&b| b == b'"').count() % 2 == 1
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# RAPID-Graph run config
mode = "functional"

[hardware]
fw_tiles = 64          # tiles on the PCM-FW die
clock_ghz = 0.5
prefetch = true

[algo]
tile_limit = 1024
balance = 1.05
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = ConfigFile::parse(SAMPLE).unwrap();
        assert_eq!(c.get_str("mode", ""), "functional");
        assert_eq!(c.get_usize("hardware.fw_tiles", 0), 64);
        assert_eq!(c.get_f64("hardware.clock_ghz", 0.0), 0.5);
        assert!(c.get_bool("hardware.prefetch", false));
        assert_eq!(c.get_usize("algo.tile_limit", 0), 1024);
        assert_eq!(c.get_f64("algo.balance", 0.0), 1.05);
    }

    #[test]
    fn defaults_on_missing() {
        let c = ConfigFile::parse("").unwrap();
        assert_eq!(c.get_usize("absent", 7), 7);
        assert!(!c.get_bool("absent", false));
    }

    #[test]
    fn error_on_garbage() {
        let e = ConfigFile::parse("not a kv line").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn hash_inside_string_kept() {
        let c = ConfigFile::parse("name = \"a#b\"").unwrap();
        assert_eq!(c.get_str("name", ""), "a#b");
    }

    #[test]
    fn keys_are_iterable() {
        let c = ConfigFile::parse("[s]\na = 1\nb = 2").unwrap();
        let keys: Vec<_> = c.keys().collect();
        assert_eq!(keys, vec!["s.a", "s.b"]);
    }
}
