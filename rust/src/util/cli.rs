//! Tiny declarative command-line parser (substitute for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! and auto-generated `--help`.

use std::collections::BTreeMap;

/// A parsed argument set.
#[derive(Debug, Default, Clone)]
pub struct Args {
    named: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.named.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|nx| !nx.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.named.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process args.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.named.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| {
                v.replace('_', "").parse().unwrap_or_else(|_| {
                    panic!("--{name} expects an integer, got {v:?}")
                })
            })
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| {
                v.replace('_', "").parse().unwrap_or_else(|_| {
                    panic!("--{name} expects an integer, got {v:?}")
                })
            })
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}"))
            })
            .unwrap_or(default)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional argument (the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

/// Render a help screen from `(option, description)` rows.
pub fn render_help(prog: &str, about: &str, options: &[(&str, &str)]) -> String {
    let mut s = format!("{prog} — {about}\n\nOPTIONS:\n");
    let width = options.iter().map(|(o, _)| o.len()).max().unwrap_or(0);
    for (o, d) in options {
        s.push_str(&format!("  {o:<width$}  {d}\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_pairs() {
        let a = parse(&["--nodes", "1024", "--degree=25.25"]);
        assert_eq!(a.get_usize("nodes", 0), 1024);
        assert_eq!(a.get_f64("degree", 0.0), 25.25);
    }

    #[test]
    fn flags_and_positional() {
        let a = parse(&["apsp", "--verbose", "--seed", "7", "extra"]);
        assert_eq!(a.subcommand(), Some("apsp"));
        assert!(a.flag("verbose"));
        assert_eq!(a.get_u64("seed", 0), 7);
        assert_eq!(a.positional(), &["apsp".to_string(), "extra".to_string()]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--fast"]);
        assert!(a.flag("fast"));
        assert_eq!(a.get("fast"), None);
    }

    #[test]
    fn underscore_numbers() {
        let a = parse(&["--n", "2_449_029"]);
        assert_eq!(a.get_usize("n", 0), 2_449_029);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("mode", "functional"), "functional");
        assert_eq!(a.get_usize("k", 17), 17);
        assert!(!a.flag("x"));
    }

    #[test]
    fn help_renders() {
        let h = render_help("prog", "does x", &[("--a", "alpha"), ("--bb", "beta")]);
        assert!(h.contains("--a "));
        assert!(h.contains("beta"));
    }
}
