//! Minimal data-parallel helpers over `std::thread::scope` (substitute for
//! rayon/tokio — the coordinator is compute-bound, so scoped OS threads
//! with chunked work-stealing-free partitioning are sufficient and keep
//! the hot loop allocation-free).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use: `RAPID_THREADS` env var, else the
/// available parallelism, clamped to [1, 64].
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("RAPID_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.clamp(1, 64);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 64)
}

/// Run `f(i)` for every `i in 0..n`, dynamically load-balanced across
/// `num_threads()` workers. `f` must be `Sync` (called concurrently).
pub fn par_for<F: Fn(usize) + Sync>(n: usize, f: F) {
    par_for_with(num_threads(), n, f)
}

/// `par_for` with an explicit worker count.
pub fn par_for_with<F: Fn(usize) + Sync>(workers: usize, n: usize, f: F) {
    if n == 0 {
        return;
    }
    let workers = workers.min(n).max(1);
    if workers == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Map `f` over `0..n` in parallel, collecting results in index order.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots = SendPtr(out.as_mut_ptr());
        let slots = &slots;
        par_for(n, |i| {
            // SAFETY: each index is written by exactly one worker.
            unsafe { slots.write(i, Some(f(i))) };
        });
    }
    out.into_iter().map(|x| x.unwrap()).collect()
}

/// Wrapper to smuggle a raw pointer into a `Sync` closure; callers must
/// guarantee disjoint index access.
struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// SAFETY: each index must be written by exactly one thread, and the
    /// pointer must stay valid for the duration of the parallel region.
    unsafe fn write(&self, i: usize, val: T) {
        *self.0.add(i) = val;
    }
}

/// Max tasks a worker drains from a ready queue per lock acquisition
/// (see [`batch_extra`]).
const DEQUEUE_BATCH: usize = 8;

/// How many tasks a worker takes *beyond* the first on one lock
/// acquisition. Batching only kicks in when the ready queue holds a
/// surplus relative to the worker count (`ready_len` is the queue length
/// after the first pop) — when work is scarce every worker still gets
/// exactly one task, so fan-out, injection wake-ups, and bounded-wait
/// behavior are identical to the unbatched executor; when work is
/// plentiful a worker pays one mutex round-trip for up to
/// [`DEQUEUE_BATCH`] tasks instead of one per task.
#[inline]
fn batch_extra(ready_len: usize, workers: usize) -> usize {
    (ready_len / workers.max(1)).min(DEQUEUE_BATCH - 1)
}

/// Shared DAG precompute for [`par_dag`] / [`par_dag_grouped`]:
/// in-degrees and successor adjacency, plus the up-front cycle check (a
/// cheap Kahn sweep) so a cycle panics instead of deadlocking a ready
/// queue.
fn dag_precompute(deps: &[Vec<u32>]) -> (Vec<usize>, Vec<Vec<u32>>) {
    let n = deps.len();
    let indeg: Vec<usize> = deps.iter().map(|d| d.len()).collect();
    let mut succs: Vec<Vec<u32>> = vec![Vec::new(); n];
    for (i, ds) in deps.iter().enumerate() {
        for &d in ds {
            assert!((d as usize) < n, "dep {d} out of range");
            succs[d as usize].push(i as u32);
        }
    }
    let mut count = vec![0usize; n];
    let mut stack: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut seen = 0usize;
    while let Some(i) = stack.pop() {
        seen += 1;
        for &s in &succs[i] {
            let s = s as usize;
            count[s] += 1;
            if count[s] == deps[s].len() {
                stack.push(s);
            }
        }
    }
    assert_eq!(seen, n, "dependency cycle in par_dag");
    (indeg, succs)
}

/// Execute a dependency DAG of `deps.len()` tasks with work-stealing
/// workers: task `i` runs (via `f(i)`) only after every task in
/// `deps[i]` finished; independent ready tasks run concurrently on up to
/// `num_threads()` workers. `deps` must be acyclic — a cycle panics up
/// front (cheap Kahn sweep) instead of deadlocking the ready queue.
///
/// A panic inside `f` aborts the remaining tasks and resurfaces on the
/// caller's thread.
pub fn par_dag<F: Fn(usize) + Sync>(deps: &[Vec<u32>], f: F) {
    let n = deps.len();
    if n == 0 {
        return;
    }
    let (mut indeg, succs) = dag_precompute(deps);
    let workers = num_threads().min(n).max(1);
    if workers == 1 {
        // deterministic serial fallback: repeated ready sweeps
        let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut finished = 0;
        while let Some(i) = ready.pop() {
            f(i);
            finished += 1;
            for &s in &succs[i] {
                indeg[s as usize] -= 1;
                if indeg[s as usize] == 0 {
                    ready.push(s as usize);
                }
            }
        }
        assert_eq!(finished, n, "dependency cycle in par_dag");
        return;
    }

    struct DagState {
        ready: Vec<usize>,
        indeg: Vec<usize>,
        remaining: usize,
        panicked: bool,
    }
    let ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    assert!(!ready.is_empty(), "dependency cycle in par_dag");
    let state = std::sync::Mutex::new(DagState {
        ready,
        indeg,
        remaining: n,
        panicked: false,
    });
    let cv = std::sync::Condvar::new();
    fn complete(g: &mut DagState, succs: &[Vec<u32>], task: usize) {
        g.remaining -= 1;
        for &sx in &succs[task] {
            let sx = sx as usize;
            g.indeg[sx] -= 1;
            if g.indeg[sx] == 0 {
                g.ready.push(sx);
            }
        }
    }
    let succs = &succs;
    let state = &state;
    let cv = &cv;
    let f = &f;
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(move || {
                let mut batch: Vec<usize> = Vec::with_capacity(DEQUEUE_BATCH);
                let mut done: Vec<usize> = Vec::with_capacity(DEQUEUE_BATCH);
                loop {
                    // run the current batch, recording completions locally
                    for bi in 0..batch.len() {
                        let task = batch[bi];
                        let res =
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(task)));
                        done.push(task);
                        if let Err(p) = res {
                            let mut g = state.lock().unwrap();
                            g.panicked = true;
                            for &t in &done {
                                complete(&mut g, succs, t);
                            }
                            drop(g);
                            cv.notify_all();
                            std::panic::resume_unwind(p);
                        }
                    }
                    batch.clear();
                    // one lock acquisition: flush the batch's completions,
                    // then grab the next batch (or park / exit)
                    let mut g = state.lock().unwrap();
                    if !done.is_empty() {
                        for &t in &done {
                            complete(&mut g, succs, t);
                        }
                        done.clear();
                        cv.notify_all();
                    }
                    loop {
                        if g.remaining == 0 || g.panicked {
                            return;
                        }
                        if let Some(t) = g.ready.pop() {
                            batch.push(t);
                            for _ in 0..batch_extra(g.ready.len(), workers) {
                                batch.push(g.ready.pop().unwrap());
                            }
                            break;
                        }
                        g = cv.wait(g).unwrap();
                    }
                }
            });
        }
    });
}

/// [`par_dag`] with per-group worker pools: every task carries a group
/// id (`group_of[i] < n_groups`), each group gets its own ready queue
/// and a dedicated worker subset, and a worker only executes tasks of
/// its own group. This models per-stack host execution for sharded runs
/// — stack-affine tasks never migrate — while dependency edges may
/// cross groups freely. Worker count is `num_threads()` rounded up to
/// at least one worker per group (round-robin assignment).
///
/// Like [`par_dag`], `deps` must be acyclic (checked up front) and a
/// panic in `f` aborts the remaining tasks and resurfaces.
pub fn par_dag_grouped<F: Fn(usize) + Sync>(
    deps: &[Vec<u32>],
    group_of: &[u32],
    n_groups: usize,
    f: F,
) {
    let n = deps.len();
    assert_eq!(group_of.len(), n, "group_of must cover every task");
    assert!(n_groups >= 1);
    if n == 0 {
        return;
    }
    debug_assert!(group_of.iter().all(|&g| (g as usize) < n_groups));
    if num_threads() == 1 || n_groups == 1 {
        // single worker (or single group): plain par_dag semantics
        return par_dag(deps, f);
    }
    let (indeg, succs) = dag_precompute(deps);

    struct GroupState {
        ready: Vec<Vec<usize>>, // per group
        indeg: Vec<usize>,
        remaining: usize,
        panicked: bool,
    }
    let mut ready: Vec<Vec<usize>> = vec![Vec::new(); n_groups];
    for i in (0..n).filter(|&i| indeg[i] == 0) {
        ready[group_of[i] as usize].push(i);
    }
    let state = std::sync::Mutex::new(GroupState {
        ready,
        indeg,
        remaining: n,
        panicked: false,
    });
    let cv = std::sync::Condvar::new();
    // never more workers than tasks, but at least one per group —
    // a workerless group's tasks would never run
    let workers = num_threads().min(n).max(n_groups);
    let complete = |g: &mut GroupState, task: usize| {
        g.remaining -= 1;
        for &sx in &succs[task] {
            let sx = sx as usize;
            g.indeg[sx] -= 1;
            if g.indeg[sx] == 0 {
                g.ready[group_of[sx] as usize].push(sx);
            }
        }
    };
    let complete = &complete;
    let state = &state;
    let cv = &cv;
    let f = &f;
    std::thread::scope(|s| {
        for w in 0..workers {
            let my_group = w % n_groups;
            s.spawn(move || {
                let mut batch: Vec<usize> = Vec::with_capacity(DEQUEUE_BATCH);
                let mut done: Vec<usize> = Vec::with_capacity(DEQUEUE_BATCH);
                loop {
                    for bi in 0..batch.len() {
                        let task = batch[bi];
                        let res =
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(task)));
                        done.push(task);
                        if let Err(p) = res {
                            let mut g = state.lock().unwrap();
                            g.panicked = true;
                            for &t in &done {
                                complete(&mut g, t);
                            }
                            drop(g);
                            cv.notify_all();
                            std::panic::resume_unwind(p);
                        }
                    }
                    batch.clear();
                    let mut g = state.lock().unwrap();
                    if !done.is_empty() {
                        for &t in &done {
                            complete(&mut g, t);
                        }
                        done.clear();
                        cv.notify_all();
                    }
                    loop {
                        if g.remaining == 0 || g.panicked {
                            return;
                        }
                        if let Some(t) = g.ready[my_group].pop() {
                            batch.push(t);
                            // batch against *this group's* surplus and
                            // worker share, not the global queue
                            let group_workers = workers.div_ceil(n_groups);
                            for _ in 0..batch_extra(g.ready[my_group].len(), group_workers) {
                                batch.push(g.ready[my_group].pop().unwrap());
                            }
                            break;
                        }
                        g = cv.wait(g).unwrap();
                    }
                }
            });
        }
    });
}

/// Growable-DAG state shared by a [`dag_pool_scope`] pool: tasks are
/// appended by [`DagPool::inject`] while the workers run (or park), so
/// the dependency bookkeeping lives behind one mutex instead of the
/// fixed-size precompute [`par_dag`] uses.
struct InjectState {
    /// Unmet-dependency count per task (grows on inject).
    deps_left: Vec<usize>,
    /// Successor adjacency (grows on inject; drained as tasks finish).
    succs: Vec<Vec<u32>>,
    /// Completion flag per task. Late injections may depend on tasks
    /// that already finished — those edges are satisfied immediately.
    finished: Vec<bool>,
    ready: Vec<usize>,
    n_done: usize,
    closed: bool,
    panicked: bool,
}

/// Injection handle of a live [`dag_pool_scope`] pool.
pub struct DagPool<'a> {
    state: &'a std::sync::Mutex<InjectState>,
    cv: &'a std::sync::Condvar,
}

impl DagPool<'_> {
    /// Splice `deps.len()` new tasks into the live schedule. `deps[i]`
    /// holds *global* task ids and must point to already-injected
    /// tasks; an edge to a task that finished before this call is
    /// satisfied immediately (injecting into an almost-drained — or
    /// fully parked — pool is the normal case). Returns the global id
    /// range assigned to the new tasks. Ready tasks become eligible at
    /// once and parked workers are woken; nothing already running is
    /// disturbed.
    pub fn inject(&self, deps: &[Vec<u32>]) -> std::ops::Range<usize> {
        let mut g = self.state.lock().unwrap();
        assert!(!g.closed, "inject into a closed pool");
        let base = g.finished.len();
        for (i, ds) in deps.iter().enumerate() {
            let id = base + i;
            g.finished.push(false);
            g.succs.push(Vec::new());
            let mut left = 0usize;
            for &d in ds {
                let d = d as usize;
                assert!(d < id, "task {id} depends on non-earlier task {d}");
                if !g.finished[d] {
                    left += 1;
                    g.succs[d].push(id as u32);
                }
            }
            g.deps_left.push(left);
            if left == 0 {
                g.ready.push(id);
            }
        }
        drop(g);
        self.cv.notify_all();
        base..base + deps.len()
    }

    /// Tasks finished so far.
    pub fn n_done(&self) -> usize {
        self.state.lock().unwrap().n_done
    }

    /// Block until `pred(n_done)` holds, re-checking after every task
    /// completion. Returns early (predicate unmet) only if a worker
    /// panicked — that panic resurfaces when the scope joins.
    pub fn wait(&self, mut pred: impl FnMut(usize) -> bool) {
        let mut g = self.state.lock().unwrap();
        while !g.panicked && !pred(g.n_done) {
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// Run a long-lived worker pool over a *growable* dependency DAG: the
/// workers execute injected tasks (via `f(global_id)`) respecting their
/// dependencies, while `body` — on the caller's thread — splices new
/// work into the live schedule through [`DagPool::inject`] at any time,
/// including while every worker is parked on an empty queue. When
/// `body` returns, the pool drains the remaining tasks and joins.
///
/// This is the substrate of the admission pipeline: a running schedule
/// accepts newly lowered task graphs without a barrier or a drain.
/// Like [`par_dag`], a panic in `f` (or in `body`) abandons the queued
/// tasks and resurfaces on the caller's thread.
pub fn dag_pool_scope<R, F: Fn(usize) + Sync>(
    workers: usize,
    f: F,
    body: impl FnOnce(&DagPool<'_>) -> R,
) -> R {
    let workers = workers.max(1);
    let state = std::sync::Mutex::new(InjectState {
        deps_left: Vec::new(),
        succs: Vec::new(),
        finished: Vec::new(),
        ready: Vec::new(),
        n_done: 0,
        closed: false,
        panicked: false,
    });
    let cv = std::sync::Condvar::new();
    fn complete(g: &mut InjectState, task: usize) {
        g.finished[task] = true;
        g.n_done += 1;
        let succs = std::mem::take(&mut g.succs[task]);
        for &sx in &succs {
            let sx = sx as usize;
            g.deps_left[sx] -= 1;
            if g.deps_left[sx] == 0 {
                g.ready.push(sx);
            }
        }
    }
    let state = &state;
    let cv = &cv;
    let f = &f;
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(move || {
                let mut batch: Vec<usize> = Vec::with_capacity(DEQUEUE_BATCH);
                let mut done: Vec<usize> = Vec::with_capacity(DEQUEUE_BATCH);
                loop {
                    for bi in 0..batch.len() {
                        let task = batch[bi];
                        let res =
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(task)));
                        done.push(task);
                        if let Err(p) = res {
                            let mut g = state.lock().unwrap();
                            g.panicked = true;
                            for &t in &done {
                                complete(&mut g, t);
                            }
                            drop(g);
                            cv.notify_all();
                            std::panic::resume_unwind(p);
                        }
                    }
                    batch.clear();
                    // one lock acquisition: flush completions (waking
                    // `DagPool::wait` watchers and parked peers), then
                    // grab the next batch. Injection stays correct: a
                    // worker only holds tasks that were already ready,
                    // and every flush re-notifies, so spliced-in tasks
                    // whose deps completed inside a batch become ready
                    // at flush time exactly as they did per-task.
                    let mut g = state.lock().unwrap();
                    if !done.is_empty() {
                        for &t in &done {
                            complete(&mut g, t);
                        }
                        done.clear();
                        cv.notify_all();
                    }
                    loop {
                        if g.panicked || (g.closed && g.n_done == g.finished.len()) {
                            return;
                        }
                        if let Some(t) = g.ready.pop() {
                            batch.push(t);
                            for _ in 0..batch_extra(g.ready.len(), workers) {
                                batch.push(g.ready.pop().unwrap());
                            }
                            break;
                        }
                        g = cv.wait(g).unwrap();
                    }
                }
            });
        }
        let pool = DagPool { state, cv };
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&pool)));
        {
            let mut g = state.lock().unwrap();
            g.closed = true;
            if out.is_err() {
                g.panicked = true;
            }
        }
        cv.notify_all();
        match out {
            Ok(v) => v,
            Err(p) => std::panic::resume_unwind(p),
        }
    })
}

/// Process disjoint mutable row-chunks of a flat `data` buffer in parallel:
/// `f(chunk_index, chunk)` where `chunk` is `rows_per_chunk * row_len`
/// elements (last chunk may be shorter).
pub fn par_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    data: &mut [T],
    chunk_len: usize,
    f: F,
) {
    assert!(chunk_len > 0);
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk_len).enumerate().collect();
    let n = chunks.len();
    let slots = std::sync::Mutex::new(chunks);
    // Pull chunks off a shared list; order does not matter.
    par_for(n, |_| {
        let item = slots.lock().unwrap().pop();
        if let Some((idx, chunk)) = item {
            f(idx, chunk);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_for_visits_all_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        par_for(1000, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_for_zero_items() {
        par_for(0, |_| panic!("must not be called"));
    }

    #[test]
    fn par_map_ordered() {
        let v = par_map(100, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn par_chunks_mut_disjoint() {
        let mut data = vec![0u32; 1003];
        par_chunks_mut(&mut data, 100, |idx, chunk| {
            for x in chunk.iter_mut() {
                *x = idx as u32 + 1;
            }
        });
        assert!(data.iter().all(|&x| x >= 1));
        assert_eq!(data[0], 1);
        assert_eq!(data[1002], 11);
    }

    #[test]
    fn num_threads_positive() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn par_dag_respects_dependencies() {
        // chain 0 -> 1 -> 2 plus a diamond 3 -> {4, 5} -> 6
        let deps: Vec<Vec<u32>> = vec![
            vec![],
            vec![0],
            vec![1],
            vec![],
            vec![3],
            vec![3],
            vec![4, 5],
        ];
        let order = std::sync::Mutex::new(Vec::new());
        par_dag(&deps, |i| {
            order.lock().unwrap().push(i);
        });
        let order = order.into_inner().unwrap();
        assert_eq!(order.len(), 7);
        let pos = |x: usize| order.iter().position(|&v| v == x).unwrap();
        assert!(pos(0) < pos(1) && pos(1) < pos(2));
        assert!(pos(3) < pos(4) && pos(3) < pos(5));
        assert!(pos(4) < pos(6) && pos(5) < pos(6));
    }

    #[test]
    fn par_dag_runs_every_task_once() {
        // layered random-ish DAG: task i depends on i - 1 and i / 2
        let n = 500;
        let deps: Vec<Vec<u32>> = (0..n)
            .map(|i| {
                let mut d = Vec::new();
                if i > 0 {
                    d.push((i - 1) as u32 / 2);
                }
                if i >= 10 {
                    d.push((i - 7) as u32);
                }
                d
            })
            .collect();
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        par_dag(&deps, |i| {
            // all deps must have completed
            for &d in &deps[i] {
                assert_eq!(hits[d as usize].load(Ordering::SeqCst), 1);
            }
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn par_dag_empty() {
        par_dag(&[], |_| panic!("must not run"));
    }

    #[test]
    fn par_dag_grouped_respects_deps_and_groups() {
        // cross-group diamond: group 0 feeds group 1 and back
        let deps: Vec<Vec<u32>> = vec![vec![], vec![0], vec![0], vec![1, 2], vec![3]];
        let groups = vec![0u32, 1, 0, 1, 0];
        let order = std::sync::Mutex::new(Vec::new());
        par_dag_grouped(&deps, &groups, 2, |i| {
            order.lock().unwrap().push(i);
        });
        let order = order.into_inner().unwrap();
        assert_eq!(order.len(), 5);
        let pos = |x: usize| order.iter().position(|&v| v == x).unwrap();
        assert!(pos(0) < pos(1) && pos(0) < pos(2));
        assert!(pos(1) < pos(3) && pos(2) < pos(3) && pos(3) < pos(4));
    }

    #[test]
    fn par_dag_grouped_runs_every_task_once() {
        let n = 400;
        let deps: Vec<Vec<u32>> = (0..n)
            .map(|i| if i > 0 { vec![(i - 1) as u32 / 2] } else { vec![] })
            .collect();
        let groups: Vec<u32> = (0..n).map(|i| (i % 3) as u32).collect();
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        par_dag_grouped(&deps, &groups, 3, |i| {
            for &d in &deps[i] {
                assert_eq!(hits[d as usize].load(Ordering::SeqCst), 1);
            }
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn par_dag_grouped_propagates_panics() {
        let deps: Vec<Vec<u32>> = (0..32).map(|_| Vec::new()).collect();
        let groups: Vec<u32> = (0..32).map(|i| (i % 4) as u32).collect();
        let res = std::panic::catch_unwind(|| {
            par_dag_grouped(&deps, &groups, 4, |i| {
                if i == 7 {
                    panic!("boom");
                }
            });
        });
        assert!(res.is_err());
    }

    #[test]
    fn dag_pool_injects_into_drained_pool() {
        // the admission pipeline's key motion: a second DAG spliced in
        // after the first fully drained (every worker parked), with
        // dependencies on already-finished tasks
        let hits: Vec<AtomicU64> = (0..6).map(|_| AtomicU64::new(0)).collect();
        let order = std::sync::Mutex::new(Vec::new());
        dag_pool_scope(
            4,
            |i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
                order.lock().unwrap().push(i);
            },
            |pool| {
                let r = pool.inject(&[vec![], vec![0], vec![1]]);
                assert_eq!(r, 0..3);
                pool.wait(|done| done == 3);
                assert_eq!(pool.n_done(), 3);
                let r = pool.inject(&[vec![2], vec![0, 3], vec![4]]);
                assert_eq!(r, 3..6);
            },
        );
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        let order = order.into_inner().unwrap();
        let pos = |x: usize| order.iter().position(|&v| v == x).unwrap();
        assert!(pos(0) < pos(1) && pos(1) < pos(2));
        assert!(pos(3) < pos(4) && pos(4) < pos(5));
    }

    #[test]
    fn dag_pool_respects_dependencies_across_waves() {
        let n = 300usize;
        let deps: Vec<Vec<u32>> = (0..n)
            .map(|i| if i > 0 { vec![(i as u32) / 2] } else { vec![] })
            .collect();
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        dag_pool_scope(
            4,
            |i| {
                for &d in &deps[i] {
                    assert_eq!(hits[d as usize].load(Ordering::SeqCst), 1);
                }
                hits[i].fetch_add(1, Ordering::SeqCst);
            },
            |pool| {
                // three waves spliced without waiting for drains
                pool.inject(&deps[..100]);
                pool.inject(&deps[100..200]);
                pool.inject(&deps[200..]);
            },
        );
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn dag_pool_zero_tasks() {
        let out = dag_pool_scope(2, |_| panic!("no tasks injected"), |_| 42);
        assert_eq!(out, 42);
    }

    #[test]
    fn dag_pool_propagates_worker_panics() {
        let res = std::panic::catch_unwind(|| {
            dag_pool_scope(
                4,
                |i| {
                    if i == 3 {
                        panic!("boom");
                    }
                },
                |pool| {
                    pool.inject(&[vec![], vec![], vec![], vec![], vec![]]);
                    pool.wait(|done| done == 5);
                },
            );
        });
        assert!(res.is_err());
    }

    #[test]
    fn par_dag_wide_queue_batches_every_task_once() {
        // 2000 mutually independent tasks: the ready queue starts with a
        // large surplus, so workers exercise the multi-task dequeue path
        let n = 2000;
        let deps: Vec<Vec<u32>> = (0..n).map(|_| Vec::new()).collect();
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        par_dag(&deps, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn dag_pool_wide_wave_then_dependent_wave() {
        // a wide wave (batched dequeues) followed by tasks depending on
        // batch-executed ancestors: completions flushed in batches must
        // still release dependents exactly once
        let n = 600usize;
        let hits: Vec<AtomicU64> = (0..2 * n).map(|_| AtomicU64::new(0)).collect();
        dag_pool_scope(
            4,
            |i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            },
            |pool| {
                let wide: Vec<Vec<u32>> = (0..n).map(|_| Vec::new()).collect();
                let r = pool.inject(&wide);
                assert_eq!(r, 0..n);
                let dependent: Vec<Vec<u32>> = (0..n).map(|i| vec![i as u32]).collect();
                pool.inject(&dependent);
                pool.wait(|done| done == 2 * n);
            },
        );
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn batch_extra_scales_with_surplus() {
        assert_eq!(batch_extra(0, 8), 0, "scarce work: one task per worker");
        assert_eq!(batch_extra(7, 8), 0);
        assert_eq!(batch_extra(16, 8), 2);
        assert_eq!(
            batch_extra(10_000, 8),
            DEQUEUE_BATCH - 1,
            "surplus capped at DEQUEUE_BATCH"
        );
    }

    #[test]
    #[should_panic(expected = "dependency cycle")]
    fn par_dag_rejects_cycles() {
        // task 0 is ready, but 1 and 2 depend on each other
        par_dag(&[vec![], vec![2], vec![1]], |_| {});
    }

    #[test]
    fn par_dag_propagates_panics() {
        let deps: Vec<Vec<u32>> = (0..64).map(|_| Vec::new()).collect();
        let res = std::panic::catch_unwind(|| {
            par_dag(&deps, |i| {
                if i == 13 {
                    panic!("boom");
                }
            });
        });
        assert!(res.is_err());
    }
}
