//! Minimal data-parallel helpers over `std::thread::scope` (substitute for
//! rayon/tokio — the coordinator is compute-bound, so scoped OS threads
//! with chunked work-stealing-free partitioning are sufficient and keep
//! the hot loop allocation-free).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use: `RAPID_THREADS` env var, else the
/// available parallelism, clamped to [1, 64].
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("RAPID_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.clamp(1, 64);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 64)
}

/// Run `f(i)` for every `i in 0..n`, dynamically load-balanced across
/// `num_threads()` workers. `f` must be `Sync` (called concurrently).
pub fn par_for<F: Fn(usize) + Sync>(n: usize, f: F) {
    par_for_with(num_threads(), n, f)
}

/// `par_for` with an explicit worker count.
pub fn par_for_with<F: Fn(usize) + Sync>(workers: usize, n: usize, f: F) {
    if n == 0 {
        return;
    }
    let workers = workers.min(n).max(1);
    if workers == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Map `f` over `0..n` in parallel, collecting results in index order.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots = SendPtr(out.as_mut_ptr());
        let slots = &slots;
        par_for(n, |i| {
            // SAFETY: each index is written by exactly one worker.
            unsafe { slots.write(i, Some(f(i))) };
        });
    }
    out.into_iter().map(|x| x.unwrap()).collect()
}

/// Wrapper to smuggle a raw pointer into a `Sync` closure; callers must
/// guarantee disjoint index access.
struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// SAFETY: each index must be written by exactly one thread, and the
    /// pointer must stay valid for the duration of the parallel region.
    unsafe fn write(&self, i: usize, val: T) {
        *self.0.add(i) = val;
    }
}

/// Process disjoint mutable row-chunks of a flat `data` buffer in parallel:
/// `f(chunk_index, chunk)` where `chunk` is `rows_per_chunk * row_len`
/// elements (last chunk may be shorter).
pub fn par_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    data: &mut [T],
    chunk_len: usize,
    f: F,
) {
    assert!(chunk_len > 0);
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk_len).enumerate().collect();
    let n = chunks.len();
    let slots = std::sync::Mutex::new(chunks);
    // Pull chunks off a shared list; order does not matter.
    par_for(n, |_| {
        let item = slots.lock().unwrap().pop();
        if let Some((idx, chunk)) = item {
            f(idx, chunk);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_for_visits_all_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        par_for(1000, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_for_zero_items() {
        par_for(0, |_| panic!("must not be called"));
    }

    #[test]
    fn par_map_ordered() {
        let v = par_map(100, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn par_chunks_mut_disjoint() {
        let mut data = vec![0u32; 1003];
        par_chunks_mut(&mut data, 100, |idx, chunk| {
            for x in chunk.iter_mut() {
                *x = idx as u32 + 1;
            }
        });
        assert!(data.iter().all(|&x| x >= 1));
        assert_eq!(data[0], 1);
        assert_eq!(data[1002], 11);
    }

    #[test]
    fn num_threads_positive() {
        assert!(num_threads() >= 1);
    }
}
