//! ASCII table rendering for benchmark/figure output.
//!
//! Every bench in `benches/` prints its figure/table through this module
//! so the rows the paper reports are regenerated in a uniform format.

/// A simple column-aligned table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity must match header"
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                s.push_str(&format!(" {cell:<w$} |", w = widths[c]));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a ratio like `1061x` / `5.8x` with sensible precision.
pub fn fmt_ratio(x: f64) -> String {
    if !x.is_finite() {
        return "inf".to_string();
    }
    if x >= 100.0 {
        format!("{x:.0}x")
    } else if x >= 10.0 {
        format!("{x:.1}x")
    } else {
        format!("{x:.2}x")
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_time(secs: f64) -> String {
    if !secs.is_finite() {
        "inf".to_string()
    } else if secs >= 3600.0 {
        format!("{:.2} h", secs / 3600.0)
    } else if secs >= 60.0 {
        format!("{:.2} min", secs / 60.0)
    } else if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Format joules with an adaptive unit.
pub fn fmt_energy(joules: f64) -> String {
    if !joules.is_finite() {
        "inf".to_string()
    } else if joules >= 1e6 {
        format!("{:.2} MJ", joules / 1e6)
    } else if joules >= 1e3 {
        format!("{:.2} kJ", joules / 1e3)
    } else if joules >= 1.0 {
        format!("{joules:.3} J")
    } else if joules >= 1e-3 {
        format!("{:.3} mJ", joules * 1e3)
    } else if joules >= 1e-6 {
        format!("{:.3} uJ", joules * 1e6)
    } else if joules >= 1e-9 {
        format!("{:.3} nJ", joules * 1e9)
    } else {
        format!("{:.3} pJ", joules * 1e12)
    }
}

/// Format a vertex count: 2449029 -> "2.45M", 32768 -> "32.8k".
pub fn fmt_count(n: usize) -> String {
    let x = n as f64;
    if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e4 {
        format!("{:.1}k", x / 1e3)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Fig. X", &["n", "speedup"]);
        t.row_strs(&["100", "12x"]);
        t.row_strs(&["32768", "42.8x"]);
        let s = t.render();
        assert!(s.contains("## Fig. X"));
        assert!(s.contains("| n     | speedup |"));
        assert!(s.lines().filter(|l| l.starts_with('+')).count() >= 3);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn ratio_formats() {
        assert_eq!(fmt_ratio(1061.4), "1061x");
        assert_eq!(fmt_ratio(42.81), "42.8x");
        assert_eq!(fmt_ratio(5.83), "5.83x");
        assert_eq!(fmt_ratio(f64::INFINITY), "inf");
    }

    #[test]
    fn time_formats() {
        assert_eq!(fmt_time(7200.0), "2.00 h");
        assert_eq!(fmt_time(90.0), "1.50 min");
        assert_eq!(fmt_time(1.5), "1.500 s");
        assert_eq!(fmt_time(0.0025), "2.500 ms");
        assert_eq!(fmt_time(2.5e-6), "2.500 us");
        assert_eq!(fmt_time(3e-9), "3.0 ns");
    }

    #[test]
    fn energy_formats() {
        assert_eq!(fmt_energy(2.5e6), "2.50 MJ");
        assert_eq!(fmt_energy(1.5), "1.500 J");
        assert_eq!(fmt_energy(0.56e-12), "0.560 pJ");
    }

    #[test]
    fn count_formats() {
        assert_eq!(fmt_count(2_449_029), "2.45M");
        assert_eq!(fmt_count(32_768), "32.8k");
        assert_eq!(fmt_count(100), "100");
    }
}
