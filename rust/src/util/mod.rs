//! Small self-contained utilities that substitute for crates unavailable
//! in the offline build image (see DESIGN.md "Environment substitutions").

pub mod arena;
pub mod bench;
pub mod cli;
pub mod config;
pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;
pub mod threads;
