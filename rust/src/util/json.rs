//! Minimal JSON writer + reader (substitute for `serde_json`).
//!
//! Writing: benches dump machine-readable results next to the ASCII
//! tables. Reading: the runtime parses the artifact `manifest.json`
//! emitted by `python/compile/aot.py`. Only the JSON subset the manifest
//! uses is supported (objects, arrays, strings, numbers, bools).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Render compactly.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse from text.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

/// Builder helpers for the common case (an object of results).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(x: f64) -> Json {
    Json::Num(x)
}
pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {txt:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i + 1..self.i + 5)
                                    .ok_or("bad \\u escape")?,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] found {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} found {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let v = obj(vec![
            ("name", s("fw_block_1024")),
            ("n", num(1024.0)),
            ("ok", Json::Bool(true)),
            ("sizes", arr(vec![num(64.0), num(128.0)])),
        ]);
        let text = v.render();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_manifest_like() {
        let text = r#"{
            "artifacts": [
                {"kind": "fw", "n": 128, "path": "fw_block_128.hlo.txt"},
                {"kind": "minplus", "n": 128, "path": "minplus_128.hlo.txt"}
            ],
            "jax_version": "0.8.2"
        }"#;
        let v = Json::parse(text).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 2);
        assert_eq!(arts[0].get("n").unwrap().as_usize(), Some(128));
        assert_eq!(
            arts[1].get("path").unwrap().as_str(),
            Some("minplus_128.hlo.txt")
        );
    }

    #[test]
    fn string_escapes() {
        let v = s("a\"b\\c\nd");
        let text = v.render();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn rejects_bad_number() {
        assert!(Json::parse("[1, 2, 3e+q]").is_err());
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(num(1024.0).render(), "1024");
        assert_eq!(num(1.5).render(), "1.5");
    }
}
