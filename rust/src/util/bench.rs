//! Micro-benchmark timing harness (substitute for `criterion`).
//!
//! Warms up, then runs enough iterations to cover a minimum measurement
//! window, and reports mean / min / stddev. Used by `benches/*.rs`
//! (compiled with `harness = false`).

use super::json::{self, Json};
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    pub iters: u64,
    pub mean: Duration,
    pub min: Duration,
    pub stddev: Duration,
}

impl Measurement {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {} (min {}, sd {}, {} iters)",
            super::table::fmt_time(self.mean.as_secs_f64()),
            super::table::fmt_time(self.min.as_secs_f64()),
            super::table::fmt_time(self.stddev.as_secs_f64()),
            self.iters
        )
    }
}

/// Options for a timing run.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    /// Warmup wall-clock budget.
    pub warmup: Duration,
    /// Measurement wall-clock budget.
    pub measure: Duration,
    /// Max sample count (each sample is one closure call).
    pub max_samples: u64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(1500),
            max_samples: 200,
        }
    }
}

impl BenchOpts {
    /// Quick profile for expensive end-to-end benches.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(0),
            measure: Duration::from_millis(300),
            max_samples: 5,
        }
    }
}

/// Time `f`, returning per-call stats. `f` should do one unit of work.
pub fn bench<F: FnMut()>(opts: BenchOpts, mut f: F) -> Measurement {
    // Warmup
    let start = Instant::now();
    while start.elapsed() < opts.warmup {
        f();
    }
    // Measure
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < opts.measure && (samples.len() as u64) < opts.max_samples {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    if samples.is_empty() {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    let n = samples.len() as u64;
    let total: Duration = samples.iter().sum();
    let mean = total / n as u32;
    let min = *samples.iter().min().unwrap();
    let mean_s = mean.as_secs_f64();
    let var = samples
        .iter()
        .map(|d| {
            let x = d.as_secs_f64() - mean_s;
            x * x
        })
        .sum::<f64>()
        / n as f64;
    Measurement {
        iters: n,
        mean,
        min,
        stddev: Duration::from_secs_f64(var.sqrt()),
    }
}

/// Convenience: run once and return elapsed seconds (for long workloads
/// where repeated sampling is impractical).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Prevent the optimizer from discarding a value (std::hint::black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Nearest-rank percentile of a sample set (`p` in [0, 1]): sorts a
/// copy by `total_cmp` and indexes `round(p * (n - 1))`. One shared
/// definition so the admission report and the CI perf-snapshot
/// (`BENCH_admission.json`) can never drift apart. Panics on an empty
/// slice.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty(), "percentile of an empty sample set");
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    let idx = (p * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Builder for the machine-readable `BENCH_*.json` perf-snapshot
/// artifacts. Every document shares one schema: a `workload` name
/// tagging which bench wrote it, flat metric keys, and an optional
/// `thresholds` block carrying the gates CI applies to a fresh artifact
/// — absolute floors and ceilings (keyed exactly as the validator reads
/// them, e.g. `qps_min` / `torn_reads_max`) plus relative drift bands
/// under `thresholds.drift.<metric>` for deterministic modeled numbers.
/// The admission, delta, serve, host-perf, and per-semiring emitters in
/// `benches/kernels.rs` all assemble through this type, so a new bench
/// key set inherits the exact shape the CI validators expect instead of
/// copy-pasting the key assembly.
#[derive(Debug, Clone, Default)]
pub struct BenchDoc {
    fields: Vec<(String, Json)>,
    thresholds: Vec<(String, Json)>,
    drift: Vec<(String, Json)>,
}

impl BenchDoc {
    /// Start a document tagged with its schema name (the `workload`
    /// key CI uses to tell the artifacts apart).
    pub fn new(schema: &str) -> Self {
        Self {
            fields: vec![("workload".to_string(), json::s(schema))],
            thresholds: Vec::new(),
            drift: Vec::new(),
        }
    }

    /// A floating-point metric.
    pub fn num(mut self, key: &str, v: f64) -> Self {
        self.fields.push((key.to_string(), json::num(v)));
        self
    }

    /// An integer metric (counts, sizes); rendered without a fraction.
    pub fn count(self, key: &str, v: usize) -> Self {
        self.num(key, v as f64)
    }

    /// A string-valued field (kernel names, notes).
    pub fn text(mut self, key: &str, v: &str) -> Self {
        self.fields.push((key.to_string(), json::s(v)));
        self
    }

    /// An arbitrary pre-built value (nested arrays like `per_graph`).
    pub fn field(mut self, key: &str, v: Json) -> Self {
        self.fields.push((key.to_string(), v));
        self
    }

    /// Splice in a pre-assembled field list (e.g. the host wall-clock
    /// keys that ride along on several artifacts).
    pub fn extend_fields(mut self, kv: Vec<(&str, Json)>) -> Self {
        for (k, v) in kv {
            self.fields.push((k.to_string(), v));
        }
        self
    }

    /// Absolute floor gate: the fresh metric must be `>= bound`.
    /// `threshold_key` is the literal key the CI validator reads from
    /// the `thresholds` block (e.g. `qps_min`).
    pub fn floor(mut self, threshold_key: &str, bound: f64) -> Self {
        self.thresholds.push((threshold_key.to_string(), json::num(bound)));
        self
    }

    /// Absolute ceiling gate: the fresh metric must be `<= bound`.
    /// `threshold_key` is the literal key the CI validator reads
    /// (e.g. `latency_p99_max_s`, `torn_reads_max`).
    pub fn ceiling(mut self, threshold_key: &str, bound: f64) -> Self {
        self.thresholds.push((threshold_key.to_string(), json::num(bound)));
        self
    }

    /// Relative drift gate: the fresh `metric_key` may exceed the
    /// committed baseline value by at most `band` (e.g. 0.25 = +25%).
    pub fn drift_max_increase(mut self, metric_key: &str, band: f64) -> Self {
        self.drift
            .push((metric_key.to_string(), json::obj(vec![("max_increase", json::num(band))])));
        self
    }

    /// Relative drift gate: the fresh `metric_key` must stay at or
    /// above `ratio` times the committed baseline value.
    pub fn drift_min_ratio(mut self, metric_key: &str, ratio: f64) -> Self {
        self.drift
            .push((metric_key.to_string(), json::obj(vec![("min_ratio", json::num(ratio))])));
        self
    }

    /// Assemble the final JSON object. The `thresholds` block (with its
    /// nested `drift` object) is only emitted when gates were declared,
    /// so purely informational artifacts stay flat.
    pub fn build(self) -> Json {
        let BenchDoc {
            mut fields,
            thresholds,
            drift,
        } = self;
        if !thresholds.is_empty() || !drift.is_empty() {
            let mut th: std::collections::BTreeMap<String, Json> =
                thresholds.into_iter().collect();
            if !drift.is_empty() {
                th.insert("drift".to_string(), Json::Obj(drift.into_iter().collect()));
            }
            fields.push(("thresholds".to_string(), Json::Obj(th)));
        }
        Json::Obj(fields.into_iter().collect())
    }

    /// Render and write the artifact (newline-terminated, the shape CI
    /// and `json::parse` both read back).
    pub fn write(self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.build().render() + "\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let m = bench(
            BenchOpts {
                warmup: Duration::from_millis(5),
                measure: Duration::from_millis(20),
                max_samples: 1000,
            },
            || {
                let mut s = 0u64;
                for i in 0..1000 {
                    s = s.wrapping_add(black_box(i));
                }
                black_box(s);
            },
        );
        assert!(m.iters >= 1);
        assert!(m.mean > Duration::ZERO);
        assert!(m.min <= m.mean);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn bench_doc_assembles_schema_thresholds_and_drift() {
        let doc = BenchDoc::new("unit_test")
            .num("qps", 1234.5)
            .count("graphs", 6)
            .text("kernel", "avx2")
            .field("sweep", json::arr(vec![json::num(1.0), json::num(2.0)]))
            .floor("qps_min", 1000.0)
            .ceiling("torn_reads_max", 0.0)
            .drift_max_increase("latency_p50_s", 0.25)
            .drift_min_ratio("speedup_vs_drain", 0.9)
            .build();
        assert_eq!(doc.get("workload").and_then(Json::as_str), Some("unit_test"));
        assert_eq!(doc.get("qps").and_then(Json::as_f64), Some(1234.5));
        assert_eq!(doc.get("graphs").and_then(Json::as_usize), Some(6));
        assert_eq!(doc.get("kernel").and_then(Json::as_str), Some("avx2"));
        assert_eq!(doc.get("sweep").and_then(Json::as_arr).map(<[Json]>::len), Some(2));
        let th = doc.get("thresholds").expect("thresholds block");
        assert_eq!(th.get("qps_min").and_then(Json::as_f64), Some(1000.0));
        assert_eq!(th.get("torn_reads_max").and_then(Json::as_f64), Some(0.0));
        let drift = th.get("drift").expect("drift block");
        let band = drift.get("latency_p50_s").and_then(|d| d.get("max_increase"));
        assert_eq!(band.and_then(Json::as_f64), Some(0.25));
        let ratio = drift.get("speedup_vs_drain").and_then(|d| d.get("min_ratio"));
        assert_eq!(ratio.and_then(Json::as_f64), Some(0.9));
        // the artifact round-trips through the parser CI reads it with
        let back = Json::parse(&doc.render()).expect("parse rendered artifact");
        assert_eq!(back, doc);
    }

    #[test]
    fn bench_doc_without_gates_stays_flat() {
        let doc = BenchDoc::new("plain").num("x", 1.0).build();
        assert!(doc.get("thresholds").is_none());
        assert_eq!(doc.get("workload").and_then(Json::as_str), Some("plain"));
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 0.5), 3.0);
        assert_eq!(percentile(&v, 1.0), 5.0);
        assert_eq!(percentile(&v, 0.9), 5.0);
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
    }
}
