//! Micro-benchmark timing harness (substitute for `criterion`).
//!
//! Warms up, then runs enough iterations to cover a minimum measurement
//! window, and reports mean / min / stddev. Used by `benches/*.rs`
//! (compiled with `harness = false`).

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    pub iters: u64,
    pub mean: Duration,
    pub min: Duration,
    pub stddev: Duration,
}

impl Measurement {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {} (min {}, sd {}, {} iters)",
            super::table::fmt_time(self.mean.as_secs_f64()),
            super::table::fmt_time(self.min.as_secs_f64()),
            super::table::fmt_time(self.stddev.as_secs_f64()),
            self.iters
        )
    }
}

/// Options for a timing run.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    /// Warmup wall-clock budget.
    pub warmup: Duration,
    /// Measurement wall-clock budget.
    pub measure: Duration,
    /// Max sample count (each sample is one closure call).
    pub max_samples: u64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(1500),
            max_samples: 200,
        }
    }
}

impl BenchOpts {
    /// Quick profile for expensive end-to-end benches.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(0),
            measure: Duration::from_millis(300),
            max_samples: 5,
        }
    }
}

/// Time `f`, returning per-call stats. `f` should do one unit of work.
pub fn bench<F: FnMut()>(opts: BenchOpts, mut f: F) -> Measurement {
    // Warmup
    let start = Instant::now();
    while start.elapsed() < opts.warmup {
        f();
    }
    // Measure
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < opts.measure && (samples.len() as u64) < opts.max_samples {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    if samples.is_empty() {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    let n = samples.len() as u64;
    let total: Duration = samples.iter().sum();
    let mean = total / n as u32;
    let min = *samples.iter().min().unwrap();
    let mean_s = mean.as_secs_f64();
    let var = samples
        .iter()
        .map(|d| {
            let x = d.as_secs_f64() - mean_s;
            x * x
        })
        .sum::<f64>()
        / n as f64;
    Measurement {
        iters: n,
        mean,
        min,
        stddev: Duration::from_secs_f64(var.sqrt()),
    }
}

/// Convenience: run once and return elapsed seconds (for long workloads
/// where repeated sampling is impractical).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Prevent the optimizer from discarding a value (std::hint::black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Nearest-rank percentile of a sample set (`p` in [0, 1]): sorts a
/// copy by `total_cmp` and indexes `round(p * (n - 1))`. One shared
/// definition so the admission report and the CI perf-snapshot
/// (`BENCH_admission.json`) can never drift apart. Panics on an empty
/// slice.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty(), "percentile of an empty sample set");
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    let idx = (p * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let m = bench(
            BenchOpts {
                warmup: Duration::from_millis(5),
                measure: Duration::from_millis(20),
                max_samples: 1000,
            },
            || {
                let mut s = 0u64;
                for i in 0..1000 {
                    s = s.wrapping_add(black_box(i));
                }
                black_box(s);
            },
        );
        assert!(m.iters >= 1);
        assert!(m.mean > Duration::ZERO);
        assert!(m.min <= m.mean);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 0.5), 3.0);
        assert_eq!(percentile(&v, 1.0), 5.0);
        assert_eq!(percentile(&v, 0.9), 5.0);
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
    }
}
