//! Deterministic pseudo-random number generation (substitute for `rand`).
//!
//! `SplitMix64` seeds `Xoshiro256++`, the standard pairing recommended by
//! the xoshiro authors. All graph generators and property tests take
//! explicit seeds so every experiment in EXPERIMENTS.md is reproducible.

/// SplitMix64 — used to expand a single `u64` seed into a full state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — fast, high-quality general-purpose PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`. Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range bound must be > 0");
        let bound = bound as u64;
        // widening multiply rejection sampling
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn gen_f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.gen_f64() as f32) * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_range(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// Fork a new independent stream (for per-thread generators).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::new(7);
        for bound in [1usize, 2, 3, 10, 1000, usize::MAX / 2] {
            for _ in 0..200 {
                assert!(r.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_range_covers_small_domain() {
        let mut r = Rng::new(9);
        let mut seen = [false; 5];
        for _ in 0..500 {
            seen[r.gen_range(5)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_f64_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_f64_mean_near_half() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let sum: f64 = (0..n).map(|_| r.gen_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(19);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn sample_all() {
        let mut r = Rng::new(21);
        let s = r.sample_indices(10, 10);
        let mut s = s;
        s.sort_unstable();
        assert_eq!(s, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut a = Rng::new(23);
        let mut f = a.fork();
        // forked stream differs from parent continuation
        let same = (0..64).filter(|_| a.next_u64() == f.next_u64()).count();
        assert!(same < 2);
    }
}
