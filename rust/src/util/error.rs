//! Minimal error type with context chaining (substitute for `anyhow`,
//! which is unavailable in the offline build image).
//!
//! Supports the subset the crate uses: `Result<T>`, `err!`/`bail!`/
//! `ensure!` macros, `.context(..)` / `.with_context(|| ..)` on both
//! `Result` and `Option`, and `?` conversion from any `std` error.
//! Display renders the whole context chain outermost-first
//! (`"open foo: No such file or directory"`), so `{e}` and `{e:#}`
//! both print the full story.

use std::fmt;

/// An error message with its accumulated context chain, rendered flat.
pub struct Error(String);

impl Error {
    pub fn msg(m: impl fmt::Display) -> Self {
        Error(m.to_string())
    }

    /// Wrap with an outer context layer.
    pub fn context(self, c: impl fmt::Display) -> Self {
        Error(format!("{c}: {}", self.0))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

// NOTE: `Error` deliberately does not implement `std::error::Error`;
// that keeps this blanket conversion from colliding with the reflexive
// `From<T> for T` impl (the same trick `anyhow` uses).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::err!($($arg)*));
        }
    };
}

/// Context-attachment helpers, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let v: i32 = s.parse()?; // From<ParseIntError>
        Ok(v)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_chains_outermost_first() {
        let e: Result<()> = Err(Error::msg("root cause"));
        let e = e.context("step failed").unwrap_err();
        let rendered = format!("{e:#}");
        assert_eq!(rendered, "step failed: root cause");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| "missing field").unwrap_err();
        assert!(format!("{e}").contains("missing field"));
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                crate::bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(format!("{}", f(12).unwrap_err()).contains("x too big: 12"));
        assert!(format!("{}", f(5).unwrap_err()).contains("five"));
    }
}
