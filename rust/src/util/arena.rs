//! Tile-buffer arena: a per-thread pool of `Vec<f32>` backing stores for
//! tile matrices, pivot-row snapshots, and min-plus panels.
//!
//! The host executor used to allocate a fresh `vec![f32; n*n]` (or
//! `vec![f32; n]`) for every tile task, every pivot-row snapshot, and
//! every blocked-FW panel extraction. In steady state those buffers are
//! all the same handful of sizes — the plan's tile census fixes them —
//! so the allocator traffic is pure overhead on the exact loops the
//! paper moves into PIM arrays. This module recycles the backing stores:
//! a lease pops a buffer from a size-classed free list (allocating only
//! on a cold miss), a recycle pushes it back.
//!
//! Design constraints:
//! * **Thread-local, lock-free.** Workers in `util::threads` executors
//!   are scoped OS threads; each keeps its own pool, so leases never
//!   contend. Buffers may be recycled on a different thread than they
//!   were leased on (slot matrices cross the DAG); that is fine — the
//!   buffer just joins the recycling thread's pool.
//! * **Numerics-neutral.** A leased buffer is always `resize`d and
//!   `fill`ed before use; pooling changes *where* the bytes live, never
//!   what they hold. All bit-identity oracles are unaffected.
//! * **Bounded.** Each pool caps its cached bytes (`set_cache_cap`);
//!   recycles beyond the cap drop the buffer instead of hoarding it.
//!   `scheduler::plan_tile_census` sizes the cap from the plan.
//!
//! [`TileArena`] is the explicit, directly-testable pool;
//! [`lease_filled`] / [`recycle`] / [`scratch_filled`] are the
//! thread-local front the kernels and the scheduler use.

use std::cell::RefCell;

/// Default per-thread cache cap: generous enough for every workload in
/// the bench suite (a 1024-tile matrix is 4 MiB; a census rarely holds
/// more than a few dozen live tiles per worker).
pub const DEFAULT_CACHE_CAP_BYTES: usize = 256 << 20;

/// Smallest size class; tiny leases all share one bucket.
const MIN_CLASS: usize = 64;

/// Snapshot of a pool's counters, for tests and the `--host-perf` bench.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ArenaStats {
    /// Buffers currently leased out (live).
    pub live: usize,
    /// Maximum simultaneous live buffers ever observed.
    pub high_water: usize,
    /// Leases served by a fresh heap allocation (cold misses).
    pub allocs: u64,
    /// Total leases served.
    pub leases: u64,
    /// Buffers returned to the pool.
    pub recycles: u64,
    /// Bytes currently cached in free lists.
    pub cached_bytes: usize,
}

/// An explicit buffer pool with size-classed free lists.
///
/// Size classes are next-power-of-two capacities (min [`MIN_CLASS`]), so
/// a buffer leased for one tile size can serve any other request in the
/// same class — the census sizes repeat, so hit rates are high.
pub struct TileArena {
    /// `(class_capacity, free list)` pairs, sorted by capacity.
    classes: Vec<(usize, Vec<Vec<f32>>)>,
    stats: ArenaStats,
    cache_cap_bytes: usize,
}

impl Default for TileArena {
    fn default() -> Self {
        Self::new()
    }
}

impl TileArena {
    pub fn new() -> Self {
        TileArena {
            classes: Vec::new(),
            stats: ArenaStats::default(),
            cache_cap_bytes: DEFAULT_CACHE_CAP_BYTES,
        }
    }

    /// Pool with an explicit cache cap (bytes of *idle* buffers kept).
    pub fn with_cache_cap(bytes: usize) -> Self {
        let mut a = Self::new();
        a.cache_cap_bytes = bytes;
        a
    }

    fn class_of(len: usize) -> usize {
        len.max(MIN_CLASS).next_power_of_two()
    }

    fn free_list(&mut self, class: usize) -> &mut Vec<Vec<f32>> {
        match self.classes.binary_search_by_key(&class, |&(c, _)| c) {
            Ok(i) => &mut self.classes[i].1,
            Err(i) => {
                self.classes.insert(i, (class, Vec::new()));
                &mut self.classes[i].1
            }
        }
    }

    /// Lease a buffer of exactly `len` elements, every element set to
    /// `fill`. Served from the free list when a buffer of the right
    /// class is cached; otherwise a single fresh allocation.
    pub fn lease_filled(&mut self, len: usize, fill: f32) -> Vec<f32> {
        self.stats.leases += 1;
        self.stats.live += 1;
        self.stats.high_water = self.stats.high_water.max(self.stats.live);
        if len == 0 {
            return Vec::new();
        }
        let class = Self::class_of(len);
        let reused = self.free_list(class).pop();
        match reused {
            Some(mut buf) => {
                self.stats.cached_bytes -= buf.capacity() * 4;
                buf.clear();
                buf.resize(len, fill);
                buf
            }
            None => {
                self.stats.allocs += 1;
                let mut buf = Vec::with_capacity(class);
                buf.resize(len, fill);
                buf
            }
        }
    }

    /// Return a buffer to the pool. Dropped (not cached) when the cache
    /// cap is reached or the buffer was not arena-shaped (zero capacity).
    pub fn recycle(&mut self, buf: Vec<f32>) {
        self.stats.recycles += 1;
        self.stats.live = self.stats.live.saturating_sub(1);
        let cap = buf.capacity();
        if cap == 0 {
            return;
        }
        let bytes = cap * 4;
        if self.stats.cached_bytes + bytes > self.cache_cap_bytes {
            return; // drop: pool is full
        }
        let class = Self::class_of(cap);
        // only cache buffers whose capacity is exactly a class size, so
        // a cached buffer always satisfies `resize(len)` without
        // reallocating for any len in its class
        if class != cap.max(MIN_CLASS) {
            return;
        }
        self.stats.cached_bytes += bytes;
        self.free_list(class).push(buf);
    }

    pub fn stats(&self) -> ArenaStats {
        self.stats
    }

    pub fn set_cache_cap(&mut self, bytes: usize) {
        self.cache_cap_bytes = bytes;
    }

    /// Drop every cached buffer (stats other than `cached_bytes` are
    /// preserved — high-water marks survive a trim).
    pub fn trim(&mut self) {
        self.classes.clear();
        self.stats.cached_bytes = 0;
    }
}

thread_local! {
    static POOL: RefCell<TileArena> = RefCell::new(TileArena::new());
}

/// Lease a `len`-element buffer filled with `fill` from this thread's
/// pool. Pair with [`recycle`] when the buffer's lifetime outlives a
/// scope (e.g. slot matrices); prefer [`scratch_filled`] for
/// scope-local scratch.
pub fn lease_filled(len: usize, fill: f32) -> Vec<f32> {
    POOL.with(|p| p.borrow_mut().lease_filled(len, fill))
}

/// Return a buffer to this thread's pool.
pub fn recycle(buf: Vec<f32>) {
    POOL.with(|p| p.borrow_mut().recycle(buf))
}

/// Counters for this thread's pool.
pub fn thread_stats() -> ArenaStats {
    POOL.with(|p| p.borrow().stats())
}

/// Set this thread's idle-cache cap (bytes).
pub fn set_thread_cache_cap(bytes: usize) {
    POOL.with(|p| p.borrow_mut().set_cache_cap(bytes))
}

/// Drop this thread's cached buffers.
pub fn trim_thread_pool() {
    POOL.with(|p| p.borrow_mut().trim())
}

/// Scope-guarded scratch lease: derefs to `[f32]`, recycles on drop
/// (including unwinds — a panicking tile task cannot leak its panels).
pub struct Scratch(Option<Vec<f32>>);

impl Scratch {
    /// Steal the backing store, skipping the drop-recycle (for buffers
    /// that get promoted into a longer-lived structure).
    pub fn into_vec(mut self) -> Vec<f32> {
        self.0.take().unwrap_or_default()
    }
}

impl std::ops::Deref for Scratch {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        self.0.as_deref().unwrap_or(&[])
    }
}

impl std::ops::DerefMut for Scratch {
    fn deref_mut(&mut self) -> &mut [f32] {
        self.0.as_deref_mut().unwrap_or(&mut [])
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        if let Some(buf) = self.0.take() {
            recycle(buf);
        }
    }
}

/// Lease scope-local scratch of `len` elements, filled with `fill`.
pub fn scratch_filled(len: usize, fill: f32) -> Scratch {
    Scratch(Some(lease_filled(len, fill)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_within_class() {
        let mut a = TileArena::new();
        let b1 = a.lease_filled(100, 0.0);
        let p1 = b1.as_ptr() as usize;
        a.recycle(b1);
        let b2 = a.lease_filled(120, 1.0); // same 128-class
        assert_eq!(b2.as_ptr() as usize, p1, "buffer should be reused");
        assert_eq!(b2.len(), 120);
        assert!(b2.iter().all(|&x| x == 1.0));
        let s = a.stats();
        assert_eq!(s.allocs, 1, "second lease must not allocate");
        assert_eq!(s.leases, 2);
    }

    #[test]
    fn high_water_tracks_concurrent_leases() {
        let mut a = TileArena::new();
        let bufs: Vec<_> = (0..5).map(|_| a.lease_filled(64, 0.0)).collect();
        assert_eq!(a.stats().live, 5);
        assert_eq!(a.stats().high_water, 5);
        for b in bufs {
            a.recycle(b);
        }
        assert_eq!(a.stats().live, 0);
        assert_eq!(a.stats().high_water, 5);
    }

    #[test]
    fn cache_cap_drops_excess() {
        // cap fits one 128-class buffer (512 B), not two
        let mut a = TileArena::with_cache_cap(600);
        let b1 = a.lease_filled(100, 0.0);
        let b2 = a.lease_filled(100, 0.0);
        a.recycle(b1);
        a.recycle(b2);
        assert_eq!(a.stats().cached_bytes, 128 * 4);
    }

    #[test]
    fn zero_len_lease_is_inert() {
        let mut a = TileArena::new();
        let b = a.lease_filled(0, 0.0);
        assert!(b.is_empty());
        a.recycle(b);
        assert_eq!(a.stats().cached_bytes, 0);
    }

    #[test]
    fn scratch_recycles_on_drop() {
        trim_thread_pool();
        let before = thread_stats();
        {
            let mut s = scratch_filled(200, 7.0);
            assert_eq!(s.len(), 200);
            s[0] = 1.0;
        }
        let after = thread_stats();
        assert_eq!(after.recycles, before.recycles + 1);
        assert_eq!(after.live, before.live);
    }
}
