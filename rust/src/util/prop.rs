//! Seeded property-testing harness (substitute for `proptest`).
//!
//! `check(cases, gen, prop)` runs `prop` on `cases` random inputs drawn by
//! `gen` from independent seeded streams; the first failing case is
//! re-reported with its seed so the exact input can be replayed. Used for
//! the coordinator/partitioner/semiring invariants listed in DESIGN.md.

use super::rng::Rng;

/// Default base seed ("RAPID" in ASCII). Override with `RAPID_PROP_SEED`.
const DEFAULT_SEED: u64 = 0x5241_5049_4400;

/// Result of a failed property run.
#[derive(Debug)]
pub struct PropFailure {
    pub case: usize,
    pub seed: u64,
    pub message: String,
}

impl std::fmt::Display for PropFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed on case {} (replay seed {:#x}): {}",
            self.case, self.seed, self.message
        )
    }
}

/// Base seed: `RAPID_PROP_SEED` env var, else a fixed default so CI is
/// deterministic (set the env var to explore fresh inputs).
pub fn base_seed() -> u64 {
    std::env::var("RAPID_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(DEFAULT_SEED)
}

/// Run `prop` on `cases` generated inputs. Returns the first failure;
/// return `Err(msg)` from the property for rich reporting.
pub fn check<T, G, P>(cases: usize, mut generate: G, prop: P) -> Result<(), PropFailure>
where
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let base = base_seed();
    for case in 0..cases {
        let seed = base
            .wrapping_add(case as u64)
            .wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = generate(&mut rng);
        if let Err(message) = prop(&input) {
            return Err(PropFailure { case, seed, message });
        }
    }
    Ok(())
}

/// Assert-style wrapper: panics with the failure report.
pub fn assert_prop<T, G, P>(cases: usize, generate: G, prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    if let Err(f) = check(cases, generate, prop) {
        panic!("{f}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        assert_prop(
            50,
            |r| r.gen_range(1000),
            |&x| {
                if x < 1000 {
                    Ok(())
                } else {
                    Err(format!("{x} out of range"))
                }
            },
        );
    }

    #[test]
    fn failing_property_is_replayable() {
        let res = check(200, |r| r.gen_range(100), |&x| {
            if x < 95 {
                Ok(())
            } else {
                Err(format!("hit {x}"))
            }
        });
        let f = res.unwrap_err();
        assert!(f.message.starts_with("hit"));
        // replayable: regenerate from the seed and refail
        let mut rng = Rng::new(f.seed);
        let x = rng.gen_range(100);
        assert!(x >= 95, "replay must reproduce the failing input");
    }

    #[test]
    fn deterministic_given_fixed_seed() {
        let run = || {
            check(100, |r| r.gen_range(1000), |&x| {
                if x % 97 != 13 {
                    Ok(())
                } else {
                    Err("bad".into())
                }
            })
        };
        match (run(), run()) {
            (Ok(()), Ok(())) => {}
            (Err(a), Err(b)) => {
                assert_eq!(a.case, b.case);
                assert_eq!(a.seed, b.seed);
            }
            _ => panic!("non-deterministic"),
        }
    }
}
