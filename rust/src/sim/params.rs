//! Hardware parameters — every constant the cost model uses, with its
//! provenance in the paper.
//!
//! Calibration note (DESIGN.md "Fidelity note"): device-event counts per
//! bit-serial op are taken from FELIX [26] and Table II; the two
//! *effective* energy constants (`fw_pj_per_madd`, `mp_pj_per_madd`)
//! fold in selective-write gating (the sign-bit mask skips futile
//! writes, paper §III-C) and FELIX multi-input fusion, and are
//! calibrated so the modeled 1024-vertex tile lands at the paper's
//! reported ~1061x/7208x CPU ratios. Everything downstream (scaling
//! curves, crossovers, topology sensitivity) is *derived*, not fitted.

/// Full hardware configuration. `Default` is the paper's system.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HwParams {
    // ---- clock (Table II: 2 ns cycle, 500 MHz)
    pub clock_hz: f64,

    // ---- PCM device (Table II, Sb2Te3/Ge4Sb6Te7 SLC)
    /// Set/reset pulse width (20 ns) — bounds any single device write.
    pub pcm_write_ns: f64,
    /// Programming energy per device event (~0.56 pJ).
    pub pcm_program_pj: f64,

    // ---- array / tile / die geometry (§III-C)
    /// PCM unit (crossbar) dimension: 1024 x 1024 cells.
    pub unit_dim: usize,
    /// Units per tile (130, H-tree connected).
    pub units_per_tile: usize,
    /// Tiles per compute die (2 GB die / (130 x 128 KiB) ≈ 120).
    pub tiles_per_die: usize,
    /// Distance word width (32-bit, §III-C comparator tree).
    pub word_bits: u32,

    // ---- FELIX bit-serial op latencies (cycles, §II-C)
    /// Cycles per 1-bit full-add (2 XOR @ 2cy + majority @ 1cy).
    pub cycles_per_bit_add: u64,
    /// Cycles per 1-bit of the min-compare subtraction (XOR @2 + NOR @1).
    pub cycles_per_bit_min: u64,
    /// Selective-write cycles per word (sign-gated, 1 column write).
    pub cycles_selective_write: u64,

    // ---- PCM-FW permutation unit (§III-C, Fig. 5d)
    /// Burst window of the row-buffer controller (32 rows).
    pub perm_burst_rows: u64,
    /// DMA read / write latency per burst (1 / 10 cycles).
    pub perm_dma_read_cycles: u64,
    pub perm_dma_write_cycles: u64,

    // ---- PCM-MP comparator tree (§III-C, Fig. 5e)
    /// Pipeline latency to reduce one 1024-wide row (1 + 6 + 6).
    pub mp_tree_latency_cycles: u64,
    /// Sustained throughput: one 1024-wide vector per cycle per unit.
    pub mp_vector_width: u64,

    // ---- effective energies (calibrated; see module docstring)
    /// Energy per FW min-add candidate (bit-serial add+min across the
    /// main block, selective write gated).
    pub fw_pj_per_madd: f64,
    /// Energy per MP min-add candidate (adds in PCM, min in the CMOS
    /// comparator tree -> cheaper than FW).
    pub mp_pj_per_madd: f64,

    // ---- UCIe interposer (§III-B: 64 lanes x 32 Gb/s full duplex)
    pub ucie_lanes: u64,
    pub ucie_gbps_per_lane: f64,
    pub ucie_pj_per_bit: f64,

    // ---- HBM3 (16 GB, [38])
    pub hbm_bytes: u64,
    pub hbm_gbps: f64,
    pub hbm_pj_per_bit: f64,
    pub hbm_active_w: f64,

    // ---- FeNAND (16 TB, ONFI 5.1 x16 [28][29])
    pub fenand_bytes: u64,
    pub fenand_read_gbps: f64,
    pub fenand_write_gbps: f64,
    pub fenand_read_pj_per_bit: f64,
    pub fenand_write_pj_per_bit: f64,
    pub fenand_active_w: f64,

    // ---- inter-stack interconnect (sharded execution): UCIe-class
    // stack-to-stack links off the interposer. Fewer lanes than the
    // in-stack UCIe fabric and pricier per bit (retimed off-package
    // reach), so cross-shard traffic is the scarce resource the shard
    // partitioner minimizes.
    pub interstack_lanes: u64,
    pub interstack_gbps_per_lane: f64,
    pub interstack_pj_per_bit: f64,

    // ---- logic die stream engines (CSR <-> dense, §III-B)
    pub stream_engines: u64,
    pub stream_bytes_per_cycle: u64,

    // ---- background power (controller SM2508 3.5 W + logic die)
    pub background_w: f64,

    // ---- scheduling knobs (ablations)
    /// Overlap component loads with the previous compute step.
    pub prefetch: bool,
    /// Use the permutation unit (off => panel extraction pays full
    /// row-by-row DMA cost, paper's motivation for the unit).
    pub permutation_unit: bool,
    /// Use the comparator tree (off => log2(1024) serial min passes).
    pub comparator_tree: bool,
}

impl Default for HwParams {
    fn default() -> Self {
        Self {
            clock_hz: 500e6,
            pcm_write_ns: 20.0,
            pcm_program_pj: 0.56,
            unit_dim: 1024,
            units_per_tile: 130,
            tiles_per_die: 120,
            word_bits: 32,
            cycles_per_bit_add: 5,
            cycles_per_bit_min: 3,
            cycles_selective_write: 1,
            perm_burst_rows: 32,
            perm_dma_read_cycles: 1,
            perm_dma_write_cycles: 10,
            mp_tree_latency_cycles: 13,
            mp_vector_width: 1024,
            fw_pj_per_madd: 16.0,
            mp_pj_per_madd: 8.0,
            ucie_lanes: 64,
            ucie_gbps_per_lane: 32.0,
            ucie_pj_per_bit: 0.6,
            hbm_bytes: 16 << 30,
            hbm_gbps: 819.0 * 8.0, // 819 GB/s
            hbm_pj_per_bit: 3.9,
            hbm_active_w: 8.6,
            fenand_bytes: 16 << 40,
            fenand_read_gbps: 38.4 * 8.0,
            fenand_write_gbps: 19.2 * 8.0,
            fenand_read_pj_per_bit: 0.5,
            fenand_write_pj_per_bit: 2.0,
            fenand_active_w: 6.4,
            interstack_lanes: 16,
            interstack_gbps_per_lane: 32.0,
            interstack_pj_per_bit: 1.3,
            stream_engines: 2,
            stream_bytes_per_cycle: 64,
            background_w: 3.5,
            prefetch: true,
            permutation_unit: true,
            comparator_tree: true,
        }
    }
}

impl HwParams {
    /// Seconds per clock cycle.
    pub fn cycle_s(&self) -> f64 {
        1.0 / self.clock_hz
    }

    /// UCIe bandwidth in bytes/s.
    pub fn ucie_bytes_per_s(&self) -> f64 {
        self.ucie_lanes as f64 * self.ucie_gbps_per_lane * 1e9 / 8.0
    }

    /// HBM3 bandwidth in bytes/s.
    pub fn hbm_bytes_per_s(&self) -> f64 {
        self.hbm_gbps * 1e9 / 8.0
    }

    /// Inter-stack interconnect bandwidth in bytes/s (one shared
    /// capacity-1 channel between all modeled stacks).
    pub fn interstack_bytes_per_s(&self) -> f64 {
        self.interstack_lanes as f64 * self.interstack_gbps_per_lane * 1e9 / 8.0
    }

    pub fn fenand_read_bytes_per_s(&self) -> f64 {
        self.fenand_read_gbps * 1e9 / 8.0
    }

    pub fn fenand_write_bytes_per_s(&self) -> f64 {
        self.fenand_write_gbps * 1e9 / 8.0
    }

    /// Logic-die CSR<->dense conversion bandwidth (bytes/s).
    pub fn stream_bytes_per_s(&self) -> f64 {
        self.stream_engines as f64 * self.stream_bytes_per_cycle as f64 * self.clock_hz
    }

    /// Cycles for one FW pivot step (panel add + min + selective write +
    /// permutation), independent of block size thanks to full-array
    /// parallelism (§III-D).
    pub fn fw_pivot_cycles(&self, n: u64) -> u64 {
        let add = self.cycles_per_bit_add * self.word_bits as u64;
        let min = self.cycles_per_bit_min * self.word_bits as u64;
        let write = self.cycles_selective_write * self.word_bits as u64 / 8;
        let perm = if self.permutation_unit {
            // 32-row coalesced bursts through the 4-stage FSM pipeline,
            // overlapped with compute: only the burst issue shows.
            n.div_ceil(self.perm_burst_rows)
                * (self.perm_dma_read_cycles + self.perm_dma_write_cycles)
                / 4
        } else {
            // row-by-row DMA, no overlap
            n * (self.perm_dma_read_cycles + self.perm_dma_write_cycles)
        };
        add + min + write + perm
    }

    /// Die-wide sustained MP throughput (min-add candidates per cycle):
    /// every unit retires one `mp_vector_width` row per cycle.
    pub fn mp_madds_per_cycle_per_tile(&self) -> u64 {
        let per_unit = if self.comparator_tree {
            self.mp_vector_width
        } else {
            // serial pairwise min: log2(width) passes over the row
            self.mp_vector_width / (self.mp_vector_width as f64).log2() as u64
        };
        // H-tree feeds half the units with operand streams; the rest
        // compute (paper: 130 units, 2 staging buffers per unit).
        per_unit * (self.units_per_tile as u64 / 2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_constants() {
        let p = HwParams::default();
        assert_eq!(p.clock_hz, 500e6); // Table II: 2 ns cycle
        assert_eq!(p.pcm_program_pj, 0.56); // Table II
        assert_eq!(p.unit_dim, 1024);
        assert_eq!(p.units_per_tile, 130); // §III-C
        assert_eq!(p.mp_tree_latency_cycles, 13); // §III-C
        assert_eq!(p.ucie_lanes, 64); // §III-B
        assert_eq!(p.hbm_bytes, 16 << 30);
        assert_eq!(p.fenand_bytes, 16 << 40);
    }

    #[test]
    fn bandwidths_positive_and_ordered() {
        let p = HwParams::default();
        assert!(p.ucie_bytes_per_s() > 2.0e11); // 2 Tb/s class (paper §V)
        assert!(p.hbm_bytes_per_s() > p.fenand_read_bytes_per_s());
        assert!(p.fenand_read_bytes_per_s() > p.fenand_write_bytes_per_s());
        // the stack-to-stack link is narrower than the in-stack fabric
        assert!(p.interstack_bytes_per_s() < p.ucie_bytes_per_s());
        assert!(p.interstack_bytes_per_s() > 0.0);
        assert!(p.interstack_pj_per_bit > p.ucie_pj_per_bit);
    }

    #[test]
    fn fw_pivot_cycles_scale() {
        let p = HwParams::default();
        let c1024 = p.fw_pivot_cycles(1024);
        let c64 = p.fw_pivot_cycles(64);
        assert!(c1024 > c64);
        // dominated by the bit-serial add/min, not the permutation
        assert!(c1024 < 2 * (p.cycles_per_bit_add + p.cycles_per_bit_min) * 32);
    }

    #[test]
    fn permutation_unit_ablation_hurts() {
        let on = HwParams::default();
        let off = HwParams {
            permutation_unit: false,
            ..on
        };
        assert!(off.fw_pivot_cycles(1024) > 4 * on.fw_pivot_cycles(1024));
    }

    #[test]
    fn comparator_tree_ablation_hurts() {
        let on = HwParams::default();
        let off = HwParams {
            comparator_tree: false,
            ..on
        };
        assert!(on.mp_madds_per_cycle_per_tile() > 5 * off.mp_madds_per_cycle_per_tile());
    }
}
