//! The simulation engine: schedules a [`Trace`] onto the modeled
//! hardware and accumulates the timeline + energy.
//!
//! Scheduling model (paper §III-B dataflow, Fig. 4a):
//!
//! * FW ops within a step spread across the PCM-FW die's tiles
//!   (tile-level parallelism, §III-A): step makespan = max(longest
//!   single op, ceil(total work / tiles)).
//! * MP merge batches run across the PCM-MP die's tiles the same way.
//! * Transfers (load, boundary build, inject, sync, store, fetch)
//!   serialize on their shared channel (UCIe / HBM / FeNAND).
//! * With `prefetch` on, a Load step overlaps the next compute step
//!   (HBM3 "prefetches next intra-component FW blocks for pipelined
//!   execution" — dataflow step 3ii); only the non-hidden part shows on
//!   the timeline.

use super::memsys;
use super::params::HwParams;
use super::pcm;
use crate::apsp::trace::{Op, Phase, Step, Trace};
use std::collections::HashMap;

/// Per-phase accounting.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseStat {
    pub secs: f64,
    pub joules: f64,
    pub ops: usize,
}

/// Simulation result.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    /// End-to-end wall time (seconds) on the modeled hardware.
    pub seconds: f64,
    /// Total energy (joules), including background/active power.
    pub joules: f64,
    /// Dynamic (op-charged) energy only.
    pub dynamic_joules: f64,
    pub per_phase: HashMap<Phase, PhaseStat>,
    /// Busy-seconds per resource.
    pub fw_busy: f64,
    pub mp_busy: f64,
    pub hbm_busy: f64,
    pub fenand_busy: f64,
    /// Total min-add candidates (work measure).
    pub madds: u64,
    /// Seconds hidden by load/compute prefetch overlap.
    pub prefetch_hidden: f64,
}

impl SimReport {
    /// FW-die utilization in [0,1].
    pub fn fw_utilization(&self) -> f64 {
        if self.seconds == 0.0 {
            0.0
        } else {
            self.fw_busy / self.seconds
        }
    }
    pub fn mp_utilization(&self) -> f64 {
        if self.seconds == 0.0 {
            0.0
        } else {
            self.mp_busy / self.seconds
        }
    }
    /// Effective min-add throughput (per second).
    pub fn madds_per_sec(&self) -> f64 {
        if self.seconds == 0.0 {
            0.0
        } else {
            self.madds as f64 / self.seconds
        }
    }
}

/// Duration + energy + resource tag of one scheduled step.
#[derive(Debug, Clone, Copy)]
struct StepCost {
    secs: f64,
    joules: f64,
    /// Longest single op (the floor when overlapped).
    min_visible: f64,
    kind: ResKind,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ResKind {
    FwDie,
    MpDie,
    Channel,
}

/// Simulate a trace; returns the report.
pub fn simulate(trace: &Trace, p: &HwParams) -> SimReport {
    let costs: Vec<StepCost> = trace.steps.iter().map(|s| step_cost(s, p)).collect();
    let mut report = SimReport::default();
    let mut i = 0;
    while i < trace.steps.len() {
        let step = &trace.steps[i];
        let cost = costs[i];
        let mut visible = cost.secs;
        // prefetch: a Load step hides under the following compute step
        if p.prefetch
            && step.phase == Phase::Load
            && i + 1 < trace.steps.len()
            && matches!(
                trace.steps[i + 1].phase,
                Phase::LocalFw | Phase::RerunFw | Phase::FinalSolve
            )
        {
            let next = costs[i + 1];
            let hidden = (cost.secs - cost.min_visible).min(next.secs);
            visible = (cost.secs - hidden).max(cost.min_visible);
            report.prefetch_hidden += cost.secs - visible;
        }
        report.seconds += visible;
        report.dynamic_joules += cost.joules;
        let stat = report.per_phase.entry(step.phase).or_default();
        stat.secs += visible;
        stat.joules += cost.joules;
        stat.ops += step.ops.len();
        match cost.kind {
            ResKind::FwDie => report.fw_busy += visible,
            ResKind::MpDie => report.mp_busy += visible,
            ResKind::Channel => {
                report.hbm_busy += visible;
                if matches!(step.phase, Phase::Store | Phase::CrossMerge) {
                    report.fenand_busy += visible;
                }
            }
        }
        i += 1;
    }
    report.madds = trace.total_madds();
    // background + active standby power over the run
    report.joules = report.dynamic_joules
        + report.seconds * p.background_w
        + report.hbm_busy * p.hbm_active_w
        + report.fenand_busy * p.fenand_active_w;
    report
}

fn step_cost(step: &Step, p: &HwParams) -> StepCost {
    match step.phase {
        Phase::LocalFw | Phase::RerunFw | Phase::FinalSolve => {
            let per_op: Vec<(u64, f64)> = step
                .ops
                .iter()
                .map(|op| match op {
                    Op::TileFw { n, .. } => pcm::fw_tile(p, *n),
                    other => panic!("non-FW op {other:?} in FW step"),
                })
                .collect();
            let (secs, longest, joules) = spread(p, &per_op, p.tiles_per_die as u64);
            StepCost {
                secs,
                joules,
                min_visible: longest,
                kind: ResKind::FwDie,
            }
        }
        Phase::CrossMerge => {
            let mut secs = 0.0;
            let mut joules = 0.0;
            let mut longest = 0.0f64;
            for op in &step.ops {
                match op {
                    Op::FetchBoundary { bytes } => {
                        let x = memsys::fenand_read(p, *bytes);
                        secs += x.secs;
                        joules += x.joules;
                    }
                    Op::MpMergeAgg {
                        stage1_madds,
                        stage2_madds,
                        rows,
                        ..
                    } => {
                        // batch spreads across all MP tiles
                        let madds = stage1_madds + stage2_madds;
                        let (cycles, e) =
                            pcm::mp_merge_on_tile(p, madds.div_ceil(p.tiles_per_die as u64), *rows);
                        let s = cycles as f64 * p.cycle_s();
                        secs += s;
                        longest = longest.max(s);
                        joules += e;
                    }
                    other => panic!("unexpected op {other:?} in CrossMerge step"),
                }
            }
            StepCost {
                secs,
                joules,
                min_visible: longest,
                kind: ResKind::MpDie,
            }
        }
        Phase::Load => {
            let per_op: Vec<(f64, f64)> = step
                .ops
                .iter()
                .map(|op| match op {
                    Op::LoadComponent { n, nnz } => {
                        let (c, e) = pcm::load_component(p, *n, *nnz);
                        (c as f64 * p.cycle_s(), e)
                    }
                    other => panic!("unexpected op {other:?} in Load step"),
                })
                .collect();
            // loads share the stream-engine/UCIe channel: serialize
            let secs: f64 = per_op.iter().map(|x| x.0).sum();
            let joules: f64 = per_op.iter().map(|x| x.1).sum();
            let longest = per_op.iter().map(|x| x.0).fold(0.0, f64::max);
            StepCost {
                secs,
                joules,
                min_visible: longest,
                kind: ResKind::Channel,
            }
        }
        Phase::BoundaryBuild | Phase::Inject | Phase::Sync | Phase::Store => {
            let mut secs = 0.0;
            let mut joules = 0.0;
            for op in &step.ops {
                let x = match op {
                    Op::BuildBoundary {
                        nb,
                        cross_nnz,
                        gather_elems,
                    } => memsys::boundary_build(p, *nb, *cross_nnz, *gather_elems),
                    Op::Inject { n, nb } => {
                        let (c, e) = pcm::inject(p, *n, *nb);
                        memsys::Xfer {
                            secs: c as f64 * p.cycle_s(),
                            joules: e,
                        }
                    }
                    Op::SyncBoundary { bytes } => memsys::hbm(p, *bytes),
                    Op::StoreCsr {
                        dense_elems,
                        csr_bytes,
                    } => memsys::store_csr(p, *dense_elems, *csr_bytes),
                    Op::StoreDense { bytes } => memsys::fenand_write(p, *bytes),
                    Op::FetchBoundary { bytes } => memsys::fenand_read(p, *bytes),
                    other => panic!("unexpected op {other:?} in {:?} step", step.phase),
                };
                secs += x.secs;
                joules += x.joules;
            }
            StepCost {
                secs,
                joules,
                min_visible: secs,
                kind: ResKind::Channel,
            }
        }
    }
}

/// Spread uniform-ish ops across `tiles` parallel executors: makespan =
/// max(longest op, total/tiles) (LPT bound). Returns `(makespan_secs,
/// longest_single_secs, total_joules)`.
fn spread(p: &HwParams, per_op: &[(u64, f64)], tiles: u64) -> (f64, f64, f64) {
    let total_cycles: u64 = per_op.iter().map(|x| x.0).sum();
    let longest: u64 = per_op.iter().map(|x| x.0).max().unwrap_or(0);
    let joules: f64 = per_op.iter().map(|x| x.1).sum();
    let makespan = (total_cycles.div_ceil(tiles)).max(longest);
    (
        makespan as f64 * p.cycle_s(),
        longest as f64 * p.cycle_s(),
        joules,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::plan::{build_plan, PlanOptions};
    use crate::apsp::recursive::{solve, SolveOptions};
    use crate::graph::generators::{self, Topology, Weights};

    fn trace_for(n: usize, topo: Topology, seed: u64) -> Trace {
        let g = generators::generate(topo, n, 12.0, Weights::Uniform(1.0, 4.0), seed);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 128,
                max_depth: usize::MAX,
                seed,
            },
        );
        solve(&g, &plan, None, SolveOptions::default()).trace
    }

    #[test]
    fn nonzero_time_and_energy() {
        let t = trace_for(1000, Topology::Nws, 1);
        let r = simulate(&t, &HwParams::default());
        assert!(r.seconds > 0.0);
        assert!(r.joules > r.dynamic_joules);
        assert!(r.madds > 0);
        assert!(r.fw_utilization() <= 1.0 + 1e-9);
    }

    #[test]
    fn bigger_graph_costs_more() {
        let p = HwParams::default();
        let a = simulate(&trace_for(600, Topology::Nws, 2), &p);
        let b = simulate(&trace_for(2400, Topology::Nws, 2), &p);
        assert!(b.seconds > a.seconds);
        assert!(b.joules > a.joules);
    }

    #[test]
    fn prefetch_hides_load_time() {
        let t = trace_for(2000, Topology::Nws, 3);
        let on = simulate(&t, &HwParams::default());
        let off = simulate(
            &t,
            &HwParams {
                prefetch: false,
                ..HwParams::default()
            },
        );
        assert!(on.seconds < off.seconds, "{} !< {}", on.seconds, off.seconds);
        assert!(on.prefetch_hidden > 0.0);
        assert_eq!(off.prefetch_hidden, 0.0);
        // energy unaffected by overlap (same dynamic work)
        assert!((on.dynamic_joules - off.dynamic_joules).abs() < 1e-12);
    }

    #[test]
    fn permutation_unit_ablation_slows_fw() {
        let t = trace_for(2000, Topology::Nws, 4);
        let on = simulate(&t, &HwParams::default());
        let off = simulate(
            &t,
            &HwParams {
                permutation_unit: false,
                ..HwParams::default()
            },
        );
        let fw_on = on.per_phase[&Phase::LocalFw].secs;
        let fw_off = off.per_phase[&Phase::LocalFw].secs;
        assert!(fw_off > 2.0 * fw_on, "{fw_off} vs {fw_on}");
    }

    #[test]
    fn per_phase_adds_up() {
        let t = trace_for(1500, Topology::OgbnProxy, 5);
        let r = simulate(&t, &HwParams::default());
        let sum: f64 = r.per_phase.values().map(|s| s.secs).sum();
        assert!((sum - r.seconds).abs() < 1e-9);
        let esum: f64 = r.per_phase.values().map(|s| s.joules).sum();
        assert!((esum - r.dynamic_joules).abs() < 1e-9);
    }

    #[test]
    fn clustered_beats_random_in_sim() {
        // the Fig. 9(c,f) mechanism: fewer boundary vertices => less
        // boundary/merge work => faster + cheaper. The effect needs
        // paper-scale tiles and a graph big enough that the boundary
        // dominates (at toy sizes the terminal dense solve is free
        // either way).
        let hw = HwParams::default();
        let mk = |topo| {
            let g = generators::generate(topo, 24_000, 20.0, Weights::Uniform(1.0, 4.0), 6);
            let plan = build_plan(
                &g,
                PlanOptions {
                    tile_limit: 1024,
                    max_depth: usize::MAX,
                    seed: 6,
                },
            );
            solve(&g, &plan, None, SolveOptions::default()).trace
        };
        let clustered = simulate(&mk(Topology::OgbnProxy), &hw);
        let random = simulate(&mk(Topology::Er), &hw);
        assert!(
            clustered.seconds < random.seconds,
            "clustered {} !< random {}",
            clustered.seconds,
            random.seconds
        );
    }
}
