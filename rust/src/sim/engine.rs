//! The simulation engine: schedules the emitted work onto the modeled
//! hardware and accumulates the timeline + energy. Two schedulers:
//!
//! * [`simulate`] — the legacy **step-barrier** model over a [`Trace`]:
//!   steps run strictly in order; FW ops within a step spread across
//!   the PCM-FW die's tiles (makespan = max(longest single op,
//!   ceil(total work / tiles))), MP batches likewise, transfers
//!   serialize on their channel, and load/compute prefetch overlap is a
//!   special case patched between adjacent steps.
//! * [`simulate_dag`] — the **dependency-aware list scheduler** over
//!   the tile-task DAG: every op becomes a unit on its resource (FW
//!   die with `tiles_per_die` malleable slots, MP die, UCIe / HBM /
//!   FeNAND channels), started greedily by critical-path priority the
//!   moment its dependencies finish. Prefetch overlap falls out of the
//!   graph instead of a special case; with `prefetch` off, loads and FW
//!   compute are made mutually exclusive (no pipelined stream-in).
//!
//! Both charge identical per-op cycles and energy — only the schedule
//! differs, so dynamic energy is scheduler-independent and the DAG
//! makespan is never worse than the barrier one on real workloads
//! (overlap can only help; asserted over the figure workloads in the
//! integration tests). One known accounting asymmetry in *background*
//! energy: the barrier model folds `FetchBoundary` time into the MP-die
//! step, so it never charges FeNAND active power for fetches; the DAG
//! model puts the fetch on the FeNAND channel (more faithful), so its
//! total joules include that standby draw.

use super::memsys;
use super::params::HwParams;
use super::pcm;
use crate::apsp::batch::BatchGraph;
use crate::apsp::shard::ShardGraph;
use crate::apsp::taskgraph::TaskGraph;
use crate::apsp::trace::{Op, Phase, Step, Trace};
use std::collections::HashMap;

/// Per-phase accounting.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseStat {
    pub secs: f64,
    pub joules: f64,
    pub ops: usize,
}

/// Simulation result.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    /// End-to-end wall time (seconds) on the modeled hardware.
    pub seconds: f64,
    /// Total energy (joules), including background/active power.
    pub joules: f64,
    /// Dynamic (op-charged) energy only.
    pub dynamic_joules: f64,
    pub per_phase: HashMap<Phase, PhaseStat>,
    /// Busy-seconds per resource.
    pub fw_busy: f64,
    pub mp_busy: f64,
    pub hbm_busy: f64,
    pub fenand_busy: f64,
    /// Busy-seconds of the inter-stack interconnect (sharded runs only;
    /// 0 for solo and batch schedules).
    pub interconnect_busy: f64,
    /// Modeled stack count of the run (1 for solo/batch schedules, `S`
    /// for [`simulate_sharded`]). Busy seconds are summed across
    /// stacks, so the utilization methods normalize by this.
    pub stacks: usize,
    /// Total min-add candidates (work measure).
    pub madds: u64,
    /// Seconds hidden by load/compute prefetch overlap.
    pub prefetch_hidden: f64,
}

impl SimReport {
    /// FW-die utilization in [0,1] (averaged over the run's stacks).
    pub fn fw_utilization(&self) -> f64 {
        if self.seconds == 0.0 {
            0.0
        } else {
            self.fw_busy / (self.seconds * self.stacks.max(1) as f64)
        }
    }
    /// MP-die utilization in [0,1] (averaged over the run's stacks).
    pub fn mp_utilization(&self) -> f64 {
        if self.seconds == 0.0 {
            0.0
        } else {
            self.mp_busy / (self.seconds * self.stacks.max(1) as f64)
        }
    }
    /// Effective min-add throughput (per second).
    pub fn madds_per_sec(&self) -> f64 {
        if self.seconds == 0.0 {
            0.0
        } else {
            self.madds as f64 / self.seconds
        }
    }
}

/// Duration + energy + resource tag of one scheduled step.
#[derive(Debug, Clone, Copy)]
struct StepCost {
    secs: f64,
    joules: f64,
    /// Longest single op (the floor when overlapped).
    min_visible: f64,
    kind: ResKind,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ResKind {
    FwDie,
    MpDie,
    Channel,
}

/// Simulate a trace; returns the report.
pub fn simulate(trace: &Trace, p: &HwParams) -> SimReport {
    let costs: Vec<StepCost> = trace.steps.iter().map(|s| step_cost(s, p)).collect();
    let mut report = SimReport {
        stacks: 1,
        ..SimReport::default()
    };
    let mut i = 0;
    while i < trace.steps.len() {
        let step = &trace.steps[i];
        let cost = costs[i];
        let mut visible = cost.secs;
        // prefetch: a Load step hides under the following compute step
        if p.prefetch
            && step.phase == Phase::Load
            && i + 1 < trace.steps.len()
            && matches!(
                trace.steps[i + 1].phase,
                Phase::LocalFw | Phase::RerunFw | Phase::FinalSolve
            )
        {
            let next = costs[i + 1];
            let hidden = (cost.secs - cost.min_visible).min(next.secs);
            visible = (cost.secs - hidden).max(cost.min_visible);
            report.prefetch_hidden += cost.secs - visible;
        }
        report.seconds += visible;
        report.dynamic_joules += cost.joules;
        let stat = report.per_phase.entry(step.phase).or_default();
        stat.secs += visible;
        stat.joules += cost.joules;
        stat.ops += step.ops.len();
        match cost.kind {
            ResKind::FwDie => report.fw_busy += visible,
            ResKind::MpDie => report.mp_busy += visible,
            ResKind::Channel => {
                report.hbm_busy += visible;
                if matches!(step.phase, Phase::Store | Phase::CrossMerge) {
                    report.fenand_busy += visible;
                }
            }
        }
        i += 1;
    }
    report.madds = trace.total_madds();
    // background + active standby power over the run
    report.joules = report.dynamic_joules
        + report.seconds * p.background_w
        + report.hbm_busy * p.hbm_active_w
        + report.fenand_busy * p.fenand_active_w;
    report
}

fn step_cost(step: &Step, p: &HwParams) -> StepCost {
    match step.phase {
        Phase::LocalFw | Phase::RerunFw | Phase::FinalSolve => {
            let per_op: Vec<(u64, f64)> = step
                .ops
                .iter()
                .map(|op| match op {
                    Op::TileFw { n, .. } => pcm::fw_tile(p, *n),
                    other => panic!("non-FW op {other:?} in FW step"),
                })
                .collect();
            let (secs, longest, joules) = spread(p, &per_op, p.tiles_per_die as u64);
            StepCost {
                secs,
                joules,
                min_visible: longest,
                kind: ResKind::FwDie,
            }
        }
        Phase::CrossMerge => {
            let mut secs = 0.0;
            let mut joules = 0.0;
            let mut longest = 0.0f64;
            for op in &step.ops {
                match op {
                    Op::FetchBoundary { bytes } => {
                        let x = memsys::fenand_read(p, *bytes);
                        secs += x.secs;
                        joules += x.joules;
                    }
                    Op::MpMergeAgg {
                        stage1_madds,
                        stage2_madds,
                        rows,
                        ..
                    } => {
                        // batch spreads across all MP tiles
                        let madds = stage1_madds + stage2_madds;
                        let (cycles, e) =
                            pcm::mp_merge_on_tile(p, madds.div_ceil(p.tiles_per_die as u64), *rows);
                        let s = cycles as f64 * p.cycle_s();
                        secs += s;
                        longest = longest.max(s);
                        joules += e;
                    }
                    other => panic!("unexpected op {other:?} in CrossMerge step"),
                }
            }
            StepCost {
                secs,
                joules,
                min_visible: longest,
                kind: ResKind::MpDie,
            }
        }
        Phase::Load => {
            let per_op: Vec<(f64, f64)> = step
                .ops
                .iter()
                .map(|op| match op {
                    Op::LoadComponent { n, nnz } => {
                        let (c, e) = pcm::load_component(p, *n, *nnz);
                        (c as f64 * p.cycle_s(), e)
                    }
                    other => panic!("unexpected op {other:?} in Load step"),
                })
                .collect();
            // loads share the stream-engine/UCIe channel: serialize
            let secs: f64 = per_op.iter().map(|x| x.0).sum();
            let joules: f64 = per_op.iter().map(|x| x.1).sum();
            let longest = per_op.iter().map(|x| x.0).fold(0.0, f64::max);
            StepCost {
                secs,
                joules,
                min_visible: longest,
                kind: ResKind::Channel,
            }
        }
        Phase::StackXfer => {
            // sharded traces are dag-scheduled; cost the ops anyway so
            // a stray barrier pass stays total
            let mut secs = 0.0;
            let mut joules = 0.0;
            for op in &step.ops {
                match op {
                    Op::StackXfer { bytes } => {
                        let x = memsys::interstack(p, *bytes);
                        secs += x.secs;
                        joules += x.joules;
                    }
                    other => panic!("unexpected op {other:?} in StackXfer step"),
                }
            }
            StepCost {
                secs,
                joules,
                min_visible: secs,
                kind: ResKind::Channel,
            }
        }
        Phase::BoundaryBuild | Phase::Inject | Phase::Sync | Phase::Store => {
            let mut secs = 0.0;
            let mut joules = 0.0;
            for op in &step.ops {
                let x = match op {
                    Op::BuildBoundary {
                        nb,
                        cross_nnz,
                        gather_elems,
                    } => memsys::boundary_build(p, *nb, *cross_nnz, *gather_elems),
                    Op::Inject { n, nb } => {
                        let (c, e) = pcm::inject(p, *n, *nb);
                        memsys::Xfer {
                            secs: c as f64 * p.cycle_s(),
                            joules: e,
                        }
                    }
                    Op::SyncBoundary { bytes } => memsys::hbm(p, *bytes),
                    Op::StoreCsr {
                        dense_elems,
                        csr_bytes,
                    } => memsys::store_csr(p, *dense_elems, *csr_bytes),
                    Op::StoreDense { bytes } => memsys::fenand_write(p, *bytes),
                    Op::FetchBoundary { bytes } => memsys::fenand_read(p, *bytes),
                    Op::StoreRead { bytes } => memsys::fenand_read(p, *bytes),
                    Op::StoreWrite { bytes } => memsys::fenand_write(p, *bytes),
                    other => panic!("unexpected op {other:?} in {:?} step", step.phase),
                };
                secs += x.secs;
                joules += x.joules;
            }
            StepCost {
                secs,
                joules,
                min_visible: secs,
                kind: ResKind::Channel,
            }
        }
    }
}

// ---------------------------------------------------------------------
// Dependency-aware list scheduler over the tile-task DAG
// ---------------------------------------------------------------------

/// Which modeled resource a schedulable unit occupies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum UnitRes {
    /// PCM-FW die: `tiles_per_die` slots, malleable (longest-remaining-
    /// first fluid schedule — one tile per op, idle capacity shared).
    FwDie,
    /// PCM-MP die: aggregated merge batches already spread internally,
    /// so one batch owns the die at a time.
    MpDie,
    /// UCIe stream-in path (loads, dB injection).
    Ucie,
    /// HBM3 channel (boundary build, sync).
    Hbm,
    /// FeNAND channels (CSR store, dense store, boundary fetch).
    Fenand,
    /// The inter-stack interconnect: one capacity-1 channel shared by
    /// all stacks of a sharded run.
    Interstack,
    /// Pure dependency bookkeeping, zero cost.
    None,
}

/// One schedulable unit: a single hardware op from a task node.
struct SimUnit {
    res: UnitRes,
    secs: f64,
    joules: f64,
    phase: Phase,
    /// Component stream-in (subject to the prefetch ablation).
    is_load: bool,
}

/// Per-op resource + cost mapping; identical cost formulas to the
/// barrier scheduler's `step_cost`, so dynamic energy and total work do
/// not depend on the scheduler.
fn op_unit(op: &Op, phase: Phase, p: &HwParams) -> SimUnit {
    let (res, secs, joules, is_load) = match op {
        Op::TileFw { n, .. } => {
            let (c, e) = pcm::fw_tile(p, *n);
            (UnitRes::FwDie, c as f64 * p.cycle_s(), e, false)
        }
        Op::MpMergeAgg {
            stage1_madds,
            stage2_madds,
            rows,
            ..
        } => {
            let madds = stage1_madds + stage2_madds;
            let (c, e) =
                pcm::mp_merge_on_tile(p, madds.div_ceil(p.tiles_per_die as u64), *rows);
            (UnitRes::MpDie, c as f64 * p.cycle_s(), e, false)
        }
        Op::LoadComponent { n, nnz } => {
            let (c, e) = pcm::load_component(p, *n, *nnz);
            (UnitRes::Ucie, c as f64 * p.cycle_s(), e, true)
        }
        Op::Inject { n, nb } => {
            let (c, e) = pcm::inject(p, *n, *nb);
            (UnitRes::Ucie, c as f64 * p.cycle_s(), e, false)
        }
        Op::BuildBoundary {
            nb,
            cross_nnz,
            gather_elems,
        } => {
            let x = memsys::boundary_build(p, *nb, *cross_nnz, *gather_elems);
            (UnitRes::Hbm, x.secs, x.joules, false)
        }
        Op::SyncBoundary { bytes } => {
            let x = memsys::hbm(p, *bytes);
            (UnitRes::Hbm, x.secs, x.joules, false)
        }
        Op::StoreCsr {
            dense_elems,
            csr_bytes,
        } => {
            let x = memsys::store_csr(p, *dense_elems, *csr_bytes);
            (UnitRes::Fenand, x.secs, x.joules, false)
        }
        Op::StoreDense { bytes } => {
            let x = memsys::fenand_write(p, *bytes);
            (UnitRes::Fenand, x.secs, x.joules, false)
        }
        Op::FetchBoundary { bytes } => {
            let x = memsys::fenand_read(p, *bytes);
            (UnitRes::Fenand, x.secs, x.joules, false)
        }
        Op::StackXfer { bytes } => {
            let x = memsys::interstack(p, *bytes);
            (UnitRes::Interstack, x.secs, x.joules, false)
        }
        Op::StoreRead { bytes } => {
            let x = memsys::fenand_read(p, *bytes);
            (UnitRes::Fenand, x.secs, x.joules, false)
        }
        Op::StoreWrite { bytes } => {
            let x = memsys::fenand_write(p, *bytes);
            (UnitRes::Fenand, x.secs, x.joules, false)
        }
    };
    SimUnit {
        res,
        secs,
        joules,
        phase,
        is_load,
    }
}

/// Max-heap priority: critical-path seconds, ties broken by unit id for
/// determinism.
#[derive(PartialEq)]
struct Pri(f64, u32);
impl Eq for Pri {}
impl PartialOrd for Pri {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Pri {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0
            .total_cmp(&other.0)
            .then_with(|| other.1.cmp(&self.1))
    }
}

/// Total per-op busy seconds of a task graph (the schedule-independent
/// work measure: each op's duration on its own resource, summed). The
/// DAG report's per-phase seconds partition exactly this quantity.
pub fn total_op_seconds(tg: &TaskGraph, p: &HwParams) -> f64 {
    tg.nodes
        .iter()
        .flat_map(|n| n.ops.iter().map(|op| op_unit(op, n.phase, p).secs))
        .sum()
}

/// Per-graph attribution of a batch schedule, by node ownership.
#[derive(Debug, Clone, Copy, Default)]
pub struct GraphSimStat {
    /// Completion time of the graph's last unit in the shared schedule
    /// (its modeled latency inside the batch).
    pub makespan: f64,
    /// Summed busy seconds of the graph's units across all resources —
    /// the schedule-independent work measure
    /// (equals [`total_op_seconds`] of the solo task graph).
    pub busy: f64,
    /// Dynamic energy of the graph's ops. Schedule-independent: equals
    /// the graph's solo `dynamic_joules` exactly, and the per-graph
    /// values sum to the batch report's `dynamic_joules`.
    pub dynamic_joules: f64,
    /// Min-add candidates contributed by this graph.
    pub madds: u64,
}

/// Simulate a merged multi-graph batch ([`BatchGraph`]) on the shared
/// resource model. Returns the batch-level report (makespan, busy
/// times, total energy) plus the per-graph attribution.
pub fn simulate_batch(batch: &BatchGraph, p: &HwParams) -> (SimReport, Vec<GraphSimStat>) {
    let stack = vec![0u32; batch.merged.n_tasks()];
    simulate_dag_attributed(
        &batch.merged,
        &batch.owner,
        batch.n_graphs(),
        &stack,
        1,
        &[],
        usize::MAX,
        p,
    )
}

/// Simulate an admission workload: the merged admitted graphs on the
/// shared resource model, with every graph entering the schedule at
/// `max(arrival, first free queue slot)` — work submitted at `t`
/// cannot start (or occupy a channel) before `t`, at most
/// `queue_depth` graphs are in flight concurrently (the host
/// pipeline's bounded admission queue, enforced on the modeled
/// timeline too, so the memory guard's in-flight window is what the
/// simulator actually schedules), and everything already admitted
/// keeps running across every arrival, exactly like the live-spliced
/// ready queue. Arrival times come from the caller's configured
/// schedule (non-decreasing), never from wall-clock.
///
/// Returns the workload report plus per-graph stats whose `makespan`
/// is the graph's completion time on the shared timeline, so its
/// admit-to-complete latency is `makespan - arrivals[g]` (queue wait
/// included). Dynamic energy attribution is schedule-, arrival-, and
/// queue-independent (identical to [`simulate_batch`] on the same
/// merged graph).
pub fn simulate_admission(
    batch: &BatchGraph,
    arrivals: &[f64],
    queue_depth: usize,
    p: &HwParams,
) -> (SimReport, Vec<GraphSimStat>) {
    assert_eq!(arrivals.len(), batch.n_graphs(), "one arrival per graph");
    assert!(
        arrivals.windows(2).all(|w| w[0] <= w[1]),
        "arrival schedule must be non-decreasing"
    );
    assert!(queue_depth >= 1, "queue_depth must be >= 1");
    let stack = vec![0u32; batch.merged.n_tasks()];
    simulate_dag_attributed(
        &batch.merged,
        &batch.owner,
        batch.n_graphs(),
        &stack,
        1,
        arrivals,
        queue_depth,
        p,
    )
}

/// The drain-and-rebatch baseline for the same arrival-stamped
/// workload: a graph arriving while a batch is running waits for the
/// full drain, then everything queued up is merged into the next
/// batch-style union and submitted together. This is what a
/// coordinator without mid-flight admission has to do — the modeled
/// dies idle out every batch's tail while arrivals queue outside.
///
/// Arrivals must be non-decreasing. Returns the total makespan (last
/// completion on the shared timeline) and each graph's completion
/// time.
pub fn simulate_drain_rebatch(
    per_graph: &[TaskGraph],
    arrivals: &[f64],
    p: &HwParams,
) -> (f64, Vec<f64>) {
    assert_eq!(arrivals.len(), per_graph.len(), "one arrival per graph");
    assert!(
        arrivals.windows(2).all(|w| w[0] <= w[1]),
        "arrival schedule must be non-decreasing"
    );
    let n = per_graph.len();
    let mut completion = vec![0.0f64; n];
    let mut t = 0.0f64;
    let mut i = 0usize;
    while i < n {
        // the machine is free at t; the next batch starts when its
        // first graph has arrived and admits everything queued by then
        let start = t.max(arrivals[i]);
        let mut j = i + 1;
        while j < n && arrivals[j] <= start {
            j += 1;
        }
        // union the window's solo lowerings in place (no need for a
        // full BatchGraph — only the merged view and ownership matter)
        let mut merged = TaskGraph::default();
        let mut owner: Vec<u32> = Vec::new();
        for (k, tg) in per_graph[i..j].iter().enumerate() {
            merged.append_offset(tg);
            owner.resize(merged.nodes.len(), k as u32);
        }
        let stack = vec![0u32; merged.nodes.len()];
        let (rep, stats) =
            simulate_dag_attributed(&merged, &owner, j - i, &stack, 1, &[], usize::MAX, p);
        for (k, st) in stats.iter().enumerate() {
            completion[i + k] = start + st.makespan;
        }
        t = start + rep.seconds;
        i = j;
    }
    (t, completion)
}

/// Simulate a sharded run ([`ShardGraph`]): `num_stacks` replicated
/// FW/MP/UCIe/HBM/FeNAND resource sets (one per modeled stack) plus the
/// capacity-1 inter-stack interconnect serializing every `StackXfer`.
/// Returns the sharded report plus the per-stack attribution by node
/// affinity (makespan, busy work, dynamic energy — exactly as
/// [`simulate_batch`] attributes by owner, so the per-stack energies
/// partition the total bit-exactly).
pub fn simulate_sharded(shard: &ShardGraph, p: &HwParams) -> (SimReport, Vec<GraphSimStat>) {
    simulate_dag_attributed(
        &shard.sharded,
        &shard.affinity,
        shard.num_stacks,
        &shard.affinity,
        shard.num_stacks,
        &[],
        usize::MAX,
        p,
    )
}

/// Simulate a tile-task DAG with dependency-aware list scheduling.
///
/// Greedy, non-idling, critical-path-priority: a unit starts the moment
/// its dependencies are done and its resource has capacity. The FW die
/// is malleable: up to `tiles_per_die` units at rate 1, with
/// longest-remaining-first processor sharing on ties — which achieves
/// the same `max(total/tiles, longest)` bound the barrier model charges
/// per step, while letting independent levels overlap.
pub fn simulate_dag(tg: &TaskGraph, p: &HwParams) -> SimReport {
    let owner = vec![0u32; tg.n_tasks()];
    simulate_dag_attributed(tg, &owner, 1, &owner, 1, &[], usize::MAX, p).0
}

/// Attribute one delta repair: simulate the repair sub-DAG
/// ([`crate::apsp::taskgraph::lower_repair`]) and the full re-solve
/// lowering of the same plan on identical hardware, returning
/// `(repair, full)` — `full.seconds / repair.seconds` is the
/// `delta_speedup` the report and bench print. Both runs use the same
/// list scheduler, so the ratio isolates the dirty-closure savings from
/// any scheduling artifact.
pub fn simulate_delta(
    repair_tg: &TaskGraph,
    full_tg: &TaskGraph,
    p: &HwParams,
) -> (SimReport, SimReport) {
    (simulate_dag(repair_tg, p), simulate_dag(full_tg, p))
}

/// The list scheduler proper, with per-owner attribution (`owner[node]`
/// in `0..n_owners`; a solo run is a one-owner batch) and per-stack
/// resource placement (`stack[node]` in `0..n_stacks`: each stack has
/// its own FW die, MP die, and UCIe/HBM/FeNAND channels; the
/// inter-stack interconnect is one shared capacity-1 channel). Batch
/// runs attribute by graph on one stack; sharded runs attribute by
/// stack with `owner == stack`. `arrival[owner]` (empty = everything
/// available at t = 0) and `queue_depth` model the admission pipeline:
/// an owner's units enter the schedule only once it is **admitted** —
/// arrived on the modeled timeline *and* holding one of the
/// `queue_depth` in-flight slots, which frees when an owner's last
/// unit retires. Owners are admitted in index order (arrival order).
/// Late admission never stalls what is already running.
#[allow(clippy::too_many_arguments)]
fn simulate_dag_attributed(
    tg: &TaskGraph,
    owner: &[u32],
    n_owners: usize,
    stack: &[u32],
    n_stacks: usize,
    arrival: &[f64],
    queue_depth: usize,
    p: &HwParams,
) -> (SimReport, Vec<GraphSimStat>) {
    debug_assert!(arrival.is_empty() || arrival.len() == n_owners);
    // ---- explode tasks into op units, chaining ops within a task
    let mut units: Vec<SimUnit> = Vec::new();
    let mut unit_owner: Vec<u32> = Vec::new();
    let mut unit_stack: Vec<u32> = Vec::new();
    let mut deps: Vec<Vec<u32>> = Vec::new();
    let mut owner_units_left = vec![0usize; n_owners.max(1)];
    let mut last_unit_of_task: Vec<u32> = Vec::with_capacity(tg.nodes.len());
    for (ni, node) in tg.nodes.iter().enumerate() {
        let entry_deps: Vec<u32> = node
            .deps
            .iter()
            .map(|&t| last_unit_of_task[t as usize])
            .collect();
        if node.ops.is_empty() {
            units.push(SimUnit {
                res: UnitRes::None,
                secs: 0.0,
                joules: 0.0,
                phase: node.phase,
                is_load: false,
            });
            unit_owner.push(owner[ni]);
            unit_stack.push(stack[ni]);
            owner_units_left[owner[ni] as usize] += 1;
            deps.push(entry_deps);
        } else {
            for (oi, op) in node.ops.iter().enumerate() {
                units.push(op_unit(op, node.phase, p));
                unit_owner.push(owner[ni]);
                unit_stack.push(stack[ni]);
                owner_units_left[owner[ni] as usize] += 1;
                if oi == 0 {
                    deps.push(entry_deps.clone());
                } else {
                    deps.push(vec![(units.len() - 2) as u32]);
                }
            }
        }
        last_unit_of_task.push((units.len() - 1) as u32);
    }
    let n = units.len();
    if p.prefetch {
        // Double-buffered stream-in (dataflow step 3ii): a tile's FW
        // starts on already-streamed panels, so a component load
        // charges the UCIe channel but does not *block* its consumers —
        // the same hiding the barrier model patches in as a special
        // case, here expressed by bypassing load edges. Loads still
        // serialize on the channel and still bound the makespan.
        let bypass: Vec<Option<Vec<u32>>> = (0..n)
            .map(|i| units[i].is_load.then(|| deps[i].clone()))
            .collect();
        for i in 0..n {
            let mut inherited: Vec<u32> = Vec::new();
            deps[i].retain(|&d| {
                if let Some(up) = &bypass[d as usize] {
                    inherited.extend(up);
                    false
                } else {
                    true
                }
            });
            deps[i].extend(inherited);
            deps[i].sort_unstable();
            deps[i].dedup();
        }
    }
    let mut succs: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut indeg: Vec<usize> = vec![0; n];
    for (i, ds) in deps.iter().enumerate() {
        indeg[i] = ds.len();
        for &d in ds {
            succs[d as usize].push(i as u32);
        }
    }
    // critical-path length to a sink (units are in topological order)
    let mut cp = vec![0.0f64; n];
    for i in (0..n).rev() {
        let tail = succs[i].iter().map(|&s| cp[s as usize]).fold(0.0, f64::max);
        cp[i] = units[i].secs + tail;
    }

    // ---- schedule-independent accounting (per owner first, then the
    // totals as sums of the per-owner sums — so per-owner values are
    // bit-identical to a solo run and sum exactly to the total)
    let mut report = SimReport {
        stacks: n_stacks,
        ..SimReport::default()
    };
    let mut stats = vec![GraphSimStat::default(); n_owners];
    for (i, u) in units.iter().enumerate() {
        if u.res == UnitRes::None {
            continue;
        }
        let gs = &mut stats[unit_owner[i] as usize];
        gs.dynamic_joules += u.joules;
        gs.busy += u.secs;
        let stat = report.per_phase.entry(u.phase).or_default();
        stat.secs += u.secs;
        stat.joules += u.joules;
        stat.ops += 1;
    }
    report.dynamic_joules = stats.iter().map(|s| s.dynamic_joules).sum();

    // ---- event-driven list schedule over per-stack resource sets.
    // Channel kinds per stack, in fixed start/completion order:
    use std::collections::BinaryHeap;
    const MP: usize = 0;
    const UCIE: usize = 1;
    const HBM: usize = 2;
    const FENAND: usize = 3;
    let ch_idx = |r: UnitRes| -> usize {
        match r {
            UnitRes::MpDie => MP,
            UnitRes::Ucie => UCIE,
            UnitRes::Hbm => HBM,
            UnitRes::Fenand => FENAND,
            _ => unreachable!("not a per-stack channel"),
        }
    };
    let mut ready_ch: Vec<[BinaryHeap<Pri>; 4]> = (0..n_stacks)
        .map(|_| std::array::from_fn(|_| BinaryHeap::new()))
        .collect();
    let mut ready_fw: Vec<BinaryHeap<Pri>> = (0..n_stacks).map(|_| BinaryHeap::new()).collect();
    let mut ready_inter: BinaryHeap<Pri> = BinaryHeap::new();
    let mut zero_ready: Vec<u32> = Vec::new();
    // (unit, remaining) per stack's malleable FW die
    let mut fw_active: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n_stacks];
    let mut chan: Vec<[Option<(u32, f64)>; 4]> = vec![[None; 4]; n_stacks];
    let mut inter: Option<(u32, f64)> = None;

    let mut remaining = n;
    let mut done = vec![false; n];
    let mut time = 0.0f64;
    // ---- bounded-queue admission state: with no arrival schedule
    // every owner is admitted up front (plain batch semantics);
    // otherwise owners enter in index order as slots free up
    let gated = !arrival.is_empty();
    let mut owner_admitted = vec![!gated; n_owners.max(1)];
    let mut next_admit = if gated { 0 } else { n_owners };
    let mut in_flight = 0usize;
    // dependency-free units of a not-yet-admitted owner park here
    let mut waiting: Vec<Vec<u32>> = vec![Vec::new(); n_owners.max(1)];
    macro_rules! enqueue {
        ($u:expr) => {{
            let u: u32 = $u;
            if !owner_admitted[unit_owner[u as usize] as usize] {
                waiting[unit_owner[u as usize] as usize].push(u);
            } else {
                let unit = &units[u as usize];
                if unit.res == UnitRes::None || unit.secs <= 0.0 {
                    zero_ready.push(u);
                } else {
                    let pri = Pri(cp[u as usize], u);
                    match unit.res {
                        UnitRes::FwDie => ready_fw[unit_stack[u as usize] as usize].push(pri),
                        UnitRes::Interstack => ready_inter.push(pri),
                        r => ready_ch[unit_stack[u as usize] as usize][ch_idx(r)].push(pri),
                    }
                }
            }
        }};
    }
    for i in 0..n {
        if indeg[i] == 0 {
            enqueue!(i as u32);
        }
    }

    let tiles = p.tiles_per_die.max(1) as f64;
    let mut fw_busy = 0.0f64;
    let mut chan_busy = 0.0f64;
    let mut fenand_busy = 0.0f64;
    let mut interconnect_busy = 0.0f64;
    let mut load_fw_overlap = 0.0f64;

    let mut retired: Vec<u32> = Vec::new();
    loop {
        // retire zero-cost units and propagate readiness
        while let Some(u) = zero_ready.pop() {
            retired.push(u);
        }
        while let Some(u) = retired.pop() {
            if done[u as usize] {
                continue;
            }
            done[u as usize] = true;
            remaining -= 1;
            let o = unit_owner[u as usize] as usize;
            // per-owner completion: time is monotone, so the last
            // assignment is the owner's finish time in the schedule
            stats[o].makespan = time;
            owner_units_left[o] -= 1;
            if owner_units_left[o] == 0 {
                // the owner's last unit retired: its in-flight slot
                // frees for the next queued arrival
                in_flight = in_flight.saturating_sub(1);
            }
            for &s in &succs[u as usize] {
                indeg[s as usize] -= 1;
                if indeg[s as usize] == 0 {
                    enqueue!(s);
                }
            }
        }
        // bounded-queue admission on the modeled timeline: the next
        // arrival enters only once it has arrived *and* holds one of
        // the `queue_depth` in-flight slots — exactly the host
        // pipeline's queue semantics
        while next_admit < n_owners && in_flight < queue_depth && arrival[next_admit] <= time {
            owner_admitted[next_admit] = true;
            in_flight += 1;
            let parked = std::mem::take(&mut waiting[next_admit]);
            for u in parked {
                enqueue!(u);
            }
            next_admit += 1;
        }
        if !zero_ready.is_empty() {
            continue;
        }

        // start channel units (capacity 1 each per stack, critical path
        // first); with prefetch off, a component load may not start
        // while its stack's FW compute is running
        for s in 0..n_stacks {
            for ri in [MP, UCIE, HBM, FENAND] {
                if chan[s][ri].is_some() {
                    continue;
                }
                let q = &mut ready_ch[s][ri];
                let mut stash: Vec<Pri> = Vec::new();
                let mut started = None;
                while let Some(top) = q.pop() {
                    let u = top.1;
                    let blocked = !p.prefetch
                        && units[u as usize].is_load
                        && !fw_active[s].is_empty();
                    if blocked {
                        stash.push(top);
                    } else {
                        started = Some(u);
                        break;
                    }
                }
                for x in stash {
                    q.push(x);
                }
                if let Some(u) = started {
                    chan[s][ri] = Some((u, units[u as usize].secs));
                }
            }
        }
        // the inter-stack interconnect: one shared capacity-1 channel
        if inter.is_none() {
            if let Some(Pri(_, u)) = ready_inter.pop() {
                inter = Some((u, units[u as usize].secs));
            }
        }
        // admit FW units per stack (the die is malleable; admission
        // just makes them eligible for a tile slot), unless a
        // non-prefetch load is streaming into that stack
        for s in 0..n_stacks {
            let load_running =
                matches!(chan[s][UCIE], Some((u, _)) if units[u as usize].is_load);
            if p.prefetch || !load_running {
                while let Some(Pri(_, u)) = ready_fw[s].pop() {
                    fw_active[s].push((u, units[u as usize].secs));
                }
            }
        }

        // FW rate assignment per stack: longest-remaining-first, rate 1
        // per tile, processor sharing inside (near-)tied groups
        let mut rates: Vec<Vec<f64>> = Vec::with_capacity(n_stacks);
        for s in 0..n_stacks {
            let fa = &mut fw_active[s];
            fa.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
            let mut r = vec![0.0f64; fa.len()];
            let mut avail = tiles;
            let mut i = 0;
            while i < fa.len() && avail > 0.0 {
                // group (near-)equal remainings
                let mut j = i + 1;
                let rem = fa[i].1;
                while j < fa.len() && (rem - fa[j].1) <= rem * 1e-9 + 1e-18 {
                    j += 1;
                }
                let k = (j - i) as f64;
                let rate = (avail / k).min(1.0);
                for slot in r.iter_mut().take(j).skip(i) {
                    *slot = rate;
                }
                avail -= rate * k;
                i = j;
            }
            rates.push(r);
        }

        // next event
        let mut dt = f64::INFINITY;
        for ch in &chan {
            for v in ch.iter().flatten() {
                dt = dt.min(v.1);
            }
        }
        if let Some((_, rem)) = inter {
            dt = dt.min(rem);
        }
        for s in 0..n_stacks {
            let fa = &fw_active[s];
            for (i, &(_, rem)) in fa.iter().enumerate() {
                if rates[s][i] > 0.0 {
                    dt = dt.min(rem / rates[s][i]);
                    // merge event: a running group drains to the next
                    // (slower) group's remaining
                    if i + 1 < fa.len() && rates[s][i + 1] < rates[s][i] {
                        let gap = rem - fa[i + 1].1;
                        if gap > 0.0 {
                            let closing = rates[s][i] - rates[s][i + 1];
                            dt = dt.min(gap / closing);
                        }
                    }
                }
            }
        }
        // with a free queue slot, the next modeled arrival is a
        // schedulable event even while everything current is
        // mid-flight (a *full* queue instead wakes on a completion,
        // which is already a candidate above)
        if next_admit < n_owners && in_flight < queue_depth {
            let gap = arrival[next_admit] - time;
            if gap > 0.0 {
                dt = dt.min(gap);
            }
        }
        if dt == f64::INFINITY {
            assert_eq!(remaining, 0, "deadlock: {remaining} units unreachable");
            break;
        }

        // advance time + accounting (busy = wall time the resource has
        // >= 1 running unit, summed over stacks; the channel bucket
        // mirrors the barrier model's lumped UCIe/HBM/FeNAND
        // accounting)
        for s in 0..n_stacks {
            let load_running =
                matches!(chan[s][UCIE], Some((u, _)) if units[u as usize].is_load);
            let any_chan =
                chan[s][UCIE].is_some() || chan[s][HBM].is_some() || chan[s][FENAND].is_some();
            if !fw_active[s].is_empty() {
                fw_busy += dt;
            }
            if any_chan {
                chan_busy += dt;
            }
            if chan[s][FENAND].is_some() {
                fenand_busy += dt;
            }
            if load_running && !fw_active[s].is_empty() {
                load_fw_overlap += dt;
            }
            if chan[s][MP].is_some() {
                report.mp_busy += dt;
            }
        }
        if inter.is_some() {
            interconnect_busy += dt;
        }
        time += dt;
        for s in 0..n_stacks {
            for ri in [MP, UCIE, HBM, FENAND] {
                if let Some((u, rem)) = chan[s][ri] {
                    let rem = rem - dt;
                    if rem <= 1e-15 {
                        chan[s][ri] = None;
                        retired.push(u);
                    } else {
                        chan[s][ri] = Some((u, rem));
                    }
                }
            }
        }
        if let Some((u, rem)) = inter {
            let rem = rem - dt;
            if rem <= 1e-15 {
                inter = None;
                retired.push(u);
            } else {
                inter = Some((u, rem));
            }
        }
        for s in 0..n_stacks {
            let mut still: Vec<(u32, f64)> = Vec::with_capacity(fw_active[s].len());
            for (i, &(u, rem)) in fw_active[s].iter().enumerate() {
                let rem = rem - rates[s][i] * dt;
                if rem <= 1e-15 {
                    retired.push(u);
                } else {
                    still.push((u, rem));
                }
            }
            fw_active[s] = still;
        }
    }

    report.seconds = time;
    report.fw_busy = fw_busy;
    report.hbm_busy = chan_busy;
    report.fenand_busy = fenand_busy;
    report.interconnect_busy = interconnect_busy;
    report.prefetch_hidden = load_fw_overlap;
    for (ni, node) in tg.nodes.iter().enumerate() {
        stats[owner[ni] as usize].madds +=
            node.ops.iter().map(|op| op.madds()).sum::<u64>();
    }
    report.madds = stats.iter().map(|s| s.madds).sum();
    // static power draws in every replicated stack for the whole run;
    // the busy-based active terms are already summed over stacks
    report.joules = report.dynamic_joules
        + report.seconds * p.background_w * n_stacks as f64
        + report.hbm_busy * p.hbm_active_w
        + report.fenand_busy * p.fenand_active_w;
    (report, stats)
}

/// Spread uniform-ish ops across `tiles` parallel executors: makespan =
/// max(longest op, total/tiles) (LPT bound). Returns `(makespan_secs,
/// longest_single_secs, total_joules)`.
fn spread(p: &HwParams, per_op: &[(u64, f64)], tiles: u64) -> (f64, f64, f64) {
    let total_cycles: u64 = per_op.iter().map(|x| x.0).sum();
    let longest: u64 = per_op.iter().map(|x| x.0).max().unwrap_or(0);
    let joules: f64 = per_op.iter().map(|x| x.1).sum();
    let makespan = (total_cycles.div_ceil(tiles)).max(longest);
    (
        makespan as f64 * p.cycle_s(),
        longest as f64 * p.cycle_s(),
        joules,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::plan::{build_plan, PlanOptions};
    use crate::apsp::recursive::{solve, SolveOptions};
    use crate::apsp::taskgraph;
    use crate::graph::generators::{self, Topology, Weights};

    fn graph_for(
        n: usize,
        topo: Topology,
        seed: u64,
    ) -> (crate::CsrGraph, crate::apsp::plan::ApspPlan) {
        let g = generators::generate(topo, n, 12.0, Weights::Uniform(1.0, 4.0), seed);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 128,
                max_depth: usize::MAX,
                seed,
            },
        );
        (g, plan)
    }

    fn trace_for(n: usize, topo: Topology, seed: u64) -> Trace {
        let g = generators::generate(topo, n, 12.0, Weights::Uniform(1.0, 4.0), seed);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 128,
                max_depth: usize::MAX,
                seed,
            },
        );
        solve(&g, &plan, None, SolveOptions::default()).trace
    }

    #[test]
    fn nonzero_time_and_energy() {
        let t = trace_for(1000, Topology::Nws, 1);
        let r = simulate(&t, &HwParams::default());
        assert!(r.seconds > 0.0);
        assert!(r.joules > r.dynamic_joules);
        assert!(r.madds > 0);
        assert!(r.fw_utilization() <= 1.0 + 1e-9);
    }

    #[test]
    fn bigger_graph_costs_more() {
        let p = HwParams::default();
        let a = simulate(&trace_for(600, Topology::Nws, 2), &p);
        let b = simulate(&trace_for(2400, Topology::Nws, 2), &p);
        assert!(b.seconds > a.seconds);
        assert!(b.joules > a.joules);
    }

    #[test]
    fn prefetch_hides_load_time() {
        let t = trace_for(2000, Topology::Nws, 3);
        let on = simulate(&t, &HwParams::default());
        let off = simulate(
            &t,
            &HwParams {
                prefetch: false,
                ..HwParams::default()
            },
        );
        assert!(on.seconds < off.seconds, "{} !< {}", on.seconds, off.seconds);
        assert!(on.prefetch_hidden > 0.0);
        assert_eq!(off.prefetch_hidden, 0.0);
        // energy unaffected by overlap (same dynamic work)
        assert!((on.dynamic_joules - off.dynamic_joules).abs() < 1e-12);
    }

    #[test]
    fn permutation_unit_ablation_slows_fw() {
        let t = trace_for(2000, Topology::Nws, 4);
        let on = simulate(&t, &HwParams::default());
        let off = simulate(
            &t,
            &HwParams {
                permutation_unit: false,
                ..HwParams::default()
            },
        );
        let fw_on = on.per_phase[&Phase::LocalFw].secs;
        let fw_off = off.per_phase[&Phase::LocalFw].secs;
        assert!(fw_off > 2.0 * fw_on, "{fw_off} vs {fw_on}");
    }

    #[test]
    fn per_phase_adds_up() {
        let t = trace_for(1500, Topology::OgbnProxy, 5);
        let r = simulate(&t, &HwParams::default());
        let sum: f64 = r.per_phase.values().map(|s| s.secs).sum();
        assert!((sum - r.seconds).abs() < 1e-9);
        let esum: f64 = r.per_phase.values().map(|s| s.joules).sum();
        assert!((esum - r.dynamic_joules).abs() < 1e-9);
    }

    #[test]
    fn dag_schedule_never_worse_than_barrier() {
        for (topo, n, seed) in [
            (Topology::Nws, 2_000usize, 11u64),
            (Topology::OgbnProxy, 3_000, 12),
            (Topology::Er, 1_500, 13),
            (Topology::Grid, 1_600, 14),
        ] {
            let (_, plan) = graph_for(n, topo, seed);
            let tg = taskgraph::lower(&plan);
            for prefetch in [true, false] {
                let p = HwParams {
                    prefetch,
                    ..HwParams::default()
                };
                let barrier = simulate(&tg.to_trace(), &p);
                let dag = simulate_dag(&tg, &p);
                assert!(
                    dag.seconds <= barrier.seconds * (1.0 + 1e-9),
                    "{} n={n} prefetch={prefetch}: dag {} > barrier {}",
                    topo.name(),
                    dag.seconds,
                    barrier.seconds
                );
                // identical dynamic work regardless of schedule
                assert!((dag.dynamic_joules - barrier.dynamic_joules).abs() < 1e-9);
                assert_eq!(dag.madds, barrier.madds);
            }
        }
    }

    #[test]
    fn dag_per_phase_sums_to_busy_work() {
        let (_, plan) = graph_for(2_500, Topology::OgbnProxy, 15);
        let tg = taskgraph::lower(&plan);
        let p = HwParams::default();
        let r = simulate_dag(&tg, &p);
        // per-phase seconds are per-resource busy work; their sum must
        // equal the independently computed total op time
        let phase_sum: f64 = r.per_phase.values().map(|s| s.secs).sum();
        let total_work = total_op_seconds(&tg, &p);
        assert!(
            (phase_sum - total_work).abs() <= 1e-9 * phase_sum.max(1.0),
            "phase work {phase_sum} != total op work {total_work}"
        );
        // energy accounting consistent
        let esum: f64 = r.per_phase.values().map(|s| s.joules).sum();
        assert!((esum - r.dynamic_joules).abs() < 1e-9);
        // wall time bounded below by every resource occupancy
        assert!(r.seconds + 1e-12 >= r.fw_busy);
        assert!(r.seconds + 1e-12 >= r.mp_busy);
        assert!(r.seconds + 1e-12 >= r.hbm_busy);
    }

    #[test]
    fn dag_schedule_deterministic() {
        let (_, plan) = graph_for(1_800, Topology::Nws, 16);
        let tg = taskgraph::lower(&plan);
        let p = HwParams::default();
        let a = simulate_dag(&tg, &p);
        let b = simulate_dag(&tg, &p);
        assert_eq!(a.seconds, b.seconds);
        assert_eq!(a.joules, b.joules);
        assert_eq!(a.fw_busy, b.fw_busy);
    }

    #[test]
    fn dag_prefetch_off_costs_at_least_as_much() {
        let (_, plan) = graph_for(2_200, Topology::Nws, 17);
        let tg = taskgraph::lower(&plan);
        let on = simulate_dag(&tg, &HwParams::default());
        let off = simulate_dag(
            &tg,
            &HwParams {
                prefetch: false,
                ..HwParams::default()
            },
        );
        assert!(off.seconds >= on.seconds - 1e-12);
        // same dynamic work either way
        assert!((on.dynamic_joules - off.dynamic_joules).abs() < 1e-12);
        // with prefetch on, some load time hides under FW compute
        assert!(on.prefetch_hidden > 0.0);
        assert_eq!(off.prefetch_hidden, 0.0);
    }

    #[test]
    fn batch_sim_attribution_is_schedule_independent() {
        use crate::apsp::batch::BatchGraph;
        let tgs: Vec<TaskGraph> = [
            (Topology::Nws, 2_000usize, 31u64),
            (Topology::OgbnProxy, 2_500, 32),
            (Topology::Er, 1_500, 33),
            (Topology::Grid, 1_600, 34),
        ]
        .iter()
        .map(|&(topo, n, seed)| {
            let (_, plan) = graph_for(n, topo, seed);
            taskgraph::lower(&plan)
        })
        .collect();
        let p = HwParams::default();
        let solos: Vec<SimReport> = tgs.iter().map(|tg| simulate_dag(tg, &p)).collect();
        let batch = BatchGraph::merge(tgs);
        let (rep, stats) = simulate_batch(&batch, &p);
        // makespan between the longest solo run and the serial sum
        let sum: f64 = solos.iter().map(|s| s.seconds).sum();
        let longest = solos.iter().map(|s| s.seconds).fold(0.0, f64::max);
        assert!(
            rep.seconds <= sum * (1.0 + 1e-9),
            "batch {} > serial sum {sum}",
            rep.seconds
        );
        assert!(
            rep.seconds >= longest * (1.0 - 1e-9),
            "batch {} < longest solo {longest}",
            rep.seconds
        );
        // per-graph attribution is schedule-independent
        for (i, (st, solo)) in stats.iter().zip(&solos).enumerate() {
            assert_eq!(
                st.dynamic_joules, solo.dynamic_joules,
                "graph {i}: batch energy attribution != solo energy"
            );
            assert_eq!(st.madds, solo.madds, "graph {i}");
            assert!(st.makespan <= rep.seconds + 1e-12, "graph {i}");
            assert!(st.makespan > 0.0, "graph {i}");
            let work = total_op_seconds(&batch.per_graph[i], &p);
            assert!(
                (st.busy - work).abs() <= 1e-9 * work.max(1.0),
                "graph {i}: busy {} != op work {work}",
                st.busy
            );
        }
        // per-graph attribution partitions the batch totals exactly
        let esum: f64 = stats.iter().map(|s| s.dynamic_joules).sum();
        assert_eq!(esum, rep.dynamic_joules);
        assert_eq!(stats.iter().map(|s| s.madds).sum::<u64>(), rep.madds);
    }

    fn admission_workload(seeds: &[u64]) -> Vec<TaskGraph> {
        let topos = [
            Topology::Nws,
            Topology::OgbnProxy,
            Topology::Er,
            Topology::Grid,
        ];
        seeds
            .iter()
            .enumerate()
            .map(|(i, &seed)| {
                let (_, plan) = graph_for(1_500 + 400 * i, topos[i % topos.len()], seed);
                taskgraph::lower(&plan)
            })
            .collect()
    }

    #[test]
    fn admission_with_zero_arrivals_matches_batch() {
        use crate::apsp::batch::BatchGraph;
        let batch = BatchGraph::merge(admission_workload(&[41, 42, 43]));
        let p = HwParams::default();
        let (br, bs) = simulate_batch(&batch, &p);
        let arrivals = vec![0.0; batch.n_graphs()];
        let (ar, asx) = simulate_admission(&batch, &arrivals, batch.n_graphs(), &p);
        // arriving at t = 0 with a deep-enough queue is exactly a
        // batch submission
        assert_eq!(ar.seconds, br.seconds);
        assert_eq!(ar.joules, br.joules);
        assert_eq!(ar.fw_busy, br.fw_busy);
        for (a, b) in asx.iter().zip(&bs) {
            assert_eq!(a.makespan, b.makespan);
            assert_eq!(a.dynamic_joules, b.dynamic_joules);
            assert_eq!(a.busy, b.busy);
        }
    }

    #[test]
    fn admission_staggered_respects_arrivals_and_partitions_energy() {
        use crate::apsp::batch::BatchGraph;
        let batch = BatchGraph::merge(admission_workload(&[44, 45, 46, 47]));
        let p = HwParams::default();
        let solos: Vec<SimReport> = batch
            .per_graph
            .iter()
            .map(|tg| simulate_dag(tg, &p))
            .collect();
        let first = solos[0].seconds;
        let arrivals: Vec<f64> = (0..batch.n_graphs())
            .map(|i| i as f64 * 0.2 * first)
            .collect();
        let (rep, stats) = simulate_admission(&batch, &arrivals, batch.n_graphs(), &p);
        let (batch_rep, _) = simulate_batch(&batch, &p);
        // delayed releases can only stretch the shared schedule
        assert!(rep.seconds >= batch_rep.seconds - 1e-12);
        for (i, st) in stats.iter().enumerate() {
            // completion never precedes arrival: released units cannot
            // start before the graph exists
            assert!(
                st.makespan > arrivals[i],
                "graph {i}: finish {} precedes arrival {}",
                st.makespan,
                arrivals[i]
            );
            assert!(st.makespan <= rep.seconds + 1e-12, "graph {i}");
            // dynamic energy attribution is arrival-independent
            assert_eq!(st.dynamic_joules, solos[i].dynamic_joules, "graph {i}");
            assert_eq!(st.madds, solos[i].madds, "graph {i}");
        }
        let esum: f64 = stats.iter().map(|s| s.dynamic_joules).sum();
        assert_eq!(esum, rep.dynamic_joules);
        // a graph arriving after everything else finished runs alone:
        // total = its arrival + its solo makespan
        let far = rep.seconds * 10.0;
        let mut late = arrivals.clone();
        let last = late.len() - 1;
        late[last] = far;
        let (lrep, lstats) = simulate_admission(&batch, &late, batch.n_graphs(), &p);
        assert!(
            (lstats[last].makespan - (far + solos[last].seconds)).abs()
                <= 1e-9 * lrep.seconds.max(1.0),
            "late graph must run at solo speed: {} vs {}",
            lstats[last].makespan,
            far + solos[last].seconds
        );
    }

    #[test]
    fn admission_beats_drain_rebatch_on_staggered_arrivals() {
        use crate::apsp::batch::BatchGraph;
        let batch = BatchGraph::merge(admission_workload(&[48, 49, 50, 51, 52, 53]));
        let p = HwParams::default();
        let first = simulate_dag(&batch.per_graph[0], &p).seconds;
        let arrivals: Vec<f64> = (0..batch.n_graphs())
            .map(|i| i as f64 * 0.15 * first)
            .collect();
        let (rep, stats) = simulate_admission(&batch, &arrivals, batch.n_graphs(), &p);
        let (drain, drain_completion) = simulate_drain_rebatch(&batch.per_graph, &arrivals, &p);
        assert!(
            rep.seconds < drain,
            "live admission {} !< drain-and-rebatch {drain}",
            rep.seconds
        );
        // per-graph: completing inside the live schedule never waits
        // longer than queuing outside a draining one... on average
        let live_sum: f64 = stats.iter().map(|s| s.makespan).sum();
        let drain_sum: f64 = drain_completion.iter().sum();
        assert!(
            live_sum <= drain_sum * (1.0 + 1e-9),
            "live completions {live_sum} > drain completions {drain_sum}"
        );
    }

    #[test]
    fn admission_queue_depth_bounds_concurrency() {
        use crate::apsp::batch::BatchGraph;
        let batch = BatchGraph::merge(admission_workload(&[57, 58, 59]));
        let p = HwParams::default();
        let zeros = vec![0.0; batch.n_graphs()];
        let solos: Vec<f64> = batch
            .per_graph
            .iter()
            .map(|tg| simulate_dag(tg, &p).seconds)
            .collect();
        // queue depth 1 strictly serializes: each graph runs alone on
        // an empty machine, so completions are the solo prefix sums
        let (rep1, stats1) = simulate_admission(&batch, &zeros, 1, &p);
        let total: f64 = solos.iter().sum();
        let mut prefix = 0.0;
        for (i, st) in stats1.iter().enumerate() {
            prefix += solos[i];
            assert!(
                (st.makespan - prefix).abs() <= 1e-9 * total,
                "graph {i}: queue-1 finish {} != prefix sum {prefix}",
                st.makespan
            );
        }
        assert!((rep1.seconds - total).abs() <= 1e-9 * total);
        // a deeper queue can only help, and the unbounded queue is the
        // batch schedule
        let (rep2, _) = simulate_admission(&batch, &zeros, 2, &p);
        let (repn, _) = simulate_admission(&batch, &zeros, batch.n_graphs(), &p);
        let (batch_rep, _) = simulate_batch(&batch, &p);
        assert!(rep2.seconds <= rep1.seconds * (1.0 + 1e-9));
        assert!(repn.seconds <= rep2.seconds * (1.0 + 1e-9));
        assert_eq!(repn.seconds, batch_rep.seconds);
        // dynamic energy is queue-independent
        assert!((rep1.dynamic_joules - batch_rep.dynamic_joules).abs() < 1e-9);
    }

    #[test]
    fn drain_rebatch_degenerates_correctly() {
        use crate::apsp::batch::BatchGraph;
        let batch = BatchGraph::merge(admission_workload(&[54, 55, 56]));
        let p = HwParams::default();
        // all at t=0: one batch, identical to simulate_batch
        let zeros = vec![0.0; batch.n_graphs()];
        let (drain, completion) = simulate_drain_rebatch(&batch.per_graph, &zeros, &p);
        let (rep, stats) = simulate_batch(&batch, &p);
        assert_eq!(drain, rep.seconds);
        for (c, s) in completion.iter().zip(&stats) {
            assert_eq!(*c, s.makespan);
        }
        // arrivals spaced far apart: every graph runs alone
        let solos: Vec<f64> = batch
            .per_graph
            .iter()
            .map(|tg| simulate_dag(tg, &p).seconds)
            .collect();
        let gap: f64 = solos.iter().sum::<f64>() * 2.0;
        let spaced: Vec<f64> = (0..batch.n_graphs()).map(|i| i as f64 * gap).collect();
        let (_, spaced_completion) = simulate_drain_rebatch(&batch.per_graph, &spaced, &p);
        for i in 0..batch.n_graphs() {
            assert!(
                (spaced_completion[i] - (spaced[i] + solos[i])).abs() <= 1e-9 * gap,
                "graph {i}: {} vs {}",
                spaced_completion[i],
                spaced[i] + solos[i]
            );
        }
    }

    #[test]
    fn clustered_beats_random_in_sim() {
        // the Fig. 9(c,f) mechanism: fewer boundary vertices => less
        // boundary/merge work => faster + cheaper. The effect needs
        // paper-scale tiles and a graph big enough that the boundary
        // dominates (at toy sizes the terminal dense solve is free
        // either way).
        let hw = HwParams::default();
        let mk = |topo| {
            let g = generators::generate(topo, 24_000, 20.0, Weights::Uniform(1.0, 4.0), 6);
            let plan = build_plan(
                &g,
                PlanOptions {
                    tile_limit: 1024,
                    max_depth: usize::MAX,
                    seed: 6,
                },
            );
            solve(&g, &plan, None, SolveOptions::default()).trace
        };
        let clustered = simulate(&mk(Topology::OgbnProxy), &hw);
        let random = simulate(&mk(Topology::Er), &hw);
        assert!(
            clustered.seconds < random.seconds,
            "clustered {} !< random {}",
            clustered.seconds,
            random.seconds
        );
    }
}
