//! Area/power breakdown per PCM unit — reproduces Table III.
//!
//! The per-component constants are transcribed from the paper (40 nm
//! synthesis scaled to 14 nm with [37]); this module re-derives the
//! percentage splits and die-level totals the paper reports, so the
//! bench prints the same rows.

use super::params::HwParams;

/// One Table III row.
#[derive(Debug, Clone, PartialEq)]
pub struct UnitComponent {
    pub name: &'static str,
    pub area_um2: f64,
    pub power_mw: f64,
}

/// Per-unit breakdown for one die flavor.
#[derive(Debug, Clone)]
pub struct UnitBreakdown {
    pub die: &'static str,
    pub components: Vec<UnitComponent>,
}

impl UnitBreakdown {
    pub fn total_area_um2(&self) -> f64 {
        self.components.iter().map(|c| c.area_um2).sum()
    }
    pub fn total_power_mw(&self) -> f64 {
        self.components.iter().map(|c| c.power_mw).sum()
    }
    /// Percentage splits, same order as `components`.
    pub fn area_pct(&self) -> Vec<f64> {
        let t = self.total_area_um2();
        self.components.iter().map(|c| 100.0 * c.area_um2 / t).collect()
    }
    pub fn power_pct(&self) -> Vec<f64> {
        let t = self.total_power_mw();
        self.components.iter().map(|c| 100.0 * c.power_mw / t).collect()
    }
}

/// Table III, PCM-FW column.
pub fn pcm_fw_unit() -> UnitBreakdown {
    UnitBreakdown {
        die: "PCM-FW",
        components: vec![
            UnitComponent {
                name: "PCM Subarray",
                area_um2: 3288.0,
                power_mw: 557.0,
            },
            UnitComponent {
                name: "Permutation Unit",
                area_um2: 917.3,
                power_mw: 0.586,
            },
            UnitComponent {
                name: "Controller",
                area_um2: 5.94,
                power_mw: 0.00126,
            },
            UnitComponent {
                name: "Others",
                area_um2: 19610.0,
                power_mw: 133.29,
            },
        ],
    }
}

/// Table III, PCM-MP column.
pub fn pcm_mp_unit() -> UnitBreakdown {
    UnitBreakdown {
        die: "PCM-MP",
        components: vec![
            UnitComponent {
                name: "PCM Subarray",
                area_um2: 3288.0,
                power_mw: 557.0,
            },
            UnitComponent {
                name: "Min Comparator",
                area_um2: 1268.0,
                power_mw: 0.684,
            },
            UnitComponent {
                name: "Controller",
                area_um2: 5.94,
                power_mw: 0.00126,
            },
            UnitComponent {
                name: "Others",
                area_um2: 19610.0,
                power_mw: 133.29,
            },
        ],
    }
}

/// System-level supporting components (paper §IV-B).
#[derive(Debug, Clone, PartialEq)]
pub struct SystemComponent {
    pub name: &'static str,
    pub power_w: f64,
    pub area_mm2: f64,
}

pub fn system_components() -> Vec<SystemComponent> {
    vec![
        SystemComponent {
            name: "HBM3 (16 GB)",
            power_w: 8.6,
            area_mm2: 121.0,
        },
        SystemComponent {
            name: "FeNAND (16 TB)",
            power_w: 6.4,
            area_mm2: 3000.0,
        },
        SystemComponent {
            name: "SM2508 controller",
            power_w: 3.5,
            area_mm2: 225.0,
        },
    ]
}

/// Die-level totals derived from the unit breakdown and geometry.
pub fn die_area_mm2(p: &HwParams, unit: &UnitBreakdown) -> f64 {
    unit.total_area_um2() * p.units_per_tile as f64 * p.tiles_per_die as f64 / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_match_table_iii() {
        let fw = pcm_fw_unit();
        assert!((fw.total_area_um2() - 23821.24).abs() < 1.0);
        assert!((fw.total_power_mw() - 690.88).abs() < 0.5);
        let mp = pcm_mp_unit();
        assert!((mp.total_area_um2() - 24171.94).abs() < 1.0);
        assert!((mp.total_power_mw() - 690.98).abs() < 0.5);
    }

    #[test]
    fn peripheral_dominates_area() {
        // paper: "82% of unit area stems from peripheral circuits"
        let fw = pcm_fw_unit();
        let pct = fw.area_pct();
        let others = fw
            .components
            .iter()
            .position(|c| c.name == "Others")
            .unwrap();
        assert!(pct[others] > 80.0 && pct[others] < 84.0, "{}", pct[others]);
    }

    #[test]
    fn subarray_dominates_power() {
        // paper: subarray ≈ 80.6% of unit power
        let mp = pcm_mp_unit();
        let pct = mp.power_pct();
        assert!(pct[0] > 79.0 && pct[0] < 82.0, "{}", pct[0]);
    }

    #[test]
    fn compute_units_negligible() {
        let fw = pcm_fw_unit();
        let perm_pct = fw.power_pct()[1];
        assert!(perm_pct < 0.2, "permutation unit power {perm_pct}%");
        let mp = pcm_mp_unit();
        let tree_pct = mp.power_pct()[1];
        assert!(tree_pct < 0.2, "comparator tree power {tree_pct}%");
    }

    #[test]
    fn system_power_near_paper_total() {
        // paper: "total power of ~18.5 W" for the supporting components
        let total: f64 = system_components().iter().map(|c| c.power_w).sum();
        assert!((total - 18.5).abs() < 0.1, "{total}");
    }

    #[test]
    fn die_area_sane() {
        let p = HwParams::default();
        let a = die_area_mm2(&p, &pcm_fw_unit());
        assert!(a > 100.0 && a < 1000.0, "{a} mm^2");
    }
}
