//! Cycle-level simulator of the RAPID-Graph hardware (paper §III-B/C/D,
//! Table II/III, §IV-B) — the substitute for the authors' in-house
//! cycle-accurate simulator + NeuroSim + synthesized RTL, none of which
//! exist on this machine.
//!
//! The simulator consumes the [`crate::apsp::trace::Trace`] emitted by
//! the recursive solver and charges cycles + energy for each op on the
//! modeled dies:
//!
//! * [`params`]  — every device/system constant, transcribed from the
//!   paper (Sb2Te3/Ge4Sb6Te7 SLC PCM, FELIX op latencies, comparator
//!   tree, UCIe v1.0, HBM3, FeNAND) with the calibration notes.
//! * [`pcm`]     — PCM-FW / PCM-MP die op cost functions.
//! * [`memsys`]  — UCIe, HBM3, FeNAND, logic-die stream engine transfers.
//! * [`area`]    — Table III (area/power per PCM unit) reproduction.
//! * [`engine`]  — schedules trace steps onto tiles and accumulates the
//!   timeline + energy, with optional load/compute prefetch overlap.

pub mod area;
pub mod engine;
pub mod memsys;
pub mod params;
pub mod pcm;

pub use engine::{
    simulate, simulate_admission, simulate_batch, simulate_dag, simulate_drain_rebatch,
    simulate_sharded, GraphSimStat, SimReport,
};
pub use params::HwParams;
