//! Memory-system cost functions: UCIe, HBM3, FeNAND, logic-die stream
//! engines (paper §III-B, Fig. 4).

use super::params::HwParams;

/// A `(seconds, joules)` transfer cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Xfer {
    pub secs: f64,
    pub joules: f64,
}

impl Xfer {
    pub fn zero() -> Self {
        Self {
            secs: 0.0,
            joules: 0.0,
        }
    }
    pub fn cycles(&self, p: &HwParams) -> u64 {
        (self.secs * p.clock_hz).ceil() as u64
    }
}

/// HBM3 read or write of `bytes`.
pub fn hbm(p: &HwParams, bytes: u64) -> Xfer {
    Xfer {
        secs: bytes as f64 / p.hbm_bytes_per_s(),
        joules: bytes as f64 * 8.0 * p.hbm_pj_per_bit * 1e-12,
    }
}

/// UCIe die-to-die transfer of `bytes`.
pub fn ucie(p: &HwParams, bytes: u64) -> Xfer {
    Xfer {
        secs: bytes as f64 / p.ucie_bytes_per_s(),
        joules: bytes as f64 * 8.0 * p.ucie_pj_per_bit * 1e-12,
    }
}

/// Inter-stack interconnect transfer of `bytes` (sharded execution:
/// boundary matrices to the hub stack, dB slices back).
pub fn interstack(p: &HwParams, bytes: u64) -> Xfer {
    Xfer {
        secs: bytes as f64 / p.interstack_bytes_per_s(),
        joules: bytes as f64 * 8.0 * p.interstack_pj_per_bit * 1e-12,
    }
}

/// FeNAND read of `bytes` (ONFI channels, interleaved).
pub fn fenand_read(p: &HwParams, bytes: u64) -> Xfer {
    Xfer {
        secs: bytes as f64 / p.fenand_read_bytes_per_s(),
        joules: bytes as f64 * 8.0 * p.fenand_read_pj_per_bit * 1e-12,
    }
}

/// FeNAND program of `bytes`.
pub fn fenand_write(p: &HwParams, bytes: u64) -> Xfer {
    Xfer {
        secs: bytes as f64 / p.fenand_write_bytes_per_s(),
        joules: bytes as f64 * 8.0 * p.fenand_write_pj_per_bit * 1e-12,
    }
}

/// Logic-die stream-engine conversion (CSR <-> dense) of `bytes`.
pub fn stream_convert(p: &HwParams, bytes: u64) -> Xfer {
    Xfer {
        secs: bytes as f64 / p.stream_bytes_per_s(),
        // conversion itself is register shuffling; charge UCIe-class
        // energy for the on-die movement
        joules: bytes as f64 * 8.0 * 0.1e-12,
    }
}

/// Boundary-graph assembly in HBM (dataflow step 3i): gather the
/// per-component boundary blocks + cross edges, write G_B back.
pub fn boundary_build(p: &HwParams, nb: u64, cross_nnz: u64, gather_elems: u64) -> Xfer {
    let bytes = gather_elems * 4 + cross_nnz * 12 + nb * nb * 4;
    let h = hbm(p, bytes);
    let u = ucie(p, gather_elems * 4);
    Xfer {
        secs: h.secs + u.secs,
        joules: h.joules + u.joules,
    }
}

/// Store a dense matrix region compressed to CSR (dataflow step 6):
/// logic-die compression + FeNAND program.
pub fn store_csr(p: &HwParams, dense_elems: u64, csr_bytes: u64) -> Xfer {
    let conv = stream_convert(p, dense_elems * 4);
    let wr = fenand_write(p, csr_bytes);
    let u = ucie(p, dense_elems * 4);
    Xfer {
        // conversion and program pipeline; the slower stage dominates
        secs: conv.secs.max(wr.secs) + u.secs,
        joules: conv.joules + wr.joules + u.joules,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_ordering_reflected_in_time() {
        let p = HwParams::default();
        let bytes = 1 << 30;
        assert!(hbm(&p, bytes).secs < ucie(&p, bytes).secs * 5.0);
        assert!(fenand_write(&p, bytes).secs > fenand_read(&p, bytes).secs);
        assert!(fenand_read(&p, bytes).secs > hbm(&p, bytes).secs);
    }

    #[test]
    fn energy_linear_in_bytes() {
        let p = HwParams::default();
        let a = hbm(&p, 1000).joules;
        let b = hbm(&p, 2000).joules;
        assert!((b / a - 2.0).abs() < 1e-9);
    }

    #[test]
    fn store_csr_dominated_by_slowest_stage() {
        let p = HwParams::default();
        let x = store_csr(&p, 1 << 20, 8 << 20);
        let wr = fenand_write(&p, 8 << 20);
        assert!(x.secs >= wr.secs);
    }

    #[test]
    fn zero_bytes_zero_cost() {
        let p = HwParams::default();
        assert_eq!(hbm(&p, 0), Xfer::zero());
        assert_eq!(ucie(&p, 0).cycles(&p), 0);
    }
}
