//! PCM compute-die cost functions: what one op costs on one tile.
//!
//! All functions return `(cycles, joules)` for executing the op on a
//! single tile; the engine divides work across tiles per step.

use super::params::HwParams;

/// Cost of a full FW pass over an `n x n` block on the PCM-FW die
/// (paper Fig. 6b/c: n pivots, each = one parallel add + one parallel
/// min + a panel permutation).
///
/// Blocks up to `unit_dim` live in one tile and update all cells per
/// pivot in parallel. Larger blocks (a terminal boundary graph that
/// refused to shrink — the random-topology worst case) fall back to
/// blocked FW across the whole die: each pivot must update
/// `ceil(n/unit_dim)^2` tile-blocks, `tiles_per_die` at a time.
pub fn fw_tile(p: &HwParams, n: u64) -> (u64, f64) {
    if n <= 1 {
        return (0, 0.0);
    }
    let ud = p.unit_dim as u64;
    let madds = n * n * n;
    let mut energy = madds as f64 * p.fw_pj_per_madd * 1e-12;
    let cycles = if n <= ud {
        n * p.fw_pivot_cycles(n)
    } else {
        // blocked FW across the die: each of the `rounds` block-pivot
        // rounds updates all blocks (3 phases), `tiles_per_die` at a time
        let rounds = n.div_ceil(ud);
        let blocks = rounds * rounds;
        let waves = blocks.div_ceil(p.tiles_per_die as u64);
        let compute = n * p.fw_pivot_cycles(ud) * waves;
        // the matrix exceeds what the die can hold resident once
        // 4n^2 approaches the 2 GB die; blocks stream HBM <-> PCM every
        // round (3 phase touches) — this is the cost the recursion
        // exists to avoid (paper §III-A)
        let bytes = rounds * 3 * n * n * 4;
        let hbm_bytes_per_cycle = (p.hbm_bytes_per_s() / p.clock_hz).max(1.0);
        let stream = (bytes as f64 / hbm_bytes_per_cycle).ceil() as u64;
        energy += bytes as f64 * 8.0 * (p.hbm_pj_per_bit + p.ucie_pj_per_bit) * 1e-12;
        compute.max(stream)
    };
    (cycles, energy)
}

/// Cost of streaming a component in and densifying it (dataflow step 1):
/// CSR read from the PCM cold region + logic-die expansion + dense
/// write-back into the compute region.
pub fn load_component(p: &HwParams, n: u64, nnz: u64) -> (u64, f64) {
    let csr_bytes = nnz * 8 + n * 8;
    let dense_bytes = n * n * 4;
    // logic-die stream engine converts at stream_bytes_per_s; PCM write
    // bandwidth is bounded by the 20 ns pulse over unit_dim-wide rows.
    let stream_s = (csr_bytes + dense_bytes) as f64 / p.stream_bytes_per_s();
    let row_writes = (n * n * 4).div_ceil(p.unit_dim as u64 * 4);
    let write_s = row_writes as f64 * p.pcm_write_ns * 1e-9;
    let secs = stream_s.max(write_s);
    let cycles = (secs * p.clock_hz).ceil() as u64;
    // energy: every written bit is a potential program event (SLC,
    // write-verify skips unchanged cells — assume half toggle)
    let energy = dense_bytes as f64 * 8.0 * 0.5 * p.pcm_program_pj * 1e-12
        + (csr_bytes + dense_bytes) as f64 * 8.0 * p.ucie_pj_per_bit * 1e-12;
    (cycles, energy)
}

/// Cost of injecting a `nb x nb` dB block into a component tile
/// (HBM3 -> UCIe -> PCM min-merged write) plus the gated writes.
pub fn inject(p: &HwParams, _n: u64, nb: u64) -> (u64, f64) {
    let bytes = nb * nb * 4;
    let xfer_s = bytes as f64 / p.ucie_bytes_per_s().min(p.hbm_bytes_per_s());
    // compare-and-swap write: one bit-serial min per value
    let min_cycles = p.cycles_per_bit_min * p.word_bits as u64;
    let rows = (nb * nb).div_ceil(p.unit_dim as u64);
    let cycles = (xfer_s * p.clock_hz).ceil() as u64 + rows * min_cycles;
    let energy = bytes as f64 * 8.0 * (p.hbm_pj_per_bit + p.ucie_pj_per_bit) * 1e-12
        + (nb * nb) as f64 * 0.25 * p.word_bits as f64 * p.pcm_program_pj * 1e-12;
    (cycles, energy)
}

/// Cost of an aggregated MP merge batch on the PCM-MP die, per tile:
/// `madds` min-add candidates streamed through the bit-serial adders
/// and the comparator tree (paper Fig. 6d).
pub fn mp_merge_on_tile(p: &HwParams, madds: u64, rows: u64) -> (u64, f64) {
    let throughput = p.mp_madds_per_cycle_per_tile();
    let cycles = madds.div_ceil(throughput.max(1)) + p.mp_tree_latency_cycles * rows.min(1);
    let energy = madds as f64 * p.mp_pj_per_madd * 1e-12;
    (cycles, energy)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fw_1024_lands_near_paper_scale() {
        // ~1061x over a CPU that needs ~1 s for n=1024 means the tile
        // must finish in ~1 ms. Sanity-check the model's order of
        // magnitude (calibration target, DESIGN.md).
        let p = HwParams::default();
        let (cycles, energy) = fw_tile(&p, 1024);
        let secs = cycles as f64 * p.cycle_s();
        assert!(
            secs > 1e-4 && secs < 1e-2,
            "FW(1024) = {secs} s, expected ~1 ms"
        );
        assert!(
            energy > 1e-3 && energy < 1e-1,
            "FW(1024) = {energy} J, expected ~tens of mJ"
        );
    }

    #[test]
    fn fw_scales_cubically_in_energy_linearly_in_cycles() {
        let p = HwParams::default();
        let (c1, e1) = fw_tile(&p, 256);
        let (c2, e2) = fw_tile(&p, 512);
        assert!((e2 / e1 - 8.0).abs() < 0.1, "energy ratio {}", e2 / e1);
        let ratio = c2 as f64 / c1 as f64;
        assert!(ratio > 1.9 && ratio < 2.6, "cycle ratio {ratio}");
    }

    #[test]
    fn trivial_blocks_free() {
        let p = HwParams::default();
        assert_eq!(fw_tile(&p, 0), (0, 0.0));
        assert_eq!(fw_tile(&p, 1), (0, 0.0));
    }

    #[test]
    fn load_cost_monotone() {
        let p = HwParams::default();
        let (c1, e1) = load_component(&p, 128, 1000);
        let (c2, e2) = load_component(&p, 1024, 20000);
        assert!(c2 > c1 && e2 > e1);
    }

    #[test]
    fn mp_throughput_reasonable() {
        let p = HwParams::default();
        // 1 Tmadd on one tile at ~66k madds/cycle @ 500 MHz ≈ 0.03 s
        let (cycles, _) = mp_merge_on_tile(&p, 1_000_000_000_000, 1_000_000);
        let secs = cycles as f64 * p.cycle_s();
        assert!(secs > 1e-3 && secs < 1.0, "{secs}");
    }

    #[test]
    fn inject_scales_with_boundary() {
        let p = HwParams::default();
        let (c1, e1) = inject(&p, 1024, 32);
        let (c2, e2) = inject(&p, 1024, 512);
        assert!(c2 > c1 && e2 > e1);
    }
}
