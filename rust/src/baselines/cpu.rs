//! CPU baseline: Intel i7-11700K (64 GB) running Floyd–Warshall.
//!
//! The model is *measured-then-scaled*: we time the crate's own
//! optimized native FW on this host at a calibration size, fit the
//! cubic constant, and translate to the paper's part via a
//! per-core-throughput ratio. This keeps the baseline honest (it is the
//! best FW we know how to write on a CPU — the same kernel the
//! functional backend uses) while producing stable numbers across
//! machines.

use super::CostPoint;
use crate::apsp::floyd_warshall;
use crate::graph::dense::DistMatrix;
use crate::graph::generators::{self, Weights};
use std::sync::OnceLock;

/// i7-11700K package power under AVX load (PL1 = 125 W).
pub const I7_TDP_W: f64 = 125.0;

/// Calibrated cubic model `t = c * n^3` (seconds).
#[derive(Debug, Clone, Copy)]
pub struct CpuModel {
    /// seconds per n^3 min-add on the modeled CPU.
    pub c: f64,
    /// Host-measured seconds at the calibration size (for reporting).
    pub measured_at: (usize, f64),
}

impl CpuModel {
    /// Measure the host once and cache the fit.
    pub fn calibrated() -> CpuModel {
        static MODEL: OnceLock<CpuModel> = OnceLock::new();
        *MODEL.get_or_init(|| {
            let n = 768usize;
            let g = generators::newman_watts_strogatz(n, 5, 0.1, Weights::Uniform(1.0, 4.0), 7);
            let mut d: DistMatrix = g.to_dense();
            let t0 = std::time::Instant::now();
            floyd_warshall::fw_parallel(&mut d);
            let secs = t0.elapsed().as_secs_f64().max(1e-9);
            std::hint::black_box(d.get(0, 1));
            CpuModel {
                c: secs / (n as f64).powi(3),
                measured_at: (n, secs),
            }
        })
    }

    /// Fixed paper-scale constant (used when host measurement is
    /// undesirable, e.g. unit tests): ~1.1 s for n=1024, matching a
    /// well-optimized parallel FW on an 8-core i7-11700K.
    pub fn paper() -> CpuModel {
        CpuModel {
            c: 1.1 / 1024f64.powi(3),
            measured_at: (0, 0.0),
        }
    }

    /// Predicted cost of exact APSP (FW) at size n.
    pub fn cost(&self, n: usize) -> CostPoint {
        let seconds = self.c * (n as f64).powi(3);
        CostPoint {
            seconds,
            joules: seconds * I7_TDP_W,
        }
    }

    /// Actually run FW on the host and measure (small n).
    pub fn measure(n: usize, seed: u64) -> CostPoint {
        let g =
            generators::newman_watts_strogatz(n, 5, 0.1, Weights::Uniform(1.0, 4.0), seed);
        let mut d = g.to_dense();
        let t0 = std::time::Instant::now();
        floyd_warshall::fw_parallel(&mut d);
        let seconds = t0.elapsed().as_secs_f64();
        std::hint::black_box(d.get(0, 1));
        CostPoint {
            seconds,
            joules: seconds * I7_TDP_W,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cubic_scaling() {
        let m = CpuModel::paper();
        let a = m.cost(1024);
        let b = m.cost(2048);
        assert!((b.seconds / a.seconds - 8.0).abs() < 1e-9);
        assert!((a.seconds - 1.1).abs() < 1e-9);
    }

    #[test]
    fn energy_tracks_time() {
        let m = CpuModel::paper();
        let c = m.cost(4096);
        assert!((c.joules - c.seconds * I7_TDP_W).abs() < 1e-9);
    }

    #[test]
    fn calibration_positive_and_cached() {
        let a = CpuModel::calibrated();
        let b = CpuModel::calibrated();
        assert!(a.c > 0.0);
        assert_eq!(a.c, b.c); // cached
        assert!(a.measured_at.1 > 0.0);
    }

    #[test]
    fn measured_small_run_sane() {
        let c = CpuModel::measure(128, 1);
        assert!(c.seconds > 0.0 && c.seconds < 5.0);
    }
}
