//! PIM-APSP baseline: the Temporal State Machine SSSP engine [16]
//! repeated over all sources — the paper's prior-PIM comparison point
//! ("Since no SOTA PIM methods directly implement APSP, we estimate the
//! performance of the Temporal PIM SSSP [16] to establish a comparable
//! APSP PIM baseline", §IV-A).
//!
//! Anchor: [16] reports 10 giga-edge-traversals/s on the memristive
//! temporal processor; APSP = n SSSP sweeps, each traversing ~|E| edges
//! (plus wavefront re-initialization per source). Energy: temporal
//! tropical-algebra ops are extremely cheap (race-logic), but the
//! n-sweep structure cannot amortize the O(n^2) result readout.

use super::CostPoint;

/// Edge traversal throughput of the temporal processor (traversals/s).
const GTEPS: f64 = 10.0e9;
/// Per-source overhead: wavefront setup + result readout (s). A 1024-row
/// readout at array speeds; dominated by peripheral conversion.
const PER_SOURCE_S: f64 = 20e-6;
/// Active power of the memristive temporal processor + periphery (W).
const POWER_W: f64 = 60.0;

/// APSP cost at n vertices, m directed edges.
pub fn pim_apsp(n: usize, m: usize) -> CostPoint {
    let n = n as f64;
    let m = m as f64;
    let seconds = n * (m / GTEPS + PER_SOURCE_S);
    CostPoint {
        seconds,
        joules: seconds * POWER_W,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ogbn_scale_matches_paper_shape() {
        // paper Fig. 8: PIM-APSP ≈ 0.7x the speed of the GPU-cluster
        // baseline but ~11x its energy efficiency. Check we land in the
        // same regime: slower than Partitioned APSP, far less energy.
        let n = 2_449_029;
        let m = 123_718_280; // both directions
        let pim = pim_apsp(n, m);
        let cluster = super::super::cluster::partitioned_apsp(n);
        let speed_ratio = cluster.seconds / pim.seconds;
        assert!(
            speed_ratio > 0.02 && speed_ratio < 1.0,
            "PIM should be slower than the cluster: ratio {speed_ratio}"
        );
        let energy_ratio = cluster.joules / pim.joules;
        assert!(energy_ratio > 5.0, "PIM energy win {energy_ratio}");
    }

    #[test]
    fn scales_linearly_in_sources_and_edges() {
        let a = pim_apsp(1000, 1_000_000);
        let b = pim_apsp(2000, 1_000_000);
        assert!(b.seconds / a.seconds > 1.9);
        let c = pim_apsp(1000, 4_000_000);
        assert!(c.seconds > 2.0 * a.seconds, "{} vs {}", c.seconds, a.seconds);
    }
}
