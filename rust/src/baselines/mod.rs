//! Baseline cost models — every comparator in the paper's §IV:
//!
//! * [`cpu`] — Intel i7-11700K: *measured* on this host via the native
//!   FW kernel, then scaled to the paper's part.
//! * [`gpu`] — NVIDIA A100 / H100 analytic roofline for blocked FW.
//! * [`cluster`] — Partitioned APSP [10] and Co-Parallel FW [11] GPU
//!   clusters, anchored to their published results ("we estimate their
//!   performance from reported scaling trends" — paper §IV-C).
//! * [`pim`] — PIM-APSP: the Temporal-State-Machine SSSP engine [16]
//!   repeated n times, the paper's PIM comparison point.
//!
//! All models return a [`CostPoint`] (seconds, joules) for an
//! (n, avg_degree) workload so figures can mix measured and modeled
//! systems uniformly.

pub mod cluster;
pub mod cpu;
pub mod gpu;
pub mod pim;

/// One (time, energy) prediction for a workload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostPoint {
    pub seconds: f64,
    pub joules: f64,
}

impl CostPoint {
    pub fn speedup_vs(&self, other: &CostPoint) -> f64 {
        other.seconds / self.seconds
    }
    pub fn energy_eff_vs(&self, other: &CostPoint) -> f64 {
        other.joules / self.joules
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_helpers() {
        let a = CostPoint {
            seconds: 1.0,
            joules: 10.0,
        };
        let b = CostPoint {
            seconds: 5.0,
            joules: 100.0,
        };
        assert_eq!(a.speedup_vs(&b), 5.0);
        assert_eq!(a.energy_eff_vs(&b), 10.0);
    }
}
