//! Distributed GPU-cluster baselines, anchored to their published
//! results exactly as the paper does ("On OGBN-products ... we estimate
//! their performance from reported scaling trends", §IV-C2):
//!
//! * **Partitioned APSP** (Djidjev et al. [10]): "computes APSP for a
//!   2M-vertex graph in approximately 30 minutes but requires 128 GPUs".
//! * **Co-Parallel FW** (Sao et al. [11]): "achieves 8.1 PFLOP/s but
//!   requires complex coordination among 4,608 GPUs", with "only 45%
//!   weak-scaling efficiency on a 300K-node graph".

use super::CostPoint;

/// Per-GPU board power assumed for the clusters (V100-class parts in
/// both papers' testbeds).
const CLUSTER_GPU_W: f64 = 300.0;
/// Non-GPU cluster overhead (CPUs, NICs, switches) per GPU.
const CLUSTER_OVERHEAD_W: f64 = 100.0;

/// Partitioned APSP [10]: anchored at (2M vertices, 1800 s, 128 GPUs);
/// work scales ~n^3 with the boundary-dominated constant, and the
/// inter-GPU synchronization keeps scaling superlinear past the anchor.
pub fn partitioned_apsp(n: usize) -> CostPoint {
    let anchor_n = 2.0e6;
    let anchor_t = 1800.0;
    let gpus = 128.0;
    let x = n as f64 / anchor_n;
    // n^3 work on fixed hardware, mildly relieved by better locality on
    // smaller graphs (communication fraction shrinks): exponent 2.7
    let seconds = anchor_t * x.powf(2.7);
    CostPoint {
        seconds,
        joules: seconds * gpus * (CLUSTER_GPU_W + CLUSTER_OVERHEAD_W),
    }
}

/// Co-Parallel FW [11]: sustained 8.1 PFLOP/s across 4,608 GPUs at 45%
/// weak-scaling efficiency; FW needs 2 n^3 FLOPs.
pub fn co_parallel_fw(n: usize) -> CostPoint {
    let gpus = 4608.0;
    let sustained = 8.1e15;
    let n = n as f64;
    // the sustained figure already includes their scaling losses at the
    // reported size; smaller graphs cannot use the full machine
    // (communication floor), modeled as a fixed 2 s launch/sync floor
    let seconds = (2.0 * n * n * n / sustained) + 2.0;
    CostPoint {
        seconds,
        joules: seconds * gpus * (CLUSTER_GPU_W + CLUSTER_OVERHEAD_W),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partitioned_anchor_reproduced() {
        let c = partitioned_apsp(2_000_000);
        assert!((c.seconds - 1800.0).abs() < 1.0);
        // 128 GPUs x 400 W x 30 min ≈ 92 MJ
        assert!(c.joules > 5e7 && c.joules < 2e8, "{}", c.joules);
    }

    #[test]
    fn co_parallel_fw_at_ogbn_scale() {
        // 2.45M vertices: 2 * n^3 / 8.1 PFLOP/s ≈ 3630 s ≈ 1 h
        let c = co_parallel_fw(2_449_029);
        assert!(c.seconds > 3000.0 && c.seconds < 5000.0, "{}", c.seconds);
    }

    #[test]
    fn both_monotone_in_n() {
        for f in [partitioned_apsp as fn(usize) -> CostPoint, co_parallel_fw] {
            let a = f(100_000);
            let b = f(1_000_000);
            assert!(b.seconds > a.seconds);
            assert!(b.joules > a.joules);
        }
    }

    #[test]
    fn cluster_energy_dwarfs_single_gpu() {
        let cluster = partitioned_apsp(2_000_000);
        let single = super::super::gpu::h100().cost(100_000);
        assert!(cluster.joules > single.joules);
    }
}
