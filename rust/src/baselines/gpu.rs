//! Single-GPU analytic baselines: NVIDIA A100-SXM4 (80 GB) and the
//! paper's "Estimated GPU H100 [35]".
//!
//! Blocked Floyd–Warshall on a GPU is bound by whichever is slower:
//! CUDA-core min-add throughput (FW's `min(a, b+c)` cannot use tensor
//! cores) or HBM traffic (each pivot panel sweep re-touches the O(n^2)
//! matrix once it exceeds L2, the paper's Fig. 9(e) argument). The model
//! is the max of those two rooflines with published part constants
//! [35], plus a fixed kernel-efficiency factor for real-world blocked-FW
//! implementations (Katz–Kider-style) on these parts.

use super::CostPoint;

/// GPU part constants.
#[derive(Debug, Clone, Copy)]
pub struct GpuModel {
    pub name: &'static str,
    /// FP32 CUDA-core peak (FLOP/s); a min-add counts as 2 FLOPs.
    pub fp32_flops: f64,
    /// HBM bandwidth (bytes/s).
    pub hbm_bytes_per_s: f64,
    /// L2 cache (bytes): below this the matrix stays on-chip.
    pub l2_bytes: f64,
    /// Board power under load (W).
    pub power_w: f64,
    /// Achieved fraction of the compute roofline for blocked FW kernels.
    pub efficiency: f64,
    /// Effective HBM bytes touched per matrix entry per pivot sweep
    /// (panel-cached tiled kernels re-read the block once: ~4 B).
    pub bytes_per_entry: f64,
    /// Achieved fraction of HBM bandwidth.
    pub mem_efficiency: f64,
    /// Kernel-launch/sync overhead per block round (s).
    pub launch_s: f64,
    /// Device memory (bytes) — FW needs 4 n^2; beyond this the workload
    /// spills to host over PCIe and slows dramatically.
    pub mem_bytes: f64,
    /// Host<->device link (bytes/s) once spilled.
    pub pcie_bytes_per_s: f64,
}

/// A100-SXM4-80GB: 19.5 TFLOP/s fp32, 2.04 TB/s HBM2e, 40 MB L2, 400 W.
pub fn a100() -> GpuModel {
    GpuModel {
        name: "A100",
        fp32_flops: 19.5e12,
        hbm_bytes_per_s: 2.04e12,
        l2_bytes: 40e6,
        power_w: 400.0,
        efficiency: 0.35,
        bytes_per_entry: 4.0,
        mem_efficiency: 0.7,
        launch_s: 5e-6,
        mem_bytes: 80e9,
        pcie_bytes_per_s: 25e9,
    }
}

/// H100-SXM5-80GB: 66.9 TFLOP/s fp32, 3.35 TB/s HBM3, 50 MB L2, 700 W
/// (the paper cites up to 700 W peak [35]).
pub fn h100() -> GpuModel {
    GpuModel {
        name: "H100",
        fp32_flops: 66.9e12,
        hbm_bytes_per_s: 3.35e12,
        l2_bytes: 50e6,
        power_w: 700.0,
        efficiency: 0.35,
        bytes_per_entry: 4.0,
        mem_efficiency: 0.7,
        launch_s: 5e-6,
        mem_bytes: 80e9,
        pcie_bytes_per_s: 50e9,
    }
}

impl GpuModel {
    /// Exact-APSP (blocked FW) cost at n vertices.
    pub fn cost(&self, n: usize) -> CostPoint {
        let n = n as f64;
        let madds = n * n * n;
        // compute roofline: 2 FLOPs per min-add on CUDA cores
        let t_compute = 2.0 * madds / (self.fp32_flops * self.efficiency);
        // memory roofline: per pivot sweep, the blocked kernel re-streams
        // the matrix once it no longer fits in L2
        let bytes = 4.0 * n * n;
        let t_mem = if bytes <= self.l2_bytes {
            0.0
        } else {
            n * self.bytes_per_entry * n * n
                / (self.hbm_bytes_per_s * self.mem_efficiency)
        };
        // kernel-launch floor: blocked FW issues ~3 kernels per 32-wide
        // block round (diagonal, panels, update)
        let t_launch = 3.0 * (n / 32.0) * self.launch_s;
        // capacity wall: spilled tiles cross PCIe each pivot sweep
        let t_spill = if bytes > self.mem_bytes {
            let excess = bytes - self.mem_bytes;
            n * excess / self.pcie_bytes_per_s
        } else {
            0.0
        };
        let seconds = t_compute.max(t_mem) + t_launch + t_spill;
        CostPoint {
            seconds,
            joules: seconds * self.power_w,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h100_faster_than_a100() {
        for n in [1024usize, 32768, 262144] {
            assert!(h100().cost(n).seconds < a100().cost(n).seconds, "n={n}");
        }
    }

    #[test]
    fn small_graphs_compute_bound_large_memory_bound() {
        let g = h100();
        // at n=1024 (4 MB matrix < 50 MB L2) memory term is zero:
        // compute + launch floor only
        let t1 = g.cost(1024).seconds;
        let expect = 2.0 * 1024f64.powi(3) / (g.fp32_flops * g.efficiency)
            + 3.0 * 32.0 * g.launch_s;
        assert!((t1 - expect).abs() / expect < 1e-9, "{t1} vs {expect}");
        // at n=32768 (4.3 GB) the memory roofline dominates
        let n = 32768f64;
        let t2 = g.cost(32768).seconds;
        let mem = n * g.bytes_per_entry * n * n / (g.hbm_bytes_per_s * g.mem_efficiency);
        assert!(t2 >= mem * 0.99, "t2={t2} mem={mem}");
    }

    #[test]
    fn superlinear_energy_growth_past_cache() {
        // Fig. 9(e): H100 energy grows superlinearly beyond ~10^3 nodes
        let g = h100();
        let e1 = g.cost(1024).joules;
        let e2 = g.cost(8192).joules;
        let ratio = e2 / e1;
        assert!(ratio > 512.0, "energy ratio {ratio} should exceed n^3 512");
    }

    #[test]
    fn capacity_wall_kicks_in() {
        let g = h100();
        // 80 GB / 4 bytes => n ~ 141k; beyond that the PCIe term appears
        let below = g.cost(140_000);
        let above = g.cost(200_000);
        assert!(above.seconds > 3.0 * below.seconds);
    }
}
