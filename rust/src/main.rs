//! RAPID-Graph CLI — the leader entrypoint.
//!
//! Subcommands:
//!   generate   synthesize a workload graph and write it to disk
//!   apsp       run the full pipeline (partition -> recursive DP solve ->
//!              PIM simulation -> validation) and print the report;
//!              --workload picks the semiring: apsp (min,+ shortest
//!              paths, default), reach (transitive closure), widest
//!              (bottleneck bandwidth), critical (longest path on the
//!              DAG orientation);
//!              with --batch, merge N independent graphs into one
//!              shared-resource schedule and print the batch table;
//!              with --stacks S, shard one graph across S modeled PIM
//!              stacks and print the scale-out table;
//!              with --admit, submit N graphs to the async admission
//!              pipeline on a modeled arrival schedule and print the
//!              per-graph latency table vs the drain baseline;
//!              with --deltas FILE, solve once and replay the file's
//!              edge-delta batches through the incremental repair
//!              engine (re-solving only dirty tiles);
//!              with --serve / --queries FILE, solve once with
//!              next-hop threading and drain query batches through the
//!              lock-free batched serve loop (add --deltas FILE for a
//!              live mutation feed between query batches)
//!   figure     regenerate a paper figure/table (7, 8, 9a, 9b, 9c, table3)
//!   validate   exhaustive Dijkstra validation on a small graph
//!
//! Examples:
//!   rapid-graph apsp --topo nws --nodes 20000 --degree 25.25
//!   rapid-graph apsp --workload widest --topo nws --nodes 5000
//!   rapid-graph apsp --graph g.bin --mode estimate
//!   rapid-graph apsp --batch --batch-size 8 --nodes 5000 --mode estimate
//!   rapid-graph apsp --batch --graphs a.bin,b.bin,c.bin
//!   rapid-graph apsp --stacks 4 --topo ogbn --nodes 50000 --mode estimate
//!   rapid-graph apsp --admit 6 --admit-interval 1e-4 --admit-queue 2 --mode estimate
//!   rapid-graph apsp --deltas updates.txt --topo nws --nodes 20000
//!   rapid-graph apsp --queries queries.txt --deltas updates.txt --topo nws --nodes 2000
//!   rapid-graph figure --id 7
//!   rapid-graph generate --topo ogbn --nodes 100000 --out g.bin

use rapid_graph::baselines::cpu::CpuModel;
use rapid_graph::util::error::{Context, Result};
use rapid_graph::{bail, ensure};
use rapid_graph::bench::figures;
use rapid_graph::coordinator::config::{resolve_cli_mode, CliMode, SystemConfig};
use rapid_graph::coordinator::{executor::Executor, report};
use rapid_graph::graph::generators::{self, Topology, Weights};
use rapid_graph::graph::io;
use rapid_graph::util::cli::{render_help, Args};
use rapid_graph::util::config::ConfigFile;
use std::path::Path;

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand() {
        Some("generate") => cmd_generate(args),
        Some("apsp") | Some("simulate") => cmd_apsp(args),
        Some("figure") => cmd_figure(args),
        Some("validate") => cmd_validate(args),
        _ => {
            print!(
                "{}",
                render_help(
                    "rapid-graph",
                    "recursive APSP on a simulated processing-in-memory stack",
                    &[
                        ("generate", "--topo nws|er|ogbn|grid --nodes N [--degree D] [--seed S] --out FILE"),
                        ("apsp", "[--graph FILE | --topo T --nodes N] [--workload apsp|reach|widest|critical] [--mode functional|estimate] [--backend native|pjrt] [--scheduler dag|barrier] [--tile T] [--max-depth D] [--validate-tolerance TOL] [--config FILE]"),
                        ("apsp --batch", "[--batch-size N] [--graphs F1,F2,.. | --topo T --nodes N] merge N graphs into one shared-resource schedule"),
                        ("apsp --stacks", "S [--graph FILE | --topo T --nodes N] shard one graph across S modeled PIM stacks"),
                        ("apsp --admit", "[N] [--arrivals T1,T2,.. | --admit-interval DT] [--admit-queue Q] [--store-capacity C] admit N graphs into a live schedule; the result store serves duplicate submissions from modeled FeNAND"),
                        ("apsp --deltas", "FILE [--graph FILE | --topo T --nodes N] [--delta-no-validate] [--delta-no-skip] solve once, then replay FILE's edge-delta batches (insert/delete/reweight) through the incremental repair engine"),
                        ("apsp --serve", "--queries FILE [--deltas FILE] [--serve-panel R] [--serve-slo MS] [--serve-readers T] [--serve-no-validate] solve once with next-hop threading, then drain FILE's query batches (dist/path/knear/reach, @tenant tags) through the lock-free batched serve loop; --deltas interleaves live repairs between query batches"),
                        ("figure", "--id 7|8|9a|9b|9c|table3 [--full]"),
                        ("validate", "--nodes N [--topo T] [--tile T]"),
                    ]
                )
            );
            Ok(())
        }
    }
}

fn load_config(args: &Args) -> Result<SystemConfig> {
    let mut cfg = SystemConfig::default();
    if let Some(path) = args.get("config") {
        let cf = ConfigFile::load(path).with_context(|| format!("load config {path}"))?;
        cfg.apply_file(&cf);
    }
    cfg.apply_args(args);
    Ok(cfg)
}

/// Load a graph file: `.bin` is the binary format, anything else is an
/// edge list.
fn load_graph(path: &str) -> Result<rapid_graph::CsrGraph> {
    if path.ends_with(".bin") {
        io::read_binary(Path::new(path))
    } else {
        io::read_edge_list(Path::new(path))
    }
}

fn graph_from_args(args: &Args) -> Result<rapid_graph::CsrGraph> {
    if let Some(path) = args.get("graph") {
        return load_graph(path);
    }
    let topo = Topology::parse(args.get_or("topo", "nws"))
        .context("unknown --topo (nws|er|ogbn|grid)")?;
    let n = args.get_usize("nodes", 10_000);
    let degree = args.get_f64("degree", 25.25);
    let seed = args.get_u64("seed", 42);
    Ok(generators::generate(
        topo,
        n,
        degree,
        Weights::Uniform(1.0, 8.0),
        seed,
    ))
}

fn cmd_generate(args: &Args) -> Result<()> {
    let g = graph_from_args(args)?;
    let out = args.get("out").context("--out FILE required")?;
    if out.ends_with(".bin") {
        io::write_binary(&g, Path::new(out))?;
    } else {
        io::write_edge_list(&g, Path::new(out))?;
    }
    println!(
        "wrote {} (n={}, m={}, avg degree {:.2})",
        out,
        g.n(),
        g.m(),
        g.avg_degree()
    );
    Ok(())
}

fn cmd_apsp(args: &Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    if args.subcommand() == Some("simulate") {
        cfg.mode = rapid_graph::coordinator::config::Mode::Estimate;
    }
    // the mode flags (--batch/--graphs, --stacks, --admit) are mutually
    // exclusive; combining them is a clean error, never a silent pick
    match resolve_cli_mode(args, cfg.num_stacks)? {
        CliMode::Batch => {
            // an explicit --batch wins over a config file's
            // run.num_stacks (so a sharding config doesn't lock batch
            // mode out)
            cfg.num_stacks = 1;
            cmd_batch(args, cfg)
        }
        CliMode::Admission => {
            cfg.num_stacks = 1;
            cmd_admit(args, cfg)
        }
        CliMode::Delta => {
            cfg.num_stacks = 1;
            cmd_delta(args, cfg)
        }
        CliMode::Serve => {
            cfg.num_stacks = 1;
            cmd_serve(args, cfg)
        }
        CliMode::Sharded => cmd_sharded(args, cfg),
        CliMode::Solo => {
            let g = graph_from_args(args)?;
            let ex = Executor::new(cfg)?;
            let r = ex.run(&g)?;
            print!("{}", report::render(&r));
            if let Some(v) = &r.validation {
                if !v.ok(r.validate_tolerance) {
                    bail!("validation FAILED");
                }
            }
            Ok(())
        }
    }
}

/// The multi-graph workload of a batch or admission run: `--graphs
/// f1,f2,..` (load) or generated — `--<count_key> N` (falling back to
/// `run.batch_size`) graphs of `--nodes` vertices each, cycling
/// through the four topologies for a heterogeneous mix (`--topo` pins
/// them to one).
fn workload_graphs(
    args: &Args,
    count_key: &str,
    default_count: usize,
) -> Result<Vec<rapid_graph::CsrGraph>> {
    ensure!(
        args.get("graph").is_none(),
        "--graph is the solo-run input; multi-graph modes load --graphs F1,F2,.."
    );
    if let Some(list) = args.get("graphs") {
        return list.split(',').map(load_graph).collect::<Result<_>>();
    }
    // `--batch N` / `--admit N` are count shorthands for --batch-size
    let count = args.get_usize(count_key, default_count).max(1);
    let n = args.get_usize("nodes", 10_000);
    let degree = args.get_f64("degree", 25.25);
    let seed = args.get_u64("seed", 42);
    let topos: Vec<Topology> = match args.get("topo") {
        Some(t) => vec![Topology::parse(t).context("unknown --topo (nws|er|ogbn|grid)")?],
        None => vec![Topology::Nws, Topology::Er, Topology::Grid, Topology::OgbnProxy],
    };
    Ok((0..count)
        .map(|i| {
            generators::generate(
                topos[i % topos.len()],
                n,
                degree,
                Weights::Uniform(1.0, 8.0),
                seed + i as u64,
            )
        })
        .collect())
}

/// `apsp --batch`: merge N independent graphs into one shared-resource
/// schedule. Graphs come from `--graphs f1,f2,..` (load) or are
/// generated — `--batch-size` (or `run.batch_size`) graphs of `--nodes`
/// vertices each, cycling through the four topologies for a
/// heterogeneous mix.
fn cmd_batch(args: &Args, cfg: SystemConfig) -> Result<()> {
    let graphs = workload_graphs(args, "batch", cfg.batch_size)?;
    let ex = Executor::new(cfg)?;
    let b = ex.run_batch(&graphs)?;
    print!("{}", report::render_batch(&b));
    for r in &b.per_graph {
        if let Some(v) = &r.validation {
            if !v.ok(r.validate_tolerance) {
                bail!("validation FAILED");
            }
        }
    }
    Ok(())
}

/// `apsp --admit`: submit N graphs to the async admission pipeline on
/// a modeled arrival schedule (`--arrivals T1,T2,..` or uniform
/// `--admit-interval` spacing, never wall-clock) with an in-flight
/// bound of `--admit-queue` graphs, and report the per-graph
/// admit-to-complete latency table against the drain-and-rebatch
/// baseline. `--store-capacity C` enables the content-addressed result
/// store: duplicate submissions are served as FeNAND reads (HIT rows)
/// instead of re-solved, and the summary adds `cache_speedup` vs the
/// same workload with the store off.
fn cmd_admit(args: &Args, cfg: SystemConfig) -> Result<()> {
    let graphs = workload_graphs(args, "admit", cfg.batch_size)?;
    let ex = Executor::new(cfg)?;
    let a = ex.run_admission(&graphs)?;
    print!("{}", report::render_admission(&a));
    for r in &a.per_graph {
        if let Some(solo) = &r.solo {
            if let Some(v) = &solo.validation {
                if !v.ok(solo.validate_tolerance) {
                    bail!("validation FAILED");
                }
            }
        }
    }
    Ok(())
}

/// `apsp --deltas FILE`: solve the base graph once, then replay FILE's
/// edge-delta batches (blank-line-separated groups of `insert u v w` /
/// `delete u v` / `reweight u v w` lines) through the incremental
/// repair engine — each batch re-solves only its dirty tile closure
/// and is bit-validated against a fresh full solve unless
/// `--delta-no-validate`. The report prints per-batch dirty-tile
/// counts, repair latency, and `delta_speedup` vs re-solving from
/// scratch.
fn cmd_delta(args: &Args, cfg: SystemConfig) -> Result<()> {
    let path = args.get("deltas").context("--deltas FILE required")?;
    let script = std::fs::read_to_string(path)
        .with_context(|| format!("read delta script {path}"))?;
    let g = graph_from_args(args)?;
    let ex = Executor::new(cfg)?;
    let d = ex.run_delta(&g, &script)?;
    print!("{}", report::render_delta(&d));
    if let Some(v) = &d.initial.validation {
        if !v.ok(d.initial.validate_tolerance) {
            bail!("validation FAILED");
        }
    }
    if d.batches
        .iter()
        .any(|b| matches!(b.max_diff, Some(diff) if diff != 0.0))
    {
        bail!("validation FAILED");
    }
    Ok(())
}

/// `apsp --serve`: solve the base graph once with next-hop threading,
/// publish the snapshot in the lock-free cell, and drain `--queries
/// FILE`'s batches (blank-line-separated groups of `dist u v` /
/// `path u v` / `knear u k` / `reach u` lines, optional `@tenant`
/// tags) through the batched source-major executor. With `--deltas
/// FILE`, one delta batch is applied between consecutive query batches
/// — re-solved and epoch-swapped while reader threads hammer the cell,
/// proving readers never block and never see a torn snapshot. The
/// report prints QPS, latency percentiles, per-tenant SLO attainment,
/// and a sample reconstructed path.
fn cmd_serve(args: &Args, cfg: SystemConfig) -> Result<()> {
    let qpath = args.get("queries").context("--queries FILE required")?;
    let queries = std::fs::read_to_string(qpath)
        .with_context(|| format!("read query script {qpath}"))?;
    let deltas = match args.get("deltas") {
        Some(path) => Some(
            std::fs::read_to_string(path)
                .with_context(|| format!("read delta script {path}"))?,
        ),
        None => None,
    };
    let g = graph_from_args(args)?;
    let ex = Executor::new(cfg)?;
    let s = ex.run_serve(&g, &queries, deltas.as_deref())?;
    print!("{}", report::render_serve(&s));
    if s.torn_reads > 0 {
        bail!("validation FAILED");
    }
    Ok(())
}

/// `apsp --stacks S`: shard one graph across S modeled PIM stacks and
/// report the scale-out table (per-stack attribution, interconnect
/// traffic, speedup over the 1-stack solo baseline).
fn cmd_sharded(args: &Args, cfg: SystemConfig) -> Result<()> {
    let g = graph_from_args(args)?;
    let ex = Executor::new(cfg)?;
    let r = ex.run_sharded(&g)?;
    print!("{}", report::render_sharded(&r));
    if let Some(v) = &r.solo.validation {
        if !v.ok(r.solo.validate_tolerance) {
            bail!("validation FAILED");
        }
    }
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let full = args.flag("full");
    match args.get_or("id", "7") {
        "7" => {
            let cpu = if full {
                CpuModel::calibrated()
            } else {
                CpuModel::paper()
            };
            let (s, e) = figures::fig7(&cfg, &cpu, &[100, 1024, 32768]);
            s.print();
            e.print();
        }
        "8" => {
            let n = if full {
                rapid_graph::bench::workload::OGBN_N
            } else {
                args.get_usize("nodes", 200_000)
            };
            figures::fig8(&cfg, n).print();
        }
        "9a" => figures::fig9_degree(&cfg, 32_768, &[12.5, 25.25, 50.0, 100.0]).print(),
        "9b" => {
            let sizes: Vec<usize> = if full {
                vec![1024, 8192, 65_536, 524_288, 2_449_029]
            } else {
                vec![1024, 8192, 65_536]
            };
            figures::fig9_size(&cfg, &sizes).0.print();
        }
        "9c" => {
            figures::fig9_topology(
                &cfg,
                if full { 131_072 } else { 32_768 },
                &[Topology::Nws, Topology::OgbnProxy, Topology::Er],
            )
            .0
            .print();
        }
        "table3" => {
            for t in figures::table3() {
                t.print();
            }
        }
        other => bail!("unknown figure id {other:?} (7|8|9a|9b|9c|table3)"),
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let g = graph_from_args(args)?;
    ensure!(
        g.n() <= 3000,
        "exhaustive validation is O(n^2); use --nodes <= 3000 (apsp does sampled validation at any size)"
    );
    let tol = cfg.validate_tolerance;
    let ex = Executor::new(cfg)?;
    let plan = ex.plan(&g);
    let backend = rapid_graph::apsp::backend::NativeBackend;
    let sol = rapid_graph::apsp::recursive::solve(
        &g,
        &plan,
        Some(&backend),
        rapid_graph::apsp::recursive::SolveOptions::default(),
    );
    let full = sol.materialize_full(&backend);
    let v = rapid_graph::apsp::validate::validate_full(&g, &full, tol);
    println!(
        "exhaustive validation: {} entries, max err {:.2e}, {} mismatches -> {}",
        v.checked,
        v.max_abs_err,
        v.mismatches,
        if v.ok(tol) { "EXACT" } else { "FAILED" }
    );
    if !v.ok(tol) {
        bail!("validation failed");
    }
    Ok(())
}
