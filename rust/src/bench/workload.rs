//! Workload definitions shared by the figure generators.

use crate::graph::csr::CsrGraph;
use crate::graph::generators::{self, Topology, Weights};

/// The paper's evaluation degree (OGBN-Products average, Fig. 9 caption).
pub const PAPER_DEGREE: f64 = 25.25;

/// OGBN-Products published size.
pub const OGBN_N: usize = generators::OGBN_PRODUCTS_N;

/// A named graph workload.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    pub topo: Topology,
    pub n: usize,
    pub degree: f64,
    pub seed: u64,
}

impl Workload {
    pub fn nws(n: usize, seed: u64) -> Self {
        Self {
            topo: Topology::Nws,
            n,
            degree: PAPER_DEGREE,
            seed,
        }
    }

    pub fn ogbn_proxy_at(n: usize, seed: u64) -> Self {
        Self {
            topo: Topology::OgbnProxy,
            n,
            degree: PAPER_DEGREE,
            seed,
        }
    }

    pub fn generate(&self) -> CsrGraph {
        generators::generate(self.topo, self.n, self.degree, Weights::Uniform(1.0, 8.0), self.seed)
    }

    pub fn label(&self) -> String {
        format!(
            "{} n={} deg={}",
            self.topo.name(),
            crate::util::table::fmt_count(self.n),
            self.degree
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workloads_generate_expected_sizes() {
        let w = Workload::nws(1000, 1);
        let g = w.generate();
        assert_eq!(g.n(), 1000);
        let d = g.avg_degree();
        assert!(d > 18.0 && d < 32.0, "degree {d}");
    }

    #[test]
    fn labels_are_informative() {
        let w = Workload::ogbn_proxy_at(OGBN_N, 2);
        assert!(w.label().contains("OGBN-proxy"));
        assert!(w.label().contains("2.45M"));
    }
}
