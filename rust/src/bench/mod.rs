//! Benchmark harness: workload definitions and figure/table generators.
//!
//! Each `benches/*.rs` binary is a thin wrapper that calls one generator
//! here and prints its tables — keeping every paper figure regenerable
//! from both `cargo bench` and the library API (and testable from unit
//! tests).

pub mod figures;
pub mod workload;
