//! Figure/table generators — one function per paper artifact.
//!
//! Every generator returns rendered tables (and raw series for JSON
//! dumps) so `benches/*.rs`, `examples/`, and unit tests share one
//! implementation. Absolute numbers come from the calibrated models;
//! the *shape* assertions (who wins, crossovers, topology ordering) are
//! unit-tested in this module per the reproduction brief.

use crate::baselines::{cluster, cpu::CpuModel, gpu, pim, CostPoint};
use crate::bench::workload::{Workload, PAPER_DEGREE};
use crate::coordinator::config::{Mode, SystemConfig};
use crate::coordinator::executor::Executor;
use crate::graph::generators::Topology;
use crate::util::table::{fmt_count, fmt_energy, fmt_ratio, fmt_time, Table};

/// RAPID-Graph modeled cost for a workload (estimate mode — the trace,
/// and therefore the modeled cost, is identical to functional mode).
pub fn rapid_cost(
    w: &Workload,
    cfg: &SystemConfig,
) -> (CostPoint, crate::coordinator::executor::RunResult) {
    let mut cfg = cfg.clone();
    cfg.mode = Mode::Estimate;
    let ex = Executor::new(cfg).expect("estimate executor");
    let g = w.generate();
    let r = ex.run(&g).expect("estimate run");
    (
        CostPoint {
            seconds: r.sim.seconds,
            joules: r.sim.joules,
        },
        r,
    )
}

/// Fig. 7: RAPID-Graph vs CPU / A100 / H100 at n = 100, 1024, 32768
/// (NWS graphs, paper degree). Returns (speedup table, energy table).
pub fn fig7(cfg: &SystemConfig, cpu_model: &CpuModel, sizes: &[usize]) -> (Table, Table) {
    let mut speed = Table::new(
        "Fig. 7(a) speedup over baselines (higher is better for RAPID)",
        &["n", "RAPID time", "vs CPU", "vs A100", "vs H100"],
    );
    let mut energy = Table::new(
        "Fig. 7(b) energy efficiency over baselines",
        &["n", "RAPID energy", "vs CPU", "vs A100", "vs H100"],
    );
    for (i, &n) in sizes.iter().enumerate() {
        let w = Workload::nws(n, 70 + i as u64);
        let (rapid, _) = rapid_cost(&w, cfg);
        let cpu = cpu_model.cost(n);
        let a100 = gpu::a100().cost(n);
        let h100 = gpu::h100().cost(n);
        speed.row(&[
            fmt_count(n),
            fmt_time(rapid.seconds),
            fmt_ratio(rapid.speedup_vs(&cpu)),
            fmt_ratio(rapid.speedup_vs(&a100)),
            fmt_ratio(rapid.speedup_vs(&h100)),
        ]);
        energy.row(&[
            fmt_count(n),
            fmt_energy(rapid.joules),
            fmt_ratio(rapid.energy_eff_vs(&cpu)),
            fmt_ratio(rapid.energy_eff_vs(&a100)),
            fmt_ratio(rapid.energy_eff_vs(&h100)),
        ]);
    }
    (speed, energy)
}

/// Fig. 8: RAPID-Graph vs PIM-APSP [16], Partitioned APSP [10] and
/// Co-Parallel APSP [11] on the OGBN-Products workload. `n` is
/// parameterizable so tests can run a scaled-down proxy; the bench uses
/// the full 2.449M.
pub fn fig8(cfg: &SystemConfig, n: usize) -> Table {
    let w = Workload::ogbn_proxy_at(n, 88);
    let (rapid, r) = rapid_cost(&w, cfg);
    let m = r.graph_m;
    let pim = pim::pim_apsp(n, m);
    let part = cluster::partitioned_apsp(n);
    let copar = cluster::co_parallel_fw(n);
    let mut t = Table::new(
        &format!("Fig. 8 SOTA comparison on OGBN-Products proxy (n={})", fmt_count(n)),
        &["system", "time", "energy", "RAPID speedup", "RAPID energy eff"],
    );
    t.row(&[
        "RAPID-Graph".into(),
        fmt_time(rapid.seconds),
        fmt_energy(rapid.joules),
        "1x".into(),
        "1x".into(),
    ]);
    for (name, c) in [
        ("PIM-APSP [16]", pim),
        ("Partitioned APSP [10]", part),
        ("Co-Parallel APSP [11]", copar),
    ] {
        t.row(&[
            name.into(),
            fmt_time(c.seconds),
            fmt_energy(c.joules),
            fmt_ratio(rapid.speedup_vs(&c)),
            fmt_ratio(rapid.energy_eff_vs(&c)),
        ]);
    }
    t
}

/// Fig. 9(a,d): degree sweep at fixed size.
pub fn fig9_degree(cfg: &SystemConfig, n: usize, degrees: &[f64]) -> Table {
    let mut t = Table::new(
        &format!("Fig. 9(a,d) degree sweep at n={}", fmt_count(n)),
        &["degree", "RAPID time", "RAPID energy", "H100 time", "H100 energy"],
    );
    for (i, &d) in degrees.iter().enumerate() {
        let w = Workload {
            topo: Topology::Nws,
            n,
            degree: d,
            seed: 90 + i as u64,
        };
        let (rapid, _) = rapid_cost(&w, cfg);
        let h = gpu::h100().cost(n); // degree-insensitive (dense FW)
        t.row(&[
            format!("{d}"),
            fmt_time(rapid.seconds),
            fmt_energy(rapid.joules),
            fmt_time(h.seconds),
            fmt_energy(h.joules),
        ]);
    }
    t
}

/// Fig. 9(b,e): size sweep at the paper degree. Returns the table and
/// the RAPID seconds series (for the linearity shape test).
pub fn fig9_size(cfg: &SystemConfig, sizes: &[usize]) -> (Table, Vec<(usize, f64)>) {
    let mut t = Table::new(
        "Fig. 9(b,e) size sweep at degree 25.25",
        &["n", "RAPID time", "RAPID energy", "H100 time", "H100 energy"],
    );
    let mut series = Vec::new();
    for (i, &n) in sizes.iter().enumerate() {
        let w = Workload::ogbn_proxy_at(n, 95 + i as u64);
        let (rapid, _) = rapid_cost(&w, cfg);
        let h = gpu::h100().cost(n);
        t.row(&[
            fmt_count(n),
            fmt_time(rapid.seconds),
            fmt_energy(rapid.joules),
            fmt_time(h.seconds),
            fmt_energy(h.joules),
        ]);
        series.push((n, rapid.seconds));
    }
    (t, series)
}

/// Fig. 9(c,f): topology sweep at fixed size and degree. Returns the
/// table plus RAPID seconds per topology in input order.
pub fn fig9_topology(cfg: &SystemConfig, n: usize, topos: &[Topology]) -> (Table, Vec<f64>) {
    let mut t = Table::new(
        &format!(
            "Fig. 9(c,f) topology sweep at n={} deg={}",
            fmt_count(n),
            PAPER_DEGREE
        ),
        &["topology", "RAPID time", "RAPID energy", "boundary |B0|", "H100 time"],
    );
    let mut series = Vec::new();
    for (i, &topo) in topos.iter().enumerate() {
        let w = Workload {
            topo,
            n,
            degree: PAPER_DEGREE,
            seed: 99 + i as u64,
        };
        let (rapid, r) = rapid_cost(&w, cfg);
        let b0 = r.boundary_sizes.first().copied().unwrap_or(0);
        t.row(&[
            topo.name().into(),
            fmt_time(rapid.seconds),
            fmt_energy(rapid.joules),
            fmt_count(b0),
            fmt_time(gpu::h100().cost(n).seconds), // topology-insensitive
        ]);
        series.push(rapid.seconds);
    }
    (t, series)
}

/// Table III: area/power per PCM unit.
pub fn table3() -> Vec<Table> {
    let mut out = Vec::new();
    for unit in [crate::sim::area::pcm_fw_unit(), crate::sim::area::pcm_mp_unit()] {
        let mut t = Table::new(
            &format!("Table III — {} unit breakdown", unit.die),
            &["component", "area (um^2)", "area %", "power (mW)", "power %"],
        );
        let apct = unit.area_pct();
        let ppct = unit.power_pct();
        for (i, c) in unit.components.iter().enumerate() {
            t.row(&[
                c.name.into(),
                format!("{:.2}", c.area_um2),
                format!("{:.2}%", apct[i]),
                format!("{:.4}", c.power_mw),
                format!("{:.2}%", ppct[i]),
            ]);
        }
        t.row(&[
            "Total".into(),
            format!("{:.2}", unit.total_area_um2()),
            "100%".into(),
            format!("{:.2}", unit.total_power_mw()),
            "100%".into(),
        ]);
        out.push(t);
    }
    // system components (paper §IV-B)
    let mut t = Table::new(
        "System-level supporting components (§IV-B)",
        &["component", "power (W)", "area (mm^2)"],
    );
    for c in crate::sim::area::system_components() {
        t.row(&[c.name.into(), format!("{:.1}", c.power_w), format!("{:.0}", c.area_mm2)]);
    }
    out.push(t);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn fig7_shape_rapid_wins_and_gap_grows() {
        let cpu = CpuModel::paper();
        let sizes = [100usize, 1024, 8192];
        let mut cpu_ratios = Vec::new();
        for (i, &n) in sizes.iter().enumerate() {
            let w = Workload::nws(n, 70 + i as u64);
            let (rapid, _) = rapid_cost(&w, &cfg());
            let r = rapid.speedup_vs(&cpu.cost(n));
            cpu_ratios.push(r);
        }
        // RAPID must win at 1024+ and the gap must grow with size
        assert!(cpu_ratios[1] > 100.0, "1024: {cpu_ratios:?}");
        assert!(cpu_ratios[2] > cpu_ratios[1], "{cpu_ratios:?}");
    }

    #[test]
    fn fig7_headline_1024_within_band() {
        // paper: 1061x speedup, 7208x energy at n=1024 vs CPU. Allow a
        // wide band (we model, they measured) but require the order of
        // magnitude.
        let cpu = CpuModel::paper();
        let w = Workload::nws(1024, 71);
        let (rapid, _) = rapid_cost(&w, &cfg());
        let s = rapid.speedup_vs(&cpu.cost(1024));
        let e = rapid.energy_eff_vs(&cpu.cost(1024));
        assert!(s > 200.0 && s < 5000.0, "speedup {s} (paper: 1061)");
        assert!(e > 1000.0 && e < 40000.0, "energy {e} (paper: 7208)");
    }

    #[test]
    fn fig8_shape_rapid_beats_all_sota() {
        // scaled-down OGBN proxy (full 2.45M runs in the bench binary)
        let t = fig8(&cfg(), 200_000);
        assert!(!t.is_empty());
        let w = Workload::ogbn_proxy_at(200_000, 88);
        let (rapid, r) = rapid_cost(&w, &cfg());
        let part = cluster::partitioned_apsp(200_000);
        let copar = cluster::co_parallel_fw(200_000);
        let pim = pim::pim_apsp(200_000, r.graph_m);
        assert!(rapid.speedup_vs(&part) > 1.0);
        assert!(rapid.speedup_vs(&copar) > 1.0);
        assert!(rapid.speedup_vs(&pim) > 1.0);
        assert!(rapid.energy_eff_vs(&part) > 10.0);
    }

    #[test]
    fn fig9_degree_stability() {
        // paper: "flat performance across a 4x degree sweep" (12.5 ->
        // 50 around the OGBN mean) — RAPID time must move far less
        // than the 4x edge-count change
        let t = fig9_degree(&cfg(), 20_000, &[12.5, 25.25, 50.0]);
        assert!(!t.is_empty());
        let mut secs = Vec::new();
        for (i, &d) in [12.5f64, 50.0].iter().enumerate() {
            let w = Workload {
                topo: Topology::Nws,
                n: 20_000,
                degree: d,
                seed: 90 + i as u64,
            };
            secs.push(rapid_cost(&w, &cfg()).0.seconds);
        }
        let ratio = (secs[1] / secs[0]).max(secs[0] / secs[1]);
        assert!(ratio < 3.0, "degree sensitivity {ratio}");
    }

    #[test]
    fn fig9_size_near_linear() {
        // paper: RAPID scales linearly; check doubling n scales time by
        // ~2-4x (not ~8x like n^3 systems)
        let (_, series) = fig9_size(&cfg(), &[50_000, 100_000]);
        let ratio = series[1].1 / series[0].1;
        assert!(ratio < 6.0, "size scaling ratio {ratio} (want << 8)");
    }

    #[test]
    fn fig9_topology_ordering() {
        // paper: clustered (NWS) and real (OGBN) beat random (ER)
        let (_, series) = fig9_topology(
            &cfg(),
            30_000,
            &[Topology::OgbnProxy, Topology::Nws, Topology::Er],
        );
        assert!(
            series[0] < series[2] && series[1] < series[2],
            "clustered/real must beat random: {series:?}"
        );
    }

    #[test]
    fn table3_renders_all_units() {
        let tables = table3();
        assert_eq!(tables.len(), 3);
        let text = tables[0].render();
        assert!(text.contains("Permutation Unit"));
        let text = tables[1].render();
        assert!(text.contains("Min Comparator"));
    }
}
